"""Deterministic, shardable, restartable data loading.

Design (DESIGN.md §4): a *stateless* pipeline — batch ``i`` is a pure
function of ``(seed, i)`` — so checkpoints never store iterator state and
elastic restarts (different host count) re-shard by construction: host h
of H consumes indices ``i*H + h``.

On-the-fly generation (the SWE protocol in the paper) and pre-generated
cached epochs (the NS/Darcy protocol) are both supported; the cache is a
host-RAM numpy store filled once by the PDE solvers in ``repro.data``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterator

import numpy as np


@dataclasses.dataclass
class StatelessLoader:
    """Wraps sample_fn(seed, index) -> batch pytree."""

    sample_fn: Callable[[int, int], Dict]
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1

    def batch_at(self, step: int) -> Dict:
        index = step * self.num_hosts + self.host_id
        return self.sample_fn(self.seed, index)

    def __iter__(self) -> Iterator[Dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class CachedDataset:
    """Pre-generate N samples once; serve deterministic mini-batches.

    Batch b of epoch-less step s uses indices hash-shuffled by (seed, s) —
    restartable from the step number alone.
    """

    def __init__(self, arrays: Dict[str, np.ndarray], batch_size: int, seed: int = 0):
        sizes = {k: len(v) for k, v in arrays.items()}
        assert len(set(sizes.values())) == 1, sizes
        self.arrays = arrays
        self.n = next(iter(sizes.values()))
        self.batch_size = batch_size
        self.seed = seed

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState((self.seed * 1_000_003 + step) % (2 ** 31))
        idx = rng.randint(0, self.n, self.batch_size)
        return {k: v[idx] for k, v in self.arrays.items()}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
