"""repro.tune — on-hardware kernel autotuner with a persistent,
oracle-validated calibration cache.

The pieces:

  space    legal tile candidates per kernel family, enumerated from the
           same ``*vmem_bytes*`` estimators the static heuristics use
  measure  median-of-k train-step walls on the real backend (interpret
           fallback for CI), achieved GB/s + roofline fraction
  oracle   the admission gate: every candidate vs its einsum reference
           under the Thm 3.2 ``stages·4εM + c·ε_f32·M`` budget
  cache    versioned JSON keyed by (family, shape, dtype, backend,
           kernel_version); atomic writes, stale/corrupt detection,
           graceful fallback to the heuristic

``python -m repro.tune {tune,validate,report}`` drives the loop;
``cache.activate(path)`` or ``$REPRO_CALIBRATION_STATE`` makes the
winners reach ``repro.kernels.ops`` tile resolution everywhere.
"""
from .cache import (  # noqa: F401
    CalibrationCache,
    CalibrationError,
    activate,
    active_cache,
    entry_key,
    load,
    safe_load,
    save,
)
from .space import Candidate, candidates, legal_blocks, tile_vmem_bytes  # noqa: F401

__all__ = [
    "CalibrationCache", "CalibrationError", "activate", "active_cache",
    "entry_key", "load", "safe_load", "save",
    "Candidate", "candidates", "legal_blocks", "tile_vmem_bytes",
]
