"""repro.serve.paged tests: BlockPool invariants (property-based),
prefix sharing, copy-on-write, paged-vs-dense bit-identity across every
LM arch x scheduler x policy, pool pressure, and the async frontend."""
import asyncio
import dataclasses
import random
from collections import Counter

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.core import get_policy
from repro.models.lm import init_lm
from repro.serve import (
    AsyncServeFrontend,
    BlockPool,
    LMEngine,
    PagedLMEngine,
    PrefixIndex,
    Request,
)
from repro.serve.paged.pool import NULL_BLOCK

jax.config.update("jax_platform_name", "cpu")


def _cfg(arch):
    cfg = get_config(arch, smoke=True)
    if cfg.moe_experts:
        # MoE expert-capacity routing is batch-composition-dependent;
        # the paged contract (like chunked prefill's) is pinned on the
        # dense-equivalent archs, so tests strip the experts.
        cfg = dataclasses.replace(cfg, moe_experts=0, moe_shared=0, d_ff=32)
    return cfg


def _requests():
    return [
        Request(uid=u, prompt=[3, 1, 4, 1, 5, 9, 2, 6, 5, 3][: 4 + u % 6],
                max_new_tokens=3 + u % 3)
        for u in range(5)
    ]


_PARAMS = {}
_DENSE = {}


def _params_for(arch):
    if arch not in _PARAMS:
        cfg = _cfg(arch)
        _PARAMS[arch] = (cfg, init_lm(jax.random.PRNGKey(0), cfg))
    return _PARAMS[arch]


def _dense_run(arch, policy_name, chunk):
    """Dense reference run, cached per (arch, policy, chunk) — per-request
    logits don't depend on the admission order, so one dense run serves
    both scheduler legs."""
    key = (arch, policy_name, chunk)
    if key not in _DENSE:
        cfg, params = _params_for(arch)
        eng = LMEngine(params, cfg, n_slots=2, max_len=32,
                       policy=get_policy(policy_name), prefill_chunk=chunk,
                       record_logits=True)
        done, _ = eng.run_until_done(_requests())
        _DENSE[key] = (
            {r.uid: list(r.generated) for r in done},
            {r.uid: eng.logits_for(r.uid) for r in done},
        )
    return _DENSE[key]


# ---------------------------------------------------------------------------
# BlockPool
# ---------------------------------------------------------------------------


class TestBlockPool:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**32 - 1))
    def test_random_workload_invariants(self, seed):
        """Property: under any interleaving of alloc/fork/release/cow the
        pool never leaks, never double-frees, and refcounts always equal
        the number of outstanding owners."""
        rng = random.Random(seed)
        pool = BlockPool(num_blocks=12, block_size=4)
        owners = []  # one entry per outstanding reference
        for _ in range(200):
            op = rng.random()
            if op < 0.4:
                b = pool.alloc()
                if b is not None:
                    assert b != NULL_BLOCK
                    owners.append(b)
            elif op < 0.6 and owners:
                owners.append(pool.fork(rng.choice(owners)))
            elif op < 0.85 and owners:
                pool.release(owners.pop(rng.randrange(len(owners))))
            elif owners:
                j = rng.randrange(len(owners))
                b = owners[j]
                if pool.refcount(b) > 1 and pool.free_blocks == 0:
                    with pytest.raises(RuntimeError):
                        pool.cow(b)
                    continue
                dst, copy = pool.cow(b)
                owners[j] = dst
                # COW of an exclusive block is the identity (no copy)
                assert (copy is None) == (dst == b)
            # invariants after every op
            counts = Counter(owners)
            for b, n in counts.items():
                assert pool.refcount(b) == n
            assert pool.refcount(NULL_BLOCK) == 1
            assert pool.live_blocks == len(counts)  # null block excluded
            assert pool.free_blocks == 12 - 1 - len(counts)
        for b in owners:
            pool.release(b)
        assert pool.live_blocks == 0

    def test_double_free_rejected(self):
        pool = BlockPool(num_blocks=4, block_size=4)
        b = pool.alloc()
        assert pool.release(b)
        with pytest.raises(ValueError, match="not allocated"):
            pool.release(b)

    def test_null_block_is_reserved(self):
        pool = BlockPool(num_blocks=4, block_size=4)
        got = {pool.alloc() for _ in range(3)}
        assert NULL_BLOCK not in got
        assert pool.alloc() is None  # exhausted, never hands out block 0


class TestPrefixIndex:
    def test_register_lookup_evict(self):
        pool = BlockPool(num_blocks=8, block_size=2)
        idx = PrefixIndex(pool)
        toks = [1, 2, 3, 4, 5, 6]
        blocks = [pool.alloc() for _ in range(3)]
        idx.register(toks, blocks, 2, now=0)
        # the index holds its own reference on top of ours
        assert all(pool.refcount(b) == 2 for b in blocks)
        hit = idx.lookup(toks, 2, max_blocks=3, now=1)
        assert hit == blocks          # full-chain hit
        for b in hit:                 # lookup forked: caller owns these
            pool.release(b)
        assert idx.lookup([9, 9, 9, 9], 2, max_blocks=2, now=2) == []
        # leaf-only LRU eviction walks the chain back to the root
        assert idx.evict_one()
        assert idx.evict_one()
        assert idx.evict_one()
        assert not idx.evict_one()    # empty
        for b in blocks:
            pool.release(b)
        assert pool.live_blocks == 0


# ---------------------------------------------------------------------------
# Paged vs dense bit-identity
# ---------------------------------------------------------------------------

ARCHS = [
    "smollm-360m",           # pure attention (GQA)
    "mamba2-370m",           # pure SSD: engine degrades to the dense path
    "hymba-1.5b",            # hybrid attn+SSD with an SWA ring cache
    "deepseek-v2-lite-16b",  # MLA latent cache (MoE stripped)
]


class TestPagedBitIdentity:
    @pytest.mark.parametrize("arch", ARCHS)
    @pytest.mark.parametrize("sched", ["fcfs", "spf"])
    @pytest.mark.parametrize("policy_name", ["full", "mixed_fno_bf16"])
    def test_matches_dense_per_step_logits(self, arch, sched, policy_name):
        """The acceptance bar: same tokens AND bit-equal per-step logits
        for every arch x scheduler x policy."""
        self._check(arch, sched, policy_name, chunk=4)

    @pytest.mark.parametrize("chunk", [1, 8])
    def test_matches_dense_across_chunk_sizes(self, chunk):
        self._check("smollm-360m", "fcfs", "full", chunk=chunk)

    def _check(self, arch, sched, policy_name, chunk):
        cfg, params = _params_for(arch)
        d_tokens, d_logits = _dense_run(arch, policy_name, chunk)
        paged = PagedLMEngine(
            params, cfg, n_slots=2, max_len=32,
            policy=get_policy(policy_name), scheduler=sched,
            prefill_chunk=chunk, record_logits=True, block_size=8)
        p_done, _ = paged.run_until_done(_requests())
        p_tokens = {r.uid: list(r.generated) for r in p_done}
        assert p_tokens == d_tokens
        for uid, rows in d_logits.items():
            got = paged.logits_for(uid)
            assert len(got) == len(rows)
            for t, (a, b) in enumerate(zip(rows, got, strict=True)):
                assert np.array_equal(a, b), (uid, t)

    def test_ssd_arch_degrades_to_dense(self):
        cfg, params = _params_for("mamba2-370m")
        eng = PagedLMEngine(params, cfg, n_slots=2, max_len=32, block_size=8)
        assert eng.pool is None
        assert eng.stats()["paged"] == {
            "active": False, "reason": "ssd arch has no KV rows"}

    def test_block_size_must_divide_cache_width(self):
        cfg, params = _params_for("smollm-360m")
        with pytest.raises(ValueError, match="block_size"):
            PagedLMEngine(params, cfg, n_slots=2, max_len=32, block_size=7)

    def test_mesh_rejected(self):
        cfg, params = _params_for("smollm-360m")
        with pytest.raises(ValueError, match="single-host"):
            PagedLMEngine(params, cfg, mesh=object())


# ---------------------------------------------------------------------------
# Prefix sharing + COW
# ---------------------------------------------------------------------------


class TestPrefixSharing:
    def test_shared_prefix_skips_prefill_bit_identically(self):
        """Requests repeating a 16-token prefix: the paged engine must
        serve them with prefix hits and strictly fewer prefill tokens,
        while every generation stays bit-identical to dense."""
        cfg, params = _params_for("smollm-360m")
        shared = [7, 3, 9, 2, 8, 1, 4, 6, 5, 0, 2, 9, 1, 3, 4, 8]
        reqs = lambda: [Request(uid=u, prompt=shared + [u + 1, u + 2],  # noqa: E731
                                max_new_tokens=4) for u in range(6)]
        dense = LMEngine(params, cfg, n_slots=2, max_len=32,
                         prefill_chunk=4, record_logits=True)
        d_done, _ = dense.run_until_done(reqs())
        paged = PagedLMEngine(params, cfg, n_slots=2, max_len=32,
                              prefill_chunk=4, record_logits=True,
                              block_size=8)
        p_done, _ = paged.run_until_done(reqs())
        assert ({r.uid: r.generated for r in p_done}
                == {r.uid: r.generated for r in d_done})
        for r in d_done:
            for a, b in zip(dense.logits_for(r.uid),
                            paged.logits_for(r.uid), strict=True):
                assert np.array_equal(a, b)
        ps, ds = paged.stats(), dense.stats()
        prefix = ps["paged"]["prefix"]
        assert prefix["hits"] > 0 and prefix["tokens_reused"] > 0
        assert ps["prompt_tokens"] < ds["prompt_tokens"]
        # shared blocks mean fewer distinct physical blocks than
        # unshared backing would need
        assert ps["paged"]["peak_live_blocks"] < 6 * (32 // 8) + 1

    def test_prefix_disabled_still_bit_identical(self):
        cfg, params = _params_for("smollm-360m")
        shared = [7, 3, 9, 2, 8, 1, 4, 6, 5, 0, 2, 9, 1, 3, 4, 8]
        reqs = lambda: [Request(uid=u, prompt=shared + [u + 1],  # noqa: E731
                                max_new_tokens=3) for u in range(3)]
        on = PagedLMEngine(params, cfg, n_slots=2, max_len=32,
                           prefill_chunk=4, block_size=8)
        off = PagedLMEngine(params, cfg, n_slots=2, max_len=32,
                            prefill_chunk=4, block_size=8,
                            prefix_sharing=False)
        a, _ = on.run_until_done(reqs())
        b, _ = off.run_until_done(reqs())
        assert ({r.uid: r.generated for r in a}
                == {r.uid: r.generated for r in b})
        assert off.stats()["paged"]["prefix"] == {"enabled": False}

    def test_cow_on_divergent_write(self):
        """Force a write into a shared block: the engine must COW (fresh
        block, device copy of the already-written rows) and keep the
        generation bit-identical to dense."""
        cfg, params = _params_for("smollm-360m")
        req = Request(uid=0, prompt=[3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5, 8],
                      max_new_tokens=4)
        dense = LMEngine(params, cfg, n_slots=1, max_len=32,
                         prefill_chunk=4, record_logits=True)
        d_done, _ = dense.run_until_done(
            [Request(uid=0, prompt=list(req.prompt), max_new_tokens=4)])
        paged = PagedLMEngine(params, cfg, n_slots=1, max_len=32,
                              prefill_chunk=4, record_logits=True,
                              block_size=8)
        paged.submit(req)
        for _ in range(3):  # 12 prompt tokens / chunk 4 = 3 prefill ticks
            paged.tick()
        # rows 8..11 live in logical block 1; share it out from under the
        # engine (as a prefix entry would) before row 12 is written
        shared = int(paged._bt[0, 1])
        paged.pool.fork(shared)
        while req.status != "done":
            paged.tick()
        assert int(paged._bt[0, 1]) != shared     # COW swapped the block
        assert paged.pool.cow_copies == 1
        assert paged.pool.refcount(shared) == 1   # ours now; engine let go
        paged.pool.release(shared)
        assert req.generated == d_done[0].generated
        for a, b in zip(dense.logits_for(0), paged.logits_for(0),
                        strict=True):
            assert np.array_equal(a, b)


class TestPoolPressure:
    def test_eviction_keeps_serving(self):
        """A pool with barely more than one slot's backing: as requests
        with *distinct* prefixes accumulate index entries, allocation
        pressure must LRU-evict them instead of wedging."""
        cfg, params = _params_for("smollm-360m")
        mk = lambda: [Request(uid=u, prompt=[u + 1] * 8 + [1, 2],  # noqa: E731
                              max_new_tokens=3) for u in range(5)]
        paged = PagedLMEngine(params, cfg, n_slots=1, max_len=32,
                              prefill_chunk=4, block_size=8, num_blocks=6)
        done, _ = paged.run_until_done(mk())
        assert all(r.status == "done" for r in done)
        assert paged.stats()["paged"]["prefix"]["evictions"] > 0
        dense = LMEngine(params, cfg, n_slots=1, max_len=32, prefill_chunk=4)
        d_done, _ = dense.run_until_done(mk())
        assert ({r.uid: r.generated for r in done}
                == {r.uid: r.generated for r in d_done})

    def test_true_exhaustion_raises(self):
        cfg, params = _params_for("smollm-360m")
        paged = PagedLMEngine(params, cfg, n_slots=2, max_len=32,
                              prefill_chunk=4, block_size=8, num_blocks=4,
                              prefix_sharing=False)
        for u in range(2):
            paged.submit(Request(uid=u, prompt=[1, 2, 3, 4, 5, 6, 7, 8, 9],
                                 max_new_tokens=4))
        with pytest.raises(RuntimeError, match="pool exhausted"):
            paged.drain()


# ---------------------------------------------------------------------------
# Async frontend
# ---------------------------------------------------------------------------


class TestAsyncFrontend:
    def test_submit_stream_and_deadlines(self):
        cfg, params = _params_for("smollm-360m")
        engine = PagedLMEngine(params, cfg, n_slots=2, max_len=32,
                               prefill_chunk=4, block_size=8)
        ref = LMEngine(params, cfg, n_slots=2, max_len=32, prefill_chunk=4)
        r_done, _ = ref.run_until_done(
            [Request(uid=u, prompt=[2, 7, 1, 8, 2, 8], max_new_tokens=4)
             for u in range(2)])
        want = {r.uid: r.generated for r in r_done}

        async def main():
            front = AsyncServeFrontend(engine)
            streamed = []

            async def consume():
                async for tok in front.stream(
                        Request(uid=1, prompt=[2, 7, 1, 8, 2, 8],
                                max_new_tokens=4)):
                    streamed.append(tok)

            a = front.submit_async(
                Request(uid=0, prompt=[2, 7, 1, 8, 2, 8], max_new_tokens=4),
                deadline_ms=0.0)  # impossible deadline => accounted miss
            done0, _ = await asyncio.gather(a, consume())
            return front, done0, streamed

        front, done0, streamed = asyncio.run(main())
        assert done0.status == "done"
        assert done0.generated == want[0]
        assert streamed == want[1]
        m = front.metrics()
        assert m["requests"] == 2 and m["completed"] == 2
        assert m["deadline_misses"] == 1 and m["deadline_miss_rate"] == 1.0
        assert m["latency_ms"]["p99"] >= m["latency_ms"]["p50"] > 0
        recs = {r["uid"]: r for r in front.records}
        assert recs[0]["deadline_missed"] is True
        assert recs[1]["deadline_missed"] is False
        assert recs[0]["ttft_ms"] is not None

    def test_duplicate_uid_rejected(self):
        cfg, params = _params_for("smollm-360m")
        engine = PagedLMEngine(params, cfg, n_slots=1, max_len=32,
                               block_size=8)

        async def main():
            front = AsyncServeFrontend(engine)
            t = asyncio.ensure_future(front.submit_async(
                Request(uid=7, prompt=[1, 2, 3], max_new_tokens=2)))
            await asyncio.sleep(0)
            with pytest.raises(ValueError, match="already in flight"):
                await front.submit_async(
                    Request(uid=7, prompt=[4, 5], max_new_tokens=2))
            await t

        asyncio.run(main())
