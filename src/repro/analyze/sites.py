"""Site-rule pass: cross-check code-level site literals against the rule
tables, and the rule tables against the canonical site vocabulary.

Three checks:

  orphan-site (error)       a ``policy.at("...")`` / ``site="..."`` /
      ``tap("...")`` string literal in ``src/`` that no canonical site
      pattern can ever match — a typo'd address silently resolves
      through the ``"*"`` catch-all to full precision, which is exactly
      the "declared precision doesn't hold" bug this pass exists for.
  pattern-no-match (error)  a rule-table pattern (DEFAULT_RULES or any
      registry policy overlay) that matches no site in the canonical
      universe — dead configuration.
  shadowed-rule (error)     a rule entry every one of whose set fields
      is, at every site its pattern matches, already supplied by an
      earlier entry *of the same table* (field-wise first-match
      resolution never reads it).  Overlays shadowing DEFAULT_RULES are
      by design and not flagged; an entry dead within its own table is
      a bug.

f-string literals contribute their constant fragments with ``*`` holes
(``f"fno/layer{i}/spectral"`` -> ``fno/layer*/spectral``); a literal is
recognised if some hole filling (and, for prefix-style literals, some
known stage suffix) lands on a canonical pattern.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, List, Optional, Sequence, Tuple

from repro.precision.policy import CANONICAL_SITES, POLICIES
from repro.precision.rules import DEFAULT_RULES, RULE_FIELDS, UNSET, site_matches

from .findings import ERROR, Finding

#: Model prefixes the "model/..." canonical sites generalise over.
_MODEL_PREFIXES = ("model", "fno", "tfno", "sfno", "lm", "gino", "unet")
#: Pipeline-stage suffixes that prefix-style literals get completed with
#: (``site=f"fno/layer{i}/spectral"`` is a prefix; the callee appends the
#: stage).  Completion only applies to ``.../spectral`` prefixes: blindly
#: appending stages to arbitrary literals would let ``*/<stage>`` match
#: any junk prefix and the orphan check would never fire.
_STAGE_SUFFIXES = ("/fft_in", "/contract", "/fft_out")
#: Candidate strings substituted into f-string holes when testing whether
#: *some* runtime value could make the literal canonical.
_HOLE_FILLERS = ("0", "layer0", "fno", "fno/layer0",
                 "fno/layer0/spectral", "model/spectral", "lm/ssd/spectral")


def canonical_patterns() -> Tuple[str, ...]:
    """CANONICAL_SITES, with the ``model/`` entries generalised to any
    model prefix (``model/dense`` covers ``fno/dense``,
    ``fno/layer3/dense``, ...)."""
    pats: List[str] = []
    for s in CANONICAL_SITES:
        pats.append(s)
        if s.startswith("model/"):
            pats.append("*/" + s[len("model/"):])
    return tuple(pats)


def site_universe() -> Tuple[str, ...]:
    """A concrete expansion of the canonical vocabulary: every canonical
    site, plus the per-model / per-layer forms the ``model/*`` entries
    stand for.  Used to give fnmatch patterns something real to match."""
    sites = set(CANONICAL_SITES)
    for s in CANONICAL_SITES:
        if not s.startswith("model/"):
            continue
        suffix = s[len("model/"):]
        for m in _MODEL_PREFIXES:
            if m == "model":
                continue
            sites.add(f"{m}/{suffix}")
            for layer in range(8):
                sites.add(f"{m}/layer{layer}/{suffix}")
    # the LM's spectral SSD mixer addresses spectral stages under a
    # non-layer scope
    for stage in ("fft_in", "contract", "fft_out"):
        sites.add(f"lm/ssd/spectral/{stage}")
    return tuple(sorted(sites))


def _is_recognized(literal_pattern: str) -> bool:
    """True if some hole filling + stage suffix of the literal matches a
    canonical pattern (i.e. the literal can address a real site)."""
    pats = canonical_patterns()
    holes = literal_pattern.count("*")
    fillers = _HOLE_FILLERS if holes else ("",)
    for filler in fillers:
        concrete = literal_pattern.replace("*", filler)
        if any(site_matches(p, concrete) for p in pats):
            return True
        if concrete.split("/")[-1] == "spectral":
            for suffix in _STAGE_SUFFIXES:
                if any(site_matches(p, concrete + suffix) for p in pats):
                    return True
    return False


# ---------------------------------------------------------------------------
# AST scan for site literals
# ---------------------------------------------------------------------------


def _literal_pattern(node: ast.expr) -> Optional[str]:
    """A site pattern from a Constant-str or JoinedStr node (f-string
    holes become ``*``); None for anything non-literal."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    if isinstance(node, ast.JoinedStr):
        parts = []
        for v in node.values:
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                parts.append(v.value)
            else:
                parts.append("*")
        return "".join(parts)
    return None


class _SiteVisitor(ast.NodeVisitor):
    def __init__(self) -> None:
        self.literals: List[Tuple[int, str]] = []  # (lineno, pattern)

    def _add(self, node: ast.expr) -> None:
        pat = _literal_pattern(node)
        if pat is not None and pat:
            self.literals.append((node.lineno, pat))

    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        if (isinstance(func, ast.Attribute) and func.attr == "at"
                and node.args):
            self._add(node.args[0])
        if isinstance(func, ast.Name) and func.id == "tap" and node.args:
            self._add(node.args[0])
        # repro.obs numerics events carry a ``site=`` attribute naming an
        # *event location* (e.g. "serve/logits"), not a precision-site
        # address — a different namespace this check must not police.
        callee = (func.attr if isinstance(func, ast.Attribute)
                  else func.id if isinstance(func, ast.Name) else None)
        if callee != "numerics_event":
            for kw in node.keywords:
                if kw.arg == "site":
                    self._add(kw.value)
        self.generic_visit(node)

    def _defaults(self, node) -> None:
        args = node.args
        pos = args.posonlyargs + args.args
        for arg, default in zip(pos[len(pos) - len(args.defaults):],
                                args.defaults, strict=True):
            if arg.arg == "site":
                self._add(default)
        for arg, default in zip(args.kwonlyargs, args.kw_defaults, strict=True):
            if arg.arg == "site" and default is not None:
                self._add(default)

    def visit_FunctionDef(self, node) -> None:
        self._defaults(node)
        self.generic_visit(node)

    def visit_AsyncFunctionDef(self, node) -> None:
        self._defaults(node)
        self.generic_visit(node)


def scan_site_literals(root: str) -> List[Tuple[str, int, str]]:
    """All site string literals under ``root``: (relpath, lineno, pattern).
    Syntax errors are reported by raising — the lint gate should fail
    loudly on an unparseable tree, not skip it."""
    out: List[Tuple[str, int, str]] = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(
            d for d in dirnames
            if d not in ("__pycache__", ".git", ".ruff_cache"))
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            with open(path, "r", encoding="utf-8") as fh:
                tree = ast.parse(fh.read(), filename=path)
            v = _SiteVisitor()
            v.visit(tree)
            rel = os.path.relpath(path, root)
            out.extend((rel, lineno, pat) for lineno, pat in v.literals)
    return out


def orphan_site_findings(root: str) -> List[Finding]:
    findings = []
    for rel, lineno, pat in scan_site_literals(root):
        if not _is_recognized(pat):
            findings.append(Finding(
                pass_name="sites", check="orphan-site", severity=ERROR,
                site=pat, where=f"{rel}:{lineno}",
                detail=f"site literal {pat!r} matches no canonical site "
                       f"pattern — it would resolve through the '*' "
                       f"catch-all to full precision",
            ))
    return findings


# ---------------------------------------------------------------------------
# Rule-table checks
# ---------------------------------------------------------------------------


def _set_fields(rule) -> Tuple[str, ...]:
    return tuple(f for f in RULE_FIELDS if getattr(rule, f) is not UNSET)


def shadowed_entries(rules: Sequence, universe: Sequence[str]
                     ) -> List[Tuple[int, str, Tuple[str, ...]]]:
    """Indices of entries dead under field-wise first-match resolution
    *within this table*: every set field at every matched universe site
    is already supplied by an earlier entry.  Returns
    (index, pattern, dead_fields) tuples."""
    dead = []
    for k, (pattern, rule) in enumerate(rules):
        fields = _set_fields(rule)
        if not fields:
            continue
        matched = [u for u in universe if site_matches(pattern, u)]
        if not matched:
            continue  # pattern-no-match reports this separately
        live = False
        for u in matched:
            for f in fields:
                supplied = any(
                    site_matches(p_earlier, u)
                    and getattr(r_earlier, f) is not UNSET
                    for p_earlier, r_earlier in rules[:k]
                )
                if not supplied:
                    live = True
                    break
            if live:
                break
        if not live:
            dead.append((k, pattern, fields))
    return dead


def rule_table_findings(
    tables: Optional[Dict[str, Sequence]] = None
) -> List[Finding]:
    """pattern-no-match + shadowed-rule over every rule table.  The
    default tables are DEFAULT_RULES and each registry policy's overlay
    (each checked on its own: an overlay shadowing the base table is the
    design, an entry dead within its own table is a bug)."""
    if tables is None:
        tables = {"DEFAULT_RULES": DEFAULT_RULES}
        for name, pol in POLICIES.items():
            if pol.rules:
                tables[f"policy:{name}"] = pol.rules
    universe = site_universe()
    findings = []
    for table_name, rules in tables.items():
        for pattern, _rule in rules:
            if not any(site_matches(pattern, u) for u in universe):
                findings.append(Finding(
                    pass_name="sites", check="pattern-no-match",
                    severity=ERROR, site=pattern, where=table_name,
                    detail=f"rule pattern {pattern!r} matches no canonical "
                           f"site — dead configuration",
                ))
        for k, pattern, fields in shadowed_entries(rules, universe):
            findings.append(Finding(
                pass_name="sites", check="shadowed-rule", severity=ERROR,
                site=pattern, where=f"{table_name}[{k}]",
                detail=f"entry {k} ({pattern!r}, fields {list(fields)}) is "
                       f"shadowed dead: earlier entries supply every set "
                       f"field at every site it matches",
            ))
    return findings


def sites_pass(src_root: str) -> List[Finding]:
    return orphan_site_findings(src_root) + rule_table_findings()
