"""Mixed-precision Fourier convolution operator (paper Section 4.2, Fig. 2).

The FNO layer computes ``(K v)(x) = iFFT( R · T_K( FFT v ) )(x)``.  The paper
runs all three spectral stages — forward FFT, tensor contraction with the
learnable ``R``, inverse FFT — at half precision (Table 4 shows the
all-half setting wins on every metric), with a ``tanh`` pre-activation for
stability and a memory-greedy contraction order.

TPU adaptation (see DESIGN.md §2): XLA has no half-precision FFT on TPU, so
the transform itself runs in f32 while inputs/outputs are **quantised to the
half spectral dtype at the boundary** (``quantize_complex``).  This models
the representation error bounded by Theorem 3.2 — the quantity the paper's
theory actually analyses — and matches what the MXU pipeline does: bf16
storage, f32 accumulation.  The contraction genuinely executes at half
precision via split-real einsums (``core.contraction``), optionally through
the Pallas kernel (``repro.kernels``).
"""
from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from .precision import ComplexPair
from repro.precision import FULL, PrecisionPolicy


# ---------------------------------------------------------------------------
# Weight initialisation (dense / CP / Tucker factorisations — TFNO)
# ---------------------------------------------------------------------------


def _n_corners(ndim: int) -> int:
    # rfftn halves the last axis only; every other truncated axis keeps the
    # low and high mode blocks => 2^(ndim-1) corner blocks.
    return 2 ** (ndim - 1)


def cp_rank(in_channels: int, out_channels: int, rank: float) -> int:
    """The CP rank a ``rank`` fraction resolves to — shared by the weight
    initialiser and the dry-run VMEM budgeter so they can never drift."""
    return max(1, int(rank * min(in_channels, out_channels) * 2))


def init_spectral_weights(
    key: jax.Array,
    in_channels: int,
    out_channels: int,
    modes: Sequence[int],
    factorization: str = "dense",
    rank: float = 0.5,
) -> dict:
    """Spectral weights R for one layer.

    dense:  complex (corners, in, out, *modes), stored split-real f32.
    cp:     Canonical-Polyadic factors (paper §4.6 uses CP for NS/Darcy):
            weight[i,o,m1..md] = Σ_r λ_r A_i[i,r] A_o[o,r] Π_k A_mk[m_k,r].
    tucker: core (r_i, r_o, r_m1..r_md) + factor matrices.
    """
    ndim = len(modes)
    nc = _n_corners(ndim)
    scale = 1.0 / (in_channels * out_channels)
    if factorization == "dense":
        shape = (nc, in_channels, out_channels, *modes)
        k1, k2 = jax.random.split(key)
        return {
            "w_re": scale * jax.random.normal(k1, shape, jnp.float32),
            "w_im": scale * jax.random.normal(k2, shape, jnp.float32),
        }
    if factorization == "cp":
        r = cp_rank(in_channels, out_channels, rank)
        keys = jax.random.split(key, 2 * (2 + ndim) + 2)
        params = {}
        params["lam_re"] = scale * jax.random.normal(keys[0], (nc, r), jnp.float32)
        params["lam_im"] = scale * jax.random.normal(keys[1], (nc, r), jnp.float32)
        dims = [in_channels, out_channels, *modes]
        names = ["i", "o"] + [f"m{k}" for k in range(ndim)]
        for idx, (nm, ddim) in enumerate(zip(names, dims, strict=True)):
            params[f"U_{nm}_re"] = jax.random.normal(
                keys[2 + 2 * idx], (nc, ddim, r), jnp.float32
            ) / math.sqrt(r)
            params[f"U_{nm}_im"] = jax.random.normal(
                keys[3 + 2 * idx], (nc, ddim, r), jnp.float32
            ) / math.sqrt(r)
        return params
    if factorization == "tucker":
        # ranks proportional to each dim
        dims = [in_channels, out_channels, *modes]
        ranks = [max(1, int(rank * d)) for d in dims]
        keys = jax.random.split(key, 2 + 2 * len(dims))
        params = {}
        params["core_re"] = scale * jax.random.normal(keys[0], (nc, *ranks), jnp.float32)
        params["core_im"] = scale * jax.random.normal(keys[1], (nc, *ranks), jnp.float32)
        names = ["i", "o"] + [f"m{k}" for k in range(len(modes))]
        for idx, (nm, ddim, rr) in enumerate(zip(names, dims, ranks, strict=True)):
            params[f"U_{nm}_re"] = jax.random.normal(
                keys[2 + 2 * idx], (nc, ddim, rr), jnp.float32
            ) / math.sqrt(rr)
            params[f"U_{nm}_im"] = jax.random.normal(
                keys[3 + 2 * idx], (nc, ddim, rr), jnp.float32
            ) / math.sqrt(rr)
        return params
    raise ValueError(f"unknown factorization {factorization!r}")


def spectral_param_count(params: dict) -> int:
    return sum(
        int(v.size) for k, v in params.items() if isinstance(v, jnp.ndarray)
    )


# ---------------------------------------------------------------------------
# Mode-corner slicing
# ---------------------------------------------------------------------------


def _corner_slices(modes: Sequence[int], spectrum_shape: Sequence[int]):
    """Slices selecting each retained corner of the (r)fft spectrum.

    For every axis but the last we keep [:m] and [-m:]; the last (rfft) axis
    keeps [:m] only.  Yields tuples of slices, one per corner, ordered so
    that corner index bits map to axes (bit k set => high block on axis k).
    """
    ndim = len(modes)
    nc = _n_corners(ndim)
    out = []
    for c in range(nc):
        sl = []
        for ax in range(ndim - 1):
            m = modes[ax]
            if (c >> ax) & 1:
                sl.append(slice(spectrum_shape[ax] - m, spectrum_shape[ax]))
            else:
                sl.append(slice(0, m))
        sl.append(slice(0, modes[-1]))
        out.append(tuple(sl))
    return out


_EINSUM_SPATIAL = "xyzuvw"


def _dense_expr(ndim: int) -> str:
    sp = _EINSUM_SPATIAL[:ndim]
    return f"bi{sp},io{sp}->bo{sp}"


def _cp_exprs(ndim: int) -> str:
    sp = _EINSUM_SPATIAL[:ndim]
    mode_terms = ",".join(f"{ch}r" for ch in sp)
    return f"bi{sp},r,ir,or,{mode_terms}->bo{sp}"


def _tucker_expr(ndim: int) -> str:
    sp = _EINSUM_SPATIAL[:ndim]
    caps = "RSABCD"  # rank index letters: R=in-rank, S=out-rank, then modes
    core = "RS" + caps[2 : 2 + ndim]
    mode_terms = ",".join(f"{ch}{caps[2+k]}" for k, ch in enumerate(sp))
    return f"bi{sp},{core},iR,oS,{mode_terms}->bo{sp}"


def _kind(params: dict) -> str:
    """Infer the factorisation kind from the parameter keys (the params
    pytree must stay array-only so it is a valid grad/optimizer target)."""
    if "w_re" in params:
        return "dense"
    if "lam_re" in params:
        return "cp"
    if "core_re" in params:
        return "tucker"
    raise ValueError(f"unrecognised spectral params: {sorted(params)}")


def _corner_weight_ops(params: dict, corner: int, ndim: int):
    """Return (expr_suffix_ops, expr) for one corner's contraction."""
    kind = _kind(params)
    if kind == "dense":
        w = jax.lax.complex(params["w_re"][corner], params["w_im"][corner])
        return [w], _dense_expr(ndim)
    if kind == "cp":
        ops = [jax.lax.complex(params["lam_re"][corner], params["lam_im"][corner])]
        for nm in ["i", "o"] + [f"m{k}" for k in range(ndim)]:
            ops.append(
                jax.lax.complex(params[f"U_{nm}_re"][corner], params[f"U_{nm}_im"][corner])
            )
        return ops, _cp_exprs(ndim)
    if kind == "tucker":
        ops = [jax.lax.complex(params["core_re"][corner], params["core_im"][corner])]
        for nm in ["i", "o"] + [f"m{k}" for k in range(ndim)]:
            ops.append(
                jax.lax.complex(params[f"U_{nm}_re"][corner], params[f"U_{nm}_im"][corner])
            )
        return ops, _tucker_expr(ndim)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def spectral_conv_apply(
    params: dict,
    x: jnp.ndarray,
    modes: Sequence[int],
    policy: PrecisionPolicy = FULL,
    use_pallas: Optional[bool] = None,
    site: str = "model/spectral",
    fuse_spectral: Optional[bool] = None,
) -> jnp.ndarray:
    """Apply the Fourier convolution to ``x`` of shape (batch, ch, *spatial).

    Pipeline (Fig. 2): [stabiliser] -> FFT -> quantise -> truncate ->
    contract (memory-greedy, split-real half) -> scatter -> dequantise ->
    iFFT.  Each stage resolves its precision through the rule table at
    ``{site}/fft_in``, ``{site}/contract`` and ``{site}/fft_out`` — callers
    pass a per-layer prefix (``"fno/layer2/spectral"``) so layers can be
    addressed individually.  Under the ``full`` rule set every site
    resolves to f32/complex64 and this is the exact full-precision FNO
    reference.

    ``use_pallas``: tri-state.  ``None`` resolves via
    ``kernels.ops.resolve_use_pallas`` (on for TPU backends and under
    ``REPRO_USE_PALLAS=1``); when on, dense and CP contractions run the
    training-grade Pallas kernels (custom-VJP backward, same telemetry
    taps), while Tucker keeps the einsum path — its core tensor has no
    mode-major kernel layout.

    ``fuse_spectral``: tri-state (``kernels.ops.resolve_fuse_spectral``;
    kill switch ``REPRO_FUSE_SPECTRAL=0``).  When it resolves on — and
    the Pallas path is active, the layer is dense, and
    ``fused_spectral_viable`` admits the shape/policy (VMEM fit at the
    floor tile, no active autoprec collector) — the *whole* pipeline
    runs as the one-grid ``spectral_fused`` megakernel instead of
    rFFT/contract/irFFT round-tripping HBM between stages.
    """
    ndim = len(modes)
    spatial = x.shape[2:]
    assert len(spatial) == ndim, (x.shape, modes)
    in_dtype = x.dtype
    kind = _kind(params)
    if use_pallas is None or use_pallas:
        from repro.kernels.ops import resolve_use_pallas

        use_pallas = resolve_use_pallas(use_pallas)

    fft_in = policy.at(f"{site}/fft_in")
    ctr = policy.at(f"{site}/contract")
    fft_out = policy.at(f"{site}/fft_out")

    if use_pallas and kind == "dense":
        from repro.kernels import ops as kops

        if kops.resolve_fuse_spectral(fuse_spectral) and \
                kops.fused_spectral_viable(
                    fft_in, ctr, x.shape[0], x.shape[1],
                    _out_channels(params), spatial, modes):
            # the megakernel: one Pallas grid for the whole pipeline —
            # the spectrum lives in VMEM between the transform stages
            return kops.spectral_conv_fused(
                x, params["w_re"], params["w_im"], modes,
                policy=policy, site=site)

    # 1. stabiliser before the forward FFT (only active for half spectral)
    x = fft_in.stabilize(x)

    # 2. forward FFT in f32 (TPU has no half FFT); boundary quantisation
    #    models the half (or simulated fp8) representation per Thm 3.2.
    xf = jnp.fft.rfftn(x.astype(jnp.float32), axes=tuple(range(2, 2 + ndim)))
    xf = fft_in.quantize(xf)

    spectrum_shape = xf.shape[2:]
    corners = _corner_slices(modes, spectrum_shape)

    out_channels = _out_channels(params)
    out_f = jnp.zeros((x.shape[0], out_channels, *spectrum_shape), jnp.complex64)

    for c, sl in enumerate(corners):
        xc = xf[(slice(None), slice(None), *sl)]
        ops, expr = _corner_weight_ops(params, c, ndim)
        if use_pallas and kind == "dense":
            from repro.kernels import ops as kops

            yc = kops.spectral_contract(xc, ops[0], policy=ctr)
        elif use_pallas and kind == "cp":
            from repro.kernels import ops as kops

            yc = kops.spectral_contract_cp(
                xc, ops[0], ops[1], ops[2], ops[3:], policy=ctr)
        else:
            # Tucker (and any future factorisation without a kernel
            # layout) falls back to the memory-greedy einsum path —
            # explicitly, never by silently reinterpreting the params.
            yc = ctr.contract(expr, xc, *ops)
        if isinstance(yc, ComplexPair):
            yc = yc.to_complex()
        out_f = out_f.at[(slice(None), slice(None), *sl)].set(yc.astype(jnp.complex64))

    # 3. inverse FFT back to physical space.  named_scope: the analyzer
    # attributes the iFFT/storage-cast eqns to the fft_out site.
    with jax.named_scope(f"{site}/fft_out"):
        y = jnp.fft.irfftn(out_f, s=spatial, axes=tuple(range(2, 2 + ndim)))
        from repro.autoprec.telemetry import fmt_of, tap

        tap(f"{site}/fft_out", y, fmt=fmt_of(fft_out))
        if fft_out.spectral_is_half:
            # iFFT output also lives at half precision in the paper's pipeline
            y = y.astype(fft_out.compute_dtype)
        return y.astype(in_dtype)


def _out_channels(params: dict) -> int:
    if _kind(params) == "dense":
        return params["w_re"].shape[2]
    return params["U_o_re"].shape[1]
