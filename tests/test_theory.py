"""Direct tests for ``repro.core.theory``: Thm 3.1/3.2 bound
monotonicity (disc bound shrinks with n, prec bound scales with ε·M)
and the empirical estimators against the closed forms."""
import math

import jax
import numpy as np
import pytest

from repro.autoprec.certify import measured_prec_error, random_fourier_field
from repro.core import theory
from repro.core.precision import FORMAT_EPS

jax.config.update("jax_platform_name", "cpu")


class TestDiscBoundMonotonicity:
    def test_upper_bound_shrinks_with_n(self):
        ns = [64, 256, 1024, 4096, 16384]
        bs = [theory.disc_upper_bound(n, d=2, omega=1.0, L=1.0, M=1.0)
              for n in ns]
        assert all(b1 > b2 for b1, b2 in zip(bs, bs[1:], strict=False))

    def test_upper_bound_rate_is_n_pow_minus_1_over_d(self):
        for d in (1, 2, 3):
            b1 = theory.disc_upper_bound(256, d, 1.0, 1.0, 1.0)
            b2 = theory.disc_upper_bound(256 * 2 ** d, d, 1.0, 1.0, 1.0)
            np.testing.assert_allclose(b1 / b2, 2.0, rtol=1e-12)

    def test_lower_bound_shrinks_faster(self):
        # n^{-2/d} decays strictly faster than n^{-1/d}
        d = 2
        r_up = (theory.disc_upper_bound(100, d, 1, 1, 1)
                / theory.disc_upper_bound(10000, d, 1, 1, 1))
        r_lo = (theory.disc_lower_bound(100, d, 1.0)
                / theory.disc_lower_bound(10000, d, 1.0))
        assert r_lo > r_up

    def test_lower_below_upper_at_moderate_n(self):
        for n in (256, 4096, 65536):
            lo = theory.disc_lower_bound(n, 2, M=1.0)
            up = theory.disc_upper_bound(n, 2, omega=1.0, L=1.0, M=1.0)
            assert lo < up

    def test_grows_with_frequency_and_lipschitz(self):
        b = lambda omega, L: theory.disc_upper_bound(1024, 2, omega, L, 1.0)  # noqa: E731
        assert b(4.0, 1.0) > b(1.0, 1.0)
        assert b(1.0, 4.0) > b(1.0, 1.0)


class TestPrecBoundScaling:
    def test_linear_in_eps_and_M(self):
        base = theory.prec_upper_bound(1e-3, 1.0)
        np.testing.assert_allclose(theory.prec_upper_bound(2e-3, 1.0), 2 * base)
        np.testing.assert_allclose(theory.prec_upper_bound(1e-3, 3.0), 3 * base)
        # the paper's proof constant
        np.testing.assert_allclose(base, 4e-3)

    def test_lower_below_upper(self):
        assert (theory.prec_lower_bound(1e-3, 2.0)
                < theory.prec_upper_bound(1e-3, 2.0))

    def test_format_ladder_ordering(self):
        # coarser formats have strictly larger worst cases
        bounds = [theory.prec_upper_bound(FORMAT_EPS[f], 1.0)
                  for f in ("float32", "float16", "bfloat16",
                            "fp8_e4m3", "fp8_e5m2")]
        assert all(a < b for a, b in zip(bounds, bounds[1:], strict=False))

    def test_crossover_grows_as_eps_shrinks(self):
        # finer formats stay "free" up to larger meshes
        n_fp16 = theory.crossover_mesh_size(FORMAT_EPS["float16"], d=3)
        n_bf16 = theory.crossover_mesh_size(FORMAT_EPS["bfloat16"], d=3)
        assert n_fp16 > n_bf16
        assert n_fp16 > 1e5  # the paper's "n* ~ 1e6 for d=3, fp16" order


class TestEmpiricalEstimators:
    def test_disc_error_shrinks_with_mesh(self):
        v, L, M = random_fourier_field(0, d=2)
        errs = [theory.disc_error(v, m, 2, omega=1.0) for m in (6, 12, 24)]
        assert errs[0] > errs[-1]
        # and stays under the closed-form bound with the analytic L, M
        for m, e in zip((6, 12, 24), errs, strict=True):
            assert e <= theory.disc_upper_bound(m * m, 2, 1.0, L, M)

    @pytest.mark.parametrize("fmt", ["float16", "bfloat16", "fp8_e4m3"])
    def test_prec_error_under_bound(self, fmt):
        v, _, M = random_fourier_field(0, d=2)
        err = measured_prec_error(v, 12, 2, 1.0, fmt)
        assert err <= theory.prec_upper_bound(FORMAT_EPS[fmt], M)
        assert err > 0.0

    def test_prec_error_tracks_format_coarseness(self):
        v, _, _ = random_fourier_field(3, d=2)
        e16 = measured_prec_error(v, 12, 2, 1.0, "float16")
        e8 = measured_prec_error(v, 12, 2, 1.0, "fp8_e5m2")
        assert e8 > e16

    def test_estimate_lipschitz_and_bound(self):
        xs = np.linspace(0.0, 1.0, 65)[:-1]
        field = np.sin(2 * math.pi * xs)[None, :] * np.ones((64, 1))
        L, M = theory.estimate_lipschitz_and_bound(field)
        assert 0.9 <= M <= 1.0
        assert 5.0 <= L <= 2 * math.pi + 0.5  # |d/dx sin(2πx)| <= 2π
