"""Synthetic token streams for the LM architecture pool.

Stateless by construction: every (seed, step, position) maps to a token
through a counter-based PRNG (jax.random.fold_in), so the pipeline needs
no iterator state — restart-after-failure resumes bit-identically from the
step number alone (the fault-tolerance property DESIGN.md §4 relies on).

Tokens follow a Zipf-like marginal with short-range Markov structure so
perplexity is learnable (a pure-uniform stream has nothing to learn).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("batch", "seq_len", "vocab"))
def token_batch(
    seed: int | jax.Array,
    step: int | jax.Array,
    batch: int,
    seq_len: int,
    vocab: int,
) -> dict:
    """Deterministic batch at (seed, step): {'tokens': (B, S+1) int32}."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    kz, km = jax.random.split(key)
    # Zipf-ish marginal: map uniform -> vocab^(u) indices
    u = jax.random.uniform(kz, (batch, seq_len + 1))
    zipf = jnp.floor(vocab ** u) - 1.0
    base = jnp.clip(zipf, 0, vocab - 1).astype(jnp.int32)
    # short-range structure: with p=0.3 repeat the previous token + 1
    rep = jax.random.bernoulli(km, 0.3, (batch, seq_len + 1))

    def mix(prev, inp):
        tok, r = inp
        out = jnp.where(r, (prev + 1) % vocab, tok)
        return out, out

    _, toks = jax.lax.scan(
        mix, base[:, 0], (base.T, rep.T)
    )
    toks = jnp.swapaxes(toks, 0, 1)
    return {"tokens": toks}


def lm_inputs(seed, step, batch, seq_len, vocab):
    """Training view: inputs = tokens[:-1], labels = tokens[1:]."""
    b = token_batch(seed, step, batch, seq_len, vocab)["tokens"]
    return {"tokens": b[:, :-1], "labels": b[:, 1:]}
