"""Exporters: Chrome ``trace_event`` JSON, Prometheus text exposition,
JSONL event logs, and the shared benchmark-result header.

Every writer here is atomic in the :mod:`repro.tune.cache` style —
serialise to a temp file in the target directory, fsync, ``os.replace``
— so a crashed run leaves either the old artifact or the new one, never
a torn file that a dashboard or CI artifact-upload step then chokes on.

The JSONL record vocabulary (one JSON object per line) is the on-disk
mirror of the trace-ring vocabulary plus two framing records:

  ``{"kind": "meta", ...}``     the :func:`result_header` for the run
  ``{"kind": "span"|"event"|"b"|"e", ...}``   trace records verbatim
  ``{"kind": "metrics", "snapshot": {...}}``  final registry snapshot

so a single ``--obs-trace out.jsonl`` file carries the whole story and
``python -m repro.obs`` can render both the timeline (→ Chrome trace)
and the metrics table from it.
"""
from __future__ import annotations

import json
import os
import subprocess
import tempfile
from datetime import datetime, timezone
from typing import Any, Dict, Iterable, List, Optional

#: schema version of benchmark-result files and obs JSONL logs
RESULT_SCHEMA_VERSION = 1


# ---------------------------------------------------------------------------
# Atomic writers (the tune/cache.py pattern)
# ---------------------------------------------------------------------------

def _write_atomic(path: str, text: str) -> str:
    path = os.fspath(path)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=".obs-", suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return path


def write_json_atomic(path: str, payload: Any) -> str:
    """Atomically write ``payload`` as pretty-printed JSON."""
    return _write_atomic(path, json.dumps(payload, indent=2, sort_keys=True,
                                          default=str) + "\n")


def write_text_atomic(path: str, text: str) -> str:
    """Atomically write ``text`` (Prometheus exposition, reports)."""
    return _write_atomic(path, text)


# ---------------------------------------------------------------------------
# Shared benchmark-result header
# ---------------------------------------------------------------------------

def _git_sha() -> Optional[str]:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=5)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else None
    except (OSError, subprocess.SubprocessError):
        return None


def result_header(**extra) -> Dict[str, Any]:
    """The metadata header every ``benchmarks/results/*.json`` carries:
    schema version, backend, jax version, git sha, UTC timestamp, and
    the ``REPRO_*`` environment that shaped the run — the fields that
    make perf numbers machine-comparable across PRs."""
    import jax

    hdr: Dict[str, Any] = {
        "schema_version": RESULT_SCHEMA_VERSION,
        "backend": jax.default_backend(),
        "jax_version": jax.__version__,
        "git_sha": _git_sha(),
        "timestamp_utc": datetime.now(timezone.utc).isoformat(
            timespec="seconds"),
        "env": {k: v for k, v in sorted(os.environ.items())
                if k.startswith("REPRO_")},
    }
    hdr.update(extra)
    return hdr


def write_result(path: str, payload: Dict[str, Any], **meta) -> str:
    """Atomically write a benchmark-result JSON with the shared header
    under ``"meta"`` (existing top-level keys of ``payload`` are kept;
    a pre-existing ``"meta"`` key is merged under the header)."""
    doc = dict(payload)
    hdr = result_header(**meta)
    prior = doc.get("meta")
    if isinstance(prior, dict):
        hdr = {**prior, **hdr}
    doc["meta"] = hdr
    return write_json_atomic(path, doc)


# ---------------------------------------------------------------------------
# JSONL event logs
# ---------------------------------------------------------------------------

def write_jsonl(path: str, records: Iterable[Dict[str, Any]]) -> str:
    """Atomically write one JSON object per line."""
    lines = [json.dumps(r, sort_keys=True, default=str) for r in records]
    return _write_atomic(path, "\n".join(lines) + ("\n" if lines else ""))


def read_jsonl(path: str) -> List[Dict[str, Any]]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def run_records(trace_records: Iterable[Dict[str, Any]],
                snapshot: Optional[Dict[str, Any]] = None,
                **meta) -> List[Dict[str, Any]]:
    """Frame trace records into the JSONL run vocabulary: a ``meta``
    header first, the timeline verbatim, a final ``metrics`` record."""
    recs: List[Dict[str, Any]] = [{"kind": "meta", **result_header(**meta)}]
    recs.extend(trace_records)
    if snapshot is not None:
        recs.append({"kind": "metrics", "snapshot": snapshot})
    return recs


# ---------------------------------------------------------------------------
# Chrome trace_event JSON (Perfetto-loadable)
# ---------------------------------------------------------------------------

_TIMELINE_KINDS = ("span", "event", "b", "e")


def chrome_trace(records: Iterable[Dict[str, Any]],
                 process_name: str = "repro") -> Dict[str, Any]:
    """Convert trace records (``trace.snapshot()`` dicts or JSONL rows)
    to the Chrome ``trace_event`` JSON object format.

    Spans become ``ph:"X"`` complete events, instants ``ph:"i"``, and
    async begin/end pairs ``ph:"b"``/``ph:"e"`` correlated by ``id`` —
    Perfetto renders the latter as per-request async tracks.  ts/dur are
    microseconds per the spec.
    """
    evs: List[Dict[str, Any]] = [{
        "ph": "M", "pid": 1, "tid": 0, "name": "process_name",
        "args": {"name": process_name},
    }]
    tids = {}
    for rec in records:
        kind = rec.get("kind")
        if kind not in _TIMELINE_KINDS:
            continue
        tid = tids.setdefault(rec.get("tid", 0), len(tids) + 1)
        ts_us = rec.get("ts_ns", 0) / 1000.0
        args = dict(rec.get("attrs") or {})
        ev: Dict[str, Any] = {
            "name": rec.get("name", "?"), "pid": 1, "tid": tid, "ts": ts_us,
        }
        if kind == "span":
            ev["ph"] = "X"
            ev["dur"] = rec.get("dur_ns", 0) / 1000.0
            ev["cat"] = rec.get("category") or "span"
            if rec.get("parent") is not None:
                args["parent"] = rec["parent"]
        elif kind == "event":
            ev["ph"] = "i"
            ev["s"] = "t"
            ev["cat"] = rec.get("category") or "event"
        else:  # b / e
            ev["ph"] = kind
            ev["cat"] = rec.get("category") or "async"
            ev["id"] = str(rec.get("id"))
        if args:
            ev["args"] = args
        evs.append(ev)
    return {"traceEvents": evs, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, records: Iterable[Dict[str, Any]],
                       **kw) -> str:
    return write_json_atomic(path, chrome_trace(records, **kw))


def validate_chrome_trace(doc: Dict[str, Any]) -> List[str]:
    """Schema check against the trace_event object format; returns a
    list of defects (empty == valid).  Used by the export tests and the
    CLI so a malformed trace fails loudly before someone drags it into
    Perfetto."""
    errs: List[str] = []
    if not isinstance(doc, dict) or not isinstance(
            doc.get("traceEvents"), list):
        return ["top level must be {'traceEvents': [...]}"]
    open_async: Dict[tuple, int] = {}
    for i, ev in enumerate(doc["traceEvents"]):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errs.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "i", "b", "e", "M", "B", "E"):
            errs.append(f"{where}: unknown ph {ph!r}")
            continue
        if not isinstance(ev.get("name"), str):
            errs.append(f"{where}: missing name")
        if ph != "M":
            if not isinstance(ev.get("ts"), (int, float)):
                errs.append(f"{where}: missing numeric ts")
            if "pid" not in ev or "tid" not in ev:
                errs.append(f"{where}: missing pid/tid")
        if ph == "X" and not isinstance(ev.get("dur"), (int, float)):
            errs.append(f"{where}: complete event missing dur")
        if ph in ("b", "e"):
            if "id" not in ev:
                errs.append(f"{where}: async event missing id")
            else:
                key = (ev.get("cat"), ev.get("name"), ev["id"])
                open_async[key] = open_async.get(key, 0) + (
                    1 if ph == "b" else -1)
    for key, n in open_async.items():
        if n > 0:
            errs.append(f"async {key} has {n} unmatched begin(s)")
    return errs


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------

def _split_series(series: str):
    """``name{k="v",...}`` -> (name, 'k="v",...'); bare name -> (name, '')."""
    if "{" in series:
        name, inner = series.split("{", 1)
        return name, inner.rstrip("}")
    return series, ""


def _with_label(inner: str, extra: str) -> str:
    return f"{inner},{extra}" if inner else extra


def prometheus_text(snapshot: Dict[str, Any]) -> str:
    """Render a registry snapshot in the Prometheus text exposition
    format (``# TYPE`` headers; histograms as cumulative
    ``_bucket{le=...}`` + ``_sum`` + ``_count``)."""
    lines: List[str] = []
    typed = set()

    def type_line(name: str, kind: str):
        if name not in typed:
            typed.add(name)
            lines.append(f"# TYPE {name} {kind}")

    for series, v in snapshot.get("counters", {}).items():
        name, inner = _split_series(series)
        type_line(name, "counter")
        lines.append(f"{name}{{{inner}}} {v:g}" if inner
                     else f"{name} {v:g}")
    for series, v in snapshot.get("gauges", {}).items():
        name, inner = _split_series(series)
        type_line(name, "gauge")
        lines.append(f"{name}{{{inner}}} {v:g}" if inner
                     else f"{name} {v:g}")
    for series, h in snapshot.get("histograms", {}).items():
        name, inner = _split_series(series)
        type_line(name, "histogram")
        cum = 0
        for edge, c in zip(h["edges"], h["counts"][:-1]):
            cum += c
            lab = _with_label(inner, f'le="{edge:g}"')
            lines.append(f"{name}_bucket{{{lab}}} {cum}")
        cum += h["counts"][-1]
        lab = _with_label(inner, 'le="+Inf"')
        lines.append(f"{name}_bucket{{{lab}}} {cum}")
        lines.append(f"{name}_sum{{{inner}}} {h['sum']:g}" if inner
                     else f"{name}_sum {h['sum']:g}")
        lines.append(f"{name}_count{{{inner}}} {h['count']}" if inner
                     else f"{name}_count {h['count']}")
    return "\n".join(lines) + ("\n" if lines else "")


def write_prometheus(path: str, snapshot: Dict[str, Any]) -> str:
    return write_text_atomic(path, prometheus_text(snapshot))
