"""SLO replay harness for the paged serving stack (CORTEX-style).

Drives a timed request trace — Poisson arrivals over shared prompt
templates, or a replayed ``--trace`` file — through the async frontend
against BOTH the paged and the dense LM engine, and records the full
latency distribution (p50/p90/p99, mean, max), jitter (latency stddev),
deadline-miss rate, and the paged-only wins: prefix-hit rate, prefill
tokens skipped, and peak physical blocks vs the dense per-slot backing.

    PYTHONPATH=src python -m benchmarks.bench_serve_slo --smoke
    PYTHONPATH=src python -m benchmarks.bench_serve_slo \
        --save-trace /tmp/trace.json
    PYTHONPATH=src python -m benchmarks.bench_serve_slo \
        --trace /tmp/trace.json

Arrivals are wall-clock: the replay sleeps each request until its trace
timestamp before submitting, so the engine sees the trace's actual
burstiness.  Greedy generations are asserted identical between the two
engines, so every recorded delta is scheduling/memory, not numerics.
Results land in ``benchmarks/results/serve_slo.json``.
"""
from __future__ import annotations

import argparse
import asyncio
import json
import os

import jax
import numpy as np

from repro.configs import get_config
from repro.models.lm import init_lm
from repro.serve import AsyncServeFrontend, LMEngine, PagedLMEngine, Request

RESULTS = os.path.join(os.path.dirname(__file__), "results",
                       "serve_slo.json")


def make_trace(n_requests: int, rate_hz: float, n_templates: int,
               template_len: int, suffix_len: int, max_new: int,
               deadline_ms: float, vocab: int, seed: int = 0) -> dict:
    """Poisson arrivals over a small pool of shared prompt templates.

    Real serving traffic repeats system prompts / few-shot headers; the
    template pool models that, so the paged engine's prefix index has
    something to hit while the dense engine re-prefills every time.
    """
    rng = np.random.RandomState(seed)
    templates = [list(map(int, rng.randint(1, vocab, template_len)))
                 for _ in range(n_templates)]
    t, items = 0.0, []
    for uid in range(n_requests):
        t += float(rng.exponential(1.0 / rate_hz))
        prompt = (templates[int(rng.randint(n_templates))]
                  + list(map(int, rng.randint(1, vocab, suffix_len))))
        items.append({"t": round(t, 6), "uid": uid, "prompt": prompt,
                      "max_new_tokens": max_new,
                      "deadline_ms": deadline_ms})
    return {"rate_hz": rate_hz, "n_templates": n_templates,
            "template_len": template_len, "items": items}


async def _replay(front: AsyncServeFrontend, trace: dict) -> dict:
    """Submit every trace item at its wall-clock arrival offset."""
    loop = asyncio.get_running_loop()
    t0 = loop.time()

    async def one(item):
        delay = item["t"] - (loop.time() - t0)
        if delay > 0:
            await asyncio.sleep(delay)
        req = Request(uid=item["uid"], prompt=list(item["prompt"]),
                      max_new_tokens=item["max_new_tokens"])
        return await front.submit_async(req,
                                        deadline_ms=item["deadline_ms"])
    done = await asyncio.gather(*[one(it) for it in trace["items"]])
    return {r.uid: list(r.generated) for r in done}


def run_engine(kind: str, trace: dict, cfg, params, n_slots: int,
               max_len: int, prefill_chunk: int, block_size: int) -> dict:
    if kind == "paged":
        engine = PagedLMEngine(params, cfg, n_slots=n_slots, max_len=max_len,
                               prefill_chunk=prefill_chunk,
                               block_size=block_size)
    else:
        engine = LMEngine(params, cfg, n_slots=n_slots, max_len=max_len,
                          prefill_chunk=prefill_chunk)
    # compile outside the timed replay (jit warmup would otherwise land
    # entirely on the first request's latency)
    warm, _ = engine.run_until_done(
        [Request(uid=-1, prompt=[1] * (prefill_chunk + 1),
                 max_new_tokens=2)])
    assert all(r.done for r in warm)
    front = AsyncServeFrontend(engine)
    generations = asyncio.run(_replay(front, trace))
    stats = engine.stats()
    row = {
        "slo": front.metrics(),
        "prefill_tokens": stats["prompt_tokens"],
        "tokens_generated": stats["tokens_generated"],
        "ticks": stats["ticks"],
    }
    if kind == "paged":
        paged = stats["paged"]
        row["blocks"] = {
            "block_size": paged["block_size"],
            "peak_live_blocks": paged["peak_live_blocks"],
            "dense_equivalent_blocks": n_slots * paged["blocks_per_slot"],
            "cow_copies": paged["cow_copies"],
            "fragmentation": paged["fragmentation"],
        }
        row["prefix"] = paged["prefix"]
    return row, generations


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--n-requests", type=int, default=24)
    ap.add_argument("--rate-hz", type=float, default=8.0)
    ap.add_argument("--n-templates", type=int, default=3)
    ap.add_argument("--template-len", type=int, default=24)
    ap.add_argument("--suffix-len", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--deadline-ms", type=float, default=2000.0)
    ap.add_argument("--n-slots", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--prefill-chunk", type=int, default=8)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", help="replay this trace JSON instead of "
                    "generating Poisson arrivals")
    ap.add_argument("--save-trace", help="write the generated trace here "
                    "(for later --trace replay) and exit")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI-sized run (few requests, short prompts)")
    ap.add_argument("--out", default=RESULTS)
    args = ap.parse_args(argv)

    if args.smoke:
        args.n_requests, args.rate_hz = 8, 50.0
        args.template_len, args.suffix_len, args.max_new = 16, 2, 3
        args.max_len, args.deadline_ms = 32, 5000.0

    cfg = get_config(args.arch, smoke=True)
    if args.trace:
        with open(args.trace) as f:
            trace = json.load(f)
    else:
        trace = make_trace(args.n_requests, args.rate_hz, args.n_templates,
                           args.template_len, args.suffix_len, args.max_new,
                           args.deadline_ms, vocab=cfg.vocab,
                           seed=args.seed)
    if args.save_trace:
        with open(args.save_trace, "w") as f:
            json.dump(trace, f)
        print(f"wrote {len(trace['items'])} arrivals -> {args.save_trace}")
        return trace

    params = init_lm(jax.random.PRNGKey(0), cfg)
    run = lambda kind: run_engine(  # noqa: E731
        kind, trace, cfg, params, args.n_slots, args.max_len,
        args.prefill_chunk, args.block_size)
    paged_row, paged_gen = run("paged")
    dense_row, dense_gen = run("dense")
    assert paged_gen == dense_gen, \
        "paged generations diverged from dense — numerics bug"

    out = {
        "arch": args.arch,
        "trace": {"n_requests": len(trace["items"]),
                  "rate_hz": trace.get("rate_hz"),
                  "n_templates": trace.get("n_templates"),
                  "deadline_ms": args.deadline_ms,
                  "replayed_from": args.trace},
        "engine": {"n_slots": args.n_slots, "max_len": args.max_len,
                   "prefill_chunk": args.prefill_chunk,
                   "block_size": args.block_size},
        "paged": paged_row,
        "dense": dense_row,
        "comparison": {
            "bit_identical_generations": True,
            "prefill_tokens_saved": (dense_row["prefill_tokens"]
                                     - paged_row["prefill_tokens"]),
            # block writes the prefix index turned into shared references
            "blocks_saved": (paged_row["prefix"]["tokens_reused"]
                             // args.block_size),
            "prefix_hit_rate": paged_row["prefix"]["hit_rate"],
        },
    }
    from benchmarks.common import write_result

    write_result(args.out, out)
    c = out["comparison"]
    print(f"p50 {paged_row['slo']['latency_ms']['p50']}ms  "
          f"p99 {paged_row['slo']['latency_ms']['p99']}ms  "
          f"jitter {paged_row['slo']['jitter_ms']}ms  "
          f"miss {paged_row['slo']['deadline_miss_rate']}")
    print(f"prefix hit rate {c['prefix_hit_rate']}  "
          f"prefill tokens saved {c['prefill_tokens_saved']}  "
          f"blocks saved {c['blocks_saved']}")
    print(f"wrote {args.out}")
    return out


if __name__ == "__main__":
    main()
