"""Synthetic Shape-Net-Car-like CFD dataset for GINO (paper §B.2).

Each sample is a random superellipsoid "car body" surface point cloud with
a potential-flow surface-pressure label (the classic sphere/ellipsoid
coefficient C_p = 1 - 9/4 sin²θ generalised to the local surface normal
against the inlet direction).  The data pipeline also precomputes the
fixed-k neighbour candidate lists + radius masks that GINO's JAX port
consumes (DESIGN.md §7), using brute-force numpy KNN — this runs once per
sample at generation time, off the training hot path.
"""
from __future__ import annotations

import numpy as np


def _superellipsoid_points(rng: np.random.RandomState, n_points: int):
    """Sample surface points + outward normals of a random superellipsoid
    centred in [0,1]^3."""
    e1 = rng.uniform(0.6, 1.4)
    e2 = rng.uniform(0.6, 1.4)
    ax = np.array([rng.uniform(0.30, 0.42), rng.uniform(0.14, 0.22), rng.uniform(0.10, 0.18)])
    theta = np.arccos(rng.uniform(-1, 1, n_points))
    phi = rng.uniform(0, 2 * np.pi, n_points)

    def sgnpow(x, p):
        return np.sign(x) * np.abs(x) ** p

    x = ax[0] * sgnpow(np.sin(theta), e1) * sgnpow(np.cos(phi), e2)
    y = ax[1] * sgnpow(np.sin(theta), e1) * sgnpow(np.sin(phi), e2)
    z = ax[2] * sgnpow(np.cos(theta), e1)
    pts = np.stack([x, y, z], axis=-1)
    # normals ∝ gradient of the implicit function; approximate by the
    # ellipsoidal normal (adequate for labels/features)
    normals = pts / (ax ** 2)
    normals /= np.linalg.norm(normals, axis=-1, keepdims=True) + 1e-9
    pts = pts + 0.5  # centre in unit cube
    return pts.astype(np.float32), normals.astype(np.float32)


def _pressure_label(normals: np.ndarray, inlet=None):
    """Potential-flow-style C_p from the angle between surface normal and
    the inlet direction: C_p = 1 - 9/4 sin²θ (sphere potential flow)."""
    if inlet is None:
        inlet = np.array([1.0, 0.0, 0.0])
    c = normals @ inlet
    s2 = 1.0 - c ** 2
    return (1.0 - 2.25 * s2).astype(np.float32)[:, None]


def _knn(src: np.ndarray, dst: np.ndarray, k: int, radius: float):
    """For each dst point: indices of k nearest src points + radius mask."""
    d2 = ((dst[:, None, :] - src[None, :, :]) ** 2).sum(-1)
    idx = np.argsort(d2, axis=1)[:, :k]
    dist = np.sqrt(np.take_along_axis(d2, idx, axis=1))
    mask = (dist <= radius).astype(np.float32)
    # always keep at least the nearest neighbour
    mask[:, 0] = 1.0
    return idx.astype(np.int32), mask


def latent_grid_coords(G: int) -> np.ndarray:
    t = np.linspace(0.0, 1.0, G)
    gx, gy, gz = np.meshgrid(t, t, t, indexing="ij")
    return np.stack([gx, gy, gz], axis=-1).reshape(-1, 3).astype(np.float32)


def sample_car_batch(
    seed: int,
    batch: int,
    n_points: int = 256,
    latent_grid: int = 8,
    k: int = 8,
    radius: float = 0.35,
):
    """Returns (batch_dict, labels).  batch_dict matches gino_apply."""
    rng = np.random.RandomState(seed)
    lat = latent_grid_coords(latent_grid)
    out = {
        "points": [], "feats": [], "enc_idx": [], "enc_mask": [],
        "query": [], "dec_idx": [], "dec_mask": [],
    }
    labels = []
    for _ in range(batch):
        pts, normals = _superellipsoid_points(rng, n_points)
        enc_idx, enc_mask = _knn(pts, lat, k, radius)
        dec_idx, dec_mask = _knn(lat, pts, k, radius)
        out["points"].append(pts)
        out["feats"].append(normals[:, :1])  # inlet-aligned normal component
        out["enc_idx"].append(enc_idx)
        out["enc_mask"].append(enc_mask)
        out["query"].append(pts)
        out["dec_idx"].append(dec_idx)
        out["dec_mask"].append(dec_mask)
        labels.append(_pressure_label(normals))
    batch_dict = {kk: np.stack(v) for kk, v in out.items()}
    return batch_dict, np.stack(labels)
