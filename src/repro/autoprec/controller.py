"""Bound-guided adaptive precision control (the decision half of autoprec).

Turns runtime telemetry (:mod:`repro.autoprec.telemetry`) into
``precision_rules(...)`` overlays over a base policy.  The decision rule
closes the loop the paper leaves open: it demotes a site group below
fp32 only while

1. **theory budget** — the Thm 3.2 worst case for the candidate format,
   ``4 ε``, stays within ``target_fraction`` of the Thm 3.1
   discretisation bound at the current grid (both evaluated in relative
   terms on the unit-normalised field: the data pipeline whitens to O(1)
   and the tanh stabiliser enforces ``M <= 1``, so amax feeds the range
   checks while the ε-vs-n trade is resolution-driven, exactly the
   paper's "precision error is dominated by discretisation error"
   argument — finer grids earn tighter formats);
2. **dynamic range** — the observed (decayed-peak, FP8-delayed-scaling
   style) amax times ``range_margin`` fits the format's max finite
   value, and the exponent histogram puts at most ``underflow_limit`` of
   the non-zero mass below its smallest normal;
3. **hysteresis** — the site has been overflow-clean for
   ``demote_patience`` consecutive controller updates and is not inside
   the post-change ``cooldown``.

Overflow streaks (``promote_streak`` consecutive dirty windows) promote
the group straight back to fp32 and start a cooldown — the "recover
first, re-earn the demotion later" contract that keeps training free of
non-recovered overflows.

Decisions are grouped at the spectral-pipeline level
(``fno/layer2/spectral`` covers its ``fft_in/contract/fft_out`` taps) and
emitted as ordinary rule entries, so every consumer — trainer, serving
engines, dry-runs — picks them up through the one resolution path
``policy.at(site)`` already uses.  A format choice that needs loss
scaling (fp16-family) switches the ``train/loss_scale`` site on in the
same overlay.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple, Union

import jax.numpy as jnp

from repro.core import theory
from repro.core.precision import FORMAT_EPS, FORMAT_MAX, FORMAT_TINY
from repro.precision import (
    FULL_PRECISION,
    PrecisionPolicy,
    SiteRule,
    get_policy,
    site_matches,
)

from .telemetry import SiteWindow

#: Formats that require dynamic loss scaling when used in training
#: (small-eps grids whose gradients flush to zero without it).
_NEEDS_LOSS_SCALING = ("float16", "fp8_e4m3", "fp8_e5m2")


def group_of(site: str) -> str:
    """Collapse a tap site onto its control group: the three spectral
    stages of one layer decide together (``fno/layer2/spectral/fft_in``
    -> ``fno/layer2/spectral``); other sites stand alone."""
    head, sep, _ = site.rpartition("/spectral/")
    return head + "/spectral" if sep else site


@dataclasses.dataclass
class ControllerConfig:
    """Knobs of the adaptive controller (see module docstring)."""

    target_fraction: float = 0.5     # precision budget as a fraction of
                                     # the Thm 3.1 discretisation bound
    grid_points: Optional[int] = None  # n (points of the physical grid);
                                       # engines pass it per batch
    spatial_dim: int = 2             # d in the Thm 3.1 rate n^{-1/d}
    omega: float = 1.0               # characteristic frequency |ω|
    rel_lipschitz: float = 1.0       # L/M of the unit-normalised field
    range_margin: float = 4.0        # amax headroom a format must cover
    underflow_limit: float = 0.01    # max fraction below smallest normal
    demote_patience: int = 2         # clean updates before a demotion
    promote_streak: int = 2          # dirty updates before a promotion
    cooldown: int = 3                # updates after any change in which
                                     # no demotion may happen
    amax_decay: float = 0.9          # decayed-peak amax tracking
    interval: int = 10               # trainer steps between updates
    #: Candidate formats, cheapest first; fp32 is the implicit fallback.
    formats: Tuple[str, ...] = (
        "fp8_e4m3", "fp8_e5m2", "bfloat16", "float16")
    #: Which control groups the controller may touch.
    control: Tuple[str, ...] = ("*/spectral",)


@dataclasses.dataclass
class SiteState:
    """Per-group hysteresis state."""

    fmt: str = "float32"
    amax: float = 0.0            # decayed peak
    clean: int = 0               # consecutive overflow-free updates
    overflow_streak: int = 0     # consecutive dirty updates
    cooldown: int = 0
    eps_budget: float = 0.0      # last computed ε ceiling (for reports)


#: Rule entries realising one format decision for a group pattern.
def _rules_for(pattern: str, fmt: str) -> Tuple[Tuple[str, SiteRule], ...]:
    if fmt == "float32":
        return ((pattern, FULL_PRECISION),)
    if fmt in ("bfloat16", "float16"):
        dt = jnp.bfloat16 if fmt == "bfloat16" else jnp.float16
        return ((pattern, SiteRule(compute=dt, quantize="half",
                                   stabilize="tanh")),)
    # simulated fp8: split-real fp16 storage rounded onto the fp8 grid
    return ((pattern, SiteRule(compute=jnp.float16, quantize=fmt,
                               stabilize="tanh")),)


class AutoPrecisionController:
    """Telemetry in, precision-rule overlays out.

    ``update(window)`` consumes a telemetry window (site ->
    :class:`~repro.autoprec.telemetry.SiteWindow`) and returns True when
    the overlay changed — the caller's cue to rebuild its compiled step
    (the trainer's step cache and the operator engine's per-resolution
    cache both key on the policy, so this is just "resolve the policy
    again").  ``policy()`` is the base policy with the current overlay
    stacked on top, named ``<base>+auto<version>`` so step caches never
    alias across versions.
    """

    def __init__(self,
                 base: Union[str, PrecisionPolicy] = "full",
                 config: Optional[ControllerConfig] = None,
                 **overrides):
        self.base = get_policy(base) if isinstance(base, str) else base
        if config is None:
            config = ControllerConfig(**overrides)
        elif overrides:
            config = dataclasses.replace(config, **overrides)
        self.config = config
        self.sites: Dict[str, SiteState] = {}
        self.version = 0
        self.updates = 0
        self.last_change_update = -1
        self.last_change_step: Optional[int] = None
        self._policy_cache: Optional[PrecisionPolicy] = None

    # -- bound-guided format choice -----------------------------------------
    def eps_budget(self, grid_points: Optional[int] = None) -> float:
        """The ε ceiling: ``target_fraction`` of the relative Thm 3.1
        discretisation bound, divided by Thm 3.2's constant.  Evaluated
        on the unit-normalised field (M = 1, L = rel_lipschitz)."""
        cfg = self.config
        n = grid_points or cfg.grid_points or 64 ** cfg.spatial_dim
        disc = theory.disc_upper_bound(
            n, cfg.spatial_dim, cfg.omega, L=cfg.rel_lipschitz, M=1.0)
        # prec_upper_bound(eps, M=1) = 4 eps  =>  eps <= fraction*disc/4
        return cfg.target_fraction * disc / 4.0

    def _format_ok(self, fmt: str, state: SiteState, window: SiteWindow,
                   budget: float) -> bool:
        if FORMAT_EPS[fmt] > budget:
            return False
        if state.amax * self.config.range_margin > FORMAT_MAX[fmt]:
            return False
        if window.fraction_below(FORMAT_TINY[fmt]) > self.config.underflow_limit:
            return False
        return True

    def _choose(self, state: SiteState, window: SiteWindow,
                budget: float) -> str:
        for fmt in self.config.formats:
            if self._format_ok(fmt, state, window, budget):
                return fmt
        return "float32"

    # -- the update loop ------------------------------------------------------
    def _controlled(self, group: str) -> bool:
        return any(site_matches(p, group) for p in self.config.control)

    def update(self, window: Dict[str, SiteWindow],
               grid_points: Optional[int] = None,
               step: Optional[int] = None) -> bool:
        """Consume one telemetry window; True when the overlay changed."""
        self.updates += 1
        # fold tap sites onto control groups
        groups: Dict[str, SiteWindow] = {}
        for site, w in window.items():
            g = group_of(site)
            if not self._controlled(g):
                continue
            if g in groups:
                groups[g].merge(w)
            else:
                groups[g] = dataclasses.replace(w, hist=w.hist.copy())

        from repro.obs import autoprec_decision, numerics_event

        budget = self.eps_budget(grid_points)
        changed = False
        for g, w in sorted(groups.items()):
            st = self.sites.setdefault(g, SiteState())
            st.eps_budget = budget
            st.amax = max(w.amax, self.config.amax_decay * st.amax)
            if st.cooldown > 0:
                st.cooldown -= 1
            if w.overflow > 0:
                st.overflow_streak += 1
                st.clean = 0
                numerics_event("overflow_streak", site=g,
                               streak=st.overflow_streak, amax=st.amax,
                               **({} if step is None else {"step": step}))
                if (st.overflow_streak >= self.config.promote_streak
                        and st.fmt != "float32"):
                    old = st.fmt
                    st.fmt = "float32"
                    st.cooldown = self.config.cooldown
                    st.overflow_streak = 0
                    changed = True
                    autoprec_decision(g, old, "float32",
                                      eps_budget=budget, amax=st.amax,
                                      step=step)
                continue
            st.overflow_streak = 0
            st.clean += 1
            if st.clean < self.config.demote_patience or st.cooldown > 0:
                continue
            best = self._choose(st, w, budget)
            if best != st.fmt:
                old = st.fmt
                st.fmt = best
                st.cooldown = self.config.cooldown
                changed = True
                autoprec_decision(g, old, best, eps_budget=budget,
                                  amax=st.amax,
                                  fmt_eps=FORMAT_EPS.get(best), step=step)
        if changed:
            self.version += 1
            self.last_change_update = self.updates
            self.last_change_step = step
            self._policy_cache = None
        return changed

    # -- outputs ---------------------------------------------------------------
    def overlay(self) -> Tuple[Tuple[str, SiteRule], ...]:
        """The current decisions as rule entries (highest priority when
        stacked onto the base policy)."""
        entries = []
        needs_scaling = False
        for g in sorted(self.sites):
            st = self.sites[g]
            entries.extend(_rules_for(f"{g}/*", st.fmt))
            needs_scaling |= st.fmt in _NEEDS_LOSS_SCALING
        if needs_scaling:
            entries.append(("train/loss_scale", SiteRule(loss_scaling=True)))
        return tuple(entries)

    def policy(self) -> PrecisionPolicy:
        if self._policy_cache is None:
            self._policy_cache = self.base.with_rules(
                *self.overlay(), name=f"{self.base.name}+auto{self.version}")
        return self._policy_cache

    def describe(self) -> dict:
        """JSON-friendly decision report (engine stats, benchmarks)."""
        return {
            "base": self.base.name,
            "version": self.version,
            "updates": self.updates,
            "last_change_update": self.last_change_update,
            "last_change_step": self.last_change_step,
            "sites": {
                g: {
                    "fmt": st.fmt,
                    "amax": st.amax,
                    "eps_budget": st.eps_budget,
                    "clean": st.clean,
                    "cooldown": st.cooldown,
                }
                for g, st in sorted(self.sites.items())
            },
        }
