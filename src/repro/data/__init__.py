"""Data substrate: PDE solvers + synthetic streams, all in JAX/numpy."""
from .grf import grf_2d, grf_sphere  # noqa: F401
from .darcy import sample_darcy_batch, solve_darcy  # noqa: F401
from .navier_stokes import sample_ns_batch, solve_ns_vorticity  # noqa: F401
from .swe import sample_swe_batch, solve_swe_linear  # noqa: F401
from .carshapes import sample_car_batch, latent_grid_coords  # noqa: F401
from .tokens import lm_inputs, token_batch  # noqa: F401
from .loader import CachedDataset, StatelessLoader  # noqa: F401
