"""Site-addressed runtime numerics taps (the measurement half of autoprec).

The paper's theory says precision error is bounded by ``4 ε M`` (Thm 3.2)
with ``M`` the sup-norm of what actually flows through a site — a
*runtime* quantity the static rule tables never see.  This module
measures it, inside jitted steps, as a functional carry:

* ``tap(site, x, fmt=..., quantized=...)`` — called from the precision
  helpers (``SitePrecision.quantize`` / ``.contract``) and from explicit
  call sites (FFT outputs).  When no collector is active it is a no-op
  that adds nothing to the traced graph; when one is, it records a
  :class:`SiteStats` — amax, exponent-bucket histogram, overflow /
  underflow counters vs the site's format, and the measured
  quantisation error ``max|q(x) − x|`` (the empirical Thm 3.2 quantity).
* ``TraceCollector`` + ``collecting(col)`` — a trace-scoped registry.
  The pattern every consumer uses::

      def step(params, batch):
          col = TraceCollector()
          with collecting(col):
              loss = loss_fn(params, batch)
          return loss, col.snapshot()      # telemetry as a step output

  Because the collector lives and dies inside the traced function, the
  recorded arrays stay inside their trace (works under ``jit``,
  ``value_and_grad(has_aux=True)`` and per-iteration inside ``scan``
  bodies); the snapshot rides out as ordinary outputs.
* ``TelemetryAggregator`` — host-side accumulation of per-step
  snapshots, with a *window* view (stats since the controller last
  looked) feeding :mod:`repro.autoprec.controller` and JSON ``counters``
  for engine ``stats()`` and reports.

Sites are the same strings the precision rule tables use
(``fno/layer2/spectral/fft_in``, ``serve/operator``, ...), so telemetry,
control and certification all speak one address space.
"""
from __future__ import annotations

import dataclasses
import threading
from contextlib import contextmanager
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision import FORMAT_MAX, FORMAT_TINY

#: Exponent-bucket histogram range: bucket ``i`` counts magnitudes in
#: ``[2^(EXP_MIN+i), 2^(EXP_MIN+i+1))``; the first/last buckets clamp.
EXP_MIN = -24
EXP_MAX = 16
N_BUCKETS = EXP_MAX - EXP_MIN

#: Distributional counters (histogram, overflow/underflow counts) are
#: computed on a strided subsample: they are consumed as *fractions*, so
#: subsampling is unbiased, and binning every element would dominate the
#: step.  ``HIST_STRIDE`` is the minimum stride; ``HIST_MAX_SAMPLES``
#: caps the subsample per tensor so the one-hot binning matrix stays
#: O(64k x 40) at any production scale.  amax and qerr stay exact over
#: every element, and overflow *detection* is exact regardless of the
#: stride: any value outside the format range also drives amax out of
#: range, which forces the counter non-zero.
#:
#: Cost, measured: the <10% overhead budget holds on *wall clock*
#: (``bench_autoprec`` records ~-14%: unrolling the block loop for
#: per-layer sites more than pays for the taps on CPU).  The exact
#: amax/qerr passes still move real bytes — the pod-scale dry-run
#: (``dryrun_fno --telemetry``) prices every-step instrumentation at
#: ~+50% counted bytes on a memory-bound cell; collect every k-th step
#: there, or raise ``interval``, if that roofline is binding.
HIST_STRIDE = 16
HIST_MAX_SAMPLES = 1 << 16


class SiteStats(NamedTuple):
    """One site's numerics for one step (jnp scalars / a histogram row).

    ``overflow`` counts values whose magnitude exceeds the site format's
    max finite value (or are already non-finite) — the values a real
    cast would turn into inf.  ``underflow`` counts non-zero values
    below the format's smallest normal.  Both counts are subsample
    estimates (they are consumed as fractions/flags), but overflow
    *detection* is exact: any out-of-range value forces the counter
    non-zero through the exact amax.  ``qerr`` is the measured
    ``max|q(x) − x|`` where a quantiser ran — the empirical quantity
    Thm 3.2 bounds by ``4 ε M``.
    """

    amax: jnp.ndarray       # f32 scalar, max |component|
    qerr: jnp.ndarray       # f32 scalar, max |q(x) - x| (0 if no quantiser)
    n: jnp.ndarray          # f32 scalar, component count
    overflow: jnp.ndarray   # f32 scalar
    underflow: jnp.ndarray  # f32 scalar
    hist: jnp.ndarray       # (N_BUCKETS,) f32


def _parts(x) -> tuple:
    """The real storage components of ``x`` (split-real complex), as
    separate arrays.  Stats reduce each part independently and merge —
    concatenating would materialise a full copy of every tapped tensor,
    whereas per-part reductions fuse into the surrounding computation
    (the difference between ~80% and ~0% extra bytes moved per step)."""
    if hasattr(x, "re") and hasattr(x, "im"):  # ComplexPair
        return (x.re, x.im)
    if jnp.iscomplexobj(x):
        return (jnp.real(x), jnp.imag(x))
    return (x,)


def fmt_of(sp) -> str:
    """The storage-format name a :class:`SitePrecision` quantises onto
    (what its overflow/underflow thresholds should be checked against)."""
    if sp.quantize_fmt is not None and sp.quantize_fmt != "half":
        return sp.quantize_fmt
    if sp.compute is None:
        return "float32"
    return jnp.dtype(sp.compute).name


def site_stats(x, fmt: Optional[str] = None, quantized=None,
               with_hist: bool = True,
               hist_stride: int = HIST_STRIDE) -> SiteStats:
    """Measure one tensor against a format's thresholds (pure jnp)."""
    fmax = FORMAT_MAX.get(fmt or "float32", float("inf"))
    tiny = FORMAT_TINY.get(fmt or "float32", 0.0)
    amax = jnp.zeros((), jnp.float32)
    overflow = jnp.zeros((), jnp.float32)
    underflow = jnp.zeros((), jnp.float32)
    hist = jnp.zeros((N_BUCKETS,), jnp.float32)
    n = 0
    for p in _parts(x):
        mag = jnp.abs(p.astype(jnp.float32))
        n += p.size
        amax = jnp.maximum(amax, jnp.max(mag, initial=0.0))
        # distributional counters on a bounded subsample (see above)
        stride = max(1, hist_stride, -(-p.size // HIST_MAX_SAMPLES))
        sub = jnp.ravel(mag)[::stride]
        scale = p.size / max(sub.size, 1)
        # NaN/inf fail `sub <= fmax` too, so non-finite values count once
        overflow += scale * jnp.sum((~(sub <= fmax)).astype(jnp.float32))
        underflow += scale * jnp.sum(
            ((sub > 0) & (sub < tiny)).astype(jnp.float32))
        if with_hist:
            nz = sub > 0
            e = jnp.floor(jnp.log2(jnp.where(nz, sub, 1.0)))
            idx = jnp.clip(e - EXP_MIN, 0, N_BUCKETS - 1).astype(jnp.int32)
            # bin via a broadcast one-hot reduction, not scatter-add: a
            # 40xK comparison matrix fuses into plain reductions, where
            # a scatter costs orders of magnitude more bytes moved
            onehot = (idx[None, :]
                      == jnp.arange(N_BUCKETS, dtype=jnp.int32)[:, None])
            hist += jnp.sum((onehot & nz[None, :]).astype(jnp.float32),
                            axis=1) * scale
    # exact overflow *detection*: an out-of-range or non-finite value
    # anywhere drives amax out of range even if the subsample missed it
    overflow = jnp.maximum(overflow, (~(amax <= fmax)).astype(jnp.float32))
    if quantized is not None:
        qerr = jnp.zeros((), jnp.float32)
        for p, q in zip(_parts(x), _parts(quantized), strict=True):
            d = jnp.abs(q.astype(jnp.float32) - p.astype(jnp.float32))
            qerr = jnp.maximum(qerr, jnp.max(d, initial=0.0))
    else:
        qerr = jnp.zeros((), jnp.float32)
    return SiteStats(
        amax=amax, qerr=qerr,
        n=jnp.asarray(float(n), jnp.float32),
        overflow=overflow, underflow=underflow, hist=hist,
    )


def merge_stats(a: SiteStats, b: SiteStats) -> SiteStats:
    return SiteStats(
        amax=jnp.maximum(a.amax, b.amax),
        qerr=jnp.maximum(a.qerr, b.qerr),
        n=a.n + b.n,
        overflow=a.overflow + b.overflow,
        underflow=a.underflow + b.underflow,
        hist=a.hist + b.hist,
    )


def merge_stacked(snapshot: Dict[str, SiteStats]) -> Dict[str, SiteStats]:
    """Reduce a snapshot whose leaves carry a leading stacking axis
    (e.g. ``lax.scan`` ys over microbatches) to per-site totals."""
    return {
        site: SiteStats(
            amax=jnp.max(s.amax, axis=0), qerr=jnp.max(s.qerr, axis=0),
            n=jnp.sum(s.n, axis=0), overflow=jnp.sum(s.overflow, axis=0),
            underflow=jnp.sum(s.underflow, axis=0),
            hist=jnp.sum(s.hist, axis=0),
        )
        for site, s in snapshot.items()
    }


# ---------------------------------------------------------------------------
# Trace-scoped collection
# ---------------------------------------------------------------------------


class TraceCollector:
    """Accumulates per-site stats for one traced step.  Repeated taps at
    the same site (corner blocks, shared patterns) merge in place."""

    def __init__(self, with_hist: bool = True,
                 hist_stride: int = HIST_STRIDE):
        self.with_hist = with_hist
        self.hist_stride = hist_stride
        self._sites: Dict[str, SiteStats] = {}

    def record(self, site: str, stats: SiteStats) -> None:
        prev = self._sites.get(site)
        self._sites[site] = stats if prev is None else merge_stats(prev, stats)

    def snapshot(self) -> Dict[str, SiteStats]:
        """The collected stats, ready to return from the traced step."""
        return dict(sorted(self._sites.items()))


_local = threading.local()


def current_collector() -> Optional[TraceCollector]:
    return getattr(_local, "collector", None)


def telemetry_active() -> bool:
    """True while a collector is in scope.  Model code consults this at
    trace time (e.g. to unroll layer scans so per-layer sites stay
    addressable at the outer trace level)."""
    return current_collector() is not None


@contextmanager
def collecting(col: TraceCollector):
    """Scope ``col`` as the active collector (thread-local, re-entrant:
    an inner scope shadows the outer one)."""
    prev = current_collector()
    _local.collector = col
    try:
        yield col
    finally:
        _local.collector = prev


def tap(site: str, x, fmt: Optional[str] = None, quantized=None) -> None:
    """Record numerics for ``site`` if a collector is active.

    ``x`` is the *pre-quantisation* tensor (so overflow counters see the
    values a narrowing cast would destroy); ``quantized`` optionally
    supplies the post-quantisation tensor for the measured ``qerr``.
    No-op — zero ops added to the trace — when no collector is active.
    """
    col = current_collector()
    if col is None:
        return
    col.record(site, site_stats(x, fmt=fmt, quantized=quantized,
                                with_hist=col.with_hist,
                                hist_stride=col.hist_stride))


# ---------------------------------------------------------------------------
# Host-side aggregation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SiteWindow:
    """Host-side accumulation of one site's stats over some steps."""

    updates: int = 0             # snapshots merged
    amax: float = 0.0            # max over the window
    qerr: float = 0.0            # max over the window
    n: float = 0.0               # component count (sum)
    overflow: float = 0.0        # count (sum)
    underflow: float = 0.0       # count (sum)
    overflow_updates: int = 0    # snapshots containing >= 1 overflow
    hist: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(N_BUCKETS))

    def merge(self, s: "SiteWindow") -> None:
        self.updates += s.updates
        self.amax = max(self.amax, s.amax)
        self.qerr = max(self.qerr, s.qerr)
        self.n += s.n
        self.overflow += s.overflow
        self.underflow += s.underflow
        self.overflow_updates += s.overflow_updates
        self.hist = self.hist + s.hist

    def fraction_below(self, threshold: float) -> float:
        """Fraction of observed non-zero magnitudes below ``threshold``
        (from the exponent histogram; used for candidate-format
        underflow checks)."""
        total = float(self.hist.sum())
        if total <= 0 or threshold <= 0:
            return 0.0
        cut = int(np.floor(np.log2(threshold))) - EXP_MIN
        cut = min(max(cut, 0), N_BUCKETS)
        return float(self.hist[:cut].sum()) / total

    def to_dict(self) -> dict:
        return {
            "updates": self.updates,
            "amax": self.amax,
            "qerr": self.qerr,
            "values": self.n,
            "overflow": self.overflow,
            "underflow": self.underflow,
            "overflow_updates": self.overflow_updates,
        }


def _window_of(stats: SiteStats) -> SiteWindow:
    overflow = float(np.asarray(stats.overflow))
    return SiteWindow(
        updates=1,
        amax=float(np.asarray(stats.amax)),
        qerr=float(np.asarray(stats.qerr)),
        n=float(np.asarray(stats.n)),
        overflow=overflow,
        underflow=float(np.asarray(stats.underflow)),
        overflow_updates=int(overflow > 0),
        hist=np.asarray(stats.hist, dtype=np.float64),
    )


class TelemetryAggregator:
    """Accumulates step snapshots on the host.

    Keeps run ``totals`` (for reports / engine ``stats()``) and a
    ``window`` that resets each time the controller consumes it via
    :meth:`take_window` — the delayed-scaling cadence.
    """

    def __init__(self):
        self.totals: Dict[str, SiteWindow] = {}
        self._window: Dict[str, SiteWindow] = {}
        self.steps = 0

    def update(self, snapshot: Dict[str, SiteStats]) -> None:
        if not snapshot:
            return
        snapshot = jax.device_get(snapshot)
        self.steps += 1
        for site, stats in snapshot.items():
            w = _window_of(stats)
            for store in (self.totals, self._window):
                if site in store:
                    store[site].merge(w)
                else:
                    store[site] = dataclasses.replace(w, hist=w.hist.copy())

    def window(self) -> Dict[str, SiteWindow]:
        return self._window

    def take_window(self) -> Dict[str, SiteWindow]:
        """The accumulated window, resetting it (controller cadence)."""
        out = self._window
        self._window = {}
        return out

    def counters(self) -> Dict[str, Any]:
        """JSON-friendly per-site counters plus run-level aggregates."""
        sites = {s: w.to_dict() for s, w in sorted(self.totals.items())}
        return {
            "steps": self.steps,
            "overflow_total": sum(w.overflow for w in self.totals.values()),
            "underflow_total": sum(w.underflow for w in self.totals.values()),
            "sites": sites,
        }
