"""Test-session setup.

Two jobs:

* The container may lack ``hypothesis``; the property tests only use a
  narrow slice of it (``given`` / ``settings`` / three strategies), so
  when the real package is missing we install a deterministic sampling
  shim into ``sys.modules`` before the test modules import.  The real
  package always wins when installed (CI installs it).
* The tier-1 CI matrix sets ``REPRO_USE_PALLAS=1`` on one leg: every
  tri-state ``use_pallas`` default (model configs, trainer, serving)
  then resolves to the Pallas kernels in interpret mode, so the same
  suite locks down both spectral paths.  The env var is honoured by
  ``repro.kernels.ops.resolve_use_pallas``; here we only surface which
  path the session runs in the pytest header.
"""
import functools
import inspect
import os
import random
import sys
import types


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running differential/fuzz cases; deselect with "
        "-m 'not slow' for the fast local loop (CI runs the full suite)")


def pytest_report_header(config):
    try:
        from repro.kernels.ops import resolve_fuse_spectral, resolve_use_pallas

        on = resolve_use_pallas(None)
        fused = on and resolve_fuse_spectral(None)
    except Exception:  # pragma: no cover - src not importable yet
        on = bool(os.environ.get("REPRO_USE_PALLAS"))
        fused = on
    path = "pallas" if on else "einsum"
    kernels = ["einsum"]
    if on:
        kernels = ["dense", "dense-fused", "cp", "lshared"]
        if fused:
            kernels.append("spectral_fused")
    return (f"repro spectral path: {path} "
            f"(REPRO_USE_PALLAS={os.environ.get('REPRO_USE_PALLAS')!r}, "
            f"REPRO_FUSE_SPECTRAL={os.environ.get('REPRO_FUSE_SPECTRAL')!r}); "
            f"active kernel set: {', '.join(kernels)}")

try:  # pragma: no cover - prefer the real thing
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _DEFAULT_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    def _integers(min_value=0, max_value=1 << 30, **_):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _floats(min_value=0.0, max_value=1.0, **_):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _sampled_from(seq):
        choices = list(seq)
        return _Strategy(lambda rng: rng.choice(choices))

    def _settings(max_examples=_DEFAULT_EXAMPLES, **_):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def _given(*strategies, **kw_strategies):
        def deco(fn):
            n_examples = getattr(fn, "_shim_max_examples", _DEFAULT_EXAMPLES)

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = random.Random(fn.__qualname__)  # deterministic
                for _ in range(n_examples):
                    drawn = [s.draw(rng) for s in strategies]
                    drawn_kw = {k: s.draw(rng) for k, s in kw_strategies.items()}
                    fn(*args, *drawn, **drawn_kw, **kwargs)

            # hide the strategy-filled params from pytest's fixture
            # resolution (functools.wraps exposes the original signature)
            params = list(inspect.signature(fn).parameters.values())
            keep = params[: len(params) - len(strategies)]
            keep = [p for p in keep if p.name not in kw_strategies]
            wrapper.__signature__ = inspect.Signature(keep)
            del wrapper.__wrapped__
            return wrapper
        return deco

    mod = types.ModuleType("hypothesis")
    mod.given = _given
    mod.settings = _settings
    st = types.ModuleType("hypothesis.strategies")
    st.integers = _integers
    st.floats = _floats
    st.sampled_from = _sampled_from
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
