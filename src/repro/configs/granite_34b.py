"""granite-34b [dense] — llama-arch, code; MQA (kv=1).
[arXiv:2405.04324; hf]"""
from .base import LMArchConfig

CONFIG = LMArchConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab=49152, head_dim=128,
)

SMOKE = LMArchConfig(
    name="granite-34b-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=1,
    d_ff=192, vocab=256, head_dim=16,
)
