"""Losses: relative L² and Sobolev H¹ (the paper trains with H¹ on NS).

H¹ uses spectral derivatives (exact for periodic fields), matching the
neuraloperator implementation the paper builds on.
"""
from __future__ import annotations

import jax.numpy as jnp


def relative_l2(pred: jnp.ndarray, target: jnp.ndarray, eps: float = 1e-8) -> jnp.ndarray:
    """Mean over batch of ||pred - target||₂ / ||target||₂."""
    axes = tuple(range(1, pred.ndim))
    num = jnp.sqrt(jnp.sum((pred - target) ** 2, axis=axes))
    den = jnp.sqrt(jnp.sum(target ** 2, axis=axes)) + eps
    return jnp.mean(num / den)


def _spectral_grad_sq(f: jnp.ndarray) -> jnp.ndarray:
    """Σ_d ||∂f/∂x_d||² per sample, via FFT (periodic). f: (B, C, *spatial)."""
    spatial_axes = tuple(range(2, f.ndim))
    total = 0.0
    for ax in spatial_axes:
        n = f.shape[ax]
        k = jnp.fft.fftfreq(n, d=1.0 / n) * 2.0 * jnp.pi
        shape = [1] * f.ndim
        shape[ax] = n
        fk = jnp.fft.fft(f, axis=ax)
        df = jnp.fft.ifft(1j * k.reshape(shape) * fk, axis=ax).real
        total = total + jnp.sum(df ** 2, axis=tuple(range(1, f.ndim)))
    return total


def relative_h1(pred: jnp.ndarray, target: jnp.ndarray, eps: float = 1e-8) -> jnp.ndarray:
    """Relative H¹ = sqrt(||e||² + ||∇e||²) / sqrt(||t||² + ||∇t||²)."""
    axes = tuple(range(1, pred.ndim))
    e = pred - target
    num = jnp.sum(e ** 2, axis=axes) + _spectral_grad_sq(e)
    den = jnp.sum(target ** 2, axis=axes) + _spectral_grad_sq(target)
    return jnp.mean(jnp.sqrt(num) / (jnp.sqrt(den) + eps))


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Token-mean CE for the LM pool. logits (B,S,V) f32, labels (B,S)."""
    logits = logits.astype(jnp.float32)
    logz = jnp.log(jnp.sum(jnp.exp(logits - logits.max(-1, keepdims=True)), -1))
    logz = logz + logits.max(-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
