"""repro.dist API tests: rule-table resolution, fallback ordering,
off-mesh no-op behaviour, and FNO spec derivation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist import (
    axis_rules,
    constrain,
    constrain_bsd,
    constrain_spatial,
    dp_axes,
    fno_param_specs,
    logical_axis_size,
    pick_spec,
    replication_report,
    use_mesh,
)
from repro.models.fno import FNOConfig, init_fno

jax.config.update("jax_platform_name", "cpu")


def _fake_mesh(shape=(2, 4), axes=("data", "model")):
    """Abstract mesh over fake devices for spec-only tests."""
    devs = np.empty(shape, dtype=object)

    class _D:
        def __init__(self, i):
            self.id = i
            self.platform = "cpu"
            self.device_kind = "fake"

    for idx in range(int(np.prod(shape))):
        devs.reshape(-1)[idx] = _D(idx)
    try:
        return Mesh(devs, axes)
    except Exception:
        pytest.skip("cannot build fake mesh on this jax version")


class TestConstrainOffMesh:
    def test_no_mesh_is_identity(self):
        x = jnp.ones((4, 8))
        assert constrain(x, "dp", "tp") is x
        assert constrain_bsd(jnp.ones((2, 4, 8))) is not None

    def test_no_mesh_under_jit(self):
        @jax.jit
        def f(x):
            return constrain_spatial(x) * 2.0

        x = jnp.ones((2, 3, 8, 8))
        np.testing.assert_allclose(np.asarray(f(x)), 2.0 * np.ones(x.shape))

    def test_single_device_mesh_is_identity(self):
        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
        x = jnp.ones((4, 8))
        with use_mesh(mesh):
            assert constrain(x, "dp", "tp") is x

    def test_logical_axis_size_off_mesh(self):
        assert logical_axis_size("dp") == 1
        assert logical_axis_size("heads") == 1


class TestPickSpecFallback:
    def test_fallback_ordering(self):
        mesh = _fake_mesh((2, 4), ("data", "model"))
        # first divisible candidate wins, even if later ones also fit
        chain = [(("model",), None), ((("data",),) + (None,)), ()]
        assert pick_spec((16, 64), mesh, chain) == P("model", None)
        # 15 % model=4 fails -> falls to data (15 % 2 fails too) -> P()
        assert pick_spec((15, 64), mesh, chain) == P()
        # 6 % 4 fails but 6 % 2 passes -> second candidate
        assert pick_spec((6, 64), mesh, chain) == P("data", None)

    def test_logical_names_resolve(self):
        mesh = _fake_mesh((2, 4), ("data", "model"))
        assert pick_spec((8, 8), mesh, [("dp", "tp"), ()]) == P("data", "model")
        # "pod" absent from this mesh: adapted away, not a failure
        assert pick_spec((8,), mesh, [(("pod", "data"),), ()]) == P("data")

    def test_multi_pod_dp(self):
        mesh = _fake_mesh((2, 2, 4), ("pod", "data", "model"))
        assert dp_axes(mesh) == ("pod", "data")
        assert pick_spec((8, 4), mesh, [("dp", None), ()]) == P(("pod", "data"), None)

    def test_axis_rules_override(self):
        mesh = _fake_mesh((2, 4), ("data", "model"))
        with axis_rules(seq=("data",)):
            assert pick_spec((8, 8), mesh, [(None, "seq"), ()]) == P(None, "data")
        assert pick_spec((8, 8), mesh, [(None, "seq"), ()]) == P(None, "model")


class TestFnoParamSpecs:
    def test_small_fno_fully_replicates(self):
        mesh = _fake_mesh()
        cfg = FNOConfig()
        p_shape = jax.eval_shape(lambda k: init_fno(k, cfg), jax.random.PRNGKey(0))
        specs = fno_param_specs(p_shape, mesh)
        spec_leaves = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        assert len(spec_leaves) == len(jax.tree_util.tree_leaves(p_shape))
        assert all(s == P() for s in spec_leaves)
        rep = replication_report(p_shape, specs)
        assert rep["sharded_bytes"] == 0
        assert rep["replicated_bytes"] == rep["total_bytes"] > 0

    def test_big_spectral_leaf_shards_channels(self):
        mesh = _fake_mesh()
        # stacked dense spectral weights above the threshold:
        # (L, corners, in, out, m1, m2) -> out channels over model
        big = jax.ShapeDtypeStruct((4, 2, 64, 64, 128, 128), jnp.float32)
        tree = {"spectral": {"w_re": big},
                "lift1": {"w": jax.ShapeDtypeStruct((5, 256), jnp.float32)}}
        specs = fno_param_specs(tree, mesh, shard_threshold=1 << 20)
        assert specs["spectral"]["w_re"][0] is None  # scan axis untouched
        assert "model" in jax.tree_util.tree_leaves(
            [list(specs["spectral"]["w_re"])])
        assert specs["lift1"]["w"] == P()

    def test_replication_report_with_sharding(self):
        mesh = _fake_mesh()
        big = jax.ShapeDtypeStruct((4, 2, 64, 64, 128, 128), jnp.float32)
        tree = {"w": big}
        specs = fno_param_specs(tree, mesh, shard_threshold=1 << 20)
        rep = replication_report(tree, specs)
        assert rep["sharded_bytes"] > 0
        assert rep["n_sharded"] == 1


_SHARDED_SERVE_SCRIPT = """
import jax, numpy as np
from jax.sharding import Mesh
from repro.configs import get_config
from repro.models.lm import init_lm
from repro.serve import Request, ServeEngine

cfg = get_config("smollm-360m", smoke=True)
params = init_lm(jax.random.PRNGKey(0), cfg)
reqs = lambda: [Request(uid=i, prompt=[1, 2, 3], max_new_tokens=4)
                for i in range(6)]
plain = ServeEngine(params, cfg, n_slots=4, max_len=32)
d1, _ = plain.run_until_done(reqs())
mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
sharded = ServeEngine(params, cfg, n_slots=4, max_len=32, mesh=mesh)
d2, _ = sharded.run_until_done(reqs())
g1 = {r.uid: r.generated for r in d1}
g2 = {r.uid: r.generated for r in d2}
assert g1 == g2, (g1, g2)
print("MATCH")
"""


class TestShardedServing:
    def test_sharded_engine_matches_unsharded(self):
        """ServeEngine(mesh=...) must generate bit-identical tokens to
        the unsharded engine.  Runs in a subprocess because the forced
        device count must be set before jax initialises."""
        import os
        import subprocess
        import sys

        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["JAX_PLATFORM_NAME"] = "cpu"
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "src")]
            + env.get("PYTHONPATH", "").split(os.pathsep))
        proc = subprocess.run(
            [sys.executable, "-c", _SHARDED_SERVE_SCRIPT],
            env=env, capture_output=True, text=True, timeout=600)
        assert proc.returncode == 0, proc.stderr[-2000:]
        assert "MATCH" in proc.stdout
