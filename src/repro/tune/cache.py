"""The persistent calibration cache: versioned JSON, atomic writes,
stale/corrupt detection, graceful fallback.

A calibration-state file is the durable output of ``python -m repro.tune
tune`` and the input to every kernel-tile resolution in
``repro.kernels.ops``.  Schema (format version 1)::

    {
      "format_version": 1,
      "kernel_version": 2,          # repro.kernels KERNEL_VERSION at tune time
      "backend": "tpu",             # jax.default_backend() at tune time
      "entries": {
        "dense|4x32x32x144|bfloat16": {
          "family": "dense",        # dense | dense-fused | cp | lshared
          "shape": [4, 32, 32, 144],
          "dtype": "bfloat16",
          "backend": "tpu",
          "kernel_version": 2,
          "block_fwd": 128,
          "block_bwd": 64,
          "wall_us": 410.2,         # median train-step wall of the winner
          "gbps": 612.5,            # achieved bytes-moved / wall
          "roofline_fraction": 0.75,
          "interpret": false,       # true => timed in interpret mode (CI)
          "validated": true,        # passed the einsum-oracle Thm 3.2 gate
          "max_err": 1.1e-3,        # worst |pallas - einsum| at admission
          "budget": 4.9e-3          # the Thm 3.2 budget it was gated under
        }, ...
      }
    }

Consumers never read the file directly — they go through ``lookup``,
which enforces per-entry staleness (kernel-version bump, backend
mismatch) and structural sanity (power-of-two blocks) and falls back to
``None`` (→ static heuristic) on any defect.  A bad calibration file can
therefore cost performance but never correctness or availability.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import warnings
from typing import Optional, Union

from repro.kernels.spectral_contract import KERNEL_VERSION

#: schema version of the calibration-state file itself (distinct from
#: KERNEL_VERSION, which tracks the kernel schedules being calibrated)
FORMAT_VERSION = 1

#: kernel families a calibration entry may address
FAMILIES = ("dense", "dense-fused", "cp", "lshared", "spectral_fused")

#: env var consulted by ``active_cache`` when nothing was activated
#: explicitly — the zero-plumbing way to point a whole process (trainer,
#: serve engines, dry-runs) at a calibration-state file.
ENV_VAR = "REPRO_CALIBRATION_STATE"


class CalibrationError(Exception):
    """A calibration-state file is unreadable or structurally invalid."""


def entry_key(family: str, shape, dtype: str) -> str:
    """The cache key: ``family|BxIxOx...|dtype`` — one entry per
    (kernel family, shape, dtype); backend and kernel version are
    checked per entry at lookup time."""
    return f"{family}|{'x'.join(str(int(s)) for s in shape)}|{dtype}"


def _is_pow2(n) -> bool:
    return isinstance(n, int) and n >= 1 and (n & (n - 1)) == 0


def _entry_ok(ent) -> bool:
    """Structural sanity of one entry — defensive against hand-edited or
    truncated files; anything off means 'treat as absent'."""
    return (
        isinstance(ent, dict)
        and ent.get("family") in FAMILIES
        and _is_pow2(ent.get("block_fwd"))
        and _is_pow2(ent.get("block_bwd"))
    )


@dataclasses.dataclass
class CalibrationCache:
    """An in-memory calibration state plus its lookup counters."""

    entries: dict
    kernel_version: int = KERNEL_VERSION
    backend: str = ""
    path: Optional[str] = None
    counters: dict = dataclasses.field(
        default_factory=lambda: {"hits": 0, "misses": 0, "stale": 0})

    def lookup(self, family: str, shape, dtype: str) -> Optional[dict]:
        """Return the validated entry for this key, or None.

        ``None`` means: no entry, a stale entry (tuned against a
        different kernel version or backend), a corrupt entry, or one
        that never passed oracle validation — in every case the caller
        falls back to the static heuristic.

        Each outcome lands in the numerics-event stream
        (``tile_cache_hit``/``miss``/``stale``) so a run timeline shows
        which resolutions got tuned tiles and which fell back.
        """
        import jax

        from repro.obs import tile_cache_event

        key = entry_key(family, shape, dtype)
        ent = self.entries.get(key)
        if ent is None:
            self.counters["misses"] += 1
            tile_cache_event("miss", family, key)
            return None
        if not _entry_ok(ent) or not ent.get("validated", False):
            self.counters["stale"] += 1
            tile_cache_event("stale", family, key)
            return None
        if ent.get("kernel_version") != KERNEL_VERSION:
            self.counters["stale"] += 1
            tile_cache_event("stale", family, key)
            return None
        if ent.get("backend") != jax.default_backend():
            self.counters["stale"] += 1
            tile_cache_event("stale", family, key)
            return None
        self.counters["hits"] += 1
        tile_cache_event("hit", family, key)
        return ent

    def put(self, ent: dict) -> None:
        self.entries[entry_key(ent["family"], ent["shape"], ent["dtype"])] = ent

    def to_json(self) -> dict:
        return {
            "format_version": FORMAT_VERSION,
            "kernel_version": self.kernel_version,
            "backend": self.backend,
            "entries": self.entries,
        }


def load(path: Union[str, os.PathLike]) -> CalibrationCache:
    """Load a calibration-state file, raising ``CalibrationError`` on
    missing/corrupt/incompatible files (callers wanting silence use
    ``safe_load``)."""
    try:
        with open(path) as f:
            raw = json.load(f)
    except FileNotFoundError as e:
        raise CalibrationError(f"calibration state not found: {path}") from e
    except (json.JSONDecodeError, OSError, UnicodeDecodeError) as e:
        raise CalibrationError(
            f"calibration state {path} is unreadable/corrupt: {e}") from e
    if not isinstance(raw, dict) or not isinstance(raw.get("entries"), dict):
        raise CalibrationError(
            f"calibration state {path} has no 'entries' table")
    if raw.get("format_version") != FORMAT_VERSION:
        raise CalibrationError(
            f"calibration state {path} has format_version "
            f"{raw.get('format_version')!r}, expected {FORMAT_VERSION}")
    return CalibrationCache(
        entries=dict(raw["entries"]),
        kernel_version=int(raw.get("kernel_version", -1)),
        backend=str(raw.get("backend", "")),
        path=os.fspath(path),
    )


def safe_load(path: Union[str, os.PathLike]) -> Optional[CalibrationCache]:
    """``load`` that degrades to a warning + None — the form every hot
    path uses, so a bad file can never take a trainer or engine down."""
    try:
        return load(path)
    except CalibrationError as e:
        warnings.warn(
            f"ignoring calibration state ({e}); kernel tiles fall back to "
            f"the static VMEM heuristic", stacklevel=2)
        return None


def save(cache: CalibrationCache, path: Union[str, os.PathLike]) -> str:
    """Atomic write: serialise to a temp file in the target directory,
    fsync, then ``os.replace`` — a crashed tune run leaves either the
    old state or the new one, never a torn file."""
    path = os.fspath(path)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=".calibration-", suffix=".json")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(cache.to_json(), f, indent=2, sort_keys=True)
            f.write("\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    cache.path = path
    return path


# ---------------------------------------------------------------------------
# Process-global activation
# ---------------------------------------------------------------------------
#
# Tile resolution happens at jit trace time deep inside model code, far
# from anything holding a cache handle — so the active cache is process
# state: either explicitly activated (trainer/engine construction, the
# CLI flag) or resolved lazily from $REPRO_CALIBRATION_STATE.

_ACTIVE: Optional[CalibrationCache] = None
_ACTIVE_EXPLICIT = False
#: (path, mtime) -> CalibrationCache memo for the env-var path, so the
#: per-trace lookup never re-reads an unchanged file
_ENV_MEMO: dict = {}


def activate(target: Union[CalibrationCache, str, os.PathLike, None]):
    """Make ``target`` the process's calibration source.

    ``target`` may be a loaded ``CalibrationCache``, a path (loaded via
    ``safe_load`` — a bad file warns and deactivates), or ``None`` to
    deactivate explicit state (the env var takes over again).  Returns
    the previously active cache.
    """
    global _ACTIVE, _ACTIVE_EXPLICIT
    prev = _ACTIVE
    if target is None:
        _ACTIVE, _ACTIVE_EXPLICIT = None, False
    elif isinstance(target, CalibrationCache):
        _ACTIVE, _ACTIVE_EXPLICIT = target, True
    else:
        _ACTIVE, _ACTIVE_EXPLICIT = safe_load(target), True
    return prev


def active_cache() -> Optional[CalibrationCache]:
    """The cache kernel-tile resolution consults: the explicitly
    activated one if any, else the ``REPRO_CALIBRATION_STATE`` env file
    (memoised by path+mtime), else None."""
    if _ACTIVE_EXPLICIT:
        return _ACTIVE
    path = os.environ.get(ENV_VAR)
    if not path:
        return None
    try:
        mtime = os.stat(path).st_mtime_ns
    except OSError:
        mtime = None
    key = (path, mtime)
    if key not in _ENV_MEMO:
        _ENV_MEMO.clear()  # hold at most the current file's parse
        _ENV_MEMO[key] = safe_load(path)
    return _ENV_MEMO[key]
