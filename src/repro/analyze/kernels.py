"""Pallas kernel pass: static checks over grids, BlockSpecs and VMEM
estimators for every registered spectral-contraction kernel family.

Kernels are *traced, never run*: ``pl.pallas_call`` is temporarily
wrapped with a recorder and each family's public entry point is walked
with ``jax.eval_shape`` (forward) and ``jax.eval_shape(jax.grad(...))``
(the custom-VJP backward kernels).  Each recorded call is then checked
offline:

  index-oob (error)          a BlockSpec index map sends some grid step
      to a block that sticks out of the (padded) operand.
  output-not-covered (error) the output index maps, over the whole grid,
      fail to write every block of the output — silent garbage in the
      uncovered region.
  accum-discipline (error)   an output block revisited across grid steps
      without the init-or-accumulate pattern (``@pl.when(program_id ==
      0)`` zero-init + ``+=``) — the dUi/dUo hazard from the CP
      backward: Pallas output buffers are uninitialised on first touch.
  vmem-underestimate (error) the family's ``*vmem_bytes*`` estimator
      reports fewer bytes than the BlockSpec tiles actually constructed
      occupy — the dry-run ``fits_vmem`` verdicts would be lies.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import inspect
import itertools
from typing import Any, Callable, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .findings import ERROR, Finding


@dataclasses.dataclass
class KernelCall:
    """One recorded ``pl.pallas_call`` invocation (trace-time only)."""

    kernel: Callable
    grid: Tuple[int, ...]
    in_specs: Sequence
    out_specs: Sequence
    out_shape: Sequence
    arg_shapes: List[Tuple[Tuple[int, ...], Any]]  # (shape, dtype) per input

    @property
    def name(self) -> str:
        return getattr(self.kernel, "__name__", repr(self.kernel))


@contextlib.contextmanager
def record_pallas_calls() -> Iterator[List[KernelCall]]:
    """Swap ``pl.pallas_call`` for a recorder that captures the specs and
    the concrete (padded) operand shapes, then delegates.  The kernel
    modules resolve ``pl.pallas_call`` at call time, so patching the
    pallas module attribute reaches them all."""
    from jax.experimental import pallas as pl

    records: List[KernelCall] = []
    orig = pl.pallas_call

    def recording(kernel, **kwargs):
        inner = orig(kernel, **kwargs)

        @functools.wraps(inner)
        def wrapped(*args):
            grid = kwargs.get("grid", ())
            if isinstance(grid, int):
                grid = (grid,)
            out_shape = kwargs.get("out_shape")
            if not isinstance(out_shape, (tuple, list)):
                out_shape = [out_shape]
            out_specs = kwargs.get("out_specs") or []
            if not isinstance(out_specs, (tuple, list)):
                # single-output calls may pass one bare BlockSpec
                out_specs = [out_specs]
            records.append(KernelCall(
                kernel=kernel,
                grid=tuple(grid),
                in_specs=list(kwargs.get("in_specs") or []),
                out_specs=list(out_specs),
                out_shape=list(out_shape),
                arg_shapes=[(tuple(a.shape), jnp.dtype(a.dtype))
                            for a in args],
            ))
            return inner(*args)

        return wrapped

    pl.pallas_call = recording
    try:
        yield records
    finally:
        pl.pallas_call = orig


# ---------------------------------------------------------------------------
# Per-call structural checks
# ---------------------------------------------------------------------------


def _spec_blocks(spec, grid: Tuple[int, ...]):
    """Evaluate a BlockSpec's index map at every grid point.  Yields
    (grid_point, block_index_tuple)."""
    index_map = spec.index_map
    for pt in itertools.product(*(range(n) for n in grid)):
        idx = index_map(*pt)
        if not isinstance(idx, tuple):
            idx = (idx,)
        yield pt, tuple(int(i) for i in idx)


def _check_spec(call: KernelCall, role: str, pos: int, spec, shape,
                findings: List[Finding], where: str) -> Optional[set]:
    """OOB check for one spec against its operand shape; returns the set
    of visited block indices (None on arity mismatch, already reported)."""
    bs = tuple(spec.block_shape)
    if len(bs) != len(shape):
        findings.append(Finding(
            pass_name="kernels", check="index-oob", severity=ERROR,
            site=None, where=where,
            detail=f"{role}[{pos}]: block shape {bs} has different rank "
                   f"than operand {shape}",
        ))
        return None
    visited = set()
    for pt, idx in _spec_blocks(spec, call.grid):
        if len(idx) != len(bs):
            findings.append(Finding(
                pass_name="kernels", check="index-oob", severity=ERROR,
                site=None, where=where,
                detail=f"{role}[{pos}]: index map returned {idx} for grid "
                       f"point {pt}, expected rank {len(bs)}",
            ))
            return None
        for d, (i, b, s) in enumerate(zip(idx, bs, shape, strict=True)):
            if i < 0 or i * b + b > s:
                findings.append(Finding(
                    pass_name="kernels", check="index-oob", severity=ERROR,
                    site=None, where=where,
                    detail=f"{role}[{pos}] dim {d}: grid point {pt} maps to "
                           f"block {i} of size {b}, out of bounds for "
                           f"extent {s}",
                ))
        visited.add(idx)
    return visited


_INIT_MARKERS = ("pl.when", "program_id")


def _has_accum_discipline(kernel: Callable) -> bool:
    """Source heuristic for the init-or-accumulate pattern on revisited
    output blocks: a ``pl.when(program_id(...) == 0)`` guarded zero-init
    plus in-place ``+=`` accumulation."""
    while isinstance(kernel, functools.partial):
        kernel = kernel.func
    try:
        src = inspect.getsource(kernel)
    except (OSError, TypeError):
        return False
    return all(m in src for m in _INIT_MARKERS) and "+=" in src


def check_call(call: KernelCall, where: str) -> List[Finding]:
    findings: List[Finding] = []
    for pos, (spec, (shape, _dt)) in enumerate(
            zip(call.in_specs, call.arg_shapes, strict=True)):
        _check_spec(call, "in", pos, spec, shape, findings, where)
    for pos, (spec, sds) in enumerate(zip(call.out_specs, call.out_shape, strict=True)):
        shape = tuple(sds.shape)
        visited = _check_spec(call, "out", pos, spec, shape, findings, where)
        if visited is None:
            continue
        bs = tuple(spec.block_shape)
        n_blocks = [s // b for s, b in zip(shape, bs, strict=True)]
        expected = set(itertools.product(*(range(n) for n in n_blocks)))
        missing = expected - visited
        if missing:
            findings.append(Finding(
                pass_name="kernels", check="output-not-covered",
                severity=ERROR, site=None, where=where,
                detail=f"out[{pos}]: {len(missing)}/{len(expected)} output "
                       f"blocks never written (e.g. {sorted(missing)[0]}) — "
                       f"uncovered regions hold garbage",
            ))
        n_steps = 1
        for g in call.grid:
            n_steps *= g
        revisited = n_steps > len(visited)
        if revisited and not _has_accum_discipline(call.kernel):
            findings.append(Finding(
                pass_name="kernels", check="accum-discipline",
                severity=ERROR, site=None, where=where,
                detail=f"out[{pos}]: output block revisited across grid "
                       f"steps but kernel source shows no "
                       f"init-or-accumulate pattern "
                       f"(@pl.when(program_id==0) zero-init + '+=')",
            ))
    return findings


def tile_bytes(call: KernelCall) -> int:
    """Bytes of VMEM the BlockSpec tiles of one call actually occupy."""
    total = 0
    for spec, (_shape, dt) in zip(call.in_specs, call.arg_shapes, strict=True):
        n = 1
        for b in spec.block_shape:
            n *= b
        total += n * dt.itemsize
    for spec, sds in zip(call.out_specs, call.out_shape, strict=True):
        n = 1
        for b in spec.block_shape:
            n *= b
        total += n * jnp.dtype(sds.dtype).itemsize
    return total


# ---------------------------------------------------------------------------
# Kernel-family registry: how to trace each family and which estimator
# budgets it
# ---------------------------------------------------------------------------

# representative trace shapes (padding-exercising: M not a block multiple)
_B, _I, _O, _R = 2, 8, 8, 4
_M, _BLOCK_M = 40, 16          # pads 40 -> 48, grid (3,)
_L, _MM, _BLOCK_L = 12, 9, 8   # pads 12 -> 16, grid (2,)
_DT = jnp.float16

# the fused spectral megakernel tiles the batch: 3 pads -> 4, grid (2,)
_FB, _FBLOCK_B = 3, 2
_FI, _FO = 4, 4
_FSPATIAL, _FMODES = (8, 8), (3, 3)   # odd modes on an even grid
_FMH = 6 * 3                          # prod(2m, ..., m_last) retained rows


def _sds(*shape):
    return jax.ShapeDtypeStruct(shape, _DT)


def _sds32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _unwrap(fn):
    # the public entry points are jit'd (static block/interpret args);
    # trace the underlying function so the recorder always sees the
    # pallas_call even when a jit cache entry exists
    return getattr(fn, "__wrapped__", fn)


def _grad_sum(fn, n_args: int):
    def loss(*args):
        out_re, out_im = fn(*args)
        return (out_re.astype(jnp.float32).sum()
                + out_im.astype(jnp.float32).sum())

    return jax.grad(loss, argnums=tuple(range(n_args)))


def _trace(fn, *abstract_args) -> List[KernelCall]:
    with record_pallas_calls() as records:
        jax.eval_shape(fn, *abstract_args)
    return records


def kernel_families() -> List[Tuple[str, Callable[[], List[KernelCall]], Callable[[], int]]]:
    """(family name, tracer, estimator) triples.  The estimator closure
    returns the family's ``*vmem_bytes*`` verdict for the exact shapes
    the tracer uses; the pass checks it covers the recorded tiles."""
    from repro.kernels.spectral_contract import (
        cp_vmem_bytes,
        fused_vmem_bytes,
        fused_vmem_bytes_bwd,
        lshared_vmem_bytes,
        spectral_contract_cp_pallas,
        spectral_contract_lshared_pallas,
        spectral_contract_pallas,
        spectral_fused_pallas,
        vmem_bytes,
        vmem_bytes_bwd,
    )

    item = jnp.dtype(_DT).itemsize
    dense = functools.partial(
        _unwrap(spectral_contract_pallas),
        block_m=_BLOCK_M, interpret=True, out_dtype=_DT)
    dense_args = (_sds(_B, _I, _M), _sds(_B, _I, _M),
                  _sds(_I, _O, _M), _sds(_I, _O, _M))
    # the fused-cast variant streams f32 operand tiles and rounds onto
    # the half grid in the tile prologue — its working set prices at
    # itemsize 4
    dense_fused = functools.partial(
        _unwrap(spectral_contract_pallas),
        block_m=_BLOCK_M, interpret=True, out_dtype=_DT, cast_to=_DT)
    dense_fused_args = (_sds32(_B, _I, _M), _sds32(_B, _I, _M),
                        _sds32(_I, _O, _M), _sds32(_I, _O, _M))
    cp = functools.partial(
        _unwrap(spectral_contract_cp_pallas),
        block_m=_BLOCK_M, interpret=True, out_dtype=_DT)
    cp_args = (_sds(_B, _I, _M), _sds(_B, _I, _M),
               _sds(_I, _R), _sds(_I, _R), _sds(_O, _R), _sds(_O, _R),
               _sds(_R, _M), _sds(_R, _M))
    lsh = functools.partial(
        _unwrap(spectral_contract_lshared_pallas),
        block_l=_BLOCK_L, interpret=True, out_dtype=_DT)
    lsh_args = (_sds(_B, _I, _L, _MM), _sds(_B, _I, _L, _MM),
                _sds(_I, _O, _L), _sds(_I, _O, _L))
    # the fused megakernel: f32 streamed operands, half quantise in-tile
    fused = functools.partial(
        _unwrap(spectral_fused_pallas),
        modes=_FMODES, block_b=_FBLOCK_B, interpret=True, cast_to=_DT)
    fused_args = (_sds32(_FB, _FI, *_FSPATIAL),
                  _sds32(_FI, _FO, _FMH), _sds32(_FI, _FO, _FMH))

    def _fused_grad(*args):
        def loss(*a):
            return fused(*a).astype(jnp.float32).sum()

        return jax.grad(loss, argnums=(0, 1, 2))(*args)

    return [
        ("dense/fwd", lambda: _trace(dense, *dense_args),
         lambda: vmem_bytes(_B, _I, _O, _BLOCK_M, item)),
        ("dense/bwd", lambda: _trace(_grad_sum(dense, 4), *dense_args),
         lambda: vmem_bytes_bwd(_B, _I, _O, _BLOCK_M, item)),
        ("dense-fused/fwd", lambda: _trace(dense_fused, *dense_fused_args),
         lambda: vmem_bytes(_B, _I, _O, _BLOCK_M, 4)),
        ("dense-fused/bwd",
         lambda: _trace(_grad_sum(dense_fused, 4), *dense_fused_args),
         lambda: vmem_bytes_bwd(_B, _I, _O, _BLOCK_M, 4)),
        ("cp/fwd", lambda: _trace(cp, *cp_args),
         lambda: cp_vmem_bytes(_B, _I, _O, _R, _BLOCK_M, item)),
        ("cp/bwd", lambda: _trace(_grad_sum(cp, 8), *cp_args),
         lambda: cp_vmem_bytes(_B, _I, _O, _R, _BLOCK_M, item)),
        ("lshared/fwd", lambda: _trace(lsh, *lsh_args),
         lambda: lshared_vmem_bytes(_B, _I, _O, _MM, _BLOCK_L, item)),
        ("lshared/bwd", lambda: _trace(_grad_sum(lsh, 4), *lsh_args),
         lambda: lshared_vmem_bytes(_B, _I, _O, _MM, _BLOCK_L, item)),
        ("spectral_fused/fwd", lambda: _trace(fused, *fused_args),
         lambda: fused_vmem_bytes(_FBLOCK_B, _FI, _FO, _FSPATIAL,
                                  _FMODES, itemsize=4)),
        ("spectral_fused/bwd", lambda: _trace(_fused_grad, *fused_args),
         lambda: fused_vmem_bytes_bwd(_FBLOCK_B, _FI, _FO, _FSPATIAL,
                                      _FMODES, itemsize=4)),
    ]


def calibration_pass(path: Optional[str] = None) -> List[Finding]:
    """calibration-coverage: every tuned entry in a calibration-state
    file must be priced under the VMEM budget by its own family's
    ``*vmem_bytes*`` estimator — the autotuner, the static heuristics
    and the dry-run ``fits_vmem`` verdicts all share that vocabulary, so
    an entry the estimators cannot cover is either corrupt or was tuned
    against a different memory model and must not steer tiling.

    ``path`` defaults to ``$REPRO_CALIBRATION_STATE``; no path means no
    findings (the check only gates states that would actually be used).
    """
    import os

    findings: List[Finding] = []
    from repro.tune import cache as tcache
    from repro.tune.space import family_itemsize, tile_vmem_bytes
    from repro.kernels.spectral_contract import VMEM_BUDGET

    path = path or os.environ.get(tcache.ENV_VAR)
    if not path:
        return findings
    try:
        state = tcache.load(path)
    except tcache.CalibrationError as e:
        findings.append(Finding(
            pass_name="kernels", check="calibration-coverage",
            severity=ERROR, site=None, where=str(path), detail=str(e)))
        return findings
    for key, ent in sorted(state.entries.items()):
        where = f"calibration:{key}"
        if not tcache._entry_ok(ent):
            findings.append(Finding(
                pass_name="kernels", check="calibration-coverage",
                severity=ERROR, site=None, where=where,
                detail="corrupt entry: unknown family or non-power-of-two "
                       "block (lookup would skip it; tuner must not have "
                       "written it)"))
            continue
        itemsize = family_itemsize(ent["family"], ent["dtype"])
        for direction, field in (("fwd", "block_fwd"), ("bwd", "block_bwd")):
            try:
                need = tile_vmem_bytes(ent["family"], ent["shape"],
                                       int(ent[field]), itemsize, direction)
            except (KeyError, TypeError, ValueError) as e:
                findings.append(Finding(
                    pass_name="kernels", check="calibration-coverage",
                    severity=ERROR, site=None, where=where,
                    detail=f"{direction} tile not priceable by the family "
                           f"estimator: {e}"))
                continue
            if need > VMEM_BUDGET:
                findings.append(Finding(
                    pass_name="kernels", check="calibration-coverage",
                    severity=ERROR, site=None, where=where,
                    detail=f"{direction} tile {ent[field]} prices at {need} "
                           f"B — over the {VMEM_BUDGET} B VMEM budget; the "
                           f"estimators do not cover this entry"))
    return findings


def kernels_pass() -> List[Finding]:
    findings: List[Finding] = []
    for family, tracer, estimator in kernel_families():
        records = tracer()
        if not records:
            findings.append(Finding(
                pass_name="kernels", check="no-kernel-traced",
                severity=ERROR, site=None, where=family,
                detail="tracing the family recorded no pallas_call — the "
                       "recorder or the entry point is broken",
            ))
            continue
        worst_tiles = 0
        for call in records:
            where = f"{family}:{call.name}"
            findings.extend(check_call(call, where))
            worst_tiles = max(worst_tiles, tile_bytes(call))
        est = estimator()
        if est < worst_tiles:
            findings.append(Finding(
                pass_name="kernels", check="vmem-underestimate",
                severity=ERROR, site=None, where=family,
                detail=f"vmem estimator reports {est} B but the BlockSpecs "
                       f"constructed occupy {worst_tiles} B of tiles — "
                       f"fits_vmem verdicts would underestimate",
            ))
    return findings
