"""Tests for ``repro.analyze`` — the static numerics & precision linter.

The seeded-violation tests are the core: plant a known bug (a bf16
contraction accumulating at bf16 inside a spectral-contract scope; a
SiteRule shadowed dead within its own table; an OOB BlockSpec index
map) and assert the analyzer reports exactly that check at exactly that
site/severity.  The clean-tree tests pin the other direction: the
shipped rule tables, site literals, and kernel families produce zero
error-severity findings.
"""
import os
import textwrap

import jax
import jax.numpy as jnp
import pytest
from jax.experimental import pallas as pl

from repro.analyze import (
    ERROR,
    WARNING,
    Finding,
    Suppression,
    dedupe,
    kernels_pass,
    load_suppressions,
    partition,
    rule_table_findings,
    shadowed_entries,
    site_universe,
    sites_pass,
    trace_findings,
)
from repro.analyze.kernels import KernelCall, check_call, tile_bytes
from repro.analyze.sites import orphan_site_findings
from repro.precision.policy import get_policy
from repro.precision.rules import SiteRule

_REPO_SRC = os.path.normpath(
    os.path.join(os.path.dirname(__file__), "..", "src"))

_DIMS = (((1,), (0,)), ((), ()))  # plain matmul dimension_numbers


def _sds(*shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# Seeded violations: the analyzer must catch these
# ---------------------------------------------------------------------------


class TestSeededDataflowViolations:
    def test_bf16_contraction_without_f32_accum_is_an_error(self):
        """The canonical planted bug: a bf16 dot_general inside a
        ``*/spectral/contract`` scope with no f32 accumulation."""

        def bad(x, w):
            with jax.named_scope("fno/layer2/spectral/contract"):
                return jax.lax.dot_general(
                    x.astype(jnp.bfloat16), w.astype(jnp.bfloat16), _DIMS)

        findings = trace_findings(
            bad, (_sds(4, 8), _sds(8, 4)), get_policy("full"), "seeded")
        hits = [f for f in findings if f.check == "half-accum-contract"]
        assert len(hits) == 1
        f = hits[0]
        assert f.severity == ERROR
        assert f.site == "fno/layer2/spectral/contract"
        assert f.where == "seeded"
        assert "bfloat16" in f.detail

    def test_f32_accumulation_in_contract_scope_is_clean(self):
        def good(x, w):
            with jax.named_scope("fno/layer2/spectral/contract"):
                return jax.lax.dot_general(
                    x.astype(jnp.bfloat16), w.astype(jnp.bfloat16), _DIMS,
                    preferred_element_type=jnp.float32)

        findings = trace_findings(
            good, (_sds(4, 8), _sds(8, 4)), get_policy("full"), "seeded")
        assert [f for f in findings if f.severity == ERROR] == []

    def test_half_accum_outside_contract_scope_is_only_a_warning(self):
        def dense(x, w):
            return jax.lax.dot_general(
                x.astype(jnp.bfloat16), w.astype(jnp.bfloat16), _DIMS)

        findings = trace_findings(
            dense, (_sds(4, 8), _sds(8, 4)), get_policy("full"), "seeded")
        hits = [f for f in findings if f.check == "half-accum"]
        assert len(hits) == 1 and hits[0].severity == WARNING

    def test_unstabilized_fp16_exp_flagged_and_tanh_clears_it(self):
        def risky(x):
            return jnp.exp(x.astype(jnp.float16))

        def stabilized(x):
            return jnp.exp(jnp.tanh(x.astype(jnp.float16)))

        policy = get_policy("full")
        flagged = trace_findings(risky, (_sds(8),), policy, "seeded")
        assert any(f.check == "fp16-overflow-risk" and f.severity == WARNING
                   for f in flagged)
        clean = trace_findings(stabilized, (_sds(8),), policy, "seeded")
        assert [f for f in clean if f.check == "fp16-overflow-risk"] == []

    def test_round_trip_cast_detected(self):
        def wasteful(x):
            return x.astype(jnp.float16).astype(jnp.float32) + 1.0

        findings = trace_findings(
            wasteful, (_sds(8),), get_policy("full"), "seeded")
        assert any(f.check == "round-trip-cast" for f in findings)

    def test_fp32_resident_demoted_site_is_an_error(self):
        """mixed_fno_fp16 demotes spectral storage to f16; a contract
        scope whose eqns never touch f16 contradicts the policy."""
        policy = get_policy("mixed_fno_fp16")
        site = "fno/layer0/spectral/contract"
        assert policy.at(site).spectral_dtype is not None  # test premise

        def all_f32(x, w):
            with jax.named_scope(site):
                return jax.lax.dot_general(
                    x, w, _DIMS, preferred_element_type=jnp.float32)

        findings = trace_findings(
            all_f32, (_sds(4, 8), _sds(8, 4)), policy, "seeded")
        hits = [f for f in findings if f.check == "fp32-resident"]
        assert len(hits) == 1
        assert hits[0].severity == ERROR and hits[0].site == site


class TestSeededRuleTableViolations:
    def test_shadowed_rule_detected(self):
        """The second entry sets only ``compute``, which the catch-all
        above it already supplies everywhere: dead under field-wise
        first-match resolution."""
        rules = (
            ("*", SiteRule(compute="float32")),
            ("fno/*", SiteRule(compute="bfloat16")),
        )
        dead = shadowed_entries(rules, site_universe())
        assert dead == [(1, "fno/*", ("compute",))]

        findings = rule_table_findings(tables={"seeded": rules})
        hits = [f for f in findings if f.check == "shadowed-rule"]
        assert len(hits) == 1
        f = hits[0]
        assert f.severity == ERROR
        assert f.site == "fno/*"
        assert f.where == "seeded[1]"

    def test_specific_before_catchall_is_not_shadowed(self):
        rules = (
            ("fno/*", SiteRule(compute="bfloat16")),
            ("*", SiteRule(compute="float32")),
        )
        assert shadowed_entries(rules, site_universe()) == []

    def test_distinct_field_is_not_shadowed(self):
        # the later entry contributes a field the catch-all leaves UNSET
        rules = (
            ("*", SiteRule(compute="float32")),
            ("fno/*", SiteRule(accum="float32")),
        )
        assert shadowed_entries(rules, site_universe()) == []

    def test_pattern_matching_nothing_is_an_error(self):
        rules = (("nonexistent/bogus/site", SiteRule(compute="float32")),)
        findings = rule_table_findings(tables={"seeded": rules})
        hits = [f for f in findings if f.check == "pattern-no-match"]
        assert len(hits) == 1 and hits[0].severity == ERROR


class TestSeededSiteLiteralViolations:
    def test_orphan_site_literal_detected(self, tmp_path):
        (tmp_path / "mod.py").write_text(textwrap.dedent("""
            def f(policy, x):
                good = policy.at("fno/layer0/spectral/contract")
                bad = policy.at("fno/layer0/spectral/contracct")  # typo
                return good, bad
        """))
        findings = orphan_site_findings(str(tmp_path))
        assert len(findings) == 1
        f = findings[0]
        assert f.check == "orphan-site" and f.severity == ERROR
        assert f.site == "fno/layer0/spectral/contracct"
        assert f.where == "mod.py:4"

    def test_fstring_prefix_literals_recognised(self, tmp_path):
        (tmp_path / "mod.py").write_text(textwrap.dedent("""
            def f(policy, i):
                return policy.at(f"sfno/layer{i}/spectral/fft_in")
        """))
        assert orphan_site_findings(str(tmp_path)) == []

    def test_syntax_error_fails_loudly(self, tmp_path):
        (tmp_path / "broken.py").write_text("def f(:\n")
        with pytest.raises(SyntaxError):
            orphan_site_findings(str(tmp_path))


class _FakeSpec:
    def __init__(self, block_shape, index_map):
        self.block_shape = block_shape
        self.index_map = index_map


def _plain_copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def _disciplined_kernel(x_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += x_ref[...]


def _call(kernel, grid, in_specs, in_shapes, out_specs, out_shapes):
    return KernelCall(
        kernel=kernel, grid=grid, in_specs=in_specs, out_specs=out_specs,
        out_shape=[jax.ShapeDtypeStruct(s, jnp.float16) for s in out_shapes],
        arg_shapes=[(s, jnp.dtype(jnp.float16)) for s in in_shapes],
    )


class TestSeededKernelViolations:
    def test_oob_index_map_detected(self):
        call = _call(
            _plain_copy_kernel, grid=(3,),
            in_specs=[_FakeSpec((8,), lambda i: (i,))], in_shapes=[(16,)],
            out_specs=[_FakeSpec((8,), lambda i: (0,))], out_shapes=[(8,)])
        findings = check_call(call, "seeded")
        oob = [f for f in findings if f.check == "index-oob"]
        assert oob and all(f.severity == ERROR for f in oob)
        assert "in[0]" in oob[0].detail

    def test_fused_fake_oob_index_map_detected(self):
        """A ``spectral_fused``-shaped call (batch-tiled grid over a
        4-rank operand) whose batch index map overruns the padded
        extent is caught at exactly the index-oob check — the real
        fused family's traced calls stay clean
        (``TestCleanTree::test_kernels_pass_clean``)."""
        call = _call(
            _plain_copy_kernel, grid=(2,),
            in_specs=[_FakeSpec((2, 4, 8, 8), lambda i: (i + 1, 0, 0, 0))],
            in_shapes=[(4, 4, 8, 8)],
            out_specs=[_FakeSpec((2, 4, 8, 8), lambda i: (i, 0, 0, 0))],
            out_shapes=[(4, 4, 8, 8)])
        findings = check_call(call, "seeded:spectral_fused")
        oob = [f for f in findings if f.check == "index-oob"]
        assert oob and all(f.severity == ERROR for f in oob)
        assert "in[0]" in oob[0].detail
        assert [f.check for f in findings if f.check != "index-oob"] == []

    def test_uncovered_output_block_detected(self):
        call = _call(
            _plain_copy_kernel, grid=(1,),
            in_specs=[_FakeSpec((8,), lambda i: (i,))], in_shapes=[(8,)],
            out_specs=[_FakeSpec((8,), lambda i: (0,))], out_shapes=[(16,)])
        findings = check_call(call, "seeded")
        assert any(f.check == "output-not-covered" and f.severity == ERROR
                   for f in findings)

    def test_revisited_block_without_discipline_detected(self):
        call = _call(
            _plain_copy_kernel, grid=(2,),
            in_specs=[_FakeSpec((8,), lambda i: (i,))], in_shapes=[(16,)],
            out_specs=[_FakeSpec((8,), lambda i: (0,))], out_shapes=[(8,)])
        findings = check_call(call, "seeded")
        assert any(f.check == "accum-discipline" for f in findings)

    def test_init_accumulate_pattern_passes(self):
        call = _call(
            _disciplined_kernel, grid=(2,),
            in_specs=[_FakeSpec((8,), lambda i: (i,))], in_shapes=[(16,)],
            out_specs=[_FakeSpec((8,), lambda i: (0,))], out_shapes=[(8,)])
        assert check_call(call, "seeded") == []

    def test_tile_bytes_counts_both_sides(self):
        call = _call(
            _plain_copy_kernel, grid=(1,),
            in_specs=[_FakeSpec((8,), lambda i: (i,))], in_shapes=[(8,)],
            out_specs=[_FakeSpec((8,), lambda i: (0,))], out_shapes=[(8,)])
        assert tile_bytes(call) == 8 * 2 + 8 * 2  # f16 in + out tiles


# ---------------------------------------------------------------------------
# Clean tree: the shipped repo produces no error-severity findings
# ---------------------------------------------------------------------------


class TestCleanTree:
    def test_sites_pass_clean_on_repo(self):
        findings = sites_pass(_REPO_SRC)
        assert [f for f in findings if f.severity == ERROR] == []

    def test_kernels_pass_clean(self):
        findings = kernels_pass()
        assert [f for f in findings if f.severity == ERROR] == []

    def test_kernels_pass_covers_fused_family(self):
        from repro.analyze.kernels import kernel_families

        names = [name for name, _, _ in kernel_families()]
        assert "spectral_fused/fwd" in names
        assert "spectral_fused/bwd" in names

    @pytest.mark.parametrize("policy_name", ["full", "mixed_fno_fp16"])
    def test_model_forward_has_no_errors(self, policy_name):
        from repro.analyze import model_findings

        findings = model_findings("fno", get_policy(policy_name),
                                  use_pallas=True)
        assert [f for f in findings if f.severity == ERROR] == []


# ---------------------------------------------------------------------------
# Suppression machinery
# ---------------------------------------------------------------------------


def _finding(check="half-accum", severity=WARNING, site="fno/dense",
             where="fno/full"):
    return Finding(pass_name="dataflow", check=check, severity=severity,
                   site=site, where=where, detail="d")


class TestSuppressions:
    def test_partition_by_check_and_site_pattern(self):
        sup = Suppression(check="half-accum", reason="reviewed",
                          site="fno/*")
        active, suppressed = partition(
            [_finding(), _finding(site="sfno/dense"),
             _finding(check="round-trip-cast")],
            [sup])
        assert len(suppressed) == 1 and suppressed[0].site == "fno/dense"
        assert len(active) == 2

    def test_site_pattern_never_matches_siteless_finding(self):
        sup = Suppression(check="half-accum", reason="r", site="*")
        active, suppressed = partition([_finding(site=None)], [sup])
        assert suppressed == [] and len(active) == 1

    def test_load_suppressions_roundtrip(self, tmp_path):
        p = tmp_path / "analyze.toml"
        p.write_text(textwrap.dedent("""
            # comment
            [[suppress]]
            check = "round-trip-cast"
            site = "*/spectral/fft_in"
            reason = "Thm 3.2 boundary quantiser"
        """))
        sups = load_suppressions(str(p))
        assert sups == (Suppression(
            check="round-trip-cast", reason="Thm 3.2 boundary quantiser",
            site="*/spectral/fft_in"),)

    def test_missing_file_is_empty_allowlist(self, tmp_path):
        assert load_suppressions(str(tmp_path / "nope.toml")) == ()

    def test_entry_without_reason_rejected(self, tmp_path):
        p = tmp_path / "analyze.toml"
        p.write_text('[[suppress]]\ncheck = "half-accum"\n')
        with pytest.raises(ValueError, match="reason"):
            load_suppressions(str(p))

    def test_unknown_key_rejected(self, tmp_path):
        p = tmp_path / "analyze.toml"
        p.write_text(
            '[[suppress]]\ncheck = "x"\nreason = "r"\nseverty = "oops"\n')
        with pytest.raises(ValueError, match="unknown"):
            load_suppressions(str(p))

    def test_shipped_suppression_file_parses(self):
        path = os.path.join(_REPO_SRC, "..", "analyze.toml")
        sups = load_suppressions(path)
        assert sups, "repo analyze.toml should ship reviewed entries"
        assert all(s.reason for s in sups)

    def test_dedupe_keeps_first_seen_order(self):
        a, b = _finding(), _finding(check="other")
        assert dedupe([a, b, a]) == [a, b]


# ---------------------------------------------------------------------------
# CLI end-to-end (cheap configuration)
# ---------------------------------------------------------------------------


class TestCLI:
    def test_main_writes_report_and_exits_zero(self, tmp_path, capsys):
        from repro.analyze.__main__ import main

        out = tmp_path / "analyze.json"
        rc = main([
            "--policies", "full", "--models", "fno", "--pallas", "off",
            "--no-trainer", "--skip", "kernels", "sites",
            "--out", str(out),
        ])
        assert rc == 0
        assert out.exists()
        import json

        report = json.loads(out.read_text())
        assert report["policies"] == ["full"]
        assert report["summary"]["errors"] == 0
        assert "wrote" in capsys.readouterr().out
