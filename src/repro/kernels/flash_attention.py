"""Pallas TPU flash attention (blocked online-softmax).

Not a paper contribution — it is the substrate kernel the LM-architecture
pool (prefill_32k cells) needs so that 32k-token attention has an O(seq)
memory footprint instead of materialising the (S×S) score matrix.

Grid: (batch·heads, q_blocks, kv_blocks); the kv axis is the minor-most
(sequential on TPU), so VMEM scratch accumulators carry the running
max / normaliser / weighted sum across kv steps (FlashAttention-2 schedule
adapted to the TPU sequential-grid model).

VMEM per step: (block_q + 2·block_k)·D half words + block_q·(D+2) f32
scratch — D=128, blocks=128 is ~180 KiB, far under the ~16 MiB budget, so
block sizes can grow to 512 on real hardware (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale, causal, block_q, block_k, kv_len,
):
    kb = pl.program_id(2)
    nkb = pl.num_programs(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]  # (TQ, D)
    k = k_ref[0]  # (TK, D)
    v = v_ref[0]  # (TK, D)

    s = (
        jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        * scale
    )  # (TQ, TK)

    # always mask kv padding beyond the true length
    k_pos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(k_pos < kv_len, s, NEG_INF)
    if causal:
        qi = pl.program_id(1)
        q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
    m_ref[...] = m_new

    @pl.when(kb == nkb - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """q/k/v: (BH, S, D) — batch·heads pre-flattened. Returns (BH, S, D)."""
    BH, S, D = q.shape
    Sk = k.shape[1]
    assert k.shape == (BH, Sk, D) and v.shape == (BH, Sk, D), (q.shape, k.shape, v.shape)
    scale = 1.0 / (D ** 0.5)

    pad_q = (-S) % block_q
    pad_k = (-Sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        # padded kv columns masked out via the causal/k_pos mask below when
        # causal; for non-causal, pad keys with NEG_INF scores via zero keys
        # and rely on softmax normaliser (zeros add exp(-inf)≈0 after mask).
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    Sp, Skp = S + pad_q, Sk + pad_k

    grid = (BH, Sp // block_q, Skp // block_k)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal,
        block_q=block_q, block_k=block_k, kv_len=Sk,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, qi, _kb: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, D), lambda bh, _qi, kb: (bh, kb, 0)),
            pl.BlockSpec((1, block_k, D), lambda bh, _qi, kb: (bh, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda bh, qi, _kb: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :S, :]
