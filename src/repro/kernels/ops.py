"""jit'd public wrappers around the Pallas kernels.

These are the entry points the model code calls.  They handle:
  * complex <-> split-real conversion at the policy's spectral dtype,
  * mode flattening / padding,
  * interpret-mode selection (CPU container validates kernels in interpret
    mode; on TPU the same call compiles to Mosaic),
  * falling back shapes that the kernels don't support.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.precision import ComplexPair
from repro.precision import FULL, PrecisionPolicy
from .spectral_contract import spectral_contract_pallas, vmem_bytes
from .flash_attention import flash_attention as _flash
from .rmsnorm import rmsnorm as _rmsnorm


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def spectral_contract(
    x, w, *, policy=FULL, block_m: int = 64,
    site: str = "model/spectral/contract",
):
    """Dense spectral contraction ``bi<modes>,io<modes>->bo<modes>``.

    ``x``: complex64 or ComplexPair, shape (B, I, *modes);
    ``w``: complex64 (the layer's dense corner weight), shape (I, O, *modes).
    ``policy``: an already-resolved ``SitePrecision`` handed down by the
    model (``policy.at("fno/layer2/spectral/contract")``), or a bare
    ``PrecisionPolicy`` — then resolved here at ``site``, which direct
    callers must set to the layer's real address for per-layer
    ``precision_rules`` overrides to reach this path.
    Returns the same kind as ``x`` (ComplexPair under a half rule).
    """
    if isinstance(policy, PrecisionPolicy):
        policy = policy.at(site)
    half = policy.spectral_dtype or jnp.float32
    was_pair = isinstance(x, ComplexPair)
    if not was_pair:
        x = ComplexPair.from_complex(x, half)
    wp = ComplexPair.from_complex(w, half) if not isinstance(w, ComplexPair) else w

    B, I, *modes = x.re.shape
    I2, O, *modes2 = wp.re.shape
    assert tuple(modes) == tuple(modes2) and I == I2, (x.re.shape, wp.re.shape)
    M = 1
    for m in modes:
        M *= m

    xr = x.re.reshape(B, I, M)
    xi = x.im.reshape(B, I, M)
    wr = wp.re.reshape(I, O, M)
    wi = wp.im.reshape(I, O, M)

    out_re, out_im = spectral_contract_pallas(
        xr, xi, wr, wi, block_m=block_m, interpret=_use_interpret(),
        out_dtype=half,
    )
    pair = ComplexPair(
        out_re.reshape(B, O, *modes), out_im.reshape(B, O, *modes)
    )
    if was_pair and policy.spectral_is_half:
        return pair
    return pair.to_complex()


def flash_attention(q, k, v, *, causal=True, block_q=128, block_k=128):
    """(B, H, S, D) attention; flattens heads into the grid batch axis."""
    B, H, S, D = q.shape
    Sk = k.shape[2]
    qf = q.reshape(B * H, S, D)
    kf = k.reshape(B * H, Sk, D)
    vf = v.reshape(B * H, Sk, D)
    out = _flash(
        qf, kf, vf, causal=causal, block_q=block_q, block_k=block_k,
        interpret=_use_interpret(),
    )
    return out.reshape(B, H, S, D)


def rmsnorm(x, w, *, eps: float = 1e-6, block_rows: int = 256):
    """Rank-agnostic RMSNorm over the last axis."""
    shape = x.shape
    flat = x.reshape(-1, shape[-1])
    out = _rmsnorm(flat, w, eps=eps, block_rows=block_rows, interpret=_use_interpret())
    return out.reshape(shape)


__all__ = ["spectral_contract", "flash_attention", "rmsnorm", "vmem_bytes"]
