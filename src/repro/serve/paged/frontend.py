"""Async serving frontend: ``submit_async`` / ``stream`` generators in
front of the synchronous engine tick loop, with per-request deadlines.

One driver task owns the tick loop: it runs while any watched request is
unfinished, delivering newly generated tokens to per-request queues
after every tick and resolving completion events.  Callers are plain
coroutines:

    front = AsyncServeFrontend(engine)
    req = await front.submit_async(Request(...), deadline_ms=250.0)
    async for tok in front.stream(Request(...)):
        ...

Deadlines are *accounting*, not preemption — a missed request still
completes (the CORTEX-style harness in ``benchmarks/bench_serve_slo.py``
wants the full latency distribution, and killing work mid-slot would
perturb the other slots' batching).  Every request leaves a metrics
record: submit->finish latency, time-to-first-token, deadline verdict.
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
from typing import Any, AsyncIterator, Dict, List, Optional

from repro.obs import registry
from repro.obs import trace as obs_trace


@dataclasses.dataclass
class _Tracked:
    req: Any
    t0: float
    deadline_ms: Optional[float]
    queue: Optional[asyncio.Queue]
    done: asyncio.Event
    delivered: int = 0
    ttft_s: Optional[float] = None


class AsyncServeFrontend:
    """Async facade over any ``submit / tick / drain / stats`` engine."""

    def __init__(self, engine, tick_sleep_s: float = 0.0,
                 max_ticks: int = 1_000_000):
        self.engine = engine
        self.tick_sleep_s = tick_sleep_s
        self.max_ticks = max_ticks
        self._watch: Dict[int, _Tracked] = {}
        self._driver: Optional[asyncio.Task] = None
        self.records: List[Dict[str, Any]] = []

    # -- public API ------------------------------------------------------------
    async def submit_async(self, req, deadline_ms: Optional[float] = None):
        """Submit and await completion; returns the finished request."""
        tr = self._track(req, deadline_ms, want_stream=False)
        if not self.engine.submit(req):
            self._finish(tr, time.perf_counter())
            return req
        self._ensure_driver()
        await tr.done.wait()
        return req

    async def stream(self, req, deadline_ms: Optional[float] = None
                     ) -> AsyncIterator[int]:
        """Submit and yield tokens as the tick loop generates them."""
        tr = self._track(req, deadline_ms, want_stream=True)
        if not self.engine.submit(req):
            self._finish(tr, time.perf_counter())
            return
        self._ensure_driver()
        while True:
            tok = await tr.queue.get()
            if tok is None:
                return
            yield tok

    # -- bookkeeping -----------------------------------------------------------
    def _track(self, req, deadline_ms, want_stream: bool) -> _Tracked:
        if req.uid in self._watch:
            raise ValueError(f"request uid {req.uid} is already in flight")
        tr = _Tracked(req=req, t0=time.perf_counter(), deadline_ms=deadline_ms,
                      queue=asyncio.Queue() if want_stream else None,
                      done=asyncio.Event())
        self._watch[req.uid] = tr
        if obs_trace.is_enabled():
            # two async tracks per uid: the whole submit->finish latency
            # and the TTFT prefix, closed at the first generated token
            obs_trace.begin("frontend/request", req.uid, category="frontend",
                            deadline_ms=deadline_ms)
            obs_trace.begin("frontend/ttft", req.uid, category="frontend")
        return tr

    def _ensure_driver(self):
        if self._driver is None or self._driver.done():
            self._driver = asyncio.get_running_loop().create_task(self._run())

    def _finish(self, tr: _Tracked, now: float):
        latency_ms = (now - tr.t0) * 1e3
        missed = (tr.deadline_ms is not None and tr.req.status == "done"
                  and latency_ms > tr.deadline_ms)
        reg = registry()
        reg.histogram("repro_frontend_latency_ms").observe(latency_ms)
        reg.counter("repro_frontend_requests_total",
                    status=tr.req.status).inc()
        if missed:
            reg.counter("repro_frontend_deadline_misses_total").inc()
        if obs_trace.is_enabled():
            if tr.ttft_s is None:
                obs_trace.end("frontend/ttft", tr.req.uid,
                              category="frontend")
            obs_trace.end("frontend/request", tr.req.uid,
                          category="frontend", status=tr.req.status,
                          latency_ms=round(latency_ms, 3),
                          deadline_missed=bool(missed))
        self.records.append({
            "uid": tr.req.uid,
            "status": tr.req.status,
            "latency_ms": round(latency_ms, 3),
            "ttft_ms": round(tr.ttft_s * 1e3, 3)
            if tr.ttft_s is not None else None,
            "deadline_ms": tr.deadline_ms,
            "deadline_missed": bool(missed),
            "n_generated": len(getattr(tr.req, "generated", []) or []),
        })
        self._watch.pop(tr.req.uid, None)
        if tr.queue is not None:
            tr.queue.put_nowait(None)
        tr.done.set()

    async def _run(self):
        """The driver: tick while anything is watched, deliver tokens."""
        ticks = 0
        while self._watch and ticks < self.max_ticks:
            # yield to the event loop *before* the (blocking) device step
            # so queued arrival coroutines get to submit into this tick
            await asyncio.sleep(self.tick_sleep_s)
            self.engine.tick()
            ticks += 1
            now = time.perf_counter()
            for tr in list(self._watch.values()):
                gen = getattr(tr.req, "generated", None) or []
                if tr.ttft_s is None and len(gen) > 0:
                    tr.ttft_s = now - tr.t0
                    registry().histogram("repro_frontend_ttft_ms").observe(
                        tr.ttft_s * 1e3)
                    if obs_trace.is_enabled():
                        obs_trace.end("frontend/ttft", tr.req.uid,
                                      category="frontend",
                                      ttft_ms=round(tr.ttft_s * 1e3, 3))
                while tr.delivered < len(gen):
                    tok = gen[tr.delivered]
                    tr.delivered += 1
                    if tr.queue is not None:
                        tr.queue.put_nowait(tok)
                if tr.req.status in ("done", "failed"):
                    self._finish(tr, now)

    # -- metrics ---------------------------------------------------------------
    def metrics(self) -> Dict[str, Any]:
        """SLO accounting over every finished request."""
        lats = sorted(r["latency_ms"] for r in self.records
                      if r["status"] == "done")
        with_deadline = [r for r in self.records
                         if r["deadline_ms"] is not None
                         and r["status"] == "done"]
        out: Dict[str, Any] = {
            "requests": len(self.records),
            "completed": sum(r["status"] == "done" for r in self.records),
            "failed": sum(r["status"] == "failed" for r in self.records),
            "deadline_misses": sum(r["deadline_missed"]
                                   for r in self.records),
            "deadline_miss_rate": round(
                sum(r["deadline_missed"] for r in with_deadline)
                / len(with_deadline), 4) if with_deadline else None,
        }
        if lats:
            def pct(p):
                k = min(len(lats) - 1, max(0, int(round(
                    p / 100.0 * (len(lats) - 1)))))
                return round(lats[k], 3)
            mean = sum(lats) / len(lats)
            out.update({
                "latency_ms": {
                    "p50": pct(50), "p90": pct(90), "p99": pct(99),
                    "mean": round(mean, 3), "max": round(lats[-1], 3),
                },
                # jitter: latency stddev — the CORTEX real-time metric
                "jitter_ms": round(
                    (sum((x - mean) ** 2 for x in lats) / len(lats)) ** 0.5,
                    3),
            })
        registry().publish("frontend", out)
        return out
