"""Batched LM serving demo: the v2 Engine API over the slot engine.

Loads a reduced config from the architecture pool (selectable with
``--arch``; any of the 10 assigned ids), submits a stream of requests
through the scheduler (FCFS or shortest-prompt-first), and drives
chunked-prefill decoding with per-request sampling:

    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-370m
    PYTHONPATH=src python examples/serve_lm.py --scheduler spf \\
        --temperature 0.8 --top-p 0.9 --prefill-chunk 16

``--paged`` swaps in the paged KV-block engine (``--block-size`` rows
per block, prefix sharing on); ``--slo-deadline-ms`` drives the run
through the async frontend with a per-request deadline and prints the
SLO accounting:

    PYTHONPATH=src python examples/serve_lm.py --paged --block-size 8 \\
        --slo-deadline-ms 250
"""
import argparse
import asyncio
import json

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models.lm import init_lm
from repro.serve import (
    AsyncServeFrontend,
    LMEngine,
    PagedLMEngine,
    Request,
    SamplingParams,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--scheduler", default="fcfs", choices=["fcfs", "spf"])
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="prompt tokens consumed per tick "
                         "(default: auto — 8 dense, 1 MoE)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--paged", action="store_true",
                    help="serve over the paged KV-block cache "
                         "(COW + prefix sharing)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV rows per block (paged engine only)")
    ap.add_argument("--slo-deadline-ms", type=float, default=None,
                    help="drive requests through the async frontend with "
                         "this per-request deadline and report SLO metrics")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    if cfg.encoder_decoder:
        raise SystemExit("enc-dec serving demo: use whisper_decode_step directly")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    if args.paged:
        engine = PagedLMEngine(params, cfg, n_slots=args.slots, max_len=64,
                               scheduler=args.scheduler,
                               prefill_chunk=args.prefill_chunk,
                               seed=args.seed, block_size=args.block_size)
    else:
        engine = LMEngine(params, cfg, n_slots=args.slots, max_len=64,
                          scheduler=args.scheduler,
                          prefill_chunk=args.prefill_chunk, seed=args.seed)

    sampling = SamplingParams(temperature=args.temperature,
                              top_k=args.top_k, top_p=args.top_p)
    rng = np.random.RandomState(0)
    reqs = [
        Request(uid=i, prompt=list(rng.randint(1, cfg.vocab, rng.randint(3, 8))),
                max_new_tokens=8, sampling=sampling)
        for i in range(args.requests)
    ]
    if args.slo_deadline_ms is not None:
        front = AsyncServeFrontend(engine)

        async def run_async():
            return await asyncio.gather(*[
                front.submit_async(r, deadline_ms=args.slo_deadline_ms)
                for r in reqs])

        done = asyncio.run(run_async())
        ticks = engine.stats()["ticks"]
        print("slo:", json.dumps(front.metrics(), indent=1))
    else:
        for r in reqs:
            engine.submit(r)
        done, ticks = engine.drain()
    stats = engine.stats()
    print(f"arch={args.arch} slots={args.slots} scheduler={args.scheduler} "
          f"chunk={engine.prefill_chunk}: served {stats['completed']} requests "
          f"in {ticks} ticks ({stats['wall_s']:.2f}s; "
          f"{stats['tokens_per_s']} tok/s on CPU)")
    for r in done[:3]:
        print(f"  req {r.uid}: prompt={r.prompt} -> generated={r.generated}")
    print("stats:", json.dumps(stats, indent=1))


if __name__ == "__main__":
    main()
