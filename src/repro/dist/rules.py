"""Logical-axis rule table: the single place where logical tensor axes
map onto physical mesh axes.

Models and step builders talk exclusively in *logical* names ("dp",
"tp", "seq", "heads", "expert", ...); the production meshes expose
*physical* names ("pod", "data", "model").  A rule maps one logical
name to an ordered tuple of physical axes — resolution keeps only the
axes present in the target mesh, so the same model code lowers
unchanged on the single-pod 16x16 mesh, the 2x16x16 multi-pod mesh, a
debug 1xN mesh, or no mesh at all.

``axis_rules(...)`` overrides the table for a scope (thread-local), so
a launch script can e.g. retarget sequence parallelism onto a dedicated
axis without touching any model file.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Iterator, Optional, Sequence, Tuple, Union

AxisName = str
Entry = Union[None, AxisName, Tuple[AxisName, ...]]

#: logical name -> ordered physical axes it may occupy.
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    # batch-like parallelism: every pod/data axis the mesh has
    "dp": ("pod", "data"),
    "batch": ("pod", "data"),
    # every axis (full-DP layouts for small-weight models, e.g. FNO)
    "all": ("pod", "data", "model"),
    # tensor-parallel family: these all live on the physical model axis
    "tp": ("model",),
    "seq": ("model",),     # sequence parallelism shares the tp axis
    "heads": ("model",),
    "embed": ("model",),
    "vocab": ("model",),
    "expert": ("model",),  # expert parallelism for MoE
}

_local = threading.local()


def current_rules() -> Dict[str, Tuple[str, ...]]:
    return getattr(_local, "rules", DEFAULT_RULES)


@contextmanager
def axis_rules(**overrides: Sequence[str]) -> Iterator[None]:
    """Scope-local overrides of the logical->physical table.

    >>> with axis_rules(seq=("data",)):
    ...     ...  # sequence parallelism over the data axis in this scope
    """
    prev = current_rules()
    merged = dict(prev)
    for name, axes in overrides.items():
        merged[name] = (axes,) if isinstance(axes, str) else tuple(axes)
    _local.rules = merged
    try:
        yield
    finally:
        _local.rules = prev


def resolve_axes(entry: Entry, mesh, used: Optional[set] = None) -> Tuple[str, ...]:
    """Resolve one per-dimension spec entry to physical mesh axes.

    ``entry`` is None, a single name, or a tuple of names; each name may
    be logical (looked up in the rule table) or already physical.  Axes
    absent from ``mesh`` are dropped silently (mesh-shape adaptation);
    axes in ``used`` are dropped (an axis shards at most one dim).
    """
    if entry is None:
        return ()
    names = (entry,) if isinstance(entry, str) else tuple(entry)
    rules = current_rules()
    mesh_axes = tuple(mesh.axis_names) if mesh is not None else ()
    out = []
    for name in names:
        for phys in rules.get(name, (name,)):
            if phys in mesh_axes and phys not in out and (
                used is None or phys not in used
            ):
                out.append(phys)
    return tuple(out)


def normalize_entry(axes: Tuple[str, ...]) -> Entry:
    """Physical axes tuple -> canonical PartitionSpec entry."""
    if not axes:
        return None
    if len(axes) == 1:
        return axes[0]
    return axes
