"""hymba-1.5b [hybrid] — parallel attention + mamba heads per block;
SWA everywhere except 3 full-attention layers (first/middle/last).
At 500k decode the full-attn layers also ring-buffer to the SWA window
(documented deviation, DESIGN.md §7 — keeps the stacked-layer cache O(W)).
[arXiv:2411.13676; hf]"""
from .base import LMArchConfig

CONFIG = LMArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab=32001, head_dim=64,
    mixer="hymba", attn_window=2048, n_full_attn_layers=3,
    ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128,
)

SMOKE = LMArchConfig(
    name="hymba-1.5b-smoke", family="hybrid",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, head_dim=16,
    mixer="hymba", attn_window=32, n_full_attn_layers=1,
    ssm_state=8, ssm_expand=2, ssm_head_dim=16, ssm_chunk=16,
)
