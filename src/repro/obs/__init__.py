"""repro.obs — unified tracing, metrics & numerics-event layer.

One spine for the evidence the paper's claim needs at production scale:

* :mod:`repro.obs.trace` — host-side span/event tracing (ring-buffered,
  free when disabled) that nests around jit boundaries;
* :mod:`repro.obs.metrics` — the typed Counter/Gauge/Histogram registry
  every ``stats()`` surface publishes into; ``snapshot()`` is the single
  machine-readable source for engine/trainer stats;
* :mod:`repro.obs.numerics` — the structured numerics-event stream
  (autoprec decisions with their budget numbers, overflow streaks,
  loss-scale moves, tile-cache outcomes, oracle rejects) interleaved
  with the performance timeline;
* :mod:`repro.obs.export` — Chrome ``trace_event`` JSON (Perfetto),
  Prometheus text exposition, JSONL run logs, and the shared
  benchmark-result header — all written atomically.

``python -m repro.obs`` renders a run summary table from a JSONL log
and converts it to a Chrome trace or Prometheus snapshot.

Span taxonomy (see README "Observability"): ``train/step``,
``train/data``, ``train/telemetry``, ``train/controller``,
``serve/tick``, ``serve/prefill``, ``serve/decode``,
``serve/operator/batch``, plus per-request async phases
``request``/``ttft`` correlated by uid.  Metric names follow
``repro_<subsystem>_<name>``.
"""
from .export import (  # noqa: F401
    RESULT_SCHEMA_VERSION,
    chrome_trace,
    prometheus_text,
    read_jsonl,
    result_header,
    run_records,
    validate_chrome_trace,
    write_chrome_trace,
    write_json_atomic,
    write_jsonl,
    write_prometheus,
    write_result,
    write_text_atomic,
)
from .metrics import (  # noqa: F401
    DEFAULT_EDGES_MS,
    MAX_LABEL_SETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    metric_names,
    registry,
)
from .numerics import (  # noqa: F401
    KINDS,
    autoprec_decision,
    loss_scale_event,
    numerics_event,
    oracle_reject,
    tile_cache_event,
)
from .trace import (  # noqa: F401
    begin,
    clear,
    disable,
    dropped,
    enable,
    end,
    event,
    is_enabled,
    snapshot,
    span,
)
