"""Pallas TPU kernels for the mixed-precision spectral tensor contraction.

This is the paper's compute hot-spot (Appendix B.4: complex-valued tensor
contraction = 4 of the top-5 GPU kernels, forward *and* backward).  The GPU
implementation uses ``view_as_real`` + cuBLAS half GEMMs; the TPU-native
adaptation tiles the contraction over *retained Fourier modes* into VMEM and
issues, per tile, a batched complex matmul as four real MXU matmuls with f32
accumulation:

    out[b,o,m] = Σ_i x[b,i,m] · w[i,o,m]          (complex, per mode m)

The op is **training-grade**: it carries a ``jax.custom_vjp`` whose backward
pass is two more Pallas kernels on the *same* mode-tiled schedule —

    dL/dx[b,i,m] = Σ_o g[b,o,m] · conj(w[i,o,m])     (contract O per tile)
    dL/dw[i,o,m] = Σ_b conj(x[b,i,m]) · g[b,o,m]     (contract B per tile)

— which are exactly the real-valued VJPs of the split-real 4-matmul forward
(the conjugations fall out of the rr−ii / ri+ir component algebra).  Both
accumulate at f32 (f64 under an ``enable_x64`` gradcheck) and store at the
primal dtypes, matching the forward's error model: half *storage*, full
*accumulation* — precisely what Theorem 3.2 bounds.

A second kernel family handles the **CP-factorised** contraction (TFNO,
paper §4.6).  The wrapper folds λ and the per-mode factors into one mode
factor ``W[r,m] = λ_r Π_k U_mk[m_k,r]`` (tiny, jnp, differentiable) and the
kernel then runs, per mode tile, the three factorised stages without ever
materialising the dense (I,O,M) weight:

    t[b,m,r] = Σ_i x[b,i,m] U_i[i,r]      rank-project   (4 real matmuls)
    u[b,m,r] = t[b,m,r] · W[r,m]          mode-scale     (VPU elementwise)
    o[b,o,m] = Σ_r u[b,m,r] U_o[o,r]      rank-expand    (4 real matmuls)

Its backward is one Pallas kernel that recomputes t,u in-tile (cheaper than
saving rank-space residuals to HBM) and emits all four gradients; dU_i/dU_o
are mode-independent, so their output blocks revisit across the sequential
grid and accumulate in place at f32.

Layout decisions (HBM→VMEM→MXU):
  * modes are flattened to one axis ``M`` and tiled by ``block_m`` — see
    ``vmem_bytes`` / ``cp_vmem_bytes`` for the per-step VMEM working set and
    ``pick_block_m`` for budget-driven tile selection;
  * channels (I, O) and CP ranks are contracted with
    ``preferred_element_type=float32`` so accumulation never happens in
    half precision — only *storage* is half;
  * the 4-multiply complex product (rr−ii, ri+ir) is used rather than
    Karatsuba 3-mult: on the MXU the extra multiply is free relative to
    the added adds/temporaries of the 3-mult form.

Validated against ``ref.spectral_contract_ref`` / ``spectral_contract_cp_ref``
in interpret mode on CPU (tests/test_kernels.py, tests/test_kernels_diff.py);
on TPU the same code path compiles natively.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: VMEM per TPU core (v5e-class) — the budget ``pick_block_m`` packs under.
VMEM_BUDGET = 16 * 2 ** 20

#: Version of the kernel *schedules* in this module (tile layouts, matmul
#: decomposition, accumulation discipline).  Bump whenever a change could
#: shift the perf landscape — every ``repro.tune`` calibration entry is
#: keyed by this value, so a bump invalidates stale tuning results
#: without anyone having to remember to delete the cache file.
#: v3: the fused rFFT→contract→irFFT family (``spectral_fused``) joins
#: the registry, batch-tiled with its own VMEM estimators.
KERNEL_VERSION = 3


def _acc_dtype(dtype) -> jnp.dtype:
    """Accumulator dtype: f32 everywhere except under an x64 gradcheck."""
    return jnp.float64 if jnp.dtype(dtype) == jnp.float64 else jnp.float32


def _pad_modes(a: jnp.ndarray, block_m: int) -> jnp.ndarray:
    pad = (-a.shape[-1]) % block_m
    if not pad:
        return a
    return jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, pad)])


# ---------------------------------------------------------------------------
# Dense kernels: forward + the two backward contractions
# ---------------------------------------------------------------------------


def _cast_tiles(cast_to, *tiles):
    """Fused storage-cast prologue: round freshly-loaded f32 tiles onto
    the site's half storage grid *in VMEM*, so the half copy of the
    operands never round-trips through HBM.  ``astype`` here performs
    exactly the rounding ``ComplexPair.from_complex`` would have done on
    the unfused path — the Thm 3.2 representation error is identical,
    only the HBM traffic changes."""
    if cast_to is None:
        return tiles
    return tuple(t.astype(cast_to) for t in tiles)


def _dense_fwd_kernel(xr_ref, xi_ref, wr_ref, wi_ref, or_ref, oi_ref,
                      *, cast_to=None):
    """One mode-tile step: batched (over modes) complex matmul.

    Refs (VMEM tiles):
      xr/xi: (B, I, TM)   wr/wi: (I, O, TM)   or/oi: (B, O, TM)
    ``cast_to``: fused-quantise mode — refs hold f32 and the storage
    rounding happens in the tile prologue (see ``_cast_tiles``).
    """
    xr, xi = xr_ref[...], xi_ref[...]
    wr, wi = wr_ref[...], wi_ref[...]
    xr, xi, wr, wi = _cast_tiles(cast_to, xr, xi, wr, wi)
    acc = _acc_dtype(xr.dtype)

    def bmm(a, b):
        # contract I; batch over the mode tile axis (last axis of both).
        # dot_general batch dims lead the output: (TM, B, O).
        return jax.lax.dot_general(
            a,
            b,
            dimension_numbers=(((1,), (0,)), ((2,), (2,))),
            preferred_element_type=acc,
        )

    rr = bmm(xr, wr)
    ii = bmm(xi, wi)
    ri = bmm(xr, wi)
    ir = bmm(xi, wr)
    or_ref[...] = jnp.transpose(rr - ii, (1, 2, 0)).astype(or_ref.dtype)
    oi_ref[...] = jnp.transpose(ri + ir, (1, 2, 0)).astype(oi_ref.dtype)


def _dense_bwd_x_kernel(gr_ref, gi_ref, wr_ref, wi_ref, dxr_ref, dxi_ref,
                        *, cast_to=None):
    """dx = g · conj(w): contract O per mode tile.

    Refs: gr/gi (B, O, TM), wr/wi (I, O, TM) -> dxr/dxi (B, I, TM).
    Split-real: dxr = Σ_o gr·wr + gi·wi ; dxi = Σ_o gi·wr − gr·wi.
    """
    gr, gi = gr_ref[...], gi_ref[...]
    wr, wi = wr_ref[...], wi_ref[...]
    gr, gi, wr, wi = _cast_tiles(cast_to, gr, gi, wr, wi)
    acc = _acc_dtype(gr.dtype)

    def bmm(a, b):
        # (B,O,TM) x (I,O,TM): contract O, batch TM -> (TM, B, I)
        return jax.lax.dot_general(
            a, b, (((1,), (1,)), ((2,), (2,))), preferred_element_type=acc
        )

    dxr = bmm(gr, wr) + bmm(gi, wi)
    dxi = bmm(gi, wr) - bmm(gr, wi)
    dxr_ref[...] = jnp.transpose(dxr, (1, 2, 0)).astype(dxr_ref.dtype)
    dxi_ref[...] = jnp.transpose(dxi, (1, 2, 0)).astype(dxi_ref.dtype)


def _dense_bwd_w_kernel(xr_ref, xi_ref, gr_ref, gi_ref, dwr_ref, dwi_ref,
                        *, cast_to=None):
    """dw = conj(x) · g: contract B per mode tile.

    Refs: xr/xi (B, I, TM), gr/gi (B, O, TM) -> dwr/dwi (I, O, TM).
    Split-real: dwr = Σ_b xr·gr + xi·gi ; dwi = Σ_b xr·gi − xi·gr.
    """
    xr, xi = xr_ref[...], xi_ref[...]
    gr, gi = gr_ref[...], gi_ref[...]
    xr, xi, gr, gi = _cast_tiles(cast_to, xr, xi, gr, gi)
    acc = _acc_dtype(xr.dtype)

    def bmm(a, b):
        # (B,I,TM) x (B,O,TM): contract B, batch TM -> (TM, I, O)
        return jax.lax.dot_general(
            a, b, (((0,), (0,)), ((2,), (2,))), preferred_element_type=acc
        )

    dwr = bmm(xr, gr) + bmm(xi, gi)
    dwi = bmm(xr, gi) - bmm(xi, gr)
    dwr_ref[...] = jnp.transpose(dwr, (1, 2, 0)).astype(dwr_ref.dtype)
    dwi_ref[...] = jnp.transpose(dwi, (1, 2, 0)).astype(dwi_ref.dtype)


def _dense_call(kernel, a_specs, out_specs, out_shapes, grid, interpret, *args):
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=a_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )(*args)


def _x_spec(B, I, block_m):
    return pl.BlockSpec((B, I, block_m), lambda m: (0, 0, m))


def _dense_fwd_call(config, xr, xi, wr, wi):
    block_m, _block_m_bwd, interpret, out_dtype, cast_to = config
    B, I, M = xr.shape
    _, O, _ = wr.shape
    xr, xi, wr, wi = (_pad_modes(a, block_m) for a in (xr, xi, wr, wi))
    Mp = xr.shape[-1]
    out_re, out_im = _dense_call(
        functools.partial(_dense_fwd_kernel, cast_to=cast_to),
        [_x_spec(B, I, block_m)] * 2 + [_x_spec(I, O, block_m)] * 2,
        [_x_spec(B, O, block_m)] * 2,
        [jax.ShapeDtypeStruct((B, O, Mp), out_dtype)] * 2,
        (Mp // block_m,),
        interpret,
        xr, xi, wr, wi,
    )
    return out_re[..., :M], out_im[..., :M]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _dense_op(config, xr, xi, wr, wi):
    return _dense_fwd_call(config, xr, xi, wr, wi)


def _dense_op_fwd(config, xr, xi, wr, wi):
    return _dense_fwd_call(config, xr, xi, wr, wi), (xr, xi, wr, wi)


def _dense_op_bwd(config, res, cts):
    xr, xi, wr, wi = res
    gr, gi = cts
    _block_m, block_m, interpret, _, cast_to = config
    B, I, M = xr.shape
    _, O, _ = wr.shape
    grp, gip = _pad_modes(gr, block_m), _pad_modes(gi, block_m)
    wrp, wip = _pad_modes(wr, block_m), _pad_modes(wi, block_m)
    xrp, xip = _pad_modes(xr, block_m), _pad_modes(xi, block_m)
    Mp = grp.shape[-1]
    grid = (Mp // block_m,)
    dxr, dxi = _dense_call(
        functools.partial(_dense_bwd_x_kernel, cast_to=cast_to),
        [_x_spec(B, O, block_m)] * 2 + [_x_spec(I, O, block_m)] * 2,
        [_x_spec(B, I, block_m)] * 2,
        [jax.ShapeDtypeStruct((B, I, Mp), xr.dtype)] * 2,
        grid, interpret, grp, gip, wrp, wip,
    )
    dwr, dwi = _dense_call(
        functools.partial(_dense_bwd_w_kernel, cast_to=cast_to),
        [_x_spec(B, I, block_m)] * 2 + [_x_spec(B, O, block_m)] * 2,
        [_x_spec(I, O, block_m)] * 2,
        [jax.ShapeDtypeStruct((I, O, Mp), wr.dtype)] * 2,
        grid, interpret, xrp, xip, grp, gip,
    )
    return (dxr[..., :M], dxi[..., :M], dwr[..., :M], dwi[..., :M])


_dense_op.defvjp(_dense_op_fwd, _dense_op_bwd)


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_m", "block_m_bwd", "interpret", "out_dtype", "cast_to"
    ),
)
def spectral_contract_pallas(
    xr: jnp.ndarray,
    xi: jnp.ndarray,
    wr: jnp.ndarray,
    wi: jnp.ndarray,
    *,
    block_m: int = 64,
    block_m_bwd: int | None = None,
    interpret: bool = True,
    out_dtype=None,
    cast_to=None,
) -> tuple:
    """Split-real complex contraction ``bim,iom->bom`` (differentiable).

    Args:
      xr/xi: (B, I, M) half (or f32) real/imag parts of the spectrum tile.
      wr/wi: (I, O, M) spectral weights.
      block_m: mode-tile size (VMEM working set scales linearly in it).
      block_m_bwd: mode-tile size for the two backward kernels (defaults
        to ``block_m``; the autotuner calibrates the directions
        independently because their working sets differ).
      interpret: run the kernel body in Python (CPU validation); on TPU
        pass False to compile to Mosaic.
      cast_to: fused-quantise mode — pass the half storage dtype and feed
        f32 operands; each tile is rounded onto the storage grid in VMEM
        (same Thm 3.2 representation error as pre-casting in HBM, one
        fewer HBM round-trip).

    Returns (out_re, out_im): (B, O, M) at ``out_dtype`` (default: x dtype).
    Reverse-mode differentiation runs the two backward Pallas kernels
    (``dL/dx = g·w̄``, ``dL/dw = x̄·g``) on the same mode tiling.
    """
    B, I, M = xr.shape
    I2, O, M2 = wr.shape
    if I != I2 or M != M2:
        raise ValueError(
            f"spectral_contract_pallas: x {xr.shape} vs w {wr.shape} — "
            f"expected (B, I, M) and (I, O, M) with matching I and M"
        )
    out_dtype = jnp.dtype(out_dtype or xr.dtype)
    cast_to = jnp.dtype(cast_to) if cast_to is not None else None
    config = (block_m, block_m_bwd or block_m, interpret, out_dtype, cast_to)
    return _dense_op(config, xr, xi, wr, wi)


# ---------------------------------------------------------------------------
# CP-factorised kernels (TFNO): project -> mode-scale -> expand per tile
# ---------------------------------------------------------------------------


def _cp_fwd_stages(xr, xi, uir, uii, uor, uoi, wr, wi, acc):
    """The three factorised stages at the accumulator dtype; returns
    (tr, ti, ur, ui, our, oui) so the backward can reuse t and u."""

    def dg(a, b, dims):
        return jax.lax.dot_general(a, b, (dims, ((), ())),
                                   preferred_element_type=acc)

    # rank-project: t[b,m,r] = Σ_i x[b,i,m] Ui[i,r]
    d_t = ((1,), (0,))
    tr = dg(xr, uir, d_t) - dg(xi, uii, d_t)
    ti = dg(xr, uii, d_t) + dg(xi, uir, d_t)
    # mode-scale: u[b,m,r] = t[b,m,r] · W[r,m]
    wrT = jnp.transpose(wr, (1, 0)).astype(acc)[None]
    wiT = jnp.transpose(wi, (1, 0)).astype(acc)[None]
    ur = tr * wrT - ti * wiT
    ui = tr * wiT + ti * wrT
    # rank-expand: o[b,m,o] = Σ_r u[b,m,r] Uo[o,r]
    d_o = ((2,), (1,))
    our = dg(ur, uor, d_o) - dg(ui, uoi, d_o)
    oui = dg(ur, uoi, d_o) + dg(ui, uor, d_o)
    return tr, ti, ur, ui, our, oui


def _cp_fwd_kernel(xr_ref, xi_ref, uir_ref, uii_ref, uor_ref, uoi_ref,
                   wr_ref, wi_ref, or_ref, oi_ref):
    """Refs: x (B,I,TM), Ui (I,R), Uo (O,R), W (R,TM) -> out (B,O,TM)."""
    acc = _acc_dtype(xr_ref.dtype)
    _, _, _, _, our, oui = _cp_fwd_stages(
        xr_ref[...], xi_ref[...], uir_ref[...], uii_ref[...],
        uor_ref[...], uoi_ref[...], wr_ref[...], wi_ref[...], acc,
    )
    or_ref[...] = jnp.transpose(our, (0, 2, 1)).astype(or_ref.dtype)
    oi_ref[...] = jnp.transpose(oui, (0, 2, 1)).astype(oi_ref.dtype)


def _cp_bwd_kernel(xr_ref, xi_ref, uir_ref, uii_ref, uor_ref, uoi_ref,
                   wr_ref, wi_ref, gr_ref, gi_ref,
                   dxr_ref, dxi_ref, duir_ref, duii_ref,
                   duor_ref, duoi_ref, dwr_ref, dwi_ref):
    """Full CP backward for one mode tile.

    Recomputes t,u (cheaper than storing rank-space residuals in HBM),
    then:  du = g·Ūo,  dUo += g·ū,  dt = du·W̄,  dW = Σ_b du·t̄,
           dx = dt·Ūi,  dUi += x̄·dt.
    The mode-independent dUi/dUo blocks revisit across the (sequential)
    grid and accumulate in place at f32.
    """
    xr, xi = xr_ref[...], xi_ref[...]
    uir, uii = uir_ref[...], uii_ref[...]
    uor, uoi = uor_ref[...], uoi_ref[...]
    wr, wi = wr_ref[...], wi_ref[...]
    gr, gi = gr_ref[...], gi_ref[...]
    acc = _acc_dtype(xr.dtype)

    def dg(a, b, dims):
        return jax.lax.dot_general(a, b, (dims, ((), ())),
                                   preferred_element_type=acc)

    tr, ti, ur, ui, _, _ = _cp_fwd_stages(
        xr, xi, uir, uii, uor, uoi, wr, wi, acc)

    # du[b,m,r] = Σ_o g[b,o,m]·conj(Uo[o,r])
    d_du = ((1,), (0,))
    dur = dg(gr, uor, d_du) + dg(gi, uoi, d_du)
    dui = dg(gi, uor, d_du) - dg(gr, uoi, d_du)
    # dUo[o,r] = Σ_{b,m} g[b,o,m]·conj(u[b,m,r])   (accumulated over tiles)
    d_bm = ((0, 2), (0, 1))
    duor = dg(gr, ur, d_bm) + dg(gi, ui, d_bm)
    duoi = dg(gi, ur, d_bm) - dg(gr, ui, d_bm)
    # dt = du·conj(W)
    wrT = jnp.transpose(wr, (1, 0)).astype(acc)[None]
    wiT = jnp.transpose(wi, (1, 0)).astype(acc)[None]
    dtr = dur * wrT + dui * wiT
    dti = dui * wrT - dur * wiT
    # dW[r,m] = Σ_b du[b,m,r]·conj(t[b,m,r])   (per-tile block)
    dwr = jnp.sum(dur * tr + dui * ti, axis=0)
    dwi = jnp.sum(dui * tr - dur * ti, axis=0)
    dwr_ref[...] = jnp.transpose(dwr, (1, 0)).astype(dwr_ref.dtype)
    dwi_ref[...] = jnp.transpose(dwi, (1, 0)).astype(dwi_ref.dtype)
    # dx[b,i,m] = Σ_r dt[b,m,r]·conj(Ui[i,r])
    d_dx = ((2,), (1,))
    dxr = dg(dtr, uir, d_dx) + dg(dti, uii, d_dx)
    dxi = dg(dti, uir, d_dx) - dg(dtr, uii, d_dx)
    dxr_ref[...] = jnp.transpose(dxr, (0, 2, 1)).astype(dxr_ref.dtype)
    dxi_ref[...] = jnp.transpose(dxi, (0, 2, 1)).astype(dxi_ref.dtype)
    # dUi[i,r] = Σ_{b,m} conj(x[b,i,m])·dt[b,m,r]   (accumulated over tiles)
    duir = dg(xr, dtr, d_bm) + dg(xi, dti, d_bm)
    duii = dg(xr, dti, d_bm) - dg(xi, dtr, d_bm)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        for ref in (duir_ref, duii_ref, duor_ref, duoi_ref):
            ref[...] = jnp.zeros(ref.shape, ref.dtype)

    duir_ref[...] += duir.astype(duir_ref.dtype)
    duii_ref[...] += duii.astype(duii_ref.dtype)
    duor_ref[...] += duor.astype(duor_ref.dtype)
    duoi_ref[...] += duoi.astype(duoi_ref.dtype)


def _cp_specs(B, I, O, R, block_m):
    x = _x_spec(B, I, block_m)
    ui = pl.BlockSpec((I, R), lambda _m: (0, 0))
    uo = pl.BlockSpec((O, R), lambda _m: (0, 0))
    w = pl.BlockSpec((R, block_m), lambda m: (0, m))
    return x, ui, uo, w


def _cp_fwd_call(config, xr, xi, uir, uii, uor, uoi, wr, wi):
    block_m, _block_m_bwd, interpret, out_dtype = config
    B, I, M = xr.shape
    O, R = uor.shape
    xr, xi, wr, wi = (_pad_modes(a, block_m) for a in (xr, xi, wr, wi))
    Mp = xr.shape[-1]
    x_s, ui_s, uo_s, w_s = _cp_specs(B, I, O, R, block_m)
    out_re, out_im = pl.pallas_call(
        _cp_fwd_kernel,
        grid=(Mp // block_m,),
        in_specs=[x_s, x_s, ui_s, ui_s, uo_s, uo_s, w_s, w_s],
        out_specs=[_x_spec(B, O, block_m)] * 2,
        out_shape=[jax.ShapeDtypeStruct((B, O, Mp), out_dtype)] * 2,
        interpret=interpret,
    )(xr, xi, uir, uii, uor, uoi, wr, wi)
    return out_re[..., :M], out_im[..., :M]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _cp_op(config, xr, xi, uir, uii, uor, uoi, wr, wi):
    return _cp_fwd_call(config, xr, xi, uir, uii, uor, uoi, wr, wi)


def _cp_op_fwd(config, xr, xi, uir, uii, uor, uoi, wr, wi):
    out = _cp_fwd_call(config, xr, xi, uir, uii, uor, uoi, wr, wi)
    return out, (xr, xi, uir, uii, uor, uoi, wr, wi)


def _cp_op_bwd(config, res, cts):
    xr, xi, uir, uii, uor, uoi, wr, wi = res
    gr, gi = cts
    _block_m, block_m, interpret, _ = config
    B, I, M = xr.shape
    O, R = uor.shape
    acc = _acc_dtype(xr.dtype)
    xrp, xip, wrp, wip, grp, gip = (
        _pad_modes(a, block_m) for a in (xr, xi, wr, wi, gr, gi))
    Mp = xrp.shape[-1]
    x_s, ui_s, uo_s, w_s = _cp_specs(B, I, O, R, block_m)
    outs = pl.pallas_call(
        _cp_bwd_kernel,
        grid=(Mp // block_m,),
        in_specs=[x_s, x_s, ui_s, ui_s, uo_s, uo_s, w_s, w_s,
                  _x_spec(B, O, block_m), _x_spec(B, O, block_m)],
        out_specs=[x_s, x_s, ui_s, ui_s, uo_s, uo_s, w_s, w_s],
        out_shape=(
            [jax.ShapeDtypeStruct((B, I, Mp), xr.dtype)] * 2
            # factor grads accumulate across revisited blocks at the
            # accumulator dtype; cast back to the primal dtype below
            + [jax.ShapeDtypeStruct((I, R), acc)] * 2
            + [jax.ShapeDtypeStruct((O, R), acc)] * 2
            + [jax.ShapeDtypeStruct((R, Mp), wr.dtype)] * 2
        ),
        interpret=interpret,
    )(xrp, xip, uir, uii, uor, uoi, wrp, wip, grp, gip)
    dxr, dxi, duir, duii, duor, duoi, dwr, dwi = outs
    return (
        dxr[..., :M], dxi[..., :M],
        duir.astype(uir.dtype), duii.astype(uii.dtype),
        duor.astype(uor.dtype), duoi.astype(uoi.dtype),
        dwr[..., :M], dwi[..., :M],
    )


_cp_op.defvjp(_cp_op_fwd, _cp_op_bwd)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_m_bwd", "interpret", "out_dtype"),
)
def spectral_contract_cp_pallas(
    xr: jnp.ndarray,
    xi: jnp.ndarray,
    uir: jnp.ndarray,
    uii: jnp.ndarray,
    uor: jnp.ndarray,
    uoi: jnp.ndarray,
    wr: jnp.ndarray,
    wi: jnp.ndarray,
    *,
    block_m: int = 64,
    block_m_bwd: int | None = None,
    interpret: bool = True,
    out_dtype=None,
) -> tuple:
    """CP-factorised split-real contraction (differentiable).

    ``out[b,o,m] = Σ_r (Σ_i x[b,i,m]·Ui[i,r]) · W[r,m] · Uo[o,r]``

    Args:
      xr/xi: (B, I, M) spectrum tile;  uir/uii: (I, R) input factor;
      uor/uoi: (O, R) output factor;   wr/wi: (R, M) combined mode factor
      (λ and the per-axis CP factors folded together by the caller).

    Returns (out_re, out_im): (B, O, M) at ``out_dtype`` (default x dtype).
    """
    B, I, M = xr.shape
    I2, R = uir.shape
    O, R2 = uor.shape
    R3, M2 = wr.shape
    if I != I2 or R != R2 or R != R3 or M != M2:
        raise ValueError(
            f"spectral_contract_cp_pallas: inconsistent factor shapes "
            f"x {xr.shape}, Ui {uir.shape}, Uo {uor.shape}, W {wr.shape}"
        )
    out_dtype = jnp.dtype(out_dtype or xr.dtype)
    config = (block_m, block_m_bwd or block_m, interpret, out_dtype)
    return _cp_op(config, xr, xi, uir, uii, uor, uoi, wr, wi)


# ---------------------------------------------------------------------------
# l-shared kernels (SFNO): weight shared over order m, tiled over degree l
# ---------------------------------------------------------------------------
#
#   out[b,o,l,m] = Σ_i x[b,i,l,m] · w[i,o,l]
#
# The spherical convolution theorem shares the weight across orders m, so
# materialising it as a dense (I, O, l, m) operand for the dense kernel
# would stream mmax× the weight bytes (and materialise an mmax× gradient
# before reduction).  These kernels instead tile over *degrees l* and ride
# m along as a free axis; the weight tile stays (I, O, TL).


def _lshared_fwd_kernel(xr_ref, xi_ref, wr_ref, wi_ref, or_ref, oi_ref):
    """Refs: x (B, I, TL, M), w (I, O, TL) -> out (B, O, TL, M)."""
    xr, xi = xr_ref[...], xi_ref[...]
    wr, wi = wr_ref[...], wi_ref[...]
    acc = _acc_dtype(xr.dtype)

    def bmm(a, b):
        # contract I; batch over the degree tile -> (TL, B, M, O)
        return jax.lax.dot_general(
            a, b, (((1,), (0,)), ((2,), (2,))), preferred_element_type=acc)

    our = bmm(xr, wr) - bmm(xi, wi)
    oui = bmm(xr, wi) + bmm(xi, wr)
    or_ref[...] = jnp.transpose(our, (1, 3, 0, 2)).astype(or_ref.dtype)
    oi_ref[...] = jnp.transpose(oui, (1, 3, 0, 2)).astype(oi_ref.dtype)


def _lshared_bwd_x_kernel(gr_ref, gi_ref, wr_ref, wi_ref, dxr_ref, dxi_ref):
    """dx = g · conj(w): g (B, O, TL, M), w (I, O, TL) -> dx (B, I, TL, M)."""
    gr, gi = gr_ref[...], gi_ref[...]
    wr, wi = wr_ref[...], wi_ref[...]
    acc = _acc_dtype(gr.dtype)

    def bmm(a, b):
        # contract O; batch TL -> (TL, B, M, I)
        return jax.lax.dot_general(
            a, b, (((1,), (1,)), ((2,), (2,))), preferred_element_type=acc)

    dxr = bmm(gr, wr) + bmm(gi, wi)
    dxi = bmm(gi, wr) - bmm(gr, wi)
    dxr_ref[...] = jnp.transpose(dxr, (1, 3, 0, 2)).astype(dxr_ref.dtype)
    dxi_ref[...] = jnp.transpose(dxi, (1, 3, 0, 2)).astype(dxi_ref.dtype)


def _lshared_bwd_w_kernel(xr_ref, xi_ref, gr_ref, gi_ref, dwr_ref, dwi_ref):
    """dw = conj(x) · g summed over b AND m: -> dw (I, O, TL).  The m
    reduction happens in-tile, so the (I, O, l, m) intermediate the dense
    path would materialise never exists."""
    xr, xi = xr_ref[...], xi_ref[...]
    gr, gi = gr_ref[...], gi_ref[...]
    acc = _acc_dtype(xr.dtype)

    def bmm(a, b):
        # contract (B, M); batch TL -> (TL, I, O)
        return jax.lax.dot_general(
            a, b, (((0, 3), (0, 3)), ((2,), (2,))),
            preferred_element_type=acc)

    dwr = bmm(xr, gr) + bmm(xi, gi)
    dwi = bmm(xr, gi) - bmm(xi, gr)
    dwr_ref[...] = jnp.transpose(dwr, (1, 2, 0)).astype(dwr_ref.dtype)
    dwi_ref[...] = jnp.transpose(dwi, (1, 2, 0)).astype(dwi_ref.dtype)


def _pad_l(a: jnp.ndarray, block_l: int, axis: int) -> jnp.ndarray:
    pad = (-a.shape[axis]) % block_l
    if not pad:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def _lshared_specs(B, I, O, Mm, block_l):
    x = pl.BlockSpec((B, I, block_l, Mm), lambda l: (0, 0, l, 0))
    g = pl.BlockSpec((B, O, block_l, Mm), lambda l: (0, 0, l, 0))
    w = pl.BlockSpec((I, O, block_l), lambda l: (0, 0, l))
    return x, g, w


def _lshared_fwd_call(config, xr, xi, wr, wi):
    block_l, _block_l_bwd, interpret, out_dtype = config
    B, I, L, Mm = xr.shape
    _, O, _ = wr.shape
    xr, xi = _pad_l(xr, block_l, 2), _pad_l(xi, block_l, 2)
    wr, wi = _pad_l(wr, block_l, 2), _pad_l(wi, block_l, 2)
    Lp = xr.shape[2]
    x_s, g_s, w_s = _lshared_specs(B, I, O, Mm, block_l)
    out_re, out_im = pl.pallas_call(
        _lshared_fwd_kernel,
        grid=(Lp // block_l,),
        in_specs=[x_s, x_s, w_s, w_s],
        out_specs=[g_s, g_s],
        out_shape=[jax.ShapeDtypeStruct((B, O, Lp, Mm), out_dtype)] * 2,
        interpret=interpret,
    )(xr, xi, wr, wi)
    return out_re[:, :, :L], out_im[:, :, :L]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _lshared_op(config, xr, xi, wr, wi):
    return _lshared_fwd_call(config, xr, xi, wr, wi)


def _lshared_op_fwd(config, xr, xi, wr, wi):
    return _lshared_fwd_call(config, xr, xi, wr, wi), (xr, xi, wr, wi)


def _lshared_op_bwd(config, res, cts):
    xr, xi, wr, wi = res
    gr, gi = cts
    _block_l, block_l, interpret, _ = config
    B, I, L, Mm = xr.shape
    _, O, _ = wr.shape
    xrp, xip = _pad_l(xr, block_l, 2), _pad_l(xi, block_l, 2)
    wrp, wip = _pad_l(wr, block_l, 2), _pad_l(wi, block_l, 2)
    grp, gip = _pad_l(gr, block_l, 2), _pad_l(gi, block_l, 2)
    Lp = xrp.shape[2]
    grid = (Lp // block_l,)
    x_s, g_s, w_s = _lshared_specs(B, I, O, Mm, block_l)
    dxr, dxi = pl.pallas_call(
        _lshared_bwd_x_kernel,
        grid=grid,
        in_specs=[g_s, g_s, w_s, w_s],
        out_specs=[x_s, x_s],
        out_shape=[jax.ShapeDtypeStruct((B, I, Lp, Mm), xr.dtype)] * 2,
        interpret=interpret,
    )(grp, gip, wrp, wip)
    dwr, dwi = pl.pallas_call(
        _lshared_bwd_w_kernel,
        grid=grid,
        in_specs=[x_s, x_s, g_s, g_s],
        out_specs=[w_s, w_s],
        out_shape=[jax.ShapeDtypeStruct((I, O, Lp), wr.dtype)] * 2,
        interpret=interpret,
    )(xrp, xip, grp, gip)
    return (dxr[:, :, :L], dxi[:, :, :L], dwr[:, :, :L], dwi[:, :, :L])


_lshared_op.defvjp(_lshared_op_fwd, _lshared_op_bwd)


@functools.partial(
    jax.jit,
    static_argnames=("block_l", "block_l_bwd", "interpret", "out_dtype"),
)
def spectral_contract_lshared_pallas(
    xr: jnp.ndarray,
    xi: jnp.ndarray,
    wr: jnp.ndarray,
    wi: jnp.ndarray,
    *,
    block_l: int = 8,
    block_l_bwd: int | None = None,
    interpret: bool = True,
    out_dtype=None,
) -> tuple:
    """Split-real ``bilm,iol->bolm`` with the weight shared over m
    (differentiable; the SFNO spherical contraction).

    xr/xi: (B, I, L, M) spectrum; wr/wi: (I, O, L) per-degree weights.
    Returns (out_re, out_im): (B, O, L, M) at ``out_dtype``.
    """
    B, I, L, Mm = xr.shape
    I2, O, L2 = wr.shape
    if I != I2 or L != L2:
        raise ValueError(
            f"spectral_contract_lshared_pallas: x {xr.shape} vs w "
            f"{wr.shape} — expected (B, I, L, M) and (I, O, L)"
        )
    out_dtype = jnp.dtype(out_dtype or xr.dtype)
    config = (block_l, block_l_bwd or block_l, interpret, out_dtype)
    return _lshared_op(config, xr, xi, wr, wi)


# ---------------------------------------------------------------------------
# Fused spectral megakernel: rFFT -> contract -> irFFT in one Pallas grid
# ---------------------------------------------------------------------------
#
# The staged pipeline round-trips HBM three times per layer: rfftn writes
# the full spectrum, the boundary quantise writes the half copy, the
# contraction writes the truncated output which the scatter + irfftn read
# back.  This family runs the whole pipeline per *batch tile* with the
# spectral activations resident in VMEM throughout:
#
#   1. truncated DFT as matmuls: per axis k the factor F_k holds only the
#      retained mode rows — the low [0, m_k) and high [S_k-m_k, S_k)
#      frequency blocks for every axis but the last, the rfft rows
#      [0, m_d) for the last — so truncation and the 2^(d-1) corner
#      gather cost nothing: they are rows that simply do not exist.
#   2. the boundary quantise (Thm 3.2's representation error) applies to
#      the VMEM-resident spectrum: the half grid via the same ``astype``
#      rounding as ``_cast_tiles`` / the simulated fp8 grid via
#      ``simulate_fp8`` — bit-identical values to the staged boundary,
#      zero HBM-visible casts.
#   3. the mode contraction reuses the dense 4-real-matmul schedule
#      (rr−ii / ri+ir, f32 accumulation) against the corner-gathered
#      weight (I, O, Mh).
#   4. the inverse transform applies per-axis iDFT factors; the last axis
#      folds the hermitian weights (1 for DC/Nyquist, 2 elsewhere) into a
#      real-output cos/sin pair, exactly ``irfftn`` of the zero-scattered
#      spectrum.
#
# The custom VJP runs the transposed pipeline in one backward kernel:
# cotangent -> adjoint iDFT -> (dx via conj(w), dw via conj(xh)) ->
# adjoint DFT -> real part.  dw is mode-independent of the batch grid, so
# its output block revisits every grid step and accumulates in place
# (init-or-accumulate discipline, as the CP backward does).


def _fused_rows(spatial, modes):
    """Retained spectrum rows per axis: 2m for truncated full-FFT axes
    (low+high corner blocks), m for the last (rfft) axis."""
    return tuple(2 * m for m in modes[:-1]) + (modes[-1],)


def fused_supported(spatial, modes) -> bool:
    """Whether the truncated-DFT factorisation is exact for this shape:
    corner blocks must not overlap (2m_k <= S_k) and the last axis must
    retain no more than the rfft spectrum holds."""
    if len(spatial) != len(modes) or not modes:
        return False
    if any(2 * m > s for m, s in zip(modes[:-1], spatial[:-1])):
        return False
    return modes[-1] <= spatial[-1] // 2 + 1


def fused_factors(spatial, modes):
    """Precomputed DFT / inverse-DFT factor matrices (numpy, float64).

    Returns a flat tuple: per axis k the forward pair (re, im) of
    ``F_k[mu, t] = exp(-2*pi*i*f_mu*t/S_k)`` over the retained rows, then
    per axis the inverse pair — ``G_k[mu, t] = exp(+2*pi*i*f_mu*t/S_k)/S_k``
    for full-FFT axes and, for the last axis, the real-output pair
    ``C_re[mu, t] = w_mu*cos(2*pi*mu*t/S_d)/S_d``,
    ``C_im[mu, t] = -w_mu*sin(...)/S_d`` with hermitian weights w
    (1 at DC and Nyquist, 2 elsewhere) — so ``y = yh_re@C_re + yh_im@C_im``
    is exactly ``irfftn`` of the zero-scattered truncated spectrum."""
    import numpy as np

    ndim = len(modes)
    fwd, inv = [], []
    for k in range(ndim):
        S, m = int(spatial[k]), int(modes[k])
        last = k == ndim - 1
        if last:
            freqs = np.arange(m)
        else:
            freqs = np.concatenate([np.arange(m), np.arange(S - m, S)])
        ang = 2.0 * np.pi * np.outer(freqs, np.arange(S)) / S
        fwd.append((np.cos(ang), -np.sin(ang)))
        if not last:
            inv.append((np.cos(ang) / S, np.sin(ang) / S))
        else:
            w = np.full(m, 2.0)
            w[0] = 1.0
            if S % 2 == 0 and m - 1 == S // 2:
                w[m - 1] = 1.0  # Nyquist row is its own conjugate
            inv.append((w[:, None] * np.cos(ang) / S,
                        -w[:, None] * np.sin(ang) / S))
    return tuple(x for pair in fwd + inv for x in pair)


def _cplx_apply(ar, ai, fr, fi, axis, f_axis, conj=False):
    """Apply one (split-real) complex factor matrix along ``axis``:
    contract that axis of (ar, ai) with axis ``f_axis`` of the factor and
    put the factor's other axis back in its place.  ``ai=None`` encodes a
    real operand (the pipeline entry).  ``conj`` multiplies by the
    conjugated factor — the adjoint the backward pipeline applies."""

    def td(a, f):
        return jnp.tensordot(a, f, axes=[[axis], [f_axis]])

    if ai is None:
        br, bi = td(ar, fr), td(ar, fi)
        if conj:
            bi = -bi
    elif conj:
        br = td(ar, fr) + td(ai, fi)
        bi = td(ai, fr) - td(ar, fi)
    else:
        br = td(ar, fr) - td(ai, fi)
        bi = td(ar, fi) + td(ai, fr)
    return jnp.moveaxis(br, -1, axis), jnp.moveaxis(bi, -1, axis)


def _fused_quantize(xhr, xhi, cast_to, sim_fmt, acc):
    """The fft_in boundary quantisation on the VMEM-resident spectrum:
    the simulated fp8 grid (Appendix B.11) and/or the half storage grid —
    value-identical to the staged ``fft_in.quantize`` + operand cast."""
    if sim_fmt is not None:
        from repro.core.precision import simulate_fp8

        xhr = simulate_fp8(xhr.astype(jnp.float32), sim_fmt).astype(acc)
        xhi = simulate_fp8(xhi.astype(jnp.float32), sim_fmt).astype(acc)
    return _cast_tiles(cast_to, xhr, xhi)


def _fused_spectrum(x_ref, fwd, cast_to, sim_fmt):
    """x tile -> quantised, mode-flattened split-real spectrum."""
    x = x_ref[...]
    acc = _acc_dtype(x.dtype)
    ar, ai = x.astype(acc), None
    for k, (fr, fi) in enumerate(fwd):
        ar, ai = _cplx_apply(ar, ai, fr, fi, 2 + k, 1)
    lead = ar.shape[:2]
    xhr = ar.reshape(*lead, -1)
    xhi = ai.reshape(*lead, -1)
    xhr, xhi = _fused_quantize(xhr, xhi, cast_to, sim_fmt, acc)
    return xhr, xhi, ar.shape[2:], acc


def _split_factor_refs(fac_refs, ndim):
    vals = [f[...] for f in fac_refs]
    fwd = [(vals[2 * k], vals[2 * k + 1]) for k in range(ndim)]
    inv = [(vals[2 * ndim + 2 * k], vals[2 * ndim + 2 * k + 1])
           for k in range(ndim)]
    return fwd, inv


def _fused_fwd_kernel(*refs, ndim, cast_to=None, sim_fmt=None):
    """One batch-tile step of the fused pipeline.

    Refs: x (BB, I, *spatial) f32, wg re/im (I, O, Mh) f32, then the
    2*ndim forward + 2*ndim inverse factor matrices -> y (BB, O, *spatial).
    ``Mh`` is the flattened retained-row count (2^(ndim-1) * prod(modes)).
    """
    x_ref, wr_ref, wi_ref = refs[:3]
    fwd, inv = _split_factor_refs(refs[3:3 + 4 * ndim], ndim)
    y_ref = refs[-1]

    xhr, xhi, mode_shape, acc = _fused_spectrum(x_ref, fwd, cast_to, sim_fmt)
    wr, wi = _cast_tiles(cast_to, wr_ref[...], wi_ref[...])

    def bmm(a, b):
        # contract I; batch over flattened modes -> (Mh, BB, O)
        return jax.lax.dot_general(
            a, b, (((1,), (0,)), ((2,), (2,))), preferred_element_type=acc)

    yhr = jnp.transpose(bmm(xhr, wr) - bmm(xhi, wi), (1, 2, 0)).astype(acc)
    yhi = jnp.transpose(bmm(xhr, wi) + bmm(xhi, wr), (1, 2, 0)).astype(acc)
    BB, O = yhr.shape[:2]
    br = yhr.reshape(BB, O, *mode_shape)
    bi = yhi.reshape(BB, O, *mode_shape)
    for k in range(ndim - 1):
        br, bi = _cplx_apply(br, bi, *inv[k], 2 + k, 0)
    cr, ci = inv[ndim - 1]
    ax = 2 + ndim - 1
    # real-output last axis: y = yh_re@C_re + yh_im@C_im (hermitian fold)
    y = (jnp.tensordot(br, cr, axes=[[ax], [0]])
         + jnp.tensordot(bi, ci, axes=[[ax], [0]]))
    y_ref[...] = jnp.moveaxis(y, -1, ax).astype(y_ref.dtype)


def _fused_bwd_kernel(*refs, ndim, cast_to=None, sim_fmt=None):
    """Transposed pipeline for one batch tile: cotangent -> adjoint iDFT
    -> contraction VJPs -> adjoint DFT -> real part.

    Refs: x, wg re/im, the 4*ndim factors, g (BB, O, *spatial) ->
    dx (BB, I, *spatial), dwg re/im (I, O, Mh).  The dw blocks revisit
    across the batch grid: zero-init on the first step, then accumulate.
    """
    x_ref, wr_ref, wi_ref = refs[:3]
    fwd, inv = _split_factor_refs(refs[3:3 + 4 * ndim], ndim)
    g_ref = refs[3 + 4 * ndim]
    dx_ref, dwr_ref, dwi_ref = refs[-3:]

    # recompute the quantised spectrum in-tile (cheaper than saving the
    # VMEM-resident intermediate to HBM, which would defeat the fusion)
    xhr, xhi, mode_shape, acc = _fused_spectrum(x_ref, fwd, cast_to, sim_fmt)
    wr, wi = _cast_tiles(cast_to, wr_ref[...], wi_ref[...])

    # adjoint of the inverse transform: gh = dL/dyh
    g = g_ref[...].astype(acc)
    cr, ci = inv[ndim - 1]
    ax = 2 + ndim - 1
    ghr = jnp.moveaxis(jnp.tensordot(g, cr, axes=[[ax], [1]]), -1, ax)
    ghi = jnp.moveaxis(jnp.tensordot(g, ci, axes=[[ax], [1]]), -1, ax)
    for k in reversed(range(ndim - 1)):
        ghr, ghi = _cplx_apply(ghr, ghi, *inv[k], 2 + k, 1, conj=True)
    BB = ghr.shape[0]
    ghr = ghr.reshape(BB, ghr.shape[1], -1)
    ghi = ghi.reshape(BB, ghi.shape[1], -1)
    # same storage grid as the forward tiles (the dense backward rounds
    # its g tiles identically) — and the matmul operand dtypes must agree
    ghr, ghi = _cast_tiles(cast_to, ghr, ghi)

    def bmm(a, b, dims):
        return jax.lax.dot_general(
            a, b, (dims, ((2,), (2,))), preferred_element_type=acc)

    # dxh = gh . conj(wg): contract O, batch modes -> (Mh, BB, I)
    d_x = ((1,), (1,))
    dxhr = jnp.transpose(bmm(ghr, wr, d_x) + bmm(ghi, wi, d_x), (1, 2, 0))
    dxhi = jnp.transpose(bmm(ghi, wr, d_x) - bmm(ghr, wi, d_x), (1, 2, 0))
    # dwg = conj(xh) . gh: contract BB, batch modes -> (Mh, I, O);
    # batch-independent, so accumulate across the grid
    d_w = ((0,), (0,))
    dwr = jnp.transpose(bmm(xhr, ghr, d_w) + bmm(xhi, ghi, d_w), (1, 2, 0))
    dwi = jnp.transpose(bmm(xhr, ghi, d_w) - bmm(xhi, ghr, d_w), (1, 2, 0))

    @pl.when(pl.program_id(0) == 0)
    def _init():
        dwr_ref[...] = jnp.zeros(dwr_ref.shape, dwr_ref.dtype)
        dwi_ref[...] = jnp.zeros(dwi_ref.shape, dwi_ref.dtype)

    dwr_ref[...] += dwr.astype(dwr_ref.dtype)
    dwi_ref[...] += dwi.astype(dwi_ref.dtype)

    # adjoint of the forward DFT, then project to the real input space
    dar = dxhr.reshape(BB, dxhr.shape[1], *mode_shape).astype(acc)
    dai = dxhi.reshape(BB, dxhi.shape[1], *mode_shape).astype(acc)
    for k in reversed(range(ndim)):
        dar, dai = _cplx_apply(dar, dai, *fwd[k], 2 + k, 0, conj=True)
    dx_ref[...] = dar.astype(dx_ref.dtype)


def _pad_batch(a: jnp.ndarray, block_b: int) -> jnp.ndarray:
    pad = (-a.shape[0]) % block_b
    if not pad:
        return a
    return jnp.pad(a, [(0, pad)] + [(0, 0)] * (a.ndim - 1))


def _fused_specs(B_block, I, O, Mh, spatial, factors):
    ndim = len(spatial)
    zeros = (0,) * ndim

    def batch_spec(ch):
        return pl.BlockSpec((B_block, ch, *spatial),
                            lambda b: (b, 0, *zeros))

    w_spec = pl.BlockSpec((I, O, Mh), lambda b: (0, 0, 0))
    f_specs = [pl.BlockSpec(f.shape, lambda b: (0, 0)) for f in factors]
    return batch_spec(I), batch_spec(O), w_spec, f_specs


def _fused_fwd_call(config, x, wgr, wgi):
    modes, block_b, _bb_bwd, interpret, out_dtype, cast_to, sim_fmt = config
    B, I = x.shape[:2]
    spatial = x.shape[2:]
    O, Mh = wgr.shape[1], wgr.shape[2]
    acc = _acc_dtype(x.dtype)
    factors = tuple(jnp.asarray(f, acc)
                    for f in fused_factors(spatial, modes))
    xp = _pad_batch(x, block_b)
    Bp = xp.shape[0]
    x_s, y_s, w_s, f_s = _fused_specs(block_b, I, O, Mh, spatial, factors)
    y = pl.pallas_call(
        functools.partial(_fused_fwd_kernel, ndim=len(modes),
                          cast_to=cast_to, sim_fmt=sim_fmt),
        grid=(Bp // block_b,),
        in_specs=[x_s, w_s, w_s, *f_s],
        out_specs=y_s,
        out_shape=jax.ShapeDtypeStruct((Bp, O, *spatial), out_dtype),
        interpret=interpret,
    )(xp, wgr, wgi, *factors)
    return y[:B]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _fused_op(config, x, wgr, wgi):
    return _fused_fwd_call(config, x, wgr, wgi)


def _fused_op_fwd(config, x, wgr, wgi):
    return _fused_fwd_call(config, x, wgr, wgi), (x, wgr, wgi)


def _fused_op_bwd(config, res, g):
    x, wgr, wgi = res
    modes, _bb, block_b, interpret, _out, cast_to, sim_fmt = config
    B, I = x.shape[:2]
    spatial = x.shape[2:]
    O, Mh = wgr.shape[1], wgr.shape[2]
    acc = _acc_dtype(x.dtype)
    factors = tuple(jnp.asarray(f, acc)
                    for f in fused_factors(spatial, modes))
    xp = _pad_batch(x, block_b)
    gp = _pad_batch(g.astype(acc), block_b)
    Bp = xp.shape[0]
    x_s, g_s, w_s, f_s = _fused_specs(block_b, I, O, Mh, spatial, factors)
    dx, dwr, dwi = pl.pallas_call(
        functools.partial(_fused_bwd_kernel, ndim=len(modes),
                          cast_to=cast_to, sim_fmt=sim_fmt),
        grid=(Bp // block_b,),
        in_specs=[x_s, w_s, w_s, *f_s, g_s],
        out_specs=[x_s, w_s, w_s],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, I, *spatial), x.dtype),
            # dw accumulates across revisited blocks at the accumulator
            # dtype; cast back to the primal dtype below
            jax.ShapeDtypeStruct((I, O, Mh), acc),
            jax.ShapeDtypeStruct((I, O, Mh), acc),
        ],
        interpret=interpret,
    )(xp, wgr, wgi, *factors, gp)
    return dx[:B], dwr.astype(wgr.dtype), dwi.astype(wgi.dtype)


_fused_op.defvjp(_fused_op_fwd, _fused_op_bwd)


@functools.partial(
    jax.jit,
    static_argnames=("modes", "block_b", "block_b_bwd", "interpret",
                     "out_dtype", "cast_to", "sim_fmt"),
)
def spectral_fused_pallas(
    x: jnp.ndarray,
    wgr: jnp.ndarray,
    wgi: jnp.ndarray,
    *,
    modes: tuple,
    block_b: int = 1,
    block_b_bwd: int | None = None,
    interpret: bool = True,
    out_dtype=None,
    cast_to=None,
    sim_fmt: str | None = None,
) -> jnp.ndarray:
    """Fused rFFT -> quantise -> contract -> irFFT (differentiable).

    Args:
      x: (B, I, *spatial) real f32 physical-space input (post-stabiliser).
      wgr/wgi: (I, O, Mh) corner-gathered split-real spectral weights,
        flattened row-major over the retained rows per axis (2m for every
        truncated full-FFT axis — low block then high block — and m for
        the last, rfft, axis); ``kernels.ops.gather_corner_weights``
        builds this layout from the per-corner (nc, I, O, *modes) params.
      modes: retained modes per axis (static).
      block_b: batch-tile size — the grid walks ceil(B/block_b) steps
        with the whole spectral pipeline VMEM-resident per step.
      cast_to: half storage grid applied to the spectrum AND the weight
        tiles in VMEM (the staged ``fft_in.quantize`` + operand cast).
      sim_fmt: simulated-fp8 grid ("fp8_e4m3" / "fp8_e5m2") applied to
        the spectrum only, before ``cast_to`` (Appendix B.11 boundary).

    Returns y: (B, O, *spatial) real, at ``out_dtype`` (default x dtype).
    Reverse-mode differentiation runs the transposed pipeline in one
    backward Pallas kernel on the ``block_b_bwd`` batch tiling.
    """
    if x.ndim != 2 + len(modes):
        raise ValueError(
            f"spectral_fused_pallas: x {x.shape} vs modes {modes} — "
            f"expected (B, I, *spatial) with one spatial axis per mode")
    spatial = x.shape[2:]
    if not fused_supported(spatial, modes):
        raise ValueError(
            f"spectral_fused_pallas: modes {modes} do not fit spatial "
            f"{spatial} (need 2m <= S per truncated axis and "
            f"m <= S//2+1 on the rfft axis)")
    rows = _fused_rows(spatial, modes)
    Mh = 1
    for r in rows:
        Mh *= r
    if wgr.shape[-1] != Mh or wgr.shape != wgi.shape or wgr.ndim != 3:
        raise ValueError(
            f"spectral_fused_pallas: weight {wgr.shape} — expected "
            f"(I, O, {Mh}) corner-gathered rows for modes {modes}")
    out_dtype = jnp.dtype(out_dtype or x.dtype)
    cast_to = jnp.dtype(cast_to) if cast_to is not None else None
    config = (tuple(int(m) for m in modes), int(block_b),
              int(block_b_bwd or block_b), interpret, out_dtype, cast_to,
              sim_fmt)
    return _fused_op(config, x, wgr, wgi)


# ---------------------------------------------------------------------------
# VMEM budgeting
# ---------------------------------------------------------------------------


def vmem_bytes(B: int, I: int, O: int, block_m: int, itemsize: int = 2) -> int:
    """Forward VMEM working set per grid step — used to pick block_m so
    the tile fits comfortably under the ~16 MiB v5e VMEM budget."""
    halves = (B * I + I * O + B * O) * block_m * 2  # re+im
    accum = B * O * block_m * 4  # f32 accumulators
    return halves * itemsize + accum


def vmem_bytes_bwd(B: int, I: int, O: int, block_m: int,
                   itemsize: int = 2) -> int:
    """Backward VMEM working set per grid step: the larger of the dx
    kernel (g, w tiles + f32 dx accumulators) and the dw kernel (x, g
    tiles + f32 dw accumulators)."""
    bwd_x = (B * O + I * O + B * I) * block_m * 2 * itemsize \
        + B * I * block_m * 4
    bwd_w = (B * I + B * O + I * O) * block_m * 2 * itemsize \
        + I * O * block_m * 4
    return max(bwd_x, bwd_w)


def cp_vmem_bytes(B: int, I: int, O: int, R: int, block_m: int,
                  itemsize: int = 2) -> int:
    """CP kernel VMEM working set per grid step (backward dominates: it
    holds x, g, W tiles, both rank factors, the recomputed t/u and the
    f32 gradient accumulators)."""
    tiles = (B * I + B * O + R) * block_m * 2 * itemsize   # x, g, W
    factors = (I * R + O * R) * 2 * itemsize               # Ui, Uo
    rankspace = 4 * B * R * block_m * 2 * 4                # t, u, du, dt (f32)
    grads = (I * R + O * R + R * block_m + B * I * block_m) * 2 * 4
    return tiles + factors + rankspace + grads


def lshared_vmem_bytes(B: int, I: int, O: int, Mm: int, block_l: int,
                       itemsize: int = 2) -> int:
    """l-shared (SFNO) kernel VMEM working set per grid step (the bwd-dx
    step, which holds g, w tiles and the f32 dx accumulator, dominates)."""
    tiles = ((B * I + B * O) * Mm + I * O) * block_l * 2 * itemsize
    accum = max(B * I, B * O) * block_l * Mm * 4
    return tiles + accum


def _fused_tile_elems(block_b: int, I: int, O: int, spatial, modes):
    """(x tile, w tile, y tile, factor, worst transform intermediate)
    element counts for one fused grid step."""
    rows = _fused_rows(spatial, modes)
    S = Mh = 1
    for s in spatial:
        S *= int(s)
    for r in rows:
        Mh *= int(r)
    fac = 4 * sum(int(r) * int(s) for r, s in zip(rows, spatial))
    # per-axis transform intermediates: spatial axes collapse to mode
    # rows one at a time, so the worst step holds the largest mixed shape
    # (split re+im) for the wider of the channel counts
    inter, cur = 0, 1
    tail = S
    for k in range(len(modes) + 1):
        inter = max(inter, cur * tail)
        if k < len(modes):
            cur *= int(rows[k])
            tail //= int(spatial[k])
    inter *= 2 * block_b * max(I, O)
    return block_b * I * S, 2 * I * O * Mh, block_b * O * S, fac, inter


def fused_vmem_bytes(block_b: int, I: int, O: int, spatial, modes,
                     itemsize: int = 4) -> int:
    """Forward VMEM working set of one fused grid step: the x / weight /
    output tiles plus the DFT factors and the worst per-axis transform
    intermediate (split-real, accumulator dtype)."""
    x_t, w_t, y_t, fac, inter = _fused_tile_elems(block_b, I, O,
                                                  spatial, modes)
    return (x_t + w_t + y_t) * itemsize + fac * 4 + inter * 4


def fused_vmem_bytes_bwd(block_b: int, I: int, O: int, spatial, modes,
                         itemsize: int = 4) -> int:
    """Backward working set: the forward tiles plus the cotangent tile,
    the dx tile and the two f32 dw accumulator blocks (the transposed
    pipeline recomputes the spectrum in-tile, so both transform
    intermediates are live)."""
    x_t, w_t, y_t, fac, inter = _fused_tile_elems(block_b, I, O,
                                                  spatial, modes)
    tiles = (x_t + w_t + 2 * y_t + x_t) * itemsize
    return tiles + w_t * 4 + fac * 4 + 2 * inter * 4


def pick_block_b(B: int, I: int, O: int, spatial, modes, *,
                 itemsize: int = 4, budget: int = VMEM_BUDGET // 2,
                 train: bool = True) -> int:
    """Largest power-of-two batch tile whose fused working set fits in
    ``budget`` bytes of VMEM (1 is the heuristic's last resort — callers
    deciding fused-vs-staged should check ``fused_vmem_bytes(1, ...)``
    themselves)."""
    for bb in (8, 4, 2, 1):
        if bb > max(B, 1):
            continue
        need = fused_vmem_bytes(I=I, O=O, spatial=spatial, modes=modes,
                                block_b=bb, itemsize=itemsize)
        if train:
            need = max(need, fused_vmem_bytes_bwd(
                bb, I, O, spatial, modes, itemsize))
        if need <= budget:
            return bb
    return 1


def pick_block_l(B: int, I: int, O: int, L: int, Mm: int, *,
                 itemsize: int = 2, budget: int = VMEM_BUDGET // 2) -> int:
    """Largest power-of-two degree tile fitting the l-shared kernel's
    working set under ``budget`` bytes of VMEM."""
    for bl in (256, 128, 64, 32, 16, 8, 4, 2):
        if bl > max(L, 2):
            continue
        if lshared_vmem_bytes(B, I, O, Mm, bl, itemsize) <= budget:
            return bl
    return 1


def pick_block_m(B: int, I: int, O: int, M: int, *, rank: int = 0,
                 itemsize: int = 2, budget: int = VMEM_BUDGET // 2,
                 train: bool = True) -> int:
    """Largest power-of-two mode tile whose fwd (and, for ``train``, bwd)
    working set fits in ``budget`` bytes of VMEM.  ``rank > 0`` budgets
    the CP kernel instead of the dense one."""
    for bm in (512, 256, 128, 64, 32, 16, 8):
        if bm > max(M, 8):
            continue
        if rank:
            need = cp_vmem_bytes(B, I, O, rank, bm, itemsize)
        else:
            need = vmem_bytes(B, I, O, bm, itemsize)
            if train:
                need = max(need, vmem_bytes_bwd(B, I, O, bm, itemsize))
        if need <= budget:
            return bm
    return 8
