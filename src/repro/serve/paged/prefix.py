"""Prefix index: a trie over full prompt blocks mapping shared prefixes
(and, for operator fields, content hashes) to live KV blocks.

Keys are exact *chain keys*: block j of a prompt is addressed by
``(key_{j-1}, tuple(tokens[j*bs:(j+1)*bs]))`` with a root sentinel at
j = -1.  Chain keys compare by value (no hashing collisions — dict
equality does the exact comparison), so a hit guarantees the cached
block was written by the byte-identical token prefix at the same
positions.

The index is itself a refcount holder: registering a block ``fork``s it
so the donor request finishing does not free it, and evicting an entry
``release``s it.  Eviction is LRU over *leaf* entries only — an interior
entry's children would become unreachable garbage if their parent left
the trie first.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .pool import BlockPool

_ROOT = ("<root>",)


def content_key(x) -> str:
    """Content hash of an operator input field: exact bytes of the
    f32-normalised array plus its shape.  Two fields with equal keys are
    bitwise-identical inputs, so memoised outputs are bitwise-valid."""
    a = np.ascontiguousarray(np.asarray(x, np.float32))
    h = hashlib.sha1(a.tobytes())
    h.update(str(a.shape).encode())
    return h.hexdigest()


@dataclasses.dataclass
class _Entry:
    block: int
    parent: Optional[Tuple]      # parent chain key (None for depth-0)
    children: int = 0
    last_used: int = 0


class PrefixIndex:
    """Trie over full prompt blocks -> physical KV block ids."""

    def __init__(self, pool: BlockPool):
        self.pool = pool
        self._entries: Dict[Tuple, _Entry] = {}
        self.hits = 0            # lookups that matched >= 1 block
        self.misses = 0
        self.tokens_reused = 0   # prefill tokens skipped via hits
        self.evictions = 0

    @staticmethod
    def _chain(tokens: Sequence[int], block_size: int) -> List[Tuple]:
        """Chain keys for every *full* block of ``tokens``."""
        keys: List[Tuple] = []
        parent: Tuple = _ROOT
        for j in range(len(tokens) // block_size):
            key = (parent, tuple(tokens[j * block_size:(j + 1) * block_size]))
            keys.append(key)
            parent = key
        return keys

    # -- lookup / register ---------------------------------------------------
    def lookup(self, tokens: Sequence[int], block_size: int,
               max_blocks: int, now: int) -> List[int]:
        """Longest cached prefix of ``tokens``: block ids, each ``fork``ed
        for the caller (the caller owns one ref per returned block).
        ``max_blocks`` caps the walk — the engine passes ``(P-1)//bs`` so
        a fully-cached prompt still leaves one token to produce the first
        generation logits."""
        out: List[int] = []
        for key in self._chain(tokens, block_size)[:max_blocks]:
            e = self._entries.get(key)
            if e is None:
                break
            e.last_used = now
            out.append(self.pool.fork(e.block))
        if out:
            self.hits += 1
            self.tokens_reused += len(out) * block_size
        else:
            self.misses += 1
        return out

    def register(self, tokens: Sequence[int], block_ids: Sequence[int],
                 block_size: int, now: int) -> int:
        """Register the full prompt blocks of ``tokens`` (whose physical
        blocks are ``block_ids[j]``) for reuse.  Blocks already indexed
        under the same chain key keep the incumbent (first writer wins —
        both hold bit-identical data).  Returns the number of newly
        indexed blocks, each ``fork``ed so the index owns one ref."""
        added = 0
        for j, key in enumerate(self._chain(tokens, block_size)):
            e = self._entries.get(key)
            if e is not None:
                e.last_used = now
                continue
            self._entries[key] = _Entry(
                block=self.pool.fork(block_ids[j]), parent=key[0]
                if key[0] is not _ROOT else None, last_used=now)
            if key[0] is not _ROOT:
                parent = self._entries.get(key[0])
                if parent is not None:
                    parent.children += 1
            added += 1
        return added

    # -- eviction ------------------------------------------------------------
    def evict_one(self) -> bool:
        """Release the least-recently-used *leaf* entry's block back to
        the pool (its owners elsewhere keep it alive).  False when the
        index is empty."""
        leaf_key, leaf = None, None
        for key, e in self._entries.items():
            if e.children == 0 and (leaf is None
                                    or e.last_used < leaf.last_used):
                leaf_key, leaf = key, e
        if leaf_key is None:
            return False
        del self._entries[leaf_key]
        if leaf.parent is not None:
            parent = self._entries.get(leaf.parent)
            if parent is not None:
                parent.children -= 1
        self.pool.release(leaf.block)
        self.evictions += 1
        return True

    def evict_until(self, pool_free: int) -> int:
        """Evict LRU leaves until the pool has ``pool_free`` free blocks
        (or the index empties).  Returns blocks actually freed."""
        freed = 0
        while self.pool.free_blocks < pool_free:
            before = self.pool.free_blocks
            if not self.evict_one():
                break
            freed += self.pool.free_blocks - before
        return freed

    # -- introspection -------------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    def stats(self) -> Dict[str, int]:
        total = self.hits + self.misses
        return {
            "entries": len(self._entries),
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": round(self.hits / total, 4) if total else 0.0,
            "tokens_reused": self.tokens_reused,
            "evictions": self.evictions,
        }
