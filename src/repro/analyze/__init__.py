"""repro.analyze — static numerics & precision linter.

Three passes over the repo's *traced* programs and *declared* tables —
nothing executes:

  dataflow  jaxpr walk of model forwards and Trainer steps per registry
            policy (half accumulation, fp16 overflow reachability,
            round-trip casts, fp32 residues on demoted sites);
  sites     AST scan of site literals + rule-table cross-checks
            (orphans, dead patterns, shadowed entries);
  kernels   BlockSpec/grid/VMEM checks over the Pallas kernel families;
  obs       AST scan for hand-rolled counters in instrumented subtrees
            that never reference repro.obs (invisible to the registry
            snapshot / Prometheus scrape).

``python -m repro.analyze`` runs everything, writes
``benchmarks/results/analyze.json`` and exits nonzero on unsuppressed
error-severity findings; ``analyze.toml`` holds the reviewed allowlist.
"""
from .findings import (  # noqa: F401
    ERROR,
    WARNING,
    Finding,
    Suppression,
    dedupe,
    load_suppressions,
    partition,
    summarize,
)
from .dataflow import (  # noqa: F401
    analyze_closed_jaxpr,
    dtype_trace,
    model_findings,
    trace_findings,
    trainer_findings,
)
from .sites import (  # noqa: F401
    rule_table_findings,
    shadowed_entries,
    site_universe,
    sites_pass,
)
from .kernels import kernels_pass, record_pallas_calls  # noqa: F401
from .obscov import obs_coverage_pass  # noqa: F401
