"""Precision policies for mixed-precision neural operators.

Implements the paper's precision model:

* An ``(a0, eps, T)``-precision system ``q`` (Section 3) — a simplified
  floating-point quantiser used by the theory module and by the simulated
  fp8 path (Appendix B.11).
* ``PrecisionPolicy`` — the explicit, jit-friendly replacement for torch
  AMP autocast.  Every module takes a policy and casts at its boundaries;
  there is no global mutable autocast state (JAX-idiomatic).
* ``ComplexPair`` — split-real representation of complex tensors so that
  half-precision *real* matmul hardware (MXU / tensor cores) can execute
  complex contractions.  This is the JAX analogue of the paper's
  ``view_as_real`` trick.

The paper uses fp16 + loss scaling on GPU; on TPU the native half format
is bf16.  Both are first-class here (``MIXED_FNO_FP16`` reproduces the
paper; ``MIXED_FNO_BF16`` is the TPU-native adaptation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# (a0, eps, T)-precision system (paper Section 3 / Appendix A)
# ---------------------------------------------------------------------------

# Machine-epsilon-style relative spacing for the formats discussed in the
# paper.  eps(fp16) ~ 2^-11 ~ 4.9e-4 (the paper quotes 1e-4 as the order of
# magnitude); eps(bf16) ~ 2^-8; eps(fp8-e4m3) ~ 2^-3; eps(fp8-e5m2) ~ 2^-2.
FORMAT_EPS = {
    "float64": 2.0 ** -52,
    "float32": 2.0 ** -23,
    "bfloat16": 2.0 ** -8,
    "float16": 2.0 ** -11,
    "fp8_e4m3": 2.0 ** -3,
    "fp8_e5m2": 2.0 ** -2,
}

# Dynamic range (max finite magnitude) per format — used by the simulated
# fp8 clipping path (Appendix B.11) and the stabiliser analysis.
FORMAT_MAX = {
    "float32": 3.4028235e38,
    "bfloat16": 3.3895314e38,
    "float16": 65504.0,
    "fp8_e4m3": 448.0,
    "fp8_e5m2": 57344.0,
}


@dataclasses.dataclass(frozen=True)
class PrecisionSystem:
    """The paper's ``(a0, eps, T)``-precision system.

    ``S = {0} ∪ {±a0 (1+eps)^i : 0 <= i <= T}`` with ``q(x) = argmin_{y∈S}|x-y|``.
    """

    a0: float
    eps: float
    T: int

    def quantize(self, x: jnp.ndarray) -> jnp.ndarray:
        """Round ``x`` to the nearest representable value (pure jnp)."""
        sign = jnp.sign(x)
        mag = jnp.abs(x)
        # index of the geometric grid point: i = round(log(mag/a0) / log(1+eps))
        log_ratio = jnp.log(jnp.maximum(mag, 1e-300) / self.a0)
        i = jnp.round(log_ratio / jnp.log1p(self.eps))
        i = jnp.clip(i, 0, self.T)
        q = self.a0 * jnp.power(1.0 + self.eps, i)
        # values below a0/2 snap to 0 (underflow)
        q = jnp.where(mag < self.a0 / 2, 0.0, q)
        return sign * q


def precision_system_for(fmt: str) -> PrecisionSystem:
    """Build an (a0, eps, T)-system approximating a named float format."""
    eps = FORMAT_EPS[fmt]
    vmax = FORMAT_MAX.get(fmt, 3.4e38)
    # smallest normal, roughly
    a0 = {
        "float32": 1.18e-38,
        "bfloat16": 1.18e-38,
        "float16": 6.1e-5,
        "fp8_e4m3": 2.0 ** -6,
        "fp8_e5m2": 2.0 ** -14,
    }.get(fmt, 1e-30)
    import math

    T = int(math.log(vmax / a0) / math.log1p(eps))
    return PrecisionSystem(a0=a0, eps=eps, T=T)


def simulate_fp8(x: jnp.ndarray, fmt: str = "fp8_e5m2") -> jnp.ndarray:
    """Simulated fp8: clip to the format's range, round the mantissa
    (Appendix B.11)."""
    clipped = jnp.clip(x, -FORMAT_MAX[fmt], FORMAT_MAX[fmt])
    return _round_mantissa(clipped, fmt)


def _round_mantissa(x: jnp.ndarray, fmt: str) -> jnp.ndarray:
    mant_bits = {"fp8_e4m3": 3, "fp8_e5m2": 2}[fmt]
    m, e = jnp.frexp(jnp.asarray(x, jnp.float32))
    m = jnp.round(m * (1 << (mant_bits + 1))) / (1 << (mant_bits + 1))
    return jnp.ldexp(m, e)


# ---------------------------------------------------------------------------
# Split-real complex representation ("view_as_real" for JAX/TPU)
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
class ComplexPair:
    """A complex tensor stored as two real tensors (re, im).

    This is how half-precision complex data lives on hardware with real-only
    half matmul units.  Registered as a pytree so it flows through jit/scan/
    pjit transparently.
    """

    __slots__ = ("re", "im")

    def __init__(self, re: jnp.ndarray, im: jnp.ndarray):
        self.re = re
        self.im = im

    # -- pytree protocol --
    def tree_flatten(self):
        return (self.re, self.im), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    # -- constructors / views --
    @classmethod
    def from_complex(cls, c: jnp.ndarray, dtype: Any) -> "ComplexPair":
        return cls(jnp.real(c).astype(dtype), jnp.imag(c).astype(dtype))

    def to_complex(self, dtype: Any = jnp.complex64) -> jnp.ndarray:
        f = jnp.float32 if dtype == jnp.complex64 else jnp.float64
        return jax.lax.complex(self.re.astype(f), self.im.astype(f))

    # -- metadata --
    @property
    def shape(self):
        return self.re.shape

    @property
    def dtype(self):
        return self.re.dtype

    def astype(self, dtype) -> "ComplexPair":
        return ComplexPair(self.re.astype(dtype), self.im.astype(dtype))

    # -- arithmetic (elementwise) --
    def __add__(self, o: "ComplexPair") -> "ComplexPair":
        return ComplexPair(self.re + o.re, self.im + o.im)

    def __mul__(self, o):
        if isinstance(o, ComplexPair):
            # 4-mult complex product; accumulation in the inputs' dtype —
            # contraction paths use f32 accumulation explicitly.
            return ComplexPair(
                self.re * o.re - self.im * o.im,
                self.re * o.im + self.im * o.re,
            )
        return ComplexPair(self.re * o, self.im * o)

    def conj(self) -> "ComplexPair":
        return ComplexPair(self.re, -self.im)

    def abs2(self) -> jnp.ndarray:
        r = self.re.astype(jnp.float32)
        i = self.im.astype(jnp.float32)
        return r * r + i * i


def quantize_complex(c: jnp.ndarray, dtype: Any) -> jnp.ndarray:
    """Round-trip a complex64 tensor through a half-precision ComplexPair.

    Models the representation (precision) error of storing spectral data at
    half precision — this is exactly the error bounded by Theorem 3.2; used
    at FFT boundaries where TPUs compute the transform in f32.
    """
    if dtype in (jnp.float32, None):
        return c
    pair = ComplexPair.from_complex(c, dtype)
    return pair.to_complex()


# ---------------------------------------------------------------------------
# PrecisionPolicy — the explicit AMP replacement
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Where each class of op computes/stores, threaded explicitly.

    Attributes:
      name:            registry key.
      param_dtype:     master weight storage (always f32 for training).
      compute_dtype:   real-valued dense ops (the AMP-autocast set).
      spectral_dtype:  FNO-block complex pipeline storage (the paper's
                       contribution: fp16/bf16 here).  ``None`` => full f32
                       complex (the "AMP leaves the FNO block in full
                       precision" failure mode the paper identifies).
      accum_dtype:     contraction accumulation (always f32: MXU-native).
      stabilizer:      pre-FFT stabiliser name ('tanh' | 'hard_clip' |
                       'sigma_clip' | None).  Paper: tanh whenever the
                       forward FFT is half precision.
      requires_loss_scaling: fp16 needs dynamic loss scaling; bf16 does not.
    """

    name: str
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    spectral_dtype: Optional[Any] = None
    accum_dtype: Any = jnp.float32
    stabilizer: Optional[str] = None
    requires_loss_scaling: bool = False

    # -- casting helpers -----------------------------------------------------
    def cast_compute(self, tree):
        """Cast a pytree of real arrays to the compute dtype."""
        def _c(x):
            if isinstance(x, jnp.ndarray) and jnp.issubdtype(x.dtype, jnp.floating):
                return x.astype(self.compute_dtype)
            return x
        return jax.tree_util.tree_map(_c, tree)

    def cast_spectral(self, c: jnp.ndarray):
        """Enter the spectral pipeline: complex64 -> ComplexPair at the
        spectral storage dtype (or stay complex64 for the full path)."""
        if self.spectral_dtype is None:
            return c
        return ComplexPair.from_complex(c, self.spectral_dtype)

    @property
    def spectral_is_half(self) -> bool:
        return self.spectral_dtype is not None

    @property
    def eps(self) -> float:
        """Relative precision of the spectral dtype (for theory checks)."""
        key = jnp.dtype(self.spectral_dtype).name if self.spectral_dtype is not None else "float32"
        return FORMAT_EPS[key]


# The paper's three headline settings + TPU-native variants + fp8 sim.
FULL = PrecisionPolicy(name="full")
AMP_FP16 = PrecisionPolicy(
    name="amp_fp16", compute_dtype=jnp.float16, requires_loss_scaling=True
)
AMP_BF16 = PrecisionPolicy(name="amp_bf16", compute_dtype=jnp.bfloat16)
MIXED_FNO_FP16 = PrecisionPolicy(
    name="mixed_fno_fp16",
    compute_dtype=jnp.float16,
    spectral_dtype=jnp.float16,
    stabilizer="tanh",
    requires_loss_scaling=True,
)
MIXED_FNO_BF16 = PrecisionPolicy(
    name="mixed_fno_bf16",
    compute_dtype=jnp.bfloat16,
    spectral_dtype=jnp.bfloat16,
    stabilizer="tanh",
)
# FNO block half, rest full — the "Half-Prec FNO only" bar in Fig. 3.
HALF_FNO_ONLY = PrecisionPolicy(
    name="half_fno_only", spectral_dtype=jnp.float16, stabilizer="tanh",
    requires_loss_scaling=True,
)

POLICIES = {
    p.name: p
    for p in [FULL, AMP_FP16, AMP_BF16, MIXED_FNO_FP16, MIXED_FNO_BF16, HALF_FNO_ONLY]
}


def get_policy(name: str) -> PrecisionPolicy:
    try:
        return POLICIES[name]
    except KeyError:
        raise KeyError(f"unknown precision policy {name!r}; have {sorted(POLICIES)}")
