"""Roofline machinery tests: the cost_analysis loop-undercount finding and
the trip-count-aware HLO parser against analytically-known programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_parse import parse_hlo, split_computations
from repro.launch.roofline import analyze_counts, model_flops

jax.config.update("jax_platform_name", "cpu")

L, N = 8, 128


def _scan_matmul_fn():
    def f(w, x):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(body, x, w)
        return jnp.sum(h)
    return f


def _shapes():
    return (jax.ShapeDtypeStruct((L, N, N), jnp.float32),
            jax.ShapeDtypeStruct((N, N), jnp.float32))


class TestCostAnalysisUndercount:
    def test_loop_bodies_counted_once(self):
        """The finding that motivates the custom parser: XLA cost_analysis
        reports a scan of length L at ~1/L of the true FLOPs."""
        f = _scan_matmul_fn()
        compiled = jax.jit(f).lower(*_shapes()).compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        true_flops = L * 2 * N ** 3
        ratio = cost["flops"] / true_flops
        assert ratio < 0.5, f"expected undercount, got ratio {ratio}"


class TestHLOParser:
    def test_forward_flops_exact(self):
        f = _scan_matmul_fn()
        compiled = jax.jit(f).lower(*_shapes()).compile()
        counts = parse_hlo(compiled.as_text())
        true_flops = L * 2 * N ** 3
        assert abs(counts.flops - true_flops) / true_flops < 0.05

    def test_grad_flops_about_3x(self):
        f = _scan_matmul_fn()
        fwd = parse_hlo(jax.jit(f).lower(*_shapes()).compile().as_text())
        bwd = parse_hlo(
            jax.jit(jax.grad(f, argnums=0)).lower(*_shapes()).compile().as_text())
        assert 2.0 < bwd.flops / fwd.flops < 4.5

    def test_collectives_counted_under_spmd(self):
        if jax.device_count() < 2:
            pytest.skip("needs >1 device (run under dryrun env)")
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = jax.make_mesh((jax.device_count(),), ("data",))
        def f(x):
            return jnp.sum(x)
        xs = jax.ShapeDtypeStruct((jax.device_count() * 4, 8), jnp.float32)
        with mesh:
            comp = jax.jit(f, in_shardings=NamedSharding(mesh, P("data"))
                           ).lower(xs).compile()
        counts = parse_hlo(comp.as_text())
        assert counts.collective_bytes >= 0  # parses without error

    def test_split_computations_structure(self):
        f = _scan_matmul_fn()
        hlo = jax.jit(f).lower(*_shapes()).compile().as_text()
        comps = split_computations(hlo)
        assert any("while" in o.op for c in comps.values() for o in c.ops)

    def test_bytes_in_sane_range(self):
        """HBM-byte estimate must be within [1x, 30x] of the tensor data
        actually touched (loose envelope; catches unit errors)."""
        f = _scan_matmul_fn()
        counts = parse_hlo(jax.jit(f).lower(*_shapes()).compile().as_text())
        data_bytes = (L * N * N + N * N) * 4
        assert data_bytes <= counts.bytes <= 40 * data_bytes


class TestRooflineTerms:
    def test_analyze_counts_math(self):
        from repro.launch.hlo_parse import HLOCounts
        c = HLOCounts(flops=197e12, bytes=819e9, collective_by_kind={"all-reduce": 50e9})
        r = analyze_counts(c, 256)
        np.testing.assert_allclose(r.compute_s, 1.0)
        np.testing.assert_allclose(r.memory_s, 1.0)
        np.testing.assert_allclose(r.collective_s, 1.0)
        assert r.step_time_s == 1.0

    def test_model_flops_6nd(self):
        assert model_flops(1e9, 1e6) == 6e15
