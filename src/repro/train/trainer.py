"""The training loop: precision schedule, fault tolerance, stragglers.

One Trainer drives any model via a user-supplied
``loss_fn(params, batch, policy) -> scalar``.

Features (DESIGN.md §4):
  * **precision schedule** (paper §4.4): each schedule phase owns its own
    jitted train step (dtype changes require recompiles — at most 2/run);
  * **dynamic loss scaling + skip-step** for fp16 phases: non-finite
    gradients skip the update and halve the scale (lax.cond, fully jitted);
  * **checkpoint/restart**: async atomic checkpoints every ``ckpt_every``;
    ``Trainer.restore()`` resumes bit-compatible (data pipeline is
    stateless so only (params, opt, scale, step) need storage);
  * **preemption**: SIGTERM sets a flag; the loop checkpoints at the next
    step boundary and exits cleanly;
  * **straggler monitor**: EWMA of step wall-time; steps slower than
    ``straggler_factor``× the EWMA are counted and surfaced through
    ``Trainer.stats`` (on multi-host this hook feeds the re-scheduler);
  * **grad accumulation** via ``lax.scan`` over microbatches.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core import PrecisionPolicy, PrecisionSchedule
from repro.optim import (
    AdamW,
    all_finite,
    init_loss_scale,
    loss_scaling_required,
    scale_loss,
    unscale_grads,
    update_loss_scale,
)
from . import checkpoint as ckpt_lib


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    schedule: PrecisionSchedule = dataclasses.field(
        default_factory=lambda: PrecisionSchedule.constant("full")
    )
    optimizer: AdamW = dataclasses.field(default_factory=AdamW)
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep_last_k: int = 3
    microbatches: int = 1
    straggler_factor: float = 3.0
    log_every: int = 10
    #: Auto-precision: an ``repro.autoprec.AutoPrecisionController`` that
    #: supersedes the static schedule — per-site formats follow runtime
    #: telemetry plus the Thm 3.1/3.2 budgets.  Also created implicitly
    #: by ``PrecisionSchedule.auto(...)``.
    autoprec: Optional[Any] = None
    #: Collect numerics telemetry (amax / overflow / underflow taps as a
    #: functional carry of the jitted step) without a controller.
    telemetry: bool = False
    #: Tri-state Pallas toggle threaded into the step builder: None =
    #: auto (TPU backends / REPRO_USE_PALLAS=1).  Resolved once at
    #: Trainer construction and passed to ``loss_fn`` when its signature
    #: accepts a ``use_pallas`` keyword (incl. ``**kwargs``) —
    #: model-agnostic loss closures that bake the flag into their config
    #: simply ignore it.
    use_pallas: Optional[bool] = None
    #: Calibration-state JSON (``repro.tune``): activated at Trainer
    #: construction so spectral tile resolution serves validated tuned
    #: tiles instead of the static heuristic.  None = keep whatever is
    #: already active (explicit ``activate()`` or
    #: ``$REPRO_CALIBRATION_STATE``).
    calibration_state: Optional[str] = None
    #: Observability (``repro.obs``): turn on the global trace ring (if
    #: not already on) and record per-step phase spans (``train/data``,
    #: ``train/step``, ``train/telemetry``, ``train/controller``),
    #: loss-scale numerics events, and step-wall histograms in the
    #: metrics registry.  The span calls themselves are free no-ops when
    #: this is off — bench_obs holds the on/off delta under 5%.
    obs: bool = False


class Trainer:
    def __init__(
        self,
        loss_fn: Callable[[Any, Dict, PrecisionPolicy], jnp.ndarray],
        params: Any,
        config: TrainerConfig,
    ):
        self.loss_fn = loss_fn
        # own the parameter buffers: the jitted step donates them
        # (donate_argnums), which would delete a caller-shared pytree.
        self.params = jax.tree_util.tree_map(jnp.copy, params)
        self.cfg = config
        self.opt_state = config.optimizer.init(params)
        self.scale_state = init_loss_scale()
        self.step = 0
        self.history: list = []
        self.stats = {"straggler_steps": 0, "skipped_steps": 0,
                      "recompiles": 0, "policy_changes": 0}
        # auto-precision: an explicit controller wins; a schedule in
        # ``auto`` mode gets a default controller over its base policy
        self.controller = config.autoprec
        if (getattr(config.schedule, "mode", "static") == "auto"
                and self.controller is None):
            from repro.autoprec import AutoPrecisionController

            self.controller = AutoPrecisionController(
                base=config.schedule.base,
                grid_points=getattr(config.schedule, "grid_points", None))
        self._collect = bool(config.telemetry or self.controller is not None)
        self.telemetry = None
        if self._collect:
            from repro.autoprec import TelemetryAggregator

            self.telemetry = TelemetryAggregator()
        from repro.kernels.ops import resolve_use_pallas

        self._use_pallas = resolve_use_pallas(config.use_pallas)
        if config.calibration_state is not None:
            from repro.tune.cache import activate

            activate(config.calibration_state)
        import inspect

        params_sig = inspect.signature(loss_fn).parameters
        self._loss_takes_pallas = "use_pallas" in params_sig or any(
            p.kind is inspect.Parameter.VAR_KEYWORD
            for p in params_sig.values()
        )
        self._obs = bool(config.obs)
        if self._obs:
            from repro.obs import trace as obs_trace

            if not obs_trace.is_enabled():
                obs_trace.enable()
        self._last_scale: Optional[float] = None
        self._steps_cache: Dict[Any, Callable] = {}
        self._preempted = False
        self._ckptr = (
            ckpt_lib.AsyncCheckpointer(config.ckpt_dir, config.keep_last_k)
            if config.ckpt_dir
            else None
        )

    # -- fault tolerance ----------------------------------------------------
    def install_preemption_handler(self, signum=signal.SIGTERM):
        signal.signal(signum, lambda *_: self._on_preempt())

    def _on_preempt(self):
        self._preempted = True

    def save(self):
        if self._ckptr is None:
            return
        state = {
            "params": self.params,
            "opt": self.opt_state,
            "scale": self.scale_state,
            "step": jnp.asarray(self.step),
        }
        self._ckptr.save(self.step, state)

    def restore(self) -> bool:
        if self.cfg.ckpt_dir is None or ckpt_lib.latest_step(self.cfg.ckpt_dir) is None:
            return False
        target = {
            "params": self.params,
            "opt": self.opt_state,
            "scale": self.scale_state,
            "step": jnp.asarray(self.step),
        }
        state, _ = ckpt_lib.restore(self.cfg.ckpt_dir, target)
        self.params = state["params"]
        self.opt_state = state["opt"]
        self.scale_state = state["scale"]
        self.step = int(state["step"])
        return True

    # -- compiled step per policy --------------------------------------------
    def _build_step(self, policy: PrecisionPolicy) -> Callable:
        opt = self.cfg.optimizer
        nmicro = self.cfg.microbatches
        collect = self._collect
        # decided by the resolved rule table (train/loss_scale site), so a
        # precision_rules override can flip it per run without a new policy
        use_scaling = loss_scaling_required(policy)
        if self._loss_takes_pallas:
            base_loss_fn, up = self.loss_fn, self._use_pallas

            def loss_fn(p, b, pol):
                return base_loss_fn(p, b, pol, use_pallas=up)
        else:
            loss_fn = self.loss_fn

        def micro_grads(params, batch, scale_state):
            # The telemetry collector lives *inside* the differentiated
            # function: taps record tracers of the loss trace and the
            # snapshot rides out through has_aux, so collection works
            # under grad and per-iteration inside the microbatch scan.
            def scaled_loss(p, b):
                if collect:
                    from repro.autoprec import TraceCollector, collecting

                    col = TraceCollector()
                    with collecting(col):
                        loss = loss_fn(p, b, policy)
                    telem = col.snapshot()
                else:
                    loss = loss_fn(p, b, policy)
                    telem = {}
                return (scale_loss(loss, scale_state) if use_scaling
                        else loss), telem

            grad_fn = jax.value_and_grad(scaled_loss, has_aux=True)
            if nmicro == 1:
                (loss, telem), grads = grad_fn(params, batch)
                return loss, grads, telem
            # split the leading batch axis into microbatches and scan
            def resplit(x):
                return x.reshape(nmicro, x.shape[0] // nmicro, *x.shape[1:])

            mb = jax.tree_util.tree_map(resplit, batch)

            def body(carry, b):
                acc_loss, acc_g = carry
                (loss, telem), g = grad_fn(params, b)
                acc_g = jax.tree_util.tree_map(jnp.add, acc_g, g)
                return (acc_loss + loss, acc_g), telem

            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (loss, grads), telems = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zero_g), mb
            )
            if collect:
                from repro.autoprec import merge_stacked

                telems = merge_stacked(telems)
            inv = 1.0 / nmicro
            return loss * inv, jax.tree_util.tree_map(lambda g: g * inv, grads), telems

        def train_step(params, opt_state, scale_state, batch):
            loss, grads, telem = micro_grads(params, batch, scale_state)
            if use_scaling:
                grads = unscale_grads(grads, scale_state)
                loss = loss / scale_state.scale
            finite = all_finite(grads)

            def do_update(_):
                return opt.update(grads, opt_state, params)

            def skip(_):
                return params, opt_state

            new_params, new_opt = jax.lax.cond(finite, do_update, skip, None)
            new_scale = (
                update_loss_scale(scale_state, finite) if use_scaling else scale_state
            )
            return new_params, new_opt, new_scale, loss, finite, telem

        return jax.jit(train_step, donate_argnums=(0, 1))

    def _step_fn(self, policy: PrecisionPolicy) -> Callable:
        # key by the policy's own rules and the active precision_rules
        # scope, not just the name: a step bakes the rules in at trace
        # time, and with_rules overlays may share the parent's name
        from repro.precision import current_overrides

        key = (policy.name, policy.rules, current_overrides())
        if key not in self._steps_cache:
            self._steps_cache[key] = self._build_step(policy)
            self.stats["recompiles"] += 1
        return self._steps_cache[key]

    # -- observability --------------------------------------------------------
    def _obs_step_end(self, dt: float) -> None:
        """Per-step metrics + loss-scale numerics events (obs mode only;
        the extra ``float(scale)`` sync is why this is gated)."""
        from repro.obs import loss_scale_event, registry

        registry().histogram("repro_train_step_wall_ms").observe(dt * 1e3)
        registry().counter("repro_train_steps_total").inc()
        new_scale = float(self.scale_state.scale)
        if self._last_scale is not None and new_scale != self._last_scale:
            kind = ("loss_scale_halved" if new_scale < self._last_scale
                    else "loss_scale_grown")
            loss_scale_event(kind, new_scale, step=self.step)
        self._last_scale = new_scale

    def publish_stats(self) -> Dict:
        """Publish ``self.stats`` into the obs registry as
        ``repro_train_*`` gauges and return the dict (the registry
        snapshot is the machine-readable export source)."""
        from repro.obs import registry

        registry().publish("train", self.stats)
        registry().gauge("repro_train_step").set(float(self.step))
        return self.stats

    # -- the loop -------------------------------------------------------------
    def run(self, batch_fn: Callable[[int], Dict], steps: Optional[int] = None):
        """batch_fn(step) -> batch pytree (stateless pipeline contract)."""
        from repro.obs import trace as obs_trace

        total = steps if steps is not None else self.cfg.total_steps
        ewma = None
        while self.step < total and not self._preempted:
            if self.controller is not None:
                # auto mode: the controller's overlay decides the formats;
                # a version bump resolves to a new (name, rules) key and
                # the step cache recompiles exactly once per change
                policy = self.controller.policy()
            else:
                policy = self.cfg.schedule.policy_at(self.step, self.cfg.total_steps)
            fn = self._step_fn(policy)
            with obs_trace.span("train/data", step=self.step):
                batch = batch_fn(self.step)
            t0 = time.perf_counter()
            # the span brackets the host call plus the float(loss) sync,
            # so its duration carries the device wall of the step
            with obs_trace.span("train/step", step=self.step,
                                policy=policy.name):
                self.params, self.opt_state, self.scale_state, loss, finite, telem = fn(
                    self.params, self.opt_state, self.scale_state, batch
                )
                loss = float(loss)
            dt = time.perf_counter() - t0
            if not bool(finite):
                self.stats["skipped_steps"] += 1
            if ewma is not None and dt > self.cfg.straggler_factor * ewma:
                self.stats["straggler_steps"] += 1
            ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
            if self.telemetry is not None:
                with obs_trace.span("train/telemetry", step=self.step):
                    self.telemetry.update(telem)
            if self._obs:
                self._obs_step_end(dt)
            self.history.append({"step": self.step, "loss": loss, "policy": policy.name, "dt": dt})
            self.step += 1
            if (self.controller is not None
                    and self.step % self.controller.config.interval == 0):
                with obs_trace.span("train/controller", step=self.step):
                    if self.controller.update(self.telemetry.take_window(),
                                              step=self.step):
                        self.stats["policy_changes"] += 1
            if self._ckptr is not None and self.step % self.cfg.ckpt_every == 0:
                self.save()
        if self._preempted and self._ckptr is not None:
            self.save()
        if self._ckptr is not None:
            self._ckptr.wait()
        if self._obs:
            self.publish_stats()
        return self.history
