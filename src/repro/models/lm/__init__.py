"""Assigned LM-family architecture pool (decoder-only, MoE, SSM, hybrid,
encoder-decoder audio, VLM) on a single scan-over-layers substrate."""
from .common import (  # noqa: F401
    blocked_attention,
    chunk_attention,
    gqa_attention,
    plain_attention,
    rmsnorm,
)
from .model import (  # noqa: F401
    FULL_WINDOW,
    init_cache,
    init_lm,
    init_paged_cache,
    layer_windows,
    lm_decode_step,
    lm_forward,
    lm_paged_decode_step,
    lm_paged_prefill_chunk,
    lm_prefill_chunk,
)
from .moe import init_moe, moe_apply  # noqa: F401
from .ssd import init_ssd, ssd_decode_step, ssd_forward  # noqa: F401
from .whisper import (  # noqa: F401
    init_whisper,
    init_whisper_cache,
    whisper_decode_step,
    whisper_encode,
    whisper_forward,
)
