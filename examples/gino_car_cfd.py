"""GINO on synthetic Shape-Net-Car-like CFD (the paper's irregular-geometry
setting): GNO encoder -> latent 3-D mixed-precision FNO -> GNO decoder,
predicting surface pressure from geometry.

    PYTHONPATH=src python examples/gino_car_cfd.py [--steps 15]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import FULL, get_policy
from repro.data import sample_car_batch
from repro.models import GINOConfig, gino_apply, init_gino
from repro.models.fno import FNOConfig
from repro.optim import AdamW
from repro.train.losses import relative_l2


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=15)
    args = ap.parse_args()

    cfg = GINOConfig(
        hidden=16, latent_grid=6, k_neighbors=6,
        fno=FNOConfig(in_channels=16, out_channels=16, hidden_channels=16,
                      lifting_channels=16, projection_channels=16,
                      n_layers=2, modes=(3, 3, 3), positional_embedding=False),
    )
    params = init_gino(jax.random.PRNGKey(0), cfg)
    policy = get_policy("mixed_fno_bf16")
    opt = AdamW(lr=2e-3)
    state = opt.init(params)

    def to_jnp(d):
        return {k: jnp.asarray(v) for k, v in d.items()}

    @jax.jit
    def step(p, s, batch, labels):
        def loss_fn(pp):
            pred = gino_apply(pp, batch, cfg, policy)
            return relative_l2(pred, labels)
        loss, g = jax.value_and_grad(loss_fn)(p)
        p2, s2 = opt.update(g, s, p)
        return p2, s2, loss

    for i in range(args.steps):
        batch, labels = sample_car_batch(
            seed=i, batch=4, n_points=128, latent_grid=cfg.latent_grid,
            k=cfg.k_neighbors)
        params, state, loss = step(params, state, to_jnp(batch), jnp.asarray(labels))
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:3d}  rel-L2 {float(loss):.4f}")

    batch, labels = sample_car_batch(seed=999, batch=4, n_points=128,
                                     latent_grid=cfg.latent_grid, k=cfg.k_neighbors)
    pred = gino_apply(params, to_jnp(batch), cfg, FULL)
    e = float(relative_l2(pred, jnp.asarray(labels)))
    print(f"eval rel-L2 on fresh geometries: {e:.4f}")


if __name__ == "__main__":
    main()
