"""Spherical shallow-water dataset (paper §B.2, Bonev et al. 2023 style).

We integrate the *linearised* rotating shallow-water equations on the
sphere (gravity-wave dynamics about a state of rest):

    ∂φ/∂t = -Φ̄ ∇·u
    ∂u/∂t = -∇φ - f k̂×u,       f = 2Ω sin(lat)

on the Gauss-Legendre lat-lon grid with spectral (SHT) hyperdiffusion
filtering each step for stability.  Random smooth initial geopotential
fields are synthesised from low-degree spherical-harmonic coefficients
(`grf_sphere`) — the learning task is φ(0) ↦ (φ, u, v)(T), matching the
SWE-on-the-fly-random-ICs protocol of the paper.  (Full nonlinear SWE is a
documented simplification — DESIGN.md §7.)
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from .grf import grf_sphere
from repro.models.sht import legendre_matrices, sht_forward, sht_inverse


def _grid(nlat: int, nlon: int):
    _, x, _ = legendre_matrices(nlat, 8, 8)
    lat = np.arcsin(np.clip(x, -1, 1))  # Gauss-Legendre latitudes
    lon = np.linspace(0, 2 * math.pi, nlon, endpoint=False)
    return jnp.asarray(lat, jnp.float32), jnp.asarray(lon, jnp.float32)


@functools.partial(jax.jit, static_argnames=("nlat", "nlon", "steps", "lmax"))
def solve_swe_linear(
    phi0: jnp.ndarray,
    nlat: int,
    nlon: int,
    steps: int = 200,
    dt: float = 150.0,
    phibar: float = 3.0e4,
    omega: float = 7.292e-5,
    radius: float = 6.371e6,
    lmax: int = 24,
):
    """phi0: (nlat, nlon) geopotential anomaly. Returns (phi, u, v) at T."""
    lmax = min(lmax, nlat, nlon // 2 + 1)
    lat, lon = _grid(nlat, nlon)
    coslat = jnp.cos(lat)[:, None]
    fcor = 2.0 * omega * jnp.sin(lat)[:, None]
    dlon = 2.0 * math.pi / nlon

    def ddlon(a):
        return (jnp.roll(a, -1, axis=1) - jnp.roll(a, 1, axis=1)) / (2 * dlon)

    def ddlat(a):
        # non-uniform Gauss latitudes: central differences w/ one-sided ends
        d = jnp.gradient(a, axis=0) / jnp.gradient(lat)[:, None]
        return d

    def filt(a):
        c = sht_forward(a, lmax, lmax)
        l = jnp.arange(lmax)[:, None]
        damp = jnp.exp(-1e-2 * (l / lmax) ** 4 * 16)
        return sht_inverse(c * damp, nlat, nlon)

    def step(state, _):
        phi, u, v = state
        div = (ddlon(u) / coslat + ddlat(v * coslat) / coslat) / radius
        dphix = ddlon(phi) / (radius * coslat)
        dphiy = ddlat(phi) / radius
        phi_n = phi - dt * phibar * div
        u_n = u + dt * (-dphix + fcor * v)
        v_n = v + dt * (-dphiy - fcor * u)
        return (filt(phi_n), filt(u_n), filt(v_n)), None

    state0 = (phi0, jnp.zeros_like(phi0), jnp.zeros_like(phi0))
    (phi, u, v), _ = jax.lax.scan(step, state0, None, length=steps)
    return phi, u, v


def sample_swe_batch(key: jax.Array, nlat: int, nlon: int, batch: int, steps: int = 200):
    """Returns (x, y): inputs (B, 3, nlat, nlon) = (φ0, 0, 0) and targets
    (B, 3, nlat, nlon) = (φ, u, v)(T)."""
    phi0 = grf_sphere(key, nlat, nlon, lmax=min(16, nlat // 2), batch=batch)
    phi0 = phi0 * 1e2  # geopotential anomaly scale (m²/s²)
    outs = jax.vmap(
        lambda p: solve_swe_linear(p, nlat, nlon, steps=steps)
    )(phi0)
    x = jnp.stack([phi0, jnp.zeros_like(phi0), jnp.zeros_like(phi0)], axis=1)
    y = jnp.stack(outs, axis=1)
    # normalise channels to O(1)
    scale = jnp.asarray([1e2, 1.0, 1.0])[None, :, None, None]
    return x / 1e2, y / scale
