"""Golden dtype-trace snapshots per registry policy.

``repro.analyze.dtype_trace`` records the exact cast / contraction /
FFT / kernel dtype sequence of one FNO spectral layer as traced under
each policy.  The sequences live in ``tests/golden/dtype_traces.json``;
a refactor that silently changes where a cast lands, which dtype a
contraction accumulates in, or whether the Pallas path quantises its
operands shows up here as a diff — set ``REPRO_REGEN_GOLDENS=1`` and
rerun to re-record after an *intentional* numerics change.
"""
import json
import os

import pytest

from repro.analyze import dtype_trace
from repro.precision.policy import POLICIES, get_policy

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "dtype_traces.json")

_KEYS = [name + suffix
         for name in sorted(POLICIES)
         for suffix in ("", "+pallas", "+fused")]


def _load_goldens():
    with open(GOLDEN_PATH) as fh:
        return json.load(fh)


def _compute(key):
    name, _, suffix = key.partition("+")
    # "+pallas" pins the *staged* Pallas pipeline (dtype_trace defaults
    # fuse_spectral=False); "+fused" snapshots the megakernel dispatch
    return dtype_trace(get_policy(name), use_pallas=suffix in ("pallas", "fused"),
                       fuse_spectral=suffix == "fused")


@pytest.fixture(scope="module")
def goldens():
    if os.environ.get("REPRO_REGEN_GOLDENS") == "1":
        gold = {key: _compute(key) for key in _KEYS}
        with open(GOLDEN_PATH, "w") as fh:
            json.dump(gold, fh, indent=2, sort_keys=True)
    return _load_goldens()


class TestGoldenTraces:
    def test_golden_file_covers_every_policy(self, goldens):
        assert sorted(goldens) == sorted(_KEYS)

    @pytest.mark.parametrize("key", _KEYS)
    def test_trace_matches_golden(self, goldens, key):
        assert _compute(key) == goldens[key], (
            f"dtype sequence for {key!r} drifted from the golden "
            f"snapshot; if the numerics change is intentional, "
            f"regenerate with REPRO_REGEN_GOLDENS=1")


class TestTraceInvariants:
    """Policy-level properties that must hold regardless of the exact
    golden sequence (these survive jax version bumps that reorder or
    rename eqns, where the snapshots would need regeneration)."""

    @pytest.mark.parametrize("name", sorted(POLICIES))
    def test_no_half_accumulation_on_pallas_path(self, name):
        trace = dtype_trace(get_policy(name), use_pallas=True)
        for entry in trace:
            if entry.startswith("dot_general:"):
                acc = entry.split("@acc=")[1].split("@")[0]
                assert acc not in ("float16", "bfloat16"), entry

    def test_half_policy_touches_half_dtype(self):
        # mixed_fno_fp16 stores the spectrum at f16: the trace must
        # actually contain the half dtype (the fp32-resident check's
        # dynamic counterpart)
        trace = dtype_trace(get_policy("mixed_fno_fp16"), use_pallas=True)
        assert any("float16" in e for e in trace), trace

    def test_full_policy_is_all_f32(self):
        trace = dtype_trace(get_policy("full"))
        for entry in trace:
            assert "float16" not in entry and "bfloat16" not in entry, entry

    @pytest.mark.parametrize("name", sorted(POLICIES))
    def test_fused_path_spectrum_never_leaves_vmem(self, name):
        """The version-robust megakernel invariant: the fused dispatch
        lowers to exactly one kernel launch with no FFT primitives and
        zero HBM-visible half casts between rFFT and irFFT — everything
        between the transforms lives inside the one pallas_call (the
        trace lists a launch before descending into its body, so every
        entry before it is HBM-visible staging)."""
        trace = dtype_trace(get_policy(name), use_pallas=True,
                            fuse_spectral=True)
        assert not any(e.startswith("fft:") for e in trace), trace
        launches = [i for i, e in enumerate(trace)
                    if e.startswith("pallas_call:")]
        assert len(launches) == 1, trace
        for entry in trace[:launches[0]]:
            assert "float16" not in entry and "bfloat16" not in entry, (
                f"HBM-visible half cast before the fused launch: {entry}")
