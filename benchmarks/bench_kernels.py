"""Kernel differential benchmark: the Pallas spectral path vs the einsum
reference, forward AND backward, wall-clock + compiled peak memory.

This is the measurement half of the training-grade kernel PR: for dense
and CP-factorised contractions it times ``value_and_grad`` through both
paths and records the compiled step's ``temp_size_in_bytes`` (the CPU
container's analogue of the paper's GPU peak-memory numbers; on TPU the
same harness prices the Mosaic kernels).  On CPU the Pallas kernels run
in *interpret mode*, so their wall numbers measure the harness, not the
hardware — the JSON records ``interpret`` so readers don't compare
apples to Mosaic.

    PYTHONPATH=src python -m benchmarks.bench_kernels [--policy mixed_fno_bf16]

Results land in ``benchmarks/results/kernels.json`` (uploaded by the CI
bench-smoke job).
"""
from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn, write_result
from repro.core import get_policy
from repro.core.spectral import _cp_exprs, _dense_expr
from repro.kernels import ops
from repro.kernels.spectral_contract import (
    cp_vmem_bytes, pick_block_m, vmem_bytes, vmem_bytes_bwd)
from repro.launch.roofline import HBM_BW
from repro.tune.measure import bytes_moved

RESULTS = os.path.join(os.path.dirname(__file__), "results", "kernels.json")

CASES = {
    # name: (B, I, O, modes) — small enough for CI, big enough that the
    # contraction dominates the traced graph
    "dense-2d": (4, 32, 32, (12, 12)),
    "dense-3d": (2, 16, 16, (6, 6, 6)),
    "cp-2d": (4, 32, 32, (12, 12)),
}

FUSED_CASES = {
    # name: (B, I, O, spatial, modes) — the whole spectral layer, not
    # just the contraction.  fused-2d matches the tune CLI's default
    # spectral_fused key so a calibration state from `tune` covers it.
    "fused-2d": (4, 16, 16, (24, 24), (6, 6)),
    "fused-1d": (4, 16, 16, (48,), (9,)),
}


def _randc(rng, shape, scale=0.5):
    return jnp.asarray(
        scale * (rng.randn(*shape) + 1j * rng.randn(*shape)), jnp.complex64)


def _temp_bytes(fn, *args) -> int:
    mem = jax.jit(fn).lower(*args).compile().memory_analysis()
    return int(getattr(mem, "temp_size_in_bytes", 0) or 0)


def bench_case(name: str, policy_name: str, seed: int = 0,
               tuned_leg: bool = False) -> dict:
    B, I, O, modes = CASES[name]
    kind = name.split("-")[0]
    ndim = len(modes)
    policy = get_policy(policy_name)
    site = policy.at("fno/layer0/spectral/contract")
    rng = np.random.RandomState(seed)
    x = _randc(rng, (B, I, *modes))
    M = int(np.prod(modes))
    # the same tile the production wrapper auto-picks (block_m=None):
    # dense vs CP working-set model, at the policy's storage itemsize
    half = site.spectral_dtype or jnp.float32
    itemsize = jnp.dtype(half).itemsize
    R = I
    if kind == "dense":
        block_m = pick_block_m(B, I, O, M, itemsize=itemsize)
    else:
        block_m = pick_block_m(B, I, O, M, rank=R, itemsize=itemsize)

    if kind == "dense":
        w = _randc(rng, (I, O, *modes))
        operands = (w,)
        expr = _dense_expr(ndim)

        def pallas_loss_at(block):
            def loss(x, *ws):
                y = ops.spectral_contract(x, ws[0], policy=site,
                                          block_m=block)
                return _abs2(y)
            return loss

        vmem = {"fwd": vmem_bytes(B, I, O, block_m),
                "bwd": vmem_bytes_bwd(B, I, O, block_m)}
        traffic_shape = (B, I, O, M)
    else:
        operands = (_randc(rng, (R,)), _randc(rng, (I, R)),
                    _randc(rng, (O, R)),
                    *[_randc(rng, (m, R)) for m in modes])
        expr = _cp_exprs(ndim)

        def pallas_loss_at(block):
            def loss(x, *ws):
                y = ops.spectral_contract_cp(x, ws[0], ws[1], ws[2],
                                             list(ws[3:]), policy=site,
                                             block_m=block)
                return _abs2(y)
            return loss

        vmem = {"fwd": cp_vmem_bytes(B, I, O, R, block_m),
                "bwd": cp_vmem_bytes(B, I, O, R, block_m)}
        traffic_shape = (B, I, O, R, M)
    pallas_loss = pallas_loss_at(block_m)

    def _abs2(y):
        if hasattr(y, "abs2"):
            return jnp.sum(y.abs2())
        return jnp.sum(jnp.abs(y) ** 2)

    def einsum_loss(x, *ws):
        return _abs2(site.contract(expr, x, *ws))

    row = {
        "case": name, "policy": policy_name,
        "B": B, "I": I, "O": O, "modes": list(modes),
        "block_m": block_m, "vmem_bytes": vmem,
        "interpret": jax.default_backend() != "tpu",
    }
    # HBM traffic model for one fwd+bwd step (repro.tune's bytes-moved
    # model at the policy's storage itemsize) — normalises walls into
    # achieved GB/s and a roofline-bandwidth fraction per row
    dtype_name = jnp.dtype(half).name
    moved = bytes_moved(kind, traffic_shape, dtype_name)
    row["bytes_moved"] = moved

    legs = [("einsum", einsum_loss), ("pallas", pallas_loss)]
    if tuned_leg:
        # tuned leg: block_m=None routes tile resolution through the
        # active calibration cache (heuristic fallback per miss).  Reset
        # the trace-time tile counters first so row["tiles"] reports
        # this case's resolutions, not the process's accumulated total.
        legs.append(("pallas_tuned", pallas_loss_at(None)))
        ops.reset_tile_resolution_stats()
    for label, loss in legs:
        fwd = jax.jit(loss)
        bwd = jax.jit(jax.value_and_grad(loss, argnums=(0,)))
        entry = {
            "fwd_us": time_fn(fwd, x, *operands),
            "fwd_bwd_us": time_fn(bwd, x, *operands),
            "fwd_temp_bytes": _temp_bytes(loss, x, *operands),
            "fwd_bwd_temp_bytes": _temp_bytes(
                jax.value_and_grad(loss, argnums=(0,)), x, *operands),
        }
        if label != "einsum":
            gbps = moved / (entry["fwd_bwd_us"] * 1e-6) / 1e9
            entry["gbps"] = round(gbps, 3)
            entry["roofline_fraction"] = round(gbps / (HBM_BW / 1e9), 6)
        row[label] = entry
    row["pallas_over_einsum_wall"] = round(
        row["pallas"]["fwd_bwd_us"] / max(row["einsum"]["fwd_bwd_us"], 1e-9), 3)
    if tuned_leg:
        row["tiles"] = ops.tile_resolution_stats()
        row["tuned_over_heuristic_wall"] = round(
            row["pallas_tuned"]["fwd_bwd_us"]
            / max(row["pallas"]["fwd_bwd_us"], 1e-9), 3)
    return row


def bench_fused_case(name: str, policy_name: str, seed: int = 0) -> dict:
    """The spectral megakernel vs the 3-stage path, whole-layer legs:
    ``einsum`` (no Pallas anywhere), ``staged`` (Pallas contraction,
    HBM-resident spectrum) and ``fused`` (one grid, spectrum in VMEM).
    Walls + compiled temp bytes per leg, plus the tune traffic model's
    HBM bytes for both pipelines — the fused pipeline must move strictly
    fewer bytes at every benchmarked shape."""
    from repro.core.spectral import init_spectral_weights, spectral_conv_apply
    from repro.kernels.spectral_contract import pick_block_b

    B, I, O, spatial, modes = FUSED_CASES[name]
    policy = get_policy(policy_name)
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(B, I, *spatial), jnp.float32)
    params = init_spectral_weights(jax.random.PRNGKey(seed), I, O, modes,
                                   "dense")
    block_b = pick_block_b(B, I, O, spatial, modes)

    def loss_at(use_pallas, fuse):
        def loss(x, params):
            y = spectral_conv_apply(
                params, x, modes, policy, use_pallas=use_pallas,
                fuse_spectral=fuse, site="fno/layer0/spectral")
            return jnp.sum(y.astype(jnp.float32) ** 2)
        return loss

    traffic_shape = (B, I, O, *spatial, *modes)
    moved = {
        "fused": bytes_moved("spectral_fused", traffic_shape, "float32"),
        "staged": bytes_moved("spectral_staged", traffic_shape, "float32"),
    }
    assert moved["fused"] < moved["staged"], (
        "the megakernel must move strictly fewer HBM bytes", name, moved)

    row = {
        "case": name, "policy": policy_name,
        "B": B, "I": I, "O": O, "spatial": list(spatial),
        "modes": list(modes), "block_b": block_b,
        "bytes_moved": moved,
        "interpret": jax.default_backend() != "tpu",
    }
    legs = [("einsum", loss_at(False, False)),
            ("staged", loss_at(True, False)),
            ("fused", loss_at(True, True))]
    for label, loss in legs:
        fwd = jax.jit(loss)
        bwd = jax.jit(jax.value_and_grad(loss, argnums=(0, 1)))
        entry = {
            "fwd_us": time_fn(fwd, x, params),
            "fwd_bwd_us": time_fn(bwd, x, params),
            "fwd_temp_bytes": _temp_bytes(loss, x, params),
            "fwd_bwd_temp_bytes": _temp_bytes(
                jax.value_and_grad(loss, argnums=(0, 1)), x, params),
        }
        if label != "einsum":
            traffic = moved["fused"] if label == "fused" else moved["staged"]
            gbps = traffic / (entry["fwd_bwd_us"] * 1e-6) / 1e9
            entry["gbps"] = round(gbps, 3)
            entry["roofline_fraction"] = round(gbps / (HBM_BW / 1e9), 6)
        row[label] = entry
    row["fused_over_staged_wall"] = round(
        row["fused"]["fwd_bwd_us"] / max(row["staged"]["fwd_bwd_us"], 1e-9), 3)
    row["fused_over_staged_hbm_bytes"] = round(
        moved["fused"] / moved["staged"], 4)
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", nargs="*",
                    default=["full", "mixed_fno_bf16"])
    ap.add_argument("--case", nargs="*",
                    default=sorted(CASES) + sorted(FUSED_CASES))
    ap.add_argument("--calibration-state", default=None,
                    help="activate a repro.tune state and add a tuned-"
                         "tiles comparison leg per row")
    args = ap.parse_args()

    tuned_leg = args.calibration_state is not None
    if tuned_leg:
        from repro.tune.cache import activate

        activate(args.calibration_state)

    rows = []
    print(f"== bench_kernels (backend={jax.default_backend()}) ==")
    print(f"{'case':>10s} {'policy':>16s} {'einsum f+b us':>14s} "
          f"{'pallas f+b us':>14s} {'ratio':>7s} {'GB/s':>7s} "
          f"{'temp MiB e/p':>14s}")
    for case in args.case:
        for pol in args.policy:
            if case in FUSED_CASES:
                row = bench_fused_case(case, pol)
                rows.append(row)
                print(f"{case:>10s} {pol:>16s} "
                      f"{row['staged']['fwd_bwd_us']:>14.0f} "
                      f"{row['fused']['fwd_bwd_us']:>14.0f} "
                      f"{row['fused_over_staged_wall']:>7.2f} "
                      f"{row['fused']['gbps']:>7.2f} "
                      f"{row['staged']['fwd_bwd_temp_bytes'] / 2**20:>6.1f}/"
                      f"{row['fused']['fwd_bwd_temp_bytes'] / 2**20:<6.1f}"
                      f"  (hbm bytes x"
                      f"{row['fused_over_staged_hbm_bytes']:.2f})")
                continue
            row = bench_case(case, pol, tuned_leg=tuned_leg)
            rows.append(row)
            print(f"{case:>10s} {pol:>16s} "
                  f"{row['einsum']['fwd_bwd_us']:>14.0f} "
                  f"{row['pallas']['fwd_bwd_us']:>14.0f} "
                  f"{row['pallas_over_einsum_wall']:>7.2f} "
                  f"{row['pallas']['gbps']:>7.2f} "
                  f"{row['einsum']['fwd_bwd_temp_bytes'] / 2**20:>6.1f}/"
                  f"{row['pallas']['fwd_bwd_temp_bytes'] / 2**20:<6.1f}")
            if tuned_leg:
                print(f"{'':>10s} {'(tuned tiles)':>16s} {'':>14s} "
                      f"{row['pallas_tuned']['fwd_bwd_us']:>14.0f} "
                      f"{row['tuned_over_heuristic_wall']:>7.2f} "
                      f"{row['pallas_tuned']['gbps']:>7.2f}")

    report = {"backend": jax.default_backend(),
              "calibration_state": args.calibration_state, "rows": rows}
    write_result(RESULTS, report)
    print(f"results -> {RESULTS}")


if __name__ == "__main__":
    main()
