"""Structured numerics-event stream: precision causality on the same
timeline as performance.

The paper's claim is a *joint* statement — precision error stays inside
the Thm 3.1/3.2 budget **while** memory and throughput improve — so the
events that justify a precision decision must interleave with the spans
that measure its cost.  ``numerics_event`` is the one funnel: every
emission bumps ``repro_numerics_events_total{kind=...}`` in the metrics
registry (always on, cheap) and, when tracing is enabled, records an
instant event in the trace ring under the ``numerics`` category so the
Chrome export shows it on the run timeline.

Event kinds (the stable vocabulary; attrs carry the numbers that
justified the decision):

  ``autoprec_demote``    controller demoted a site group — attrs carry
                         the ε budget, the decayed-peak amax, and the
                         candidate format's ε (Thm 3.2 vs Thm 3.1);
  ``autoprec_promote``   overflow streak promoted a group back to fp32;
  ``overflow_streak``    a telemetry window saw overflows at a group;
  ``loss_scale_halved``  non-finite grads halved the dynamic loss scale;
  ``loss_scale_grown``   the growth interval raised it back;
  ``tile_cache_hit`` / ``tile_cache_miss`` / ``tile_cache_stale``
                         calibration-cache lookup outcomes at kernel
                         tile resolution (trace time);
  ``oracle_reject``      a tuned tile candidate failed the einsum
                         oracle's Thm 3.2 gate;
  ``nonfinite_logits``   a serve engine observed non-finite logits rows.
"""
from __future__ import annotations

from typing import Optional

from . import trace
from .metrics import registry

KINDS = (
    "autoprec_demote",
    "autoprec_promote",
    "overflow_streak",
    "loss_scale_halved",
    "loss_scale_grown",
    "tile_cache_hit",
    "tile_cache_miss",
    "tile_cache_stale",
    "oracle_reject",
    "nonfinite_logits",
)

EVENT_CATEGORY = "numerics"


def numerics_event(kind: str, site: Optional[str] = None, **attrs) -> None:
    """Record one numerics event: counter always, trace event when the
    ring is enabled.  ``site`` is the precision-site / control-group
    address the event attributes to (same address space as the rule
    tables)."""
    if kind not in KINDS:
        raise ValueError(f"unknown numerics event kind {kind!r}; "
                         f"known: {KINDS}")
    registry().counter("repro_numerics_events_total", kind=kind).inc()
    if trace.is_enabled():
        if site is not None:
            attrs["site"] = site
        trace.event(f"numerics/{kind}", category=EVENT_CATEGORY, **attrs)


# -- wiring helpers (keep call sites one-liners) ----------------------------


def autoprec_decision(group: str, old_fmt: str, new_fmt: str, *,
                      eps_budget: float, amax: float,
                      fmt_eps: Optional[float] = None,
                      step: Optional[int] = None) -> None:
    """A controller format change with the budget numbers that justified
    it — the record the acceptance criterion wants visible in Perfetto."""
    kind = ("autoprec_promote" if new_fmt == "float32"
            else "autoprec_demote")
    numerics_event(kind, site=group, from_fmt=old_fmt, to_fmt=new_fmt,
                   eps_budget=eps_budget, amax=amax,
                   **({} if fmt_eps is None else {"fmt_eps": fmt_eps}),
                   **({} if step is None else {"step": step}))


def loss_scale_event(kind: str, scale: float,
                     step: Optional[int] = None) -> None:
    numerics_event(kind, scale=scale,
                   **({} if step is None else {"step": step}))


def tile_cache_event(outcome: str, family: str, key: str) -> None:
    numerics_event(f"tile_cache_{outcome}", family=family, key=key)


def oracle_reject(key: str, *, max_err: float, budget_min: float,
                  worst_excess: float) -> None:
    numerics_event("oracle_reject", key=key, max_err=max_err,
                   budget_min=budget_min, worst_excess=worst_excess)
