"""Trip-count-aware HLO cost parser.

``compiled.cost_analysis()`` counts each while-loop body once (verified in
tests/test_roofline.py), which is useless for scan-over-layers models, so
we parse the post-SPMD HLO text ourselves:

  * per-computation symbol tables (%value -> type) because operand
    references in scheduled HLO are untyped;
  * dot FLOPs = 2·|out|·K with K read from lhs_contracting_dims and the
    lhs operand's recorded shape;
  * HBM bytes at fusion granularity; dynamic-update-slice (and DUS-rooted
    fusions — the scan carry writes) count the updated *slice*, not the
    whole carry buffer (XLA updates in place);
  * collective bytes = output bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute;
  * while bodies scale by the loop trip count extracted from the largest
    sane comparison constant in the loop condition.

Everything is per-device (the HLO is the per-device SPMD program).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(
    r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)\[([\d,]*)\]"
)
COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")
_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "while", "conditional",
    "copy-start", "copy-done", "get-dimension-size", "add-dependency",
    "opt-barrier",
}
_MAX_SANE_TRIPS = 1_000_000


def _type_bytes(typ: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(typ):
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[m.group(1)]
    return total


def _type_dims(typ: str) -> List[int]:
    m = _SHAPE_RE.search(typ)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class _Op:
    name: str
    typ: str
    op: str
    operands: List[str]
    line: str


def _parse_def(ln: str) -> Optional[_Op]:
    m = re.match(r"\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$", ln)
    if not m:
        return None
    name, rest = m.group(1), m.group(2).strip()
    if rest.startswith("("):
        depth = 0
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        typ, rest2 = rest[: end + 1], rest[end + 1:].strip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        typ, rest2 = rest[:sp], rest[sp + 1:].strip()
    mo = re.match(r"([\w\-]+)\(", rest2)
    if not mo:
        return None
    op = mo.group(1)
    # operand names inside the op's balanced parens
    depth = 0
    start = rest2.find("(")
    end = start
    for i in range(start, len(rest2)):
        if rest2[i] == "(":
            depth += 1
        elif rest2[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    operands = re.findall(r"%([\w\.\-]+)", rest2[start:end + 1])
    return _Op(name=name, typ=typ, op=op, operands=operands, line=ln)


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[_Op]
    sym: Dict[str, str]          # value name -> type string
    root: Optional[_Op]


def split_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[str] = None
    ops: List[_Op] = []
    root: Optional[_Op] = None
    for raw in hlo.splitlines():
        bare = raw.strip()
        if bare.endswith("{") and "(" in bare and ("->" in bare or bare.startswith("ENTRY")):
            toks = bare.split()
            nm = toks[1] if bare.startswith("ENTRY") else toks[0]
            current = nm.lstrip("%").split("(")[0]
            ops, root = [], None
            continue
        if bare == "}":
            if current is not None:
                comps[current] = Computation(
                    name=current, ops=ops,
                    sym={o.name: o.typ for o in ops}, root=root,
                )
            current = None
            continue
        if current is None or not bare:
            continue
        o = _parse_def(bare)
        if o is not None:
            ops.append(o)
            if bare.lstrip().startswith("ROOT"):
                root = o
    return comps


def entry_name(hlo: str) -> Optional[str]:
    for ln in hlo.splitlines():
        if ln.startswith("ENTRY"):
            return ln.split()[1].lstrip("%").split("(")[0]
    return None


def _trip_count(cond: Computation) -> int:
    best = 1
    for o in cond.ops:
        for m in re.finditer(r"constant\((\d+)\)", o.line):
            v = int(m.group(1))
            if 1 < v <= _MAX_SANE_TRIPS:
                best = max(best, v)
    return best


@dataclasses.dataclass
class HLOCounts:
    flops: float = 0.0
    bytes: float = 0.0
    collective_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def collective_bytes(self) -> float:
        return sum(self.collective_by_kind.values())

    def scaled(self, k: float) -> "HLOCounts":
        return HLOCounts(self.flops * k, self.bytes * k,
                         {kk: v * k for kk, v in self.collective_by_kind.items()})

    def __add__(self, o: "HLOCounts") -> "HLOCounts":
        d = dict(self.collective_by_kind)
        for k, v in o.collective_by_kind.items():
            d[k] = d.get(k, 0.0) + v
        return HLOCounts(self.flops + o.flops, self.bytes + o.bytes, d)


def _dot_flops(o: _Op, sym: Dict[str, str]) -> float:
    out_n = 1
    for d in _type_dims(o.typ):
        out_n *= d
    mcd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", o.line)
    if mcd is None or not o.operands:
        return 0.0
    lhs_typ = sym.get(o.operands[0], "")
    lhs_dims = _type_dims(lhs_typ)
    K = 1
    for idx in [int(x) for x in mcd.group(1).split(",") if x]:
        if idx < len(lhs_dims):
            K *= lhs_dims[idx]
    return 2.0 * out_n * K


def _fusion_target(o: _Op) -> Optional[str]:
    m = re.search(r"calls=%?([\w\.\-]+)", o.line)
    return m.group(1) if m else None


def _op_bytes(o: _Op, sym: Dict[str, str], comps: Dict[str, Computation]) -> float:
    """HBM traffic of one scheduled op."""
    if o.op in _FREE_OPS:
        return 0.0
    out_b = _type_bytes(o.typ)
    opnd_b = sum(_type_bytes(sym.get(n, "")) for n in o.operands)
    if o.op == "dynamic-update-slice":
        # in-place slice write: read+write the update slice only
        upd = _type_bytes(sym.get(o.operands[1], "")) if len(o.operands) > 1 else 0
        return 2.0 * upd
    if o.op == "scatter":
        # in-place scatter (KV-cache writes): traffic = updates + indices,
        # not the whole buffer
        upd = _type_bytes(sym.get(o.operands[2], "")) if len(o.operands) > 2 else 0
        idx = _type_bytes(sym.get(o.operands[1], "")) if len(o.operands) > 1 else 0
        return 2.0 * upd + idx
    if o.op == "fusion":
        callee = _fusion_target(o)
        c = comps.get(callee) if callee else None
        if c is not None and c.root is not None and c.root.op in (
                "dynamic-update-slice", "scatter"):
            # in-place-rooted fusion (scan carry / cache write): buffer is
            # aliased; traffic = non-buffer operands + 2x the update.
            upd_operand_idx = 1 if c.root.op == "dynamic-update-slice" else 2
            upd = (_type_bytes(c.sym.get(c.root.operands[upd_operand_idx], ""))
                   if len(c.root.operands) > upd_operand_idx else 0)
            non_buffer = sum(
                _type_bytes(sym.get(n, "")) for n in o.operands
                if _type_bytes(sym.get(n, "")) != out_b
            )
            return non_buffer + 2.0 * upd
        return out_b + opnd_b
    if o.op == "dynamic-slice":
        return 2.0 * out_b
    return out_b + opnd_b


def parse_hlo(hlo: str) -> HLOCounts:
    comps = split_computations(hlo)

    direct: Dict[str, HLOCounts] = {}
    whiles: Dict[str, List[Tuple[str, str]]] = {}
    flop_calls: Dict[str, List[str]] = {}
    for name, comp in comps.items():
        c = HLOCounts(collective_by_kind={})
        wl: List[Tuple[str, str]] = []
        fl: List[str] = []
        for o in comp.ops:
            base = o.op.replace("-start", "")
            if base in COLLECTIVE_OPS:
                c.collective_by_kind[base] = (
                    c.collective_by_kind.get(base, 0.0) + _type_bytes(o.typ))
                continue
            if o.op.endswith("-done"):
                continue
            if o.op == "dot":
                c.flops += _dot_flops(o, comp.sym)
            c.bytes += _op_bytes(o, comp.sym, comps)
            if o.op == "while":
                mc = re.search(r"condition=%?([\w\.\-]+)", o.line)
                mb = re.search(r"body=%?([\w\.\-]+)", o.line)
                if mc and mb:
                    wl.append((mb.group(1), mc.group(1)))
            if o.op == "fusion":
                tgt = _fusion_target(o)
                if tgt:
                    fl.append(tgt)
            for m in re.finditer(r"to_apply=%?([\w\.\-]+)", o.line):
                fl.append(m.group(1))
        direct[name] = c
        whiles[name] = wl
        flop_calls[name] = fl

    memo: Dict[str, HLOCounts] = {}

    def total(name: str, depth: int = 0) -> HLOCounts:
        if name not in direct or depth > 64:
            return HLOCounts(collective_by_kind={})
        if name in memo:
            return memo[name]
        acc = HLOCounts(direct[name].flops, direct[name].bytes,
                        dict(direct[name].collective_by_kind))
        for callee in flop_calls[name]:
            sub = total(callee, depth + 1)
            acc.flops += sub.flops      # fusion internals: flops only
            for k, v in sub.collective_by_kind.items():
                acc.collective_by_kind[k] = acc.collective_by_kind.get(k, 0.0) + v
        for body, cond in whiles[name]:
            trips = _trip_count(comps[cond]) if cond in comps else 1
            acc = acc + total(body, depth + 1).scaled(trips)
        memo[name] = acc
        return acc

    entry = entry_name(hlo)
    if entry is None:
        out = HLOCounts(collective_by_kind={})
        for c in direct.values():
            out = out + c
        return out
    return total(entry)
