"""U-Net baseline (paper Section 4.5 / Table 2).

A standard 2-D conv U-Net used as the non-operator PDE surrogate baseline.
Kept deliberately conventional so the comparison isolates the operator-vs-
CNN question, as in the paper: FNO beats U-Net on error, and the paper's
mixed-precision FNO saves more memory than AMP-on-U-Net.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import PrecisionPolicy, FULL


@dataclasses.dataclass(frozen=True)
class UNetConfig:
    in_channels: int = 3
    out_channels: int = 1
    base_width: int = 32
    depth: int = 3


def _conv_init(key, cin, cout, k=3):
    scale = (2.0 / (cin * k * k)) ** 0.5
    kw, kb = jax.random.split(key)
    return {
        "w": scale * jax.random.normal(kw, (cout, cin, k, k), jnp.float32),
        "b": jnp.zeros((cout,), jnp.float32),
    }


def _conv(p, x, dtype, stride=1):
    y = jax.lax.conv_general_dilated(
        x.astype(dtype),
        p["w"].astype(dtype),
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return y + p["b"].astype(dtype)[None, :, None, None]


def init_unet(key: jax.Array, cfg: UNetConfig) -> dict:
    params = {"enc": [], "dec": []}
    w = cfg.base_width
    keys = jax.random.split(key, 4 * cfg.depth + 4)
    ki = iter(range(len(keys)))
    cin = cfg.in_channels
    enc = []
    width = w
    for _ in range(cfg.depth):
        enc.append(
            {
                "c1": _conv_init(keys[next(ki)], cin, width),
                "c2": _conv_init(keys[next(ki)], width, width),
            }
        )
        cin = width
        width *= 2
    params["enc"] = enc
    params["mid1"] = _conv_init(keys[next(ki)], cin, width)
    params["mid2"] = _conv_init(keys[next(ki)], width, cin)
    dec = []
    for d in range(cfg.depth):
        width = cin // (2 ** d)
        dec.append(
            {
                "c1": _conv_init(keys[next(ki)], width * 2, width),
                "c2": _conv_init(keys[next(ki)], width, max(width // 2, cfg.base_width)),
            }
        )
    params["dec"] = dec
    params["head"] = _conv_init(keys[next(ki)], max(width // 2, cfg.base_width), cfg.out_channels, k=1)
    return params


def unet_apply(
    params: dict, x: jnp.ndarray, cfg: UNetConfig, policy: PrecisionPolicy = FULL
) -> jnp.ndarray:
    """x: (B, C, H, W) -> (B, out, H, W).  H, W must be divisible by 2^depth."""
    if x.shape[-2] % (1 << cfg.depth) or x.shape[-1] % (1 << cfg.depth):
        raise ValueError(
            f"spatial dims {x.shape[-2:]} not divisible by 2^{cfg.depth}")
    cdt = policy.at("unet/dense").compute_dtype
    head_dt = policy.at("unet/proj_out").compute_dtype
    h = x.astype(cdt)
    skips = []
    for blk in params["enc"]:
        h = jax.nn.gelu(_conv(blk["c1"], h, cdt))
        h = jax.nn.gelu(_conv(blk["c2"], h, cdt))
        skips.append(h)
        h = jax.lax.reduce_window(
            h, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
        )
    h = jax.nn.gelu(_conv(params["mid1"], h, cdt))
    h = jax.nn.gelu(_conv(params["mid2"], h, cdt))
    for blk, skip in zip(params["dec"], reversed(skips), strict=True):
        B, C, H, W = h.shape
        h = jax.image.resize(h, (B, C, H * 2, W * 2), "nearest")
        h = jnp.concatenate([h, skip.astype(cdt)], axis=1)
        h = jax.nn.gelu(_conv(blk["c1"], h, cdt))
        h = jax.nn.gelu(_conv(blk["c2"], h, cdt))
    return _conv(params["head"], h.astype(head_dt), head_dt)
