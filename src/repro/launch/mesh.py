"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialisation.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ("data", "model").
    Multi-pod: 2x16x16 = 512 chips ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int = 1):
    """Tiny mesh over whatever devices exist (tests)."""
    devs = jax.devices()[:n_devices]
    import numpy as np
    from jax.sharding import Mesh

    return Mesh(np.array(devs).reshape(1, len(devs)), ("data", "model"))
