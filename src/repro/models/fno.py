"""FNO / TFNO models (Li et al. 2021; Kossaifi et al. 2023) with the
mixed-precision spectral pipeline as a first-class feature.

Architecture (matches the neuraloperator reference):
  lifting MLP  ->  n_layers x [ SpectralConv + (1x1 conv skip) + GELU ]
               ->  projection MLP
Per-layer weights are stacked on a leading axis and the block loop runs
under ``lax.scan`` so the HLO stays one-layer-sized (critical for the
512-device dry-run compile times and for remat).

Precision is site-addressed: dense (real) ops resolve ``fno/dense`` /
``fno/layer<i>/dense`` (the AMP set), the spectral pipeline resolves
``fno/layer<i>/spectral/{fft_in,contract,fft_out}``, and the output head
``fno/proj_out`` (f32 by default); parameters are f32 masters.  When a
``precision_rules(...)`` override makes layers heterogeneous (e.g. the
last layer pinned to full precision), the block loop automatically
unrolls instead of scanning so each layer can compile at its own
formats.
"""
from __future__ import annotations

import dataclasses
import operator
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import PrecisionPolicy, FULL
from repro.core.spectral import init_spectral_weights, spectral_conv_apply
from repro.dist.constrain import constrain_spatial


@dataclasses.dataclass(frozen=True)
class FNOConfig:
    in_channels: int = 3
    out_channels: int = 1
    hidden_channels: int = 64
    lifting_channels: int = 256
    projection_channels: int = 256
    n_layers: int = 4
    modes: Tuple[int, ...] = (16, 16)
    factorization: str = "dense"  # dense | cp | tucker  (TFNO = cp/tucker)
    rank: float = 0.5
    #: Tri-state: None = auto (Pallas kernels on TPU backends and under
    #: REPRO_USE_PALLAS=1, einsum elsewhere); True/False force it.
    use_pallas: Optional[bool] = None
    #: Tri-state: None = auto (the one-grid rFFT→contract→irFFT megakernel
    #: whenever the Pallas path is on and the dense layer shape/policy is
    #: viable; REPRO_FUSE_SPECTRAL=0 kills it); True/False force it.
    fuse_spectral: Optional[bool] = None
    positional_embedding: bool = True  # append normalised grid coords

    @property
    def ndim(self) -> int:
        return len(self.modes)


def _linear_init(key, d_in, d_out):
    scale = (1.0 / d_in) ** 0.5
    kw, kb = jax.random.split(key)
    return {
        "w": scale * jax.random.normal(kw, (d_in, d_out), jnp.float32),
        "b": jnp.zeros((d_out,), jnp.float32),
    }


def _linear(p, x, dtype):
    # channel-last contraction; x: (..., d_in)
    return (
        jnp.einsum("...i,io->...o", x.astype(dtype), p["w"].astype(dtype))
        + p["b"].astype(dtype)
    )


def init_fno(key: jax.Array, cfg: FNOConfig) -> dict:
    keys = jax.random.split(key, 6)
    in_ch = cfg.in_channels + (cfg.ndim if cfg.positional_embedding else 0)
    params = {
        "lift1": _linear_init(keys[0], in_ch, cfg.lifting_channels),
        "lift2": _linear_init(keys[1], cfg.lifting_channels, cfg.hidden_channels),
        "proj1": _linear_init(keys[2], cfg.hidden_channels, cfg.projection_channels),
        "proj2": _linear_init(keys[3], cfg.projection_channels, cfg.out_channels),
    }
    # stacked per-layer spectral weights: vmap the initialiser over layers
    layer_keys = jax.random.split(keys[4], cfg.n_layers)
    spect = [
        init_spectral_weights(
            k, cfg.hidden_channels, cfg.hidden_channels, cfg.modes,
            cfg.factorization, cfg.rank,
        )
        for k in layer_keys
    ]
    params["spectral"] = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *spect)
    skip_keys = jax.random.split(keys[5], cfg.n_layers)
    skips = [
        _linear_init(k, cfg.hidden_channels, cfg.hidden_channels) for k in skip_keys
    ]
    params["skips"] = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *skips)
    return params


def _positional_grid(spatial: Sequence[int], dtype) -> jnp.ndarray:
    axes = [jnp.linspace(0.0, 1.0, s, dtype=jnp.float32) for s in spatial]
    grids = jnp.meshgrid(*axes, indexing="ij")
    return jnp.stack(grids, axis=0).astype(dtype)  # (ndim, *spatial)


def _layer_sites(policy: PrecisionPolicy, model: str, layer: int):
    """The resolved precision of one block layer (dense + spectral stages)."""
    base = f"{model}/layer{layer}"
    return (
        policy.at(f"{base}/dense"),
        policy.at(f"{base}/spectral/fft_in"),
        policy.at(f"{base}/spectral/contract"),
        policy.at(f"{base}/spectral/fft_out"),
    )


def layers_uniform(policy: PrecisionPolicy, model: str, n_layers: int) -> bool:
    """True when every layer resolves to the same formats, so the block
    loop can run as one ``lax.scan``; per-layer ``precision_rules``
    overrides make this False and the caller unrolls instead."""
    first = _layer_sites(policy, model, 0)
    return all(_layer_sites(policy, model, l) == first for l in range(1, n_layers))


def apply_block_loop(block, h, stacked, policy: PrecisionPolicy, model: str,
                     n_layers: int):
    """Run ``block(h, layer_params, layer_idx)`` over a stacked layer pytree.

    One ``lax.scan`` when every layer resolves to the same formats (HLO
    stays one-layer-sized); an unrolled loop when per-layer
    ``precision_rules`` make the layers heterogeneous, so each layer
    lowers at its own formats.  Shared by the FNO and SFNO block loops.

    Also unrolls while an autoprec telemetry collector is in scope: taps
    inside a scan body would be invisible to the outer trace, and the
    controller needs each ``<model>/layer<i>/spectral/*`` site reported
    under its own address.
    """
    from repro.autoprec.telemetry import telemetry_active

    if layers_uniform(policy, model, n_layers) and not telemetry_active():
        h, _ = jax.lax.scan(lambda c, lp: (block(c, lp, 0), None), h, stacked)
        return h
    for l in range(n_layers):
        lp = jax.tree_util.tree_map(operator.itemgetter(l), stacked)
        h = block(h, lp, l)
    return h


def fno_apply(
    params: dict,
    x: jnp.ndarray,
    cfg: FNOConfig,
    policy: PrecisionPolicy = FULL,
) -> jnp.ndarray:
    """x: (batch, in_channels, *spatial) -> (batch, out_channels, *spatial)."""
    B = x.shape[0]
    spatial = x.shape[2:]
    cdt = policy.at("fno/dense").compute_dtype

    if cfg.positional_embedding:
        pos = _positional_grid(spatial, x.dtype)
        pos = jnp.broadcast_to(pos[None], (B, cfg.ndim, *spatial))
        x = jnp.concatenate([x, pos], axis=1)

    # lifting (channel-last for the MLPs)
    h = jnp.moveaxis(x, 1, -1)
    h = _linear(params["lift1"], h, cdt)
    h = jax.nn.gelu(h)
    h = _linear(params["lift2"], h, cdt)
    h = jnp.moveaxis(h, -1, 1)  # (B, hidden, *spatial)

    def block(h, layer_params, layer: int):
        # Full-DP layout: at FNO sizes (~2-50M params) the weights are tiny,
        # so shard batch over EVERY mesh axis and replicate weights — FFTs
        # and contractions become embarrassingly parallel and the only
        # collective left is the gradient all-reduce (§Perf iteration 5:
        # collective term 2.02s -> ~0.04s on tfno-ns).  The layout decision
        # (incl. the channels-over-tp fallback when the batch doesn't cover
        # the mesh) lives in repro.dist, not here.
        h = constrain_spatial(h)
        spect, skip = layer_params
        ldt = policy.at(f"fno/layer{layer}/dense").compute_dtype
        y = spectral_conv_apply(
            spect, h, cfg.modes, policy, use_pallas=cfg.use_pallas,
            fuse_spectral=cfg.fuse_spectral,
            site=f"fno/layer{layer}/spectral",
        ).astype(ldt)
        s = jnp.moveaxis(
            _linear(skip, jnp.moveaxis(h, 1, -1), ldt), -1, 1
        )
        return jax.nn.gelu(y + s)

    h = h.astype(cdt)
    h = apply_block_loop(block, h, (params["spectral"], params["skips"]),
                         policy, "fno", cfg.n_layers)

    # projection
    h = jnp.moveaxis(h, 1, -1)
    h = _linear(params["proj1"], h, cdt)
    h = jax.nn.gelu(h)
    h = _linear(params["proj2"], h, policy.at("fno/proj_out").compute_dtype)
    return jnp.moveaxis(h, -1, 1)


def fno_infer(
    params: dict,
    x: jnp.ndarray,
    cfg: FNOConfig,
    policy: PrecisionPolicy = FULL,
) -> jnp.ndarray:
    """Batched-inference entry point for serving.

    x: (batch, in_channels, *spatial) -> (batch, out_channels, *spatial),
    cast to the ``serve/operator`` site's transport dtype (f32 in the
    base table).  Every op in the forward is per-sample independent
    (batched GEMMs, FFTs, pointwise), so the operator engine's
    micro-batching is bit-identical to serving each field alone under
    the same precision policy.
    """
    y = fno_apply(params, x, cfg, policy)
    return y.astype(policy.at("serve/operator").compute_dtype)


def param_count(params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))
