"""repro.autoprec tests: telemetry taps (eager, jitted, under grad and
microbatch scan), the bound-guided controller's demote/promote
hysteresis, auto-precision training (incl. loss-scale composition), the
serving engines' numerics counters/online control, and certification."""
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np

from repro.autoprec import (
    AutoPrecisionController,
    SiteWindow,
    TelemetryAggregator,
    TraceCollector,
    collecting,
    group_of,
    tap,
    telemetry_active,
)
from repro.autoprec.telemetry import site_stats
from repro.core import PrecisionSchedule
from repro.core.precision import FORMAT_EPS
from repro.models import FNOConfig, fno_apply, init_fno
from repro.optim import init_loss_scale, update_loss_scale
from repro.precision import get_policy
from repro.train import Trainer, TrainerConfig, relative_l2

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------


class TestSiteStats:
    def test_amax_and_counts(self):
        x = jnp.asarray([0.5, -2.0, 1e5, 1e-6, 0.0], jnp.float32)
        s = site_stats(x, fmt="float16", hist_stride=1)
        assert float(s.amax) == 1e5
        assert float(s.overflow) == 1.0       # 1e5 > 65504
        assert float(s.underflow) == 1.0      # 1e-6 below fp16 tiny, 0 exempt
        assert float(s.n) == 5.0
        assert float(s.hist.sum()) == 4.0     # non-zero values only

    def test_nonfinite_counts_as_overflow(self):
        x = jnp.asarray([1.0, jnp.inf, jnp.nan], jnp.float32)
        s = site_stats(x, fmt="float32", hist_stride=1)
        assert float(s.overflow) == 2.0

    def test_qerr_measures_quantisation(self):
        x = jnp.asarray(np.random.RandomState(0).randn(256), jnp.float32)
        q = x.astype(jnp.bfloat16).astype(jnp.float32)
        s = site_stats(x, fmt="bfloat16", quantized=q, hist_stride=1)
        # measured error under the per-value representation bound eps*amax
        assert 0.0 < float(s.qerr) <= FORMAT_EPS["bfloat16"] * float(s.amax)

    def test_complex_split_real_components(self):
        c = jnp.asarray([1.0 + 2.0j, -3.0 + 0.5j], jnp.complex64)
        s = site_stats(c, hist_stride=1)
        assert float(s.n) == 4.0              # re+im components
        assert float(s.amax) == 3.0


class TestCollector:
    def test_tap_noop_without_collector(self):
        assert not telemetry_active()
        tap("some/site", jnp.ones(3))  # must not raise, records nothing

    def test_repeated_taps_merge(self):
        col = TraceCollector(hist_stride=1)
        with collecting(col):
            assert telemetry_active()
            tap("s", jnp.asarray([1.0]), fmt="float32")
            tap("s", jnp.asarray([5.0]), fmt="float32")
        snap = col.snapshot()
        assert float(snap["s"].amax) == 5.0
        assert float(snap["s"].n) == 2.0

    def test_jit_collection_matches_eager(self):
        cfg = FNOConfig(in_channels=1, out_channels=1, hidden_channels=8,
                        lifting_channels=8, projection_channels=8,
                        n_layers=1, modes=(4, 4))
        params = init_fno(jax.random.PRNGKey(0), cfg)
        x = jnp.asarray(np.random.RandomState(0).randn(2, 1, 16, 16),
                        jnp.float32)
        pol = get_policy("mixed_fno_bf16")

        def run(p, x):
            col = TraceCollector()
            with collecting(col):
                y = fno_apply(p, x, cfg, pol)
            return y, col.snapshot()

        y_e, snap_e = run(params, x)
        y_j, snap_j = jax.jit(run)(params, x)
        assert set(snap_e) == set(snap_j)
        for site in snap_e:
            # jit fuses the FFT differently; amax agrees to float noise
            np.testing.assert_allclose(float(snap_e[site].amax),
                                       float(snap_j[site].amax), rtol=1e-3)
        # the spectral sites of the one layer are all addressed
        assert "fno/layer0/spectral/fft_in" in snap_e
        assert "fno/layer0/spectral/contract" in snap_e
        assert "fno/layer0/spectral/fft_out" in snap_e

    def test_aggregator_window_and_totals(self):
        agg = TelemetryAggregator()
        col = TraceCollector(hist_stride=1)
        with collecting(col):
            tap("s", jnp.asarray([2.0]), fmt="float32")
        agg.update(col.snapshot())
        agg.update(col.snapshot())
        assert agg.totals["s"].updates == 2
        w = agg.take_window()
        assert w["s"].updates == 2
        assert agg.window() == {}             # window resets, totals stay
        assert agg.totals["s"].updates == 2
        assert agg.counters()["sites"]["s"]["amax"] == 2.0

    def test_fraction_below(self):
        col = TraceCollector(hist_stride=1)
        with collecting(col):
            tap("s", jnp.asarray([1e-6] * 3 + [1.0] * 7), fmt="float32")
        agg = TelemetryAggregator()
        agg.update(col.snapshot())
        frac = agg.totals["s"].fraction_below(6.1e-5)  # fp16 tiny
        np.testing.assert_allclose(frac, 0.3, atol=0.01)


# ---------------------------------------------------------------------------
# controller
# ---------------------------------------------------------------------------


def _window(amax=10.0, overflow=0.0, n=1000.0):
    w = SiteWindow(updates=1, amax=amax, qerr=0.0, n=n,
                   overflow=overflow, underflow=0.0,
                   overflow_updates=int(overflow > 0))
    # all mass in a healthy exponent bucket
    w.hist[30] = n
    return w


class TestController:
    def test_demotes_after_patience(self):
        ctl = AutoPrecisionController(base="full", grid_points=1024,
                                      demote_patience=2, cooldown=0)
        assert not ctl.update({"fno/layer0/spectral/fft_in": _window()})
        assert ctl.update({"fno/layer0/spectral/fft_in": _window()})
        assert ctl.sites["fno/layer0/spectral"].fmt == "bfloat16"
        assert ctl.policy().name == "full+auto1"
        assert ctl.policy().at("fno/layer0/spectral/contract").spectral_is_half

    def test_budget_tightens_with_grid(self):
        # Thm 3.1: finer grids shrink the disc bound, so the eps ceiling
        # falls below bf16's eps and the controller must pick fp16
        ctl = AutoPrecisionController(base="full", demote_patience=1,
                                      cooldown=0)
        assert ctl.eps_budget(1024) > FORMAT_EPS["bfloat16"]
        assert ctl.eps_budget(262144) < FORMAT_EPS["bfloat16"]
        ctl.update({"fno/layer0/spectral/fft_in": _window()},
                   grid_points=262144)
        assert ctl.sites["fno/layer0/spectral"].fmt == "float16"
        # fp16-family decisions switch dynamic loss scaling on
        assert ctl.policy().at("train/loss_scale").loss_scaling

    def test_range_check_blocks_fp16(self):
        # amax*margin beyond fp16's 65504 => fp16 rejected; at a fine
        # grid where bf16 fails the eps budget, only f32 remains
        ctl = AutoPrecisionController(base="full", demote_patience=1,
                                      cooldown=0, range_margin=4.0)
        ctl.update({"fno/layer0/spectral/fft_in": _window(amax=30000.0)},
                   grid_points=262144)
        assert ctl.sites["fno/layer0/spectral"].fmt == "float32"

    def test_promotes_on_overflow_streak_with_cooldown(self):
        ctl = AutoPrecisionController(base="full", grid_points=1024,
                                      demote_patience=1, promote_streak=2,
                                      cooldown=2)
        site = "fno/layer0/spectral/fft_in"
        ctl.update({site: _window()})
        assert ctl.sites["fno/layer0/spectral"].fmt == "bfloat16"
        assert not ctl.update({site: _window(overflow=5.0)})  # streak 1
        assert ctl.update({site: _window(overflow=5.0)})      # promoted
        assert ctl.sites["fno/layer0/spectral"].fmt == "float32"
        # cooldown: a clean window cannot immediately re-demote
        assert not ctl.update({site: _window()})
        assert ctl.sites["fno/layer0/spectral"].fmt == "float32"

    def test_uncontrolled_sites_ignored(self):
        ctl = AutoPrecisionController(base="full", grid_points=1024,
                                      demote_patience=1, cooldown=0)
        ctl.update({"lm/dense": _window(), "serve/operator": _window()})
        assert ctl.sites == {}
        assert ctl.overlay() == ()

    def test_group_of(self):
        assert group_of("fno/layer3/spectral/fft_in") == "fno/layer3/spectral"
        assert group_of("sfno/layer0/spectral/contract") == "sfno/layer0/spectral"
        assert group_of("serve/kv_cache") == "serve/kv_cache"


# ---------------------------------------------------------------------------
# trainer integration
# ---------------------------------------------------------------------------


def _tiny_problem(n_layers=2, res=16):
    cfg = FNOConfig(in_channels=1, out_channels=1, hidden_channels=8,
                    lifting_channels=8, projection_channels=8,
                    n_layers=n_layers, modes=(4, 4))
    params = init_fno(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(4, 1, res, res), jnp.float32)
    t = jnp.asarray(rng.randn(4, 1, res, res) * 0.1, jnp.float32)

    def loss_fn(p, batch, policy):
        return relative_l2(fno_apply(p, batch["x"], cfg, policy), batch["t"])

    return cfg, params, loss_fn, {"x": x, "t": t}


class TestAutoPrecisionTraining:
    def test_auto_mode_demotes_and_recompiles_once_per_change(self):
        cfg, params, loss_fn, batch = _tiny_problem()
        ctl = AutoPrecisionController(base="full", grid_points=256,
                                      interval=3, demote_patience=1,
                                      cooldown=0)
        tr = Trainer(loss_fn, params,
                     TrainerConfig(total_steps=9, autoprec=ctl))
        hist = tr.run(lambda _s: batch)
        assert np.isfinite([h["loss"] for h in hist]).all()
        assert tr.stats["policy_changes"] == 1
        assert tr.stats["recompiles"] == 2    # full+auto0 and full+auto1
        assert hist[0]["policy"] == "full+auto0"
        assert hist[-1]["policy"] == "full+auto1"
        for i in range(cfg.n_layers):
            assert ctl.sites[f"fno/layer{i}/spectral"].fmt == "bfloat16"
        # telemetry saw every spectral tap site with zero overflows
        counters = tr.telemetry.counters()
        assert counters["overflow_total"] == 0
        assert len(counters["sites"]) == 3 * cfg.n_layers

    def test_schedule_auto_mode_builds_controller(self):
        _, params, loss_fn, batch = _tiny_problem(n_layers=1)
        tr = Trainer(loss_fn, params, TrainerConfig(
            total_steps=2, schedule=PrecisionSchedule.auto("full")))
        tr.run(lambda _s: batch)
        assert tr.controller is not None
        assert tr.controller.base.name == "full"

    def test_microbatch_scan_merges_telemetry(self):
        _, params, loss_fn, batch = _tiny_problem(n_layers=1)
        tr = Trainer(loss_fn, params, TrainerConfig(
            total_steps=2, microbatches=2, telemetry=True))
        tr.run(lambda _s: batch)
        w = tr.telemetry.totals["fno/layer0/spectral/fft_in"]
        # both microbatches' taps merged into each step's stats
        tr1 = Trainer(loss_fn, params, TrainerConfig(
            total_steps=2, microbatches=1, telemetry=True))
        tr1.run(lambda _s: batch)
        w1 = tr1.telemetry.totals["fno/layer0/spectral/fft_in"]
        np.testing.assert_allclose(w.n, w1.n)

    def test_static_training_unaffected(self):
        """No controller, no telemetry: the step signature/behaviour of
        plain schedules is unchanged (loss path identical)."""
        _, params, loss_fn, batch = _tiny_problem(n_layers=1)
        tr = Trainer(loss_fn, params, TrainerConfig(total_steps=3))
        hist = tr.run(lambda _s: batch)
        assert tr.telemetry is None
        assert hist[-1]["loss"] < hist[0]["loss"]


class TestLossScaleComposition:
    """Satellite regression: dynamic scale halves on overflow, recovers
    after the growth interval, and composes with controller-driven
    overlay changes."""

    def test_scale_halves_on_injected_overflow_and_training_recovers(self):
        _, params, loss_fn, batch = _tiny_problem(n_layers=1)
        bad = {"x": batch["x"].at[0, 0, 0, 0].set(jnp.inf), "t": batch["t"]}
        tr = Trainer(loss_fn, params, TrainerConfig(
            total_steps=6,
            schedule=PrecisionSchedule.constant("mixed_fno_fp16")))
        s0 = float(tr.scale_state.scale)
        tr.run(lambda s: bad if s == 2 else batch)
        assert tr.stats["skipped_steps"] == 1
        assert float(tr.scale_state.scale) == s0 * 0.5
        # subsequent steps trained through (finite losses, no divergence)
        assert np.isfinite([h["loss"] for h in tr.history[3:]]).all()

    def test_scale_regrows_after_growth_interval(self):
        s = init_loss_scale(1024.0)
        s = update_loss_scale(s, jnp.asarray(False))      # overflow: halve
        assert float(s.scale) == 512.0
        for _ in range(3):
            s = update_loss_scale(s, jnp.asarray(True), growth_interval=3)
        assert float(s.scale) == 1024.0                   # recovered

    def test_controller_overlay_change_preserves_scale_state(self):
        """A controller demotion to an fp16-family format flips loss
        scaling on mid-run via a recompile; the scale state must carry
        across the step swap instead of resetting."""
        _, params, loss_fn, batch = _tiny_problem(n_layers=1)
        ctl = AutoPrecisionController(
            base="full", grid_points=256, interval=2, demote_patience=1,
            cooldown=0, formats=("float16",))
        tr = Trainer(loss_fn, params, TrainerConfig(
            total_steps=8, autoprec=ctl))
        # age the scale state so a reset would be visible
        tr.scale_state = tr.scale_state._replace(
            scale=jnp.asarray(256.0, jnp.float32))
        hist = tr.run(lambda _s: batch)
        assert ctl.sites["fno/layer0/spectral"].fmt == "float16"
        assert tr.stats["policy_changes"] == 1
        # loss scaling became active (fp16 overlay) and the carried
        # scale kept evolving from 256, not from the 2^15 init
        assert ctl.policy().at("train/loss_scale").loss_scaling
        assert float(tr.scale_state.scale) <= 256.0
        assert tr.stats["skipped_steps"] == 0
        assert np.isfinite([h["loss"] for h in hist]).all()


# ---------------------------------------------------------------------------
# serving integration
# ---------------------------------------------------------------------------


class TestOperatorEngineAutoprec:
    def _engine_parts(self):
        cfg = FNOConfig(in_channels=1, out_channels=1, hidden_channels=8,
                        lifting_channels=8, projection_channels=8,
                        n_layers=1, modes=(4, 4))
        params = init_fno(jax.random.PRNGKey(0), cfg)
        return cfg, params

    def test_telemetry_counters_in_stats(self):
        from repro.serve import FieldRequest, OperatorEngine

        cfg, params = self._engine_parts()
        eng = OperatorEngine(params, cfg, telemetry=True, max_batch=2)
        rng = np.random.RandomState(0)
        for i in range(4):
            eng.submit(FieldRequest(
                uid=i, x=rng.randn(1, 16, 16).astype(np.float32)))
        eng.drain()
        numerics = eng.stats()["numerics"]
        assert numerics["overflow_total"] == 0
        assert "fno/layer0/spectral/fft_in" in numerics["sites"]

    def test_online_controller_retunes_policy(self):
        from repro.serve import FieldRequest, OperatorEngine

        cfg, params = self._engine_parts()
        ctl = AutoPrecisionController(base="full", demote_patience=1,
                                      cooldown=0)
        eng = OperatorEngine(params, cfg, autoprec=ctl, max_batch=2,
                             autoprec_every=2)
        rng = np.random.RandomState(0)
        fields = [rng.randn(1, 16, 16).astype(np.float32) for _ in range(8)]
        for i, x in enumerate(fields):
            eng.submit(FieldRequest(uid=i, x=x))
        done, _ = eng.drain()
        stats = eng.stats()
        assert stats["policy"] == "full+auto1"
        assert stats["autoprec"]["sites"]["fno/layer0/spectral"]["fmt"] == "bfloat16"
        # served fields remain close to the full-precision forward
        from repro.models import fno_infer
        from repro.precision import FULL

        ref = np.asarray(fno_infer(
            params, jnp.asarray(fields[-1])[None], cfg, FULL))[0]
        got = next(r.y for r in done if r.uid == len(fields) - 1)
        np.testing.assert_allclose(np.asarray(got), ref, atol=0.05)

    def test_lm_engine_numerics_counters(self):
        from repro.configs import get_config
        from repro.models.lm import init_lm
        from repro.serve import LMEngine, Request

        cfg = get_config("smollm-360m", smoke=True)
        params = init_lm(jax.random.PRNGKey(0), cfg)
        eng = LMEngine(params, cfg, n_slots=2, max_len=32, telemetry=True)
        for i in range(2):
            eng.submit(Request(uid=i, prompt=[1, 2, 3], max_new_tokens=2))
        eng.drain()
        numerics = eng.stats()["numerics"]
        assert numerics["logits_nonfinite"] == 0
        assert numerics["rows_observed"] > 0
        assert numerics["logits_amax"] > 0.0


# ---------------------------------------------------------------------------
# certification
# ---------------------------------------------------------------------------


class TestPallasPathParity:
    """The Pallas contraction path feeds the same telemetry streams and
    drives the controller to the same demotion decisions as the einsum
    path (the tentpole contract of the training-grade kernel PR)."""

    def test_contract_taps_observed_and_decisions_match(self):
        import dataclasses

        from repro.autoprec.certify import (
            instrumented_apply, sample_inputs, tiny_fno)

        cfg, params = tiny_fno()
        x = sample_inputs("grf", 24, 2)
        decisions, amaxes = {}, {}
        for up in (False, True):
            c = dataclasses.replace(cfg, use_pallas=up)
            ctl = AutoPrecisionController(base="full", grid_points=24 ** 2)
            totals = {}
            for r in range(4):
                _, totals = instrumented_apply(ctl.policy(), c, params, x)
                ctl.update(totals, step=r)
            # every per-layer contract tap is observed on this path
            for layer in range(cfg.n_layers):
                site = f"fno/layer{layer}/spectral/contract"
                assert site in totals, (up, sorted(totals))
                assert totals[site].amax > 0.0
            decisions[up] = {
                g: s["fmt"] for g, s in ctl.describe()["sites"].items()}
            amaxes[up] = {s: w.amax for s, w in totals.items()}
        assert decisions[True] == decisions[False]
        # non-vacuous: the certify harness demotes its spectral groups
        assert any(f != "float32" for f in decisions[True].values())
        # and the measured ranges agree across paths (same stream, not
        # merely the same thresholded outcome)
        for site, a_e in amaxes[False].items():
            a_p = amaxes[True][site]
            assert abs(a_p - a_e) <= 0.05 * (abs(a_e) + 1e-9), site


class TestCertification:
    def test_mixed_bf16_certifies(self):
        from repro.autoprec.certify import certify_policy

        rep = certify_policy(get_policy("mixed_fno_bf16"),
                             resolution=16, batch=2)
        assert rep["all_within"]
        assert len(rep["demoted_sites"]) > 0
        for s in rep["demoted_sites"]:
            row = rep["sites"][s]
            assert row["qerr_measured"] <= row["prec_budget"]
            assert row["overflow"] == 0
        # the headline claim: precision error far below the disc bound
        assert rep["end_to_end"]["prec_fraction_of_disc"] < 0.5

    def test_controller_certifies(self):
        from repro.autoprec.certify import certify_controller

        ctl = AutoPrecisionController(base="full", grid_points=256,
                                      demote_patience=1, cooldown=0)
        rep = certify_controller(ctl, rounds=2, resolution=16, batch=2)
        assert rep["all_within"]
        assert rep["controller"]["version"] >= 1
        assert len(rep["demoted_sites"]) > 0

    def test_dryrun_overhead_helper(self):
        from repro.launch.dryrun import telemetry_overhead

        plain = SimpleNamespace(flops_per_device=100.0, bytes_per_device=50.0)
        instr = SimpleNamespace(flops_per_device=104.0, bytes_per_device=51.0)
        oh = telemetry_overhead(plain, instr)
        np.testing.assert_allclose(oh["flops_overhead"], 0.04)
        np.testing.assert_allclose(oh["bytes_overhead"], 0.02)
