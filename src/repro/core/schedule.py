"""Precision scheduling (paper Section 4.4, Table 1).

The paper's schedule: first 25% of training fully mixed (half FNO block +
AMP), middle 50% AMP only, final 25% full precision.  Intuition: early
gradients are large and tolerate coarse arithmetic; late-training updates
are small and benefit from full precision.  The scheduled run *beats* the
full-precision baseline on zero-shot super-resolution (Table 1).

A schedule is now a piecewise-constant **stack of rule overlays** over a
base policy, not a sequence of whole-policy swaps: each phase is either a
registry rule-set name (``"mixed_fno_fp16"`` — itself an overlay over the
shared site table) or a raw tuple of ``(site_pattern, SiteRule)`` entries
layered onto ``base``.  That makes partial-precision phases expressible —
e.g. a phase that half-quantises only the spectral contraction while the
FFT boundary stays full — which the old whole-policy schedule could not
say.

Because a precision change alters compiled dtypes, each phase owns its own
jitted train step; the trainer swaps steps at phase boundaries (cheap: at
most ``len(phases)-1`` recompiles per run).  Phase policies carry stable,
distinct names so the trainer's step cache keys correctly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

from repro.precision import PrecisionPolicy, get_policy
from repro.precision.rules import normalize_entries

#: A phase overlay: a registry policy name, or rule entries over ``base``.
Overlay = Union[str, tuple]


@dataclasses.dataclass(frozen=True)
class PrecisionSchedule:
    """Piecewise-constant precision-rule overlays over normalised progress.

    ``phases`` is a tuple of (end_fraction, overlay), end-exclusive and
    strictly increasing, final end_fraction == 1.0.
    """

    phases: Tuple[Tuple[float, Overlay], ...]
    base: str = "full"
    #: ``"static"`` = the piecewise-constant phases above; ``"auto"`` =
    #: the trainer supersedes the phases with an
    #: ``repro.autoprec.AutoPrecisionController`` over ``base`` — per-site
    #: formats follow runtime telemetry and the Thm 3.1/3.2 budgets.
    mode: str = "static"
    #: Auto mode only: the physical grid size n the Thm 3.1 budget is
    #: evaluated at.  Set it to the training resolution (e.g. 64*64) —
    #: the trainer cannot infer it from an opaque loss_fn, and the
    #: controller's fallback default assumes a 64^d grid.
    grid_points: Optional[int] = None

    def __post_init__(self):
        if self.mode not in ("static", "auto"):
            raise ValueError(f"mode must be 'static' or 'auto', got {self.mode!r}")
        ends = [e for e, _ in self.phases]
        if sorted(ends) != ends or ends[-1] != 1.0:
            raise ValueError(f"phase ends must increase to 1.0, got {ends}")
        for _, overlay in self.phases:
            if not isinstance(overlay, str):
                normalize_entries(overlay)  # raise early on malformed entries

    def _phase_policy(self, idx: int) -> PrecisionPolicy:
        end, overlay = self.phases[idx]
        if isinstance(overlay, str):
            return get_policy(overlay)
        return get_policy(self.base).with_rules(
            *overlay, name=f"{self.base}+overlay{idx}"
        )

    def policy_at(self, step: int, total_steps: int) -> PrecisionPolicy:
        frac = (step + 0.5) / max(total_steps, 1)
        for idx, (end, _) in enumerate(self.phases):
            if frac < end:
                return self._phase_policy(idx)
        return self._phase_policy(len(self.phases) - 1)

    def phase_boundaries(self, total_steps: int):
        """[(start_step, end_step, policy), ...] for trainer step swapping."""
        out = []
        prev = 0.0
        for idx, (end, _) in enumerate(self.phases):
            s, e = int(prev * total_steps), int(end * total_steps)
            if e > s:
                out.append((s, e, self._phase_policy(idx)))
            prev = end
        return out

    @classmethod
    def paper_default(cls, half: str = "fp16") -> "PrecisionSchedule":
        mixed = f"mixed_fno_{half}"
        amp = f"amp_{half}"
        return cls(phases=((0.25, mixed), (0.75, amp), (1.0, "full")))

    @classmethod
    def constant(cls, name: str) -> "PrecisionSchedule":
        return cls(phases=((1.0, name),))

    @classmethod
    def auto(cls, base: str = "full",
             grid_points: Optional[int] = None) -> "PrecisionSchedule":
        """Auto-precision mode: instead of the paper's fixed 25/50/25
        phases, the trainer measures per-site numerics at runtime and
        lets a controller demote/promote sites against the theory
        budgets.  Pass ``grid_points`` (the training resolution, e.g.
        ``64 * 64``) so the Thm 3.1 budget is evaluated at the real
        grid.  Standalone consumers (``policy_at`` outside a trainer)
        see the base policy."""
        return cls(phases=((1.0, base),), base=base, mode="auto",
                   grid_points=grid_points)
