"""Configuration schema for every selectable architecture + input shapes.

One ``<arch>.py`` per assigned architecture lives beside this module; each
exposes ``CONFIG`` (full size, dry-run only) and ``SMOKE`` (reduced, runs a
real forward/train step on CPU).  The paper's own FNO-family configs are in
``fno_*.py`` / ``sfno_*.py`` / ``gino_*.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LMArchConfig:
    """Unified description of the LM-family architecture pool."""

    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default: d_model // n_heads

    # --- MoE ---
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_shared: int = 0             # shared (always-on) experts
    moe_ff: int = 0                 # per-expert hidden dim
    capacity_factor: float = 1.25

    # --- MLA (deepseek) ---
    mla_kv_lora: int = 0            # 0 => standard GQA attention
    mla_rope_dim: int = 64
    mla_nope_dim: int = 128
    mla_v_dim: int = 128

    # --- SSM (mamba2 SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 64

    # --- mixer selection ---
    mixer: str = "attn"             # attn | ssd | hymba (parallel attn+ssd)
    attn_window: int = 0            # 0 = full attention; >0 = sliding window
    n_full_attn_layers: int = 0     # hymba: this many layers get full attn

    # --- encoder-decoder (whisper) ---
    encoder_decoder: bool = False
    dec_layers: int = 0
    max_dec_len: int = 448

    # --- modality frontend stubs ---
    frontend: str = "none"          # none | audio_stub | vision_stub
    n_patches: int = 0              # vlm: image patch embeddings prepended

    # --- misc ---
    tie_embeddings: bool = True
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for the long_500k cell (SSM / hybrid-with-SWA)."""
        return self.mixer in ("ssd", "hymba")

    def params_dense_approx(self) -> int:
        """6ND napkin-math helper (N below)."""
        d, L = self.d_model, self.n_layers
        attn = d * self.n_heads * self.hd + 2 * d * self.n_kv_heads * self.hd + self.n_heads * self.hd * d
        if self.moe_experts:
            ff = self.moe_experts * 3 * d * self.moe_ff + self.moe_shared * 3 * d * self.moe_ff
        else:
            ff = 3 * d * self.d_ff
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        total_layers = L + (self.dec_layers if self.encoder_decoder else 0)
        return total_layers * (attn + ff) + emb

    def active_params_approx(self) -> int:
        if not self.moe_experts:
            return self.params_dense_approx()
        d, L = self.d_model, self.n_layers
        attn = d * self.n_heads * self.hd + 2 * d * self.n_kv_heads * self.hd + self.n_heads * self.hd * d
        ff = (self.moe_top_k + self.moe_shared) * 3 * d * self.moe_ff
        emb = self.vocab * d
        return L * (attn + ff) + emb


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str   # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def cell_is_runnable(cfg: LMArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch, shape) is a valid dry-run cell, with a reason if not.

    long_500k needs sub-quadratic attention (skip pure full-attention
    archs, per the assignment); encoder-only archs would skip decode —
    every arch in this pool has a decoder, so only the long_500k rule and
    the whisper decoder-length cap apply.
    """
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, "full-attention arch: 500k decode is the quadratic regime (skip per assignment)"
    return True, ""
