"""One benchmark per paper table/figure (CPU-scale reproductions).

Each function prints ``name,us_per_call,derived`` CSV rows (the harness
contract) where ``derived`` carries the table's headline quantity
(memory reduction %, error gap %, etc.).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    FULL,
    ComplexPair,
    contract,
    get_policy,
    greedy_path,
    path_intermediate_bytes,
    quantize_complex,
    theory,
)
from repro.core.contraction import PathCache
from repro.models import UNetConfig, fno_apply, init_unet, unet_apply
from repro.precision import SiteRule
from repro.train.losses import relative_l2

from .common import darcy_data, eval_fno, small_fno, time_fn, train_fno

ROWS = []


def row(name: str, us: float, derived: str):
    ROWS.append(f"{name},{us:.1f},{derived}")
    print(f"{name},{us:.1f},{derived}")


# ---------------------------------------------------------------------------
# Fig 3: GPU memory usage reduction (analog: compiled temp bytes of a
# train-step gradient computation per policy)
# ---------------------------------------------------------------------------


def bench_memory_fig3():
    """Memory reduction per policy.  Primary metric: *analytic* bytes of
    the spectral-domain activations at the policy's storage dtypes (the
    quantity the paper's Fig. 3 measures on GPU; on this CPU container
    XLA emulates bf16 at f32 so compiled temp is reported only as a
    reference, and half-policy temps are not meaningful)."""
    B, C, n = 8, 32, 64
    modes = (8, 8)
    nfreq = n // 2 + 1

    def spectral_bytes(policy):
        itemsize = 8 if policy.spectral_dtype is None else 4  # c64 vs 2xhalf
        full_spec = B * C * n * nfreq * itemsize
        corners = 2 * B * C * modes[0] * modes[1] * itemsize
        return (full_spec + corners) * 4  # 4 layers

    base = spectral_bytes(FULL)
    for pol in ("amp_bf16", "half_fno_only", "mixed_fno_bf16"):
        b = spectral_bytes(get_policy(pol))
        red = 100.0 * (1 - b / base)
        row(f"fig3_memory_{pol}", 0.0,
            f"spectral_bytes={b} reduction={red:.1f}% (paper: up to 50%)")
    row("fig3_memory_full", 0.0, f"spectral_bytes={base} reduction=0.0%")


# ---------------------------------------------------------------------------
# Fig 4: training throughput (CPU-indicative step times per policy)
# ---------------------------------------------------------------------------


def bench_throughput_fig4():
    cfg, params = small_fno(hidden=32, modes=(8, 8))
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(4, 1, 64, 64), jnp.float32)
    t = jnp.asarray(rng.randn(4, 1, 64, 64), jnp.float32)
    times = {}
    for pol_name in ("full", "amp_bf16", "mixed_fno_bf16"):
        policy = get_policy(pol_name)

        @jax.jit
        def step(p, xx, tt):
            return jax.grad(
                lambda pp: relative_l2(fno_apply(pp, xx, cfg, policy), tt)
            )(p)

        times[pol_name] = time_fn(step, params, x, t)
    for k, v in times.items():
        row(f"fig4_throughput_{k}", v, f"speedup_vs_full={times['full']/v:.2f}x")


# ---------------------------------------------------------------------------
# Fig 5 / Table 6: error parity of mixed vs full training
# ---------------------------------------------------------------------------


def bench_convergence_fig5():
    cfg, params = small_fno(hidden=16, modes=(8, 8))
    train, test = darcy_data(n=32, ntrain=32, ntest=16)
    mixed = get_policy("mixed_fno_bf16")
    p_full, _ = train_fno(cfg, params, train, FULL, steps=30)
    p_mix, _ = train_fno(cfg, params, train, mixed, steps=30)
    # evaluate each model under its own policy: the tanh stabiliser is part
    # of the trained function (evaluating a tanh-trained model without it
    # inflates test error ~3x — found empirically, §Perf notes)
    e_full = eval_fno(cfg, p_full, test, FULL)
    e_mix = eval_fno(cfg, p_mix, test, mixed)
    gap = 100.0 * (e_mix - e_full) / e_full
    row("fig5_convergence", 0.0,
        f"test_l2_full={e_full:.4f} test_l2_mixed={e_mix:.4f} gap={gap:+.1f}%")


# ---------------------------------------------------------------------------
# Table 1: zero-shot super-resolution + precision schedule
# ---------------------------------------------------------------------------


def bench_superres_table1():
    cfg, params = small_fno(hidden=16, modes=(8, 8))
    train, _ = darcy_data(n=32, ntrain=32)
    _, test_hi = darcy_data(n=64, ntrain=1, ntest=8, maxiter=600)

    results = {}
    mixed = get_policy("mixed_fno_bf16")
    p_full, _ = train_fno(cfg, params, train, FULL, steps=30)
    results["full"] = eval_fno(cfg, p_full, test_hi, FULL)
    p_mix, _ = train_fno(cfg, params, train, mixed, steps=30)
    results["mixed"] = eval_fno(cfg, p_mix, test_hi, mixed)
    # schedule: 25% mixed, 50% amp, 25% full (final phase trains the
    # un-stabilised function, so full-precision eval is consistent)
    p = params
    p, _ = train_fno(cfg, p, train, mixed, steps=8)
    p, _ = train_fno(cfg, p, train, get_policy("amp_bf16"), steps=15)
    p, _ = train_fno(cfg, p, train, FULL, steps=7)
    results["schedule"] = eval_fno(cfg, p, test_hi, FULL)
    row("table1_superres", 0.0,
        " ".join(f"{k}={v:.4f}" for k, v in results.items()))


# ---------------------------------------------------------------------------
# Table 2: U-Net comparison
# ---------------------------------------------------------------------------


def bench_unet_table2():
    cfg, params = small_fno(hidden=16, modes=(8, 8))
    train, test = darcy_data(n=32, ntrain=32, ntest=16)
    mixed = get_policy("mixed_fno_bf16")
    p_fno, _ = train_fno(cfg, params, train, mixed, steps=30)
    e_fno = eval_fno(cfg, p_fno, test, mixed)

    ucfg = UNetConfig(in_channels=1, out_channels=1, base_width=16, depth=2)
    uparams = init_unet(jax.random.PRNGKey(1), ucfg)
    from repro.optim import AdamW

    opt = AdamW(lr=2e-3, weight_decay=0.0)
    st = opt.init(uparams)
    a, u = train

    @jax.jit
    def ustep(p, s):
        def loss_fn(pp):
            return relative_l2(unet_apply(pp, a, ucfg, get_policy("amp_bf16")), u)
        loss, g = jax.value_and_grad(loss_fn)(p)
        p2, s2 = opt.update(g, s, p)
        return p2, s2, loss

    for _ in range(30):
        uparams, st, _ = ustep(uparams, st)
    at, ut = test
    e_unet = float(relative_l2(unet_apply(uparams, at, ucfg, FULL), ut))
    row("table2_unet", 0.0, f"fno_l2={e_fno:.4f} unet_l2={e_unet:.4f} fno_wins={e_fno < e_unet}")


# ---------------------------------------------------------------------------
# Table 3 + Appendix B.5/B.6: stabiliser study
# ---------------------------------------------------------------------------


def bench_stabilizers_table3():
    """The paper's failure mode: the *FFT inside the FNO block* overflows
    fp16 (the DC bin sums n² grid values), while the real-valued layers
    around it are fine.  HALF_FNO_ONLY isolates exactly that: compute stays
    f32, only the spectral pipeline is fp16 — so any NaN comes from the
    block, and only tanh-class pre-activations prevent it."""
    cfg, params = small_fno(hidden=16, modes=(8, 8))
    rng = np.random.RandomState(0)
    # activations large enough that Σ over the 64x64 grid exceeds 65504
    a = jnp.asarray(rng.randn(4, 1, 64, 64) * 40.0 + 30.0, jnp.float32)

    for stab in (None, "tanh", "hard_clip", "sigma_clip"):
        policy = get_policy("half_fno_only").with_rules(
            ("*/spectral/*", SiteRule(stabilize=stab)),
            name=f"half_fno_{stab or 'none'}",
        )
        y = fno_apply(params, a, cfg, policy)
        finite = bool(np.isfinite(np.asarray(y, np.float32)).all())
        row(f"table3_stabilizer_{stab or 'none'}", 0.0, f"finite={finite}")


# ---------------------------------------------------------------------------
# Table 4: FNO-block per-stage precision ablation (8 settings)
# ---------------------------------------------------------------------------


def bench_block_precision_table4():
    from repro.core.spectral import _corner_slices, _corner_weight_ops
    from repro.core import init_spectral_weights

    rng = np.random.RandomState(0)
    key = jax.random.PRNGKey(0)
    params = init_spectral_weights(key, 8, 8, (6, 6))
    x = jnp.asarray(rng.randn(2, 8, 24, 24), jnp.float32)

    def staged(fft_half, contract_half, ifft_half):
        xf = jnp.fft.rfftn(jnp.tanh(x), axes=(2, 3))
        if fft_half:
            xf = quantize_complex(xf, jnp.float16)
        slices = _corner_slices((6, 6), xf.shape[2:])
        out = jnp.zeros((2, 8, *xf.shape[2:]), jnp.complex64)
        pol = get_policy("mixed_fno_fp16") if contract_half else FULL
        for c, sl in enumerate(slices):
            xc = xf[(slice(None), slice(None), *sl)]
            ops, expr = _corner_weight_ops(params, c, 2)
            yc = contract(expr, xc, *ops, policy=pol)
            if isinstance(yc, ComplexPair):
                yc = yc.to_complex()
            out = out.at[(slice(None), slice(None), *sl)].set(yc.astype(jnp.complex64))
        y = jnp.fft.irfftn(out, s=(24, 24), axes=(2, 3))
        if ifft_half:
            y = y.astype(jnp.float16)
        return y.astype(jnp.float32)

    ref = np.asarray(staged(False, False, False))
    for f in (False, True):
        for c in (False, True):
            for i in (False, True):
                y = np.asarray(staged(f, c, i))
                rel = np.linalg.norm(y - ref) / (np.linalg.norm(ref) + 1e-12)
                tag = f"{'H' if f else 'F'}{'H' if c else 'F'}{'H' if i else 'F'}"
                row(f"table4_block_{tag}", 0.0, f"rel_err_vs_full={rel:.2e}")


# ---------------------------------------------------------------------------
# Tables 8/9/10/11: contraction engine ablations
# ---------------------------------------------------------------------------


def bench_contraction_tables():
    rng = np.random.RandomState(0)
    # TFNO CP einsum at realistic-ish sizes
    b, i, o, mx, my, r = 8, 32, 32, 12, 12, 32
    X = jnp.asarray(rng.randn(b, i, mx, my) + 1j * rng.randn(b, i, mx, my), jnp.complex64)
    lam = jnp.asarray(rng.randn(r) + 1j * rng.randn(r), jnp.complex64)
    Ui = jnp.asarray(rng.randn(i, r) + 1j * rng.randn(i, r), jnp.complex64)
    Uo = jnp.asarray(rng.randn(o, r) + 1j * rng.randn(o, r), jnp.complex64)
    Ux = jnp.asarray(rng.randn(mx, r) + 1j * rng.randn(mx, r), jnp.complex64)
    Uy = jnp.asarray(rng.randn(my, r) + 1j * rng.randn(my, r), jnp.complex64)
    expr = "bixy,r,ir,or,xr,yr->boxy"
    ops = (X, lam, Ui, Uo, Ux, Uy)
    shapes = [t.shape for t in ops]

    # Table 9: path caching
    cold = PathCache()
    t_search = time_fn(lambda: greedy_path(expr, shapes, "memory"), iters=5)
    t_cached = time_fn(lambda: cold.get(expr, shapes, "memory"), iters=5)
    row("table9_path_cache", t_cached, f"search_us={t_search:.0f} cached_speedup={t_search/max(t_cached,1e-9):.0f}x")

    # Table 10: greedy-memory vs flop-optimal peak intermediate
    p_mem = greedy_path(expr, shapes, "memory")
    p_flop = greedy_path(expr, shapes, "flops")
    m1 = path_intermediate_bytes(expr, shapes, p_mem, itemsize=8)
    m2 = path_intermediate_bytes(expr, shapes, p_flop, itemsize=8)
    row("table10_greedy_vs_flop", 0.0,
        f"greedy_peak={m1}B flop_peak={m2}B reduction={100*(1-m1/max(m2,1)):.1f}%")

    # Table 8: Option A (single giant einsum) vs Option C (pairwise greedy)
    f_pair = jax.jit(lambda *t: contract(expr, *t, policy=FULL))
    f_naive = jax.jit(lambda *t: jnp.einsum(expr, *t, optimize=False)
                      if b * i * o * mx * my * r < 2e8 else f_pair(*t))
    t_pair = time_fn(f_pair, *ops)
    t_naive = time_fn(f_naive, *ops)
    np.testing.assert_allclose(
        np.asarray(f_pair(*ops)), np.asarray(f_naive(*ops)), rtol=1e-3, atol=1e-3
    )
    row("table8_contract_options", t_pair,
        f"naive_us={t_naive:.0f} ours_speedup={t_naive/max(t_pair,1e-9):.1f}x")

    # Table 11: weights-only-half vs inputs+weights half (bytes moved)
    half_both = (X.nbytes // 2) + sum(t.nbytes // 2 for t in ops[1:])
    half_w = X.nbytes + sum(t.nbytes // 2 for t in ops[1:])
    row("table11_half_inputs", 0.0,
        f"both_half={half_both}B weights_only={half_w}B extra={100*(half_w/half_both-1):.0f}%")


# ---------------------------------------------------------------------------
# Fig 7: theory bounds vs empirical errors
# ---------------------------------------------------------------------------


def bench_theory_fig7():
    v = lambda x: np.sin(2 * np.pi * x[..., 0]) + 0.5 * np.prod(x, axis=-1)
    for d in (1, 2):
        for m in (8, 16, 32):
            n = m ** d
            disc = theory.disc_error(v, m=m, d=d, omega=1.0)
            prec = theory.prec_error(v, m=m, d=d, omega=1.0, dtype="float16")
            ub_d = theory.disc_upper_bound(n, d, 1.0, L=2 * np.pi, M=1.5)
            ub_p = theory.prec_upper_bound(2 ** -11, 1.5)
            row(f"fig7_theory_d{d}_m{m}", 0.0,
                f"disc={disc:.2e}<=ub={ub_d:.2e} prec={prec:.2e}<=ub={ub_p:.2e} prec_lt_disc={prec < disc}")


# ---------------------------------------------------------------------------
# Fig 14: frequency-mode ablation
# ---------------------------------------------------------------------------


def bench_freq_modes_fig14():
    train, test = darcy_data(n=32, ntrain=24, ntest=12)
    for modes in (4, 8, 12):
        cfg, params = small_fno(hidden=16, modes=(modes, modes))
        mixed = get_policy("mixed_fno_bf16")
        p_f, _ = train_fno(cfg, params, train, FULL, steps=25)
        p_h, _ = train_fno(cfg, params, train, mixed, steps=25)
        e_f = eval_fno(cfg, p_f, test, FULL)
        e_h = eval_fno(cfg, p_h, test, mixed)
        row(f"fig14_modes_{modes}", 0.0, f"full={e_f:.4f} mixed={e_h:.4f}")


ALL = [
    bench_memory_fig3,
    bench_throughput_fig4,
    bench_convergence_fig5,
    bench_superres_table1,
    bench_unet_table2,
    bench_stabilizers_table3,
    bench_block_precision_table4,
    bench_contraction_tables,
    bench_theory_fig7,
    bench_freq_modes_fig14,
]
