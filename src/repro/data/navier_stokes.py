"""2-D Navier-Stokes (vorticity form, unit torus) pseudo-spectral solver.

Matches the paper's dataset (§B.2): Re=500, forcing f ~ N(0, 27(-Δ+9I)^{-4}),
ω(0)=0, learn G: f ↦ ω(T) with T=5.  Crank-Nicolson for the viscous term +
Heun for the advection term, 2/3-rule dealiasing — the classic scheme
(Chandler & Kerswell 2013) in jit-able JAX.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .grf import grf_2d


def _wavenumbers(n):
    k = jnp.fft.fftfreq(n, d=1.0 / n) * 2.0 * jnp.pi
    kx = k[:, None]
    ky = k[None, :]
    k2 = kx ** 2 + ky ** 2
    k2_inv = jnp.where(k2 > 0, 1.0 / jnp.maximum(k2, 1e-12), 0.0)
    # 2/3 dealias mask
    cutoff = n // 3
    fx = jnp.abs(jnp.fft.fftfreq(n, d=1.0 / n))
    mask = (fx[:, None] <= cutoff) & (fx[None, :] <= cutoff)
    return kx, ky, k2, k2_inv, mask


def _nonlinear(w_hat, kx, ky, k2_inv, mask):
    """-(u·∇)ω in spectral space with dealiasing."""
    psi_hat = w_hat * k2_inv           # -Δψ = ω  =>  ψ̂ = ω̂/|k|²
    u = jnp.fft.ifft2(1j * ky * psi_hat).real      # u =  ∂ψ/∂y
    v = jnp.fft.ifft2(-1j * kx * psi_hat).real     # v = -∂ψ/∂x
    wx = jnp.fft.ifft2(1j * kx * w_hat).real
    wy = jnp.fft.ifft2(1j * ky * w_hat).real
    adv = u * wx + v * wy
    return -jnp.fft.fft2(adv) * mask


@functools.partial(jax.jit, static_argnames=("n", "steps"))
def solve_ns_vorticity(
    f: jnp.ndarray, n: int, T: float = 5.0, Re: float = 500.0, steps: int = 512
) -> jnp.ndarray:
    """Integrate ω_t + u·∇ω = (1/Re)Δω + f from ω(0)=0 to t=T.

    f: (n, n) forcing; returns ω(T): (n, n).
    """
    nu = 1.0 / Re
    dt = T / steps
    kx, ky, k2, k2_inv, mask = _wavenumbers(n)
    f_hat = jnp.fft.fft2(f) * mask
    # Crank-Nicolson viscous factors
    cn_a = 1.0 - 0.5 * dt * nu * (-k2)
    cn_b = 1.0 + 0.5 * dt * nu * (-k2)

    def step(w_hat, _):
        n1 = _nonlinear(w_hat, kx, ky, k2_inv, mask)
        w_pred = (w_hat * cn_b + dt * (n1 + f_hat)) / cn_a
        n2 = _nonlinear(w_pred, kx, ky, k2_inv, mask)
        w_new = (w_hat * cn_b + dt * (0.5 * (n1 + n2) + f_hat)) / cn_a
        return w_new, None

    w_hat0 = jnp.zeros((n, n), jnp.complex64)
    w_hatT, _ = jax.lax.scan(step, w_hat0, None, length=steps)
    return jnp.fft.ifft2(w_hatT).real


def sample_ns_batch(key: jax.Array, n: int, batch: int, T: float = 5.0, steps: int = 512):
    """Returns (f, w): forcings (B, 1, n, n) and solutions ω(T) (B, 1, n, n)."""
    f = grf_2d(key, n, alpha=4.0, tau=3.0, sigma=27.0 ** 0.5, batch=batch)
    w = jax.vmap(lambda fi: solve_ns_vorticity(fi, n, T=T, steps=steps))(f)
    return f[:, None], w[:, None]
