"""Mixture-of-Experts FFN with grouped-local, sort-based capacity dispatch.

Index-based (not one-hot) dispatch: the GShard (T, E, C) one-hot tensor is
O(T·E·C) and explodes at 32k tokens × 40 experts; instead we argsort the
(token, expert) assignments by expert, compute each token's position inside
its expert's segment with a cumulative bincount, drop beyond-capacity
tokens, and gather/scatter through an (E, C) index table.

**Grouped-local routing** (§Perf iterations 3-4): tokens are reshaped to
(G, T/G) with G = the data-parallel degree and the dispatch is vmapped per
group, so routing never crosses data shards — without this GSPMD turned
the global argsort into a 141-second collective term on granite-moe
train_4k.  Two alternatives were measured and REJECTED (EXPERIMENTS.md
§Perf iteration 4): (a) explicit G-batched dispatch ops (index-matrix
scatters lower to gather-heavy GSPMD code: granite-moe 27.0s → 34.9s);
(b) forcing E-over-model sharding on the dispatch gather/scatter
(deepseek 14.2s → 38s).  The vmapped form below is the best-measured:
expert GEMMs shard through the *weights'* sharding (EP over model when E
divides — deepseek 64/16; ff-dim sharding fallback for granite-moe's
indivisible E=40).

The router is reduction-sensitive, so its dtype resolves from the
``lm/router`` precision site (f32 under every registry rule set — the
AMP-blocklist rule the shared table encodes); expert GEMMs follow the
``lm/dense`` compute dtype with f32 accumulation.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .common import init_swiglu, swiglu
from repro.dist.constrain import constrain, constrain_tokens, logical_axis_size


def init_moe(key, d_model, n_experts, moe_ff, n_shared, shared_ff):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    s_in = (1.0 / d_model) ** 0.5
    s_out = (1.0 / moe_ff) ** 0.5
    params = {
        "router": s_in * jax.random.normal(k1, (d_model, n_experts), jnp.float32),
        "wg": s_in * jax.random.normal(k2, (n_experts, d_model, moe_ff), jnp.float32),
        "wu": s_in * jax.random.normal(k3, (n_experts, d_model, moe_ff), jnp.float32),
        "wd": s_out * jax.random.normal(k4, (n_experts, moe_ff, d_model), jnp.float32),
    }
    if n_shared > 0:
        params["shared"] = init_swiglu(k5, d_model, n_shared * shared_ff)
    return params


def moe_apply(
    params,
    x: jnp.ndarray,           # (T, d) flattened tokens
    top_k: int,
    capacity_factor: float,
    dtype,
    router_dtype=jnp.float32,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (out (T, d), aux_loss scalar)."""
    T, d = x.shape
    G = logical_axis_size("dp")   # data-parallel degree = dispatch groups
    if T % G:
        G = 1
    if G > 1:
        xg = constrain(x.reshape(G, T // G, d), "dp", None, None)
        outs, auxes = jax.vmap(
            lambda xi: _moe_one_group(params, xi, top_k, capacity_factor,
                                      dtype, router_dtype,
                                      use_constraints=False)
        )(xg)
        out = constrain(outs, "dp", None, None).reshape(T, d)
        return out, jnp.mean(auxes)
    return _moe_one_group(params, x, top_k, capacity_factor, dtype, router_dtype)


def _moe_one_group(
    params,
    x: jnp.ndarray,           # (T, d) tokens local to one dispatch group
    top_k: int,
    capacity_factor: float,
    dtype,
    router_dtype=jnp.float32,
    use_constraints: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    # sharding constraints are illegal under the grouped vmap; the caller
    # constrains the grouped tensors instead
    if use_constraints:
        x = constrain_tokens(x)
    T, d = x.shape
    E = params["router"].shape[1]
    C = max(1, int(top_k * T * capacity_factor / E))

    # --- routing at the lm/router site dtype (f32 under every registry
    # rule set: top-k and the balance loss are reduction-sensitive) ---
    logits = jnp.einsum("td,de->te", x.astype(router_dtype),
                        params["router"].astype(router_dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)        # (T, k)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    # load-balancing auxiliary loss (Switch-style)
    me = probs.mean(axis=0)                                     # (E,)
    ce = jnp.zeros(E).at[expert_ids.reshape(-1)].add(1.0) / (T * top_k)
    aux = E * jnp.sum(me * ce)

    # --- sort-based dispatch ---
    flat_expert = expert_ids.reshape(-1)                        # (T*k,)
    flat_token = jnp.repeat(jnp.arange(T), top_k)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_expert)
    se, stok, sg = flat_expert[order], flat_token[order], flat_gate[order]
    counts = jnp.zeros(E, jnp.int32).at[se].add(1)
    seg_start = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(counts)[:-1]])
    pos_in_seg = jnp.arange(T * top_k) - seg_start[se]
    valid = pos_in_seg < C

    # (E, C) index table: which flat token sits in slot (e, c); sentinel T
    table = jnp.full((E, C), T, jnp.int32)
    table = table.at[se, jnp.minimum(pos_in_seg, C - 1)].set(
        jnp.where(valid, stok, T)
    )
    gates_tab = jnp.zeros((E, C), jnp.float32).at[
        se, jnp.minimum(pos_in_seg, C - 1)
    ].set(jnp.where(valid, sg, 0.0))

    # gather expert inputs (pad row T = zeros)
    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    expert_in = x_pad[table].astype(dtype)                      # (E, C, d)
    if use_constraints:
        expert_in = constrain(expert_in, "expert", None, None)  # EP when E divides

    def _mm(expr, a, b):
        # CPU thunk runtime can't execute batched bf16xbf16=f32 dots;
        # upcast there (TPU keeps bf16 inputs + f32 MXU accumulation).
        if jax.default_backend() == "cpu" and a.dtype == jnp.bfloat16:
            a, b = a.astype(jnp.float32), b.astype(jnp.float32)
        return jnp.einsum(expr, a, b, preferred_element_type=jnp.float32)

    g = _mm("ecd,edf->ecf", expert_in, params["wg"].astype(dtype)).astype(dtype)
    u = _mm("ecd,edf->ecf", expert_in, params["wu"].astype(dtype)).astype(dtype)
    h = jax.nn.silu(g) * u
    y = _mm("ecf,efd->ecd", h, params["wd"].astype(dtype))      # (E, C, d) f32

    # --- combine: scatter-add back to tokens, gate-weighted ---
    y = y * gates_tab[..., None]
    out = jnp.zeros((T + 1, d), jnp.float32).at[table.reshape(-1)].add(
        y.reshape(-1, d)
    )[:T]

    if "shared" in params:
        out = out + swiglu(params["shared"], x, dtype).astype(jnp.float32)
    return out.astype(dtype), aux
