"""Batched LM serving demo: continuous batching over the slot engine.

Loads a reduced config from the architecture pool (selectable with
``--arch``; any of the 10 assigned ids), admits a stream of requests, and
drives greedy decoding with per-slot KV caches / SSM state.

    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-370m
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models.lm import init_lm
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    if cfg.encoder_decoder:
        raise SystemExit("enc-dec serving demo: use whisper_decode_step directly")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(params, cfg, n_slots=args.slots, max_len=64)

    rng = np.random.RandomState(0)
    reqs = [
        Request(uid=i, prompt=list(rng.randint(1, cfg.vocab, rng.randint(3, 8))),
                max_new_tokens=8)
        for i in range(args.requests)
    ]
    t0 = time.perf_counter()
    done, ticks = engine.run_until_done(reqs)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.generated) for r in done)
    print(f"arch={args.arch} slots={args.slots}: served {len(done)} requests, "
          f"{total_tokens} tokens in {ticks} ticks ({dt:.2f}s; "
          f"{total_tokens/dt:.1f} tok/s on CPU)")
    for r in done[:3]:
        print(f"  req {r.uid}: prompt={r.prompt} -> generated={r.generated}")


if __name__ == "__main__":
    main()
