import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Dry-run for the paper's own FNO-family configs at pod scale.

These rows extend the 40-cell LM table with the cells most representative
of the paper's technique, lowered under BOTH the paper-faithful mixed
policy (`mixed_fno_bf16`) and the full-precision baseline — the §Perf
hillclimb compares and optimises them.

  tfno-ns   train 128x128,  global batch 1024 (CP-factorised weights)
  tfno-ns-hr train 512x512, global batch 64   (the paper's super-res goal)
  sfno-swe  train 256x512,  global batch 128
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.fno_paper import FNO_DARCY, SFNO_SWE, TFNO_NS
from repro.core import get_policy
from repro.precision import describe
from repro.dist import use_mesh
from repro.dist.sharding import fno_param_specs, pick_spec, to_named
from repro.launch.dryrun import save_result
from repro.launch.steps import opt_specs as _opt_specs
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze_counts, parse_hlo, spectral_kernel_vmem
from repro.models import fno_apply, init_fno, init_sfno, sfno_apply
from repro.optim import AdamW
from repro.train.losses import relative_l2

FNO_CELLS = {
    "tfno-ns": dict(kind="fno", cfg=TFNO_NS, res=(128, 128), batch=1024),
    "tfno-ns-hr": dict(kind="fno", cfg=TFNO_NS, res=(512, 512), batch=64),
    "fno-darcy": dict(kind="fno", cfg=FNO_DARCY, res=(128, 128), batch=1024),
    "sfno-swe": dict(kind="sfno", cfg=SFNO_SWE, res=(256, 512), batch=128),
}


def run_fno_cell(name: str, multi_pod: bool, policy_name: str,
                 verbose: bool = True, telemetry: bool = False) -> dict:
    spec = FNO_CELLS[name]
    cfg = spec["cfg"]
    policy = get_policy(policy_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": name, "shape": f"train_{spec['res'][0]}x{spec['res'][1]}_b{spec['batch']}",
           "mesh": mesh_name, "kind": "train", "policy": policy_name,
           "policy_sites": describe(policy)}
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    B = spec["batch"]
    res = spec["res"]

    if spec["kind"] == "fno":
        init_fn = lambda k: init_fno(k, cfg)
        apply_fn = lambda p, x: fno_apply(p, x, cfg, policy)
        in_ch = cfg.in_channels
    else:
        init_fn = lambda k: init_sfno(k, cfg)
        apply_fn = lambda p, x: sfno_apply(p, x, cfg, policy)
        in_ch = cfg.in_channels

    p_shape = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3)
    opt_shape = jax.eval_shape(opt.init, p_shape)
    batch = {
        "x": jax.ShapeDtypeStruct((B, in_ch, *res), jnp.float32),
        "y": jax.ShapeDtypeStruct((B, cfg.out_channels, *res), jnp.float32),
    }

    def train_step(params, opt_state, b):
        def loss_fn(p):
            pred = apply_fn(p, b["x"])
            return relative_l2(pred, b["y"])
        loss, grads = jax.value_and_grad(loss_fn)(params)
        new_p, new_o = opt.update(grads, opt_state, params)
        return new_p, new_o, loss

    param_specs = fno_param_specs(p_shape, mesh)
    p_named = to_named(mesh, param_specs)
    opt_named = to_named(mesh, _opt_specs(opt_shape, param_specs))
    # full-DP input layout: batch over every mesh axis when divisible
    # (matches constrain_spatial in the model — §Perf iteration 5)
    bspecs = jax.tree_util.tree_map(
        lambda v: pick_spec(v.shape, mesh, [
            ("all",) + (None,) * (len(v.shape) - 1),
            ("dp",) + (None,) * (len(v.shape) - 1),
            (),
        ]),
        batch,
    )
    b_named = to_named(mesh, bspecs)
    with use_mesh(mesh):
        lowered = jax.jit(
            train_step,
            in_shardings=(p_named, opt_named, b_named),
            out_shardings=(p_named, opt_named, NamedSharding(mesh, P())),
        ).lower(p_shape, opt_shape, batch)
        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    counts = parse_hlo(compiled.as_text())
    n_dev = mesh.devices.size
    roof = analyze_counts(counts, n_dev)
    # Pallas spectral-contraction tiling estimate for this cell: the
    # full-DP layout leaves B/n_dev fields per device; dense FNO corners
    # contract hidden->hidden over the retained modes, the SFNO over the
    # (lmax, mmax) spherical spectrum, and CP factorisations budget the
    # factorised kernel at the layer's CP rank.
    h = cfg.hidden_channels
    rank = 0
    if getattr(cfg, "factorization", "dense") == "cp":
        from repro.core.spectral import cp_rank

        rank = cp_rank(h, h, cfg.rank)
    kmodes = cfg.modes if spec["kind"] == "fno" else (cfg.lmax, cfg.mmax)
    itemsize = 2 if policy.spectral_is_half else 4
    kdtype = (jnp.dtype(policy.spectral_dtype).name
              if policy.spectral_is_half else "float32")
    rec["spectral_kernel"] = spectral_kernel_vmem(
        max(1, B // n_dev), h, h, kmodes, rank=rank,
        l_shared=spec["kind"] == "sfno", itemsize=itemsize, dtype=kdtype)
    rec.update({
        "status": "ok",
        "compile_s": round(time.time() - t0, 1),
        "n_devices": n_dev,
        "memory_analysis": {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
        },
        "collective_bytes_by_kind": counts.collective_by_kind,
        "roofline": roof.to_dict(),
    })
    if telemetry:
        # also lower the autoprec-instrumented twin of the train step —
        # numerics taps collected as a functional carry — and record its
        # relative flops/bytes cost next to the plain roofline
        from repro.autoprec import TraceCollector, collecting
        from repro.launch.dryrun import telemetry_overhead

        t1 = time.time()

        def train_step_telem(params, opt_state, b):
            def loss_fn(p):
                col = TraceCollector()
                with collecting(col):
                    pred = apply_fn(p, b["x"])
                    loss = relative_l2(pred, b["y"])
                return loss, col.snapshot()
            (loss, telem), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            new_p, new_o = opt.update(grads, opt_state, params)
            return new_p, new_o, loss, telem

        with use_mesh(mesh):
            t_compiled = jax.jit(
                train_step_telem,
                in_shardings=(p_named, opt_named, b_named),
                out_shardings=(p_named, opt_named,
                               NamedSharding(mesh, P()), None),
            ).lower(p_shape, opt_shape, batch).compile()
        t_counts = parse_hlo(t_compiled.as_text())
        t_roof = analyze_counts(t_counts, n_dev)
        rec["telemetry"] = {
            "compile_s": round(time.time() - t1, 1),
            "roofline": t_roof.to_dict(),
            "overhead": telemetry_overhead(roof, t_roof),
        }
    if verbose:
        print(f"== {name} ({policy_name}) on {mesh_name} ==")
        print("memory:", rec["memory_analysis"])
        print("roofline:", json.dumps(rec["roofline"], indent=2))
        if "telemetry" in rec:
            print("telemetry overhead:", rec["telemetry"]["overhead"])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None, choices=list(FNO_CELLS) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--policy", default="mixed_fno_bf16")
    ap.add_argument("--telemetry", action="store_true",
                    help="also lower the autoprec-instrumented step and "
                         "record the telemetry overhead")
    args = ap.parse_args()
    cells = [args.cell] if args.cell else list(FNO_CELLS)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    failures = []
    for c in cells:
        for mp in meshes:
            try:
                rec = run_fno_cell(c, mp, args.policy,
                                   telemetry=args.telemetry)
            except Exception as e:
                traceback.print_exc()
                rec = {"arch": c, "shape": "train", "mesh": "2x16x16" if mp else "16x16",
                       "policy": args.policy, "status": "FAILED",
                       "error": f"{type(e).__name__}: {e}"}
                failures.append(rec)
            save_result(rec)
    if failures:
        raise SystemExit(1)
    print("fno cells passed")


if __name__ == "__main__":
    main()
