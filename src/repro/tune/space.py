"""Search-space enumeration: legal tile candidates per kernel family.

Legality is defined by the same ``*vmem_bytes*`` estimators the static
heuristics (``pick_block_m`` / ``pick_block_l``) and the dry-run VMEM
reports use — a candidate the tuner may time is exactly a tile those
estimators price under the VMEM budget.  That shared vocabulary is what
lets ``repro.analyze``'s calibration-coverage check re-derive, offline,
that every cached tile was legal.

Forward and backward tiles are enumerated independently (their working
sets differ — the dense backward holds two gradient kernels' worth of
tiles), then crossed: the autotuner times each (block_fwd, block_bwd)
pair as one train step, because that is the unit the custom-VJP config
actually pins.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Sequence, Tuple

from repro.kernels.spectral_contract import (
    VMEM_BUDGET,
    cp_vmem_bytes,
    fused_vmem_bytes,
    fused_vmem_bytes_bwd,
    lshared_vmem_bytes,
    vmem_bytes,
    vmem_bytes_bwd,
)

#: the block ladders the heuristics walk — the tuner searches the same
#: rungs so a calibrated tile is always one the heuristic *could* have
#: picked (just not necessarily the one it would)
BLOCKS_M = (512, 256, 128, 64, 32, 16, 8)
BLOCKS_L = (256, 128, 64, 32, 16, 8, 4, 2, 1)
BLOCKS_B = (8, 4, 2, 1)


def fused_axes(shape: Sequence[int]) -> Tuple[
        int, int, int, Tuple[int, ...], Tuple[int, ...]]:
    """Unpack a ``spectral_fused`` shape key ``(B, I, O, *spatial,
    *modes)`` — spatial and modes have equal length, so the split is
    unambiguous for any rank."""
    B, I, O = (int(s) for s in shape[:3])
    rest = shape[3:]
    d = len(rest) // 2
    if d < 1 or len(rest) != 2 * d:
        raise ValueError(f"malformed spectral_fused shape {tuple(shape)}")
    spatial = tuple(int(s) for s in rest[:d])
    modes = tuple(int(s) for s in rest[d:])
    return B, I, O, spatial, modes

#: same headroom the heuristics leave: half the physical VMEM
DEFAULT_BUDGET = VMEM_BUDGET // 2


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One (family, shape, dtype, fwd/bwd tile) point of the search."""

    family: str            # dense | dense-fused | cp | lshared | spectral_fused
    shape: Tuple[int, ...]  # dense: (B,I,O,M)  cp: (B,I,O,R,M)
                            # lshared: (B,I,O,L,Mm)
                            # spectral_fused: (B,I,O,*spatial,*modes)
    dtype: str             # storage dtype name, e.g. "bfloat16"
    block_fwd: int
    block_bwd: int


def family_itemsize(family: str, dtype: str) -> int:
    """Bytes/element the family's tiles stream: the storage dtype's —
    except the cast-fusing families (dense-fused, spectral_fused),
    which stream f32 operands and quantise on tiles in VMEM."""
    import jax.numpy as jnp

    if family in ("dense-fused", "spectral_fused"):
        return 4
    return jnp.dtype(dtype).itemsize


def tile_vmem_bytes(family: str, shape: Sequence[int], block: int,
                    itemsize: int, direction: str) -> int:
    """Price one tile with the family's estimator (the coverage check's
    workhorse).  ``direction``: "fwd" | "bwd"."""
    if family in ("dense", "dense-fused"):
        B, I, O, _M = shape
        if direction == "fwd":
            return vmem_bytes(B, I, O, block, itemsize)
        return vmem_bytes_bwd(B, I, O, block, itemsize)
    if family == "cp":
        B, I, O, R, _M = shape
        # one estimator for both directions: the CP backward dominates
        # and cp_vmem_bytes already prices it
        return cp_vmem_bytes(B, I, O, R, block, itemsize)
    if family == "lshared":
        B, I, O, _L, Mm = shape
        return lshared_vmem_bytes(B, I, O, Mm, block, itemsize)
    if family == "spectral_fused":
        _B, I, O, spatial, modes = fused_axes(shape)
        if direction == "fwd":
            return fused_vmem_bytes(block, I, O, spatial, modes,
                                    itemsize=itemsize)
        return fused_vmem_bytes_bwd(block, I, O, spatial, modes,
                                    itemsize=itemsize)
    raise ValueError(f"unknown kernel family {family!r}")


def _tiled_extent(family: str, shape: Sequence[int]) -> int:
    """The axis length the family tiles over (M for mode-tiled kernels,
    L for the l-shared one, the batch for the fused spectral grid)."""
    if family == "lshared":
        return int(shape[3])
    if family == "spectral_fused":
        return int(shape[0])
    return int(shape[-1])


def legal_blocks(family: str, shape: Sequence[int], dtype: str,
                 direction: str, *,
                 budget: int = DEFAULT_BUDGET) -> List[int]:
    """Every ladder rung that (a) does not exceed the tiled extent by
    more than the heuristic's own floor allows and (b) fits the family's
    VMEM estimate under ``budget``."""
    itemsize = family_itemsize(family, dtype)
    extent = _tiled_extent(family, shape)
    if family == "lshared":
        ladder, floor = BLOCKS_L, 1
    elif family == "spectral_fused":
        ladder, floor = BLOCKS_B, 1
    else:
        ladder, floor = BLOCKS_M, 8
    out = []
    for b in ladder:
        if b > max(extent, floor):
            continue
        if tile_vmem_bytes(family, shape, b, itemsize, direction) <= budget:
            out.append(b)
    if not out:
        out = [floor]  # the heuristics' own last resort
    return out


def candidates(family: str, shape: Sequence[int], dtype: str, *,
               budget: int = DEFAULT_BUDGET,
               limit: Optional[int] = None) -> List[Candidate]:
    """The (block_fwd × block_bwd) cross of legal tiles for one key.

    ``limit`` caps the cross for smoke runs: pairs are ordered
    largest-tile-first (the heuristic's own preference), so a truncated
    search still covers the region the heuristic lives in plus its
    neighbours.
    """
    fwd = legal_blocks(family, shape, dtype, "fwd", budget=budget)
    bwd = legal_blocks(family, shape, dtype, "bwd", budget=budget)
    pairs = list(itertools.product(fwd, bwd))
    if limit is not None:
        pairs = pairs[:max(1, int(limit))]
    return [
        Candidate(family=family, shape=tuple(int(s) for s in shape),
                  dtype=dtype, block_fwd=f, block_bwd=b)
        for f, b in pairs
    ]
