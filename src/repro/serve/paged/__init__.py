"""repro.serve.paged — paged KV-block serving.

:class:`BlockPool` (fixed-size KV blocks, free list, refcounts, COW),
:class:`PrefixIndex` (trie over prompt blocks / content hashes for
operator fields -> shared blocks), :class:`PagedLMEngine` (the LM slot
engine over block tables, bit-identical to the dense path), and
:class:`AsyncServeFrontend` (submit_async / stream with per-request
deadline accounting).
"""
from .engine import PagedLMEngine  # noqa: F401
from .frontend import AsyncServeFrontend  # noqa: F401
from .pool import NULL_BLOCK, BlockPool  # noqa: F401
from .prefix import PrefixIndex, content_key  # noqa: F401
