"""Shared test helpers: tolerance math, random operands, shape tables.

One home for the budget/tolerance machinery that test_kernels.py,
test_kernels_diff.py and test_tune.py used to copy-paste: the Thm 3.2
elementwise budget assertion, the relative-error norm, seeded complex
operands, the per-policy grad tolerances, and the calibration-entry
builders the tune tests seed states with.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import get_policy
from repro.core.precision import ComplexPair
from repro.core.theory import prec_upper_bound
from repro.precision import POLICIES

F32_EPS = float(np.finfo(np.float32).eps)

POLICY_NAMES = sorted(POLICIES)

#: policies whose contract site stores at a half format — only these
#: have a storage rounding to fuse / quantise
HALF_POLICY_NAMES = [
    n for n in POLICY_NAMES
    if get_policy(n).at("fno/layer0/spectral/contract").spectral_is_half
]

#: one small shape per mode dimensionality (kept tiny: every case jit-
#: compiles its own interpret-mode kernel)
MODES_BY_NDIM = {1: (7,), 2: (3, 5), 3: (2, 3, 2)}

#: odd / non-MXU-aligned spatial grids per dimensionality for the fused
#: megakernel legs — the truncated-DFT factors must be exact on grids
#: that are not powers of two and not even
SPATIAL_BY_NDIM = {1: (15,), 2: (9, 11), 3: (6, 7, 5)}

#: grad-parity tolerance per registry policy: tight where the contract
#: site stays f32 (full and the AMP-only sets), storage-precision-sized
#: where it quantises (half/fp8 families)
GRAD_TOLS = {
    "full": 1e-5,
    "amp_bf16": 1e-4,
    "amp_fp16": 1e-4,
    "half_fno_only": 0.03,
    "mixed_fno_bf16": 0.08,
    "mixed_fno_fp16": 0.03,
    "sim_fp8_e4m3": 0.03,
    "sim_fp8_e5m2": 0.03,
}


def rand_complex(rng, shape, scale=0.5):
    return jnp.asarray(
        scale * (rng.randn(*shape) + 1j * rng.randn(*shape)), jnp.complex64
    )


def to_np_complex(y):
    if isinstance(y, ComplexPair):
        y = y.to_complex()
    return np.asarray(y)


def rel_err(a, b):
    dt = np.complex128 if np.iscomplexobj(np.asarray(a)) else np.float64
    a = np.asarray(a, dt).ravel()
    b = np.asarray(b, dt).ravel()
    return float(np.linalg.norm(a - b) / (np.linalg.norm(b) + 1e-12))


def assert_within_budget(y_pallas, y_einsum, eps, mag, stages, label,
                         f32_c=32, atol=1e-5):
    """|pallas − einsum| ≤ stages·4εM + f32_c·ε_f32·M + atol, elementwise.

    ``mag`` is the contraction of operand magnitudes — the per-output
    empirical M of Thm 3.2; each requantising stage of either path may
    contribute up to ``prec_upper_bound(eps, M) = 4εM``.
    """
    budget = stages * prec_upper_bound(eps, mag) + f32_c * F32_EPS * mag + atol
    diff = np.abs(to_np_complex(y_pallas) - to_np_complex(y_einsum))
    worst = float((diff - budget).max())
    assert np.all(diff <= budget), (
        f"{label}: pallas-vs-einsum exceeds the Thm 3.2 budget by {worst:.3e}"
        f" (max diff {diff.max():.3e}, min budget {budget.min():.3e})"
    )


def fused_mag(x, wgr, wgi, spatial, modes):
    """Composed per-output magnitude M of the fused pipeline: |x| pushed
    through the absolute forward DFT factors, the absolute gathered
    weight, and the absolute inverse factors — the envelope every
    rounding stage of either the fused or the staged path lives under."""
    from repro.kernels.spectral_contract import _fused_rows, fused_factors

    ndim = len(modes)
    facs = fused_factors(spatial, modes)

    def apply(a, f, axis, f_axis):
        return np.moveaxis(
            np.tensordot(a, f, axes=[[axis], [f_axis]]), -1, axis)

    mag = np.abs(np.asarray(x, np.float64))
    for k in range(ndim):
        fr, fi = facs[2 * k], facs[2 * k + 1]
        mag = apply(mag, np.abs(fr + 1j * fi), 2 + k, 1)
    B, I = mag.shape[:2]
    mag = mag.reshape(B, I, -1)
    w_abs = np.abs(np.asarray(wgr, np.float64)
                   + 1j * np.asarray(wgi, np.float64))
    mag = np.einsum("bim,iom->bom", mag, w_abs)
    rows = _fused_rows(spatial, modes)
    O = mag.shape[1]
    mag = mag.reshape(B, O, *rows)
    for k in range(ndim - 1):
        gr, gi = facs[2 * ndim + 2 * k], facs[2 * ndim + 2 * k + 1]
        mag = apply(mag, np.abs(gr + 1j * gi), 2 + k, 0)
    cr, ci = facs[4 * ndim - 2], facs[4 * ndim - 1]
    ax = 2 + ndim - 1
    return apply(mag, np.abs(cr) + np.abs(ci), ax, 0)


def calibration_entry(family, shape, dtype="bfloat16", block_fwd=8,
                      block_bwd=8, **kw):
    """A structurally-valid calibration-cache entry for the current
    backend/kernel version (override any field via ``kw``)."""
    from repro.kernels.spectral_contract import KERNEL_VERSION

    ent = {
        "family": family, "shape": list(shape), "dtype": dtype,
        "backend": jax.default_backend(), "kernel_version": KERNEL_VERSION,
        "block_fwd": block_fwd, "block_bwd": block_bwd, "validated": True,
    }
    ent.update(kw)
    return ent


def calibration_state(tmp_path, *entries, name="state.json", **header):
    """Write a calibration state holding ``entries`` and return its path."""
    from repro.tune import cache as cache_mod

    state = cache_mod.CalibrationCache(
        entries={}, backend=jax.default_backend())
    for ent in entries:
        state.put(ent)
    for k, v in header.items():
        setattr(state, k, v)
    return cache_mod.save(state, tmp_path / name)
