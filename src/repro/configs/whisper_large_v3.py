"""whisper-large-v3 [audio] — enc-dec backbone; conv frontend is a STUB
(input_specs provides precomputed frame embeddings).
[arXiv:2212.04356; unverified]"""
from .base import LMArchConfig

CONFIG = LMArchConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab=51866, head_dim=64,
    encoder_decoder=True, dec_layers=32, max_dec_len=448,
    frontend="audio_stub",
)

SMOKE = LMArchConfig(
    name="whisper-large-v3-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, head_dim=16,
    encoder_decoder=True, dec_layers=2, max_dec_len=16,
    frontend="audio_stub",
)
