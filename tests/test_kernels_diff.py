"""Differential harness: the Pallas spectral path vs the einsum reference.

This is the proof obligation for the training-grade kernel path
(DESIGN/paper mapping: the contraction is the precision-critical site of
Thm 3.2, so "numerically interchangeable" means *within the theorem's own
budget*, not bitwise):

  * forward: for every registry policy, random (B, I, O) including
    non-MXU-aligned channels, 1D/2D/3D modes, dense and CP factorisations,
    ``|pallas − einsum| ≤ n_stages · 4 ε M + c·ε_f32·M`` elementwise, where
    ``ε`` is the policy's storage grid spacing (``SitePrecision.eps``),
    ``M`` the contraction of operand magnitudes actually flowing through
    the site (the empirical sup bound of Thm 3.2), ``4εM`` is
    ``core.theory.prec_upper_bound``, one term per requantising stage of
    the memory-greedy einsum path, plus an f32 accumulation-order term;
  * backward: ``value_and_grad`` through ``spectral_conv_apply`` and a
    full FNO/TFNO train step (incl. the fp16 loss-scale interaction)
    matches the einsum path per policy, and the custom VJP passes an
    fp64 central-difference gradcheck on a tiny dense case;
  * edges: non-``block_m``-divisible mode counts exercise the kernel's
    padding path, Tucker params fall back to the einsum path, and
    non-dense operands are rejected loudly rather than silently.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    FULL,
    get_policy,
    init_spectral_weights,
    spectral_conv_apply,
)
from repro.core.precision import ComplexPair
from repro.core.spectral import _cp_exprs, _dense_expr
from repro.kernels import ops, ref
from repro.kernels.spectral_contract import (
    pick_block_m,
    spectral_contract_pallas,
)
from repro.models import FNOConfig, fno_apply, init_fno
from repro.precision import POLICIES
from repro.train import Trainer, TrainerConfig, relative_l2

from helpers import (
    GRAD_TOLS,
    HALF_POLICY_NAMES,
    MODES_BY_NDIM,
    POLICY_NAMES,
    SPATIAL_BY_NDIM,
    assert_within_budget as _assert_within_budget,
    fused_mag,
    rand_complex as _randc,
    rel_err as _rel_err,
)

jax.config.update("jax_platform_name", "cpu")


def _diff_dense(policy_name, B, I, O, modes, seed, block_m=8):
    policy = get_policy(policy_name)
    site = policy.at("fno/layer0/spectral/contract")
    rng = np.random.RandomState(seed)
    x = _randc(rng, (B, I, *modes))
    w = _randc(rng, (I, O, *modes))
    y_e = site.contract(_dense_expr(len(modes)), x, w)
    y_p = ops.spectral_contract(x, w, policy=site, block_m=block_m)
    mag = np.einsum(
        _dense_expr(len(modes)).replace(" ", ""), np.abs(x), np.abs(w))
    # two requantising stages: one per path's storage rounding of the result
    _assert_within_budget(
        y_p, y_e, site.eps, mag, stages=2,
        label=f"dense {policy_name} B{B} I{I} O{O} modes{modes}")


def _diff_cp(policy_name, B, I, O, R, modes, seed, block_m=8):
    policy = get_policy(policy_name)
    site = policy.at("fno/layer0/spectral/contract")
    ndim = len(modes)
    rng = np.random.RandomState(seed)
    x = _randc(rng, (B, I, *modes))
    lam = _randc(rng, (R,))
    ui = _randc(rng, (I, R))
    uo = _randc(rng, (O, R))
    factors = [_randc(rng, (m, R)) for m in modes]
    expr = _cp_exprs(ndim)
    y_e = site.contract(expr, x, lam, ui, uo, *factors)
    y_p = ops.spectral_contract_cp(x, lam, ui, uo, factors, policy=site,
                                   block_m=block_m)
    mag = np.einsum(expr.replace(" ", ""), np.abs(x), np.abs(lam),
                    np.abs(ui), np.abs(uo), *[np.abs(f) for f in factors])
    # the memory-greedy einsum path requantises after each of its
    # (n_operands − 1) pairwise steps; the kernel path rounds its three
    # factorised stages — budget one 4εM term per stage on either side
    _assert_within_budget(
        y_p, y_e, site.eps, mag, stages=(ndim + 3) + 3,
        label=f"cp {policy_name} B{B} I{I} O{O} R{R} modes{modes}")


def _diff_lshared(policy_name, B, I, O, L, Mm, seed, block_l=2):
    """The SFNO order-shared contraction ``bilm,iol->bolm``."""
    policy = get_policy(policy_name)
    site = policy.at("sfno/layer0/spectral/contract")
    rng = np.random.RandomState(seed)
    x = _randc(rng, (B, I, L, Mm))
    w = _randc(rng, (I, O, L))
    y_e = site.contract("bilm,iol->bolm", x, w)
    y_p = ops.spectral_contract_lshared(x, w, policy=site, block_l=block_l)
    mag = np.einsum("bilm,iol->bolm", np.abs(x), np.abs(w))
    _assert_within_budget(
        y_p, y_e, site.eps, mag, stages=2,
        label=f"lshared {policy_name} B{B} I{I} O{O} L{L} M{Mm}")


class TestDifferentialAllPolicies:
    """Full registry-policy × factorisation × dimensionality cross."""

    @pytest.mark.parametrize("policy_name", POLICY_NAMES)
    @pytest.mark.parametrize("ndim", [1, 2, 3])
    def test_dense(self, policy_name, ndim):
        _diff_dense(policy_name, B=2, I=3, O=4, modes=MODES_BY_NDIM[ndim],
                    seed=ndim)

    @pytest.mark.parametrize("policy_name", POLICY_NAMES)
    def test_lshared(self, policy_name):
        _diff_lshared(policy_name, B=2, I=3, O=4, L=5, Mm=4, seed=21)

    @pytest.mark.parametrize("policy_name", POLICY_NAMES)
    @pytest.mark.parametrize("ndim", [1, 2, 3])
    def test_cp(self, policy_name, ndim):
        _diff_cp(policy_name, B=2, I=3, O=4, R=3, modes=MODES_BY_NDIM[ndim],
                 seed=10 + ndim)

    @pytest.mark.slow
    @given(
        st.integers(min_value=1, max_value=3),
        st.integers(min_value=1, max_value=13),
        st.integers(min_value=1, max_value=13),
        st.integers(min_value=1, max_value=21),
        st.sampled_from(sorted(POLICIES)),
        st.sampled_from(["dense", "cp"]),
    )
    @settings(max_examples=12, deadline=None)
    def test_property_random_shapes(self, B, I, O, M, policy_name, kind):
        """Random non-MXU-aligned channels and mode counts (1D keeps the
        example budget affordable; the ndim cross above covers 2D/3D)."""
        seed = B * 10000 + I * 1000 + O * 100 + M
        if kind == "dense":
            _diff_dense(policy_name, B, I, O, (M,), seed)
        else:
            _diff_cp(policy_name, B, I, O, max(1, min(I, O)), (M,), seed)


class TestPaddingAndFallback:
    def test_block_m_padding_edge(self):
        """Modes not divisible by block_m exercise the zero-pad + slice
        path of all three dense kernels (fwd and both backward)."""
        rng = np.random.RandomState(3)
        x = _randc(rng, (2, 4, 13))   # M=13, block_m=8 -> pad to 16
        w = _randc(rng, (4, 5, 13))
        cr = jnp.asarray(rng.randn(2, 5, 13), jnp.float32)

        def loss(fn):
            def f(xr, xi, wr, wi):
                yr, yi = fn(xr, xi, wr, wi)
                return jnp.sum(yr * cr + yi * cr)
            return f

        args = tuple(jnp.asarray(a, jnp.float32)
                     for a in (x.real, x.imag, w.real, w.imag))
        pl_fn = loss(lambda *a: spectral_contract_pallas(
            *a, block_m=8, interpret=True))

        def ref_pair(xr, xi, wr, wi):
            y = ref.spectral_contract_ref(
                jax.lax.complex(xr, xi), jax.lax.complex(wr, wi))
            return jnp.real(y), jnp.imag(y)

        v1, g1 = jax.value_and_grad(pl_fn, argnums=(0, 1, 2, 3))(*args)
        v2, g2 = jax.value_and_grad(loss(ref_pair), argnums=(0, 1, 2, 3))(*args)
        np.testing.assert_allclose(float(v1), float(v2), rtol=1e-5)
        for a, b in zip(g1, g2, strict=True):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-4)

    def test_ops_wrapper_padding_multimode(self):
        rng = np.random.RandomState(4)
        x = _randc(rng, (2, 3, 3, 5))  # M=15, block_m=4 -> pad to 16
        w = _randc(rng, (3, 4, 3, 5))
        got = ops.spectral_contract(x, w, policy=FULL, block_m=4)
        want = jnp.einsum("bixy,ioxy->boxy", x, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    def test_tucker_falls_back_to_einsum(self):
        rng = np.random.RandomState(5)
        params = init_spectral_weights(jax.random.PRNGKey(5), 4, 4, (3, 3),
                                       "tucker")
        x = jnp.asarray(rng.randn(2, 4, 8, 8), jnp.float32)
        a = spectral_conv_apply(params, x, (3, 3), FULL, use_pallas=False)
        b = spectral_conv_apply(params, x, (3, 3), FULL, use_pallas=True)
        assert jnp.array_equal(a, b), "tucker must take the identical einsum path"

    def test_non_dense_operands_raise(self):
        rng = np.random.RandomState(6)
        x = _randc(rng, (2, 4, 8))
        with pytest.raises(ValueError, match="dense-only"):
            ops.spectral_contract(x, _randc(rng, (4, 4)), policy=FULL)
        with pytest.raises(ValueError, match="ComplexPair"):
            ops.spectral_contract(x, {"U_i_re": np.zeros((4, 2))}, policy=FULL)
        with pytest.raises(ValueError, match="disagree"):
            ops.spectral_contract(x, _randc(rng, (5, 4, 8)), policy=FULL)
        with pytest.raises(ValueError, match="mode factors"):
            ops.spectral_contract_cp(
                x, _randc(rng, (3,)), _randc(rng, (4, 3)), _randc(rng, (4, 3)),
                [], policy=FULL)

    def test_resolve_use_pallas_env(self, monkeypatch):
        from repro.kernels.ops import resolve_use_pallas

        assert resolve_use_pallas(True) is True
        assert resolve_use_pallas(False) is False
        monkeypatch.setenv("REPRO_USE_PALLAS", "1")
        assert resolve_use_pallas(None) is True
        monkeypatch.setenv("REPRO_USE_PALLAS", "0")
        assert resolve_use_pallas(None) is False
        monkeypatch.delenv("REPRO_USE_PALLAS")
        assert resolve_use_pallas(None) == (jax.default_backend() == "tpu")

    def test_pick_block_m_respects_budget(self):
        from repro.kernels.spectral_contract import (
            cp_vmem_bytes, vmem_bytes, vmem_bytes_bwd)

        bm = pick_block_m(32, 64, 64, 4096)
        assert bm in (8, 16, 32, 64, 128, 256, 512)
        need = max(vmem_bytes(32, 64, 64, bm), vmem_bytes_bwd(32, 64, 64, bm))
        assert need <= 8 * 2 ** 20
        bm_cp = pick_block_m(32, 64, 64, 4096, rank=64)
        assert cp_vmem_bytes(32, 64, 64, 64, bm_cp) <= 8 * 2 ** 20


# ---------------------------------------------------------------------------
# Gradients
# ---------------------------------------------------------------------------


def _grad_leaves(g):
    return jax.tree_util.tree_leaves(g)


def _grad_parity(policy_name, factorization, modes, spatial, seed=11):
    policy = get_policy(policy_name)
    rng = np.random.RandomState(seed)
    params = init_spectral_weights(
        jax.random.PRNGKey(seed), 4, 4, modes, factorization)
    x = jnp.asarray(rng.randn(2, 4, *spatial), jnp.float32)

    def loss(p, use_pallas):
        y = spectral_conv_apply(p, x, modes, policy, use_pallas=use_pallas)
        return jnp.mean(y ** 2)

    l_e, g_e = jax.value_and_grad(loss)(params, False)
    l_p, g_p = jax.value_and_grad(loss)(params, True)
    tol = GRAD_TOLS[policy_name]
    assert abs(float(l_p) - float(l_e)) <= tol * (abs(float(l_e)) + 1e-6)
    for a, b in zip(_grad_leaves(g_p), _grad_leaves(g_e), strict=True):
        assert _rel_err(a, b) <= tol, (policy_name, factorization, modes)


class TestGradients:
    assert sorted(GRAD_TOLS) == POLICY_NAMES, "cover every registry policy"

    @pytest.mark.parametrize("policy_name", POLICY_NAMES)
    @pytest.mark.parametrize("factorization", ["dense", "cp"])
    def test_spectral_conv_value_and_grad_matches(self, policy_name,
                                                  factorization):
        _grad_parity(policy_name, factorization, (3, 3), (8, 8))

    @pytest.mark.parametrize("policy_name", ["full", "mixed_fno_bf16"])
    @pytest.mark.parametrize("factorization", ["dense", "cp"])
    @pytest.mark.parametrize("ndim", [1, 3])
    def test_spectral_conv_grads_1d_3d(self, policy_name, factorization,
                                       ndim):
        modes = MODES_BY_NDIM[ndim]
        spatial = tuple(2 * m + 2 for m in modes)
        _grad_parity(policy_name, factorization, modes, spatial, seed=ndim)

    @pytest.mark.parametrize("policy_name", ["full", "mixed_fno_bf16"])
    def test_lshared_grads_match_einsum(self, policy_name):
        """value_and_grad through the SFNO l-shared kernel vs the einsum
        path (both via the resolved contract site)."""
        policy = get_policy(policy_name)
        site = policy.at("sfno/layer0/spectral/contract")
        rng = np.random.RandomState(23)
        x = _randc(rng, (2, 3, 5, 4))
        w = _randc(rng, (3, 4, 5))

        def loss(w, use_pallas):
            if use_pallas:
                y = ops.spectral_contract_lshared(x, w, policy=site,
                                                  block_l=2)
            else:
                y = site.contract("bilm,iol->bolm", x, w)
            if isinstance(y, ComplexPair):
                return jnp.mean(y.abs2())
            return jnp.mean(jnp.abs(y) ** 2)

        l_e, g_e = jax.value_and_grad(loss, holomorphic=False)(w, False)
        l_p, g_p = jax.value_and_grad(loss, holomorphic=False)(w, True)
        tol = GRAD_TOLS[policy_name] * 10  # complex-cotangent casts add noise
        assert abs(float(l_p) - float(l_e)) <= tol * (abs(float(l_e)) + 1e-6)
        assert _rel_err(np.asarray(g_p), np.asarray(g_e)) <= tol

    @pytest.mark.slow
    @pytest.mark.parametrize("factorization", ["dense", "cp"])
    def test_train_step_parity_with_loss_scaling(self, factorization):
        """Full FNO/TFNO train steps through the Trainer, pallas vs
        einsum, under the fp16 policy whose ``train/loss_scale`` site is
        on — the loss-scale interaction rides through the custom VJP."""
        cfg = FNOConfig(in_channels=1, out_channels=1, hidden_channels=8,
                        lifting_channels=8, projection_channels=8,
                        n_layers=2, modes=(4, 4), factorization=factorization)
        params = init_fno(jax.random.PRNGKey(0), cfg)
        rng = np.random.RandomState(0)
        batches = [
            {"a": jnp.asarray(rng.randn(4, 1, 12, 12), jnp.float32),
             "u": jnp.asarray(rng.randn(4, 1, 12, 12), jnp.float32)}
            for _ in range(3)
        ]

        def loss_fn(p, batch, policy, use_pallas=None):
            c = dataclasses.replace(cfg, use_pallas=use_pallas)
            return relative_l2(fno_apply(p, batch["a"], c, policy), batch["u"])

        from repro.core import PrecisionSchedule

        results = {}
        for up in (False, True):
            tr = Trainer(loss_fn, params, TrainerConfig(
                total_steps=3,
                schedule=PrecisionSchedule.constant("mixed_fno_fp16"),
                use_pallas=up,
            ))
            hist = tr.run(lambda step: batches[step])
            results[up] = (tr.params, tr.scale_state, hist)
        p_e, s_e, h_e = results[False]
        p_p, s_p, h_p = results[True]
        assert float(s_e.scale) == float(s_p.scale)
        # two independent half-storage roundings accumulated over 3 fp16
        # train steps; 2e-3 was borderline on some CPU backends (2.15e-3
        # observed), so the budget carries headroom over the observed peak
        for a, b in zip(_grad_leaves(p_p), _grad_leaves(p_e), strict=True):
            assert _rel_err(a, b) <= 3e-3
        for he, hp in zip(h_e, h_p, strict=True):
            assert abs(he["loss"] - hp["loss"]) <= 0.02 * (abs(he["loss"]) + 1e-6)

    def test_fd_gradcheck_fp64_dense(self):
        """fp64 central-difference check of the custom VJP itself (both
        backward kernels), on a tiny dense case in interpret mode."""
        jax.config.update("jax_enable_x64", True)
        try:
            rng = np.random.RandomState(2)
            shapes = [(1, 2, 3), (1, 2, 3), (2, 3, 3), (2, 3, 3)]
            args = [jnp.asarray(rng.randn(*s), jnp.float64) for s in shapes]
            cr = jnp.asarray(rng.randn(1, 3, 3), jnp.float64)
            ci = jnp.asarray(rng.randn(1, 3, 3), jnp.float64)

            def loss(xr, xi, wr, wi):
                yr, yi = spectral_contract_pallas(
                    xr, xi, wr, wi, block_m=8, interpret=True)
                return jnp.sum(yr * cr + yi * ci)

            grads = jax.grad(loss, argnums=(0, 1, 2, 3))(*args)
            h = 1e-6
            for k in range(4):
                g = np.asarray(grads[k])
                fd = np.zeros_like(g)
                flat = np.asarray(args[k]).copy()
                for idx in np.ndindex(g.shape):
                    plus = flat.copy(); plus[idx] += h
                    minus = flat.copy(); minus[idx] -= h
                    ap = list(args); ap[k] = jnp.asarray(plus)
                    am = list(args); am[k] = jnp.asarray(minus)
                    fd[idx] = (float(loss(*ap)) - float(loss(*am))) / (2 * h)
                np.testing.assert_allclose(g, fd, rtol=1e-6, atol=1e-7,
                                           err_msg=f"arg {k}")
        finally:
            jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# Fused quantize prologue (cast_to)
# ---------------------------------------------------------------------------


class TestFusedCastPrologue:
    """The in-kernel quantize fusion must be numerically *invisible*:
    same storage grid, same rounding, one fewer HBM round-trip."""

    def _xw(self, seed=17, B=2, I=3, O=4, modes=(3, 5)):
        rng = np.random.RandomState(seed)
        return _randc(rng, (B, I, *modes)), _randc(rng, (I, O, *modes))

    @pytest.mark.parametrize("policy_name", HALF_POLICY_NAMES)
    def test_forward_bit_identical_to_unfused(self, policy_name):
        """In-VMEM ``astype(half)`` is the same rounding as the HBM
        ``ComplexPair.from_complex`` pass it replaces — the fused forward
        is bitwise equal, not merely within budget."""
        site = get_policy(policy_name).at("fno/layer0/spectral/contract")
        x, w = self._xw()
        y_f = ops.spectral_contract(x, w, policy=site, block_m=4,
                                    fuse_casts=True)
        y_u = ops.spectral_contract(x, w, policy=site, block_m=4,
                                    fuse_casts=False)
        assert jnp.array_equal(jnp.asarray(y_f), jnp.asarray(y_u)), (
            policy_name)

    @pytest.mark.parametrize("policy_name", HALF_POLICY_NAMES)
    def test_fused_within_budget_vs_einsum(self, policy_name):
        site = get_policy(policy_name).at("fno/layer0/spectral/contract")
        x, w = self._xw(seed=18)
        y_e = site.contract(_dense_expr(2), x, w)
        y_p = ops.spectral_contract(x, w, policy=site, block_m=4,
                                    fuse_casts=True)
        mag = np.einsum("bixy,ioxy->boxy", np.abs(x), np.abs(w))
        _assert_within_budget(y_p, y_e, site.eps, mag, stages=2,
                              label=f"dense-fused {policy_name}")

    @pytest.mark.parametrize("policy_name", HALF_POLICY_NAMES)
    def test_grads_match_unfused(self, policy_name):
        """The fused backward writes dx/dw at f32 (the residuals'
        dtype); the unfused one rounds them to half — they may differ
        only by that final storage rounding."""
        site = get_policy(policy_name).at("fno/layer0/spectral/contract")
        x, w = self._xw(seed=19)

        def loss(x, w, fuse):
            y = ops.spectral_contract(x, w, policy=site, block_m=4,
                                      fuse_casts=fuse)
            return jnp.sum(jnp.abs(jnp.asarray(y)) ** 2)

        l_f, g_f = jax.value_and_grad(loss, argnums=(0, 1))(x, w, True)
        l_u, g_u = jax.value_and_grad(loss, argnums=(0, 1))(x, w, False)
        np.testing.assert_allclose(float(l_f), float(l_u), rtol=1e-6)
        tol = max(8 * site.eps, 1e-4)
        for a, b in zip(g_f, g_u, strict=True):
            assert _rel_err(a, b) <= tol, policy_name

    def test_full_precision_site_never_fuses(self):
        """No quantize rule means nothing to fuse: both flags produce
        the identical f32 path."""
        x, w = self._xw(seed=20)
        y_t = ops.spectral_contract(x, w, policy=FULL, block_m=4,
                                    fuse_casts=True)
        y_f = ops.spectral_contract(x, w, policy=FULL, block_m=4,
                                    fuse_casts=False)
        assert jnp.array_equal(y_t, y_f)

    def test_pair_inputs_skip_fusion(self):
        """Operands already rounded to half pairs have no cast to fuse;
        the flag must be a no-op on them."""
        site = get_policy("mixed_fno_bf16").at("fno/layer0/spectral/contract")
        x, w = self._xw(seed=21)
        xp = ComplexPair.from_complex(x, site.spectral_dtype)
        wp = ComplexPair.from_complex(w, site.spectral_dtype)
        y_t = ops.spectral_contract(xp, wp, policy=site, block_m=4,
                                    fuse_casts=True)
        y_f = ops.spectral_contract(xp, wp, policy=site, block_m=4,
                                    fuse_casts=False)
        assert jnp.array_equal(y_t.re, y_f.re)
        assert jnp.array_equal(y_t.im, y_f.im)

    def test_resolve_fuse_casts_env_and_flag(self, monkeypatch):
        from repro.kernels.ops import resolve_fuse_casts

        assert resolve_fuse_casts(True) is True
        assert resolve_fuse_casts(False) is False
        monkeypatch.setenv("REPRO_FUSE_CASTS", "0")
        assert resolve_fuse_casts(None) is False
        assert resolve_fuse_casts(True) is True  # explicit beats env
        monkeypatch.setenv("REPRO_FUSE_CASTS", "1")
        assert resolve_fuse_casts(None) is True
        monkeypatch.delenv("REPRO_FUSE_CASTS")
        assert resolve_fuse_casts(None) is True  # default ON


# ---------------------------------------------------------------------------
# Fused rFFT -> contract -> irFFT megakernel
# ---------------------------------------------------------------------------


def _fused_layer(seed, I, O, modes):
    return init_spectral_weights(
        jax.random.PRNGKey(seed), I, O, modes, "dense")


def _diff_fused(policy_name, B, I, O, spatial, modes, seed):
    """The one-grid megakernel vs the staged einsum reference, under the
    composed Thm 3.2 budget: each path has (at most) four requantising
    stages — forward transform, quantise, contract, inverse transform —
    so the elementwise budget carries stages=8, one ``4 eps M`` term per
    stage of either side, with ``M`` the composed magnitude envelope of
    the whole pipeline (``helpers.fused_mag``)."""
    policy = get_policy(policy_name)
    fft_in = policy.at("fno/layer0/spectral/fft_in")
    ctr = policy.at("fno/layer0/spectral/contract")
    assert ops.fused_spectral_viable(fft_in, ctr, B, I, O, spatial, modes), (
        "test shape must engage the fused path", spatial, modes)
    rng = np.random.RandomState(seed)
    params = _fused_layer(seed, I, O, modes)
    x = jnp.asarray(rng.randn(B, I, *spatial), jnp.float32)

    y_f = spectral_conv_apply(params, x, modes, policy, use_pallas=True,
                              fuse_spectral=True, site="fno/layer0/spectral")
    y_s = spectral_conv_apply(params, x, modes, policy, use_pallas=False,
                              site="fno/layer0/spectral")
    assert y_f.shape == y_s.shape == (B, O, *spatial)
    assert y_f.dtype == y_s.dtype, (policy_name, y_f.dtype, y_s.dtype)

    xs = fft_in.stabilize(x)
    wgr, wgi = ops.gather_corner_weights(
        params["w_re"], params["w_im"], modes)
    mag = fused_mag(np.asarray(xs, np.float64), np.asarray(wgr, np.float64),
                    np.asarray(wgi, np.float64), spatial, modes)
    _assert_within_budget(
        np.asarray(y_f, np.float64), np.asarray(y_s, np.float64),
        ctr.eps, mag, stages=8,
        label=f"fused {policy_name} B{B} I{I} O{O} "
              f"spatial{spatial} modes{modes}")


class TestFusedMegakernel:
    """Differential proof for the ``spectral_fused`` family: the whole
    rFFT -> contract -> irFFT pipeline in one Pallas grid must stay
    within the composed Thm 3.2 budget against the staged einsum path,
    for every registry policy, on odd / non-MXU-aligned grids."""

    @pytest.mark.parametrize("policy_name", POLICY_NAMES)
    @pytest.mark.parametrize("ndim", [1, 2, 3])
    def test_fused_vs_staged_all_policies(self, policy_name, ndim):
        _diff_fused(policy_name, B=2, I=3, O=4,
                    spatial=SPATIAL_BY_NDIM[ndim],
                    modes=MODES_BY_NDIM[ndim], seed=40 + ndim)

    def test_fused_matches_einsum_reference_full(self):
        """Against the pure jnp staged reference (no Pallas anywhere),
        full precision: the truncated-DFT factorisation itself."""
        _diff_fused("full", B=1, I=2, O=2, spatial=(8, 16),
                    modes=(4, 5), seed=51)

    @pytest.mark.slow
    @given(
        st.integers(min_value=1, max_value=3),
        st.integers(min_value=1, max_value=5),
        st.integers(min_value=1, max_value=5),
        st.integers(min_value=5, max_value=14),
        st.integers(min_value=5, max_value=15),
        st.sampled_from(sorted(POLICIES)),
    )
    @settings(max_examples=10, deadline=None)
    def test_property_fuzzed_shapes(self, B, I, O, S0, S1, policy_name):
        """Hypothesis-fuzzed 2D shapes: odd spatial dims, modes that do
        not divide the batch tile, Nyquist-touching last-axis modes."""
        m0 = max(1, S0 // 2 - 1)           # corner blocks must not overlap
        m1 = S1 // 2 + 1                   # retain the full rfft extent
        seed = B * 100000 + I * 10000 + O * 1000 + S0 * 16 + S1
        _diff_fused(policy_name, B, I, O, (S0, S1), (m0, m1), seed)

    def test_fused_fp64_gradcheck(self):
        """fp64 central-difference check of the fused custom VJP itself
        (transposed-pipeline backward kernel), on a tiny 2D case in
        interpret mode, sampling entries of each operand."""
        from repro.kernels.spectral_contract import spectral_fused_pallas

        jax.config.update("jax_enable_x64", True)
        try:
            spatial, modes = (7, 8), (3, 3)
            rows_flat = (2 * modes[0]) * modes[1]
            rng = np.random.RandomState(7)
            shapes = [(1, 2, *spatial), (2, 3, rows_flat), (2, 3, rows_flat)]
            args = [jnp.asarray(rng.randn(*s), jnp.float64) for s in shapes]
            c = jnp.asarray(rng.randn(1, 3, *spatial), jnp.float64)

            def loss(x, wgr, wgi):
                y = spectral_fused_pallas(
                    x, wgr, wgi, modes=modes, block_b=1, interpret=True)
                return jnp.sum(y * c)

            grads = jax.grad(loss, argnums=(0, 1, 2))(*args)
            h = 1e-6
            for k in range(3):
                g = np.asarray(grads[k])
                flat = np.asarray(args[k], np.float64)
                idxs = [np.unravel_index(j, g.shape) for j in
                        rng.choice(g.size, size=min(8, g.size),
                                   replace=False)]
                for idx in idxs:
                    plus = flat.copy(); plus[idx] += h
                    minus = flat.copy(); minus[idx] -= h
                    ap = list(args); ap[k] = jnp.asarray(plus)
                    am = list(args); am[k] = jnp.asarray(minus)
                    fd = (float(loss(*ap)) - float(loss(*am))) / (2 * h)
                    np.testing.assert_allclose(
                        g[idx], fd, rtol=1e-5, atol=1e-6,
                        err_msg=f"arg {k} idx {idx}")
        finally:
            jax.config.update("jax_enable_x64", False)

    @pytest.mark.slow
    def test_train_step_parity_fused_vs_staged_fp16_loss_scale(self):
        """Full FNO train steps through the Trainer, fused megakernel vs
        the staged Pallas path, under the fp16 policy whose
        ``train/loss_scale`` site is on — the loss-scale interaction
        rides through the fused custom VJP."""
        cfg = FNOConfig(in_channels=1, out_channels=1, hidden_channels=8,
                        lifting_channels=8, projection_channels=8,
                        n_layers=2, modes=(4, 4), factorization="dense")
        params = init_fno(jax.random.PRNGKey(1), cfg)
        rng = np.random.RandomState(1)
        batches = [
            {"a": jnp.asarray(rng.randn(4, 1, 12, 12), jnp.float32),
             "u": jnp.asarray(rng.randn(4, 1, 12, 12), jnp.float32)}
            for _ in range(3)
        ]

        from repro.core import PrecisionSchedule

        results = {}
        for fuse in (False, True):
            def loss_fn(p, batch, policy, use_pallas=None, fuse=fuse):
                c = dataclasses.replace(cfg, use_pallas=use_pallas,
                                        fuse_spectral=fuse)
                return relative_l2(fno_apply(p, batch["a"], c, policy),
                                   batch["u"])

            tr = Trainer(loss_fn, params, TrainerConfig(
                total_steps=3,
                schedule=PrecisionSchedule.constant("mixed_fno_fp16"),
                use_pallas=True,
            ))
            hist = tr.run(lambda step: batches[step])
            results[fuse] = (tr.params, tr.scale_state, hist)
        p_s, s_s, h_s = results[False]
        p_f, s_f, h_f = results[True]
        assert float(s_s.scale) == float(s_f.scale)
        # both paths round the spectrum onto the same fp16 grid but order
        # their f32 accumulations differently; 3 accumulated steps
        for a, b in zip(_grad_leaves(p_f), _grad_leaves(p_s), strict=True):
            assert _rel_err(a, b) <= 5e-3
        for hs, hf in zip(h_s, h_f, strict=True):
            assert abs(hs["loss"] - hf["loss"]) <= 0.02 * (abs(hs["loss"]) + 1e-6)

    def test_unviable_shapes_fall_back_to_staged(self):
        """Corner overlap (2m > S) and non-dense factorisations must
        keep the staged path — same result with the flag forced on."""
        policy = get_policy("full")
        rng = np.random.RandomState(9)
        x = jnp.asarray(rng.randn(2, 3, 5, 8), jnp.float32)
        params = _fused_layer(9, 3, 4, (3, 3))  # 2*3 > 5: unsupported
        fft_in = policy.at("fno/layer0/spectral/fft_in")
        ctr = policy.at("fno/layer0/spectral/contract")
        assert not ops.fused_spectral_viable(
            fft_in, ctr, 2, 3, 4, (5, 8), (3, 3))
        y_on = spectral_conv_apply(params, x, (3, 3), policy,
                                   use_pallas=True, fuse_spectral=True)
        y_off = spectral_conv_apply(params, x, (3, 3), policy,
                                    use_pallas=True, fuse_spectral=False)
        np.testing.assert_allclose(np.asarray(y_on), np.asarray(y_off),
                                   rtol=1e-6, atol=1e-6)

    def test_resolve_fuse_spectral_env_and_flag(self, monkeypatch):
        from repro.kernels.ops import resolve_fuse_spectral

        assert resolve_fuse_spectral(True) is True
        assert resolve_fuse_spectral(False) is False
        monkeypatch.setenv("REPRO_FUSE_SPECTRAL", "0")
        assert resolve_fuse_spectral(None) is False
        assert resolve_fuse_spectral(True) is True  # explicit beats env
        monkeypatch.setenv("REPRO_FUSE_SPECTRAL", "1")
        assert resolve_fuse_spectral(None) is True
        monkeypatch.delenv("REPRO_FUSE_SPECTRAL")
        assert resolve_fuse_spectral(None) is True  # default ON

    def test_telemetry_collector_forces_staged(self):
        """An active autoprec collector must veto the fused path: its
        per-stage taps observe the HBM spectrum the megakernel never
        materialises."""
        from repro.autoprec.telemetry import TraceCollector, collecting

        policy = get_policy("full")
        fft_in = policy.at("fno/layer0/spectral/fft_in")
        ctr = policy.at("fno/layer0/spectral/contract")
        assert ops.fused_spectral_viable(
            fft_in, ctr, 2, 3, 4, (9, 11), (3, 5))
        with collecting(TraceCollector()):
            assert not ops.fused_spectral_viable(
                fft_in, ctr, 2, 3, 4, (9, 11), (3, 5))
