"""Per-architecture smoke tests (assignment requirement): instantiate the
REDUCED config of each family and run one forward + one train step on CPU,
asserting output shapes and no NaNs.  Also decode-step smoke per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.core import AMP_BF16, FULL
from repro.data import lm_inputs
from repro.models.lm import (
    init_cache,
    init_lm,
    init_whisper,
    init_whisper_cache,
    lm_decode_step,
    lm_forward,
    whisper_decode_step,
    whisper_encode,
    whisper_forward,
)
from repro.train.losses import cross_entropy

jax.config.update("jax_platform_name", "cpu")

B, S = 2, 32


def _decoder_batch(cfg):
    return lm_inputs(0, 0, B, S, cfg.vocab)


def _forward(cfg, params, batch, policy=FULL):
    if cfg.encoder_decoder:
        frames = jnp.ones((B, S, cfg.d_model), jnp.float32) * 0.1
        dec = batch["tokens"][:, : cfg.max_dec_len]
        return whisper_forward(params, frames, dec, cfg, policy)
    if cfg.frontend == "vision_stub":
        patches = jnp.ones((B, cfg.n_patches, cfg.d_model), jnp.float32) * 0.1
        logits, _ = lm_forward(params, batch["tokens"], cfg, policy,
                               patch_embeds=patches)
        return logits
    logits, _ = lm_forward(params, batch["tokens"], cfg, policy)
    return logits


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = get_config(arch, smoke=True)
    init = init_whisper if cfg.encoder_decoder else init_lm
    params = init(jax.random.PRNGKey(0), cfg)
    batch = _decoder_batch(cfg)
    logits = _forward(cfg, params, batch)
    exp_s = min(cfg.max_dec_len, S) if cfg.encoder_decoder else (
        S + cfg.n_patches if cfg.frontend == "vision_stub" else S
    )
    assert logits.shape == (B, exp_s, cfg.vocab), logits.shape
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    init = init_whisper if cfg.encoder_decoder else init_lm
    params = init(jax.random.PRNGKey(1), cfg)
    batch = _decoder_batch(cfg)

    def loss_fn(p):
        logits = _forward(cfg, p, batch, AMP_BF16)
        if cfg.frontend == "vision_stub":
            logits = logits[:, cfg.n_patches :]  # loss on text positions
        T = min(logits.shape[1], batch["labels"].shape[1])
        return cross_entropy(logits[:, :T], batch["labels"][:, :T])

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.isfinite(np.asarray(g, np.float32)).all() for g in leaves)
    # gradient must reach the embedding at least
    assert any(float(jnp.abs(g.astype(jnp.float32)).max()) > 0 for g in leaves)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    if cfg.encoder_decoder:
        params = init_whisper(jax.random.PRNGKey(2), cfg)
        frames = jnp.ones((B, S, cfg.d_model), jnp.float32) * 0.1
        memory = whisper_encode(params, frames, cfg)
        cache = init_whisper_cache(params, memory, cfg, B)
        tok = jnp.zeros((B,), jnp.int32)
        for _ in range(3):
            logits, cache = whisper_decode_step(params, cache, tok, cfg)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        assert logits.shape == (B, cfg.vocab)
        assert np.isfinite(np.asarray(logits)).all()
        return
    params = init_lm(jax.random.PRNGKey(2), cfg)
    cache = init_cache(cfg, B, max_len=64)
    tok = jnp.zeros((B,), jnp.int32)
    for _ in range(3):
        logits, cache = lm_decode_step(params, cache, tok, cfg)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert int(cache["step"][0]) == 3


class TestDecodeMatchesForward:
    """Decode-step logits must match the full forward at each position —
    the KV-cache correctness contract (dense + MLA + SSD paths)."""

    @pytest.mark.parametrize("arch", ["smollm-360m", "deepseek-v2-lite-16b", "mamba2-370m", "hymba-1.5b"])
    def test_match(self, arch):
        import dataclasses

        cfg = get_config(arch, smoke=True)
        if cfg.moe_experts:
            # MoE capacity drops are batch-dependent (an 8-token forward can
            # drop tokens a 1-token decode wouldn't) — test the cache logic
            # with a no-drop capacity.
            cfg = dataclasses.replace(cfg, capacity_factor=8.0)
        params = init_lm(jax.random.PRNGKey(3), cfg)
        T = 8
        toks = jnp.asarray(np.random.RandomState(0).randint(0, cfg.vocab, (1, T)))
        full_logits, _ = lm_forward(params, toks, cfg, FULL)
        cache = init_cache(cfg, 1, max_len=T)
        outs = []
        for t in range(T):
            lg, cache = lm_decode_step(params, cache, toks[:, t], cfg, FULL)
            outs.append(lg)
        dec_logits = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(
            np.asarray(dec_logits), np.asarray(full_logits), rtol=2e-2, atol=2e-2
        )


class TestSSDCorrectness:
    def test_chunked_matches_sequential(self):
        """The chunked SSD must equal the naive per-step recurrence."""
        from repro.models.lm.ssd import init_ssd, ssd_forward, ssd_decode_step

        cfg = get_config("mamba2-370m", smoke=True)
        params = init_ssd(jax.random.PRNGKey(4), cfg.d_model, cfg.d_inner,
                          cfg.ssm_heads, cfg.ssm_state)
        rng = np.random.RandomState(1)
        u = jnp.asarray(rng.randn(2, 24, cfg.d_model) * 0.3, jnp.float32)
        y_chunked = np.asarray(ssd_forward(params, u, cfg, FULL))
        # sequential reference via the decode step
        state = jnp.zeros((2, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state))
        ys = []
        for t in range(24):
            y, state = ssd_decode_step(params, u[:, t], state, cfg, FULL)
            ys.append(y)
        y_seq = np.stack([np.asarray(y) for y in ys], axis=1)
        np.testing.assert_allclose(y_chunked, y_seq, rtol=2e-3, atol=2e-3)


class TestMoE:
    def test_moe_routes_and_combines(self):
        from repro.models.lm.moe import init_moe, moe_apply

        params = init_moe(jax.random.PRNGKey(5), 32, 4, 64, 0, 0)
        x = jnp.asarray(np.random.RandomState(2).randn(64, 32), jnp.float32)
        out, aux = moe_apply(params, x, top_k=2, capacity_factor=2.0, dtype=jnp.float32)
        assert out.shape == (64, 32)
        assert np.isfinite(np.asarray(out)).all()
        assert float(aux) > 0.0

    def test_moe_capacity_drops_gracefully(self):
        from repro.models.lm.moe import init_moe, moe_apply

        params = init_moe(jax.random.PRNGKey(6), 16, 4, 32, 0, 0)
        x = jnp.asarray(np.random.RandomState(3).randn(128, 16), jnp.float32)
        out, _ = moe_apply(params, x, top_k=2, capacity_factor=0.25, dtype=jnp.float32)
        assert np.isfinite(np.asarray(out)).all()
