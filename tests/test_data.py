"""Data-substrate tests: PDE solvers produce physical solutions; loaders
are deterministic/restartable (the fault-tolerance invariant)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.data import (
    CachedDataset,
    StatelessLoader,
    grf_2d,
    lm_inputs,
    sample_car_batch,
    sample_darcy_batch,
    sample_ns_batch,
    sample_swe_batch,
    solve_darcy,
    solve_ns_vorticity,
    token_batch,
)
from repro.data.darcy import darcy_matvec

jax.config.update("jax_platform_name", "cpu")


class TestGRF:
    def test_zero_mean_and_smooth(self):
        f = np.asarray(grf_2d(jax.random.PRNGKey(0), 64, batch=8))
        assert abs(f.mean()) < 0.5
        # smoothness: neighbouring-pixel correlation is high
        corr = np.corrcoef(f[:, :-1, :].ravel(), f[:, 1:, :].ravel())[0, 1]
        assert corr > 0.9

    def test_deterministic(self):
        a = np.asarray(grf_2d(jax.random.PRNGKey(1), 32))
        b = np.asarray(grf_2d(jax.random.PRNGKey(1), 32))
        np.testing.assert_array_equal(a, b)


class TestDarcy:
    def test_solution_satisfies_pde(self):
        """Residual ||A u - f|| should be small after CG."""
        key = jax.random.PRNGKey(0)
        g = grf_2d(key, 24)
        a = jnp.where(g[0] > 0, 12.0, 3.0)
        u = solve_darcy(a, 24, maxiter=2000)
        res = np.asarray(darcy_matvec(a, u)) - 1.0
        assert np.abs(res).max() < 1e-2

    def test_solution_positive_interior(self):
        # -∇·(a∇u)=1 with u=0 boundary and a>0 => raw u > 0 inside (max
        # principle).  Outputs are whitened as u_n = (u - 5e-3)/5e-3, so
        # raw positivity means u_n > -1.
        a, u = sample_darcy_batch(jax.random.PRNGKey(1), 16, 2, maxiter=2000)
        assert np.asarray(u).min() > -1.0 - 1e-3

    def test_batch_shapes(self):
        a, u = sample_darcy_batch(jax.random.PRNGKey(2), 16, 3, maxiter=200)
        assert a.shape == (3, 1, 16, 16) and u.shape == (3, 1, 16, 16)


class TestNavierStokes:
    def test_energy_bounded_and_finite(self):
        f = grf_2d(jax.random.PRNGKey(0), 32, alpha=4.0, tau=3.0, sigma=27 ** 0.5)[0]
        w = solve_ns_vorticity(f, 32, T=1.0, steps=128)
        w = np.asarray(w)
        assert np.isfinite(w).all()
        assert np.abs(w).max() < 1e3

    def test_zero_forcing_stays_zero(self):
        w = solve_ns_vorticity(jnp.zeros((32, 32)), 32, T=1.0, steps=64)
        assert np.abs(np.asarray(w)).max() < 1e-6

    def test_batch_shapes(self):
        f, w = sample_ns_batch(jax.random.PRNGKey(1), 32, 2, T=0.5, steps=64)
        assert f.shape == (2, 1, 32, 32) and w.shape == (2, 1, 32, 32)


class TestSWE:
    def test_finite_and_wave_propagation(self):
        x, y = sample_swe_batch(jax.random.PRNGKey(0), 16, 32, 1, steps=20)
        assert np.isfinite(np.asarray(y)).all()
        # gravity waves must move the initial field
        assert np.abs(np.asarray(y[:, 0]) - np.asarray(x[:, 0])).max() > 1e-4
        assert x.shape == (1, 3, 16, 32) and y.shape == (1, 3, 16, 32)


class TestCarShapes:
    def test_batch_structure(self):
        batch, labels = sample_car_batch(0, 2, n_points=64, latent_grid=4, k=4)
        assert batch["points"].shape == (2, 64, 3)
        assert batch["enc_idx"].shape == (2, 64, 4)
        assert labels.shape == (2, 64, 1)
        assert (batch["points"] >= 0).all() and (batch["points"] <= 1).all()
        # pressure coefficient bounded: 1 - 2.25 sin² in [-1.25, 1]
        assert labels.min() >= -1.26 and labels.max() <= 1.01

    def test_knn_mask_keeps_nearest(self):
        batch, _ = sample_car_batch(1, 1, n_points=32, latent_grid=4, k=4)
        assert (batch["enc_mask"][:, :, 0] == 1.0).all()


class TestTokens:
    def test_deterministic_and_in_range(self):
        a = np.asarray(token_batch(0, 5, 4, 32, 1000)["tokens"])
        b = np.asarray(token_batch(0, 5, 4, 32, 1000)["tokens"])
        np.testing.assert_array_equal(a, b)
        assert a.min() >= 0 and a.max() < 1000

    def test_different_steps_differ(self):
        a = np.asarray(token_batch(0, 1, 4, 32, 1000)["tokens"])
        b = np.asarray(token_batch(0, 2, 4, 32, 1000)["tokens"])
        assert not np.array_equal(a, b)

    def test_lm_inputs_shifted(self):
        d = lm_inputs(0, 0, 2, 16, 100)
        np.testing.assert_array_equal(
            np.asarray(d["tokens"][:, 1:]), np.asarray(d["labels"][:, :-1])
        )


class TestLoaders:
    def test_stateless_loader_restart_identical(self):
        """The fault-tolerance invariant: batch(step) after 'restart' is
        bit-identical — no iterator state to lose."""
        fn = lambda seed, idx: {"x": np.full((2,), seed * 100 + idx)}
        l1 = StatelessLoader(fn, seed=3)
        seq1 = [l1.batch_at(s)["x"][0] for s in range(5)]
        l2 = StatelessLoader(fn, seed=3)  # "restarted process"
        seq2 = [l2.batch_at(s)["x"][0] for s in range(5)]
        assert seq1 == seq2

    def test_host_sharding_disjoint(self):
        fn = lambda _seed, idx: {"i": np.asarray([idx])}
        hosts = [StatelessLoader(fn, host_id=h, num_hosts=4) for h in range(4)]
        seen = [int(h.batch_at(7)["i"][0]) for h in hosts]
        assert len(set(seen)) == 4  # disjoint indices across hosts

    @given(st.integers(min_value=0, max_value=10_000))
    @settings(max_examples=20, deadline=None)
    def test_property_cached_dataset_restartable(self, step):
        ds = CachedDataset({"x": np.arange(100)}, batch_size=8, seed=1)
        np.testing.assert_array_equal(ds.batch_at(step)["x"], ds.batch_at(step)["x"])
