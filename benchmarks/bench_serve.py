"""Serve throughput: one-token-per-tick vs chunked batched prefill.

The old engine teacher-forced one prompt token per engine tick; the v2
``LMEngine`` consumes up to ``prefill_chunk`` pending tokens per tick
through the fused ``lm_prefill_chunk`` step.  This cell drives identical
request streams through both settings at the assigned LM configs (smoke
shapes — CPU container) and records ticks + wall time + tokens/s:

    PYTHONPATH=src python -m benchmarks.bench_serve

Results land in ``benchmarks/results/serve_prefill.json``; greedy
generations are asserted identical across chunk settings, so the
recorded speedup is numerics-free.
"""
from __future__ import annotations

import os

import jax
import numpy as np

from repro.configs import get_config
from repro.models.lm import init_lm
from repro.serve import LMEngine, Request

RESULTS = os.path.join(os.path.dirname(__file__), "results",
                       "serve_prefill.json")

ARCHS = ("smollm-360m", "mamba2-370m")
CHUNKS = (1, 8)
N_REQUESTS = 8
N_SLOTS = 2
PROMPT_LEN = 24
MAX_NEW = 4
MAX_LEN = 64


def _requests(vocab: int):
    rng = np.random.RandomState(0)
    return [
        Request(uid=i, prompt=list(rng.randint(1, vocab, PROMPT_LEN)),
                max_new_tokens=MAX_NEW)
        for i in range(N_REQUESTS)
    ]


def bench_arch(arch: str) -> dict:
    cfg = get_config(arch, smoke=True)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    rows = {}
    generations = {}
    for chunk in CHUNKS:
        engine = LMEngine(params, cfg, n_slots=N_SLOTS, max_len=MAX_LEN,
                          prefill_chunk=chunk)
        # warm both compiled steps, then zero every counter the recorded
        # row reads so warmup traffic never contaminates the measurement
        warm, _ = engine.run_until_done(
            [Request(uid=-1, prompt=[1] * (chunk + 1), max_new_tokens=2)])
        assert all(r.done for r in warm)
        engine.reset_counters()
        done, ticks = engine.run_until_done(_requests(cfg.vocab))
        assert len(done) == N_REQUESTS and all(r.done for r in done)
        generations[chunk] = {r.uid: list(r.generated) for r in done}
        s = engine.stats()
        rows[str(chunk)] = {
            "ticks": ticks,
            "wall_s": s["wall_s"],
            "prompt_tokens": s["prompt_tokens"],
            "tokens_generated": s["tokens_generated"],
            "tokens_per_s": s["tokens_per_s"],
            "prefill_ticks": s["prefill_ticks"],
            "decode_ticks": s["decode_ticks"],
        }
    # chunking must not change greedy generations
    assert generations[CHUNKS[0]] == generations[CHUNKS[-1]], generations
    base, best = rows[str(CHUNKS[0])], rows[str(CHUNKS[-1])]
    return {
        "arch": arch,
        "n_requests": N_REQUESTS,
        "n_slots": N_SLOTS,
        "prompt_len": PROMPT_LEN,
        "max_new_tokens": MAX_NEW,
        "by_chunk": rows,
        "tick_speedup": round(base["ticks"] / best["ticks"], 2),
        "wall_speedup": round(base["wall_s"] / best["wall_s"], 2)
        if best["wall_s"] else None,
    }


def main():
    from benchmarks.common import write_result

    recs = [bench_arch(a) for a in ARCHS]
    write_result(RESULTS, {"records": recs})
    print("arch,chunk,ticks,wall_s,tokens_per_s")
    for r in recs:
        for chunk, row in r["by_chunk"].items():
            print(f"{r['arch']},{chunk},{row['ticks']},{row['wall_s']},"
                  f"{row['tokens_per_s']}")
        print(f"# {r['arch']}: {r['tick_speedup']}x fewer ticks, "
              f"{r['wall_speedup']}x wall-clock")
    print(f"-> {RESULTS}")


if __name__ == "__main__":
    main()
