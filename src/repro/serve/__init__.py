"""repro.serve — the Engine serving API.

One protocol (``submit / tick / drain / stats``) over two engines:
:class:`LMEngine` (continuous-batching LM decode with chunked batched
prefill and per-request sampling) and :class:`OperatorEngine`
(micro-batched FNO/SFNO field inference in resolution buckets), both
fed by a shared :class:`Scheduler` (FCFS / shortest-prompt-first with
capacity rejection).  ``ServeEngine`` is the pre-v2 alias of
``LMEngine``.
"""
from .engine import Engine, EngineBase, LMEngine, Request, ServeEngine  # noqa: F401
from .operator import FieldRequest, OperatorEngine  # noqa: F401
from .sampler import (  # noqa: F401
    GREEDY,
    SamplingParams,
    request_key,
    sample_token,
)
from .scheduler import POLICIES, Scheduler  # noqa: F401
