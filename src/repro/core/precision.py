"""Numeric-format primitives for mixed-precision neural operators.

Implements the paper's format-level machinery:

* An ``(a0, eps, T)``-precision system ``q`` (Section 3) — a simplified
  floating-point quantiser used by the theory module and by the simulated
  fp8 path (Appendix B.11).
* ``ComplexPair`` — split-real representation of complex tensors so that
  half-precision *real* matmul hardware (MXU / tensor cores) can execute
  complex contractions.  This is the JAX analogue of the paper's
  ``view_as_real`` trick.
* ``quantize_complex`` / ``simulate_fp8`` — boundary rounding onto a half
  or fp8 grid (the representation error bounded by Theorem 3.2).

*Which* format applies *where* is no longer decided here: precision
policies live in :mod:`repro.precision` as site-addressed rule sets
(``policy.at("fno/layer2/spectral/contract")``), and this module only
provides the grids those rules quantise onto.  ``repro.core`` re-exports
the policy registry for backward compatibility.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# (a0, eps, T)-precision system (paper Section 3 / Appendix A)
# ---------------------------------------------------------------------------

# Machine-epsilon-style relative spacing for the formats discussed in the
# paper.  eps(fp16) ~ 2^-11 ~ 4.9e-4 (the paper quotes 1e-4 as the order of
# magnitude); eps(bf16) ~ 2^-8; eps(fp8-e4m3) ~ 2^-3; eps(fp8-e5m2) ~ 2^-2.
FORMAT_EPS = {
    "float64": 2.0 ** -52,
    "float32": 2.0 ** -23,
    "bfloat16": 2.0 ** -8,
    "float16": 2.0 ** -11,
    "fp8_e4m3": 2.0 ** -3,
    "fp8_e5m2": 2.0 ** -2,
}

# Dynamic range (max finite magnitude) per format — used by the simulated
# fp8 clipping path (Appendix B.11) and the stabiliser analysis.
FORMAT_MAX = {
    "float32": 3.4028235e38,
    "bfloat16": 3.3895314e38,
    "float16": 65504.0,
    "fp8_e4m3": 448.0,
    "fp8_e5m2": 57344.0,
}

# Smallest normal magnitude per format — the underflow threshold the
# telemetry taps and the autoprec controller's candidate checks use.
FORMAT_TINY = {
    "float64": 2.2250738585072014e-308,
    "float32": 1.1754944e-38,
    "bfloat16": 1.1754944e-38,
    "float16": 6.103515625e-05,
    "fp8_e4m3": 2.0 ** -6,
    "fp8_e5m2": 2.0 ** -14,
}


@dataclasses.dataclass(frozen=True)
class PrecisionSystem:
    """The paper's ``(a0, eps, T)``-precision system.

    ``S = {0} ∪ {±a0 (1+eps)^i : 0 <= i <= T}`` with ``q(x) = argmin_{y∈S}|x-y|``.
    """

    a0: float
    eps: float
    T: int

    def quantize(self, x: jnp.ndarray) -> jnp.ndarray:
        """Round ``x`` to the nearest representable value (pure jnp)."""
        sign = jnp.sign(x)
        mag = jnp.abs(x)
        # index of the geometric grid point: i = round(log(mag/a0) / log(1+eps))
        log_ratio = jnp.log(jnp.maximum(mag, 1e-300) / self.a0)
        i = jnp.round(log_ratio / jnp.log1p(self.eps))
        i = jnp.clip(i, 0, self.T)
        q = self.a0 * jnp.power(1.0 + self.eps, i)
        # values below a0/2 snap to 0 (underflow)
        q = jnp.where(mag < self.a0 / 2, 0.0, q)
        return sign * q


def precision_system_for(fmt: str) -> PrecisionSystem:
    """Build an (a0, eps, T)-system approximating a named float format."""
    eps = FORMAT_EPS[fmt]
    vmax = FORMAT_MAX.get(fmt, 3.4e38)
    a0 = FORMAT_TINY.get(fmt, 1e-30)  # smallest normal
    T = int(math.log(vmax / a0) / math.log1p(eps))
    return PrecisionSystem(a0=a0, eps=eps, T=T)


def simulate_fp8(x: jnp.ndarray, fmt: str = "fp8_e5m2") -> jnp.ndarray:
    """Simulated fp8: clip to the format's range, round the mantissa
    (Appendix B.11)."""
    clipped = jnp.clip(x, -FORMAT_MAX[fmt], FORMAT_MAX[fmt])
    return _round_mantissa(clipped, fmt)


def _round_mantissa(x: jnp.ndarray, fmt: str) -> jnp.ndarray:
    mant_bits = {"fp8_e4m3": 3, "fp8_e5m2": 2}[fmt]
    m, e = jnp.frexp(jnp.asarray(x, jnp.float32))
    m = jnp.round(m * (1 << (mant_bits + 1))) / (1 << (mant_bits + 1))
    return jnp.ldexp(m, e)


# ---------------------------------------------------------------------------
# Split-real complex representation ("view_as_real" for JAX/TPU)
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
class ComplexPair:
    """A complex tensor stored as two real tensors (re, im).

    This is how half-precision complex data lives on hardware with real-only
    half matmul units.  Registered as a pytree so it flows through jit/scan/
    pjit transparently.
    """

    __slots__ = ("re", "im")

    def __init__(self, re: jnp.ndarray, im: jnp.ndarray):
        self.re = re
        self.im = im

    # -- pytree protocol --
    def tree_flatten(self):
        return (self.re, self.im), None

    @classmethod
    def tree_unflatten(cls, _aux, children):
        return cls(*children)

    # -- constructors / views --
    @classmethod
    def from_complex(cls, c: jnp.ndarray, dtype: Any) -> "ComplexPair":
        return cls(jnp.real(c).astype(dtype), jnp.imag(c).astype(dtype))

    def to_complex(self, dtype: Any = jnp.complex64) -> jnp.ndarray:
        f = jnp.float32 if dtype == jnp.complex64 else jnp.float64
        return jax.lax.complex(self.re.astype(f), self.im.astype(f))

    # -- metadata --
    @property
    def shape(self):
        return self.re.shape

    @property
    def dtype(self):
        return self.re.dtype

    def astype(self, dtype) -> "ComplexPair":
        return ComplexPair(self.re.astype(dtype), self.im.astype(dtype))

    # -- arithmetic (elementwise) --
    def __add__(self, o: "ComplexPair") -> "ComplexPair":
        return ComplexPair(self.re + o.re, self.im + o.im)

    def __mul__(self, o):
        if isinstance(o, ComplexPair):
            # 4-mult complex product; accumulation in the inputs' dtype —
            # contraction paths use f32 accumulation explicitly.
            return ComplexPair(
                self.re * o.re - self.im * o.im,
                self.re * o.im + self.im * o.re,
            )
        return ComplexPair(self.re * o, self.im * o)

    def conj(self) -> "ComplexPair":
        return ComplexPair(self.re, -self.im)

    def abs2(self) -> jnp.ndarray:
        r = self.re.astype(jnp.float32)
        i = self.im.astype(jnp.float32)
        return r * r + i * i


def quantize_complex(c: jnp.ndarray, dtype: Any) -> jnp.ndarray:
    """Round-trip a complex64 tensor through a half-precision ComplexPair.

    Models the representation (precision) error of storing spectral data at
    half precision — this is exactly the error bounded by Theorem 3.2; used
    at FFT boundaries where TPUs compute the transform in f32.
    """
    if dtype in (jnp.float32, None):
        return c
    pair = ComplexPair.from_complex(c, dtype)
    return pair.to_complex()
