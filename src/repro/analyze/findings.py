"""Finding records and the reviewed-suppression (allowlist) machinery.

Every analyzer pass emits :class:`Finding` rows; the CLI partitions them
against the suppression file (``analyze.toml`` at the repo root) and
exits nonzero only on *unsuppressed* ``error``-severity findings.  A
suppression is a reviewed statement that a specific finding is
intentional — e.g. the ``f32→half→f32`` round trip inside
``quantize_complex`` IS Theorem 3.2's boundary quantiser, not wasted
bandwidth — so it must name the check and carry a reason.
"""
from __future__ import annotations

import dataclasses
import fnmatch
from typing import Dict, List, Optional, Sequence, Tuple

ERROR = "error"
WARNING = "warning"
SEVERITIES = (ERROR, WARNING)

#: Pass names, in report order.
PASSES = ("dataflow", "sites", "kernels", "obs")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer result.

    pass_name: which pass produced it ("dataflow" | "sites" | "kernels").
    check:     stable slug of the rule that fired (suppression key).
    severity:  "error" gates CI; "warning" is informational.
    site:      precision-site address the finding attributes to, when the
               pass could recover one (name-stack scope, rule pattern);
               None for findings without a site (e.g. kernel structure).
    where:     locator — "model/policy" for traces, "file:line" for the
               AST pass, the kernel family for the Pallas pass.
    detail:    human-readable specifics.
    """

    pass_name: str
    check: str
    severity: str
    site: Optional[str]
    where: str
    detail: str

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def dedupe(findings: Sequence[Finding]) -> List[Finding]:
    """Drop exact duplicates, keeping first-seen order."""
    seen = set()
    out = []
    for f in findings:
        key = (f.pass_name, f.check, f.severity, f.site, f.where, f.detail)
        if key not in seen:
            seen.add(key)
            out.append(f)
    return out


# ---------------------------------------------------------------------------
# Suppressions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Suppression:
    """One ``[[suppress]]`` table from the allowlist file.

    ``check`` matches exactly; ``site`` / ``where`` are optional fnmatch
    patterns (absent = match anything, including findings with no site).
    ``reason`` is required — an allowlist entry without a rationale is a
    review failure, not a review.
    """

    check: str
    reason: str
    site: Optional[str] = None
    where: Optional[str] = None

    def matches(self, f: Finding) -> bool:
        if self.check != f.check:
            return False
        if self.site is not None:
            if f.site is None or not fnmatch.fnmatchcase(f.site, self.site):
                return False
        if self.where is not None:
            if not fnmatch.fnmatchcase(f.where, self.where):
                return False
        return True


def _parse_minimal_toml(text: str) -> dict:
    """Just enough TOML for the suppression file on Python 3.10 (no
    ``tomllib``): ``[[suppress]]`` table arrays of string key/values.
    Anything fancier should use a real parser — raise rather than guess."""
    out: Dict[str, list] = {}
    current: Optional[dict] = None
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        if line.startswith("[[") and line.endswith("]]"):
            name = line[2:-2].strip()
            current = {}
            out.setdefault(name, []).append(current)
            continue
        if "=" in line and current is not None:
            key, _, val = line.partition("=")
            key, val = key.strip(), val.strip()
            # strip a trailing comment outside the quotes
            if val and val[0] in "\"'":
                quote = val[0]
                end = val.find(quote, 1)
                if end < 0:
                    raise ValueError(
                        f"analyze.toml:{lineno}: unterminated string")
                current[key] = val[1:end]
                continue
        raise ValueError(
            f"analyze.toml:{lineno}: unsupported syntax {raw!r} — the "
            f"fallback parser handles only [[suppress]] tables with "
            f"string values"
        )
    return out


def load_suppressions(path: str) -> Tuple[Suppression, ...]:
    """Load ``[[suppress]]`` entries; missing file = empty allowlist."""
    try:
        with open(path, "rb") as fh:
            raw = fh.read()
    except FileNotFoundError:
        return ()
    try:
        import tomllib  # Python 3.11+

        data = tomllib.loads(raw.decode("utf-8"))
    except ModuleNotFoundError:
        data = _parse_minimal_toml(raw.decode("utf-8"))
    entries = []
    for i, tbl in enumerate(data.get("suppress", [])):
        if "check" not in tbl or "reason" not in tbl:
            raise ValueError(
                f"{path}: suppress entry #{i + 1} needs both 'check' and "
                f"'reason' keys, got {sorted(tbl)}"
            )
        unknown = set(tbl) - {"check", "reason", "site", "where"}
        if unknown:
            raise ValueError(
                f"{path}: suppress entry #{i + 1} has unknown keys "
                f"{sorted(unknown)}"
            )
        entries.append(Suppression(**tbl))
    return tuple(entries)


def partition(
    findings: Sequence[Finding], suppressions: Sequence[Suppression]
) -> Tuple[List[Finding], List[Finding]]:
    """Split into (active, suppressed)."""
    active, suppressed = [], []
    for f in findings:
        (suppressed if any(s.matches(f) for s in suppressions) else active
         ).append(f)
    return active, suppressed


def summarize(findings: Sequence[Finding]) -> dict:
    """Per-(pass, check, severity) counts for the report table."""
    counts: Dict[Tuple[str, str, str], int] = {}
    for f in findings:
        key = (f.pass_name, f.check, f.severity)
        counts[key] = counts.get(key, 0) + 1
    return {
        "total": len(findings),
        "errors": sum(1 for f in findings if f.severity == ERROR),
        "warnings": sum(1 for f in findings if f.severity == WARNING),
        "by_check": [
            {"pass": p, "check": c, "severity": s, "count": n}
            for (p, c, s), n in sorted(counts.items())
        ],
    }
