"""Unit + property tests for repro.core.precision and contraction."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    ComplexPair,
    FULL,
    PathCache,
    contract,
    get_policy,
    greedy_path,
    path_intermediate_bytes,
    precision_system_for,
    quantize_complex,
)

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# (a0, eps, T)-precision system
# ---------------------------------------------------------------------------


class TestPrecisionSystem:
    def test_quantize_relative_error_bounded(self):
        q = precision_system_for("float16")
        x = jnp.asarray(np.random.RandomState(0).uniform(0.01, 100.0, size=512))
        qx = q.quantize(x)
        rel = np.abs(np.asarray(qx) - np.asarray(x)) / np.asarray(x)
        # nearest grid point => relative error <= eps/2 (up to rounding slack)
        assert rel.max() <= q.eps * 0.51 + 1e-12

    def test_underflow_to_zero(self):
        q = precision_system_for("float16")
        tiny = jnp.asarray([q.a0 / 4.0, -q.a0 / 4.0])
        assert np.all(np.asarray(q.quantize(tiny)) == 0.0)

    def test_sign_preserved(self):
        q = precision_system_for("float16")
        x = jnp.asarray([-3.0, 3.0])
        qx = np.asarray(q.quantize(x))
        assert qx[0] < 0 < qx[1]

    @given(st.floats(min_value=1e-3, max_value=1e3, allow_nan=False))
    @settings(max_examples=50, deadline=None)
    def test_property_quantize_close(self, v):
        q = precision_system_for("float16")
        qv = float(q.quantize(jnp.asarray([v]))[0])
        assert abs(qv - v) <= q.eps * v + 1e-12


# ---------------------------------------------------------------------------
# ComplexPair
# ---------------------------------------------------------------------------


class TestComplexPair:
    def test_roundtrip(self):
        rng = np.random.RandomState(0)
        c = rng.randn(4, 8) + 1j * rng.randn(4, 8)
        pair = ComplexPair.from_complex(jnp.asarray(c, jnp.complex64), jnp.float32)
        np.testing.assert_allclose(np.asarray(pair.to_complex()), c, rtol=1e-6)

    def test_half_roundtrip_error_small(self):
        rng = np.random.RandomState(1)
        c = (rng.randn(64) + 1j * rng.randn(64)).astype(np.complex64)
        q = quantize_complex(jnp.asarray(c), jnp.float16)
        err = np.abs(np.asarray(q) - c)
        assert err.max() < 2e-3  # fp16 relative precision on O(1) data

    def test_mul_matches_complex(self):
        rng = np.random.RandomState(2)
        a = rng.randn(16) + 1j * rng.randn(16)
        b = rng.randn(16) + 1j * rng.randn(16)
        pa = ComplexPair.from_complex(jnp.asarray(a, jnp.complex64), jnp.float32)
        pb = ComplexPair.from_complex(jnp.asarray(b, jnp.complex64), jnp.float32)
        np.testing.assert_allclose(np.asarray((pa * pb).to_complex()), a * b, rtol=1e-5)

    def test_is_pytree(self):
        pair = ComplexPair(jnp.ones(3), jnp.zeros(3))
        leaves = jax.tree_util.tree_leaves(pair)
        assert len(leaves) == 2
        out = jax.jit(lambda p: p * 2.0)(pair)
        np.testing.assert_allclose(np.asarray(out.re), 2.0)


# ---------------------------------------------------------------------------
# Greedy contraction path
# ---------------------------------------------------------------------------


class TestGreedyPath:
    def test_matmul_chain_order(self):
        # (2x1000) @ (1000x2) @ (2x1000): memory-greedy contracts the small
        # intermediate first.
        expr = "ab,bc,cd->ad"
        shapes = [(2, 1000), (1000, 2), (2, 1000)]
        path = greedy_path(expr, shapes, "memory")
        peak = path_intermediate_bytes(expr, shapes, path)
        assert peak == 2 * 2 * 4  # (a,c) intermediate = 2x2 floats

    def test_memory_vs_flops_paths_differ(self):
        # Engineered so the FLOP-optimal order creates a larger intermediate.
        expr = "ab,bc,cd->ad"
        shapes = [(8, 4), (4, 1024), (1024, 2)]
        p_mem = greedy_path(expr, shapes, "memory")
        p_flop = greedy_path(expr, shapes, "flops")
        mem_peak = path_intermediate_bytes(expr, shapes, p_mem)
        flop_peak = path_intermediate_bytes(expr, shapes, p_flop)
        assert mem_peak <= flop_peak

    def test_path_cache_hit(self):
        cache = PathCache()
        expr = "ab,bc->ac"
        shapes = [(3, 4), (4, 5)]
        cache.get(expr, shapes, "memory")
        cache.get(expr, shapes, "memory")
        assert cache.hits == 1 and cache.misses == 1

    @given(
        st.integers(min_value=1, max_value=6),
        st.integers(min_value=1, max_value=6),
        st.integers(min_value=1, max_value=6),
        st.integers(min_value=1, max_value=6),
    )
    @settings(max_examples=25, deadline=None)
    def test_property_chain_correct(self, a, b, c, d):
        rng = np.random.RandomState(a * 7 + b)
        A = jnp.asarray(rng.randn(a, b), jnp.float32)
        B = jnp.asarray(rng.randn(b, c), jnp.float32)
        C = jnp.asarray(rng.randn(c, d), jnp.float32)
        got = contract("ab,bc,cd->ad", A, B, C, policy=FULL)
        want = np.einsum("ab,bc,cd->ad", A, B, C)
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Mixed-precision contraction executor
# ---------------------------------------------------------------------------


class TestContract:
    def _rand_complex(self, rng, shape):
        return jnp.asarray(rng.randn(*shape) + 1j * rng.randn(*shape), jnp.complex64)

    def test_full_matches_einsum_complex(self):
        rng = np.random.RandomState(0)
        x = self._rand_complex(rng, (2, 3, 4, 4))
        w = self._rand_complex(rng, (3, 5, 4, 4))
        got = contract("bixy,ioxy->boxy", x, w, policy=FULL)
        want = np.einsum("bixy,ioxy->boxy", np.asarray(x), np.asarray(w))
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("policy_name", ["mixed_fno_fp16", "mixed_fno_bf16"])
    def test_half_close_to_full(self, policy_name):
        rng = np.random.RandomState(3)
        x = self._rand_complex(rng, (2, 8, 6, 6))
        w = self._rand_complex(rng, (8, 8, 6, 6)) * 0.1
        policy = get_policy(policy_name)
        got = contract("bixy,ioxy->boxy", x, w, policy=policy)
        got = got.to_complex() if hasattr(got, "to_complex") else got
        want = np.einsum("bixy,ioxy->boxy", np.asarray(x), np.asarray(w))
        rel = np.abs(np.asarray(got) - want) / (np.abs(want) + 1e-3)
        assert rel.mean() < 2e-2  # half-precision storage error only

    def test_cp_multi_operand(self):
        # TFNO's CP contraction: bixy,r,ir,or,xr,yr->boxy
        rng = np.random.RandomState(4)
        b, i, o, x_, y_, r = 2, 4, 5, 3, 3, 6
        X = self._rand_complex(rng, (b, i, x_, y_))
        lam = self._rand_complex(rng, (r,))
        Ui = self._rand_complex(rng, (i, r))
        Uo = self._rand_complex(rng, (o, r))
        Ux = self._rand_complex(rng, (x_, r))
        Uy = self._rand_complex(rng, (y_, r))
        got = contract("bixy,r,ir,or,xr,yr->boxy", X, lam, Ui, Uo, Ux, Uy, policy=FULL)
        want = np.einsum(
            "bixy,r,ir,or,xr,yr->boxy",
            *[np.asarray(t) for t in (X, lam, Ui, Uo, Ux, Uy)],
        )
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)

    def test_contract_jittable(self):
        rng = np.random.RandomState(5)
        x = self._rand_complex(rng, (2, 3, 4, 4))
        w = self._rand_complex(rng, (3, 5, 4, 4))
        f = jax.jit(lambda a, b: contract("bixy,ioxy->boxy", a, b, policy=FULL))
        np.testing.assert_allclose(
            np.asarray(f(x, w)),
            np.einsum("bixy,ioxy->boxy", np.asarray(x), np.asarray(w)),
            rtol=1e-5,
            atol=1e-5,
        )

    @given(st.integers(min_value=0, max_value=10 ** 6))
    @settings(max_examples=30, deadline=None)
    def test_property_random_multi_operand_matches_einsum(self, seed):
        """contract under FULL == jnp.einsum for randomized 3-4 operand
        expressions (implicit-output convention, shared/contracted/batch
        indices in arbitrary combinations)."""
        rng = np.random.RandomState(seed)
        letters = "abcdef"
        dims = {ch: int(rng.randint(1, 5)) for ch in letters}
        n_ops = int(rng.randint(3, 5))
        terms = []
        for _ in range(n_ops):
            k = int(rng.randint(1, 4))
            idx = rng.choice(len(letters), size=k, replace=False)
            terms.append("".join(letters[i] for i in sorted(idx)))
        expr = ",".join(terms)
        ops = [
            jnp.asarray(rng.randn(*[dims[c] for c in t]), jnp.float32)
            for t in terms
        ]
        got = np.asarray(contract(expr, *ops, policy=FULL))
        want = np.einsum(expr, *[np.asarray(o) for o in ops])
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Memory- vs FLOP-objective paths on the paper's spectral einsums
# ---------------------------------------------------------------------------


class TestObjectivePaths:
    # the paper's dense / CP / Tucker spectral contractions (§4.2/§4.6)
    SPECTRAL_CASES = [
        ("bixy,ioxy->boxy", [(4, 8, 12, 12), (8, 8, 12, 12)]),
        (
            "bixy,r,ir,or,xr,yr->boxy",
            [(4, 8, 12, 12), (6,), (8, 6), (8, 6), (12, 6), (12, 6)],
        ),
        (
            "bixy,RSAB,iR,oS,xA,yB->boxy",
            [(4, 8, 12, 12), (4, 4, 6, 6), (8, 4), (8, 4), (12, 6), (12, 6)],
        ),
        # 3-D CP (the setting where Table 10 reports the biggest saving)
        (
            "bixyz,r,ir,or,xr,yr,zr->boxyz",
            [(2, 6, 8, 8, 8), (5,), (6, 5), (6, 5), (8, 5), (8, 5), (8, 5)],
        ),
    ]

    @pytest.mark.parametrize("expr,shapes", SPECTRAL_CASES)
    def test_memory_peak_never_exceeds_flops_path(self, expr, shapes):
        p_mem = greedy_path(expr, shapes, "memory")
        p_fl = greedy_path(expr, shapes, "flops")
        peak_mem = path_intermediate_bytes(expr, shapes, p_mem)
        peak_fl = path_intermediate_bytes(expr, shapes, p_fl)
        assert peak_mem <= peak_fl, (expr, peak_mem, peak_fl)

    @pytest.mark.parametrize("expr,shapes", SPECTRAL_CASES)
    def test_both_objectives_compute_the_same_value(self, expr, shapes):
        rng = np.random.RandomState(7)
        ops = [jnp.asarray(rng.randn(*s), jnp.float32) for s in shapes]
        a = np.asarray(contract(expr, *ops, policy=FULL, objective="memory"))
        b = np.asarray(contract(expr, *ops, policy=FULL, objective="flops"))
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    def test_parse_shared_with_path_search(self):
        """contract hands its parse through to the cache miss path (no
        re-parse), and cached calls skip the search entirely."""
        cache = PathCache()
        expr = "ab,bc,cd->ad"
        shapes = [(3, 4), (4, 5), (5, 6)]
        rng = np.random.RandomState(8)
        ops = [jnp.asarray(rng.randn(*s), jnp.float32) for s in shapes]
        contract(expr, *ops, policy=FULL, cache=cache)
        contract(expr, *ops, policy=FULL, cache=cache)
        assert cache.misses == 1 and cache.hits == 1
