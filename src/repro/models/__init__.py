"""Model zoo: neural operators (paper) + the assigned LM architecture pool."""
from .fno import FNOConfig, fno_apply, fno_infer, init_fno, param_count  # noqa: F401
from .sfno import SFNOConfig, init_sfno, sfno_apply, sfno_infer  # noqa: F401
from .gino import GINOConfig, gino_apply, init_gino  # noqa: F401
from .unet import UNetConfig, init_unet, unet_apply  # noqa: F401
