"""The admission gate: every candidate vs its einsum oracle, under the
paper's own tolerance.

Thm 3.2 bounds the precision error of a half-stored contraction by
``4·ε·M`` per requantising stage, where ``ε`` is the storage grid
spacing and ``M`` the contraction of operand magnitudes.  The
differential test suite (tests/test_kernels_diff.py) asserts the Pallas
kernels against the einsum reference under exactly

    budget = stages · 4εM + 32·ε_f32·M + atol      (elementwise)

and this module applies the same machinery at tuning time: a candidate
tile whose kernel output strays outside that envelope is *refused* — a
mistuned-but-wrong kernel is unrepresentable in the calibration cache.

``perturb`` injects a scaled multiple of the budget into the kernel
output before the comparison.  It exists so the gate itself is testable:
``python -m repro.tune validate --perturb 2`` must reject every entry
(the seeded-violation self-check CI can run), proving the oracle is
live, not vacuously green.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.precision import FORMAT_EPS
from repro.core.theory import prec_upper_bound
from repro.kernels.spectral_contract import _fused_rows, fused_factors
from .measure import default_interpret, make_operands
from .space import Candidate, fused_axes

F32_EPS = float(np.finfo(np.float32).eps)
ATOL = 1e-5

#: requantising stages per family — one 4εM term each, mirroring the
#: stage counts the differential tests budget for the same kernels.
#: spectral_fused composes four: the forward-DFT boundary quantisation,
#: the two contraction operand grids, and the half output store — the
#: composed Thm 3.2 budget the fused differential leg asserts too.
STAGES = {"dense": 2, "dense-fused": 2, "cp": 6, "lshared": 2,
          "spectral_fused": 4}

#: the fused pipeline's f32 transform stages (truncated DFT + inverse)
#: accumulate over prod(spatial) elements; 32·ε_f32 per magnitude unit
#: covers them at every shape the suite and tuner exercise
FUSED_F32_C = 32


def storage_eps(dtype: str) -> float:
    """Grid spacing ε of the storage dtype ("bfloat16", "float16", ...)."""
    return FORMAT_EPS[dtype]


def _c(re, im):
    return np.asarray(re, np.float64) + 1j * np.asarray(im, np.float64)


def _rounded(arr, dtype):
    """Round an f32 operand onto the storage grid the kernel will use
    (identity when it already lives there)."""
    import jax.numpy as jnp

    return np.asarray(jnp.asarray(arr).astype(jnp.dtype(dtype))
                      .astype(jnp.float32))


def reference(cand: Candidate, ops) -> tuple:
    """(exact complex reference, magnitude contraction M) for the
    candidate's operands — computed at complex128 from the same storage-
    rounded values the kernel consumes, so the elementwise budget
    charges only the kernel's own stages."""
    family, dtype = cand.family, cand.dtype
    if family in ("dense", "dense-fused"):
        xr, xi, wr, wi = ops
        if family == "dense-fused":
            # the kernel rounds f32 tiles onto the half grid in-kernel;
            # the oracle must agree on the operands being contracted
            xr, xi, wr, wi = (_rounded(a, dtype) for a in (xr, xi, wr, wi))
        x, w = _c(xr, xi), _c(wr, wi)
        ref = np.einsum("bim,iom->bom", x, w)
        mag = np.einsum("bim,iom->bom", np.abs(x), np.abs(w))
    elif family == "cp":
        xr, xi, uir, uii, uor, uoi, wr, wi = ops
        x, ui, uo, w = _c(xr, xi), _c(uir, uii), _c(uor, uoi), _c(wr, wi)
        t = np.einsum("bim,ir->bmr", x, ui)
        u = t * np.transpose(w)[None]
        ref = np.einsum("bmr,or->bom", u, uo)
        tm = np.einsum("bim,ir->bmr", np.abs(x), np.abs(ui))
        mag = np.einsum("bmr,or->bom",
                        tm * np.abs(np.transpose(w))[None], np.abs(uo))
    elif family == "lshared":
        xr, xi, wr, wi = ops
        x, w = _c(xr, xi), _c(wr, wi)
        ref = np.einsum("bilm,iol->bolm", x, w)
        mag = np.einsum("bilm,iol->bolm", np.abs(x), np.abs(w))
    elif family == "spectral_fused":
        return _fused_reference(cand, ops)
    else:
        raise ValueError(f"unknown kernel family {family!r}")
    return ref, mag


def _apply_factor(a, f, axis, f_axis):
    return np.moveaxis(np.tensordot(a, f, axes=[[axis], [f_axis]]), -1, axis)


def _fused_reference(cand: Candidate, ops) -> tuple:
    """Composed f64 reference for the fused pipeline, from the same
    storage-rounded spectrum/weights the kernel contracts, with the
    magnitude ``M`` composed through the absolute factor matrices — the
    per-element Thm 3.2 envelope of the whole rFFT→contract→irFFT chain,
    not of one stage."""
    x, wgr, wgi = (np.asarray(a, np.float64) for a in ops)
    B, I, O, spatial, modes = fused_axes(cand.shape)
    ndim = len(modes)
    facs = fused_factors(spatial, modes)
    fwd = [(facs[2 * k], facs[2 * k + 1]) for k in range(ndim)]
    inv = [(facs[2 * ndim + 2 * k], facs[2 * ndim + 2 * k + 1])
           for k in range(ndim)]

    a = x.astype(np.complex128)
    mag_a = np.abs(x)
    for k, (fr, fi) in enumerate(fwd):
        F = fr + 1j * fi
        a = _apply_factor(a, F, 2 + k, 1)
        mag_a = _apply_factor(mag_a, np.abs(F), 2 + k, 1)
    ah = a.reshape(B, I, -1)
    mag_ah = mag_a.reshape(B, I, -1)
    if cand.dtype != "float32":
        ah = (_rounded(ah.real, cand.dtype)
              + 1j * _rounded(ah.imag, cand.dtype))
        wgr = _rounded(wgr, cand.dtype)
        wgi = _rounded(wgi, cand.dtype)
    w = wgr + 1j * wgi
    yh = np.einsum("bim,iom->bom", ah, w)
    mag_yh = np.einsum("bim,iom->bom", mag_ah, np.abs(w))
    rows = _fused_rows(spatial, modes)
    yh = yh.reshape(B, O, *rows)
    mag_yh = mag_yh.reshape(B, O, *rows)
    for k in range(ndim - 1):
        G = inv[k][0] + 1j * inv[k][1]
        yh = _apply_factor(yh, G, 2 + k, 0)
        mag_yh = _apply_factor(mag_yh, np.abs(G), 2 + k, 0)
    cr, ci = inv[-1]
    ax = 2 + ndim - 1
    ref = (_apply_factor(yh.real, cr, ax, 0)
           + _apply_factor(yh.imag, ci, ax, 0))
    mag = _apply_factor(mag_yh, np.abs(cr) + np.abs(ci), ax, 0)
    return ref, mag


def check(cand: Candidate, *, interpret: Optional[bool] = None,
          seed: int = 0, perturb: float = 0.0) -> dict:
    """Run the candidate's forward kernel and gate it against the einsum
    oracle.  Returns {passed, max_err, budget_min, worst_excess}."""
    import jax.numpy as jnp

    from repro.kernels.spectral_contract import (
        spectral_contract_cp_pallas as cp_kern,
        spectral_contract_lshared_pallas as l_kern,
        spectral_contract_pallas as d_kern,
    )

    interpret = default_interpret() if interpret is None else interpret
    ops = make_operands(cand.family, cand.shape, cand.dtype, seed=seed)
    out_dtype = jnp.dtype(cand.dtype)
    if cand.family == "spectral_fused":
        from repro.kernels.spectral_contract import spectral_fused_pallas

        _B, _I, _O, _spatial, modes = fused_axes(cand.shape)
        y = spectral_fused_pallas(
            *ops, modes=modes, block_b=cand.block_fwd,
            block_b_bwd=cand.block_bwd, interpret=interpret,
            cast_to=None if cand.dtype == "float32" else out_dtype)
        got = np.asarray(y.astype(jnp.float32), np.float64)
    elif cand.family in ("dense", "dense-fused"):
        yr, yi = d_kern(
            *ops, block_m=cand.block_fwd, block_m_bwd=cand.block_bwd,
            interpret=interpret, out_dtype=out_dtype,
            cast_to=out_dtype if cand.family == "dense-fused" else None)
    elif cand.family == "cp":
        yr, yi = cp_kern(
            *ops, block_m=cand.block_fwd, block_m_bwd=cand.block_bwd,
            interpret=interpret, out_dtype=out_dtype)
    else:
        yr, yi = l_kern(
            *ops, block_l=cand.block_fwd, block_l_bwd=cand.block_bwd,
            interpret=interpret, out_dtype=out_dtype)
    if cand.family != "spectral_fused":
        got = _c(np.asarray(yr.astype(jnp.float32)),
                 np.asarray(yi.astype(jnp.float32)))

    ref, mag = reference(cand, ops)
    eps = storage_eps(cand.dtype)
    f32_c = FUSED_F32_C if cand.family == "spectral_fused" else 32
    budget = (STAGES[cand.family] * prec_upper_bound(eps, mag)
              + f32_c * F32_EPS * mag + ATOL)
    if perturb:
        # seeded violation: shift the kernel output by perturb×budget so
        # any |perturb| > 1 must trip the gate everywhere
        got = got + perturb * budget
    diff = np.abs(got - ref)
    excess = float((diff - budget).max())
    result = {
        "passed": bool(np.all(diff <= budget)),
        "max_err": float(diff.max()),
        "budget_min": float(budget.min()),
        "worst_excess": excess,
    }
    if not result["passed"]:
        from repro.obs import oracle_reject
        from .cache import entry_key

        oracle_reject(
            f"{entry_key(cand.family, cand.shape, cand.dtype)}"
            f"|b{cand.block_fwd}x{cand.block_bwd}",
            max_err=result["max_err"], budget_min=result["budget_min"],
            worst_excess=excess)
    return result
