"""End-to-end serve smoke: drive a tiny LM engine and a tiny FNO engine
and record their ``stats()`` next to the dry-run artifact.

This is the serving analogue of the dry-run cells: a real (CPU-sized)
engine run whose artifact records the resolved precision site table
*and* the engine's own accounting — tokens/s / fields/s, slot occupancy,
queue wait, admission counters — so CI tracks the serving path the same
way it tracks lowered training cells.

    PYTHONPATH=src python -m repro.launch.serve_smoke
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.fno_paper import FNO_DARCY_SMOKE
from repro.core import get_policy
from repro.models import init_fno
from repro.models.lm import init_lm
from repro.precision import describe
from repro.serve import (
    FieldRequest,
    LMEngine,
    OperatorEngine,
    PagedLMEngine,
    Request,
    SamplingParams,
)

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "results", "serve_smoke.json")


def run_lm_smoke(policy_name: str = "full") -> dict:
    cfg = get_config("smollm-360m", smoke=True)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    policy = get_policy(policy_name)
    engine = LMEngine(params, cfg, n_slots=2, max_len=64, policy=policy,
                      scheduler="spf", prefill_chunk=8, seed=0)
    rng = np.random.RandomState(0)
    reqs = [
        Request(uid=i,
                prompt=list(rng.randint(1, cfg.vocab, rng.randint(3, 12))),
                max_new_tokens=6,
                sampling=SamplingParams(temperature=0.7, top_p=0.9)
                if i % 2 else SamplingParams())
        for i in range(6)
    ]
    # one oversized request proves the failure path stays accounted
    reqs.append(Request(uid=99, prompt=[1] * 100, max_new_tokens=10))
    for r in reqs:
        engine.submit(r)
    finished, ticks = engine.drain(max_ticks=500)
    assert sum(r.status == "done" for r in finished) == 6, finished
    assert sum(r.status == "failed" for r in finished) == 1
    return {"arch": cfg.name, "policy": policy_name,
            "policy_sites": describe(policy), "stats": engine.stats()}


def run_paged_lm_smoke(policy_name: str = "full") -> dict:
    """Paged engine over repeated-prefix prompts: the artifact must show
    prefix hits (shared blocks doing real work) and a greedy stream
    identical to the dense engine's."""
    cfg = get_config("smollm-360m", smoke=True)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    policy = get_policy(policy_name)
    rng = np.random.RandomState(0)
    shared = list(rng.randint(1, cfg.vocab, 16))
    mk = lambda: [  # noqa: E731
        Request(uid=i, prompt=shared + list(rng2.randint(1, cfg.vocab, 2)),
                max_new_tokens=4)
        for rng2 in [np.random.RandomState(7)] for i in range(6)]
    engine = PagedLMEngine(params, cfg, n_slots=2, max_len=32, policy=policy,
                           prefill_chunk=8, block_size=8)
    finished, _ = engine.run_until_done(mk())
    assert all(r.status == "done" for r in finished), finished
    dense = LMEngine(params, cfg, n_slots=2, max_len=32, policy=policy,
                     prefill_chunk=8)
    d_finished, _ = dense.run_until_done(mk())
    assert ({r.uid: r.generated for r in finished}
            == {r.uid: r.generated for r in d_finished})
    stats = engine.stats()
    assert stats["paged"]["prefix"]["hits"] > 0, stats["paged"]
    assert stats["prompt_tokens"] < dense.stats()["prompt_tokens"]
    return {"arch": cfg.name, "policy": policy_name, "stats": stats}


def run_operator_smoke(policy_name: str = "mixed_fno_bf16") -> dict:
    cfg = FNO_DARCY_SMOKE
    params = init_fno(jax.random.PRNGKey(1), cfg)
    policy = get_policy(policy_name)
    engine = OperatorEngine(params, cfg, model="fno", policy=policy,
                            max_batch=4, memo_window=8)
    rng = np.random.RandomState(1)
    reqs = [FieldRequest(uid=i, x=rng.randn(1, 16, 16).astype(np.float32))
            for i in range(5)]
    reqs += [FieldRequest(uid=10 + i, x=rng.randn(1, 32, 32).astype(np.float32))
             for i in range(3)]
    # a repeat of an already-served field: the content-hash memo must
    # answer it without recompute (counter lands in the artifact)
    reqs.append(FieldRequest(uid=20, x=np.array(reqs[0].x, copy=True)))
    for r in reqs:
        engine.submit(r)
    finished, ticks = engine.drain(max_ticks=50)
    assert all(r.status == "done" for r in finished), finished
    repeat = next(r for r in finished if r.uid == 20)
    assert np.array_equal(repeat.y, reqs[0].y)
    assert engine.stats()["memo"]["hits"] >= 1
    return {"arch": "fno-darcy-smoke", "policy": policy_name,
            "policy_sites": describe(policy), "stats": engine.stats()}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=RESULTS)
    ap.add_argument("--lm-policy", default="full")
    ap.add_argument("--operator-policy", default="mixed_fno_bf16")
    ap.add_argument("--obs-trace", default=None, metavar="OUT_JSONL",
                    help="enable repro.obs tracing across the three "
                         "engine runs and write the timeline + metrics "
                         "snapshot as JSONL (plus <stem>.trace.json and "
                         "<stem>.prom)")
    args = ap.parse_args()

    from repro.obs import trace

    if args.obs_trace:
        trace.enable()

    rec = {
        "lm": run_lm_smoke(args.lm_policy),
        "lm_paged": run_paged_lm_smoke(args.lm_policy),
        "operator": run_operator_smoke(args.operator_policy),
    }
    from repro.obs import write_result

    write_result(args.out, rec)
    print(json.dumps(rec, indent=1))
    print(f"\nserve smoke ok -> {args.out}")

    if args.obs_trace:
        from repro.obs import (registry, run_records, write_chrome_trace,
                               write_jsonl, write_prometheus)

        recs = trace.snapshot()
        snap = registry().snapshot()
        write_jsonl(args.obs_trace,
                    run_records(recs, snapshot=snap, run="serve_smoke"))
        stem = os.path.splitext(args.obs_trace)[0]
        write_chrome_trace(stem + ".trace.json", recs)
        write_prometheus(stem + ".prom", snap)
        print(f"obs: {len(recs)} trace records -> {args.obs_trace} "
              f"(+ {stem}.trace.json, {stem}.prom)")


if __name__ == "__main__":
    main()
