"""repro.dist — declarative distribution layer.

Two halves, one rule table:

* :mod:`repro.dist.constrain` — ambient-mesh ``with_sharding_constraint``
  wrappers taking *logical* axis names, used inside models.
* :mod:`repro.dist.sharding` — pytree spec derivation with
  divisibility-checked fallback chains, used by launch / serving code.

Models never name a physical mesh axis; the logical->physical mapping
lives in :mod:`repro.dist.rules` and is overridable per scope.
"""
from .constrain import (  # noqa: F401
    ambient_mesh,
    constrain,
    constrain_bhsd,
    constrain_bsd,
    constrain_spatial,
    constrain_tokens,
    logical_axis_size,
    use_mesh,
)
from .rules import DEFAULT_RULES, axis_rules, current_rules  # noqa: F401
from .sharding import (  # noqa: F401
    batch_specs,
    cache_specs,
    dp_axes,
    fno_param_specs,
    lm_param_specs,
    pick_spec,
    replication_report,
    to_named,
)
