"""PDE-inference-as-a-service demo: micro-batched mixed-precision FNO
serving through the same Engine API as the LM demo.

Submits Darcy-style coefficient fields at two resolutions; the
``OperatorEngine`` buckets them by grid, pads each micro-batch to a
fixed width (one compiled kernel per resolution), and runs the batched
``fno_infer`` under the requested precision rule set.  Batched outputs
are verified bit-identical against a solo run — micro-batching is a
pure throughput knob:

    PYTHONPATH=src python examples/serve_darcy.py --policy mixed_fno_bf16
"""
import argparse
import json

import jax
import numpy as np

from repro.configs.fno_paper import FNO_DARCY_SMOKE
from repro.core import get_policy
from repro.data import grf_2d
from repro.models import init_fno
from repro.serve import FieldRequest, OperatorEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="mixed_fno_bf16")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--scheduler", default="fcfs", choices=["fcfs", "spf"])
    args = ap.parse_args()

    cfg = FNO_DARCY_SMOKE
    policy = get_policy(args.policy)
    params = init_fno(jax.random.PRNGKey(0), cfg)
    engine = OperatorEngine(params, cfg, model="fno", policy=policy,
                            max_batch=args.max_batch,
                            scheduler=args.scheduler)

    key = jax.random.PRNGKey(1)
    reqs = []
    for i in range(args.requests):
        key, k = jax.random.split(key)
        n = 16 if i % 2 else 32   # two resolution buckets
        a = np.asarray(grf_2d(k, n, batch=1))          # (1, n, n) coeff field
        reqs.append(FieldRequest(uid=i, x=a))
    for r in reqs:
        engine.submit(r)
    done, ticks = engine.drain()
    stats = engine.stats()
    print(f"policy={args.policy} max_batch={args.max_batch}: served "
          f"{stats['fields_served']} fields in {ticks} ticks "
          f"({stats['fields_per_s']} fields/s on CPU); "
          f"buckets={stats['buckets']}")

    # micro-batching is bit-exact: replay one request through a fresh engine
    probe = done[0]
    solo = OperatorEngine(params, cfg, model="fno", policy=policy,
                          max_batch=args.max_batch)
    sr = FieldRequest(uid=0, x=probe.x)
    solo.submit(sr)
    solo.drain()
    assert np.array_equal(sr.y, probe.y), "batched != solo"
    print("batched == solo: bit-identical")
    print("stats:", json.dumps(stats, indent=1))


if __name__ == "__main__":
    main()
