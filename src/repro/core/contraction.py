"""Memory-greedy tensor-contraction engine (paper Section 4.2, Appendix B.12).

The paper decomposes every multi-operand spectral einsum into *two-operand*
sub-contractions, chooses the next pair **greedily by intermediate tensor
size** (opt-einsum's default instead minimises FLOPs — Table 10 shows the
memory-greedy path saves up to 12% memory on 3-D problems), and **caches the
path** because shapes are static (Table 9: path search is up to 76% of the
contraction cost if re-done per call).

This module provides:

* ``greedy_path(expr, shapes, objective)`` — pairwise contraction path,
  ``objective in {"memory", "flops"}``.
* ``PathCache`` — shape-keyed memoisation of paths.
* ``contract(expr, *ops, policy=...)`` — executes the path; operands may be
  real arrays, complex64 arrays, or split-real ``ComplexPair``s.  Pairwise
  complex products on ComplexPairs run as **real einsums with f32
  accumulation** (``preferred_element_type``) and re-quantise the result to
  the policy's spectral dtype — the TPU-native version of the paper's
  view-as-real half GEMMs (Option C of Table 8: low-dimensional sub-results
  stay complex; only the big contractions go split-real).
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp

from .precision import ComplexPair
from repro.precision import FULL

Path = Tuple[Tuple[int, int], ...]
Parsed = Tuple[List[str], str, Dict[str, int]]


# ---------------------------------------------------------------------------
# Expression parsing
# ---------------------------------------------------------------------------


def _parse(expr: str, shapes: Sequence[Tuple[int, ...]]):
    expr = expr.replace(" ", "")
    if "->" in expr:
        lhs, out = expr.split("->")
    else:
        lhs = expr
        # implicit output: indices appearing exactly once, sorted
        counts: Dict[str, int] = {}
        for term in lhs.split(","):
            for ch in term:
                counts[ch] = counts.get(ch, 0) + 1
        out = "".join(sorted(ch for ch, n in counts.items() if n == 1))
    terms = lhs.split(",")
    if len(terms) != len(shapes):
        raise ValueError(f"{expr}: {len(terms)} terms but {len(shapes)} operands")
    dims: Dict[str, int] = {}
    for term, shape in zip(terms, shapes, strict=True):
        if len(term) != len(shape):
            raise ValueError(f"term {term} rank mismatch with shape {shape}")
        for ch, s in zip(term, shape, strict=True):
            if ch in dims and dims[ch] != s:
                raise ValueError(f"index {ch}: size {dims[ch]} vs {s}")
            dims[ch] = s
    return terms, out, dims


def _pair_output(a: str, b: str, others: List[str], final: str) -> str:
    """Indices of the intermediate from contracting terms a,b: every index of
    a∪b still needed by a remaining operand or the final output."""
    needed = set(final)
    for t in others:
        needed |= set(t)
    keep = [ch for ch in dict.fromkeys(a + b) if ch in needed]
    return "".join(keep)


def _size(term: str, dims: Dict[str, int]) -> int:
    n = 1
    for ch in term:
        n *= dims[ch]
    return n


def _pair_flops(a: str, b: str, _out: str, dims: Dict[str, int]) -> int:
    # 2 * prod(all involved indices)
    return 2 * _size("".join(dict.fromkeys(a + b)), dims)


# ---------------------------------------------------------------------------
# Greedy path search
# ---------------------------------------------------------------------------


def greedy_path(
    expr: str,
    shapes: Sequence[Tuple[int, ...]],
    objective: str = "memory",
    parsed: Optional[Parsed] = None,
) -> Path:
    """Pairwise contraction order.

    ``objective="memory"``: at each step pick the pair minimising the size of
    the intermediate tensor (the paper's choice).  ``"flops"``: minimise the
    pairwise FLOP count (opt-einsum-default-like), used as the ablation
    baseline for Table 10.

    ``parsed`` lets a caller that already ran ``_parse`` (e.g. ``contract``)
    hand the result through instead of re-parsing the expression.
    """
    terms, final, dims = parsed if parsed is not None else _parse(expr, shapes)
    terms = list(terms)
    ids = list(range(len(terms)))  # position -> original operand id chains
    path: List[Tuple[int, int]] = []
    while len(terms) > 1:
        best = None
        for i in range(len(terms)):
            for j in range(i + 1, len(terms)):
                others = [t for k, t in enumerate(terms) if k not in (i, j)]
                out = _pair_output(terms[i], terms[j], others, final)
                mem = _size(out, dims)
                fl = _pair_flops(terms[i], terms[j], out, dims)
                key = (mem, fl) if objective == "memory" else (fl, mem)
                if best is None or key < best[0]:
                    best = (key, i, j, out)
        _, i, j, out = best
        path.append((i, j))
        new_terms = [t for k, t in enumerate(terms) if k not in (i, j)] + [out]
        terms = new_terms
    return tuple(path)


class PathCache:
    """Shape-keyed path memoisation (Table 9: avoids the 60-76% path-search
    overhead per einsum call).  Thread-safe; shapes are static under jit so
    in practice each (expr, shapes) is computed exactly once per process."""

    def __init__(self):
        self._cache: Dict[Any, Path] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(
        self,
        expr: str,
        shapes: Sequence[Tuple[int, ...]],
        objective: str,
        parsed: Optional[Parsed] = None,
    ) -> Path:
        key = (expr, tuple(map(tuple, shapes)), objective)
        with self._lock:
            p = self._cache.get(key)
            if p is not None:
                self.hits += 1
                return p
        p = greedy_path(expr, shapes, objective, parsed=parsed)
        with self._lock:
            self._cache[key] = p
            self.misses += 1
        return p

    def clear(self):
        with self._lock:
            self._cache.clear()
            self.hits = self.misses = 0


_GLOBAL_PATH_CACHE = PathCache()


def global_path_cache() -> PathCache:
    return _GLOBAL_PATH_CACHE


# ---------------------------------------------------------------------------
# Pairwise execution with mixed precision
# ---------------------------------------------------------------------------


def _is_complexpair(x) -> bool:
    return isinstance(x, ComplexPair)


def _einsum_real(expr, a, b, accum_dtype, out_dtype):
    r = jnp.einsum(expr, a, b, preferred_element_type=accum_dtype)
    return r.astype(out_dtype)


def _pairwise(
    expr: str,
    a,
    b,
    policy,
):
    """One two-operand contraction, dispatching on operand kinds.

    ComplexPair × ComplexPair  -> 4 real einsums, f32 accumulate, requantise.
    ComplexPair × real         -> 2 real einsums.
    complex64 × {complex64,real} -> native jnp.einsum (full path).
    """
    accum = policy.accum_dtype
    if _is_complexpair(a) or _is_complexpair(b):
        half = policy.spectral_dtype or jnp.float32
        if _is_complexpair(a) and _is_complexpair(b):
            rr = jnp.einsum(expr, a.re, b.re, preferred_element_type=accum)
            ii = jnp.einsum(expr, a.im, b.im, preferred_element_type=accum)
            ri = jnp.einsum(expr, a.re, b.im, preferred_element_type=accum)
            ir = jnp.einsum(expr, a.im, b.re, preferred_element_type=accum)
            return ComplexPair((rr - ii).astype(half), (ri + ir).astype(half))
        if _is_complexpair(a):
            breal = b.astype(half) if b.dtype != half else b
            return ComplexPair(
                _einsum_real(expr, a.re, breal, accum, half),
                _einsum_real(expr, a.im, breal, accum, half),
            )
        areal = a.astype(half) if a.dtype != half else a
        return ComplexPair(
            _einsum_real(expr, areal, b.re, accum, half),
            _einsum_real(expr, areal, b.im, accum, half),
        )
    # full-precision (or real-only) path
    return jnp.einsum(expr, a, b, preferred_element_type=None if jnp.iscomplexobj(a) or jnp.iscomplexobj(b) else accum)


def contract(
    expr: str,
    *operands,
    policy=FULL,
    objective: str = "memory",
    cache: Optional[PathCache] = None,
):
    """Execute a multi-operand einsum along the memory-greedy path.

    ``policy`` may be a ``PrecisionPolicy`` (resolved at its spectral
    contraction site) or a ``SitePrecision`` already resolved by the caller
    (``policy.at("fno/layer2/spectral/contract")``) — anything exposing
    ``spectral_dtype`` / ``spectral_is_half`` / ``accum_dtype``.

    Operands may be real jnp arrays, complex arrays, or ComplexPair.  With a
    half-precision rule in force, complex operands are converted to
    split-real ComplexPairs first (the paper's "both weights and inputs in
    half" — see Table 11: weights-only-half forfeits nearly all the memory
    win).
    """
    cache = cache or _GLOBAL_PATH_CACHE
    ops = list(operands)

    # Cast complex operands to the spectral representation mandated by policy.
    if policy.spectral_is_half:
        ops = [
            ComplexPair.from_complex(o, policy.spectral_dtype)
            if (not _is_complexpair(o)) and jnp.iscomplexobj(o)
            else o
            for o in ops
        ]
        # real operands participating in spectral contraction go to half too
        ops = [
            o.astype(policy.spectral_dtype)
            if (not _is_complexpair(o)) and o.dtype in (jnp.float32, jnp.float64)
            else o
            for o in ops
        ]

    shapes = [o.shape for o in ops]
    parsed = _parse(expr, shapes)
    terms, final, dims = parsed
    path = cache.get(expr, shapes, objective, parsed=parsed)

    terms = list(terms)
    vals = list(ops)
    for (i, j) in path:
        others = [t for k, t in enumerate(terms) if k not in (i, j)]
        out = _pair_output(terms[i], terms[j], others, final)
        sub = f"{terms[i]},{terms[j]}->{out}"
        res = _pairwise(sub, vals[i], vals[j], policy)
        vals = [v for k, v in enumerate(vals) if k not in (i, j)] + [res]
        terms = others + [out]

    (result,) = vals
    (term,) = terms
    if term != final:
        # final transpose/trace fix-up
        perm_expr = f"{term}->{final}"
        if _is_complexpair(result):
            result = ComplexPair(
                jnp.einsum(perm_expr, result.re), jnp.einsum(perm_expr, result.im)
            )
        else:
            result = jnp.einsum(perm_expr, result)
    return result


def path_intermediate_bytes(
    expr: str, shapes: Sequence[Tuple[int, ...]], path: Path, itemsize: int = 4
) -> int:
    """Peak intermediate size along a path (for napkin math / Table 10)."""
    terms, final, dims = _parse(expr, shapes)
    terms = list(terms)
    peak = 0
    for step, (i, j) in enumerate(path):
        others = [t for k, t in enumerate(terms) if k not in (i, j)]
        out = _pair_output(terms[i], terms[j], others, final)
        if step < len(path) - 1:  # the last step's output is the result,
            peak = max(peak, _size(out, dims) * itemsize)  # not an intermediate
        terms = others + [out]
    return peak


def path_flops(expr: str, shapes: Sequence[Tuple[int, ...]], path: Path) -> int:
    terms, final, dims = _parse(expr, shapes)
    terms = list(terms)
    total = 0
    for (i, j) in path:
        others = [t for k, t in enumerate(terms) if k not in (i, j)]
        out = _pair_output(terms[i], terms[j], others, final)
        total += _pair_flops(terms[i], terms[j], out, dims)
        terms = others + [out]
    return total
