"""Training substrate tests: optimizer, loss scaling, trainer loop,
checkpoint/restart, preemption, precision schedule, grad compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PrecisionSchedule
from repro.models import FNOConfig, fno_apply, init_fno
from repro.optim import AdamW, compress_tree, init_loss_scale, unscale_grads, update_loss_scale
from repro.train import Trainer, TrainerConfig, checkpoint, relative_h1, relative_l2
from repro.train.losses import cross_entropy

jax.config.update("jax_platform_name", "cpu")


class TestLosses:
    def test_relative_l2_zero_on_equal(self):
        x = jnp.ones((2, 1, 8, 8))
        assert float(relative_l2(x, x)) < 1e-6

    def test_relative_h1_penalises_gradient_error(self):
        t = jnp.zeros((1, 1, 32, 32))
        xx = jnp.linspace(0, 2 * np.pi, 32, endpoint=False)
        smooth = 0.1 * jnp.ones((1, 1, 32, 32))
        wiggly = 0.1 * jnp.sin(8 * xx)[None, None, :, None] * jnp.ones((1, 1, 32, 32))
        t1 = jnp.ones((1, 1, 32, 32))  # target with unit norm
        assert float(relative_h1(wiggly, t1)) > float(relative_h1(smooth, t1))

    def test_cross_entropy_matches_uniform(self):
        logits = jnp.zeros((2, 3, 7))
        labels = jnp.zeros((2, 3), jnp.int32)
        np.testing.assert_allclose(float(cross_entropy(logits, labels)), np.log(7), rtol=1e-5)


class TestAdamW:
    def test_converges_quadratic(self):
        opt = AdamW(lr=0.1, weight_decay=0.0)
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = opt.init(params)
        loss = lambda p: jnp.sum(p["w"] ** 2)
        for _ in range(200):
            g = jax.grad(loss)(params)
            params, state = opt.update(g, state, params)
        assert float(loss(params)) < 1e-3

    def test_grad_clip(self):
        opt = AdamW(lr=0.0, grad_clip_norm=1.0)
        params = {"w": jnp.zeros(3)}
        state = opt.init(params)
        g = {"w": jnp.asarray([1e6, 0.0, 0.0])}
        new_params, new_state = opt.update(g, state, params)
        assert float(jnp.abs(new_state.mu["w"]).max()) <= 0.2  # clipped to norm 1

    def test_half_grads_upcast(self):
        opt = AdamW(lr=0.1)
        params = {"w": jnp.ones(2)}
        state = opt.init(params)
        g = {"w": jnp.ones(2, jnp.bfloat16)}
        new_params, _ = opt.update(g, state, params)
        assert new_params["w"].dtype == jnp.float32


class TestLossScale:
    def test_scale_unscale_roundtrip(self):
        s = init_loss_scale(1024.0)
        grads = {"w": jnp.asarray([2.0])}
        scaled = jax.tree_util.tree_map(lambda g: g * s.scale, grads)
        back = unscale_grads(scaled, s)
        np.testing.assert_allclose(np.asarray(back["w"]), [2.0])

    def test_backoff_on_nonfinite(self):
        s = init_loss_scale(1024.0)
        s2 = update_loss_scale(s, jnp.asarray(False))
        assert float(s2.scale) == 512.0

    def test_growth_after_interval(self):
        s = init_loss_scale(8.0)
        for _ in range(200):
            s = update_loss_scale(s, jnp.asarray(True), growth_interval=200)
        assert float(s.scale) == 16.0


def _tiny_problem():
    cfg = FNOConfig(
        in_channels=1, out_channels=1, hidden_channels=8,
        lifting_channels=8, projection_channels=8, n_layers=1, modes=(4, 4),
    )
    params = init_fno(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(4, 1, 16, 16), jnp.float32)
    t = jnp.asarray(rng.randn(4, 1, 16, 16) * 0.1, jnp.float32)

    def loss_fn(p, batch, policy):
        y = fno_apply(p, batch["x"], cfg, policy)
        return relative_l2(y, batch["t"])

    batch_fn = lambda _step: {"x": x, "t": t}
    return params, loss_fn, batch_fn


class TestTrainer:
    def test_loss_decreases(self):
        params, loss_fn, batch_fn = _tiny_problem()
        tr = Trainer(loss_fn, params, TrainerConfig(total_steps=15))
        hist = tr.run(batch_fn)
        assert hist[-1]["loss"] < hist[0]["loss"]

    def test_precision_schedule_switches(self):
        params, loss_fn, batch_fn = _tiny_problem()
        sched = PrecisionSchedule(
            phases=((0.4, "mixed_fno_bf16"), (1.0, "full"))
        )
        tr = Trainer(loss_fn, params, TrainerConfig(total_steps=10, schedule=sched))
        hist = tr.run(batch_fn)
        policies = [h["policy"] for h in hist]
        assert policies[0] == "mixed_fno_bf16" and policies[-1] == "full"
        assert tr.stats["recompiles"] == 2

    def test_fp16_loss_scaling_runs(self):
        params, loss_fn, batch_fn = _tiny_problem()
        sched = PrecisionSchedule.constant("mixed_fno_fp16")
        tr = Trainer(loss_fn, params, TrainerConfig(total_steps=5, schedule=sched))
        hist = tr.run(batch_fn)
        assert np.isfinite([h["loss"] for h in hist]).all()

    def test_microbatch_equivalence(self):
        """grad accumulation over 2 microbatches ~ full-batch gradient."""
        params, loss_fn, batch_fn = _tiny_problem()
        t1 = Trainer(loss_fn, params, TrainerConfig(total_steps=3, microbatches=1))
        t2 = Trainer(loss_fn, params, TrainerConfig(total_steps=3, microbatches=2))
        h1 = t1.run(batch_fn)
        h2 = t2.run(batch_fn)
        np.testing.assert_allclose(h1[0]["loss"], h2[0]["loss"], rtol=1e-4)

    def test_checkpoint_restart(self, tmp_path):
        params, loss_fn, batch_fn = _tiny_problem()
        d = str(tmp_path / "ck")
        tr = Trainer(loss_fn, params, TrainerConfig(total_steps=10, ckpt_dir=d, ckpt_every=5))
        tr.run(batch_fn, steps=7)
        tr._ckptr.wait()
        # fresh trainer, restore, continue
        tr2 = Trainer(loss_fn, params, TrainerConfig(total_steps=10, ckpt_dir=d, ckpt_every=5))
        assert tr2.restore()
        assert tr2.step == 5
        tr2.run(batch_fn)
        assert tr2.step == 10

    def test_preemption_checkpoints_and_stops(self, tmp_path):
        params, loss_fn, batch_fn = _tiny_problem()
        d = str(tmp_path / "ck2")
        tr = Trainer(loss_fn, params, TrainerConfig(total_steps=100, ckpt_dir=d, ckpt_every=1000))
        # simulate SIGTERM after 3 steps via wrapping batch_fn
        def preempting_batch(step):
            if step == 3:
                tr._on_preempt()
            return batch_fn(step)
        tr.run(preempting_batch)
        assert tr.step <= 4
        assert checkpoint.latest_step(d) is not None


class TestCheckpoint:
    def test_atomic_save_restore(self, tmp_path):
        d = str(tmp_path / "c")
        tree = {"a": jnp.arange(5.0), "b": {"c": jnp.ones((2, 2))}}
        checkpoint.save(d, 3, tree)
        restored, step = checkpoint.restore(d, tree)
        assert step == 3
        np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(5.0))

    def test_keep_last_k(self, tmp_path):
        d = str(tmp_path / "c")
        tree = {"a": jnp.zeros(1)}
        for s in range(6):
            checkpoint.save(d, s, tree, keep_last_k=2)
        assert checkpoint.latest_step(d) == 5
        dirs = [x for x in os.listdir(d) if x.startswith("step_")]
        assert len(dirs) == 2

    def test_elastic_restore_with_sharding(self, tmp_path):
        """Restore re-shards onto the current mesh (1-device here)."""
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

        d = str(tmp_path / "c")
        tree = {"w": jnp.arange(8.0)}
        checkpoint.save(d, 0, tree)
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        sh = {"w": NamedSharding(mesh, P())}
        restored, _ = checkpoint.restore(d, tree, shardings=sh)
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(8.0))


class TestGradCompression:
    def test_bf16_compression_small_error(self):
        rng = np.random.RandomState(0)
        g = {"w": jnp.asarray(rng.randn(1000), jnp.float32)}
        c = compress_tree(g)
        err = np.abs(np.asarray(c["w"], np.float32) - np.asarray(g["w"]))
        rel = err / (np.abs(np.asarray(g["w"])) + 1e-9)
        assert rel.mean() < 5e-3
        assert c["w"].dtype == jnp.bfloat16
