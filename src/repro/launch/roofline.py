"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch × mesh), in seconds:

    compute    = HLO_FLOPs            / (chips × peak_FLOP/s)
    memory     = HLO_bytes_accessed   / (chips × HBM_bw)
    collective = collective_bytes     / (chips × link_bw)

``compiled.cost_analysis()`` counts each while-loop body ONCE (verified in
tests/test_roofline.py: a lax.scan of length 8 reports exactly 1/8 of the
true FLOPs), which makes it useless for scan-over-layers models.  We
therefore parse the post-partitioning HLO text ourselves:

  * FLOPs: every ``dot`` op (2 · |out| · K, K from lhs_contracting_dims),
    accumulated through fusions/calls, and multiplied by while-loop trip
    counts extracted from each loop condition's comparison constant.
  * bytes: operand+output bytes of every materialising op at fusion
    granularity (fusion boundaries = HBM round-trips), same loop scaling.
  * collective bytes: output bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute, same loop scaling.

All values are per-device (the HLO is the post-SPMD per-device program).

Hardware model (TPU v5e-class, from the assignment):
    197 TFLOP/s bf16 per chip · 819 GB/s HBM · ~50 GB/s/link ICI.
"""
from __future__ import annotations


PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

from .hlo_parse import HLOCounts, parse_hlo  # noqa: F401  (re-export)
import dataclasses as _dc


@_dc.dataclass
class CollectiveStats:
    bytes_by_kind: dict

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def collective_bytes(hlo: str) -> CollectiveStats:
    return CollectiveStats(parse_hlo(hlo).collective_by_kind)


@_dc.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    n_devices: int
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def to_dict(self) -> dict:
        return _dc.asdict(self) | {
            "dominant": self.dominant, "step_time_s": self.step_time_s}


def analyze_counts(counts: HLOCounts, n_devices: int) -> Roofline:
    return Roofline(
        flops_per_device=counts.flops,
        bytes_per_device=counts.bytes,
        collective_bytes_per_device=counts.collective_bytes,
        n_devices=n_devices,
        compute_s=counts.flops / PEAK_FLOPS,
        memory_s=counts.bytes / HBM_BW,
        collective_s=counts.collective_bytes / ICI_BW,
    )


def model_flops(n_params_active: float, tokens: float) -> float:
    """6·N·D napkin-math (per the assignment: N_active for MoE)."""
    return 6.0 * n_params_active * tokens


def spectral_kernel_vmem(B: int, I: int, O: int, modes, *, rank: int = 0,
                         l_shared: bool = False,
                         itemsize: int = 2, dtype: str = None) -> dict:
    """Tiling record for the Pallas spectral-contraction kernel at one
    dry-run cell: the budget-chosen tile and the fwd/bwd VMEM working
    sets it implies — dense when ``rank == 0``, CP otherwise, or the
    SFNO l-shared kernel when ``l_shared`` (then ``modes = (lmax, mmax)``
    and the tile runs over degrees).  The wrappers resolve the same
    ``pick_block_*`` choice at run time, so this record describes the
    tiling that actually executes.  When ``dtype`` is given and an
    active calibration state (``repro.tune``) holds a validated entry
    for the cell, the tuned fwd/bwd tiles replace the heuristic and the
    record says so via ``tile_source``.  Dry-runs attach it next to the
    roofline so a cell that would spill VMEM is visible without
    compiling for real hardware."""
    from repro.kernels.ops import (
        cp_vmem_bytes, lshared_vmem_bytes, pick_block_l, pick_block_m,
        vmem_bytes, vmem_bytes_bwd)
    from repro.kernels.spectral_contract import VMEM_BUDGET

    def _calibrated(family, shape):
        if dtype is None:
            return None
        from repro.tune.cache import active_cache

        cache = active_cache()
        if cache is None:
            return None
        ent = cache.lookup(family, shape, dtype)
        if ent is None:
            return None
        return int(ent["block_fwd"]), int(ent["block_bwd"])

    if l_shared:
        L, Mm = (int(m) for m in modes)
        tuned = _calibrated("lshared", (B, I, O, L, Mm))
        if tuned:
            bl, bl_bwd = tuned
        else:
            bl = bl_bwd = pick_block_l(B, I, O, L, Mm, itemsize=itemsize)
        fwd = lshared_vmem_bytes(B, I, O, Mm, bl, itemsize)
        bwd = lshared_vmem_bytes(B, I, O, Mm, bl_bwd, itemsize)
        tile, tile_bwd, n_tiled, kind = bl, bl_bwd, L, "l_shared"
    else:
        M = 1
        for m in modes:
            M *= int(m)
        shape = (B, I, O, rank, M) if rank else (B, I, O, M)
        tuned = _calibrated("cp" if rank else "dense", shape)
        if tuned:
            tile, tile_bwd = tuned
        else:
            tile = tile_bwd = pick_block_m(B, I, O, M, rank=rank,
                                           itemsize=itemsize)
        if rank:
            fwd = cp_vmem_bytes(B, I, O, rank, tile, itemsize)
            bwd = cp_vmem_bytes(B, I, O, rank, tile_bwd, itemsize)
        else:
            fwd = vmem_bytes(B, I, O, tile, itemsize)
            bwd = vmem_bytes_bwd(B, I, O, tile_bwd, itemsize)
        n_tiled, kind = M, ("cp" if rank else "dense")
    return {
        "kind": kind,
        "block": tile,
        "block_bwd": tile_bwd,
        "tiled_extent": n_tiled,
        "grid_steps": -(-n_tiled // tile),
        "rank": rank,
        "itemsize": itemsize,
        "dtype": dtype,
        "tile_source": "calibrated" if tuned else "heuristic",
        "vmem_fwd_bytes": fwd,
        "vmem_bwd_bytes": bwd,
        "fits_vmem": max(fwd, bwd) <= VMEM_BUDGET,
    }
