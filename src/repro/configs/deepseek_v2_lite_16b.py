"""deepseek-v2-lite-16b [moe] — MLA (kv_lora=512) + 2 shared + 64 routed
top-6 experts, per-expert d_ff=1408.
[arXiv:2405.04434; hf]"""
from .base import LMArchConfig

CONFIG = LMArchConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400,
    moe_experts=64, moe_top_k=6, moe_shared=2, moe_ff=1408,
    mla_kv_lora=512, mla_rope_dim=64, mla_nope_dim=128, mla_v_dim=128,
)

SMOKE = LMArchConfig(
    name="deepseek-v2-lite-16b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=64, vocab=256,
    moe_experts=4, moe_top_k=2, moe_shared=1, moe_ff=64,
    mla_kv_lora=32, mla_rope_dim=8, mla_nope_dim=16, mla_v_dim=16,
)
