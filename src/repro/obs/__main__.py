"""``python -m repro.obs`` — run-summary table and format conversion.

Subcommands::

    summary RUN.jsonl            span/event/metrics summary of one run
    chrome  RUN.jsonl OUT.json   convert the JSONL log to a Chrome
                                 trace_event file (load in Perfetto)
    prom    RUN.jsonl OUT.prom   dump the run's final metrics snapshot
                                 in Prometheus text exposition format

A run log is the JSONL file written by ``--obs-trace`` (trainer demo,
serve smoke): a ``meta`` header, the span/event timeline, and a final
``metrics`` snapshot record.
"""
from __future__ import annotations

import argparse
import sys
from collections import defaultdict
from typing import Any, Dict, List, Optional, Tuple

from . import export


def _load(path: str) -> Tuple[Optional[dict], List[dict], Optional[dict]]:
    """Split a run log into (meta, timeline records, metrics snapshot)."""
    meta, timeline, snap = None, [], None
    for rec in export.read_jsonl(path):
        kind = rec.get("kind")
        if kind == "meta":
            meta = rec
        elif kind == "metrics":
            snap = rec.get("snapshot")
        elif kind in ("span", "event", "b", "e"):
            timeline.append(rec)
    return meta, timeline, snap


def _fmt_ns(ns: float) -> str:
    if ns >= 1e9:
        return f"{ns / 1e9:.3f}s"
    if ns >= 1e6:
        return f"{ns / 1e6:.2f}ms"
    return f"{ns / 1e3:.1f}us"


def _table(rows: List[List[str]], header: List[str]) -> str:
    widths = [max(len(str(r[i])) for r in [header] + rows)
              for i in range(len(header))]
    fmt = "  ".join(f"{{:<{w}}}" for w in widths)
    lines = [fmt.format(*header), fmt.format(*("-" * w for w in widths))]
    lines += [fmt.format(*map(str, r)) for r in rows]
    return "\n".join(lines)


def cmd_summary(args) -> int:
    meta, timeline, snap = _load(args.run)
    if meta:
        print(f"run: backend={meta.get('backend')} "
              f"jax={meta.get('jax_version')} "
              f"sha={(meta.get('git_sha') or '?')[:12]} "
              f"at={meta.get('timestamp_utc')}")
    # spans: count / total / mean per name
    agg: Dict[str, List[float]] = defaultdict(list)
    n_events: Dict[str, int] = defaultdict(int)
    for rec in timeline:
        if rec.get("kind") == "span":
            agg[rec["name"]].append(float(rec.get("dur_ns", 0)))
        elif rec.get("kind") == "event":
            n_events[rec["name"]] += 1
    if agg:
        rows = []
        for name, durs in sorted(agg.items(),
                                 key=lambda kv: -sum(kv[1])):
            rows.append([name, len(durs), _fmt_ns(sum(durs)),
                         _fmt_ns(sum(durs) / len(durs)),
                         _fmt_ns(max(durs))])
        print()
        print(_table(rows, ["span", "count", "total", "mean", "max"]))
    if n_events:
        print()
        print(_table(sorted([[k, v] for k, v in n_events.items()],
                            key=lambda r: -r[1]),
                     ["event", "count"]))
    if snap:
        rows = []
        for series, v in snap.get("counters", {}).items():
            rows.append([series, "counter", f"{v:g}"])
        for series, v in snap.get("gauges", {}).items():
            rows.append([series, "gauge", f"{v:g}"])
        for series, h in snap.get("histograms", {}).items():
            rows.append([series, "histogram",
                         f"count={h['count']} sum={h['sum']:g}"])
        for name, val in snap.get("external", {}).items():
            rows.append([name, "external", str(val)])
        if rows:
            print()
            print(_table(rows, ["metric", "type", "value"]))
    if not timeline and not snap:
        print("no trace records or metrics snapshot in this log",
              file=sys.stderr)
        return 1
    return 0


def cmd_chrome(args) -> int:
    _meta, timeline, _snap = _load(args.run)
    doc = export.chrome_trace(timeline)
    errs = export.validate_chrome_trace(doc)
    if errs:
        for e in errs:
            print(f"invalid trace: {e}", file=sys.stderr)
        return 1
    export.write_json_atomic(args.out, doc)
    n = len(doc["traceEvents"]) - 1  # minus the process_name metadata
    print(f"wrote {args.out} ({n} trace events)")
    return 0


def cmd_prom(args) -> int:
    _meta, _timeline, snap = _load(args.run)
    if snap is None:
        print("run log has no metrics snapshot record", file=sys.stderr)
        return 1
    export.write_prometheus(args.out, snap)
    print(f"wrote {args.out}")
    return 0


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="observability run logs: summarise and convert")
    sub = p.add_subparsers(dest="cmd", required=True)

    s = sub.add_parser("summary", help="span/event/metrics summary")
    s.add_argument("run", help="JSONL run log (--obs-trace output)")
    s.set_defaults(fn=cmd_summary)

    c = sub.add_parser("chrome", help="convert JSONL -> Chrome trace JSON")
    c.add_argument("run")
    c.add_argument("out", help="output trace_event JSON path")
    c.set_defaults(fn=cmd_chrome)

    pr = sub.add_parser("prom", help="dump Prometheus text exposition")
    pr.add_argument("run")
    pr.add_argument("out", help="output .prom path")
    pr.set_defaults(fn=cmd_prom)
    return p


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
