"""Gaussian random fields on the periodic unit torus, sampled spectrally.

The measure N(0, σ²(-Δ + τ²I)^(-α)) is the standard source of PDE initial
conditions / coefficients (Li et al. 2021; Kossaifi et al. 2023).  The
paper's Navier-Stokes forcing uses N(0, 27(-Δ+9I)^{-4}).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def grf_2d(
    key: jax.Array,
    n: int,
    alpha: float = 4.0,
    tau: float = 9.0,
    sigma: float | None = None,
    batch: int = 1,
) -> jnp.ndarray:
    """Sample ``batch`` fields of shape (n, n) from N(0, σ²(-Δ+τ²)^{-α}).

    σ defaults to τ^(α - d/2)·√(2)·... — we follow the convention where the
    covariance is normalised so field variance is O(1); the paper's NS
    forcing (27(-Δ+9I)^{-4}) corresponds to alpha=4, tau=9(? τ²=9), σ²=27.
    """
    kx = jnp.fft.fftfreq(n, d=1.0 / n)
    ky = jnp.fft.fftfreq(n, d=1.0 / n)
    k2 = (kx[:, None] ** 2 + ky[None, :] ** 2) * (2 * jnp.pi) ** 2
    if sigma is None:
        sigma = tau ** (0.5 * (2 * alpha - 2.0))
    # sqrt of covariance spectrum
    sqrt_eig = sigma * (k2 + tau ** 2) ** (-alpha / 2.0)
    sqrt_eig = sqrt_eig.at[0, 0].set(0.0)  # zero-mean

    kr, ki = jax.random.split(key)
    noise = jax.random.normal(kr, (batch, n, n)) + 1j * jax.random.normal(
        ki, (batch, n, n)
    )
    coeff = noise * sqrt_eig[None]
    field = jnp.fft.ifft2(coeff, axes=(-2, -1)).real * n
    return field


def grf_sphere(key: jax.Array, nlat: int, nlon: int, lmax: int = 16, decay: float = 2.0, batch: int = 1):
    """Random smooth fields on the sphere via SHT synthesis of random
    low-degree coefficients with power-law decay."""
    from repro.models.sht import sht_inverse

    mmax = lmax
    kr, ki = jax.random.split(key)
    re = jax.random.normal(kr, (batch, lmax, mmax))
    im = jax.random.normal(ki, (batch, lmax, mmax))
    l = jnp.arange(lmax)[:, None]
    m = jnp.arange(mmax)[None, :]
    amp = (1.0 + l.astype(jnp.float32)) ** (-decay)
    valid = (m <= l).astype(jnp.float32)
    coeffs = (re + 1j * im) * amp * valid
    coeffs = coeffs.at[:, :, 0].set(coeffs[:, :, 0].real.astype(jnp.complex64))
    return sht_inverse(coeffs.astype(jnp.complex64), nlat, nlon)
