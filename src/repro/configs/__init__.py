"""Architecture registry: ``get_config(arch_id)`` / ``--arch <id>``."""
from __future__ import annotations

import importlib
from typing import Dict

from .base import LMArchConfig, ShapeConfig, SHAPES, cell_is_runnable  # noqa: F401

ARCH_IDS = [
    "smollm-360m",
    "granite-34b",
    "stablelm-3b",
    "starcoder2-15b",
    "whisper-large-v3",
    "mamba2-370m",
    "granite-moe-3b-a800m",
    "deepseek-v2-lite-16b",
    "hymba-1.5b",
    "llava-next-mistral-7b",
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch_id: str, smoke: bool = False) -> LMArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.SMOKE if smoke else mod.CONFIG


def all_configs(smoke: bool = False) -> Dict[str, LMArchConfig]:
    return {a: get_config(a, smoke) for a in ARCH_IDS}
