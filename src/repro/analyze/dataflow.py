"""Dtype-flow pass: static precision lint over traced jaxprs.

Traces model forwards (and full Trainer steps) with ``jax.make_jaxpr``
on abstract inputs — nothing executes — then walks every eqn, recursing
into sub-jaxprs (pjit, scan, custom-VJP, Pallas kernels), and checks the
paper's statically-decidable failure modes:

  half-accum-contract (error)   a half-precision ``dot_general`` whose
      accumulation dtype is not f32 *inside a spectral-contract scope* —
      the invariant Theorem 3.2's error model assumes (half storage,
      full accumulation) and the one the MXU gives for free.
  half-accum (warning)          the same outside spectral scopes (the
      dense AMP set accepts half accumulation the way torch.autocast
      does, but it is worth seeing).
  half-accum-reduce             ``reduce_sum``/``reduce_prod`` carried
      out at a half dtype (error inside contract scopes, else warning).
  fp16-overflow-risk (warning)  ``exp`` / ``x**n`` / norm-like reduces
      on an fp16 value with no intervening bounded op (stabiliser,
      tanh, clamp) — the §3 overflow mode.  fp16 only: bf16 keeps the
      f32 exponent range.
  round-trip-cast (warning)     ``f32 → half → f32`` with no compute
      between — wasted HBM bandwidth, unless it is the Thm 3.2 boundary
      quantiser (suppressed by site in ``analyze.toml``).
  fp32-resident (error)         a ``*/spectral/contract`` scope whose
      policy demotes storage to half but whose eqns never touch the
      half dtype — the declared precision does not hold in the lowered
      program (the §4 memory-efficiency failure).

Site attribution rides on ``jax.named_scope``: the precision helpers
(``SitePrecision.stabilize/quantize/contract``) and the Pallas wrappers
push their site address (slashes and all) onto the trace-time name
stack, and ``eqn.source_info.name_stack`` carries it here.
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import core as jcore

from repro.precision.rules import site_matches

from .findings import ERROR, WARNING, Finding

_HALF = (jnp.dtype(jnp.float16), jnp.dtype(jnp.bfloat16))
_F16 = jnp.dtype(jnp.float16)

#: Primitives whose output is bounded O(1) regardless of input — they
#: clear the fp16 overflow taint (this is exactly what the paper's
#: pre-FFT stabilisers are: tanh / clamp families).
_BOUNDED_PRIMS = {
    "tanh", "erf", "erfc", "logistic", "sin", "cos", "sign", "clamp",
    "eq", "ne", "lt", "le", "gt", "ge", "is_finite",
}
#: Shape/layout/identity primitives through which boundedness flows.
_TRANSPARENT_PRIMS = {
    "reshape", "transpose", "broadcast_in_dim", "slice", "squeeze",
    "expand_dims", "rev", "copy", "convert_element_type", "neg", "abs",
    "real", "imag", "conj", "complex", "reduce_max", "reduce_min",
    "stop_gradient", "dynamic_slice", "gather", "pad", "concatenate",
    "select_n", "max", "min",
}
#: Products of bounded values stay bounded (sums too, up to a constant
#: factor irrelevant at fp16 range scale).
_COMBINING_PRIMS = {"mul", "add", "sub", "div"}
#: Primitives that can push a finite fp16 value past 65504.
_OVERFLOW_PRIMS = {"exp", "exp2", "expm1", "cosh", "sinh"}

_SITE_PATH_RE = re.compile(r"[A-Za-z0-9_]+(?:/[A-Za-z0-9_]+)+")


def _dtype_of(v) -> Optional[jnp.dtype]:
    aval = getattr(v, "aval", None)
    dt = getattr(aval, "dtype", None)
    return jnp.dtype(dt) if dt is not None else None


def eqn_site(eqn, inherited: Optional[str]) -> Optional[str]:
    """The innermost precision-site address on the eqn's name stack.

    Transform frames stringify as ``jvp(...)`` / ``transpose(...)`` and
    einsum appends a spec scope (``ij,jk->ik``); plain slash-paths are
    exactly the site strings our ``named_scope`` wiring pushed."""
    s = str(eqn.source_info.name_stack)
    paths = _SITE_PATH_RE.findall(s)
    return paths[-1] if paths else inherited


def _sub_jaxprs(eqn):
    """Yield every sub-jaxpr in an eqn's params (pjit/scan/custom-VJP/
    Pallas/cond all stash them under different keys and shapes)."""
    for v in eqn.params.values():
        if isinstance(v, jcore.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jcore.Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for item in v:
                if isinstance(item, jcore.ClosedJaxpr):
                    yield item.jaxpr
                elif isinstance(item, jcore.Jaxpr):
                    yield item


class _Walk:
    """One recursive walk over a closed jaxpr, accumulating findings."""

    def __init__(self, policy, where: str):
        self.policy = policy
        self.where = where
        self.findings: List[Finding] = []
        #: contract-scope site -> set of dtypes seen on its eqns
        self.site_dtypes: Dict[str, set] = {}

    # -- finding helpers ----------------------------------------------------
    def _emit(self, check: str, severity: str, site: Optional[str],
              detail: str) -> None:
        self.findings.append(Finding(
            pass_name="dataflow", check=check, severity=severity,
            site=site, where=self.where, detail=detail,
        ))

    def _contract_severity(self, site: Optional[str]) -> str:
        if site is not None and site_matches("*/spectral/contract", site):
            return ERROR
        return WARNING

    # -- the walk ------------------------------------------------------------
    def walk(self, jaxpr: jcore.Jaxpr, inherited_site: Optional[str],
             bounded_in: Optional[Sequence[bool]] = None) -> None:
        bounded: Dict[Any, bool] = {}
        if bounded_in is not None and len(bounded_in) == len(jaxpr.invars):
            for var, b in zip(jaxpr.invars, bounded_in, strict=False):
                bounded[var] = b

        def is_bounded(v) -> bool:
            if isinstance(v, jcore.Literal):
                return True
            return bounded.get(v, False)

        producers: Dict[Any, Any] = {}
        consumers: Dict[Any, list] = {}
        for eqn in jaxpr.eqns:
            for ov in eqn.outvars:
                producers[ov] = eqn
            for iv in eqn.invars:
                if not isinstance(iv, jcore.Literal):
                    consumers.setdefault(iv, []).append(eqn)

        for eqn in jaxpr.eqns:
            prim = eqn.primitive.name
            site = eqn_site(eqn, inherited_site)
            in_dts = [_dtype_of(v) for v in eqn.invars]
            out_dts = [_dtype_of(v) for v in eqn.outvars]

            # record dtypes seen per contract scope for fp32-resident
            if site is not None and site.endswith("/contract"):
                seen = self.site_dtypes.setdefault(site, set())
                seen.update(dt for dt in in_dts + out_dts if dt is not None)

            # 1. half accumulation on contractions
            if prim in ("dot_general", "conv_general_dilated"):
                pref = eqn.params.get("preferred_element_type")
                acc = jnp.dtype(pref) if pref is not None else out_dts[0]
                if acc in _HALF:
                    self._emit(
                        "half-accum-contract"
                        if self._contract_severity(site) == ERROR
                        else "half-accum",
                        self._contract_severity(site), site,
                        f"{prim} accumulates at {acc.name} "
                        f"(inputs {[d.name for d in in_dts if d]}); set "
                        f"preferred_element_type=float32",
                    )
            if prim in ("reduce_sum", "reduce_prod", "cumsum"):
                if in_dts and in_dts[0] in _HALF and out_dts[0] in _HALF:
                    self._emit(
                        "half-accum-reduce", self._contract_severity(site),
                        site,
                        f"{prim} carried out at {out_dts[0].name}",
                    )

            # 2. fp16 overflow-prone primitives on unbounded values
            risky = prim in _OVERFLOW_PRIMS or (
                prim == "integer_pow" and eqn.params.get("y", 1) >= 2
            )
            if risky and out_dts[0] == _F16:
                if any(dt == _F16 and not is_bounded(v)
                       for v, dt in zip(eqn.invars, in_dts, strict=True)):
                    self._emit(
                        "fp16-overflow-risk", WARNING, site,
                        f"{prim} on an unstabilized float16 value "
                        f"(no bounded op between source and use)",
                    )

            # 3. round-trip casts: f32 -> half -> f32, no compute between
            if (prim == "convert_element_type"
                    and in_dts and in_dts[0] is not None
                    and jnp.dtype(in_dts[0]) == jnp.dtype(jnp.float32)
                    and out_dts[0] in _HALF):
                outv = eqn.outvars[0]
                cons = consumers.get(outv, [])
                if cons and all(
                    c.primitive.name == "convert_element_type"
                    and _dtype_of(c.outvars[0]) == jnp.dtype(jnp.float32)
                    for c in cons
                ):
                    self._emit(
                        "round-trip-cast", WARNING, site,
                        f"float32 -> {out_dts[0].name} -> float32 with no "
                        f"compute between (wasted HBM round trip)",
                    )

            # -- propagate boundedness and recurse ---------------------------
            if prim in _BOUNDED_PRIMS:
                out_b = True
            elif prim in _TRANSPARENT_PRIMS:
                ins = [v for v in eqn.invars]
                out_b = bool(ins) and all(is_bounded(v) for v in ins)
            elif prim in _COMBINING_PRIMS:
                out_b = all(is_bounded(v) for v in eqn.invars)
            else:
                out_b = False
            for ov in eqn.outvars:
                bounded[ov] = out_b

            sub_bounded = [is_bounded(v) for v in eqn.invars]
            for sub in _sub_jaxprs(eqn):
                self.walk(
                    sub, site,
                    sub_bounded if len(sub.invars) == len(sub_bounded)
                    else None,
                )

    # -- post-walk checks ----------------------------------------------------
    def finish(self) -> List[Finding]:
        for site, dtypes in sorted(self.site_dtypes.items()):
            sp = self.policy.at(site)
            demoted = sp.spectral_dtype is not None
            if not demoted:
                continue
            half = jnp.dtype(sp.spectral_dtype)
            if half not in dtypes:
                self._emit(
                    "fp32-resident", ERROR, site,
                    f"policy {self.policy.name!r} demotes this site to "
                    f"{half.name} but no eqn under its scope touches that "
                    f"dtype — the declared precision does not hold",
                )
        return self.findings


def analyze_closed_jaxpr(closed: jcore.ClosedJaxpr, policy,
                         where: str) -> List[Finding]:
    w = _Walk(policy, where)
    w.walk(closed.jaxpr, None)
    return w.finish()


def trace_findings(fn, abstract_args: Sequence, policy,
                   where: str) -> List[Finding]:
    """``make_jaxpr`` the callable on abstract inputs and lint the trace."""
    closed = jax.make_jaxpr(fn)(*abstract_args)
    return analyze_closed_jaxpr(closed, policy, where)


# ---------------------------------------------------------------------------
# Model / trainer tracing
# ---------------------------------------------------------------------------


def tiny_model(model: str):
    """(config, params, abstract input) for a representative-but-cheap
    instance of each operator family.  TFNO = the CP-factorised FNO."""
    if model in ("fno", "tfno"):
        from repro.models import FNOConfig, init_fno

        cfg = FNOConfig(
            in_channels=1, out_channels=1, hidden_channels=8,
            lifting_channels=8, projection_channels=8, n_layers=2,
            modes=(4, 4),
            factorization="cp" if model == "tfno" else "dense",
        )
        params = init_fno(jax.random.PRNGKey(0), cfg)
        x = jax.ShapeDtypeStruct((2, 1, 16, 16), jnp.float32)
        return cfg, params, x
    if model == "sfno":
        from repro.models import SFNOConfig, init_sfno

        cfg = SFNOConfig(
            in_channels=1, out_channels=1, hidden_channels=8,
            lifting_channels=8, projection_channels=8, n_layers=2,
            nlat=8, nlon=16, lmax=4, mmax=4,
        )
        params = init_sfno(jax.random.PRNGKey(0), cfg)
        x = jax.ShapeDtypeStruct((2, 1, 8, 16), jnp.float32)
        return cfg, params, x
    raise ValueError(f"unknown model {model!r}; have fno | tfno | sfno")


def model_findings(model: str, policy, use_pallas: bool) -> List[Finding]:
    """Lint one model forward under one policy/kernel-path combination."""
    cfg, params, x = tiny_model(model)
    if model == "sfno":
        from repro.models import sfno_apply as apply_fn
    else:
        from repro.models import fno_apply as apply_fn
    import dataclasses as _dc

    cfg = _dc.replace(cfg, use_pallas=use_pallas)
    where = f"{model}/{policy.name}" + ("+pallas" if use_pallas else "")
    return trace_findings(
        lambda p, xx: apply_fn(p, xx, cfg, policy), (params, x),
        policy, where,
    )


def trainer_findings(policy, use_pallas: bool = False) -> List[Finding]:
    """Lint a full Trainer step (fwd + bwd + optimizer + loss scaling)."""
    from repro.models import FNOConfig, fno_apply, init_fno
    from repro.train import Trainer, TrainerConfig, relative_l2

    cfg = FNOConfig(
        in_channels=1, out_channels=1, hidden_channels=8,
        lifting_channels=8, projection_channels=8, n_layers=1,
        modes=(4, 4), use_pallas=use_pallas,
    )
    params = init_fno(jax.random.PRNGKey(0), cfg)

    def loss_fn(p, batch, pol):
        return relative_l2(fno_apply(p, batch["x"], cfg, pol), batch["t"])

    tr = Trainer(loss_fn, params, TrainerConfig(total_steps=1))
    step = tr._build_step(policy)
    batch = {
        "x": jax.ShapeDtypeStruct((2, 1, 16, 16), jnp.float32),
        "t": jax.ShapeDtypeStruct((2, 1, 16, 16), jnp.float32),
    }
    where = f"trainer/{policy.name}" + ("+pallas" if use_pallas else "")
    return trace_findings(
        step, (tr.params, tr.opt_state, tr.scale_state, batch),
        policy, where,
    )


# ---------------------------------------------------------------------------
# Golden dtype traces (snapshot-test helper)
# ---------------------------------------------------------------------------

_TRACE_PRIMS = ("convert_element_type", "dot_general", "fft", "pallas_call",
                "integer_pow", "tanh")


def _trace_entries(jaxpr: jcore.Jaxpr, inherited: Optional[str],
                   out: List[str]) -> None:
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        site = eqn_site(eqn, inherited)
        if prim in _TRACE_PRIMS:
            ins = ",".join(
                d.name for d in (_dtype_of(v) for v in eqn.invars)
                if d is not None
            )
            outs = ",".join(
                d.name for d in (_dtype_of(v) for v in eqn.outvars)
                if d is not None
            )
            entry = f"{prim}:{ins}->{outs}"
            if prim == "dot_general":
                pref = eqn.params.get("preferred_element_type")
                entry += f"@acc={jnp.dtype(pref).name if pref else outs}"
            if site:
                entry += f"@{site}"
            out.append(entry)
        for sub in _sub_jaxprs(eqn):
            _trace_entries(sub, site, out)


def dtype_trace(policy, use_pallas: bool = False,
                factorization: str = "dense",
                fuse_spectral: Optional[bool] = False) -> List[str]:
    """The exact cast/contract/FFT dtype sequence of one FNO spectral
    layer under ``policy`` — the golden-snapshot surface: a policy or
    model refactor that silently changes numerics changes this list.

    ``fuse_spectral`` defaults to *False* (not auto) so the staged
    traces stay pinned to the staged pipeline whatever the environment;
    pass ``True`` to snapshot the fused megakernel's dispatch."""
    from repro.core.spectral import init_spectral_weights, spectral_conv_apply

    params = init_spectral_weights(
        jax.random.PRNGKey(0), 4, 4, (4, 4), factorization)
    x = jax.ShapeDtypeStruct((2, 4, 16, 16), jnp.float32)
    closed = jax.make_jaxpr(
        lambda p, xx: spectral_conv_apply(
            p, xx, (4, 4), policy, use_pallas=use_pallas,
            site="model/spectral", fuse_spectral=fuse_spectral,
        )
    )(params, x)
    out: List[str] = []
    _trace_entries(closed.jaxpr, None, out)
    return out
