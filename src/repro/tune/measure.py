"""On-hardware measurement of tile candidates.

Each candidate is timed as the unit its config actually pins: one
``value_and_grad`` train step through the kernel family's custom VJP
(forward + both backward kernels on the candidate's fwd/bwd tiles),
median-of-k with warmup, ``block_until_ready`` around every sample.

On a TPU the kernels compile to Mosaic and the walls are real; on CPU
the same loop runs in interpret mode so CI can exercise the full tune →
validate → cache → resolve cycle (the cache marks such entries
``interpret: true`` — their GB/s figures rank candidates relative to
each other but are not hardware bandwidth).

Alongside wall time every measurement reports achieved GB/s (a
per-family bytes-moved model over the measured wall) and the fraction of
the roofline HBM bandwidth that represents — the CORTEX-style
per-kernel bandwidth report.
"""
from __future__ import annotations

import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.spectral_contract import (
    _fused_rows,
    spectral_contract_cp_pallas,
    spectral_contract_lshared_pallas,
    spectral_contract_pallas,
    spectral_fused_pallas,
)
from repro.launch.roofline import HBM_BW
from .space import Candidate, family_itemsize, fused_axes


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def bytes_moved(family: str, shape, dtype: str) -> int:
    """HBM traffic model for one train step (fwd + both backward
    kernels): every operand read and every output written once, re+im
    planes, at the family's streaming itemsize.  A model, not a
    measurement — good enough to rank candidates and to normalise walls
    into achieved GB/s."""
    itemsize = family_itemsize(family, dtype)
    if family in ("spectral_fused", "spectral_staged"):
        # real-valued physical tensors + split-real gathered weight; no
        # re+im doubling of x/y.  ``spectral_staged`` is the same
        # boundary traffic *plus* the HBM round-trips of the 3-stage
        # pipeline's intermediate spectra (the rFFT output written and
        # re-read, the scattered contraction output written and re-read
        # by the irFFT) — the model the fused bench leg compares
        # against, at the staged f32 spectrum width.
        B, I, O, spatial, modes = fused_axes(shape)
        S = int(np.prod(spatial))
        Mh = int(np.prod(_fused_rows(spatial, modes)))
        x_el, w_el, y_el = B * I * S, 2 * I * O * Mh, B * O * S
        fwd = x_el + w_el + y_el
        bwd = y_el + x_el + w_el + x_el + w_el  # g in, x/w re-read, dx/dw out
        elems = fwd + bwd
        if family == "spectral_staged":
            Sh = int(np.prod(spatial[:-1])) * (spatial[-1] // 2 + 1)
            spec_in = 2 * B * I * Sh   # rFFT out: written + re-read
            spec_out = 2 * B * O * Sh  # scattered contract out: idem
            elems += 2 * (spec_in + spec_out)   # fwd
            elems += 4 * (spec_in + spec_out)   # bwd re-traverses both
        return int(elems) * itemsize
    if family in ("dense", "dense-fused"):
        B, I, O, M = shape
        fwd = (B * I + I * O + B * O) * M
        bwd = 2 * (B * I + I * O + B * O) * M
        elems = fwd + bwd
    elif family == "cp":
        B, I, O, R, M = shape
        factors = (I + O) * R + R * M
        fwd = (B * I + B * O) * M + factors
        bwd = (2 * B * I + 2 * B * O) * M + 2 * factors
        elems = fwd + bwd
    elif family == "lshared":
        B, I, O, L, Mm = shape
        fwd = (B * I + B * O) * L * Mm + I * O * L
        bwd = (2 * B * I + 2 * B * O) * L * Mm + 2 * I * O * L
        elems = fwd + bwd
    else:
        raise ValueError(f"unknown kernel family {family!r}")
    return int(elems) * 2 * itemsize


def make_operands(family: str, shape, dtype: str, seed: int = 0):
    """Seeded split-real operands for one family — the same arrays the
    oracle check rebuilds, so a validated entry was validated on the
    data it was timed on."""
    rng = np.random.RandomState(seed)
    op_dtype = (jnp.float32 if family in ("dense-fused", "spectral_fused")
                else jnp.dtype(dtype))

    def arr(*s):
        return jnp.asarray(0.5 * rng.randn(*s), jnp.float32).astype(op_dtype)

    if family == "spectral_fused":
        B, I, O, spatial, modes = fused_axes(shape)
        Mh = int(np.prod(_fused_rows(spatial, modes)))
        return (arr(B, I, *spatial), arr(I, O, Mh), arr(I, O, Mh))
    if family in ("dense", "dense-fused"):
        B, I, O, M = shape
        return (arr(B, I, M), arr(B, I, M), arr(I, O, M), arr(I, O, M))
    if family == "cp":
        B, I, O, R, M = shape
        return (arr(B, I, M), arr(B, I, M), arr(I, R), arr(I, R),
                arr(O, R), arr(O, R), arr(R, M), arr(R, M))
    if family == "lshared":
        B, I, O, L, Mm = shape
        return (arr(B, I, L, Mm), arr(B, I, L, Mm),
                arr(I, O, L), arr(I, O, L))
    raise ValueError(f"unknown kernel family {family!r}")


def build_step(cand: Candidate, *, interpret: Optional[bool] = None):
    """The jitted value_and_grad train step a candidate is timed on."""
    interpret = default_interpret() if interpret is None else interpret
    family = cand.family
    if family == "spectral_fused":
        _B, _I, _O, _spatial, modes = fused_axes(cand.shape)
        kern = functools.partial(
            spectral_fused_pallas, modes=modes,
            block_b=cand.block_fwd, block_b_bwd=cand.block_bwd,
            interpret=interpret,
            cast_to=(None if cand.dtype == "float32"
                     else jnp.dtype(cand.dtype)),
        )

        def loss(*ops):
            return jnp.sum(kern(*ops).astype(jnp.float32) ** 2)

        n = len(make_operands(family, cand.shape, cand.dtype))
        return jax.jit(jax.value_and_grad(loss, argnums=tuple(range(n))))
    if family in ("dense", "dense-fused"):
        kern = functools.partial(
            spectral_contract_pallas,
            block_m=cand.block_fwd, block_m_bwd=cand.block_bwd,
            interpret=interpret, out_dtype=jnp.dtype(cand.dtype),
            cast_to=jnp.dtype(cand.dtype) if family == "dense-fused"
            else None,
        )
    elif family == "cp":
        kern = functools.partial(
            spectral_contract_cp_pallas,
            block_m=cand.block_fwd, block_m_bwd=cand.block_bwd,
            interpret=interpret, out_dtype=jnp.dtype(cand.dtype),
        )
    elif family == "lshared":
        kern = functools.partial(
            spectral_contract_lshared_pallas,
            block_l=cand.block_fwd, block_l_bwd=cand.block_bwd,
            interpret=interpret, out_dtype=jnp.dtype(cand.dtype),
        )
    else:
        raise ValueError(f"unknown kernel family {family!r}")

    def loss(*ops):
        yr, yi = kern(*ops)
        return (jnp.sum(yr.astype(jnp.float32) ** 2)
                + jnp.sum(yi.astype(jnp.float32) ** 2))

    n = len(make_operands(family, cand.shape, cand.dtype))
    return jax.jit(jax.value_and_grad(loss, argnums=tuple(range(n))))


def _wall_us(fn, args, iters: int, warmup: int) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(samples))


def measure(cand: Candidate, *, interpret: Optional[bool] = None,
            iters: int = 3, warmup: int = 1, seed: int = 0) -> dict:
    """Time one candidate; returns the perf fields of a cache entry."""
    interpret = default_interpret() if interpret is None else interpret
    step = build_step(cand, interpret=interpret)
    ops = make_operands(cand.family, cand.shape, cand.dtype, seed=seed)
    wall = _wall_us(step, ops, iters, warmup)
    moved = bytes_moved(cand.family, cand.shape, cand.dtype)
    gbps = moved / (wall * 1e-6) / 1e9 if wall > 0 else 0.0
    return {
        "wall_us": wall,
        "bytes_moved": moved,
        "gbps": round(gbps, 3),
        "roofline_fraction": round(gbps / (HBM_BW / 1e9), 6),
        "interpret": bool(interpret),
    }
