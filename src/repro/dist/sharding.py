"""Rule-table spec derivation: pytrees of shapes -> pytrees of
PartitionSpecs, with divisibility-checked fallback chains.

Everything routes through :func:`pick_spec`: a candidate chain is tried
in order and the first candidate whose every sharded dimension divides
cleanly wins (axes absent from the mesh are adapted away, indivisible
axes fail the candidate).  The derivations (`lm_param_specs`,
`fno_param_specs`, `batch_specs`, `cache_specs`) encode the layout
policy once, so launch, dry-run, and serving all derive identical
shardings from the same tables.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .rules import Entry, normalize_entry, resolve_axes

Candidate = Tuple[Entry, ...]


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The physical data-parallel axes present in ``mesh``."""
    return resolve_axes("dp", mesh)


def _try_candidate(shape, mesh: Mesh, cand: Candidate) -> Optional[P]:
    """Resolve one candidate; None when a sharded dim doesn't divide."""
    if len(cand) > len(shape):
        return None
    used: set = set()
    entries = []
    for dim, entry in zip(shape, cand, strict=False):
        axes = resolve_axes(entry, mesh, used)
        prod = 1
        for ax in axes:
            prod *= mesh.shape[ax]
        if axes and dim % prod != 0:
            return None  # indivisible -> candidate fails, chain continues
        used.update(axes)
        entries.append(normalize_entry(axes))
    return P(*entries)


def pick_spec(shape, mesh: Mesh, chain: Sequence[Candidate]) -> P:
    """First candidate in ``chain`` that shards ``shape`` cleanly.

    Candidate entries are per-dimension: None, an axis name (logical or
    physical), or a tuple of names.  Names missing from the mesh are
    dropped silently; a name present but indivisible fails the whole
    candidate so the chain's fallback ordering is respected.  An empty
    candidate ``()`` always succeeds (full replication), as does an
    exhausted chain.
    """
    for cand in chain:
        spec = _try_candidate(tuple(shape), mesh, cand)
        if spec is not None:
            return spec
    return P()


def _path_names(path) -> Tuple[str, ...]:
    return tuple(
        str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k))))
        for k in path
    )


def _nbytes(leaf) -> int:
    return int(leaf.size) * jnp.dtype(leaf.dtype).itemsize


# ---------------------------------------------------------------------------
# Parameter rule tables
# ---------------------------------------------------------------------------


def _weight_chain(shape) -> Tuple[Candidate, ...]:
    """Fallback chain for a (possibly layer-stripped) weight shape."""
    r = len(shape)
    if r < 2:
        return ((),)
    if r == 2:
        # shard the larger dim over tp (column-parallel for (d, ff),
        # row-parallel for (ff, d)); fall back to the other, then replicate
        big = 0 if shape[0] > shape[1] else 1
        first = [None, None]
        first[big] = "tp"
        second = [None, None]
        second[1 - big] = "tp"
        return (tuple(first), tuple(second), ())
    if r == 3:
        # (E, d, ff) expert stacks: expert parallelism over tp when E
        # divides (deepseek 64/16), else shard the expert ff dim
        # (granite-moe's indivisible E=40), else the middle dim
        return (
            ("expert", None, None),
            (None, None, "tp"),
            (None, "tp", None),
            (),
        )
    # higher-rank (spectral-style) weights: try the channel dims
    tail = (None,) * (r - 3)
    return (
        (None, None, "tp") + tail,
        (None, "tp", None) + tail,
        (),
    )


def lm_param_specs(params_shape: Any, mesh: Mesh) -> Any:
    """PartitionSpec tree for an LM parameter tree.

    Layer-stacked leaves (under "layers") never shard the leading L
    axis — it is the ``lax.scan`` carrier.  2D weights shard their
    larger dim over tp with divisibility fallback; vectors/norms
    replicate; expert stacks prefer expert parallelism.
    """

    def spec(path, leaf):
        shape = tuple(leaf.shape)
        stacked = "layers" in _path_names(path)
        inner_shape = shape[1:] if stacked else shape
        inner = pick_spec(inner_shape, mesh, _weight_chain(inner_shape))
        if stacked:
            return P(None, *inner) if len(inner) else P()
        return inner

    return jax.tree_util.tree_map_with_path(spec, params_shape)


def fno_param_specs(params_shape: Any, mesh: Mesh,
                    *, shard_threshold: int = 1 << 24) -> Any:
    """PartitionSpec tree for FNO/SFNO parameter trees.

    Default layout is full-DP: the weights are tiny relative to the
    activations, so everything replicates and the batch shards over the
    whole mesh (see ``constrain_spatial``).  Spectral leaves above
    ``shard_threshold`` elements (high-resolution dense factorizations)
    shard a channel dim over tp so the hr cells still fit.
    """

    def spec(path, leaf):
        shape = tuple(leaf.shape)
        names = _path_names(path)
        if int(leaf.size) < shard_threshold or len(shape) < 3:
            return P()
        # stacked leaves carry (L, ...) — never shard the scan axis
        stacked = bool(names) and names[0] in ("spectral", "skips")
        inner_shape = shape[1:] if stacked else shape
        inner = pick_spec(inner_shape, mesh, _weight_chain(inner_shape))
        if stacked:
            return P(None, *inner) if len(inner) else P()
        return inner

    return jax.tree_util.tree_map_with_path(spec, params_shape)


# ---------------------------------------------------------------------------
# Batch / cache rule tables
# ---------------------------------------------------------------------------


def batch_specs(batch: Any, mesh: Mesh) -> Any:
    """Leading-dim data parallelism for input batches, replicate fallback."""
    dp = dp_axes(mesh)

    def spec(leaf):
        r = len(leaf.shape)
        return pick_spec(leaf.shape, mesh, [(dp,) + (None,) * (r - 1), ()])

    return jax.tree_util.tree_map(spec, batch)


def cache_specs(cache: Any, mesh: Mesh, cfg: Any) -> Any:
    """Decode-cache layout: slots over dp, heads over tp when they divide.

    Layer-stacked leaves — leading dim equal to the config's layer
    count — keep the scan axis replicated and shard the slot dim that
    follows it; per-slot leaves (e.g. the ``step`` clocks) shard dim 0.
    """
    dp = dp_axes(mesh)
    layer_counts = {
        n for n in (getattr(cfg, "n_layers", None), getattr(cfg, "dec_layers", None))
        if n
    }
    head_keys = ("k", "v", "ssd_state")

    def spec(path, leaf):
        shape = tuple(leaf.shape)
        r = len(shape)
        names = _path_names(path)
        stacked = r >= 2 and shape[0] in layer_counts
        if not stacked:
            return pick_spec(shape, mesh, [(dp,) + (None,) * (r - 1), ()])
        base = [None, dp] + [None] * (r - 2)
        chain = []
        if names and names[-1] in head_keys and r > 2:
            with_heads = list(base)
            with_heads[2] = "heads"
            chain.append(tuple(with_heads))
        chain += [tuple(base), ()]
        return pick_spec(shape, mesh, chain)

    return jax.tree_util.tree_map_with_path(spec, cache)


# ---------------------------------------------------------------------------
# Materialisation + accounting
# ---------------------------------------------------------------------------


def to_named(mesh: Mesh, spec_tree: Any) -> Any:
    """PartitionSpec tree -> NamedSharding tree on ``mesh``."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def replication_report(shape_tree: Any, spec_tree: Any) -> Dict[str, Any]:
    """Byte accounting of a (shapes, specs) pair: how much parameter
    memory is sharded vs fully replicated per device."""
    stats = {"total_bytes": 0, "sharded_bytes": 0, "replicated_bytes": 0,
             "n_leaves": 0, "n_sharded": 0}

    def acc(leaf, spec):
        nbytes = _nbytes(leaf)
        sharded = any(e is not None for e in tuple(spec))
        stats["total_bytes"] += nbytes
        stats["n_leaves"] += 1
        if sharded:
            stats["sharded_bytes"] += nbytes
            stats["n_sharded"] += 1
        else:
            stats["replicated_bytes"] += nbytes
        return spec

    jax.tree_util.tree_map(acc, shape_tree, spec_tree,
                           is_leaf=lambda x: isinstance(x, P))
    total = stats["total_bytes"]
    stats["replicated_fraction"] = (
        stats["replicated_bytes"] / total if total else 0.0
    )
    return stats
