"""The admission gate: every candidate vs its einsum oracle, under the
paper's own tolerance.

Thm 3.2 bounds the precision error of a half-stored contraction by
``4·ε·M`` per requantising stage, where ``ε`` is the storage grid
spacing and ``M`` the contraction of operand magnitudes.  The
differential test suite (tests/test_kernels_diff.py) asserts the Pallas
kernels against the einsum reference under exactly

    budget = stages · 4εM + 32·ε_f32·M + atol      (elementwise)

and this module applies the same machinery at tuning time: a candidate
tile whose kernel output strays outside that envelope is *refused* — a
mistuned-but-wrong kernel is unrepresentable in the calibration cache.

``perturb`` injects a scaled multiple of the budget into the kernel
output before the comparison.  It exists so the gate itself is testable:
``python -m repro.tune validate --perturb 2`` must reject every entry
(the seeded-violation self-check CI can run), proving the oracle is
live, not vacuously green.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.precision import FORMAT_EPS
from repro.core.theory import prec_upper_bound
from .measure import default_interpret, make_operands
from .space import Candidate

F32_EPS = float(np.finfo(np.float32).eps)
ATOL = 1e-5

#: requantising stages per family — one 4εM term each, mirroring the
#: stage counts the differential tests budget for the same kernels
STAGES = {"dense": 2, "dense-fused": 2, "cp": 6, "lshared": 2}


def storage_eps(dtype: str) -> float:
    """Grid spacing ε of the storage dtype ("bfloat16", "float16", ...)."""
    return FORMAT_EPS[dtype]


def _c(re, im):
    return np.asarray(re, np.float64) + 1j * np.asarray(im, np.float64)


def _rounded(arr, dtype):
    """Round an f32 operand onto the storage grid the kernel will use
    (identity when it already lives there)."""
    import jax.numpy as jnp

    return np.asarray(jnp.asarray(arr).astype(jnp.dtype(dtype))
                      .astype(jnp.float32))


def reference(cand: Candidate, ops) -> tuple:
    """(exact complex reference, magnitude contraction M) for the
    candidate's operands — computed at complex128 from the same storage-
    rounded values the kernel consumes, so the elementwise budget
    charges only the kernel's own stages."""
    family, dtype = cand.family, cand.dtype
    if family in ("dense", "dense-fused"):
        xr, xi, wr, wi = ops
        if family == "dense-fused":
            # the kernel rounds f32 tiles onto the half grid in-kernel;
            # the oracle must agree on the operands being contracted
            xr, xi, wr, wi = (_rounded(a, dtype) for a in (xr, xi, wr, wi))
        x, w = _c(xr, xi), _c(wr, wi)
        ref = np.einsum("bim,iom->bom", x, w)
        mag = np.einsum("bim,iom->bom", np.abs(x), np.abs(w))
    elif family == "cp":
        xr, xi, uir, uii, uor, uoi, wr, wi = ops
        x, ui, uo, w = _c(xr, xi), _c(uir, uii), _c(uor, uoi), _c(wr, wi)
        t = np.einsum("bim,ir->bmr", x, ui)
        u = t * np.transpose(w)[None]
        ref = np.einsum("bmr,or->bom", u, uo)
        tm = np.einsum("bim,ir->bmr", np.abs(x), np.abs(ui))
        mag = np.einsum("bmr,or->bom",
                        tm * np.abs(np.transpose(w))[None], np.abs(uo))
    elif family == "lshared":
        xr, xi, wr, wi = ops
        x, w = _c(xr, xi), _c(wr, wi)
        ref = np.einsum("bilm,iol->bolm", x, w)
        mag = np.einsum("bilm,iol->bolm", np.abs(x), np.abs(w))
    else:
        raise ValueError(f"unknown kernel family {family!r}")
    return ref, mag


def check(cand: Candidate, *, interpret: Optional[bool] = None,
          seed: int = 0, perturb: float = 0.0) -> dict:
    """Run the candidate's forward kernel and gate it against the einsum
    oracle.  Returns {passed, max_err, budget_min, worst_excess}."""
    import jax.numpy as jnp

    from repro.kernels.spectral_contract import (
        spectral_contract_cp_pallas as cp_kern,
        spectral_contract_lshared_pallas as l_kern,
        spectral_contract_pallas as d_kern,
    )

    interpret = default_interpret() if interpret is None else interpret
    ops = make_operands(cand.family, cand.shape, cand.dtype, seed=seed)
    out_dtype = jnp.dtype(cand.dtype)
    if cand.family in ("dense", "dense-fused"):
        yr, yi = d_kern(
            *ops, block_m=cand.block_fwd, block_m_bwd=cand.block_bwd,
            interpret=interpret, out_dtype=out_dtype,
            cast_to=out_dtype if cand.family == "dense-fused" else None)
    elif cand.family == "cp":
        yr, yi = cp_kern(
            *ops, block_m=cand.block_fwd, block_m_bwd=cand.block_bwd,
            interpret=interpret, out_dtype=out_dtype)
    else:
        yr, yi = l_kern(
            *ops, block_l=cand.block_fwd, block_l_bwd=cand.block_bwd,
            interpret=interpret, out_dtype=out_dtype)
    got = _c(np.asarray(yr.astype(jnp.float32)),
             np.asarray(yi.astype(jnp.float32)))

    ref, mag = reference(cand, ops)
    eps = storage_eps(cand.dtype)
    budget = (STAGES[cand.family] * prec_upper_bound(eps, mag)
              + 32 * F32_EPS * mag + ATOL)
    if perturb:
        # seeded violation: shift the kernel output by perturb×budget so
        # any |perturb| > 1 must trip the gate everywhere
        got = got + perturb * budget
    diff = np.abs(got - ref)
    excess = float((diff - budget).max())
    result = {
        "passed": bool(np.all(diff <= budget)),
        "max_err": float(diff.max()),
        "budget_min": float(budget.min()),
        "worst_excess": excess,
    }
    if not result["passed"]:
        from repro.obs import oracle_reject
        from .cache import entry_key

        oracle_reject(
            f"{entry_key(cand.family, cand.shape, cand.dtype)}"
            f"|b{cand.block_fwd}x{cand.block_bwd}",
            max_err=result["max_err"], budget_min=result["budget_min"],
            worst_excess=excess)
    return result
