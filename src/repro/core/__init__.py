"""Core contribution: mixed-precision spectral compute with guarantees.

Public API:
  PrecisionPolicy / get_policy / POLICIES  — site-addressed rule sets
                                             (re-exported from
                                             ``repro.precision``)
  ComplexPair                              — split-real half complex
  contract / greedy_path / PathCache       — memory-greedy contraction
  spectral_conv_apply / init_spectral_weights — mixed-precision FNO block
  PrecisionSchedule                        — stack of precision-rule
                                             overlays over training
  theory                                   — Thm 3.1/3.2 estimators+bounds
"""
from .precision import (  # noqa: F401
    ComplexPair,
    PrecisionSystem,
    FORMAT_EPS,
    FORMAT_MAX,
    precision_system_for,
    quantize_complex,
    simulate_fp8,
)
from repro.precision import (  # noqa: F401
    AMP_BF16,
    AMP_FP16,
    FULL,
    HALF_FNO_ONLY,
    MIXED_FNO_BF16,
    MIXED_FNO_FP16,
    POLICIES,
    SIM_FP8_E4M3,
    SIM_FP8_E5M2,
    PrecisionPolicy,
    SitePrecision,
    SiteRule,
    get_policy,
    precision_rules,
)
from .contraction import (  # noqa: F401
    PathCache,
    contract,
    global_path_cache,
    greedy_path,
    path_flops,
    path_intermediate_bytes,
)
from .stabilizer import get_stabilizer, STABILIZERS  # noqa: F401
from .spectral import (  # noqa: F401
    init_spectral_weights,
    spectral_conv_apply,
    spectral_param_count,
)
from .schedule import PrecisionSchedule  # noqa: F401
from . import theory  # noqa: F401
