"""repro.tune: the autotuner's cache, oracle gate, and its reach into
kernel tile resolution.

The load-bearing claims:

  * robustness — corrupted / stale / version-mismatched calibration
    files degrade to a warning plus the static heuristic, never a
    crash, and stale entries are invisible at lookup;
  * the oracle gate is live — ``validate --perturb 2`` (a seeded
    Thm 3.2 budget violation) must reject every entry;
  * resolution really consults the cache — a seeded entry provably
    changes the executed Pallas grid on BOTH the forward and backward
    kernels vs the heuristic tiling, and the source counters say so.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analyze.kernels import calibration_pass, record_pallas_calls
from repro.core import get_policy
from repro.kernels import ops
from repro.kernels.spectral_contract import (
    KERNEL_VERSION,
    VMEM_BUDGET,
    pick_block_m,
)
from repro.tune import cache as cache_mod
from repro.tune import oracle, space
from repro.tune.__main__ import main as tune_main

jax.config.update("jax_platform_name", "cpu")

BACKEND = jax.default_backend()


@pytest.fixture(autouse=True)
def _pristine_calibration(monkeypatch):
    """No test leaks activation state or the env var into another."""
    monkeypatch.delenv(cache_mod.ENV_VAR, raising=False)
    cache_mod.activate(None)
    yield
    cache_mod.activate(None)


# shared with test_kernels*.py via tests/helpers.py
from helpers import (  # noqa: E402
    calibration_entry as _entry,
    calibration_state as _state_with,
)


# ---------------------------------------------------------------------------
# search space
# ---------------------------------------------------------------------------

class TestSearchSpace:
    @pytest.mark.parametrize("family,shape", [
        ("dense", (2, 8, 8, 40)),
        ("dense-fused", (2, 8, 8, 40)),
        ("cp", (2, 8, 8, 4, 40)),
        ("lshared", (2, 8, 8, 12, 9)),
        ("spectral_fused", (2, 8, 8, 12, 9, 3, 3)),
    ])
    def test_candidates_legal(self, family, shape):
        cands = space.candidates(family, shape, "bfloat16")
        assert cands, f"no candidates for {family} {shape}"
        itemsize = space.family_itemsize(family, "bfloat16")
        for c in cands:
            for block, direction in ((c.block_fwd, "fwd"),
                                     (c.block_bwd, "bwd")):
                assert block & (block - 1) == 0
                assert space.tile_vmem_bytes(
                    family, shape, block, itemsize, direction
                ) <= space.DEFAULT_BUDGET

    def test_fused_prices_at_f32(self):
        """The fused family streams f32 operand tiles, so its legal
        blocks can only shrink relative to plain dense."""
        shape = (4, 32, 32, 512)
        dense = space.legal_blocks("dense", shape, "bfloat16", "fwd")
        fused = space.legal_blocks("dense-fused", shape, "bfloat16", "fwd")
        assert max(fused) <= max(dense)

    def test_limit_caps_cross_product(self):
        cands = space.candidates("dense", (2, 8, 8, 40), "bfloat16", limit=3)
        assert len(cands) == 3


# ---------------------------------------------------------------------------
# cache: robustness of every failure mode
# ---------------------------------------------------------------------------

class TestCacheRobustness:
    def test_corrupt_json_warns_and_falls_back(self, tmp_path):
        bad = tmp_path / "corrupt.json"
        bad.write_text("{ this is not json")
        with pytest.raises(cache_mod.CalibrationError):
            cache_mod.load(bad)
        with pytest.warns(UserWarning, match="calibration"):
            assert cache_mod.safe_load(bad) is None
        with pytest.warns(UserWarning):
            cache_mod.activate(str(bad))
        assert cache_mod.active_cache() is None

    def test_missing_file_and_bad_structure(self, tmp_path):
        with pytest.raises(cache_mod.CalibrationError, match="not found"):
            cache_mod.load(tmp_path / "absent.json")
        noent = tmp_path / "noentries.json"
        noent.write_text(json.dumps({"format_version": 1}))
        with pytest.raises(cache_mod.CalibrationError, match="entries"):
            cache_mod.load(noent)

    def test_format_version_mismatch_rejected(self, tmp_path):
        p = _state_with(tmp_path, _entry("dense", (2, 8, 8, 40)))
        raw = json.loads(open(p).read())
        raw["format_version"] = 999
        open(p, "w").write(json.dumps(raw))
        with pytest.raises(cache_mod.CalibrationError, match="format_version"):
            cache_mod.load(p)

    def test_kernel_version_bump_invalidates_entry(self, tmp_path):
        p = _state_with(tmp_path, _entry(
            "dense", (2, 8, 8, 40), kernel_version=KERNEL_VERSION - 1))
        state = cache_mod.load(p)
        assert state.lookup("dense", (2, 8, 8, 40), "bfloat16") is None
        assert state.counters["stale"] == 1

    def test_backend_mismatch_invalidates_entry(self, tmp_path):
        p = _state_with(tmp_path, _entry(
            "dense", (2, 8, 8, 40), backend="not-a-backend"))
        state = cache_mod.load(p)
        assert state.lookup("dense", (2, 8, 8, 40), "bfloat16") is None
        assert state.counters["stale"] == 1

    @pytest.mark.parametrize("defect", [
        {"validated": False},
        {"block_fwd": 7},          # not a power of two
        {"block_bwd": "8"},        # wrong type
    ])
    def test_defective_entries_are_invisible(self, tmp_path, defect):
        p = _state_with(tmp_path, _entry("dense", (2, 8, 8, 40), **defect))
        state = cache_mod.load(p)
        assert state.lookup("dense", (2, 8, 8, 40), "bfloat16") is None

    def test_hit_and_miss_counters(self, tmp_path):
        p = _state_with(tmp_path, _entry("dense", (2, 8, 8, 40)))
        state = cache_mod.load(p)
        assert state.lookup("dense", (2, 8, 8, 40), "bfloat16") is not None
        assert state.lookup("dense", (9, 9, 9, 9), "bfloat16") is None
        assert state.counters == {"hits": 1, "misses": 1, "stale": 0}

    def test_atomic_save_roundtrip(self, tmp_path):
        p = _state_with(tmp_path, _entry("cp", (2, 8, 8, 4, 40)))
        state = cache_mod.load(p)
        assert state.path == str(p)
        assert state.lookup("cp", (2, 8, 8, 4, 40), "bfloat16") is not None
        # no tempfile droppings from the atomic write
        assert [f.name for f in tmp_path.iterdir()] == ["state.json"]

    def test_env_var_resolution_tracks_mtime(self, tmp_path, monkeypatch):
        p = _state_with(tmp_path, _entry("dense", (2, 8, 8, 40), block_fwd=8))
        monkeypatch.setenv(cache_mod.ENV_VAR, str(p))
        c1 = cache_mod.active_cache()
        assert c1.lookup("dense", (2, 8, 8, 40), "bfloat16")["block_fwd"] == 8
        _state_with(tmp_path, _entry("dense", (2, 8, 8, 40), block_fwd=16))
        os.utime(p, ns=(0, 0))  # force a visible mtime change
        c2 = cache_mod.active_cache()
        assert c2.lookup("dense", (2, 8, 8, 40), "bfloat16")["block_fwd"] == 16

    def test_explicit_activation_beats_env(self, tmp_path, monkeypatch):
        p_env = _state_with(tmp_path, _entry("dense", (2, 8, 8, 40)),
                            name="env.json")
        monkeypatch.setenv(cache_mod.ENV_VAR, str(p_env))
        explicit = cache_mod.CalibrationCache(entries={}, backend=BACKEND)
        cache_mod.activate(explicit)
        assert cache_mod.active_cache() is explicit
        cache_mod.activate(None)
        assert cache_mod.active_cache().path == str(p_env)

    def test_bad_file_never_crashes_resolution(self, tmp_path, monkeypatch):
        """The acceptance bar: a corrupt state behind the env var costs a
        warning, and the kernel wrapper still runs on the heuristic."""
        bad = tmp_path / "bad.json"
        bad.write_text("]]garbage")
        monkeypatch.setenv(cache_mod.ENV_VAR, str(bad))
        site = get_policy("mixed_fno_bf16").at("fno/layer0/spectral/contract")
        rng = np.random.RandomState(0)
        x = jnp.asarray(rng.randn(2, 3, 4, 5) + 1j * rng.randn(2, 3, 4, 5),
                        jnp.complex64)
        w = jnp.asarray(rng.randn(3, 4, 4, 5) + 1j * rng.randn(3, 4, 4, 5),
                        jnp.complex64)
        with pytest.warns(UserWarning, match="calibration"):
            y = ops.spectral_contract(x, w, policy=site)
        assert y.shape == (2, 4, 4, 5)
        stats = ops.tile_resolution_stats()
        assert stats["calibration_state"] is None
        assert stats["sources"]["heuristic"] >= 1


# ---------------------------------------------------------------------------
# the seeded entry flips the executed tiling (acceptance criterion)
# ---------------------------------------------------------------------------

def _grids_of_step(x, w, site, fuse_casts):
    """Executed Pallas grids for one value_and_grad through the dense
    wrapper: [fwd, fwd(recompute), bwd_dx, bwd_dw]."""
    def loss(x, w):
        y = ops.spectral_contract(x, w, policy=site, fuse_casts=fuse_casts)
        return jnp.sum(jnp.abs(y) ** 2)

    with record_pallas_calls() as calls:
        jax.block_until_ready(jax.value_and_grad(loss, argnums=(0, 1))(x, w))
    return [c.grid for c in calls]


@pytest.mark.parametrize("fuse_casts", [False, True],
                         ids=["dense", "dense-fused"])
def test_seeded_entry_flips_executed_tiling(tmp_path, fuse_casts):
    B, I, O, M = 2, 8, 8, 40
    site = get_policy("mixed_fno_bf16").at("fno/layer0/spectral/contract")
    rng = np.random.RandomState(7)
    x = jnp.asarray(0.5 * (rng.randn(B, I, M) + 1j * rng.randn(B, I, M)),
                    jnp.complex64)
    w = jnp.asarray(0.5 * (rng.randn(I, O, M) + 1j * rng.randn(I, O, M)),
                    jnp.complex64)

    family = "dense-fused" if fuse_casts else "dense"
    itemsize = 4 if fuse_casts else 2
    heur = pick_block_m(B, I, O, M, itemsize=itemsize)
    seeded_fwd, seeded_bwd = 8, 16
    assert heur not in (seeded_fwd, seeded_bwd), "seed must differ"

    grids_heur = _grids_of_step(x, w, site, fuse_casts)

    p = _state_with(tmp_path, _entry(family, (B, I, O, M),
                                     block_fwd=seeded_fwd,
                                     block_bwd=seeded_bwd))
    cache_mod.activate(str(p))
    before = dict(ops._TILE_SOURCES)
    grids_cal = _grids_of_step(x, w, site, fuse_casts)

    # fwd kernels run on the seeded fwd tile, bwd kernels on the seeded
    # bwd tile — and every grid differs from the heuristic run's
    steps = lambda blk: (-(-M // blk),)  # noqa: E731
    assert grids_cal[0] == steps(seeded_fwd)
    assert grids_cal[-1] == steps(seeded_bwd)
    assert grids_cal != grids_heur
    assert grids_heur[0] == steps(heur)

    stats = ops.tile_resolution_stats()
    assert stats["calibration_state"] == str(p)
    assert stats["sources"]["calibrated"] > before["calibrated"]
    assert stats["cache"]["hits"] >= 1


def test_trainer_and_engine_activate_state(tmp_path):
    from repro.train import Trainer, TrainerConfig

    p = _state_with(tmp_path, _entry("dense", (2, 8, 8, 40)))
    cfg = TrainerConfig(total_steps=1, calibration_state=str(p))
    Trainer(lambda prm, b, pol: jnp.sum(prm["w"] ** 2),
            {"w": jnp.ones((2,))}, cfg)
    assert cache_mod.active_cache().path == str(p)

    cache_mod.activate(None)
    from repro.models import FNOConfig, init_fno
    from repro.serve.operator import OperatorEngine

    mcfg = FNOConfig(in_channels=1, out_channels=1, hidden_channels=4,
                     lifting_channels=4, projection_channels=4,
                     n_layers=1, modes=(3, 3))
    params = init_fno(jax.random.PRNGKey(0), mcfg)
    eng = OperatorEngine(params, mcfg, calibration_state=str(p))
    stats = eng.stats()
    assert stats["tiles"]["calibration_state"] == str(p)
    assert set(stats["tiles"]["sources"]) == {"heuristic", "calibrated"}


# ---------------------------------------------------------------------------
# oracle gate + CLI
# ---------------------------------------------------------------------------

class TestOracleGate:
    def test_correct_candidate_passes(self):
        cand = space.Candidate("dense", (2, 4, 4, 9), "bfloat16", 8, 8)
        verdict = oracle.check(cand, interpret=True)
        assert verdict["passed"], verdict

    def test_seeded_violation_is_rejected(self):
        cand = space.Candidate("dense", (2, 4, 4, 9), "bfloat16", 8, 8)
        verdict = oracle.check(cand, interpret=True, perturb=2.0)
        assert not verdict["passed"]
        assert verdict["worst_excess"] > 0

    def test_fused_candidate_passes_composed_budget(self):
        cand = space.Candidate(
            "spectral_fused", (2, 4, 4, 12, 9, 3, 3), "bfloat16", 2, 2)
        verdict = oracle.check(cand, interpret=True)
        assert verdict["passed"], verdict

    def test_fused_seeded_violation_is_rejected(self):
        """A seeded composed-budget violation on the megakernel must be
        caught at the oracle's fused branch — the gate prices
        ``STAGES['spectral_fused']`` requantising stages plus the
        composed f32 accumulation term, mirroring ``--perturb``."""
        cand = space.Candidate(
            "spectral_fused", (2, 4, 4, 12, 9, 3, 3), "bfloat16", 2, 2)
        verdict = oracle.check(cand, interpret=True, perturb=2.0)
        assert not verdict["passed"]
        assert verdict["worst_excess"] > 0
        # it was the budget comparison that tripped, not a shape error:
        # the verdict carries the priced budget and the measured error
        assert verdict["max_err"] > verdict["budget_min"]

    def test_fused_malformed_shape_rejected_loudly(self):
        with pytest.raises(ValueError, match="spectral_fused"):
            space.fused_axes((2, 4, 4, 12, 9, 3))  # odd spatial+modes tail

    def test_validate_cli_rejects_seeded_violation(self, tmp_path, capsys):
        p = _state_with(
            tmp_path,
            _entry("dense", (2, 4, 4, 9)),
            _entry("spectral_fused", (2, 4, 4, 12, 9, 3, 3),
                   block_fwd=2, block_bwd=2))
        argv = ["validate", "--state", str(p), "--interpret"]
        assert tune_main(argv) == 0
        assert tune_main(argv + ["--perturb", "2"]) == 1
        assert "REJECT" in capsys.readouterr().out

    def test_validate_cli_skips_stale_and_prunes_corrupt(self, tmp_path):
        p = _state_with(
            tmp_path,
            _entry("dense", (2, 4, 4, 9)),
            _entry("dense", (2, 4, 4, 11),
                   kernel_version=KERNEL_VERSION - 1),
            _entry("dense", (2, 4, 4, 13), block_fwd=7),
        )
        # stale entry is skipped (not a failure); corrupt one fails
        assert tune_main(["validate", "--state", str(p),
                          "--interpret", "--prune"]) == 1
        state = cache_mod.load(p)
        assert cache_mod.entry_key(
            "dense", (2, 4, 4, 13), "bfloat16") not in state.entries
        assert cache_mod.entry_key(
            "dense", (2, 4, 4, 11), "bfloat16") in state.entries
        assert tune_main(["validate", "--state", str(p),
                          "--interpret"]) == 0

    def test_validate_cli_unreadable_state(self, tmp_path):
        assert tune_main(["validate", "--state",
                          str(tmp_path / "nope.json")]) == 2


def test_tune_smoke_cycle(tmp_path):
    """The CI loop end-to-end: tune --smoke admits oracle-validated
    entries, validate re-checks them, report renders."""
    p = tmp_path / "cal.json"
    rc = tune_main(["tune", "--smoke", "--interpret", "--state", str(p),
                    "--limit", "1", "--iters", "1"])
    assert rc == 0
    state = cache_mod.load(p)
    assert state.entries, "tune wrote no entries"
    for ent in state.entries.values():
        assert ent["validated"] and ent["interpret"]
        assert ent["kernel_version"] == KERNEL_VERSION
        assert ent["gbps"] >= 0 and 0 <= ent["roofline_fraction"] <= 1
    assert tune_main(["validate", "--state", str(p), "--interpret"]) == 0
    assert tune_main(["report", "--state", str(p)]) == 0


# ---------------------------------------------------------------------------
# analyze: calibration-coverage
# ---------------------------------------------------------------------------

class TestCalibrationCoverage:
    def test_clean_state_no_findings(self, tmp_path):
        p = _state_with(tmp_path, _entry("dense", (2, 8, 8, 40)))
        assert calibration_pass(str(p)) == []

    def test_oversized_tile_is_an_error(self, tmp_path):
        # a "tuned" tile whose bwd working set overflows VMEM: the
        # coverage check must flag it even though it is structurally fine
        p = _state_with(tmp_path, _entry(
            "dense", (64, 512, 512, 4096), block_fwd=8, block_bwd=4096))
        findings = calibration_pass(str(p))
        assert findings and all(f.check == "calibration-coverage"
                                for f in findings)
        assert any("budget" in f.detail or "VMEM" in f.detail
                   for f in findings)

    def test_unreadable_state_is_an_error_finding(self, tmp_path):
        bad = tmp_path / "bad.json"
        bad.write_text("nope")
        findings = calibration_pass(str(bad))
        assert len(findings) == 1 and findings[0].severity == "error"

    def test_no_path_no_findings(self, monkeypatch):
        monkeypatch.delenv(cache_mod.ENV_VAR, raising=False)
        assert calibration_pass(None) == []


def test_oversized_entry_never_served(tmp_path):
    """Defense in depth: lookup itself doesn't re-price VMEM (that's the
    analyze check), but the seeded oversized entry still routes through
    the kernels' padding path without crashing."""
    B, I, O, M = 2, 4, 4, 9
    p = _state_with(tmp_path, _entry("dense-fused", (B, I, O, M),
                                     block_fwd=16, block_bwd=16))
    cache_mod.activate(str(p))
    site = get_policy("mixed_fno_bf16").at("fno/layer0/spectral/contract")
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(B, I, 3, 3) + 1j * rng.randn(B, I, 3, 3),
                    jnp.complex64)
    w = jnp.asarray(rng.randn(I, O, 3, 3) + 1j * rng.randn(I, O, 3, 3),
                    jnp.complex64)
    y = ops.spectral_contract(x, w, policy=site)
    assert y.shape == (B, O, 3, 3)
    assert VMEM_BUDGET > 0  # the constant the coverage check prices against
