"""Darcy flow dataset: -∇·(a(x)∇u(x)) = f, u|∂D = 0  (paper §B.2).

Coefficients a(x) are piecewise-constant pushforwards of a GRF (12 where
the GRF is positive, 3 elsewhere — the Li et al. 2021 construction), the
forcing is f ≡ 1, and the solution is computed in JAX with conjugate
gradients on the 5-point finite-difference operator with harmonic-mean
face coefficients.  Everything is jit-able and runs on device.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .grf import grf_2d


def _face_harmonic(a: jnp.ndarray, axis: int) -> jnp.ndarray:
    a0 = jax.lax.slice_in_dim(a, 0, a.shape[axis] - 1, axis=axis)
    a1 = jax.lax.slice_in_dim(a, 1, a.shape[axis], axis=axis)
    return 2.0 * a0 * a1 / (a0 + a1)


def darcy_matvec(a: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """Apply A = -∇·(a∇·) to interior field u (n, n); Dirichlet boundary."""
    n = u.shape[-1]
    h = 1.0 / (n + 1)
    up = jnp.pad(u, ((1, 1), (1, 1)))
    ap = jnp.pad(a, ((1, 1), (1, 1)), mode="edge")
    ax = _face_harmonic(ap, 0)  # (n+1, n+2) faces along x
    ay = _face_harmonic(ap, 1)  # (n+2, n+1)
    # flux divergence
    fx = ax * (up[1:, :] - up[:-1, :])  # (n+1, n+2)
    fy = ay * (up[:, 1:] - up[:, :-1])  # (n+2, n+1)
    div = (fx[1:, 1:-1] - fx[:-1, 1:-1]) + (fy[1:-1, 1:] - fy[1:-1, :-1])
    return -div / (h * h)


@functools.partial(jax.jit, static_argnames=("n", "maxiter"))
def solve_darcy(a: jnp.ndarray, n: int, maxiter: int = 500) -> jnp.ndarray:
    """CG-solve -∇·(a∇u) = 1 for one coefficient field a (n, n)."""
    f = jnp.ones((n, n), jnp.float32)
    op = lambda u: darcy_matvec(a, u)
    u, _ = jax.scipy.sparse.linalg.cg(op, f, tol=1e-6, maxiter=maxiter)
    return u


def sample_darcy_batch(key: jax.Array, n: int, batch: int, maxiter: int = 500):
    """Returns (a, u): coefficients (B, 1, n, n) and solutions (B, 1, n, n).

    Both channels are whitened to O(1) — the standard neuraloperator
    preprocessing the paper inherits.  This matters for mixed precision:
    the tanh stabiliser is ~identity near 0 but *saturates* on the raw
    piecewise-{3,12} coefficients, collapsing the spectral-path signal
    (found empirically — EXPERIMENTS.md §Perf notes)."""
    g = grf_2d(key, n, alpha=2.0, tau=3.0, batch=batch)
    a = jnp.where(g > 0, 12.0, 3.0).astype(jnp.float32)
    u = jax.vmap(lambda ai: solve_darcy(ai, n, maxiter))(a)
    a = (a - 7.5) / 4.5          # whiten {3,12} -> {-1,+1}
    u = (u - 5e-3) / 5e-3        # interior solution scale for f≡1
    return a[:, None], u[:, None]
