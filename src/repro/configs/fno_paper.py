"""The paper's own model configs (TFNO/FNO on NS & Darcy, SFNO on SWE,
GINO on Shape-Net-Car/Ahmed-body, U-Net baseline)."""
from repro.models import FNOConfig, GINOConfig, SFNOConfig, UNetConfig

# TFNO on Navier-Stokes (CP-factorised weights, §4.6) — paper-scale
TFNO_NS = FNOConfig(
    in_channels=1, out_channels=1, hidden_channels=64,
    lifting_channels=256, projection_channels=256,
    n_layers=4, modes=(42, 42), factorization="cp", rank=0.5,
)

# FNO on Darcy (dense weights)
FNO_DARCY = FNOConfig(
    in_channels=1, out_channels=1, hidden_channels=64,
    lifting_channels=256, projection_channels=256,
    n_layers=4, modes=(32, 32), factorization="dense",
)

# SFNO on the spherical SWE (256x512 grid in the paper)
SFNO_SWE = SFNOConfig(
    in_channels=3, out_channels=3, hidden_channels=64, n_layers=4,
    nlat=256, nlon=512, lmax=128, mmax=128,
    lifting_channels=128, projection_channels=128,
)

# GINO on Shape-Net Car (64^3 latent grid in the paper)
GINO_CAR = GINOConfig(
    in_features=1, out_features=1, hidden=64, latent_grid=32, k_neighbors=8,
    fno=FNOConfig(
        in_channels=32, out_channels=32, hidden_channels=64,
        lifting_channels=64, projection_channels=64,
        n_layers=4, modes=(12, 12, 12), positional_embedding=False,
    ),
)

UNET_BASELINE = UNetConfig(in_channels=1, out_channels=1, base_width=32, depth=3)

# Reduced smoke variants
TFNO_NS_SMOKE = FNOConfig(
    in_channels=1, out_channels=1, hidden_channels=16,
    lifting_channels=16, projection_channels=16,
    n_layers=2, modes=(8, 8), factorization="cp",
)
FNO_DARCY_SMOKE = FNOConfig(
    in_channels=1, out_channels=1, hidden_channels=16,
    lifting_channels=16, projection_channels=16, n_layers=2, modes=(8, 8),
)
SFNO_SWE_SMOKE = SFNOConfig(
    in_channels=3, out_channels=3, hidden_channels=8, n_layers=2,
    nlat=16, nlon=32, lmax=8, mmax=8, lifting_channels=8, projection_channels=8,
)
GINO_CAR_SMOKE = GINOConfig(
    in_features=1, out_features=1, hidden=8, latent_grid=4, k_neighbors=4,
    fno=FNOConfig(
        in_channels=8, out_channels=8, hidden_channels=8,
        lifting_channels=8, projection_channels=8, n_layers=1,
        modes=(2, 2, 2), positional_embedding=False,
    ),
)
