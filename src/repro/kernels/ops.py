"""jit'd public wrappers around the Pallas kernels.

These are the entry points the model code calls.  They handle:
  * complex <-> split-real conversion at the policy's spectral dtype,
  * mode flattening / padding,
  * interpret-mode selection (CPU container validates kernels in interpret
    mode; on TPU the same call compiles to Mosaic),
  * the autoprec telemetry tap at the contract site — the same
    ``tap(site, activation, fmt)`` stream ``SitePrecision.contract``
    feeds on the einsum path, so the controller's demotion decisions see
    identical amax/overflow streams whichever path runs,
  * explicit rejection of inputs the kernels don't support (Tucker
    factors and rank-mismatched operands fall back to the einsum path in
    ``core/spectral.py``, never silently through this one).
"""
from __future__ import annotations

import os
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.precision import ComplexPair
from repro.precision import FULL, PrecisionPolicy
from .spectral_contract import (
    VMEM_BUDGET,
    cp_vmem_bytes,
    fused_supported,
    fused_vmem_bytes,
    fused_vmem_bytes_bwd,
    lshared_vmem_bytes,
    pick_block_b,
    pick_block_l,
    pick_block_m,
    spectral_contract_cp_pallas,
    spectral_contract_lshared_pallas,
    spectral_contract_pallas,
    spectral_fused_pallas,
    vmem_bytes,
    vmem_bytes_bwd,
)
from .flash_attention import flash_attention as _flash
from .rmsnorm import rmsnorm as _rmsnorm


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def resolve_use_pallas(flag: Optional[bool] = None) -> bool:
    """Resolve a tri-state ``use_pallas`` setting.

    Explicit True/False wins; ``None`` means *auto*: on when the env var
    ``REPRO_USE_PALLAS`` is truthy (the tier-1 CI leg sets it to run the
    whole suite through the kernels in interpret mode), otherwise on
    exactly when the backend is a TPU (where the kernels compile to
    Mosaic; interpret mode elsewhere stays opt-in).
    """
    if flag is not None:
        return bool(flag)
    env = os.environ.get("REPRO_USE_PALLAS")
    if env is not None and env != "":
        return env.lower() not in ("0", "false", "no")
    return jax.default_backend() == "tpu"


def resolve_fuse_casts(flag: Optional[bool] = None) -> bool:
    """Resolve the tri-state ``fuse_casts`` setting for the dense path.

    Explicit True/False wins; ``None`` means *auto*: on unless the env
    var ``REPRO_FUSE_CASTS`` is falsy (kill switch).  When on — and the
    site quantises to half and the operands arrive as complex (not
    pre-cast ComplexPairs) — the storage rounding happens inside the
    kernel's tile prologue instead of as a separate HBM-resident cast.
    """
    if flag is not None:
        return bool(flag)
    env = os.environ.get("REPRO_FUSE_CASTS")
    if env is not None and env != "":
        return env.lower() not in ("0", "false", "no")
    return True


def resolve_fuse_spectral(flag: Optional[bool] = None) -> bool:
    """Resolve the tri-state ``fuse_spectral`` setting.

    Explicit True/False wins; ``None`` means *auto*: on unless the env
    var ``REPRO_FUSE_SPECTRAL`` is falsy (kill switch).  When on — and
    the layer is dense, the shape passes :func:`fused_supported`, the
    batch=1 working set fits VMEM, and no autoprec collector is active
    (the fused spectrum never touches HBM, so the per-stage taps have
    nothing to observe) — ``core/spectral`` dispatches the whole
    rFFT → contract → irFFT pipeline into one Pallas grid
    (``spectral_fused``) instead of the three-stage path.
    """
    if flag is not None:
        return bool(flag)
    env = os.environ.get("REPRO_FUSE_SPECTRAL")
    if env is not None and env != "":
        return env.lower() not in ("0", "false", "no")
    return True


def fused_spectral_viable(fft_in, ctr, B: int, I: int, O: int,
                          spatial: Sequence[int],
                          modes: Sequence[int]) -> bool:
    """Can this dense layer run the fused megakernel bit-for-spec?

    Requires structural support (modes fit the truncated-DFT factor
    layout), a VMEM fit for the training working set at the floor tile
    ``block_b=1``, an inactive autoprec collector (the staged path owns
    telemetry — its taps see the HBM spectrum the fused path never
    materialises), and spectral sites that agree on one quantisation
    spec (every registry policy does; bespoke overlays that quantise
    ``fft_in`` differently from ``contract`` keep the staged path,
    whose per-stage semantics they address).
    """
    from repro.autoprec.telemetry import telemetry_active

    if telemetry_active():
        return False
    if not fused_supported(tuple(spatial), tuple(modes)):
        return False
    if fused_vmem_bytes_bwd(1, I, O, tuple(spatial), tuple(modes),
                            itemsize=4) > VMEM_BUDGET:
        return False
    if fft_in.quantize_fmt != ctr.quantize_fmt:
        return False
    if fft_in.quantize_fmt is not None and fft_in.compute != ctr.compute:
        return False
    return True


def _fused_qspec(ctr):
    """(cast_to, sim_fmt) static kernel params for a contract-site rule.

    ``half`` quantisation → round operand tiles onto the half grid in
    VMEM (``cast_to``); simulated fp8 → fp8-grid rounding of the
    spectrum (``sim_fmt``) *then* the half storage cast, exactly the
    staged ``fft_in.quantize → half contraction`` composition.
    """
    fmt = ctr.quantize_fmt
    if fmt is None:
        return None, None
    if fmt == "half":
        return ctr.compute, None
    return ctr.compute, fmt


def gather_corner_weights(w_re, w_im, modes: Sequence[int]):
    """Fold per-corner dense weights into the fused kernel's layout.

    ``w_re``/``w_im``: (corners, I, O, *modes) split-real corner
    weights.  The fused kernel's forward DFT keeps, per truncated axis,
    the low block ``[0, m)`` then the high block ``[S-m, S)`` — so
    corner ``c``'s weight lands at axis-``k`` rows ``[m, 2m)`` when bit
    ``k`` of ``c`` is set, ``[0, m)`` otherwise (last axis: always
    ``[0, m)``).  Returns ``(wgr, wgi)`` of shape (I, O, Mh) with the
    row-major flattening the kernel contracts over.  Pure differentiable
    ``jnp`` — gradients scatter back to the per-corner params.
    """
    nc, I, O, *mlist = w_re.shape
    ndim = len(modes)
    rows = tuple(2 * m for m in modes[:-1]) + (modes[-1],)
    out_r = jnp.zeros((I, O, *rows), w_re.dtype)
    out_i = jnp.zeros((I, O, *rows), w_im.dtype)
    for c in range(nc):
        sl = [slice(None), slice(None)]
        for ax in range(ndim - 1):
            m = modes[ax]
            sl.append(slice(m, 2 * m) if (c >> ax) & 1 else slice(0, m))
        sl.append(slice(0, modes[-1]))
        out_r = out_r.at[tuple(sl)].set(w_re[c])
        out_i = out_i.at[tuple(sl)].set(w_im[c])
    Mh = 1
    for r in rows:
        Mh *= r
    return out_r.reshape(I, O, Mh), out_i.reshape(I, O, Mh)


def _site_of(policy, site: str):
    """Resolve a PrecisionPolicy at ``site``; pass SitePrecision through."""
    if isinstance(policy, PrecisionPolicy):
        return policy.at(site)
    return policy


#: tile-resolution outcomes since process start, counted at trace time
#: (one per compiled shape, not per step — jit caches the resolution).
_TILE_SOURCES = {"heuristic": 0, "calibrated": 0}


def _obs_kernel_call(family: str, shape: tuple, dtype) -> None:
    """Per-family traced-call counter + bytes-moved gauge in the obs
    registry.  Counted where tiles resolve — once per compiled shape,
    not per executed step (jit caches the wrapper's trace); the gauge
    carries the tune traffic model's HBM bytes for the last shape this
    family traced with."""
    from repro.obs import registry
    from repro.tune.measure import bytes_moved

    reg = registry()
    reg.counter("repro_kernels_calls_total", family=family).inc()
    reg.gauge("repro_kernels_bytes_moved", family=family).set(
        float(bytes_moved(family, shape, jnp.dtype(dtype).name)))


def _resolve_blocks(family: str, shape: tuple, dtype, heuristic):
    """Resolve (block_fwd, block_bwd, source) for one kernel launch.

    Consults the active ``repro.tune`` calibration cache first (explicit
    ``tune.cache.activate(...)`` or the ``REPRO_CALIBRATION_STATE`` env
    var); entries that are missing, stale (kernel-version or backend
    mismatch) or corrupt fall back to the static VMEM heuristic — tuning
    state can degrade the heuristic path's performance only, never its
    availability.
    """
    from repro.tune.cache import active_cache

    _obs_kernel_call(family, shape, dtype)
    cache = active_cache()
    if cache is not None:
        ent = cache.lookup(family, shape, jnp.dtype(dtype).name)
        if ent is not None:
            _TILE_SOURCES["calibrated"] += 1
            return int(ent["block_fwd"]), int(ent["block_bwd"]), "calibrated"
    _TILE_SOURCES["heuristic"] += 1
    return heuristic(), None, "heuristic"


def tile_resolution_stats() -> dict:
    """Where this process's kernel tiles came from: per-source counts
    plus the active calibration cache's path and hit/miss/stale
    counters (None when no cache is active).  Surfaced by
    ``OperatorEngine.stats()`` and the dry-run roofline report."""
    from repro.tune.cache import active_cache

    cache = active_cache()
    out = {
        "sources": dict(_TILE_SOURCES),
        "calibration_state": cache.path if cache is not None else None,
        "cache": dict(cache.counters) if cache is not None else None,
    }
    return out


def reset_tile_resolution_stats() -> None:
    """Zero the tile-source counters and the active calibration cache's
    hit/miss/stale counters (bench hygiene between warmup and
    measurement legs).  Registered with the obs registry below, so
    ``repro.obs.registry().reset()`` covers it too."""
    from repro.tune.cache import active_cache

    for k in _TILE_SOURCES:
        _TILE_SOURCES[k] = 0
    cache = active_cache()
    if cache is not None:
        for k in cache.counters:
            cache.counters[k] = 0


def _register_obs() -> None:
    from repro.obs import registry

    registry().register_external(
        "repro_kernels_tiles", tile_resolution_stats,
        reset_tile_resolution_stats)


_register_obs()


def _tap_contract(policy, x) -> None:
    # same telemetry stream as SitePrecision.contract on the einsum path:
    # the activation operand against the site's storage format
    from repro.autoprec.telemetry import fmt_of, tap

    tap(policy.site, x, fmt=fmt_of(policy))


def _to_pair(x, half) -> ComplexPair:
    if isinstance(x, ComplexPair):
        return x if x.dtype == half else x.astype(half)
    return ComplexPair.from_complex(x, half)


def spectral_contract(
    x, w, *, policy=FULL, block_m: Optional[int] = None,
    block_m_bwd: Optional[int] = None, fuse_casts: Optional[bool] = None,
    site: str = "model/spectral/contract",
):
    """Dense spectral contraction ``bi<modes>,io<modes>->bo<modes>``.

    ``block_m=None`` (the production default) resolves the mode tile from
    the active calibration cache when one holds a validated entry for
    this (family, shape, dtype, backend, kernel-version) key, else via
    ``pick_block_m`` from the actual shapes and storage itemsize — the
    same estimate the dry-runs record, so their ``fits_vmem`` verdict
    describes the tiling that really executes.  ``block_m_bwd`` tiles the
    two backward kernels independently (default: the forward tile, or
    the calibrated backward tile when one resolved).

    ``fuse_casts``: tri-state (see ``resolve_fuse_casts``).  When it
    resolves on — and the site quantises and ``x``/``w`` arrive as
    complex — the half storage rounding runs inside the kernel's tile
    prologue (``cast_to``), so the half operand copies never round-trip
    through HBM; numerically it is the same Thm 3.2 rounding.

    ``x``: complex64 or ComplexPair, shape (B, I, *modes);
    ``w``: complex64 or ComplexPair (the layer's dense corner weight),
    shape (I, O, *modes).  Anything else — CP/Tucker factor dicts, rank
    mismatches — raises ``ValueError`` (the factorised paths are
    ``spectral_contract_cp`` and the einsum fallback in
    ``core/spectral.py``; nothing is silently reinterpreted here).
    ``policy``: an already-resolved ``SitePrecision`` handed down by the
    model (``policy.at("fno/layer2/spectral/contract")``), or a bare
    ``PrecisionPolicy`` — then resolved here at ``site``, which direct
    callers must set to the layer's real address for per-layer
    ``precision_rules`` overrides to reach this path.
    Returns the same kind as ``x`` (ComplexPair under a half rule).
    """
    policy = _site_of(policy, site)
    for name, a in (("x", x), ("w", w)):
        if not (isinstance(a, ComplexPair) or hasattr(a, "ndim")):
            raise ValueError(
                f"spectral_contract: {name} is {type(a).__name__}, not a "
                f"dense array/ComplexPair — factorised (CP/Tucker) weights "
                f"must go through spectral_contract_cp or the einsum path"
            )
    if len(x.shape) != len(w.shape) or len(x.shape) < 3:
        raise ValueError(
            f"spectral_contract is dense-only: expected x (B, I, *modes) "
            f"and w (I, O, *modes) of equal rank >= 3, got {x.shape} vs "
            f"{w.shape} — CP/Tucker factors take spectral_contract_cp or "
            f"the einsum fallback in core/spectral.py"
        )
    half = policy.spectral_dtype or jnp.float32
    was_pair = isinstance(x, ComplexPair)
    _tap_contract(policy, x)
    fused = (
        policy.spectral_is_half
        and not was_pair
        and not isinstance(w, ComplexPair)
        and resolve_fuse_casts(fuse_casts)
    )
    if fused:
        # fused-quantise path: split to f32 pairs without rounding here;
        # the kernel prologue rounds each tile onto the half grid in
        # VMEM (same representation error, one fewer HBM round-trip).
        xp = ComplexPair.from_complex(x, jnp.float32)
        wp = ComplexPair.from_complex(w, jnp.float32)
    else:
        xp = _to_pair(x, half)
        wp = _to_pair(w, half)

    B, I, *modes = xp.re.shape
    I2, O, *modes2 = wp.re.shape
    if tuple(modes) != tuple(modes2) or I != I2:
        raise ValueError(
            f"spectral_contract: x {xp.re.shape} and w {wp.re.shape} "
            f"disagree on channels or modes"
        )
    M = 1
    for m in modes:
        M *= m
    # the fused path streams f32 operand tiles, so its VMEM working set
    # (and its calibration entries) price at itemsize 4
    itemsize = 4 if fused else jnp.dtype(half).itemsize
    if block_m is None:
        block_m, tuned_bwd, _src = _resolve_blocks(
            "dense-fused" if fused else "dense", (B, I, O, M), half,
            lambda: pick_block_m(B, I, O, M, itemsize=itemsize),
        )
        block_m_bwd = block_m_bwd or tuned_bwd

    # named_scope: eqns traced under this site carry its address in
    # their name stack — repro.analyze attributes findings with it
    with jax.named_scope(policy.site):
        out_re, out_im = spectral_contract_pallas(
            xp.re.reshape(B, I, M), xp.im.reshape(B, I, M),
            wp.re.reshape(I, O, M), wp.im.reshape(I, O, M),
            block_m=block_m, block_m_bwd=block_m_bwd,
            interpret=_use_interpret(), out_dtype=half,
            cast_to=half if fused else None,
        )
    pair = ComplexPair(
        out_re.reshape(B, O, *modes), out_im.reshape(B, O, *modes)
    )
    if was_pair and policy.spectral_is_half:
        return pair
    return pair.to_complex()


def spectral_conv_fused(
    x, w_re, w_im, modes: Sequence[int], *, policy=FULL,
    block_b: Optional[int] = None, block_b_bwd: Optional[int] = None,
    site: str = "model/spectral",
):
    """The whole dense Fourier convolution in one Pallas grid.

    Fused rFFT → mode contraction → irFFT: the forward/inverse
    transforms run as precomputed truncated-DFT factor matmuls over the
    VMEM-resident batch tile, the contraction reuses the dense 4-real-
    matmul schedule with the ``cast_to``/``sim_fmt`` quantise prologue,
    and the spectrum never round-trips HBM between stages.  Semantically
    this is ``spectral_conv_apply`` for a dense layer: the stabiliser,
    boundary quantisation, per-corner contraction and output cast all
    happen — the corners as row blocks of the gathered weight, the
    quantisation on in-VMEM tiles.

    ``x``: real (B, I, *spatial); ``w_re``/``w_im``: (corners, I, O,
    *modes) split-real corner weights (the layer's ``params``);
    ``modes``: retained modes per axis.  ``policy``: a
    ``PrecisionPolicy`` resolved here at ``{site}/fft_in|contract|
    fft_out``, exactly like the staged pipeline.  ``block_b`` tiles the
    batch axis (``None`` → calibration cache, then the VMEM ladder).
    Returns real (B, O, *spatial) at ``x``'s dtype.
    """
    if not isinstance(policy, PrecisionPolicy):
        raise ValueError(
            "spectral_conv_fused resolves fft_in/contract/fft_out sites "
            "itself: pass the PrecisionPolicy, not a SitePrecision")
    fft_in = policy.at(f"{site}/fft_in")
    ctr = policy.at(f"{site}/contract")
    fft_out = policy.at(f"{site}/fft_out")

    ndim = len(modes)
    spatial = tuple(x.shape[2:])
    if len(spatial) != ndim:
        raise ValueError(
            f"spectral_conv_fused: x {x.shape} vs modes {tuple(modes)}")
    if not fused_supported(spatial, modes):
        raise ValueError(
            f"spectral_conv_fused: spatial {spatial} cannot retain modes "
            f"{tuple(modes)} in the fused factor layout — the staged "
            f"path in core/spectral.py handles this shape")
    in_dtype = x.dtype
    B, I = x.shape[:2]
    O = w_re.shape[2]

    # 1. stabiliser before the forward transform (half spectral only) —
    #    the one stage that stays outside the grid: it reads/writes the
    #    HBM-resident physical input the caller already owns.
    x = fft_in.stabilize(x)

    # 2–6. everything else is one kernel launch.
    wgr, wgi = gather_corner_weights(w_re, w_im, modes)
    cast_to, sim_fmt = _fused_qspec(ctr)
    half = ctr.spectral_dtype or jnp.float32
    if block_b is None:
        # the fused family streams f32 operands (quantisation happens on
        # tiles in VMEM), so shape keys and working sets price at
        # itemsize 4 whatever the policy's storage dtype
        block_b, tuned_bwd, _src = _resolve_blocks(
            "spectral_fused", (B, I, O, *spatial, *modes), half,
            lambda: pick_block_b(B, I, O, spatial, modes, itemsize=4),
        )
        block_b_bwd = block_b_bwd or tuned_bwd

    with jax.named_scope(ctr.site):
        y = spectral_fused_pallas(
            x.astype(jnp.float32), wgr, wgi, modes=tuple(modes),
            block_b=block_b, block_b_bwd=block_b_bwd,
            interpret=_use_interpret(), cast_to=cast_to, sim_fmt=sim_fmt,
        )

    from repro.autoprec.telemetry import fmt_of, tap

    tap(f"{site}/fft_out", y, fmt=fmt_of(fft_out))
    if fft_out.spectral_is_half:
        y = y.astype(fft_out.compute_dtype)
    return y.astype(in_dtype)


def cp_mode_factor(lam, mode_factors: Sequence) -> jnp.ndarray:
    """Fold λ (R,) and the per-axis CP factors (m_k, R) into the combined
    mode factor ``W[r, m] = λ_r Π_k U_mk[m_k, r]`` over the row-major
    flattened mode index (tiny, differentiable jnp — the kernels never
    materialise the dense (I, O, M) weight this factor replaces)."""
    w = lam[:, None]
    for f in mode_factors:
        w = (w[:, :, None] * jnp.transpose(f)[:, None, :]).reshape(
            w.shape[0], -1)
    return w


def spectral_contract_cp(
    x, lam, ui, uo, mode_factors: Sequence, *, policy=FULL,
    block_m: Optional[int] = None, block_m_bwd: Optional[int] = None,
    site: str = "model/spectral/contract",
):
    """CP-factorised spectral contraction (TFNO §4.6) on the Pallas path.

    ``x``: complex64 or ComplexPair (B, I, *modes); ``lam``: (R,) complex
    CP weights; ``ui``/``uo``: (I, R)/(O, R) channel factors;
    ``mode_factors``: one (m_k, R) complex factor per mode axis.
    Returns the same kind as ``x`` (ComplexPair under a half rule).
    """
    policy = _site_of(policy, site)
    half = policy.spectral_dtype or jnp.float32
    was_pair = isinstance(x, ComplexPair)
    _tap_contract(policy, x)
    xp = _to_pair(x, half)

    B, I, *modes = xp.re.shape
    if len(mode_factors) != len(modes):
        raise ValueError(
            f"spectral_contract_cp: {len(mode_factors)} mode factors for "
            f"{len(modes)}-d modes {tuple(modes)}"
        )
    M = 1
    for m in modes:
        M *= m
    w = cp_mode_factor(lam, mode_factors)  # (R, M) complex
    uip = _to_pair(ui, half)
    uop = _to_pair(uo, half)
    wp = _to_pair(w, half)
    O = uop.re.shape[0]
    R = uip.re.shape[1]
    if block_m is None:
        block_m, tuned_bwd, _src = _resolve_blocks(
            "cp", (B, I, O, R, M), half,
            lambda: pick_block_m(B, I, O, M, rank=R,
                                 itemsize=jnp.dtype(half).itemsize),
        )
        block_m_bwd = block_m_bwd or tuned_bwd

    with jax.named_scope(policy.site):
        out_re, out_im = spectral_contract_cp_pallas(
            xp.re.reshape(B, I, M), xp.im.reshape(B, I, M),
            uip.re, uip.im, uop.re, uop.im, wp.re, wp.im,
            block_m=block_m, block_m_bwd=block_m_bwd,
            interpret=_use_interpret(), out_dtype=half,
        )
    pair = ComplexPair(
        out_re.reshape(B, O, *modes), out_im.reshape(B, O, *modes)
    )
    if was_pair and policy.spectral_is_half:
        return pair
    return pair.to_complex()


def spectral_contract_lshared(
    x, w, *, policy=FULL, block_l: Optional[int] = None,
    block_l_bwd: Optional[int] = None,
    site: str = "model/spectral/contract",
):
    """Order-shared spherical contraction ``bilm,iol->bolm`` (SFNO).

    ``x``: complex64 or ComplexPair, shape (B, I, L, M) — the (degree,
    order) spherical spectrum; ``w``: complex64 or ComplexPair (I, O, L),
    shared across orders m per the spherical convolution theorem.  The
    kernel tiles over degrees and reduces m in-tile, so the dense
    (I, O, L, M) weight (and its gradient) is never materialised.
    Returns the same kind as ``x`` (ComplexPair under a half rule).
    """
    policy = _site_of(policy, site)
    if len(x.shape) != 4 or len(w.shape) != 3:
        raise ValueError(
            f"spectral_contract_lshared: expected x (B, I, L, M) and "
            f"w (I, O, L), got {x.shape} vs {w.shape}"
        )
    half = policy.spectral_dtype or jnp.float32
    was_pair = isinstance(x, ComplexPair)
    _tap_contract(policy, x)
    xp = _to_pair(x, half)
    wp = _to_pair(w, half)
    B, I, L, Mm = xp.re.shape
    O = wp.re.shape[1]
    if block_l is None:
        block_l, tuned_bwd, _src = _resolve_blocks(
            "lshared", (B, I, O, L, Mm), half,
            lambda: pick_block_l(B, I, O, L, Mm,
                                 itemsize=jnp.dtype(half).itemsize),
        )
        block_l_bwd = block_l_bwd or tuned_bwd
    with jax.named_scope(policy.site):
        out_re, out_im = spectral_contract_lshared_pallas(
            xp.re, xp.im, wp.re, wp.im,
            block_l=block_l, block_l_bwd=block_l_bwd,
            interpret=_use_interpret(), out_dtype=half,
        )
    pair = ComplexPair(out_re, out_im)
    if was_pair and policy.spectral_is_half:
        return pair
    return pair.to_complex()


def flash_attention(q, k, v, *, causal=True, block_q=128, block_k=128):
    """(B, H, S, D) attention; flattens heads into the grid batch axis."""
    from repro.obs import registry

    registry().counter("repro_kernels_calls_total",
                       family="flash_attention").inc()
    B, H, S, D = q.shape
    Sk = k.shape[2]
    qf = q.reshape(B * H, S, D)
    kf = k.reshape(B * H, Sk, D)
    vf = v.reshape(B * H, Sk, D)
    out = _flash(
        qf, kf, vf, causal=causal, block_q=block_q, block_k=block_k,
        interpret=_use_interpret(),
    )
    return out.reshape(B, H, S, D)


def rmsnorm(x, w, *, eps: float = 1e-6, block_rows: int = 256):
    """Rank-agnostic RMSNorm over the last axis."""
    from repro.obs import registry

    registry().counter("repro_kernels_calls_total", family="rmsnorm").inc()
    shape = x.shape
    flat = x.reshape(-1, shape[-1])
    out = _rmsnorm(flat, w, eps=eps, block_rows=block_rows, interpret=_use_interpret())
    return out.reshape(shape)


__all__ = [
    "spectral_contract", "spectral_contract_cp", "spectral_contract_lshared",
    "spectral_conv_fused", "gather_corner_weights", "fused_spectral_viable",
    "cp_mode_factor", "flash_attention", "rmsnorm", "resolve_use_pallas",
    "resolve_fuse_casts", "resolve_fuse_spectral", "tile_resolution_stats",
    "reset_tile_resolution_stats",
    "vmem_bytes", "vmem_bytes_bwd", "cp_vmem_bytes", "lshared_vmem_bytes",
    "fused_vmem_bytes", "fused_vmem_bytes_bwd",
    "pick_block_m", "pick_block_l", "pick_block_b",
]
