"""Unified decoder-only LM covering the assigned architecture pool.

Per-layer mixer dispatch (static, from config):
  attn   — GQA attention with RoPE (llama-family: smollm, granite,
           stablelm, starcoder2, llava backbone) or MLA (deepseek).
  ssd    — Mamba-2 state-space duality (mamba2-370m).
  hymba  — parallel attention + SSD heads, mean-fused (hymba-1.5b); SWA
           windows are per-layer *data* (an (L,) array scanned alongside
           the weights) so full-attention layers coexist with sliding-
           window layers inside one ``lax.scan`` stack.

FFN dispatch: dense SwiGLU or MoE (sort-based capacity dispatch, expert
parallelism over the ``model`` mesh axis).

All layer weights are stacked on a leading L axis and the layer loop is a
``lax.scan`` — the HLO stays one-layer-sized, which keeps the 512-device
dry-run compile tractable and gives remat a natural boundary.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import PrecisionPolicy, FULL
from repro.configs.base import LMArchConfig
from repro.dist.constrain import constrain, constrain_bhsd, constrain_bsd
from .common import (
    apply_rope,
    apply_rope_chunk,
    apply_rope_one,
    chunk_attention,
    decode_attention,
    gqa_attention,
    init_swiglu,
    rmsnorm,
    swiglu,
)
from .moe import init_moe, moe_apply
from .ssd import init_ssd, ssd_decode_step, ssd_forward

FULL_WINDOW = 2 ** 30  # "window" value meaning full attention


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_attn(key, cfg: LMArchConfig):
    d, H, Hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    s = (1.0 / d) ** 0.5
    if cfg.mla_kv_lora:
        r, dr, dn, dv = cfg.mla_kv_lora, cfg.mla_rope_dim, cfg.mla_nope_dim, cfg.mla_v_dim
        keys = jax.random.split(key, 6)
        return {
            "wq": s * jax.random.normal(keys[0], (d, H * (dn + dr)), jnp.float32),
            "w_dkv": s * jax.random.normal(keys[1], (d, r), jnp.float32),
            "w_kr": s * jax.random.normal(keys[2], (d, dr), jnp.float32),
            "w_uk": (1.0 / r) ** 0.5 * jax.random.normal(keys[3], (r, H * dn), jnp.float32),
            "w_uv": (1.0 / r) ** 0.5 * jax.random.normal(keys[4], (r, H * dv), jnp.float32),
            "wo": (1.0 / (H * dv)) ** 0.5 * jax.random.normal(keys[5], (H * dv, d), jnp.float32),
        }
    keys = jax.random.split(key, 4)
    return {
        "wq": s * jax.random.normal(keys[0], (d, H * hd), jnp.float32),
        "wk": s * jax.random.normal(keys[1], (d, Hk * hd), jnp.float32),
        "wv": s * jax.random.normal(keys[2], (d, Hk * hd), jnp.float32),
        "wo": (1.0 / (H * hd)) ** 0.5 * jax.random.normal(keys[3], (H * hd, d), jnp.float32),
    }


def _init_layer(key, cfg: LMArchConfig):
    keys = jax.random.split(key, 4)
    layer = {"ln1": jnp.ones((cfg.d_model,), jnp.float32),
             "ln2": jnp.ones((cfg.d_model,), jnp.float32)}
    if cfg.mixer in ("attn", "hymba"):
        layer["attn"] = _init_attn(keys[0], cfg)
    if cfg.mixer in ("ssd", "hymba"):
        layer["ssd"] = init_ssd(keys[1], cfg.d_model, cfg.d_inner,
                                cfg.ssm_heads, cfg.ssm_state)
    if cfg.moe_experts:
        layer["ffn"] = init_moe(keys[2], cfg.d_model, cfg.moe_experts,
                                cfg.moe_ff, cfg.moe_shared, cfg.moe_ff)
    elif cfg.d_ff:
        layer["ffn"] = init_swiglu(keys[2], cfg.d_model, cfg.d_ff)
    return layer


def layer_windows(cfg: LMArchConfig, n_layers: Optional[int] = None) -> jnp.ndarray:
    """(L,) per-layer attention windows.  hymba: n_full_attn_layers get
    full attention (first/middle/last), the rest the SWA window."""
    L = n_layers or cfg.n_layers
    if cfg.attn_window <= 0:
        return jnp.full((L,), FULL_WINDOW, jnp.int32)
    w = jnp.full((L,), cfg.attn_window, jnp.int32)
    if cfg.n_full_attn_layers > 0:
        idx = jnp.linspace(0, L - 1, cfg.n_full_attn_layers).astype(jnp.int32)
        w = w.at[idx].set(FULL_WINDOW)
    return w


def init_lm(key: jax.Array, cfg: LMArchConfig) -> Dict:
    keys = jax.random.split(key, cfg.n_layers + 3)
    layers = [_init_layer(keys[i], cfg) for i in range(cfg.n_layers)]
    params = {
        "embed": (1.0 / cfg.d_model ** 0.5)
        * jax.random.normal(keys[-3], (cfg.vocab, cfg.d_model), jnp.float32),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "layers": jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *layers),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = (1.0 / cfg.d_model ** 0.5) * jax.random.normal(
            keys[-2], (cfg.vocab, cfg.d_model), jnp.float32
        )
    if cfg.frontend == "vision_stub":
        params["patch_proj"] = (1.0 / cfg.d_model ** 0.5) * jax.random.normal(
            keys[-1], (cfg.d_model, cfg.d_model), jnp.float32
        )
    return params


# ---------------------------------------------------------------------------
# Attention forward (full-sequence / prefill)
# ---------------------------------------------------------------------------


def _attn_forward(ap, h, positions, window, cfg: LMArchConfig, dtype):
    B, S, d = h.shape
    H, Hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    def proj(w, x):
        return jnp.einsum("bsd,de->bse", x.astype(dtype), w.astype(dtype),
                          preferred_element_type=jnp.float32).astype(dtype)

    if cfg.mla_kv_lora:
        dn, dr, dv = cfg.mla_nope_dim, cfg.mla_rope_dim, cfg.mla_v_dim
        q = proj(ap["wq"], h).reshape(B, S, H, dn + dr)
        q_nope, q_rope = q[..., :dn], q[..., dn:]
        q_rope = apply_rope(q_rope.transpose(0, 2, 1, 3), positions, cfg.rope_theta)
        q = jnp.concatenate([q_nope.transpose(0, 2, 1, 3), q_rope], axis=-1)
        c_kv = proj(ap["w_dkv"], h)                       # (B,S,r)
        k_r = proj(ap["w_kr"], h)                         # (B,S,dr)
        k_r = apply_rope(k_r[:, None], positions, cfg.rope_theta)  # (B,1,S,dr)
        k_n = proj(ap["w_uk"], c_kv).reshape(B, S, H, dn).transpose(0, 2, 1, 3)
        k = constrain_bhsd(jnp.concatenate(
            [k_n, jnp.broadcast_to(k_r, (B, H, S, dr))], axis=-1))
        v = constrain_bhsd(proj(ap["w_uv"], c_kv).reshape(B, S, H, dv).transpose(0, 2, 1, 3))
        q = constrain_bhsd(q)
        o = gqa_attention(q, k, v, positions, positions, window)
        o = o.transpose(0, 2, 1, 3).reshape(B, S, H * dv)
    else:
        q = constrain_bhsd(proj(ap["wq"], h).reshape(B, S, H, hd).transpose(0, 2, 1, 3))
        k = constrain_bhsd(proj(ap["wk"], h).reshape(B, S, Hk, hd).transpose(0, 2, 1, 3))
        v = constrain_bhsd(proj(ap["wv"], h).reshape(B, S, Hk, hd).transpose(0, 2, 1, 3))
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        o = gqa_attention(q, k, v, positions, positions, window)
        o = o.transpose(0, 2, 1, 3).reshape(B, S, H * hd)
    return jnp.einsum("bse,ed->bsd", o, ap["wo"].astype(dtype),
                      preferred_element_type=jnp.float32).astype(dtype)


def _ffn_forward(fp, h, cfg: LMArchConfig, dtype, router_dtype=jnp.float32):
    if cfg.moe_experts:
        B, S, d = h.shape
        out, aux = moe_apply(fp, h.reshape(B * S, d), cfg.moe_top_k,
                             cfg.capacity_factor, dtype,
                             router_dtype=router_dtype)
        return out.reshape(B, S, d), aux
    return swiglu(fp, h, dtype), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Full forward (training / prefill)
# ---------------------------------------------------------------------------


def lm_forward(
    params: Dict,
    tokens: jnp.ndarray,
    cfg: LMArchConfig,
    policy: PrecisionPolicy = FULL,
    patch_embeds: Optional[jnp.ndarray] = None,
    inputs_embeds: Optional[jnp.ndarray] = None,
    remat: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens (B, S) -> (logits (B, S_total, V) at f32, aux_loss).

    vlm: ``patch_embeds`` (B, Np, d) are projected and prepended.
    audio/enc usage can pass ``inputs_embeds`` directly instead of tokens.
    ``remat=True`` checkpoints each layer (training at 4k×256 needs it).

    Precision resolves through the rule table: the dense mixer/FFN set at
    ``lm/dense``, the (reduction-sensitive) MoE router at ``lm/router``
    and the unembedding head at ``lm/proj_out`` (both f32 by default).
    """
    dtype = policy.at("lm/dense").compute_dtype
    router_dtype = policy.at("lm/router").compute_dtype
    head_dtype = policy.at("lm/proj_out").compute_dtype
    if inputs_embeds is not None:
        h = inputs_embeds.astype(dtype)
    else:
        h = params["embed"][tokens].astype(dtype)
    if patch_embeds is not None:
        pe = jnp.einsum("bnd,de->bne", patch_embeds.astype(dtype),
                        params["patch_proj"].astype(dtype)).astype(dtype)
        h = jnp.concatenate([pe, h], axis=1)
    h = constrain_bsd(h)
    B, S, _ = h.shape
    positions = jnp.arange(S)
    windows = layer_windows(cfg)

    def block(carry, layer_in):
        h, aux = carry
        lp, window = layer_in
        h = constrain_bsd(h)
        hn = rmsnorm(h, lp["ln1"], cfg.norm_eps)
        if cfg.mixer == "attn":
            mix = _attn_forward(lp["attn"], hn, positions, window, cfg, dtype)
        elif cfg.mixer == "ssd":
            mix = ssd_forward(lp["ssd"], hn, cfg, policy)
        else:  # hymba: parallel attention + SSD heads, mean-fused
            a = _attn_forward(lp["attn"], hn, positions, window, cfg, dtype)
            s = ssd_forward(lp["ssd"], hn, cfg, policy)
            mix = 0.5 * (a + s)
        h = h + mix
        hn = rmsnorm(h, lp["ln2"], cfg.norm_eps)
        f, a_loss = (
            _ffn_forward(lp.get("ffn"), hn, cfg, dtype, router_dtype)
            if "ffn" in lp
            else (0.0, 0.0)
        )
        h = h + f
        return (h, aux + a_loss), None

    if remat:
        block = jax.checkpoint(
            block, policy=jax.checkpoint_policies.nothing_saveable
        )
    (h, aux), _ = jax.lax.scan(block, (h, jnp.zeros((), jnp.float32)),
                               (params["layers"], windows))
    h = constrain_bsd(rmsnorm(h, params["final_norm"], cfg.norm_eps))
    unembed = params.get("unembed", params["embed"])
    logits = jnp.einsum("bsd,vd->bsv", h.astype(head_dtype),
                        unembed.astype(head_dtype))
    logits = constrain(logits, "dp", "seq", None)  # S-sharded CE
    return logits, aux


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------


def init_cache(cfg: LMArchConfig, batch: int, max_len: int, dtype=jnp.float32) -> Dict:
    """Decode cache pytree (zeros; per-slot ``step`` clocks support
    continuous batching — every request tracks its own position).

    ``dtype`` is the KV storage dtype and should follow the serving
    policy's compute dtype (f32 default keeps the decode-vs-forward
    contract exact under the FULL policy; AMP policies pass bf16/fp16
    for the memory saving).

    Attention caches are ring buffers of length min(max_len, window) when
    the arch is sliding-window (hymba), else full length.  SSD state is the
    O(1) recurrent state.  MLA caches the compressed c_kv + rope key only
    (the MLA memory saving).
    """
    L = cfg.n_layers
    cache: Dict = {"step": jnp.zeros((batch,), jnp.int32)}
    if cfg.mixer in ("attn", "hymba"):
        W = max_len if cfg.attn_window <= 0 else min(max_len, cfg.attn_window)
        if cfg.mla_kv_lora:
            cache["c_kv"] = jnp.zeros((L, batch, W, cfg.mla_kv_lora), dtype)
            cache["k_rope"] = jnp.zeros((L, batch, W, cfg.mla_rope_dim), dtype)
        else:
            cache["k"] = jnp.zeros((L, batch, cfg.n_kv_heads, W, cfg.hd), dtype)
            cache["v"] = jnp.zeros((L, batch, cfg.n_kv_heads, W, cfg.hd), dtype)
        cache["kv_pos"] = jnp.full((L, batch, W), -1, jnp.int32)
    if cfg.mixer in ("ssd", "hymba"):
        cache["ssd_state"] = jnp.zeros(
            (L, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
        )
    return cache


def _attn_decode(ap, h, layer_cache, pos, window, cfg: LMArchConfig, dtype):
    """h: (B, d) one token; layer_cache: this layer's cache slices;
    pos: (B,) per-slot positions (continuous batching)."""
    B, d = h.shape
    H, Hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    def proj(w, x):
        return jnp.einsum("bd,de->be", x.astype(dtype), w.astype(dtype),
                          preferred_element_type=jnp.float32).astype(dtype)

    W = layer_cache["kv_pos"].shape[-1]
    slot = jnp.mod(pos, W)          # (B,)
    b_idx = jnp.arange(B)

    if cfg.mla_kv_lora:
        dn, dr, dv = cfg.mla_nope_dim, cfg.mla_rope_dim, cfg.mla_v_dim
        q = proj(ap["wq"], h).reshape(B, H, dn + dr)
        q_r = apply_rope_one(q[:, :, dn:], pos, cfg.rope_theta)
        q = jnp.concatenate([q[:, :, :dn], q_r], axis=-1)[:, :, None, :]  # (B,H,1,*)
        c_kv = proj(ap["w_dkv"], h)
        k_r = apply_rope_one(proj(ap["w_kr"], h)[:, None, :], pos, cfg.rope_theta)[:, 0]
        ckv_cache = layer_cache["c_kv"].at[b_idx, slot].set(
            c_kv.astype(layer_cache["c_kv"].dtype))
        kr_cache = layer_cache["k_rope"].at[b_idx, slot].set(
            k_r.astype(layer_cache["k_rope"].dtype))
        kv_pos = layer_cache["kv_pos"].at[b_idx, slot].set(pos)
        # expand cached compressed kv for all W slots
        k_n = jnp.einsum("bwr,re->bwe", ckv_cache.astype(dtype), ap["w_uk"].astype(dtype),
                         preferred_element_type=jnp.float32).astype(dtype)
        k_n = k_n.reshape(B, W, H, dn).transpose(0, 2, 1, 3)
        k_full = jnp.concatenate(
            [k_n, jnp.broadcast_to(kr_cache.astype(dtype)[:, None], (B, H, W, dr))], axis=-1
        )
        v_full = jnp.einsum("bwr,re->bwe", ckv_cache.astype(dtype), ap["w_uv"].astype(dtype),
                            preferred_element_type=jnp.float32).astype(dtype)
        v_full = v_full.reshape(B, W, H, dv).transpose(0, 2, 1, 3)
        o = decode_attention(q, k_full, v_full, kv_pos, pos, window)
        o = o[:, :, 0].reshape(B, H * dv)
        new = {"c_kv": ckv_cache, "k_rope": kr_cache, "kv_pos": kv_pos}
    else:
        q = proj(ap["wq"], h).reshape(B, H, hd)
        k = proj(ap["wk"], h).reshape(B, Hk, hd)
        v = proj(ap["wv"], h).reshape(B, Hk, hd)
        q = apply_rope_one(q, pos, cfg.rope_theta)[:, :, None, :]
        k = apply_rope_one(k, pos, cfg.rope_theta)
        k_cache = layer_cache["k"].at[b_idx, :, slot].set(k.astype(layer_cache["k"].dtype))
        v_cache = layer_cache["v"].at[b_idx, :, slot].set(v.astype(layer_cache["v"].dtype))
        kv_pos = layer_cache["kv_pos"].at[b_idx, slot].set(pos)
        o = decode_attention(q, k_cache.astype(dtype), v_cache.astype(dtype),
                             kv_pos, pos, window)
        o = o[:, :, 0].reshape(B, H * hd)
        new = {"k": k_cache, "v": v_cache, "kv_pos": kv_pos}
    out = jnp.einsum("be,ed->bd", o, ap["wo"].astype(dtype),
                     preferred_element_type=jnp.float32).astype(dtype)
    return out, new


def lm_decode_step(
    params: Dict,
    cache: Dict,
    tokens: jnp.ndarray,   # (B,) next token ids
    cfg: LMArchConfig,
    policy: PrecisionPolicy = FULL,
) -> Tuple[jnp.ndarray, Dict]:
    """One serve step: returns (logits (B, V) f32, new cache).

    ``cache['step']`` is (B,): per-slot position clocks."""
    dtype = policy.at("lm/dense").compute_dtype
    router_dtype = policy.at("lm/router").compute_dtype
    head_dtype = policy.at("lm/proj_out").compute_dtype
    pos = cache["step"]                          # (B,)
    h = params["embed"][tokens].astype(dtype)   # (B, d)
    windows = layer_windows(cfg)

    # assemble per-layer cache slices for the scan
    layer_cache_keys = [k for k in cache if k not in ("step",)]
    xs_cache = {k: cache[k] for k in layer_cache_keys}

    def block(h, layer_in):
        lp, window, lc = layer_in
        hn = rmsnorm(h, lp["ln1"], cfg.norm_eps)
        new_lc = dict(lc)
        if cfg.mixer == "attn":
            mix, upd = _attn_decode(lp["attn"], hn, lc, pos, window, cfg, dtype)
            new_lc.update(upd)
        elif cfg.mixer == "ssd":
            mix, new_state = ssd_decode_step(lp["ssd"], hn, lc["ssd_state"], cfg, policy)
            new_lc["ssd_state"] = new_state
        else:
            a, upd = _attn_decode(lp["attn"], hn, lc, pos, window, cfg, dtype)
            s, new_state = ssd_decode_step(lp["ssd"], hn, lc["ssd_state"], cfg, policy)
            mix = 0.5 * (a + s)
            new_lc.update(upd)
            new_lc["ssd_state"] = new_state
        h = h + mix
        hn = rmsnorm(h, lp["ln2"], cfg.norm_eps)
        if "ffn" in lp:
            if cfg.moe_experts:
                f, _ = moe_apply(lp["ffn"], hn, cfg.moe_top_k, cfg.capacity_factor,
                                 dtype, router_dtype=router_dtype)
            else:
                f = swiglu(lp["ffn"], hn, dtype)
            h = h + f
        return h, new_lc

    h, new_xs = jax.lax.scan(block, h, (params["layers"], windows, xs_cache))
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    unembed = params.get("unembed", params["embed"])
    logits = jnp.einsum("bd,vd->bv", h.astype(head_dtype), unembed.astype(head_dtype))
    new_cache = dict(new_xs)
    new_cache["step"] = pos + 1
    return logits, new_cache


# ---------------------------------------------------------------------------
# Chunked batched prefill (serve prefill_chunk step)
# ---------------------------------------------------------------------------


def _attn_prefill_chunk(ap, h, layer_cache, q_pos, write_slot, window,
                        cfg: LMArchConfig, dtype):
    """h: (B, K, d) a chunk of K tokens per slot; writes the chunk's KVs
    into the cache, then attends every chunk query against the updated
    cache (write-then-attend).

    Per-slot bookkeeping: q_pos (B, K) absolute positions, write_slot
    (B, K) ring-buffer rows (== W for padding tokens, which the scatter
    drops).  Masked cache columns contribute an exact 0.0 to the softmax,
    so the chunk path is bit-identical to feeding the same tokens
    one-per-tick through ``_attn_decode`` — as long as the chunk does not
    wrap the ring buffer over positions still inside an in-chunk query's
    window (the engine clamps SWA chunks accordingly).
    """
    B, K, d = h.shape
    H, Hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    def proj(w, x):
        return jnp.einsum("bkd,de->bke", x.astype(dtype), w.astype(dtype),
                          preferred_element_type=jnp.float32).astype(dtype)

    b_idx = jnp.arange(B)[:, None]                       # (B, 1)

    if cfg.mla_kv_lora:
        dn, dr, dv = cfg.mla_nope_dim, cfg.mla_rope_dim, cfg.mla_v_dim
        W = layer_cache["kv_pos"].shape[-1]
        q = proj(ap["wq"], h).reshape(B, K, H, dn + dr)
        q_nope, q_rope = q[..., :dn], q[..., dn:]
        q_rope = apply_rope_chunk(q_rope.transpose(0, 2, 1, 3), q_pos,
                                  cfg.rope_theta)
        q = jnp.concatenate([q_nope.transpose(0, 2, 1, 3), q_rope], axis=-1)
        c_kv = proj(ap["w_dkv"], h)                       # (B, K, r)
        k_r = apply_rope_chunk(proj(ap["w_kr"], h)[:, None], q_pos,
                               cfg.rope_theta)[:, 0]      # (B, K, dr)
        ckv_cache = layer_cache["c_kv"].at[b_idx, write_slot].set(
            c_kv.astype(layer_cache["c_kv"].dtype), mode="drop")
        kr_cache = layer_cache["k_rope"].at[b_idx, write_slot].set(
            k_r.astype(layer_cache["k_rope"].dtype), mode="drop")
        kv_pos = layer_cache["kv_pos"].at[b_idx, write_slot].set(
            q_pos, mode="drop")
        k_n = jnp.einsum("bwr,re->bwe", ckv_cache.astype(dtype), ap["w_uk"].astype(dtype),
                         preferred_element_type=jnp.float32).astype(dtype)
        k_n = k_n.reshape(B, W, H, dn).transpose(0, 2, 1, 3)
        k_full = jnp.concatenate(
            [k_n, jnp.broadcast_to(kr_cache.astype(dtype)[:, None], (B, H, W, dr))], axis=-1
        )
        v_full = jnp.einsum("bwr,re->bwe", ckv_cache.astype(dtype), ap["w_uv"].astype(dtype),
                            preferred_element_type=jnp.float32).astype(dtype)
        v_full = v_full.reshape(B, W, H, dv).transpose(0, 2, 1, 3)
        o = chunk_attention(q, k_full, v_full, kv_pos, q_pos, window)
        o = o.transpose(0, 2, 1, 3).reshape(B, K, H * dv)
        new = {"c_kv": ckv_cache, "k_rope": kr_cache, "kv_pos": kv_pos}
    else:
        q = proj(ap["wq"], h).reshape(B, K, H, hd).transpose(0, 2, 1, 3)
        k = proj(ap["wk"], h).reshape(B, K, Hk, hd).transpose(0, 2, 1, 3)
        v = proj(ap["wv"], h).reshape(B, K, Hk, hd)
        q = apply_rope_chunk(q, q_pos, cfg.rope_theta)
        k = apply_rope_chunk(k, q_pos, cfg.rope_theta).transpose(0, 2, 1, 3)  # (B,K,Hk,hd)
        k_cache = layer_cache["k"].at[b_idx, :, write_slot].set(
            k.astype(layer_cache["k"].dtype), mode="drop")
        v_cache = layer_cache["v"].at[b_idx, :, write_slot].set(
            v.astype(layer_cache["v"].dtype), mode="drop")
        kv_pos = layer_cache["kv_pos"].at[b_idx, write_slot].set(
            q_pos, mode="drop")
        o = chunk_attention(q, k_cache.astype(dtype), v_cache.astype(dtype),
                            kv_pos, q_pos, window)
        o = o.transpose(0, 2, 1, 3).reshape(B, K, H * hd)
        new = {"k": k_cache, "v": v_cache, "kv_pos": kv_pos}
    out = jnp.einsum("bke,ed->bkd", o, ap["wo"].astype(dtype),
                     preferred_element_type=jnp.float32).astype(dtype)
    return out, new


# ---------------------------------------------------------------------------
# Paged KV cache (block-table gather / masked-scatter serving path)
# ---------------------------------------------------------------------------


def init_paged_cache(
    cfg: LMArchConfig,
    batch: int,
    num_blocks: int,
    block_size: int,
    max_len: int,
    dtype=jnp.float32,
) -> Dict:
    """Paged decode cache: KV rows live in ``num_blocks`` fixed-size
    blocks instead of per-slot ``(batch, W)`` strips.  One physical block
    id addresses all L layers at once (leading-L storage), so a block
    table is a single ``(batch, W // block_size)`` int32 array shared by
    every layer.

    Block 0 is reserved as the *null block*: its ``kv_pos`` stays -1
    forever, so unallocated table entries gather an all-masked view.
    ``ssd_state`` (O(1) recurrent state) is not paged — it stays a dense
    per-slot array exactly as in :func:`init_cache`.
    """
    L = cfg.n_layers
    cache: Dict = {"step": jnp.zeros((batch,), jnp.int32)}
    if cfg.mixer in ("attn", "hymba"):
        W = max_len if cfg.attn_window <= 0 else min(max_len, cfg.attn_window)
        if W % block_size:
            raise ValueError(
                f"cache width {W} (max_len/window) must be a multiple of "
                f"block_size {block_size}")
        if cfg.mla_kv_lora:
            cache["c_kv"] = jnp.zeros((L, num_blocks, block_size, cfg.mla_kv_lora), dtype)
            cache["k_rope"] = jnp.zeros((L, num_blocks, block_size, cfg.mla_rope_dim), dtype)
        else:
            cache["k"] = jnp.zeros((L, num_blocks, cfg.n_kv_heads, block_size, cfg.hd), dtype)
            cache["v"] = jnp.zeros((L, num_blocks, cfg.n_kv_heads, block_size, cfg.hd), dtype)
        cache["kv_pos"] = jnp.full((L, num_blocks, block_size), -1, jnp.int32)
    if cfg.mixer in ("ssd", "hymba"):
        cache["ssd_state"] = jnp.zeros(
            (L, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
        )
    return cache


def _paged_view(lbc: Dict, block_table: jnp.ndarray) -> Dict:
    """Gather one layer's dense ``(B, ..., W, ...)`` cache view out of its
    block arrays via the block table.  The view is fed to the *exact*
    dense ``_attn_decode`` / ``_attn_prefill_chunk`` — blocks mapped from
    the null block (or stale rows) carry ``kv_pos == -1`` and the mask in
    ``chunk_attention`` replaces their scores with NEG_INF outright, so
    the paged path stays bit-identical to the dense cache path."""
    B, nbt = block_table.shape
    bs = lbc["kv_pos"].shape[-1]
    W = nbt * bs
    view = {"kv_pos": lbc["kv_pos"][block_table].reshape(B, W)}
    if "c_kv" in lbc:
        view["c_kv"] = lbc["c_kv"][block_table].reshape(B, W, lbc["c_kv"].shape[-1])
        view["k_rope"] = lbc["k_rope"][block_table].reshape(B, W, lbc["k_rope"].shape[-1])
    else:
        k = lbc["k"][block_table]                       # (B, nbt, Hk, bs, hd)
        view["k"] = k.transpose(0, 2, 1, 3, 4).reshape(B, k.shape[2], W, k.shape[4])
        v = lbc["v"][block_table]
        view["v"] = v.transpose(0, 2, 1, 3, 4).reshape(B, v.shape[2], W, v.shape[4])
    return view


def _paged_scatter(lbc: Dict, upd: Dict, block_table: jnp.ndarray,
                   rows: jnp.ndarray, valid: jnp.ndarray) -> Dict:
    """Scatter the rows a dense step just wrote (``rows``: (B, K) ring
    rows, ``valid``: (B, K)) from the updated dense view back into the
    layer's block arrays.  Invalid rows route to sentinel block id Nb and
    are dropped — the null block and shared blocks are never written
    through an inactive or padding row."""
    B, K = rows.shape
    Nb = lbc["kv_pos"].shape[0]
    bs = lbc["kv_pos"].shape[-1]
    W = block_table.shape[1] * bs
    b_idx = jnp.arange(B)[:, None]                       # (B, 1)
    rows_c = jnp.clip(rows, 0, W - 1)
    wb = jnp.where(valid, block_table[b_idx, rows_c // bs], Nb)
    wo = jnp.mod(rows_c, bs)
    new = dict(lbc)
    new["kv_pos"] = lbc["kv_pos"].at[wb, wo].set(
        upd["kv_pos"][b_idx, rows_c], mode="drop")
    if "c_kv" in lbc:
        new["c_kv"] = lbc["c_kv"].at[wb, wo].set(
            upd["c_kv"][b_idx, rows_c], mode="drop")
        new["k_rope"] = lbc["k_rope"].at[wb, wo].set(
            upd["k_rope"][b_idx, rows_c], mode="drop")
    else:
        new["k"] = lbc["k"].at[wb, :, wo].set(
            upd["k"][b_idx, :, rows_c], mode="drop")
        new["v"] = lbc["v"].at[wb, :, wo].set(
            upd["v"][b_idx, :, rows_c], mode="drop")
    return new


def lm_paged_decode_step(
    params: Dict,
    cache: Dict,
    block_table: jnp.ndarray,   # (B, W // block_size) physical block ids
    active: jnp.ndarray,        # (B,) bool: slot holds a live request
    tokens: jnp.ndarray,        # (B,) next token ids
    cfg: LMArchConfig,
    policy: PrecisionPolicy = FULL,
) -> Tuple[jnp.ndarray, Dict]:
    """One paged serve step: gather each layer's dense view from the
    block arrays, run the *exact* dense :func:`_attn_decode`, scatter the
    written row back.  Identical einsum shapes => identical HLO => logits
    bit-identical to :func:`lm_decode_step` over an equivalently-filled
    dense cache.  ``active`` masks the write-back only (inactive slots
    must not touch the null block their table entries point at)."""
    dtype = policy.at("lm/dense").compute_dtype
    router_dtype = policy.at("lm/router").compute_dtype
    head_dtype = policy.at("lm/proj_out").compute_dtype
    pos = cache["step"]                          # (B,)
    h = params["embed"][tokens].astype(dtype)   # (B, d)
    windows = layer_windows(cfg)

    bs = cache["kv_pos"].shape[-1]
    W = block_table.shape[1] * bs
    rows = jnp.mod(pos, W)[:, None]              # (B, 1)
    valid = active[:, None]                      # (B, 1)
    xs_cache = {k: cache[k] for k in cache if k != "step"}

    def block(h, layer_in):
        lp, window, lc = layer_in
        hn = rmsnorm(h, lp["ln1"], cfg.norm_eps)
        new_lc = dict(lc)
        if cfg.mixer == "attn":
            view = _paged_view(lc, block_table)
            mix, upd = _attn_decode(lp["attn"], hn, view, pos, window, cfg, dtype)
            new_lc.update(_paged_scatter(lc, upd, block_table, rows, valid))
        elif cfg.mixer == "ssd":
            mix, new_state = ssd_decode_step(lp["ssd"], hn, lc["ssd_state"], cfg, policy)
            new_lc["ssd_state"] = new_state
        else:
            view = _paged_view(lc, block_table)
            a, upd = _attn_decode(lp["attn"], hn, view, pos, window, cfg, dtype)
            s, new_state = ssd_decode_step(lp["ssd"], hn, lc["ssd_state"], cfg, policy)
            mix = 0.5 * (a + s)
            new_lc.update(_paged_scatter(lc, upd, block_table, rows, valid))
            new_lc["ssd_state"] = new_state
        h = h + mix
        hn = rmsnorm(h, lp["ln2"], cfg.norm_eps)
        if "ffn" in lp:
            if cfg.moe_experts:
                f, _ = moe_apply(lp["ffn"], hn, cfg.moe_top_k, cfg.capacity_factor,
                                 dtype, router_dtype=router_dtype)
            else:
                f = swiglu(lp["ffn"], hn, dtype)
            h = h + f
        return h, new_lc

    h, new_xs = jax.lax.scan(block, h, (params["layers"], windows, xs_cache))
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    unembed = params.get("unembed", params["embed"])
    logits = jnp.einsum("bd,vd->bv", h.astype(head_dtype), unembed.astype(head_dtype))
    new_cache = dict(new_xs)
    new_cache["step"] = pos + 1
    return logits, new_cache


def lm_paged_prefill_chunk(
    params: Dict,
    cache: Dict,
    block_table: jnp.ndarray,   # (B, W // block_size) physical block ids
    tokens: jnp.ndarray,        # (B, K) next chunk of token ids per slot
    n_valid: jnp.ndarray,       # (B,) valid prefix length per slot (0..K)
    cfg: LMArchConfig,
    policy: PrecisionPolicy = FULL,
) -> Tuple[jnp.ndarray, Dict]:
    """Paged chunked prefill: the block-table twin of
    :func:`lm_prefill_chunk` (gather view -> exact dense chunk step ->
    masked scatter).  Slots with ``n_valid == 0`` neither write nor
    advance, so no ``active`` mask is needed here."""
    dtype = policy.at("lm/dense").compute_dtype
    router_dtype = policy.at("lm/router").compute_dtype
    head_dtype = policy.at("lm/proj_out").compute_dtype
    B, K = tokens.shape
    pos0 = cache["step"]                                  # (B,)
    j = jnp.arange(K)
    q_pos = pos0[:, None] + j[None, :]                    # (B, K)
    valid = j[None, :] < n_valid[:, None]                 # (B, K)

    h = params["embed"][tokens].astype(dtype)             # (B, K, d)
    h = jnp.where(valid[..., None], h, 0)                 # padding rows inert
    windows = layer_windows(cfg)

    bs = cache["kv_pos"].shape[-1]
    W = block_table.shape[1] * bs
    rows = jnp.mod(q_pos, W)                              # (B, K)
    # dense-view write slot; W (out of bounds) drops padding writes
    write_slot = jnp.where(valid, rows, W)
    xs_cache = {k: cache[k] for k in cache if k != "step"}

    def block(h, layer_in):
        lp, window, lc = layer_in
        hn = rmsnorm(h, lp["ln1"], cfg.norm_eps)
        new_lc = dict(lc)
        if cfg.mixer == "attn":
            view = _paged_view(lc, block_table)
            mix, upd = _attn_prefill_chunk(lp["attn"], hn, view, q_pos,
                                           write_slot, window, cfg, dtype)
            new_lc.update(_paged_scatter(lc, upd, block_table, rows, valid))
        elif cfg.mixer == "ssd":
            mix, new_state = _ssd_prefill_chunk(lp["ssd"], hn, lc["ssd_state"],
                                                valid, cfg, policy)
            new_lc["ssd_state"] = new_state
        else:
            view = _paged_view(lc, block_table)
            a, upd = _attn_prefill_chunk(lp["attn"], hn, view, q_pos,
                                         write_slot, window, cfg, dtype)
            s, new_state = _ssd_prefill_chunk(lp["ssd"], hn, lc["ssd_state"],
                                              valid, cfg, policy)
            mix = 0.5 * (a + s)
            new_lc.update(_paged_scatter(lc, upd, block_table, rows, valid))
            new_lc["ssd_state"] = new_state
        h = h + mix
        hn = rmsnorm(h, lp["ln2"], cfg.norm_eps)
        if "ffn" in lp:
            if cfg.moe_experts:
                f, _ = moe_apply(lp["ffn"], hn.reshape(B * K, -1), cfg.moe_top_k,
                                 cfg.capacity_factor, dtype,
                                 router_dtype=router_dtype)
                f = f.reshape(B, K, -1)
            else:
                f = swiglu(lp["ffn"], hn, dtype)
            h = h + f
        return h, new_lc

    h, new_xs = jax.lax.scan(block, h, (params["layers"], windows, xs_cache))
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    last = jnp.clip(n_valid - 1, 0, K - 1)
    h_last = h[jnp.arange(B), last]                       # (B, d)
    unembed = params.get("unembed", params["embed"])
    logits = jnp.einsum("bd,vd->bv", h_last.astype(head_dtype),
                        unembed.astype(head_dtype))
    new_cache = dict(new_xs)
    new_cache["step"] = pos0 + n_valid
    return logits, new_cache


def _ssd_prefill_chunk(sp, h, state0, valid, cfg: LMArchConfig, policy):
    """Scan the exact one-token SSD recurrence over the K chunk positions
    (state updates masked for padding tokens) — bit-identical to feeding
    the chunk token-by-token, which is the serve contract."""
    def step(state, inp):
        u_j, valid_j = inp                               # (B, d), (B,)
        y_j, new_state = ssd_decode_step(sp, u_j, state, cfg, policy)
        new_state = jnp.where(valid_j[:, None, None, None], new_state, state)
        return new_state, y_j

    state, ys = jax.lax.scan(
        step, state0, (h.transpose(1, 0, 2), valid.transpose(1, 0)))
    return ys.transpose(1, 0, 2), state                  # (B, K, d)


def lm_prefill_chunk(
    params: Dict,
    cache: Dict,
    tokens: jnp.ndarray,    # (B, K) next chunk of token ids per slot
    n_valid: jnp.ndarray,   # (B,) valid prefix length per slot (0..K)
    cfg: LMArchConfig,
    policy: PrecisionPolicy = FULL,
) -> Tuple[jnp.ndarray, Dict]:
    """One chunked-prefill serve step: consume up to K pending tokens per
    slot in a single fused pass, writing their KVs / SSD state into the
    cache, and return the logits at each slot's *last valid* token.

    Returns (logits (B, V) f32, new cache).  Slots with ``n_valid == 0``
    are untouched (no writes, clock unchanged); slots with ``n_valid == 1``
    behave exactly like one ``lm_decode_step`` tick.  This is the serve
    engine's throughput win: prompts cost ceil(len/K) ticks instead of
    len ticks, and the K-token projections/FFNs run as one GEMM.
    """
    dtype = policy.at("lm/dense").compute_dtype
    router_dtype = policy.at("lm/router").compute_dtype
    head_dtype = policy.at("lm/proj_out").compute_dtype
    B, K = tokens.shape
    pos0 = cache["step"]                                  # (B,)
    j = jnp.arange(K)
    q_pos = pos0[:, None] + j[None, :]                    # (B, K)
    valid = j[None, :] < n_valid[:, None]                 # (B, K)

    h = params["embed"][tokens].astype(dtype)             # (B, K, d)
    h = jnp.where(valid[..., None], h, 0)                 # padding rows inert
    windows = layer_windows(cfg)

    layer_cache_keys = [k for k in cache if k not in ("step",)]
    xs_cache = {k: cache[k] for k in layer_cache_keys}
    if "kv_pos" in cache:
        W = cache["kv_pos"].shape[-1]
        # ring row per chunk token; W (out of bounds) drops padding writes
        write_slot = jnp.where(valid, jnp.mod(q_pos, W), W)
    else:
        write_slot = None

    def block(h, layer_in):
        lp, window, lc = layer_in
        hn = rmsnorm(h, lp["ln1"], cfg.norm_eps)
        new_lc = dict(lc)
        if cfg.mixer == "attn":
            mix, upd = _attn_prefill_chunk(lp["attn"], hn, lc, q_pos,
                                           write_slot, window, cfg, dtype)
            new_lc.update(upd)
        elif cfg.mixer == "ssd":
            mix, new_state = _ssd_prefill_chunk(lp["ssd"], hn, lc["ssd_state"],
                                                valid, cfg, policy)
            new_lc["ssd_state"] = new_state
        else:
            a, upd = _attn_prefill_chunk(lp["attn"], hn, lc, q_pos,
                                         write_slot, window, cfg, dtype)
            s, new_state = _ssd_prefill_chunk(lp["ssd"], hn, lc["ssd_state"],
                                              valid, cfg, policy)
            mix = 0.5 * (a + s)
            new_lc.update(upd)
            new_lc["ssd_state"] = new_state
        h = h + mix
        hn = rmsnorm(h, lp["ln2"], cfg.norm_eps)
        if "ffn" in lp:
            if cfg.moe_experts:
                f, _ = moe_apply(lp["ffn"], hn.reshape(B * K, -1), cfg.moe_top_k,
                                 cfg.capacity_factor, dtype,
                                 router_dtype=router_dtype)
                f = f.reshape(B, K, -1)
            else:
                f = swiglu(lp["ffn"], hn, dtype)
            h = h + f
        return h, new_lc

    h, new_xs = jax.lax.scan(block, h, (params["layers"], windows, xs_cache))
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    # only each slot's last valid position seeds generation
    last = jnp.clip(n_valid - 1, 0, K - 1)
    h_last = h[jnp.arange(B), last]                       # (B, d)
    unembed = params.get("unembed", params["embed"])
    logits = jnp.einsum("bd,vd->bv", h_last.astype(head_dtype),
                        unembed.astype(head_dtype))
    new_cache = dict(new_xs)
    new_cache["step"] = pos0 + n_valid
    return logits, new_cache
