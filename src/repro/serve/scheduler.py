"""Admission scheduler shared by every serve engine.

One waiting queue + a pluggable admission policy, generic over request
types: engines hand in a ``cost`` function (prompt length for the LM
engine, grid points for the operator engine) and a ``capacity_check``
that rejects requests which could *never* run — oversized requests fail
fast at submit instead of spinning the engine's drain loop forever
(the old ``ServeEngine.admit`` silently accepted prompts that overran
the KV cache).

Policies:
  fcfs  first-come-first-served (arrival order).
  spf   shortest-prompt-first: order by ``cost`` (ties arrival order) —
        the latency-optimising policy for heavy-tailed prompt lengths.

The scheduler also owns per-tick queue accounting (wait ticks, depth,
admit/reject counters) that ``Engine.stats()`` reports.
"""
from __future__ import annotations

from typing import Any, Callable, List, Optional, Tuple

POLICIES = ("fcfs", "spf")


class Scheduler:
    def __init__(
        self,
        policy: str = "fcfs",
        capacity_check: Optional[Callable[[Any], Tuple[bool, str]]] = None,
        cost: Optional[Callable[[Any], float]] = None,
    ):
        if policy not in POLICIES:
            raise ValueError(f"unknown scheduler policy {policy!r}; have {POLICIES}")
        self.policy = policy
        self.capacity_check = capacity_check
        self.cost = cost or (lambda _req: 0.0)
        self.waiting: List[Any] = []
        self.rejected: List[Any] = []
        self.n_submitted = 0
        self.n_admitted = 0
        self.wait_ticks_total = 0

    # -- submit ----------------------------------------------------------------
    def submit(self, req, tick: int = 0) -> bool:
        """Queue a request, or fail it immediately if it exceeds capacity.
        Rejected requests get ``status='failed'`` + ``error`` and are
        surfaced through ``take_failed`` / the engine's drain."""
        self.n_submitted += 1
        if self.capacity_check is not None:
            ok, reason = self.capacity_check(req)
            if not ok:
                req.status = "failed"
                req.error = reason
                self.rejected.append(req)
                return False
        req.status = "queued"
        req.submit_tick = tick
        self.waiting.append(req)
        return True

    # -- admission -------------------------------------------------------------
    def _ordered(self) -> List[Any]:
        if self.policy == "spf":
            # python sort is stable => ties stay in arrival order
            return sorted(self.waiting, key=self.cost)
        return list(self.waiting)

    def take(self, n: int, tick: int = 0,
             bucket_key: Optional[Callable[[Any], Any]] = None) -> List[Any]:
        """Admit up to ``n`` requests in policy order.

        ``bucket_key`` restricts the batch to requests sharing the
        policy-order head's bucket (the operator engine's
        same-resolution micro-batching); ``None`` admits across buckets.
        """
        order = self._ordered()
        if not order or n <= 0:
            return []
        head_bucket = bucket_key(order[0]) if bucket_key else None
        picked = []
        for req in order:
            if len(picked) >= n:
                break
            if bucket_key is not None and bucket_key(req) != head_bucket:
                continue
            picked.append(req)
        picked_ids = {id(r) for r in picked}
        self.waiting = [r for r in self.waiting if id(r) not in picked_ids]
        for req in picked:
            req.status = "running"
            req.start_tick = tick
            self.wait_ticks_total += tick - req.submit_tick
            self.n_admitted += 1
        return picked

    def take_failed(self) -> List[Any]:
        """Pop every capacity-rejected request (drain surfaces these)."""
        failed, self.rejected = self.rejected, []
        return failed

    # -- accounting ------------------------------------------------------------
    @property
    def depth(self) -> int:
        return len(self.waiting)

    def stats(self) -> dict:
        n_rej = self.n_submitted - self.n_admitted - self.depth
        return {
            "policy": self.policy,
            "depth": self.depth,
            "submitted": self.n_submitted,
            "admitted": self.n_admitted,
            "rejected": n_rej,
            "wait_ticks_total": self.wait_ticks_total,
            "avg_wait_ticks": (
                self.wait_ticks_total / self.n_admitted if self.n_admitted else 0.0
            ),
        }
