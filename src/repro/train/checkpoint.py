"""Checkpointing: atomic, async-capable, elastic-restorable.

Design for the 1000+ node regime (DESIGN.md §4):
  * **atomic**: write to ``<dir>/tmp.<step>`` then ``os.rename`` — a
    preempted save never corrupts the latest checkpoint;
  * **async**: ``save_async`` snapshots to host memory (device_get) on the
    caller's thread — cheap — and writes to disk on a background thread,
    overlapping I/O with the next training steps;
  * **elastic**: leaves are stored as *full* (unsharded) arrays plus a
    step/metadata manifest, so ``restore`` can re-shard onto any mesh
    (different device count after failures) by ``device_put`` with the new
    NamedSharding.  At true 100B+ scale one would write per-shard files;
    the manifest format has a ``shards`` field reserved for that extension.
  * **keep_last_k** garbage collection.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


_SEP = "|"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(p) for p in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out, treedef


def save(ckpt_dir: str, step: int, tree: Any, keep_last_k: int = 3) -> str:
    """Synchronous atomic save. Returns the checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    arrays, _ = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "format": 1, "shards": None}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep_last_k)
    return final


class AsyncCheckpointer:
    """Snapshot on the training thread, write on a background thread."""

    def __init__(self, ckpt_dir: str, keep_last_k: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep_last_k = keep_last_k
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any):
        self.wait()  # one outstanding save at a time
        arrays, _ = _flatten(tree)  # device->host here, on caller thread

        def _write():
            os.makedirs(self.ckpt_dir, exist_ok=True)
            tmp = os.path.join(self.ckpt_dir, f"tmp.{step}")
            final = os.path.join(self.ckpt_dir, f"step_{step:010d}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump({"step": step, "format": 1, "shards": None}, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            _gc(self.ckpt_dir, self.keep_last_k)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and os.path.isdir(os.path.join(ckpt_dir, d))
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, target: Any, step: Optional[int] = None, shardings: Any = None):
    """Restore into the structure of ``target``.

    ``shardings``: optional pytree (same structure) of NamedSharding — the
    elastic path: arrays are device_put with the *current* mesh's sharding
    regardless of how many devices wrote the checkpoint.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    flat, treedef = jax.tree_util.tree_flatten_with_path(target)
    shard_flat = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else [None] * len(flat)
    )
    leaves = []
    for (key_path, leaf), shard in zip(flat, shard_flat, strict=True):
        key = _SEP.join(str(p) for p in key_path)
        arr = data[key]
        if shard is not None:
            leaves.append(jax.device_put(arr, shard))
        else:
            leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype if hasattr(leaf, "dtype") else None))
    return jax.tree_util.tree_unflatten(treedef, [l for l in leaves]), step


def _gc(ckpt_dir: str, keep_last_k: int):
    dirs = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and os.path.isdir(os.path.join(ckpt_dir, d))
    )
    for d in dirs[:-keep_last_k]:
        shutil.rmtree(os.path.join(ckpt_dir, d))
