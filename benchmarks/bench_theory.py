"""Fig. 7 reproduction: measured discretisation/precision error vs the
Thm 3.1 / Thm 3.2 closed-form bounds on Darcy-like random fields.

This is the benchmark ``repro.core.theory``'s docstring promises.  It
reuses the certification harness (:mod:`repro.autoprec.certify`): a
smooth random Fourier field with *analytic* sup-norm and Lipschitz
bounds stands in for the paper's Darcy fields, so the bounds are
evaluated with their true constants rather than estimates.

Per mesh size ``m`` (n = m^d lattice points):
  * measured disc error (Eq. 1, reference integral on an 8x finer grid)
    against ``c2 √d (M|ω|+L) n^{-1/d}`` (upper) and the ``n^{-2/d}``
    lower rate;
  * measured precision error (Eq. 2) per format — fp16 via the real
    numpy cast, bf16/fp8 via the (a0, ε, T)-system quantiser — against
    ``4 ε M``, which is mesh-independent: the paper's crossover argument
    in one table.

    PYTHONPATH=src python -m benchmarks.bench_theory [--d 2]

Results land in ``benchmarks/results/theory_fig7.json``.
"""
from __future__ import annotations

import argparse
import os

from repro.autoprec.certify import theory_rows
from repro.core import theory

RESULTS = os.path.join(os.path.dirname(__file__), "results",
                       "theory_fig7.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--d", type=int, default=2)
    ap.add_argument("--omega", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--m", type=int, nargs="*", default=[6, 10, 16, 24])
    args = ap.parse_args()

    rows = theory_rows(seed=args.seed, d=args.d,
                       m_values=tuple(args.m), omega=args.omega)

    print(f"== bench_theory (d={args.d}, omega={args.omega}) ==")
    print(f"{'m':>4s} {'n':>7s} {'disc meas':>11s} {'disc upper':>11s} "
          f"{'fp16 prec':>11s} {'4εM fp16':>11s} {'bf16 prec':>11s}")
    violations = 0
    for r in rows:
        fp16, bf16 = r["prec"]["float16"], r["prec"]["bfloat16"]
        print(f"{r['m']:>4d} {r['n']:>7d} {r['disc_measured']:>11.3e} "
              f"{r['disc_upper']:>11.3e} {fp16['measured']:>11.3e} "
              f"{fp16['upper']:>11.3e} {bf16['measured']:>11.3e}")
        if r["disc_measured"] > r["disc_upper"]:
            violations += 1
        for p in r["prec"].values():
            if p["measured"] > p["upper"]:
                violations += 1

    # the paper's asymptotic claim: disc error shrinks with n, prec
    # error does not — beyond the crossover, half precision is "free".
    # Measured per-m errors can wiggle, so check the sweep endpoints.
    disc_monotone = rows[-1]["disc_measured"] < rows[0]["disc_measured"]
    crossover = theory.crossover_mesh_size(
        eps=2.0 ** -11, d=args.d, omega=args.omega)
    report = {
        "d": args.d,
        "omega": args.omega,
        "rows": rows,
        "bound_violations": violations,
        "disc_shrinks_with_n": disc_monotone,
        "crossover_mesh_size_fp16": crossover,
    }
    from benchmarks.common import write_result

    write_result(RESULTS, report)
    print(f"bound violations: {violations}  "
          f"(crossover n* for fp16, d={args.d}: {crossover:.3e})")
    print(f"results -> {RESULTS}")
    return 1 if violations else 0


if __name__ == "__main__":
    raise SystemExit(main())
