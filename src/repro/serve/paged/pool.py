"""Fixed-size KV block allocator: free list + per-block refcounts.

The pool is pure host-side bookkeeping — it never touches device memory.
Device block arrays are ``(L, num_blocks, block_size, ...)``; one
physical block id indexes every layer at once, so "a block" here is one
integer and the engine translates pool decisions into batched device
updates (kv_pos invalidation on allocation, block copies on COW).

Ownership model:

  * refcount == number of logical owners (slot table entries + prefix
    index entries).  ``alloc`` hands out refcount-1 blocks; ``fork``
    adds an owner (prefix sharing); ``release`` drops one and returns
    the block to the free list at zero.
  * block 0 is the *null block*: unallocated block-table entries map to
    it on device so gathers stay in bounds.  It is never allocated,
    forked or released — its ``kv_pos`` stays -1 forever.
  * copy-on-write is a two-step owned by the engine: ``cow`` re-homes
    one owner of a shared block onto a fresh block and reports the
    (src, dst) pair; the engine then issues the device copy.  A block
    with refcount > 1 is never written in place.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

#: Reserved null block id (device alias for "unallocated").
NULL_BLOCK = 0


class BlockPool:
    """Allocator over ``num_blocks`` fixed-size KV blocks (id 0 reserved)."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError(f"need >= 2 blocks (one is the reserved null "
                             f"block), got {num_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # LIFO free list: recently freed blocks are re-used first, which
        # keeps the working set of touched blocks small
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._ref: List[int] = [0] * num_blocks
        self._ref[NULL_BLOCK] = 1     # permanently owned by the pool
        self.allocs = 0
        self.frees = 0
        self.cow_copies = 0

    # -- primitives ----------------------------------------------------------
    def alloc(self) -> Optional[int]:
        """A fresh block with refcount 1, or None when exhausted."""
        if not self._free:
            return None
        b = self._free.pop()
        assert self._ref[b] == 0, f"free-list block {b} had refcount {self._ref[b]}"
        self._ref[b] = 1
        self.allocs += 1
        return b

    def fork(self, block: int) -> int:
        """Add an owner to a live block (prefix sharing)."""
        self._check_live(block)
        self._ref[block] += 1
        return block

    def release(self, block: int) -> bool:
        """Drop one owner; True if the block returned to the free list."""
        self._check_live(block)
        self._ref[block] -= 1
        if self._ref[block] == 0:
            self._free.append(block)
            self.frees += 1
            return True
        return False

    def cow(self, block: int) -> Tuple[int, Optional[Tuple[int, int]]]:
        """Resolve exclusive ownership of ``block`` before a write.

        refcount == 1: already exclusive — returns (block, None).
        refcount > 1: re-homes this owner onto a fresh block, returns
        (dst, (src, dst)) so the engine can issue the device copy.
        Raises RuntimeError when the pool is exhausted (the engine runs
        prefix-index eviction and retries before letting that escape).
        """
        self._check_live(block)
        if self._ref[block] == 1:
            return block, None
        dst = self.alloc()
        if dst is None:
            raise RuntimeError("BlockPool exhausted during copy-on-write")
        self._ref[block] -= 1     # this owner moves to dst
        self.cow_copies += 1
        return dst, (block, dst)

    def _check_live(self, block: int) -> None:
        if not (0 < block < self.num_blocks):
            raise ValueError(f"invalid block id {block} "
                             f"(null block 0 is never owned)")
        if self._ref[block] <= 0:
            raise ValueError(f"block {block} is not allocated")

    # -- introspection -------------------------------------------------------
    def refcount(self, block: int) -> int:
        return self._ref[block]

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def live_blocks(self) -> int:
        """Allocated blocks, excluding the reserved null block."""
        return (self.num_blocks - 1) - len(self._free)

    @property
    def occupancy(self) -> float:
        return self.live_blocks / max(self.num_blocks - 1, 1)

    def stats(self) -> Dict[str, float]:
        return {
            "num_blocks": self.num_blocks,
            "block_size": self.block_size,
            "live_blocks": self.live_blocks,
            "free_blocks": self.free_blocks,
            "occupancy": round(self.occupancy, 4),
            "allocs": self.allocs,
            "frees": self.frees,
            "cow_copies": self.cow_copies,
        }
