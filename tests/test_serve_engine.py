"""repro.serve v2 tests: scheduler admission, chunked batched prefill,
sampling, and the operator (FNO/SFNO) engine."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.fno_paper import FNO_DARCY_SMOKE, SFNO_SWE_SMOKE
from repro.core import get_policy
from repro.models import fno_infer, init_fno, init_sfno
from repro.models.lm import init_lm, lm_forward
from repro.serve import (
    FieldRequest,
    LMEngine,
    OperatorEngine,
    Request,
    SamplingParams,
    Scheduler,
    sample_token,
)

jax.config.update("jax_platform_name", "cpu")


def _params(arch, seed=0):
    cfg = get_config(arch, smoke=True)
    return cfg, init_lm(jax.random.PRNGKey(seed), cfg)


def _forward_greedy(params, cfg, prompt, n_new):
    """Straight-line lm_forward greedy decode — the serve ground truth."""
    toks = list(prompt)
    out = []
    for _ in range(n_new):
        logits, _ = lm_forward(params, jnp.asarray([toks]), cfg)
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks.append(nxt)
    return out


class TestSchedulerAdmission:
    def test_oversized_request_fails_at_submit(self):
        """Regression: the old engine silently admitted requests with
        prompt+max_new > max_len, overrunning the KV cache."""
        cfg, params = _params("smollm-360m")
        engine = LMEngine(params, cfg, n_slots=2, max_len=16)
        bad = Request(uid=0, prompt=[1] * 14, max_new_tokens=4)
        assert not engine.submit(bad)
        assert bad.status == "failed"
        assert "max_len" in bad.error

    def test_run_until_done_returns_failed_fast(self):
        """Regression: an unservable request must come back failed
        instead of spinning the drain loop for max_ticks."""
        cfg, params = _params("smollm-360m")
        engine = LMEngine(params, cfg, n_slots=1, max_len=16)
        reqs = [Request(uid=0, prompt=[1] * 20, max_new_tokens=4),
                Request(uid=1, prompt=[1, 2], max_new_tokens=2)]
        done, ticks = engine.run_until_done(reqs, max_ticks=500)
        by_uid = {r.uid: r for r in done}
        assert by_uid[0].status == "failed"
        assert by_uid[1].status == "done"
        assert ticks < 20  # nowhere near max_ticks
        s = engine.stats()
        assert s["failed"] == 1 and s["queue"]["rejected"] == 1

    def test_spf_orders_by_prompt_length(self):
        sched = Scheduler("spf", cost=lambda r: len(r.prompt))
        a = Request(uid=0, prompt=[1] * 8)
        b = Request(uid=1, prompt=[1] * 2)
        c = Request(uid=2, prompt=[1] * 2)
        for r in (a, b, c):
            sched.submit(r, tick=0)
        picked = sched.take(2, tick=3)
        # shortest first; FCFS tie-break keeps b before c
        assert [r.uid for r in picked] == [1, 2]
        assert sched.stats()["wait_ticks_total"] == 6
        assert sched.take(5)[0].uid == 0

    def test_fcfs_preserves_arrival_order(self):
        sched = Scheduler("fcfs", cost=lambda r: len(r.prompt))
        a = Request(uid=0, prompt=[1] * 8)
        b = Request(uid=1, prompt=[1])
        sched.submit(a), sched.submit(b)
        assert [r.uid for r in sched.take(2)] == [0, 1]

    def test_take_uses_identity_not_value_equality(self):
        """Two value-identical requests (or ndarray-payload field
        requests sharing a uid) must dequeue independently."""
        sched = Scheduler("fcfs")
        a = FieldRequest(uid=0, x=np.zeros((1, 4, 4), np.float32))
        b = FieldRequest(uid=0, x=np.zeros((1, 4, 4), np.float32))
        sched.submit(a), sched.submit(b)
        first = sched.take(1)
        assert first == [a] and sched.depth == 1
        assert sched.take(1) == [b] and sched.depth == 0

    def test_moe_archs_default_to_token_by_token_prefill(self):
        """MoE expert-capacity dispatch is batch-composition-dependent,
        so the exactness-preserving auto default is chunk=1 for MoE and
        8 for dense archs (explicit chunks are honoured)."""
        cfg, params = _params("smollm-360m")
        assert LMEngine(params, cfg, max_len=16).prefill_chunk == 8
        mcfg = get_config("granite-moe-3b-a800m", smoke=True)
        mparams = init_lm(jax.random.PRNGKey(0), mcfg)
        assert LMEngine(mparams, mcfg, max_len=16).prefill_chunk == 1
        assert LMEngine(mparams, mcfg, max_len=16,
                        prefill_chunk=4).prefill_chunk == 4


class TestChunkedPrefill:
    @pytest.mark.parametrize("arch", ["smollm-360m", "mamba2-370m"])
    def test_chunk_sizes_agree(self, arch):
        """Chunked prefill must reproduce one-token-per-tick (the old
        engine path) exactly, while taking fewer ticks."""
        cfg, params = _params(arch)
        prompts = [[3, 1, 4, 1, 5, 9, 2, 6], [2, 7], [1] * 12]
        outs, ticks = {}, {}
        for chunk in (1, 4, 8):
            engine = LMEngine(params, cfg, n_slots=2, max_len=32,
                              prefill_chunk=chunk)
            reqs = [Request(uid=i, prompt=list(p), max_new_tokens=3)
                    for i, p in enumerate(prompts)]
            done, t = engine.run_until_done(reqs)
            outs[chunk] = {r.uid: r.generated for r in done}
            ticks[chunk] = t
        assert outs[1] == outs[4] == outs[8]
        assert ticks[8] < ticks[1]

    def test_chunk_agrees_for_mla(self):
        """The MLA (compressed-KV) chunk write/expand path."""
        cfg = get_config("deepseek-v2-lite-16b", smoke=True)
        # drop MoE: capacity dispatch is batch-composition-dependent by
        # design, so only the dense variant pins exact token equality
        cfg = dataclasses.replace(cfg, moe_experts=0, moe_shared=0, d_ff=32)
        params = init_lm(jax.random.PRNGKey(3), cfg)
        outs = {}
        for chunk in (1, 4):
            engine = LMEngine(params, cfg, n_slots=2, max_len=32,
                              prefill_chunk=chunk)
            reqs = [Request(uid=i, prompt=[5, 3, 8, 2, 9, 1][: 3 + i],
                            max_new_tokens=3) for i in range(3)]
            done, _ = engine.run_until_done(reqs)
            outs[chunk] = {r.uid: r.generated for r in done}
        assert outs[1] == outs[4]

    def test_chunk_agrees_for_swa_ring_wrap(self):
        """Hybrid (hymba) SWA ring cache: chunks are clamped so writes
        never wrap rows an in-chunk query still needs; generations must
        match the token-by-token path even when the prompt wraps the
        ring."""
        cfg, params = _params("hymba-1.5b")
        assert cfg.attn_window > 0
        prompt = list(np.random.RandomState(0).randint(1, cfg.vocab,
                                                       cfg.attn_window + 8))
        outs = {}
        for chunk in (1, 16):
            engine = LMEngine(params, cfg, n_slots=1,
                              max_len=cfg.attn_window + 16,
                              prefill_chunk=chunk)
            done, _ = engine.run_until_done(
                [Request(uid=0, prompt=list(prompt), max_new_tokens=3)])
            outs[chunk] = done[0].generated
        assert outs[1] == outs[16]

    @pytest.mark.parametrize("scheduler", ["fcfs", "spf"])
    def test_interleaved_batching_matches_forward(self, scheduler):
        """Continuous-batching invariant: interleaved admit/finish across
        ticks (staggered lengths, slot reuse, mixed prefill/decode ticks)
        produces per-request generations identical to a straight-line
        ``lm_forward`` greedy decode, under both admission policies."""
        cfg, params = _params("smollm-360m", seed=11)
        rng = np.random.RandomState(2)
        reqs = [
            Request(uid=i,
                    prompt=list(rng.randint(1, cfg.vocab, 2 + 3 * (i % 3))),
                    max_new_tokens=2 + (i % 3))
            for i in range(5)
        ]
        ref = {r.uid: _forward_greedy(params, cfg, r.prompt, r.max_new_tokens)
               for r in reqs}
        engine = LMEngine(params, cfg, n_slots=2, max_len=32,
                          scheduler=scheduler, prefill_chunk=4)
        done, _ = engine.run_until_done([dataclasses.replace(r) for r in reqs])
        assert len(done) == len(reqs)
        for r in done:
            assert r.generated == ref[r.uid], f"uid {r.uid} ({scheduler})"


class TestSampler:
    def test_greedy_is_argmax(self):
        logits = jnp.asarray([0.1, 2.0, -1.0, 1.9])
        assert sample_token(logits) == 1
        key = jax.random.PRNGKey(0)
        assert sample_token(logits, SamplingParams(temperature=0.5, top_k=1),
                            key) == 1

    def test_top_p_degenerates_to_greedy(self):
        logits = jnp.asarray([0.0, 5.0, 1.0, 2.0])
        tok = sample_token(logits,
                           SamplingParams(temperature=1.0, top_p=1e-6),
                           jax.random.PRNGKey(3))
        assert tok == 1

    def test_top_k_restricts_support(self):
        logits = jnp.asarray([5.0, 4.9, -10.0, -10.0])
        p = SamplingParams(temperature=2.0, top_k=2)
        toks = {sample_token(logits, p, jax.random.PRNGKey(i))
                for i in range(20)}
        assert toks <= {0, 1}

    def test_invalid_params_rejected(self):
        with pytest.raises(ValueError):
            SamplingParams(top_p=0.0)
        with pytest.raises(ValueError):
            SamplingParams(top_k=-1)
        with pytest.raises(ValueError):
            sample_token(jnp.zeros(4), SamplingParams(temperature=1.0))

    def test_engine_sampling_deterministic_under_fixed_key(self):
        """Same engine seed => identical sampled streams, regardless of
        greedy traffic interleaved in other slots."""
        cfg, params = _params("smollm-360m")
        sampling = SamplingParams(temperature=0.8, top_k=32, top_p=0.95)

        def run(extra_greedy):
            engine = LMEngine(params, cfg, n_slots=2, max_len=32, seed=123)
            reqs = [Request(uid=7, prompt=[4, 2, 9], max_new_tokens=5,
                            sampling=sampling)]
            if extra_greedy:
                reqs.append(Request(uid=1, prompt=[1] * 6, max_new_tokens=4))
            done, _ = engine.run_until_done(reqs)
            return [r.generated for r in done if r.uid == 7][0]

        a, b, c = run(False), run(False), run(True)
        assert a == b == c

        engine = LMEngine(params, cfg, n_slots=2, max_len=32, seed=124)
        done, _ = engine.run_until_done(
            [Request(uid=7, prompt=[4, 2, 9], max_new_tokens=5,
                     sampling=sampling)])
        assert done[0].generated != a  # different seed, different stream


class TestOperatorEngine:
    @pytest.mark.parametrize("policy_name", ["full", "mixed_fno_bf16"])
    def test_batched_matches_solo_bit_identically(self, policy_name):
        """Micro-batching is a pure throughput knob: per-field outputs are
        bit-identical to a single-request run under the same policy
        (padded micro-batches compile one kernel per resolution)."""
        policy = get_policy(policy_name)
        cfg = FNO_DARCY_SMOKE
        params = init_fno(jax.random.PRNGKey(1), cfg)
        rng = np.random.RandomState(0)
        xs = [rng.randn(1, 16, 16).astype(np.float32) for _ in range(5)]

        engine = OperatorEngine(params, cfg, model="fno", policy=policy,
                                max_batch=4)
        reqs = [FieldRequest(uid=i, x=x) for i, x in enumerate(xs)]
        for r in reqs:
            engine.submit(r)
        done, _ = engine.drain()
        assert all(r.status == "done" for r in done)

        for i, x in enumerate(xs):
            solo = OperatorEngine(params, cfg, model="fno", policy=policy,
                                  max_batch=4)
            sr = FieldRequest(uid=0, x=x)
            solo.submit(sr)
            solo.drain()
            assert np.array_equal(sr.y, reqs[i].y)

    def test_engine_output_matches_fno_infer(self):
        """The engine is a scheduler around ``fno_infer``: its output rows
        equal the jitted padded-batch forward."""
        cfg = FNO_DARCY_SMOKE
        policy = get_policy("mixed_fno_bf16")
        params = init_fno(jax.random.PRNGKey(1), cfg)
        rng = np.random.RandomState(3)
        xs = [rng.randn(1, 16, 16).astype(np.float32) for _ in range(4)]
        engine = OperatorEngine(params, cfg, model="fno", policy=policy,
                                max_batch=4)
        reqs = [FieldRequest(uid=i, x=x) for i, x in enumerate(xs)]
        for r in reqs:
            engine.submit(r)
        engine.drain()
        ref = np.asarray(jax.jit(
            lambda p, x: fno_infer(p, x, cfg, policy))(params, jnp.stack(
                [jnp.asarray(x) for x in xs])))
        for i, r in enumerate(reqs):
            assert np.array_equal(r.y, ref[i])

    def test_resolution_buckets_and_stats(self):
        cfg = FNO_DARCY_SMOKE
        params = init_fno(jax.random.PRNGKey(1), cfg)
        engine = OperatorEngine(params, cfg, model="fno", max_batch=4)
        rng = np.random.RandomState(1)
        for i in range(5):
            engine.submit(FieldRequest(uid=i,
                                       x=rng.randn(1, 16, 16).astype(np.float32)))
        for i in range(3):
            engine.submit(FieldRequest(uid=10 + i,
                                       x=rng.randn(1, 24, 24).astype(np.float32)))
        done, ticks = engine.drain()
        assert sum(r.status == "done" for r in done) == 8
        # 16x16 needs two ticks (5 > max_batch), 24x24 one
        assert ticks == 3
        s = engine.stats()
        assert s["buckets"] == {"16x16": 5, "24x24": 3}
        assert s["fields_served"] == 8 and s["batches"] == 3

    def test_malformed_fields_fail_at_submit(self):
        cfg = FNO_DARCY_SMOKE
        params = init_fno(jax.random.PRNGKey(1), cfg)
        engine = OperatorEngine(params, cfg, model="fno", max_batch=2)
        bad_ch = FieldRequest(uid=0, x=np.zeros((3, 16, 16), np.float32))
        bad_nd = FieldRequest(uid=1, x=np.zeros((1, 16, 16, 16), np.float32))
        assert not engine.submit(bad_ch)
        assert not engine.submit(bad_nd)
        assert "channels" in bad_ch.error and "-d" in bad_nd.error

    def test_sfno_engine_serves_fixed_grid(self):
        cfg = SFNO_SWE_SMOKE
        params = init_sfno(jax.random.PRNGKey(2), cfg)
        engine = OperatorEngine(params, cfg, model="sfno", max_batch=2)
        rng = np.random.RandomState(4)
        good = [FieldRequest(uid=i,
                             x=rng.randn(3, cfg.nlat, cfg.nlon).astype(np.float32))
                for i in range(3)]
        bad = FieldRequest(uid=9, x=rng.randn(3, 8, 8).astype(np.float32))
        for r in good:
            engine.submit(r)
        assert not engine.submit(bad)
        done, _ = engine.drain()
        assert sum(r.status == "done" for r in done) == 3
        assert all(r.y.shape == (cfg.out_channels, cfg.nlat, cfg.nlon)
                   for r in good)


class TestBatchedSlotReset:
    def test_multi_admission_single_tick_matches_forward(self):
        """Regression for the batched slot-invalidation path: several
        requests admitted in ONE tick (one indexed cache update covering
        all of them) plus slot reuse mid-flight must still reproduce the
        straight-line forward greedy decode for every request."""
        cfg, params = _params("smollm-360m")
        prompts = [[3, 1, 4], [1, 5, 9, 2, 6], [5, 3, 5], [8, 9, 7, 9],
                   [3, 2, 3, 8, 4, 6]]
        lens = [4, 2, 3, 2, 4]
        engine = LMEngine(params, cfg, n_slots=3, max_len=32,
                          prefill_chunk=4)
        done, _ = engine.run_until_done(
            [Request(uid=u, prompt=p, max_new_tokens=n)
             for u, (p, n) in enumerate(zip(prompts, lens, strict=True))])
        assert all(r.status == "done" for r in done)
        for r in done:
            assert r.generated == _forward_greedy(
                params, cfg, prompts[r.uid], lens[r.uid]), r.uid

    def test_admission_does_not_disturb_running_slots(self):
        """A slot admitted while its neighbour is mid-decode must not
        perturb the neighbour's stream (the indexed reset touches only
        the admitted columns)."""
        cfg, params = _params("smollm-360m")
        engine = LMEngine(params, cfg, n_slots=2, max_len=32,
                          prefill_chunk=4)
        a = Request(uid=0, prompt=[3, 1, 4, 1, 5], max_new_tokens=6)
        b = Request(uid=1, prompt=[9, 2, 6], max_new_tokens=3)
        engine.submit(a)
        for _ in range(3):   # a is mid-generation when b arrives
            engine.tick()
        engine.submit(b)
        engine.drain()
        assert a.generated == _forward_greedy(params, cfg, a.prompt, 6)
        assert b.generated == _forward_greedy(params, cfg, b.prompt, 3)


class TestOperatorMemo:
    def test_memoized_matches_batched_bit_identically(self):
        """The content-hash memo is invisible to results: repeated fields
        (across ticks AND inside one batch) return bit-identical outputs
        while skipping recompute, and the counters say so."""
        cfg = FNO_DARCY_SMOKE
        params = init_fno(jax.random.PRNGKey(1), cfg)
        rng = np.random.RandomState(0)
        xs = [rng.randn(1, 16, 16).astype(np.float32) for _ in range(3)]
        fields = [xs[0], xs[1], xs[0], xs[2], xs[1], xs[0], xs[2], xs[0]]

        plain = OperatorEngine(params, cfg, model="fno", max_batch=4)
        pr = [FieldRequest(uid=i, x=x) for i, x in enumerate(fields)]
        for r in pr:
            plain.submit(r)
        plain.drain()

        memo = OperatorEngine(params, cfg, model="fno", max_batch=4,
                              memo_window=8)
        mr = [FieldRequest(uid=i, x=x) for i, x in enumerate(fields)]
        for r in mr:
            memo.submit(r)
        memo.drain()

        for a, b in zip(pr, mr, strict=True):
            assert a.status == b.status == "done"
            assert np.array_equal(a.y, b.y), a.uid
        st = memo.stats()["memo"]
        assert st == {"window": 8, "entries": 3, "hits": 5, "misses": 3,
                      "hit_rate": 0.625, "evictions": 0}
        # 3 distinct fields => strictly fewer device batches than plain
        assert memo.stats()["batches"] < plain.stats()["batches"]

    def test_memo_lru_eviction(self):
        cfg = FNO_DARCY_SMOKE
        params = init_fno(jax.random.PRNGKey(1), cfg)
        rng = np.random.RandomState(1)
        xs = [rng.randn(1, 16, 16).astype(np.float32) for _ in range(3)]
        engine = OperatorEngine(params, cfg, model="fno", max_batch=1,
                                memo_window=1)
        for i, x in enumerate(xs + [xs[0]]):
            engine.submit(FieldRequest(uid=i, x=x))
        engine.drain()
        st = engine.stats()["memo"]
        # window 1: xs[0] was evicted before it came back => 4 misses
        assert st["misses"] == 4 and st["hits"] == 0
        assert st["evictions"] == 3 and st["entries"] == 1
