"""Serving engine + distribution layer tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config
from repro.dist.sharding import batch_specs, lm_param_specs, pick_spec, replication_report
from repro.launch.steps import build_step, params_shape
from repro.configs.base import SHAPES, cell_is_runnable
from repro.models.lm import init_lm
from repro.serve import LMEngine, Request, ServeEngine

jax.config.update("jax_platform_name", "cpu")


def _fake_mesh(shape=(2, 4), axes=("data", "model")):
    """An abstract mesh over fake devices for spec-only tests."""
    devs = np.empty(shape, dtype=object)

    class _D:  # minimal device stand-in
        def __init__(self, i):
            self.id = i
            self.platform = "cpu"
            self.device_kind = "fake"
    for i in range(shape[0]):
        for j in range(shape[1]):
            devs[i, j] = _D(i * shape[1] + j)
    try:
        return Mesh(devs, axes)
    except Exception:
        pytest.skip("cannot build fake mesh on this jax version")


class TestShardingRules:
    def test_pick_spec_divisibility(self):
        mesh = _fake_mesh()
        # 15 does not divide model=4 -> falls through to replicate
        assert pick_spec((15, 64), mesh, [(("model",), None), ()]) == P()
        assert pick_spec((16, 64), mesh, [(("model",), None), ()]) == P("model", None)

    def test_lm_param_specs_structure(self):
        mesh = _fake_mesh()
        cfg = get_config("smollm-360m", smoke=True)
        p_shape = params_shape(cfg)
        specs = lm_param_specs(p_shape, mesh)
        flatp = jax.tree_util.tree_leaves_with_path(specs,
                                                    is_leaf=lambda x: isinstance(x, P))
        assert len(flatp) == len(jax.tree_util.tree_leaves(p_shape))
        # layer-stacked leaves never shard the leading L axis
        for path, spec in flatp:
            names = [str(p.key) if hasattr(p, "key") else str(p) for p in path]
            if "layers" in names and len(spec) > 0:
                assert spec[0] is None

    def test_replication_report_counts(self):
        mesh = _fake_mesh()
        cfg = get_config("smollm-360m", smoke=True)
        p_shape = params_shape(cfg)
        specs = lm_param_specs(p_shape, mesh)
        rep = replication_report(p_shape, specs)
        assert rep["sharded_bytes"] > 0

    def test_batch_specs_dp(self):
        mesh = _fake_mesh()
        batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
        specs = batch_specs(batch, mesh)
        assert specs["tokens"][0] in ("data", ("data",))

    def test_cell_runnability_rules(self):
        dense = get_config("smollm-360m")
        ssm = get_config("mamba2-370m")
        ok, _ = cell_is_runnable(dense, SHAPES["long_500k"])
        assert not ok  # full attention skips 500k decode
        ok, _ = cell_is_runnable(ssm, SHAPES["long_500k"])
        assert ok

    def test_step_bundles_build_for_all_kinds(self):
        cfg = get_config("smollm-360m", smoke=True)
        for shape_name in ("train_4k", "prefill_32k", "decode_32k"):
            import dataclasses
            shape = dataclasses.replace(SHAPES[shape_name], seq_len=64, global_batch=2)
            b = build_step(cfg, shape)
            assert b.params_shape is not None


class TestServeEngine:
    def test_serve_engine_is_lm_engine(self):
        """Back-compat: the pre-v2 name resolves to the v2 engine."""
        assert ServeEngine is LMEngine

    def test_serves_all_requests(self):
        cfg = get_config("smollm-360m", smoke=True)
        params = init_lm(jax.random.PRNGKey(0), cfg)
        engine = LMEngine(params, cfg, n_slots=2, max_len=32)
        reqs = [Request(uid=i, prompt=[1, 2, 3], max_new_tokens=4) for i in range(5)]
        done, ticks = engine.run_until_done(reqs)
        assert len(done) == 5
        assert all(len(r.generated) == 4 for r in done)

    def test_continuous_batching_isolation(self):
        """A request admitted into a freed slot must produce the same output
        as the same request served alone (cache-reset correctness)."""
        cfg = get_config("smollm-360m", smoke=True)
        params = init_lm(jax.random.PRNGKey(1), cfg)
        prompt = [5, 7, 9]

        solo = LMEngine(params, cfg, n_slots=1, max_len=32)
        (d1,), _ = solo.run_until_done([Request(uid=0, prompt=prompt, max_new_tokens=4)])

        crowded = LMEngine(params, cfg, n_slots=1, max_len=32)
        reqs = [Request(uid=0, prompt=[2, 4], max_new_tokens=3),
                Request(uid=1, prompt=prompt, max_new_tokens=4)]
        done, _ = crowded.run_until_done(reqs)
        d2 = [r for r in done if r.uid == 1][0]
        assert d1.generated == d2.generated

    def test_ssm_engine(self):
        cfg = get_config("mamba2-370m", smoke=True)
        params = init_lm(jax.random.PRNGKey(2), cfg)
        engine = LMEngine(params, cfg, n_slots=2, max_len=32)
        done, _ = engine.run_until_done(
            [Request(uid=0, prompt=[1, 2], max_new_tokens=3)])
        assert len(done) == 1 and len(done[0].generated) == 3

    def test_engine_matches_forward_greedy_decode(self):
        """Regression for the final-prompt-token double-feed: the engine's
        greedy output must equal a straight-line ``lm_forward`` greedy
        decode.  Before the fix, the logits of the step consuming the last
        prompt token were discarded and ``prompt[-1]`` was fed again, so
        the first generated token came from a skewed cache position."""
        from repro.models.lm import lm_forward

        cfg = get_config("smollm-360m", smoke=True)
        params = init_lm(jax.random.PRNGKey(7), cfg)
        prompt = [3, 1, 4, 1, 5]
        n_new = 5

        toks = list(prompt)
        ref = []
        for _ in range(n_new):
            logits, _ = lm_forward(params, jnp.asarray([toks]), cfg)
            nxt = int(jnp.argmax(logits[0, -1]))
            ref.append(nxt)
            toks.append(nxt)

        engine = LMEngine(params, cfg, n_slots=1, max_len=64)
        done, _ = engine.run_until_done(
            [Request(uid=0, prompt=prompt, max_new_tokens=n_new)])
        assert done[0].generated == ref
