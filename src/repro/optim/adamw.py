"""AdamW with f32 master weights, built from scratch (no optax on box).

Mixed-precision contract (Micikevicius et al. 2017, the substrate the
paper's recipe sits on): parameters and optimizer moments stay f32;
gradients arrive possibly in half (after the compressed DP all-reduce,
``optim.grad_comm``) and are upcast before the moment update.

ZeRO-style state sharding: the moment tensors inherit the parameters'
NamedSharding but can additionally be sharded over the ``data`` axis via
``dist.sharding.zero_shard_rules`` — wired up in launch/dryrun.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jnp.ndarray  # scalar int32
    mu: Any             # first moments (pytree like params)
    nu: Any             # second moments


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 1e-4
    grad_clip_norm: Optional[float] = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamWState(
            count=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(zeros, params),
            nu=jax.tree_util.tree_map(zeros, params),
        )

    def update(self, grads, state: AdamWState, params, lr_scale=1.0):
        """Returns (new_params, new_state).  grads may be half precision."""
        grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        if self.grad_clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.grad_clip_norm / (gnorm + 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

        count = state.count + 1
        b1, b2 = self.b1, self.b2
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads
        )
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * (g * g), state.nu, grads
        )
        c = count.astype(jnp.float32)
        mu_hat_scale = 1.0 / (1 - b1 ** c)
        nu_hat_scale = 1.0 / (1 - b2 ** c)
        lr = self.lr * lr_scale

        def step(p, m, v):
            upd = (m * mu_hat_scale) / (jnp.sqrt(v * nu_hat_scale) + self.eps)
            return (p - lr * (upd + self.weight_decay * p)).astype(p.dtype)

        new_params = jax.tree_util.tree_map(step, params, mu, nu)
        return new_params, AdamWState(count=count, mu=mu, nu=nu)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def all_finite(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.all(
        jnp.stack([jnp.all(jnp.isfinite(x.astype(jnp.float32))) for x in leaves])
    )
