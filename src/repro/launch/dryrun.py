import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture × input shape) cell, on the single-pod 16×16 mesh
and the 2×16×16 multi-pod mesh:

    with mesh:
        lowered  = jax.jit(step, in_shardings=…, out_shardings=…).lower(…)
        compiled = lowered.compile()
        print(compiled.memory_analysis())   # proves it fits
        print(compiled.cost_analysis())     # FLOPs/bytes for §Roofline

Results append to benchmarks/results/dryrun.json so interrupted sweeps
resume.  Failures here (sharding mismatch, OOM at compile, unsupported
collective) are bugs in the system — not acceptable skips.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, cell_is_runnable, get_config
from repro.dist import use_mesh
from repro.dist.sharding import lm_param_specs, replication_report
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze_counts, model_flops, parse_hlo
from repro.launch.steps import build_prefill_chunk_step, build_step, bundle_shardings

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "results", "dryrun.json")


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             policy_name: str = "amp_bf16", verbose: bool = True,
             prefill_chunk: int = 0, telemetry: bool = False) -> dict:
    from repro.core import get_policy
    from repro.precision import describe

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "policy": policy_name,
           # resolved site table: the record says exactly which sites this
           # cell lowered at which formats, not just a policy name
           "policy_sites": describe(get_policy(policy_name))}

    ok, reason = cell_is_runnable(cfg, shape)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    bundle = build_step(cfg, shape, get_policy(policy_name))
    param_specs = lm_param_specs(bundle.params_shape, mesh)
    in_sh, out_sh = bundle_shardings(bundle, cfg, mesh, param_specs)

    if shape.kind == "train":
        lower_args = (bundle.params_shape, bundle.extra_state_shape["opt_state"],
                      bundle.inputs["batch"])
    elif shape.kind == "prefill":
        lower_args = (bundle.params_shape, bundle.inputs["batch"])
    else:  # decode
        lower_args = (bundle.params_shape, bundle.inputs["cache"],
                      bundle.inputs["tokens"])

    with use_mesh(mesh):
        jitted = jax.jit(bundle.step_fn, in_shardings=in_sh, out_shardings=out_sh)
        compiled = jitted.lower(*lower_args).compile()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    counts = parse_hlo(hlo)   # trip-count-aware FLOPs/bytes/collectives
    n_dev = mesh.devices.size
    roof = analyze_counts(counts, n_dev)

    # MODEL_FLOPS (6·N·D) vs compiled useful-compute ratio
    if shape.kind == "train":
        tokens = shape.global_batch * (cfg.max_dec_len if cfg.encoder_decoder
                                       else shape.seq_len)
        mf = model_flops(cfg.active_params_approx(), tokens)  # 6ND = fwd+bwd
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mf = 2.0 * cfg.active_params_approx() * tokens
    else:
        tokens = shape.global_batch  # one token per slot
        mf = 2.0 * cfg.active_params_approx() * tokens

    global_flops = roof.flops_per_device * n_dev
    rec.update({
        "status": "ok",
        "compile_s": round(time.time() - t0, 1),
        "n_devices": n_dev,
        "memory_analysis": {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "cost_analysis_raw": {k: cost.get(k) for k in
                          ("flops", "bytes accessed", "transcendentals")
                          if k in cost},
        "collective_bytes_by_kind": counts.collective_by_kind,
        "roofline": roof.to_dict(),
        "model_flops_6nd": mf,
        "useful_flops_ratio": (mf / global_flops) if global_flops else None,
        "replication": replication_report(bundle.params_shape, param_specs),
    })
    if shape.kind == "train" and telemetry:
        # lower the autoprec-instrumented twin of the train step (taps
        # collected as a functional carry) and record its relative cost
        from repro.launch.steps import build_train_step

        t1 = time.time()
        tb = build_train_step(cfg, shape, get_policy(policy_name),
                              telemetry=True)
        t_in, t_out = bundle_shardings(tb, cfg, mesh, param_specs)
        with use_mesh(mesh):
            t_compiled = jax.jit(tb.step_fn, in_shardings=t_in,
                                 out_shardings=t_out).lower(
                tb.params_shape, tb.extra_state_shape["opt_state"],
                tb.inputs["batch"]).compile()
        t_roof = analyze_counts(parse_hlo(t_compiled.as_text()), n_dev)
        rec["telemetry"] = {
            "compile_s": round(time.time() - t1, 1),
            "roofline": t_roof.to_dict(),
            "overhead": telemetry_overhead(roof, t_roof),
        }

    if shape.kind == "decode" and prefill_chunk > 0 and not cfg.encoder_decoder:
        # also lower the serve engine's chunked-prefill step against the
        # same cache, so the record shows what chunking buys: the chunk
        # step moves K tokens of weights-reads per tick instead of 1.
        t1 = time.time()
        cb = build_prefill_chunk_step(cfg, shape, get_policy(policy_name),
                                      chunk=prefill_chunk)
        c_in, c_out = bundle_shardings(cb, cfg, mesh, param_specs)
        with use_mesh(mesh):
            c_compiled = jax.jit(cb.step_fn, in_shardings=c_in,
                                 out_shardings=c_out).lower(
                cb.params_shape, cb.inputs["cache"], cb.inputs["tokens"],
                cb.inputs["n_valid"]).compile()
        c_counts = parse_hlo(c_compiled.as_text())
        rec["prefill_chunk"] = {
            "chunk": prefill_chunk,
            "compile_s": round(time.time() - t1, 1),
            "roofline": analyze_counts(c_counts, n_dev).to_dict(),
            "collective_bytes_by_kind": c_counts.collective_by_kind,
        }

    if verbose:
        print(f"== {bundle.description} on {mesh_name} ==")
        print("memory_analysis:", rec["memory_analysis"])
        print("cost_analysis (raw, loop bodies once):", rec["cost_analysis_raw"])
        print("collectives:", counts.collective_by_kind)
        print("roofline:", json.dumps(rec["roofline"], indent=2))
        if "telemetry" in rec:
            print("telemetry overhead:", rec["telemetry"]["overhead"])
        if "prefill_chunk" in rec:
            print("prefill_chunk roofline:",
                  json.dumps(rec["prefill_chunk"]["roofline"], indent=2))
    return rec


def telemetry_overhead(plain, instrumented) -> dict:
    """Relative cost of a telemetry-instrumented step vs its plain twin
    (per-device flops/bytes from the compiled rooflines).  Both dry-runs
    record this so the autoprec overhead budget (<10% of step cost) is
    visible at lowering time, before a single real step runs."""

    def rel(a, b):
        return round(b / a - 1.0, 6) if a else None

    return {
        "flops_overhead": rel(plain.flops_per_device,
                              instrumented.flops_per_device),
        "bytes_overhead": rel(plain.bytes_per_device,
                              instrumented.bytes_per_device),
    }


def load_results(path=RESULTS):
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return []


def save_result(rec: dict, path=RESULTS):
    from repro.obs import result_header, write_json_atomic

    results = load_results(path)
    results = [r for r in results
               if not (r["arch"] == rec["arch"] and r["shape"] == rec["shape"]
                       and r["mesh"] == rec["mesh"] and r.get("policy") == rec.get("policy"))]
    # the file stays a flat record list (roofline_report iterates it);
    # the shared metadata header rides on each appended record instead
    rec = {**rec, "meta": result_header()}
    results.append(rec)
    write_json_atomic(path, results)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--policy", default="amp_bf16")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="also lower the chunked-prefill serve step for "
                         "decode cells at this chunk size (0 = off)")
    ap.add_argument("--telemetry", action="store_true",
                    help="also lower the autoprec-instrumented train step "
                         "for train cells and record the telemetry overhead")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    done = {(r["arch"], r["shape"], r["mesh"], r.get("policy")) for r in load_results()
            if r.get("status") in ("ok", "skipped")} if args.skip_done else set()

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                if (arch, shape, mesh_name, args.policy) in done:
                    print(f"-- {arch} {shape} {mesh_name}: already done")
                    continue
                try:
                    rec = run_cell(arch, shape, mp, args.policy,
                                   prefill_chunk=args.prefill_chunk,
                                   telemetry=args.telemetry)
                except Exception as e:  # a failure here is a bug
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "policy": args.policy,
                           "status": "FAILED", "error": f"{type(e).__name__}: {e}"}
                    failures.append(rec)
                save_result(rec)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f["arch"], f["shape"], f["mesh"], f["error"][:120])
        raise SystemExit(1)
    print("\nall requested cells passed")


if __name__ == "__main__":
    main()
