"""End-to-end driver: train a mixed-precision FNO on Darcy flow.

Generates the dataset with the in-repo CG solver, trains with the paper's
precision schedule (25% mixed / 50% AMP / 25% full), dynamic loss scaling
where fp16 is involved, checkpoints/restarts, and evaluates zero-shot
super-resolution — the full Table 1 protocol at CPU scale.

    PYTHONPATH=src python examples/train_darcy.py [--steps 60] [--n 32]
"""
import argparse
import tempfile

import jax
import numpy as np

from repro.core import FULL, PrecisionSchedule, get_policy
from repro.data import sample_darcy_batch
from repro.models import FNOConfig, fno_apply, init_fno
from repro.optim import AdamW
from repro.precision import FULL_PRECISION, precision_rules
from repro.train import Trainer, TrainerConfig, relative_l2


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--n", type=int, default=32)
    ap.add_argument("--half", default="bf16", choices=["bf16", "fp16"])
    ap.add_argument("--auto-precision", action="store_true",
                    help="replace the static 25/50/25 schedule with the "
                         "telemetry-driven controller: per-site formats "
                         "follow runtime amax/overflow counters plus the "
                         "Thm 3.1/3.2 budgets")
    ap.add_argument("--calibration-state", default=None,
                    help="repro.tune calibration-state JSON: spectral "
                         "tile resolution serves validated tuned tiles "
                         "instead of the static heuristic (default: "
                         "$REPRO_CALIBRATION_STATE if set)")
    ap.add_argument("--obs-trace", default=None, metavar="OUT_JSONL",
                    help="enable repro.obs and write the run timeline + "
                         "metrics snapshot as JSONL, plus a Perfetto-"
                         "loadable <stem>.trace.json and a Prometheus "
                         "<stem>.prom next to it; inspect with "
                         "`python -m repro.obs summary OUT_JSONL`")
    args = ap.parse_args()

    print("generating Darcy data (CG solver)...")
    a_tr, u_tr = sample_darcy_batch(jax.random.PRNGKey(0), args.n, 64, maxiter=400)
    a_te, u_te = sample_darcy_batch(jax.random.PRNGKey(1), args.n, 16, maxiter=400)
    a_hi, u_hi = sample_darcy_batch(jax.random.PRNGKey(2), args.n * 2, 8, maxiter=800)

    cfg = FNOConfig(in_channels=1, out_channels=1, hidden_channels=24,
                    lifting_channels=24, projection_channels=24,
                    n_layers=3, modes=(8, 8))
    params = init_fno(jax.random.PRNGKey(3), cfg)

    def loss_fn(p, batch, policy):
        pred = fno_apply(p, batch["a"], cfg, policy)
        return relative_l2(pred, batch["u"])

    def batch_fn(step):
        idx = np.random.RandomState(step).randint(0, a_tr.shape[0], 16)
        return {"a": a_tr[idx], "u": u_tr[idx]}

    with tempfile.TemporaryDirectory() as ckpt_dir:
        if args.auto_precision:
            # auto mode: telemetry taps measure per-site numerics inside
            # the jitted step; the controller demotes spectral sites only
            # while the Thm 3.2 budget stays inside the discretisation
            # error at this grid, and promotes back on overflow streaks
            from repro.autoprec import AutoPrecisionController

            autoprec = AutoPrecisionController(
                base="full", grid_points=args.n ** 2, interval=5)
            schedule = PrecisionSchedule.auto("full", grid_points=args.n ** 2)
        else:
            autoprec = None
            schedule = PrecisionSchedule.paper_default(args.half)
        tcfg = TrainerConfig(
            total_steps=args.steps,
            schedule=schedule, autoprec=autoprec,
            optimizer=AdamW(lr=2e-3, weight_decay=1e-5),
            ckpt_dir=ckpt_dir, ckpt_every=20,
            calibration_state=args.calibration_state,
            obs=args.obs_trace is not None,
        )
        trainer = Trainer(loss_fn, params, tcfg)
        trainer.install_preemption_handler()
        if args.auto_precision:
            print(f"training {args.steps} steps with bound-guided "
                  f"auto-precision (base=full)...")
        else:
            print(f"training {args.steps} steps with the paper schedule "
                  f"(25% mixed / 50% AMP / 25% full, half={args.half})...")
        hist = trainer.run(batch_fn)
        for h in hist[:: max(1, len(hist) // 8)]:
            print(f"  step {h['step']:4d} policy={h['policy']:<16s} loss={h['loss']:.4f}")
        if trainer.controller is not None:
            decisions = trainer.controller.describe()
            print("auto-precision decisions:",
                  {g: s["fmt"] for g, s in decisions["sites"].items()})
            counters = trainer.telemetry.counters()
            print(f"telemetry: {counters['steps']} steps, "
                  f"overflows={counters['overflow_total']:.0f}")

        # restart check
        t2 = Trainer(loss_fn, params, tcfg)
        assert t2.restore(), "checkpoint restore failed"
        print(f"restart OK from step {t2.step} (stats: {trainer.stats})")

        p_final = trainer.params
        e_test = float(relative_l2(fno_apply(p_final, a_te, cfg, FULL), u_te))
        e_super = float(relative_l2(fno_apply(p_final, a_hi, cfg, FULL), u_hi))
        print(f"test rel-L2 @ {args.n}x{args.n}:      {e_test:.4f}")
        print(f"zero-shot super-res @ {2*args.n}x{2*args.n}: {e_super:.4f}")

        # Per-site override: evaluate the paper's mixed pipeline with the
        # LAST FNO layer pinned to full precision — a per-layer precision
        # experiment the flat policy API could not express.  The scoped
        # rule takes precedence over the policy's own "*/spectral/*" rule.
        mixed = get_policy(f"mixed_fno_{args.half}")
        e_mixed = float(relative_l2(fno_apply(p_final, a_te, cfg, mixed), u_te))
        with precision_rules((f"fno/layer{cfg.n_layers - 1}/*", FULL_PRECISION)):
            e_lastfull = float(
                relative_l2(fno_apply(p_final, a_te, cfg, mixed), u_te))
        print(f"mixed eval rel-L2:                 {e_mixed:.4f}")
        print(f"mixed, last layer full (override): {e_lastfull:.4f}")

    if args.obs_trace:
        import os

        from repro.obs import (registry, run_records, trace,
                               write_chrome_trace, write_jsonl,
                               write_prometheus)

        recs = trace.snapshot()
        snap = registry().snapshot()
        write_jsonl(args.obs_trace,
                    run_records(recs, snapshot=snap,
                                run="train_darcy", steps=args.steps,
                                auto_precision=args.auto_precision))
        stem = os.path.splitext(args.obs_trace)[0]
        write_chrome_trace(stem + ".trace.json", recs)
        write_prometheus(stem + ".prom", snap)
        print(f"obs: {len(recs)} trace records -> {args.obs_trace} "
              f"(+ {stem}.trace.json, {stem}.prom); "
              f"render with `python -m repro.obs summary {args.obs_trace}`")


if __name__ == "__main__":
    main()
