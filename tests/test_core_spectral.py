"""Tests for the mixed-precision Fourier convolution + theory + schedule."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FULL,
    MIXED_FNO_BF16,
    MIXED_FNO_FP16,
    PrecisionSchedule,
    get_policy,
    init_spectral_weights,
    spectral_conv_apply,
    theory,
)

jax.config.update("jax_platform_name", "cpu")


def _x(rng, shape):
    return jnp.asarray(rng.randn(*shape), jnp.float32)


class TestSpectralConv:
    @pytest.mark.parametrize("ndim,spatial", [(1, (32,)), (2, (16, 16)), (3, (8, 8, 8))])
    def test_shapes(self, ndim, spatial):
        rng = np.random.RandomState(0)
        assert len(spatial) == ndim
        key = jax.random.PRNGKey(0)
        modes = tuple(max(2, s // 4) for s in spatial)
        params = init_spectral_weights(key, 4, 6, modes)
        x = _x(rng, (2, 4, *spatial))
        y = spectral_conv_apply(params, x, modes, FULL)
        assert y.shape == (2, 6, *spatial)
        assert np.isfinite(np.asarray(y)).all()

    @pytest.mark.parametrize("fact", ["dense", "cp", "tucker"])
    def test_factorizations_run(self, fact):
        rng = np.random.RandomState(1)
        key = jax.random.PRNGKey(1)
        params = init_spectral_weights(key, 4, 4, (4, 4), factorization=fact)
        x = _x(rng, (2, 4, 16, 16))
        y = spectral_conv_apply(params, x, (4, 4), FULL)
        assert y.shape == (2, 4, 16, 16)
        assert np.isfinite(np.asarray(y)).all()

    @pytest.mark.parametrize("policy_name", ["mixed_fno_fp16", "mixed_fno_bf16"])
    @pytest.mark.parametrize("fact", ["dense", "cp"])
    def test_half_close_to_full(self, policy_name, fact):
        """Precision error of the half pipeline is small relative to signal —
        the empirical counterpart of Thm 3.2."""
        rng = np.random.RandomState(2)
        key = jax.random.PRNGKey(2)
        params = init_spectral_weights(key, 8, 8, (6, 6), factorization=fact)
        x = _x(rng, (2, 8, 24, 24))
        y_full = np.asarray(spectral_conv_apply(params, x, (6, 6), FULL))
        y_half = np.asarray(
            spectral_conv_apply(params, x, (6, 6), get_policy(policy_name))
        ).astype(np.float32)
        # tanh stabiliser changes the function; compare against the full
        # pipeline with the same stabiliser applied.
        x_stab = jnp.tanh(x)
        y_ref = np.asarray(spectral_conv_apply(params, x_stab, (6, 6), FULL))
        rel = np.linalg.norm(y_half - y_ref) / (np.linalg.norm(y_ref) + 1e-9)
        assert rel < 0.05, rel

    def test_no_overflow_on_large_inputs_with_tanh(self):
        """The paper's headline failure mode: naive half FNO overflows. With
        the tanh stabiliser the half pipeline must stay finite even for
        inputs near the fp16 max."""
        rng = np.random.RandomState(3)
        key = jax.random.PRNGKey(3)
        params = init_spectral_weights(key, 4, 4, (4, 4))
        x = _x(rng, (1, 4, 16, 16)) * 3e4  # near fp16 max 65504
        y = spectral_conv_apply(params, x, (4, 4), MIXED_FNO_FP16)
        assert np.isfinite(np.asarray(y, dtype=np.float32)).all()

    def test_naive_half_overflows_without_stabilizer(self):
        """Counterpart: without the stabiliser, the fp16 FFT boundary
        overflows for large inputs (reproduces the NaN failure)."""
        from repro.precision import SiteRule

        rng = np.random.RandomState(4)
        key = jax.random.PRNGKey(4)
        params = init_spectral_weights(key, 4, 4, (4, 4))
        naive = MIXED_FNO_FP16.with_rules(
            ("*/spectral/*", SiteRule(stabilize=None)), name="naive_fp16"
        )
        x = _x(rng, (1, 4, 64, 64)) * 3e4
        y = spectral_conv_apply(params, x, (4, 4), naive)
        assert not np.isfinite(np.asarray(y, dtype=np.float32)).all()

    def test_grad_flows(self):
        rng = np.random.RandomState(5)
        key = jax.random.PRNGKey(5)
        params = init_spectral_weights(key, 4, 4, (4, 4))
        x = _x(rng, (2, 4, 16, 16))

        def loss(p):
            y = spectral_conv_apply(p, x, (4, 4), MIXED_FNO_BF16)
            return jnp.sum(y.astype(jnp.float32) ** 2)

        g = jax.grad(loss)(params)
        flat = [v for k, v in g.items() if isinstance(v, jnp.ndarray)]
        assert all(np.isfinite(np.asarray(t)).all() for t in flat)
        assert any(np.abs(np.asarray(t)).max() > 0 for t in flat)

    def test_discretization_convergence(self):
        """FNO property: the same operator applied at finer resolution
        converges (zero-shot super-resolution, Table 1 setting).  We check
        the spectral conv of a band-limited function is resolution-stable."""
        key = jax.random.PRNGKey(6)
        params = init_spectral_weights(key, 1, 1, (4, 4))

        def field(n):
            t = np.linspace(0, 1, n, endpoint=False)
            xx, yy = np.meshgrid(t, t, indexing="ij")
            f = np.sin(2 * np.pi * xx) * np.cos(4 * np.pi * yy)
            return jnp.asarray(f[None, None], jnp.float32)

        outs = {}
        for n in (32, 64):
            y = np.asarray(spectral_conv_apply(params, field(n), (4, 4), FULL))
            outs[n] = y[0, 0, :: n // 32, :: n // 32]  # sample to common grid
        rel = np.linalg.norm(outs[64] - outs[32]) / np.linalg.norm(outs[32])
        assert rel < 0.05, rel


class TestTheory:
    def test_disc_error_within_bounds_1d(self):
        v = lambda x: np.prod(x, axis=-1)  # the lower-bound witness v(x)=x1..xd
        for m in (16, 32, 64):
            err = theory.disc_error(v, m=m, d=1, omega=1.0)
            ub = theory.disc_upper_bound(n=m, d=1, omega=1.0, L=1.0, M=1.0)
            assert err <= ub, (m, err, ub)

    def test_disc_error_decays_with_n(self):
        v = lambda x: np.sin(2 * np.pi * x[..., 0]) * np.prod(x, axis=-1)
        errs = [theory.disc_error(v, m=m, d=1, omega=1.0) for m in (8, 16, 32, 64)]
        assert errs[0] > errs[-1]

    def test_prec_error_bounded(self):
        v = lambda x: np.prod(x, axis=-1)
        for d in (1, 2):
            err = theory.prec_error(v, m=16, d=d, omega=1.0, dtype="float16")
            ub = theory.prec_upper_bound(eps=2.0 ** -11, M=1.0)
            assert err <= ub, (d, err, ub)

    def test_precision_smaller_than_discretization(self):
        """The paper's headline claim: Prec << Disc at realistic mesh sizes."""
        v = lambda x: np.sin(2 * np.pi * x[..., 0]) + 0.5 * np.prod(x, axis=-1)
        disc = theory.disc_error(v, m=64, d=2, omega=1.0)
        prec = theory.prec_error(v, m=64, d=2, omega=1.0, dtype="float16")
        assert prec < disc

    def test_crossover_mesh_size_3d_fp16(self):
        n_star = theory.crossover_mesh_size(eps=1e-4, d=3)
        assert n_star > 1e6  # the paper quotes ~1e6 for 3-D fp16


class TestSchedule:
    def test_paper_default_phases(self):
        s = PrecisionSchedule.paper_default("fp16")
        total = 100
        assert s.policy_at(0, total).name == "mixed_fno_fp16"
        assert s.policy_at(50, total).name == "amp_fp16"
        assert s.policy_at(99, total).name == "full"

    def test_boundaries_cover_run(self):
        s = PrecisionSchedule.paper_default("bf16")
        bs = s.phase_boundaries(1000)
        assert bs[0][0] == 0 and bs[-1][1] == 1000
        assert all(b[1] == nb[0] for b, nb in zip(bs, bs[1:], strict=False))

    def test_invalid_raises(self):
        with pytest.raises(ValueError):
            PrecisionSchedule(phases=((0.5, "full"), (0.4, "full")))
