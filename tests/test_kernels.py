"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

All kernels run in interpret mode on CPU (the TPU compile path is covered
by the dry-run, which lowers the same call sites for the production mesh).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import ComplexPair, FULL, get_policy
from repro.kernels import ops, ref
from repro.kernels.spectral_contract import spectral_contract_pallas, vmem_bytes

from helpers import rand_complex

jax.config.update("jax_platform_name", "cpu")

# this module's sweeps predate the shared helper and pinned unit scale
_rand_complex = functools.partial(rand_complex, scale=1.0)


class TestSpectralContractKernel:
    @pytest.mark.parametrize(
        "B,I,O,M", [(1, 4, 4, 8), (2, 8, 16, 32), (3, 16, 8, 65), (2, 8, 8, 1)]
    )
    def test_shapes_f32(self, B, I, O, M):
        rng = np.random.RandomState(B * 100 + I)
        x = _rand_complex(rng, (B, I, M))
        w = _rand_complex(rng, (I, O, M))
        xr, xi = jnp.real(x), jnp.imag(x)
        wr, wi = jnp.real(w), jnp.imag(w)
        out_re, out_im = spectral_contract_pallas(
            xr, xi, wr, wi, block_m=16, interpret=True
        )
        want = ref.spectral_contract_ref(x, w)
        np.testing.assert_allclose(np.asarray(out_re), np.real(want), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(out_im), np.imag(want), rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("dtype", [jnp.float16, jnp.bfloat16])
    def test_half_dtypes(self, dtype):
        rng = np.random.RandomState(7)
        B, I, O, M = 2, 8, 8, 24
        x = _rand_complex(rng, (B, I, M), scale=0.5)
        w = _rand_complex(rng, (I, O, M), scale=0.2)
        xr = jnp.real(x).astype(dtype)
        xi = jnp.imag(x).astype(dtype)
        wr = jnp.real(w).astype(dtype)
        wi = jnp.imag(w).astype(dtype)
        out_re, out_im = spectral_contract_pallas(
            xr, xi, wr, wi, block_m=8, interpret=True
        )
        want = ref.spectral_contract_ref(x, w)
        got = np.asarray(out_re, np.float32) + 1j * np.asarray(out_im, np.float32)
        rel = np.abs(got - np.asarray(want)) / (np.abs(np.asarray(want)) + 1e-2)
        # storage-precision error only (accumulation is f32)
        tol = 2e-2 if dtype == jnp.float16 else 1e-1
        assert rel.mean() < tol

    def test_ops_wrapper_multimode(self):
        """The ops wrapper flattens (x, y) mode axes and restores them."""
        rng = np.random.RandomState(8)
        x = _rand_complex(rng, (2, 4, 6, 5))
        w = _rand_complex(rng, (4, 8, 6, 5))
        got = ops.spectral_contract(x, w, policy=FULL)
        want = jnp.einsum("bixy,ioxy->boxy", x, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3)

    def test_ops_wrapper_half_policy_returns_pair(self):
        rng = np.random.RandomState(9)
        policy = get_policy("mixed_fno_bf16")
        x = ComplexPair.from_complex(_rand_complex(rng, (2, 4, 6, 5)), jnp.bfloat16)
        w = _rand_complex(rng, (4, 8, 6, 5))
        got = ops.spectral_contract(x, w, policy=policy)
        assert isinstance(got, ComplexPair)
        assert got.re.dtype == jnp.bfloat16
        assert got.shape == (2, 8, 6, 5)

    @pytest.mark.slow
    @given(
        st.integers(min_value=1, max_value=3),
        st.integers(min_value=1, max_value=12),
        st.integers(min_value=1, max_value=12),
        st.integers(min_value=1, max_value=40),
        st.sampled_from([4, 8, 16]),
    )
    @settings(max_examples=15, deadline=None)
    def test_property_any_shape(self, B, I, O, M, block_m):
        rng = np.random.RandomState(B * 1000 + I * 100 + O * 10 + M)
        x = _rand_complex(rng, (B, I, M))
        w = _rand_complex(rng, (I, O, M))
        out_re, out_im = spectral_contract_pallas(
            jnp.real(x), jnp.imag(x), jnp.real(w), jnp.imag(w),
            block_m=block_m, interpret=True,
        )
        want = ref.spectral_contract_ref(x, w)
        np.testing.assert_allclose(np.asarray(out_re), np.real(want), rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(np.asarray(out_im), np.imag(want), rtol=1e-3, atol=1e-3)

    def test_vmem_budget_helper(self):
        # default tile must sit well under 16 MiB
        assert vmem_bytes(32, 64, 64, 64) < 4 * 2 ** 20


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("S,D,causal", [(64, 32, True), (128, 64, False), (96, 32, True)])
    def test_matches_ref(self, S, D, causal):
        rng = np.random.RandomState(0)
        q = jnp.asarray(rng.randn(2, S, D), jnp.float32)
        k = jnp.asarray(rng.randn(2, S, D), jnp.float32)
        v = jnp.asarray(rng.randn(2, S, D), jnp.float32)
        from repro.kernels.flash_attention import flash_attention

        got = flash_attention(q, k, v, causal=causal, block_q=32, block_k=32, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)

    def test_unaligned_lengths(self):
        """Padding paths: S=50, Sk=70 with 32-blocks."""
        rng = np.random.RandomState(1)
        q = jnp.asarray(rng.randn(1, 50, 32), jnp.float32)
        k = jnp.asarray(rng.randn(1, 70, 32), jnp.float32)
        v = jnp.asarray(rng.randn(1, 70, 32), jnp.float32)
        from repro.kernels.flash_attention import flash_attention

        got = flash_attention(q, k, v, causal=False, block_q=32, block_k=32, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)

    def test_bf16(self):
        rng = np.random.RandomState(2)
        q = jnp.asarray(rng.randn(2, 64, 32), jnp.bfloat16)
        k = jnp.asarray(rng.randn(2, 64, 32), jnp.bfloat16)
        v = jnp.asarray(rng.randn(2, 64, 32), jnp.bfloat16)
        from repro.kernels.flash_attention import flash_attention

        got = flash_attention(q, k, v, causal=True, block_q=32, block_k=32, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=5e-2, atol=5e-2
        )

    def test_ops_wrapper_heads(self):
        rng = np.random.RandomState(3)
        q = jnp.asarray(rng.randn(2, 4, 64, 32), jnp.float32)
        k = jnp.asarray(rng.randn(2, 4, 64, 32), jnp.float32)
        v = jnp.asarray(rng.randn(2, 4, 64, 32), jnp.float32)
        got = ops.flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
        want = ref.flash_attention_ref(
            q.reshape(8, 64, 32), k.reshape(8, 64, 32), v.reshape(8, 64, 32), causal=True
        ).reshape(2, 4, 64, 32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


class TestRMSNormKernel:
    @pytest.mark.parametrize("N,D", [(8, 16), (300, 64), (1, 128)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, N, D, dtype):
        rng = np.random.RandomState(N)
        x = jnp.asarray(rng.randn(N, D), dtype)
        w = jnp.asarray(rng.rand(D) + 0.5, dtype)
        from repro.kernels.rmsnorm import rmsnorm

        got = rmsnorm(x, w, block_rows=64, interpret=True)
        want = ref.rmsnorm_ref(x, w)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=2e-2, atol=2e-2
        )

    def test_ops_wrapper_rank3(self):
        rng = np.random.RandomState(4)
        x = jnp.asarray(rng.randn(2, 5, 16), jnp.float32)
        w = jnp.ones(16, jnp.float32)
        got = ops.rmsnorm(x, w)
        want = ref.rmsnorm_ref(x.reshape(-1, 16), w).reshape(2, 5, 16)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


class TestKernelModelIntegration:
    def test_spectral_conv_pallas_path_matches_jnp(self):
        """spectral_conv_apply(use_pallas=True) == jnp contraction path."""
        import numpy as np
        from repro.core import FULL, init_spectral_weights, spectral_conv_apply

        rng = np.random.RandomState(11)
        key = jax.random.PRNGKey(11)
        params = init_spectral_weights(key, 4, 4, (4, 4))
        x = jnp.asarray(rng.randn(2, 4, 16, 16), jnp.float32)
        y_jnp = np.asarray(
            spectral_conv_apply(params, x, (4, 4), FULL, use_pallas=False))
        y_pl = np.asarray(spectral_conv_apply(params, x, (4, 4), FULL, use_pallas=True))
        np.testing.assert_allclose(y_pl, y_jnp, rtol=1e-3, atol=1e-4)

    def test_spectral_conv_pallas_half(self):
        import numpy as np
        from repro.core import get_policy, init_spectral_weights, spectral_conv_apply

        rng = np.random.RandomState(12)
        key = jax.random.PRNGKey(12)
        policy = get_policy("mixed_fno_bf16")
        params = init_spectral_weights(key, 8, 8, (4, 4))
        x = jnp.asarray(rng.randn(2, 8, 16, 16), jnp.float32)
        y_pl = np.asarray(
            spectral_conv_apply(params, x, (4, 4), policy, use_pallas=True), np.float32
        )
        y_jnp = np.asarray(
            spectral_conv_apply(params, x, (4, 4), policy, use_pallas=False),
            np.float32,
        )
        rel = np.linalg.norm(y_pl - y_jnp) / (np.linalg.norm(y_jnp) + 1e-9)
        assert rel < 0.05, rel


class TestBlockedAttentionJNP:
    """Pure-JAX blocked attention (models/lm/common.py) vs plain reference,
    including the MLA case where v's head dim differs from q/k's."""

    def test_matches_plain(self):
        from repro.models.lm.common import blocked_attention, plain_attention

        rng = np.random.RandomState(0)
        B, H, S, D = 2, 3, 96, 16
        q = jnp.asarray(rng.randn(B, H, S, D), jnp.float32)
        k = jnp.asarray(rng.randn(B, H, S, D), jnp.float32)
        v = jnp.asarray(rng.randn(B, H, S, D), jnp.float32)
        pos = jnp.arange(S)
        got = blocked_attention(q, k, v, pos, pos, 1 << 30, q_chunk=32, k_chunk=32)
        want = plain_attention(q, k, v, pos, pos, 1 << 30)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)

    def test_mla_distinct_v_dim(self):
        from repro.models.lm.common import blocked_attention, plain_attention

        rng = np.random.RandomState(1)
        B, H, S, D, Dv = 1, 2, 64, 24, 16
        q = jnp.asarray(rng.randn(B, H, S, D), jnp.float32)
        k = jnp.asarray(rng.randn(B, H, S, D), jnp.float32)
        v = jnp.asarray(rng.randn(B, H, S, Dv), jnp.float32)
        pos = jnp.arange(S)
        got = blocked_attention(q, k, v, pos, pos, 1 << 30, q_chunk=16, k_chunk=16)
        want = plain_attention(q, k, v, pos, pos, 1 << 30)
        assert got.shape == (B, H, S, Dv)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)

    def test_sliding_window(self):
        from repro.models.lm.common import blocked_attention, plain_attention

        rng = np.random.RandomState(2)
        B, H, S, D = 1, 2, 96, 16
        q = jnp.asarray(rng.randn(B, H, S, D), jnp.float32)
        k = jnp.asarray(rng.randn(B, H, S, D), jnp.float32)
        v = jnp.asarray(rng.randn(B, H, S, D), jnp.float32)
        pos = jnp.arange(S)
        got = blocked_attention(q, k, v, pos, pos, 24, q_chunk=32, k_chunk=32)
        want = plain_attention(q, k, v, pos, pos, 24)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)
