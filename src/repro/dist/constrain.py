"""Ambient-mesh sharding constraints with logical axis names.

``constrain(x, *spec)`` is ``with_sharding_constraint`` that

* reads the mesh from the ambient context (``with mesh:`` /
  :func:`use_mesh`) instead of a threaded argument,
* accepts *logical* axis names ("dp", "tp", "seq", ...) or tuples of
  them per dimension, resolved through :mod:`repro.dist.rules`,
* no-ops gracefully when there is no mesh, the mesh is trivial, or a
  requested axis does not divide the dimension (the longest divisible
  prefix of the resolved axes is kept).

Models therefore never name a physical mesh axis; the shape helpers
(`constrain_bsd`, `constrain_bhsd`, `constrain_tokens`,
`constrain_spatial`) additionally own the standard layout decisions for
their tensor shapes so call sites stay one line.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Iterator, Optional, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .rules import Entry, normalize_entry, resolve_axes


def ambient_mesh() -> Optional[Mesh]:
    """The mesh of the enclosing ``with mesh:`` scope, or None."""
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
    except Exception:  # pragma: no cover - jax internals moved
        try:
            from jax.interpreters.pxla import thread_resources

            m = thread_resources.env.physical_mesh
        except Exception:
            return None
    if m is None or m.empty:
        return None
    return m


@contextmanager
def use_mesh(mesh: Optional[Mesh]) -> Iterator[Optional[Mesh]]:
    """Declarative entry point: make ``mesh`` ambient for the scope.

    ``use_mesh(None)`` is a no-op scope, so launch code can be written
    unconditionally: ``with use_mesh(maybe_mesh): ...``.
    """
    if mesh is None:
        yield None
    else:
        with mesh:
            yield mesh


def logical_axis_size(names: Union[str, tuple], mesh: Optional[Mesh] = None) -> int:
    """Product of the mesh sizes of the resolved physical axes (1 off-mesh)."""
    mesh = mesh if mesh is not None else ambient_mesh()
    if mesh is None:
        return 1
    size = 1
    for ax in resolve_axes(names, mesh):
        size *= mesh.shape[ax]
    return size


def _resolve_spec(shape, spec, mesh) -> Optional[P]:
    """Per-dim logical entries -> PartitionSpec of physical axes.

    Divisibility is enforced per dimension: the longest prefix of the
    resolved axes whose size product divides the dim is kept.  Returns
    None when nothing ends up sharded (caller no-ops).
    """
    used: set = set()
    entries = []
    for dim, entry in zip(shape, spec, strict=False):
        axes = resolve_axes(entry, mesh, used)
        while axes:
            prod = 1
            for ax in axes:
                prod *= mesh.shape[ax]
            if dim % prod == 0:
                break
            axes = axes[:-1]
        used.update(axes)
        entries.append(normalize_entry(axes))
    if all(e is None for e in entries):
        return None
    return P(*entries)


def constrain(x: jnp.ndarray, *spec: Entry) -> jnp.ndarray:
    """``with_sharding_constraint`` by logical axis names; ambient mesh.

    Trailing dims not covered by ``spec`` are replicated.  Off-mesh (or
    on a single-device mesh) this is the identity.
    """
    mesh = ambient_mesh()
    if mesh is None or mesh.devices.size <= 1:
        return x
    p = _resolve_spec(x.shape, spec, mesh)
    if p is None:
        return x
    return jax.lax.with_sharding_constraint(x, p)


def constrain_bsd(x: jnp.ndarray) -> jnp.ndarray:
    """(B, S, d) activations: batch over dp, sequence over the tp axis
    (sequence parallelism), features replicated."""
    return constrain(x, "dp", "seq", None)


def constrain_bhsd(x: jnp.ndarray) -> jnp.ndarray:
    """(B, H, S, D) attention tensors: heads over tp when they divide,
    else sequence (context parallelism)."""
    mesh = ambient_mesh()
    if mesh is None or mesh.devices.size <= 1:
        return x
    tp = logical_axis_size("heads", mesh)
    if tp > 1 and x.shape[1] % tp == 0:
        return constrain(x, "dp", "heads", None, None)
    return constrain(x, "dp", None, "seq", None)


def constrain_tokens(x: jnp.ndarray) -> jnp.ndarray:
    """(T, d) flattened token tables (MoE dispatch): tokens over dp."""
    return constrain(x, "dp", None)


def constrain_spatial(x: jnp.ndarray) -> jnp.ndarray:
    """(B, C, *spatial) neural-operator activations.

    Full-DP layout: FNO-family weights are tiny, so when the global
    batch covers the whole mesh the batch dim is sharded over EVERY
    axis and weights replicate — FFTs and contractions become
    embarrassingly parallel and the only collective left is the
    gradient all-reduce.  Fallback when the batch doesn't cover the
    mesh: batch over dp, channels over tp.
    """
    mesh = ambient_mesh()
    if mesh is None or mesh.devices.size <= 1:
        return x
    total = logical_axis_size("all", mesh)
    if total > 1 and x.shape[0] % total == 0:
        return constrain(x, ("dp", "tp"), *([None] * (x.ndim - 1)))
    return constrain(x, "dp", "tp", *([None] * (x.ndim - 2)))
