"""Shared benchmark helpers: timing, memory analysis, tiny-problem setup.

Every ``benchmarks/results/*.json`` goes through :func:`write_result`
(re-exported from :mod:`repro.obs.export`, where src-tree writers import
it from): the payload is written atomically with a shared metadata
header under ``"meta"`` — schema version, backend, jax version, git sha,
UTC timestamp, ``REPRO_*`` env — so the perf trajectory is
machine-comparable across PRs.
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np

from repro.models import FNOConfig, fno_apply, init_fno
from repro.obs import result_header, write_result  # noqa: F401
from repro.train.losses import relative_l2


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time per call in microseconds (CPU indicative)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def compiled_temp_bytes(fn: Callable, *shapes) -> int:
    """temp_size_in_bytes of the compiled function (the memory analog of
    the paper's GPU memory measurements on this CPU container)."""
    compiled = jax.jit(fn).lower(*shapes).compile()
    mem = compiled.memory_analysis()
    return int(getattr(mem, "temp_size_in_bytes", 0))


def small_fno(factorization: str = "dense", modes=(8, 8), hidden=32):
    cfg = FNOConfig(
        in_channels=1, out_channels=1, hidden_channels=hidden,
        lifting_channels=hidden, projection_channels=hidden,
        n_layers=4, modes=modes, factorization=factorization,
    )
    params = init_fno(jax.random.PRNGKey(0), cfg)
    return cfg, params


def darcy_data(n: int = 32, ntrain: int = 32, ntest: int = 16, maxiter: int = 300):
    from repro.data import sample_darcy_batch

    a_tr, u_tr = sample_darcy_batch(jax.random.PRNGKey(0), n, ntrain, maxiter)
    a_te, u_te = sample_darcy_batch(jax.random.PRNGKey(1), n, ntest, maxiter)
    return (a_tr, u_tr), (a_te, u_te)


def train_fno(cfg, params, data, policy, steps: int = 40, lr: float = 2e-3):
    """Plain Adam-free SGD train loop for ablation benches; returns
    (params, final_train_loss)."""
    from repro.optim import AdamW

    (a, u) = data
    opt = AdamW(lr=lr, weight_decay=0.0)
    state = opt.init(params)

    @jax.jit
    def step(p, s):
        def loss_fn(pp):
            pred = fno_apply(pp, a, cfg, policy)
            return relative_l2(pred, u)
        loss, g = jax.value_and_grad(loss_fn)(p)
        p2, s2 = opt.update(g, s, p)
        return p2, s2, loss

    loss = None
    for _ in range(steps):
        params, state, loss = step(params, state)
    return params, float(loss)


def eval_fno(cfg, params, data, policy) -> float:
    a, u = data
    pred = fno_apply(params, a, cfg, policy)
    return float(relative_l2(pred, u))
