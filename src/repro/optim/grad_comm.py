"""Gradient communication: compressed data-parallel all-reduce.

Distributed-optimization trick for the 1000+ node regime (DESIGN.md §4):
the DP gradient all-reduce is the dominant collective for dense models, so
we cast gradients to bf16 *before* ``psum`` and back to f32 after — 2x
less ICI traffic for <1e-3 relative error on the summed gradient (bf16 has
f32's exponent range, so no loss-scale interaction).  Exposed as a
``shard_map`` wrapper; the dry-run lowers it to verify the collective
schedule on the production mesh.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def compress_tree(grads, dtype=jnp.bfloat16):
    return jax.tree_util.tree_map(lambda g: g.astype(dtype), grads)


def decompress_tree(grads, dtype=jnp.float32):
    return jax.tree_util.tree_map(lambda g: g.astype(dtype), grads)


def psum_compressed(grads, axis_name: str, dtype=jnp.bfloat16):
    """bf16 all-reduce: cast → psum → upcast.  Used inside shard_map."""
    small = compress_tree(grads, dtype)
    summed = jax.lax.psum(small, axis_name)
    return decompress_tree(summed)


def make_dp_allreduce(mesh, axis_names=("pod", "data"), dtype=jnp.bfloat16):
    """Returns f(grads)->grads performing the compressed DP all-reduce via
    shard_map over the data axes, identity on the model axis."""
    from jax.experimental.shard_map import shard_map

    names = tuple(n for n in axis_names if n in mesh.axis_names)

    def reduce_fn(grads):
        out = grads
        for n in names:
            out = psum_compressed(out, n, dtype)
        scale = 1.0
        for n in names:
            scale *= mesh.shape[n]
        return jax.tree_util.tree_map(lambda g: g / scale, out)

    # replicated-in, replicated-out over the data axes; the caller supplies
    # per-shard partial gradients.
    spec = P(*names)

    def wrapper(grads):
        return shard_map(
            reduce_fn,
            mesh=mesh,
            in_specs=jax.tree_util.tree_map(lambda _: P(), grads),
            out_specs=jax.tree_util.tree_map(lambda _: P(), grads),
            check_rep=False,
        )(grads)

    return wrapper
