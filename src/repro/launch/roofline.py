"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch × mesh), in seconds:

    compute    = HLO_FLOPs            / (chips × peak_FLOP/s)
    memory     = HLO_bytes_accessed   / (chips × HBM_bw)
    collective = collective_bytes     / (chips × link_bw)

``compiled.cost_analysis()`` counts each while-loop body ONCE (verified in
tests/test_roofline.py: a lax.scan of length 8 reports exactly 1/8 of the
true FLOPs), which makes it useless for scan-over-layers models.  We
therefore parse the post-partitioning HLO text ourselves:

  * FLOPs: every ``dot`` op (2 · |out| · K, K from lhs_contracting_dims),
    accumulated through fusions/calls, and multiplied by while-loop trip
    counts extracted from each loop condition's comparison constant.
  * bytes: operand+output bytes of every materialising op at fusion
    granularity (fusion boundaries = HBM round-trips), same loop scaling.
  * collective bytes: output bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute, same loop scaling.

All values are per-device (the HLO is the post-SPMD per-device program).

Hardware model (TPU v5e-class, from the assignment):
    197 TFLOP/s bf16 per chip · 819 GB/s HBM · ~50 GB/s/link ICI.
"""
from __future__ import annotations


PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

from .hlo_parse import HLOCounts, parse_hlo  # noqa: F401  (re-export)
import dataclasses as _dc


@_dc.dataclass
class CollectiveStats:
    bytes_by_kind: dict

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def collective_bytes(hlo: str) -> CollectiveStats:
    return CollectiveStats(parse_hlo(hlo).collective_by_kind)


@_dc.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    n_devices: int
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def to_dict(self) -> dict:
        return _dc.asdict(self) | {
            "dominant": self.dominant, "step_time_s": self.step_time_s}


def analyze_counts(counts: HLOCounts, n_devices: int) -> Roofline:
    return Roofline(
        flops_per_device=counts.flops,
        bytes_per_device=counts.bytes,
        collective_bytes_per_device=counts.collective_bytes,
        n_devices=n_devices,
        compute_s=counts.flops / PEAK_FLOPS,
        memory_s=counts.bytes / HBM_BW,
        collective_s=counts.collective_bytes / ICI_BW,
    )


def model_flops(n_params_active: float, tokens: float) -> float:
    """6·N·D napkin-math (per the assignment: N_active for MoE)."""
    return 6.0 * n_params_active * tokens


def spectral_kernel_vmem(B: int, I: int, O: int, modes, *, rank: int = 0,
                         l_shared: bool = False,
                         itemsize: int = 2) -> dict:
    """Tiling record for the Pallas spectral-contraction kernel at one
    dry-run cell: the budget-chosen tile and the fwd/bwd VMEM working
    sets it implies — dense when ``rank == 0``, CP otherwise, or the
    SFNO l-shared kernel when ``l_shared`` (then ``modes = (lmax, mmax)``
    and the tile runs over degrees).  The wrappers resolve the same
    ``pick_block_*`` choice at run time, so this record describes the
    tiling that actually executes.  Dry-runs attach it next to the
    roofline so a cell that would spill VMEM is visible without
    compiling for real hardware."""
    from repro.kernels.ops import (
        cp_vmem_bytes, lshared_vmem_bytes, pick_block_l, pick_block_m,
        vmem_bytes, vmem_bytes_bwd)
    from repro.kernels.spectral_contract import VMEM_BUDGET

    if l_shared:
        L, Mm = (int(m) for m in modes)
        bl = pick_block_l(B, I, O, L, Mm, itemsize=itemsize)
        fwd = bwd = lshared_vmem_bytes(B, I, O, Mm, bl, itemsize)
        tile, n_tiled, kind = bl, L, "l_shared"
    else:
        M = 1
        for m in modes:
            M *= int(m)
        tile = pick_block_m(B, I, O, M, rank=rank, itemsize=itemsize)
        if rank:
            fwd = bwd = cp_vmem_bytes(B, I, O, rank, tile, itemsize)
        else:
            fwd = vmem_bytes(B, I, O, tile, itemsize)
            bwd = vmem_bytes_bwd(B, I, O, tile, itemsize)
        n_tiled, kind = M, ("cp" if rank else "dense")
    return {
        "kind": kind,
        "block": tile,
        "tiled_extent": n_tiled,
        "grid_steps": -(-n_tiled // tile),
        "rank": rank,
        "itemsize": itemsize,
        "vmem_fwd_bytes": fwd,
        "vmem_bwd_bytes": bwd,
        "fits_vmem": max(fwd, bwd) <= VMEM_BUDGET,
    }
