"""Batched serving engine: slot-based continuous batching over the
unified LM decode step.

A fixed pool of B slots holds independent requests; each engine tick runs
one fused ``lm_decode_step`` for the whole pool (one token per active
slot).  Finished/empty slots keep decoding padding (masked out) — the
standard static-shape trick that keeps the step jit-stable while requests
arrive and depart (continuous batching).  Prefill is chunked through
``lm_forward`` and its final hidden state seeds the slot's KV cache via
teacher-forced decode of the prompt (simple, correct; a fused prefill
kernel is a perf-pass item, §Perf).

This engine is what the decode_32k / long_500k dry-run cells lower: one
``serve_step`` with a KV cache of seq_len.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PrecisionPolicy, FULL
from repro.configs.base import LMArchConfig
from repro.dist import use_mesh
from repro.models.lm import init_cache, lm_decode_step


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(
        self,
        params,
        cfg: LMArchConfig,
        n_slots: int = 4,
        max_len: int = 512,
        policy: PrecisionPolicy = FULL,
        greedy: bool = True,
        mesh=None,
    ):
        self.params = params
        self.cfg = cfg
        self.policy = policy
        self.n_slots = n_slots
        self.max_len = max_len
        self.greedy = greedy
        self.mesh = mesh
        # KV storage dtype comes from the serve/kv_cache site of the rule
        # table (f32 under `full` for an exact decode contract; bf16/fp16
        # under the AMP rule sets for the memory saving).
        self.cache = init_cache(cfg, n_slots, max_len,
                                dtype=policy.at("serve/kv_cache").compute_dtype)
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.slot_pending: List[List[int]] = [[] for _ in range(n_slots)]
        step_fn = lambda p, c, t: lm_decode_step(p, c, t, cfg, policy)
        if mesh is None:
            self._step = jax.jit(step_fn)
        else:
            # shard the serving state through the same rule tables the
            # dry-run lowers with: params by lm_param_specs, the slot
            # cache by cache_specs, per-slot tokens data-parallel.
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.dist.sharding import (
                batch_specs,
                cache_specs,
                lm_param_specs,
                to_named,
            )

            p_named = to_named(
                mesh, lm_param_specs(jax.eval_shape(lambda: params), mesh))
            c_named = to_named(
                mesh, cache_specs(jax.eval_shape(lambda: self.cache), mesh, cfg))
            t_named = to_named(
                mesh,
                batch_specs(jax.ShapeDtypeStruct((n_slots,), jnp.int32), mesh))
            self.params = jax.device_put(params, p_named)
            self.cache = jax.device_put(self.cache, c_named)
            self._step = jax.jit(
                step_fn,
                in_shardings=(p_named, c_named, t_named),
                out_shardings=(NamedSharding(mesh, P()), c_named),
            )

    # -- admission -----------------------------------------------------------
    def _reset_slot(self, i: int):
        """Zero slot i's clock and invalidate its cache rows (continuous
        batching: other slots keep decoding undisturbed)."""
        c = dict(self.cache)
        c["step"] = c["step"].at[i].set(0)
        if "kv_pos" in c:
            c["kv_pos"] = c["kv_pos"].at[:, i].set(-1)
        if "ssd_state" in c:
            c["ssd_state"] = c["ssd_state"].at[:, i].set(0.0)
        self.cache = c

    def admit(self, req: Request) -> bool:
        for i, s in enumerate(self.slots):
            if s is None:
                self.slots[i] = req
                self._reset_slot(i)
                # feed the prompt token-by-token (teacher forcing) then decode
                self.slot_pending[i] = list(req.prompt)
                return True
        return False

    # -- one engine tick -------------------------------------------------------
    def tick(self):
        """Run one fused decode step for the slot pool.

        The step that consumes a slot's *last* pending prompt token is also
        the step whose logits define the first generated token — discarding
        them (and re-feeding ``prompt[-1]`` next tick) would decode from a
        skewed cache position, desynchronising the engine from a
        straight-line ``lm_forward`` greedy decode.
        """
        tokens = np.zeros((self.n_slots,), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if self.slot_pending[i]:
                tokens[i] = self.slot_pending[i][0]
            elif req.generated:
                tokens[i] = req.generated[-1]
            else:
                # empty-prompt request: decode from token 0
                tokens[i] = 0
        with use_mesh(self.mesh):
            logits, self.cache = self._step(self.params, self.cache,
                                            jnp.asarray(tokens))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if self.slot_pending[i]:
                self.slot_pending[i].pop(0)
                if self.slot_pending[i]:
                    continue  # still prefilling this slot
                # fall through: the prompt is consumed and this step's
                # logits are the first generation
            req.generated.append(int(nxt[i]))
            if len(req.generated) >= req.max_new_tokens:
                req.done = True
                self.slots[i] = None  # free the slot (continuous batching)

    def run_until_done(self, requests: List[Request], max_ticks: int = 10_000):
        queue = list(requests)
        done: List[Request] = []
        ticks = 0
        while (queue or any(self.slots)) and ticks < max_ticks:
            while queue and self.admit(queue[0]):
                queue.pop(0)
            inflight = [r for r in self.slots if r is not None]
            self.tick()
            done.extend(r for r in inflight if r.done)
            ticks += 1
        return done, ticks
