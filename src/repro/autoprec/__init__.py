"""repro.autoprec — runtime numerics telemetry + bound-guided adaptive
precision control.

The third leg after ``repro.dist`` and ``repro.precision``: where the
rule tables say *which format runs where*, autoprec **measures** what
actually flows through each site at runtime (amax, exponent histograms,
overflow/underflow counters, measured quantisation error — collected
inside jitted steps as a functional carry) and **decides** which sites
can run below fp32, demoting only while the observed range plus the
Thm 3.1/3.2 budgets stay within a target fraction of the discretisation
error and promoting back on overflow streaks.

Public API:
  tap / TraceCollector / collecting     — trace-time site taps
  SiteStats / SiteWindow / TelemetryAggregator — carry + host aggregation
  telemetry_active / merge_stacked / fmt_of    — integration helpers
  AutoPrecisionController / ControllerConfig   — telemetry -> rule overlays
  certify (submodule)                    — empirical bound certification
"""
from .telemetry import (  # noqa: F401
    SiteStats,
    SiteWindow,
    TelemetryAggregator,
    TraceCollector,
    collecting,
    fmt_of,
    merge_stacked,
    tap,
    telemetry_active,
)
from .controller import (  # noqa: F401
    AutoPrecisionController,
    ControllerConfig,
    group_of,
)
