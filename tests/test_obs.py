"""repro.obs: trace ring, metrics registry, numerics events, exporters,
CLI, and the wiring into Trainer / serve engines.

The metrics-schema golden (``tests/golden/obs_metrics_keys.json``)
freezes the *series names* a canonical run publishes — key drift in any
``stats()`` surface (trainer, LM engine, scheduler) or in the numerics
vocabulary shows up here as a diff.  Set ``REPRO_REGEN_GOLDENS=1`` and
rerun to re-record after an intentional schema change.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

from repro.kernels import ops
from repro.models import FNOConfig, fno_apply, init_fno
from repro.obs import (
    KINDS,
    MAX_LABEL_SETS,
    autoprec_decision,
    chrome_trace,
    metric_names,
    numerics_event,
    prometheus_text,
    read_jsonl,
    registry,
    result_header,
    run_records,
    tile_cache_event,
    trace,
    validate_chrome_trace,
    write_jsonl,
    write_result,
)
from repro.obs.__main__ import main as obs_main
from repro.train import Trainer, TrainerConfig, relative_l2

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden",
                           "obs_metrics_keys.json")


@pytest.fixture(autouse=True)
def _obs_clean():
    """Each test starts from a disabled trace and an empty registry;
    the kernels external (dropped by ``clear()``) is re-registered."""
    trace.disable()
    trace.clear()
    registry().clear()
    ops._register_obs()
    yield
    trace.disable()
    trace.clear()
    registry().clear()
    ops._register_obs()


# ---------------------------------------------------------------------------
# trace ring
# ---------------------------------------------------------------------------


class TestTrace:
    def test_off_is_shared_noop(self):
        s1, s2 = trace.span("a"), trace.span("b", k=1)
        assert s1 is s2  # the shared _NULL object: zero allocation off
        with s1:
            trace.event("x")
        assert trace.snapshot() == []

    def test_span_nesting_records_depth_and_parent(self):
        trace.enable()
        with trace.span("outer"):
            with trace.span("inner", k=2):
                pass
        recs = trace.snapshot()
        # spans close inner-first
        inner, outer = recs
        assert inner["name"] == "inner" and inner["depth"] == 1
        assert inner["parent"] == "outer" and inner["attrs"] == {"k": 2}
        assert outer["name"] == "outer" and outer["depth"] == 0
        assert "parent" not in outer
        assert inner["ts_ns"] >= outer["ts_ns"]
        assert inner["dur_ns"] <= outer["dur_ns"]

    def test_ring_wraps_drop_oldest(self):
        trace.enable(capacity=8)
        for i in range(12):
            trace.event(f"e{i}")
        recs = trace.snapshot()
        assert [r["name"] for r in recs] == [f"e{i}" for i in range(4, 12)]
        assert trace.dropped() == 4

    def test_async_begin_end_and_event_kinds(self):
        trace.enable()
        trace.begin("request", 7, category="request", engine="lm")
        trace.event("mark", category="c", n=1)
        trace.end("request", 7, category="request")
        kinds = [r["kind"] for r in trace.snapshot()]
        assert kinds == ["b", "event", "e"]
        b = trace.snapshot()[0]
        assert b["id"] == 7 and b["category"] == "request"

    def test_clear_keeps_enabled_state(self):
        trace.enable()
        trace.event("x")
        trace.clear()
        assert trace.is_enabled() and trace.snapshot() == []


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


class TestMetrics:
    def test_name_convention_enforced(self):
        with pytest.raises(ValueError, match="convention"):
            registry().counter("Bad-Name")
        with pytest.raises(ValueError, match="convention"):
            registry().gauge("nope")

    def test_counter_gauge_histogram_snapshot(self):
        registry().counter("repro_t_total", kind="a").inc()
        registry().counter("repro_t_total", kind="a").inc(2)
        registry().gauge("repro_t_g").set(3.5)
        h = registry().histogram("repro_t_ms", edges=(1.0, 10.0))
        h.observe(0.5)
        h.observe(5.0)
        h.observe(100.0)
        snap = registry().snapshot()
        assert snap["counters"]['repro_t_total{kind="a"}'] == 3.0
        assert snap["gauges"]["repro_t_g"] == 3.5
        hs = snap["histograms"]["repro_t_ms"]
        assert hs["counts"] == [1, 1, 1] and hs["count"] == 3

    def test_label_cardinality_capped(self):
        for i in range(MAX_LABEL_SETS):
            registry().counter("repro_t_total", k=str(i))
        with pytest.raises(ValueError, match="label sets"):
            registry().counter("repro_t_total", k="one-too-many")

    def test_histogram_redeclare_different_edges_raises(self):
        registry().histogram("repro_t_ms", edges=(1.0, 2.0))
        with pytest.raises(ValueError, match="different edges"):
            registry().histogram("repro_t_ms", edges=(1.0, 3.0))

    def test_publish_flattens_nested_stats(self):
        registry().publish("eng", {"ticks": 4, "memo": {"hits": 2},
                                   "name": "skipped-string"})
        g = registry().snapshot()["gauges"]
        assert g["repro_eng_ticks"] == 4.0
        assert g["repro_eng_memo_hits"] == 2.0
        assert not any("name" in k for k in g)

    def test_register_external_snapshot_and_reset(self):
        box = {"n": 5}
        registry().register_external(
            "repro_t_ext", lambda: dict(box),
            lambda: box.update(n=0))
        assert registry().snapshot()["external"]["repro_t_ext"] == {"n": 5}
        registry().reset()
        assert box["n"] == 0

    def test_reset_zeroes_instruments(self):
        registry().counter("repro_t_total").inc(9)
        registry().reset()
        assert registry().snapshot()["counters"]["repro_t_total"] == 0.0

    def test_kernels_external_registered(self):
        snap = registry().snapshot()
        assert "repro_kernels_tiles" in snap.get("external", {})

    def test_fused_family_counter_and_bytes_gauge(self):
        """One fused launch lands on the per-family traced-call counter
        and the bytes-moved gauge under the ``spectral_fused`` label."""
        import jax
        import jax.numpy as jnp
        import numpy as np

        from repro.core import FULL, init_spectral_weights
        from repro.kernels import ops

        params = init_spectral_weights(
            jax.random.PRNGKey(0), 2, 2, (2, 3), "dense")
        x = jnp.asarray(np.random.RandomState(0).randn(1, 2, 6, 8),
                        jnp.float32)
        registry().reset()
        ops.spectral_conv_fused(x, params["w_re"], params["w_im"], (2, 3),
                                policy=FULL)
        snap = registry().snapshot()
        key = 'repro_kernels_calls_total{family="spectral_fused"}'
        assert snap["counters"].get(key, 0) >= 1, snap["counters"]
        gkey = 'repro_kernels_bytes_moved{family="spectral_fused"}'
        assert snap["gauges"].get(gkey, 0) > 0, snap["gauges"]


# ---------------------------------------------------------------------------
# numerics events
# ---------------------------------------------------------------------------


class TestNumerics:
    def test_unknown_kind_raises(self):
        with pytest.raises(ValueError, match="unknown numerics event"):
            numerics_event("not_a_kind")

    def test_counter_always_trace_only_when_enabled(self):
        numerics_event("oracle_reject", key="k")
        assert trace.snapshot() == []
        trace.enable()
        numerics_event("oracle_reject", site="s", key="k")
        c = registry().snapshot()["counters"]
        assert c['repro_numerics_events_total{kind="oracle_reject"}'] == 2.0
        (ev,) = trace.snapshot()
        assert ev["name"] == "numerics/oracle_reject"
        assert ev["category"] == "numerics"
        assert ev["attrs"] == {"key": "k", "site": "s"}

    def test_every_kind_lands_in_counter(self):
        for kind in KINDS:
            numerics_event(kind)
        c = registry().snapshot()["counters"]
        assert all(
            c[f'repro_numerics_events_total{{kind="{kind}"}}'] == 1.0
            for kind in KINDS)

    def test_forced_demote_emits_budgeted_event(self):
        from repro.autoprec import AutoPrecisionController

        trace.enable()
        ctl = AutoPrecisionController(base="full", grid_points=1024,
                                      demote_patience=1, cooldown=0)
        from tests.test_autoprec import _window

        assert ctl.update({"fno/layer0/spectral/fft_in": _window()})
        c = registry().snapshot()["counters"]
        assert c['repro_numerics_events_total{kind="autoprec_demote"}'] >= 1
        demotes = [r for r in trace.snapshot()
                   if r["name"] == "numerics/autoprec_demote"]
        attrs = demotes[0]["attrs"]
        # the acceptance criterion: the event carries the budget numbers
        assert attrs["to_fmt"] == "bfloat16"
        assert attrs["eps_budget"] > 0 and attrs["fmt_eps"] > 0
        assert attrs["site"] == "fno/layer0/spectral"

    def test_seeded_stale_cache_hit_emits_event(self):
        from repro.tune.cache import CalibrationCache, entry_key

        trace.enable()
        cache = CalibrationCache(entries={})
        cache.entries[entry_key("spectral_dense", (4, 8, 8), "float32")] = {
            "family": "spectral_dense", "block_fwd": 8, "block_bwd": 8,
            "validated": False,   # seeded stale: never oracle-validated
        }
        assert cache.lookup("spectral_dense", (4, 8, 8), "float32") is None
        assert cache.counters["stale"] == 1
        c = registry().snapshot()["counters"]
        assert c['repro_numerics_events_total{kind="tile_cache_stale"}'] == 1
        (ev,) = trace.snapshot()
        assert ev["name"] == "numerics/tile_cache_stale"
        assert ev["attrs"]["family"] == "spectral_dense"

    def test_autoprec_decision_promote_vs_demote(self):
        autoprec_decision("g", "bfloat16", "float32",
                          eps_budget=1e-3, amax=2.0)
        autoprec_decision("g", "float32", "float16",
                          eps_budget=1e-3, amax=2.0, fmt_eps=4.9e-4)
        c = registry().snapshot()["counters"]
        assert c['repro_numerics_events_total{kind="autoprec_promote"}'] == 1
        assert c['repro_numerics_events_total{kind="autoprec_demote"}'] == 1


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------


def _sample_records():
    trace.enable()
    with trace.span("outer", step=1):
        with trace.span("inner"):
            pass
        trace.event("mark", category="numerics", site="s")
    trace.begin("request", 3, category="request")
    trace.end("request", 3, category="request")
    return trace.snapshot()


class TestExport:
    def test_chrome_trace_validates(self):
        doc = chrome_trace(_sample_records())
        assert validate_chrome_trace(doc) == []
        phs = [e["ph"] for e in doc["traceEvents"]]
        assert phs == ["M", "X", "i", "X", "b", "e"]
        x_inner = doc["traceEvents"][1]
        assert x_inner["name"] == "inner"
        assert x_inner["args"]["parent"] == "outer"
        # ns -> us conversion
        assert all(e.get("dur", 0) < 1e7 for e in doc["traceEvents"])

    def test_validate_catches_defects(self):
        errs = validate_chrome_trace({"traceEvents": [
            {"ph": "X", "name": "a", "ts": 0, "pid": 1, "tid": 1},
            {"ph": "b", "name": "r", "ts": 0, "pid": 1, "tid": 1,
             "id": "1", "cat": "c"},
            {"ph": "Z", "name": "?", "ts": 0, "pid": 1, "tid": 1},
        ]})
        assert any("missing dur" in e for e in errs)
        assert any("unmatched begin" in e for e in errs)
        assert any("unknown ph" in e for e in errs)

    def test_prometheus_text(self):
        registry().counter("repro_t_total", kind="a").inc(2)
        h = registry().histogram("repro_t_ms", edges=(1.0, 10.0))
        h.observe(0.5)
        h.observe(100.0)
        text = prometheus_text(registry().snapshot())
        assert "# TYPE repro_t_total counter" in text
        assert 'repro_t_total{kind="a"} 2' in text
        assert 'repro_t_ms_bucket{le="1"} 1' in text
        assert 'repro_t_ms_bucket{le="+Inf"} 2' in text
        assert "repro_t_ms_count 2" in text

    def test_result_header_fields(self):
        hdr = result_header(extra_field=7)
        assert hdr["schema_version"] == 1
        assert hdr["backend"] == jax.default_backend()
        assert hdr["jax_version"] == jax.__version__
        assert "timestamp_utc" in hdr and hdr["extra_field"] == 7
        assert isinstance(hdr["env"], dict)

    def test_write_result_and_atomicity(self, tmp_path):
        path = str(tmp_path / "sub" / "r.json")
        write_result(path, {"x": 1})
        doc = json.load(open(path))
        assert doc["x"] == 1 and doc["meta"]["schema_version"] == 1
        # no temp litter from the atomic protocol
        assert os.listdir(tmp_path / "sub") == ["r.json"]

    def test_jsonl_roundtrip_and_run_framing(self, tmp_path):
        recs = run_records(_sample_records(),
                           snapshot=registry().snapshot())
        path = str(tmp_path / "run.jsonl")
        write_jsonl(path, recs)
        back = read_jsonl(path)
        assert back[0]["kind"] == "meta"
        assert back[-1]["kind"] == "metrics"
        assert [r["kind"] for r in back[1:-1]] == [
            "span", "event", "span", "b", "e"]


# ---------------------------------------------------------------------------
# wiring: trainer spans + paged-serve tick spans
# ---------------------------------------------------------------------------


def _tiny_trainer(**cfg_kw):
    cfg = FNOConfig(in_channels=1, out_channels=1, hidden_channels=8,
                    lifting_channels=8, projection_channels=8,
                    n_layers=1, modes=(4, 4))
    params = init_fno(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(2, 1, 16, 16), jnp.float32)
    t = jnp.asarray(rng.randn(2, 1, 16, 16) * 0.1, jnp.float32)

    def loss_fn(p, batch, policy):
        return relative_l2(fno_apply(p, batch["x"], cfg, policy),
                           batch["t"])

    return Trainer(loss_fn, params,
                   TrainerConfig(total_steps=2, obs=True, **cfg_kw))


class TestTrainerWiring:
    def test_step_spans_nest_and_metrics_land(self):
        tr = _tiny_trainer()
        with trace.span("test/run"):
            tr.run(lambda _s: {
                "x": jnp.zeros((2, 1, 16, 16)),
                "t": jnp.zeros((2, 1, 16, 16))})
        spans = [r for r in trace.snapshot() if r["kind"] == "span"]
        by_name = {}
        for s in spans:
            by_name.setdefault(s["name"], []).append(s)
        assert len(by_name["train/step"]) == 2
        assert len(by_name["train/data"]) == 2
        # phase spans nest under the caller's span
        assert all(s["parent"] == "test/run" and s["depth"] == 1
                   for s in by_name["train/step"])
        assert by_name["train/step"][0]["attrs"]["step"] == 0
        snap = registry().snapshot()
        assert snap["counters"]["repro_train_steps_total"] == 2.0
        assert snap["histograms"]["repro_train_step_wall_ms"]["count"] == 2
        # publish_stats ran at end of run
        assert snap["gauges"]["repro_train_step"] == 2.0

    def test_obs_off_trainer_records_nothing(self):
        cfg = FNOConfig(in_channels=1, out_channels=1, hidden_channels=8,
                        lifting_channels=8, projection_channels=8,
                        n_layers=1, modes=(4, 4))
        params = init_fno(jax.random.PRNGKey(0), cfg)
        tr = Trainer(
            lambda p, b, pol: relative_l2(
                fno_apply(p, b["x"], cfg, pol), b["t"]),
            params, TrainerConfig(total_steps=1))
        tr.run(lambda _s: {"x": jnp.zeros((2, 1, 16, 16)),
                           "t": jnp.zeros((2, 1, 16, 16))})
        assert trace.snapshot() == []
        assert "repro_train_steps_total" not in (
            registry().snapshot()["counters"])


def _paged_run():
    import dataclasses

    from repro.configs import get_config
    from repro.models.lm import init_lm
    from repro.serve import PagedLMEngine, Request

    cfg = get_config("smollm-360m", smoke=True)
    if cfg.moe_experts:
        cfg = dataclasses.replace(cfg, moe_experts=0, moe_shared=0, d_ff=32)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    engine = PagedLMEngine(params, cfg, n_slots=2, max_len=32, block_size=8)
    reqs = [Request(uid=u, prompt=[3, 1, 4, 1, 5, 9][: 4 + u % 2],
                    max_new_tokens=2) for u in range(3)]
    finished, _ = engine.run_until_done(reqs)
    return engine, finished


class TestServeWiring:
    def test_paged_tick_spans_and_request_tracks(self):
        trace.enable()
        engine, finished = _paged_run()
        assert all(r.status == "done" for r in finished)
        recs = trace.snapshot()
        ticks = [r for r in recs if r["name"] == "serve/tick"]
        assert ticks and all(
            r["attrs"]["engine"] == "lm_paged" for r in ticks)
        # prefill/decode phases nest inside the tick span
        phases = [r for r in recs
                  if r["name"] in ("serve/prefill", "serve/decode")]
        assert phases and all(p["parent"] == "serve/tick" and p["depth"] >= 1
                              for p in phases)
        # one async begin/end pair per request uid
        begins = {r["id"] for r in recs
                  if r["kind"] == "b" and r["name"] == "request"}
        ends = {r["id"] for r in recs
                if r["kind"] == "e" and r["name"] == "request"}
        assert begins == ends == {0, 1, 2}
        # the whole timeline exports to a valid Chrome trace
        assert validate_chrome_trace(chrome_trace(recs)) == []

    def test_stats_publish_and_reset_counters(self):
        engine, _ = _paged_run()
        stats = engine.stats()
        assert stats["completed"] == 3
        g = registry().snapshot()["gauges"]
        assert g["repro_serve_lm_paged_completed"] == 3.0
        engine.reset_counters()
        stats2 = engine.stats()
        assert stats2["completed"] == 0 and stats2["wall_s"] == 0.0
        # absolute tick count is preserved; occupancy uses the new window
        assert stats2["ticks"] == stats["ticks"]


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


class TestCLI:
    def _run_file(self, tmp_path):
        _sample_records()
        numerics_event("autoprec_demote", site="g", eps_budget=1e-3)
        path = str(tmp_path / "run.jsonl")
        write_jsonl(path, run_records(trace.snapshot(),
                                      snapshot=registry().snapshot()))
        return path

    def test_summary(self, tmp_path, capsys):
        path = self._run_file(tmp_path)
        assert obs_main(["summary", path]) == 0
        out = capsys.readouterr().out
        assert "outer" in out and "inner" in out
        assert "numerics/autoprec_demote" in out
        assert "repro_numerics_events_total" in out

    def test_chrome_subcommand(self, tmp_path):
        path = self._run_file(tmp_path)
        out = str(tmp_path / "trace.json")
        assert obs_main(["chrome", path, out]) == 0
        doc = json.load(open(out))
        assert validate_chrome_trace(doc) == []

    def test_prom_subcommand(self, tmp_path):
        path = self._run_file(tmp_path)
        out = str(tmp_path / "metrics.prom")
        assert obs_main(["prom", path, out]) == 0
        assert "# TYPE repro_numerics_events_total counter" in open(out).read()


# ---------------------------------------------------------------------------
# metrics schema golden
# ---------------------------------------------------------------------------


#: kernel-call series carry (family=...) labels that depend on which
#: compiled paths a leg traces (REPRO_USE_PALLAS) — excluded from the
#: schema golden so both CI legs pin the same key set.
_VOLATILE_PREFIXES = ("repro_kernels_calls_total",
                      "repro_kernels_bytes_moved")


def _golden_names():
    for kind in KINDS:
        numerics_event(kind)
    tile_cache_event("miss", "spectral_dense", "k")
    tr = _tiny_trainer()
    tr.run(lambda _s: {"x": jnp.zeros((2, 1, 16, 16)),
                       "t": jnp.zeros((2, 1, 16, 16))})
    engine, _ = _paged_run()
    engine.stats()
    return [n for n in metric_names()
            if not n.startswith(_VOLATILE_PREFIXES)]


class TestMetricsSchemaGolden:
    def test_metric_names_match_golden(self):
        names = _golden_names()
        if os.environ.get("REPRO_REGEN_GOLDENS") == "1":
            with open(GOLDEN_PATH, "w") as fh:
                json.dump(names, fh, indent=2)
        with open(GOLDEN_PATH) as fh:
            golden = json.load(fh)
        assert names == golden, (
            "metrics snapshot schema drifted from the golden key set; "
            "if the stats-surface change is intentional, regenerate "
            "with REPRO_REGEN_GOLDENS=1")
