from .adamw import AdamW, AdamWState, all_finite, global_norm  # noqa: F401
from .loss_scale import (  # noqa: F401
    LossScaleState,
    init_loss_scale,
    loss_scaling_required,
    scale_loss,
    unscale_grads,
    update_loss_scale,
)
from .grad_comm import (  # noqa: F401
    compress_tree,
    decompress_tree,
    make_dp_allreduce,
    psum_compressed,
)
