"""Render the roofline table from benchmarks/results/dryrun.json."""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun.json")


def rows(mesh: str = "16x16"):
    if not os.path.exists(RESULTS):
        return []
    with open(RESULTS) as f:
        data = json.load(f)
    out = []
    for r in sorted(data, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        if r.get("status") == "skipped":
            out.append((r["arch"], r["shape"], "SKIP", r.get("reason", "")))
            continue
        if r.get("status") != "ok":
            out.append((r["arch"], r["shape"], "FAIL", r.get("error", "")[:60]))
            continue
        roof = r["roofline"]
        mf = r.get("model_flops_6nd")
        useful = r.get("useful_flops_ratio")
        out.append((
            r["arch"], r["shape"], roof["dominant"],
            f"compute={roof['compute_s']:.3g}s memory={roof['memory_s']:.3g}s "
            f"collective={roof['collective_s']:.3g}s"
            + (f" useful6ND={useful:.2f}" if useful else ""),
        ))
    return out


def main():
    print("arch,shape,dominant,terms")
    for r in rows():
        print(",".join(str(x) for x in r))


if __name__ == "__main__":
    main()
