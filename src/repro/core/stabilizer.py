"""Pre-FFT numerical stabilisers (paper Section 4.3, Appendix B.5/B.6).

Naïve half-precision FNO overflows (NaN) on every dataset the paper tries.
Global (post-forward) remedies — loss scaling, gradient clipping, delayed
updates — all diverge (Fig. 10) because they never touch the forward FFT
overflow inside the FNO block.  The fix is a *local* pre-activation before
each forward FFT; ``tanh`` wins (Table 3): it is ~identity near 0, smooth,
and provably shrinks both the sup-norm M and the Lipschitz constant L that
appear in the Theorem 3.1/3.2 bounds — so it tightens the very quantities
the theory says control the error.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax.numpy as jnp


def tanh_stabilizer(x: jnp.ndarray) -> jnp.ndarray:
    """The paper's choice.  |tanh(x)| <= 1 bounds the FFT input so the half
    dynamic range (65504 for fp16) can never overflow; near 0 it is the
    identity so small signals are untouched."""
    return jnp.tanh(x)


def hard_clip_stabilizer(x: jnp.ndarray, limit: float = 3.0) -> jnp.ndarray:
    """hard-clip baseline from Table 3."""
    return jnp.clip(x, -limit, limit)


def sigma_clip_stabilizer(x: jnp.ndarray, k: float = 2.0) -> jnp.ndarray:
    """2σ-clip baseline from Table 3: clip to mean ± k·std (per sample)."""
    axes = tuple(range(1, x.ndim))
    mu = jnp.mean(x, axis=axes, keepdims=True)
    sd = jnp.std(x, axis=axes, keepdims=True)
    return jnp.clip(x, mu - k * sd, mu + k * sd)


def fixed_scale_stabilizer(x: jnp.ndarray, divisor: float = 10.0) -> jnp.ndarray:
    """Pointwise division baseline (Appendix B.6) — shown to squash normal
    data into a range half precision cannot distinguish; kept for ablations."""
    return x / divisor


STABILIZERS = {
    None: lambda x: x,
    "none": lambda x: x,
    "tanh": tanh_stabilizer,
    "hard_clip": hard_clip_stabilizer,
    "sigma_clip": sigma_clip_stabilizer,
    "fixed_scale": fixed_scale_stabilizer,
}


def get_stabilizer(name: Optional[str]) -> Callable[[jnp.ndarray], jnp.ndarray]:
    try:
        return STABILIZERS[name]
    except KeyError:
        raise KeyError(
            f"unknown stabilizer {name!r}; have {sorted(k for k in STABILIZERS if k)}"
        ) from None
