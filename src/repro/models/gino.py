"""Geometry-Informed Neural Operator (Li et al. 2023).

GNO encoder (irregular mesh -> regular latent grid) -> 3-D FNO on the
latent grid -> GNO decoder (latent grid -> query points) -> pressure head.

JAX adaptation (DESIGN.md §7): the radius graphs are realised as fixed-k
neighbour candidate lists precomputed by the data pipeline (static shapes
for jit), with a radius mask applied on top.  The kernel integral
  (K f)(x) = ∫_{B_r(x)} κ(x, y) f(y) dy
becomes a masked mean over the k candidates with κ an MLP on [x, y].
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import PrecisionPolicy, FULL
from .fno import FNOConfig, fno_apply, init_fno, _linear, _linear_init


@dataclasses.dataclass(frozen=True)
class GINOConfig:
    in_features: int = 1          # per-point input features (e.g. normals dot)
    out_features: int = 1         # predicted field (pressure)
    hidden: int = 32
    latent_grid: int = 16         # latent cube resolution G (G^3 nodes)
    k_neighbors: int = 8
    fno: FNOConfig = dataclasses.field(
        default_factory=lambda: FNOConfig(
            in_channels=32, out_channels=32, hidden_channels=48,
            lifting_channels=48, projection_channels=48,
            n_layers=4, modes=(8, 8, 8), positional_embedding=False,
        )
    )


def init_gino(key: jax.Array, cfg: GINOConfig) -> dict:
    keys = jax.random.split(key, 8)
    h = cfg.hidden
    return {
        # edge kernels: κ(x, y, f) — MLP on [x(3), y(3), feats]
        "enc_k1": _linear_init(keys[0], 6 + cfg.in_features, h),
        "enc_k2": _linear_init(keys[1], h, cfg.fno.in_channels),
        "dec_k1": _linear_init(keys[2], 6 + cfg.fno.out_channels, h),
        "dec_k2": _linear_init(keys[3], h, h),
        "head1": _linear_init(keys[4], h, h),
        "head2": _linear_init(keys[5], h, cfg.out_features),
        "fno": init_fno(keys[6], cfg.fno),
    }


def _latent_coords(G: int) -> jnp.ndarray:
    t = jnp.linspace(0.0, 1.0, G)
    gx, gy, gz = jnp.meshgrid(t, t, t, indexing="ij")
    return jnp.stack([gx, gy, gz], axis=-1).reshape(G ** 3, 3)


def _gno_aggregate(p1, p2, x_to, x_from, feats, idx, mask, dtype):
    """Masked-mean kernel aggregation.

    x_to:   (Nt, 3) destination coords.
    x_from: (Nf, 3) source coords.
    feats:  (Nf, F) source features.
    idx:    (Nt, k) candidate source indices.
    mask:   (Nt, k) 1.0 where the candidate is inside the radius ball.
    """
    nbr_x = x_from[idx]          # (Nt, k, 3)
    nbr_f = feats[idx]           # (Nt, k, F)
    dest = jnp.broadcast_to(x_to[:, None, :], nbr_x.shape)
    edge_in = jnp.concatenate([dest, nbr_x, nbr_f], axis=-1)
    e = _linear(p1, edge_in, dtype)
    e = jax.nn.gelu(e)
    e = _linear(p2, e, dtype)
    m = mask[..., None].astype(dtype)
    return (e * m).sum(axis=1) / jnp.maximum(m.sum(axis=1), 1.0)


def gino_apply(
    params: dict,
    batch: dict,
    cfg: GINOConfig,
    policy: PrecisionPolicy = FULL,
) -> jnp.ndarray:
    """batch (all per-sample, vmapped over the leading batch axis):
      points     (B, N, 3)    surface mesh vertices in [0,1]^3
      feats      (B, N, Fin)  per-point input features
      enc_idx    (B, G^3, k)  candidate point indices per latent node
      enc_mask   (B, G^3, k)
      query      (B, Nq, 3)   output query points
      dec_idx    (B, Nq, k)   candidate latent-node indices per query
      dec_mask   (B, Nq, k)
    Returns (B, Nq, out_features).
    """
    cdt = policy.at("gino/dense").compute_dtype
    head_dt = policy.at("gino/proj_out").compute_dtype
    G = cfg.latent_grid
    lat_xyz = _latent_coords(G)

    def one(points, feats, enc_idx, enc_mask, query, dec_idx, dec_mask):
        lat = _gno_aggregate(
            params["enc_k1"], params["enc_k2"], lat_xyz, points, feats,
            enc_idx, enc_mask, cdt,
        )  # (G^3, C)
        lat = lat.T.reshape(1, cfg.fno.in_channels, G, G, G)
        lat = fno_apply(params["fno"], lat, cfg.fno, policy)[0]
        lat = lat.reshape(cfg.fno.out_channels, G ** 3).T  # (G^3, C)
        out = _gno_aggregate(
            params["dec_k1"], params["dec_k2"], query, lat_xyz, lat,
            dec_idx, dec_mask, cdt,
        )
        out = jax.nn.gelu(_linear(params["head1"], out, cdt))
        return _linear(params["head2"], out, head_dt)

    return jax.vmap(one)(
        batch["points"], batch["feats"], batch["enc_idx"], batch["enc_mask"],
        batch["query"], batch["dec_idx"], batch["dec_mask"],
    )
