"""repro.precision — site-addressed mixed-precision rules.

The precision twin of ``repro.dist``: one rule table mapping site
patterns (``"*/spectral/contract"``, ``"serve/kv_cache"``, …) onto
formats, resolved per call-site via ``policy.at(site)`` and overridable
for a scope with ``precision_rules(...)``.

Public API:
  PrecisionPolicy / get_policy / POLICIES   — named rule sets
  SitePrecision                             — resolved site (cast /
                                              stabilize / quantize /
                                              contract helpers)
  SiteRule / FULL_PRECISION / DEFAULT_RULES — rule-table entries
  precision_rules(...)                      — scoped overrides
  describe(policy)                          — canonical-site report
"""
from .rules import (  # noqa: F401
    DEFAULT_RULES,
    FULL_PRECISION,
    SiteRule,
    UNSET,
    current_overrides,
    precision_rules,
    site_matches,
)
from .policy import (  # noqa: F401
    AMP_BF16,
    AMP_FP16,
    CANONICAL_SITES,
    FULL,
    HALF_FNO_ONLY,
    MIXED_FNO_BF16,
    MIXED_FNO_FP16,
    POLICIES,
    SIM_FP8_E4M3,
    SIM_FP8_E5M2,
    PrecisionPolicy,
    SitePrecision,
    describe,
    get_policy,
    resolve_site,
)
