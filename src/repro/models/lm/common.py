"""Shared LM machinery: RMSNorm, RoPE, SwiGLU, blocked attention.

Attention comes in three executable forms:
  * plain (small seq; exact reference),
  * blocked two-level scan (prefill/train at 4k-32k: O(S) memory via
    online softmax over (q-chunk × kv-chunk) tiles — the pure-JAX mirror
    of the Pallas flash kernel, used where interpret-mode Pallas would be
    too slow / not lowerable inside pjit),
  * decode (one query against a KV cache, optionally ring-buffered SWA).

All matmuls take ``preferred_element_type=f32`` (MXU accumulate) with
storage at the dtype the caller resolved from the ``lm/dense`` precision
site — these helpers are below the rule table and never consult it.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps)).astype(x.dtype) * w.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., S, D) with positions (..., S) or (S,)."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)  # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _mask_scores(s, q_pos, k_pos, window):
    """Causal + sliding-window mask. window may be a traced scalar;
    window >= seq acts as full attention."""
    causal = q_pos[:, None] >= k_pos[None, :]
    inwin = (q_pos[:, None] - k_pos[None, :]) < window
    return jnp.where(causal & inwin, s, NEG_INF)


def plain_attention(q, k, v, q_pos, k_pos, window) -> jnp.ndarray:
    """q: (B,H,S,D), k/v: (B,H,Sk,D). Exact reference path (small S)."""
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k, preferred_element_type=jnp.float32) * scale
    s = _mask_scores(s, q_pos, k_pos, window)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


def blocked_attention(
    q, k, v, q_pos, k_pos, window, q_chunk: int = 0, k_chunk: int = 512
) -> jnp.ndarray:
    """Flash attention in pure JAX: q chunks are a *batched* (shardable)
    dim; a single lax.scan streams kv chunks with online softmax.

    Sharding: heads go to the ``model`` mesh axis when divisible; otherwise
    the q-chunk axis does (context parallelism) — this is what keeps e.g.
    smollm's 15 heads from replicating S² attention on every device
    (EXPERIMENTS.md §Perf iteration 2).  q_chunk defaults to S/model_size
    (capped at 512) so the chunk grid aligns with the sequence sharding.

    Memory per kv step: (B,H,nq,Tq,Tk)/shards scores — O(S·Tk) not O(S²).
    Blocks entirely outside the causal/window band still execute (masked).
    """
    from repro.dist.constrain import constrain, logical_axis_size

    B, H, S, D = q.shape
    Dv = v.shape[-1]   # MLA: v head dim != q/k head dim
    Sk = k.shape[2]
    msize = logical_axis_size("heads")
    if q_chunk <= 0:
        q_chunk = max(64, min(512, S // max(msize, 1)))
    pad_q = (-S) % q_chunk
    pad_k = (-Sk) % k_chunk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad_q), constant_values=-1)
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad_k), constant_values=2 ** 30)
    nq, nk = q.shape[2] // q_chunk, k.shape[2] // k_chunk
    scale = 1.0 / (D ** 0.5)

    qb = q.reshape(B, H, nq, q_chunk, D)
    shard_heads = (H % max(msize, 1)) == 0
    if shard_heads:
        qb = constrain(qb, "dp", "heads", None, None, None)
    else:
        qb = constrain(qb, "dp", None, "seq", None, None)
    kb = k.reshape(B, H, nk, k_chunk, D).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(B, H, nk, k_chunk, Dv).transpose(2, 0, 1, 3, 4)
    qpb = q_pos.reshape(nq, q_chunk)
    kpb = k_pos.reshape(nk, k_chunk)

    def kv_step(carry, kv_in):
        acc, m, l = carry
        ki, vi, kp = kv_in  # (B,H,Tk,D), (Tk,)
        s = jnp.einsum("bhntd,bhkd->bhntk", qb, ki,
                       preferred_element_type=jnp.float32) * scale
        causal = qpb[:, :, None] >= kp[None, None, :]
        inwin = (qpb[:, :, None] - kp[None, None, :]) < window
        s = jnp.where((causal & inwin)[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhntk,bhkd->bhntd", p.astype(vi.dtype), vi,
            preferred_element_type=jnp.float32,
        )
        return (acc, m_new, l), None

    acc0 = jnp.zeros((B, H, nq, q_chunk, Dv), jnp.float32)
    m0 = jnp.full((B, H, nq, q_chunk), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, nq, q_chunk), jnp.float32)
    kv_step = jax.checkpoint(kv_step)  # flash bwd: recompute p per block
    (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0), (kb, vb, kpb))
    out = (acc / jnp.maximum(l[..., None], 1e-30)).astype(q.dtype)
    out = out.reshape(B, H, nq * q_chunk, Dv)
    return out[:, :, :S, :]


def gqa_attention(
    q, k, v, q_pos, k_pos, window, *, blocked_threshold: int = 1024
) -> jnp.ndarray:
    """GQA: q (B,Hq,S,D); k/v (B,Hkv,Sk,D) with Hq = g*Hkv; repeats kv."""
    Hq, Hkv = q.shape[1], k.shape[1]
    if Hq != Hkv:
        g = Hq // Hkv
        k = jnp.repeat(k, g, axis=1)
        v = jnp.repeat(v, g, axis=1)
    fn = blocked_attention if q.shape[2] >= blocked_threshold else plain_attention
    return fn(q, k, v, q_pos, k_pos, window)


def chunk_attention(q, k_cache, v_cache, k_pos, q_pos, window) -> jnp.ndarray:
    """K-query cache attention: q (B,H,K,D) vs cache (B,Hkv,S,D).

    The serve-side generalisation of one-token decode to a *chunk* of K
    queries (chunked batched prefill): every query row attends the same
    cache, masked per-query by position.  k_pos: (B, S) per-slot cache
    positions (-1 => empty; supports ring-buffer SWA caches), q_pos:
    (B, K) per-query absolute positions (continuous batching: every
    request tracks its own clock).  Linear in S per query.

    With K=1 this is exactly the old ``decode_attention`` — the masked
    columns contribute an exact 0.0 after ``exp``, so chunked and
    token-by-token cache attention produce bit-identical rows.
    """
    Hq, Hkv = q.shape[1], k_cache.shape[1]
    if Hq != Hkv:
        g = Hq // Hkv
        k_cache = jnp.repeat(k_cache, g, axis=1)
        v_cache = jnp.repeat(v_cache, g, axis=1)
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k_cache,
                   preferred_element_type=jnp.float32) * scale
    qp = q_pos[:, :, None]                                     # (B, K, 1)
    kp = k_pos[:, None, :]                                     # (B, 1, S)
    valid = (kp >= 0) & (kp <= qp) & ((qp - kp) < window)      # (B, K, S)
    s = jnp.where(valid[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v_cache.dtype), v_cache,
                      preferred_element_type=jnp.float32).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, k_pos, q_pos, window) -> jnp.ndarray:
    """One-token decode: q (B,H,1,D) vs cache; q_pos (B,) per-slot clocks.
    The K=1 special case of :func:`chunk_attention`."""
    return chunk_attention(q, k_cache, v_cache, k_pos, q_pos[:, None], window)


def apply_rope_one(x: jnp.ndarray, pos: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """RoPE for one decode token: x (B, H, D), pos (B,)."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)
    ang = pos[:, None, None].astype(jnp.float32) * freqs  # (B, 1, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_rope_chunk(x: jnp.ndarray, pos: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """RoPE for a prefill chunk: x (B, H, K, D), pos (B, K) per-slot
    absolute positions (continuous batching: slots sit at different
    offsets, so positions can't be a shared (S,) range)."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)
    ang = pos[:, None, :, None].astype(jnp.float32) * freqs  # (B, 1, K, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def init_swiglu(key, d_model, d_ff):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = (1.0 / d_model) ** 0.5
    s_out = (1.0 / d_ff) ** 0.5
    return {
        "wg": s_in * jax.random.normal(k1, (d_model, d_ff), jnp.float32),
        "wu": s_in * jax.random.normal(k2, (d_model, d_ff), jnp.float32),
        "wd": s_out * jax.random.normal(k3, (d_ff, d_model), jnp.float32),
    }


def swiglu(p, x, dtype):
    g = jnp.einsum("...d,df->...f", x.astype(dtype), p["wg"].astype(dtype),
                   preferred_element_type=jnp.float32).astype(dtype)
    u = jnp.einsum("...d,df->...f", x.astype(dtype), p["wu"].astype(dtype),
                   preferred_element_type=jnp.float32).astype(dtype)
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, p["wd"].astype(dtype),
                      preferred_element_type=jnp.float32).astype(dtype)
