"""Spherical FNO (Bonev et al. 2023) on our own SHT substrate.

Block: SHT -> truncate to (lmax, mmax) -> per-degree channel contraction
``bilm,iol->bolm`` (weights shared over order m, per the spherical
convolution theorem) -> iSHT, plus a pointwise skip, GELU.

The Legendre transforms and the spectral contraction are GEMMs, so the
paper's mixed-precision pipeline applies verbatim: tanh pre-activation
before the SHT, half-precision storage of the spherical spectrum
(boundary-quantised), contraction at half with f32 accumulation.  Every
stage resolves its format through the precision rule table at the
``sfno/layer<i>/spectral/*`` sites — the stabilise->quantise sequence is
the shared site helpers, not an inline re-implementation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import PrecisionPolicy, FULL, ComplexPair
from repro.dist.constrain import constrain_spatial
from .fno import _linear, _linear_init, apply_block_loop
from .sht import sht_forward, sht_inverse


@dataclasses.dataclass(frozen=True)
class SFNOConfig:
    in_channels: int = 3
    out_channels: int = 3
    hidden_channels: int = 64
    n_layers: int = 4
    nlat: int = 64
    nlon: int = 128
    lmax: int = 32
    mmax: int = 32
    lifting_channels: int = 128
    projection_channels: int = 128
    #: Tri-state like FNOConfig: None = auto (TPU / REPRO_USE_PALLAS=1).
    use_pallas: Optional[bool] = None


def init_sfno(key: jax.Array, cfg: SFNOConfig) -> dict:
    keys = jax.random.split(key, 5)
    params = {
        "lift1": _linear_init(keys[0], cfg.in_channels, cfg.lifting_channels),
        "lift2": _linear_init(keys[1], cfg.lifting_channels, cfg.hidden_channels),
        "proj1": _linear_init(keys[2], cfg.hidden_channels, cfg.projection_channels),
        "proj2": _linear_init(keys[3], cfg.projection_channels, cfg.out_channels),
    }
    h = cfg.hidden_channels
    scale = 1.0 / h
    lkeys = jax.random.split(keys[4], cfg.n_layers)
    ws, skips = [], []
    for lk in lkeys:
        k1, k2, k3 = jax.random.split(lk, 3)
        ws.append(
            {
                "w_re": scale * jax.random.normal(k1, (h, h, cfg.lmax), jnp.float32),
                "w_im": scale * jax.random.normal(k2, (h, h, cfg.lmax), jnp.float32),
            }
        )
        skips.append(_linear_init(k3, h, h))
    params["spectral"] = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ws)
    params["skips"] = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *skips)
    return params


def _spherical_conv(h, w, cfg: SFNOConfig, policy: PrecisionPolicy,
                    site: str = "sfno/layer0/spectral"):
    """h: (B, C, nlat, nlon) -> (B, C, nlat, nlon) via spherical spectrum."""
    fft_in = policy.at(f"{site}/fft_in")
    ctr = policy.at(f"{site}/contract")
    fft_out = policy.at(f"{site}/fft_out")
    coeffs = sht_forward(fft_in.stabilize(h).astype(jnp.float32),
                         cfg.lmax, cfg.mmax, precision=fft_in)  # (B,C,l,m)
    wc = jax.lax.complex(w["w_re"], w["w_im"])  # (i, o, l)
    from repro.kernels.ops import resolve_use_pallas

    if resolve_use_pallas(cfg.use_pallas):
        from repro.kernels import ops as kops

        # the spherical weight is shared over order m (per the spherical
        # convolution theorem): the l-shared kernel tiles over degrees
        # and never materialises the dense (i, o, l, m) weight
        out = kops.spectral_contract_lshared(coeffs, wc, policy=ctr)
    else:
        out = ctr.contract("bilm,iol->bolm", coeffs, wc)
    if isinstance(out, ComplexPair):
        out = out.to_complex()
    # named_scope: repro.analyze attributes the inverse-transform and
    # storage-cast eqns to the fft_out site (as core/spectral.py does)
    with jax.named_scope(f"{site}/fft_out"):
        y = sht_inverse(out.astype(jnp.complex64), cfg.nlat, cfg.nlon)
        from repro.autoprec.telemetry import fmt_of, tap

        tap(f"{site}/fft_out", y, fmt=fmt_of(fft_out))
        if fft_out.spectral_is_half:
            y = y.astype(fft_out.compute_dtype)
        return y


def sfno_apply(
    params: dict, x: jnp.ndarray, cfg: SFNOConfig, policy: PrecisionPolicy = FULL
) -> jnp.ndarray:
    """x: (B, in_channels, nlat, nlon) -> (B, out_channels, nlat, nlon)."""
    cdt = policy.at("sfno/dense").compute_dtype
    h = jnp.moveaxis(x, 1, -1)
    h = _linear(params["lift1"], h, cdt)
    h = jax.nn.gelu(h)
    h = _linear(params["lift2"], h, cdt)
    h = jnp.moveaxis(h, -1, 1)

    def block(h, layer, layer_idx: int):
        h = constrain_spatial(h)
        w, skip = layer
        ldt = policy.at(f"sfno/layer{layer_idx}/dense").compute_dtype
        y = _spherical_conv(h, w, cfg, policy,
                            site=f"sfno/layer{layer_idx}/spectral").astype(ldt)
        s = jnp.moveaxis(_linear(skip, jnp.moveaxis(h, 1, -1), ldt), -1, 1)
        return jax.nn.gelu(y + s)

    h = h.astype(cdt)
    h = apply_block_loop(block, h, (params["spectral"], params["skips"]),
                         policy, "sfno", cfg.n_layers)

    h = jnp.moveaxis(h, 1, -1)
    h = _linear(params["proj1"], h, cdt)
    h = jax.nn.gelu(h)
    h = _linear(params["proj2"], h, policy.at("sfno/proj_out").compute_dtype)
    return jnp.moveaxis(h, -1, 1)


def sfno_infer(
    params: dict, x: jnp.ndarray, cfg: SFNOConfig, policy: PrecisionPolicy = FULL
) -> jnp.ndarray:
    """Batched-inference entry point for serving (see ``fno_infer``):
    (B, in_channels, nlat, nlon) -> (B, out_channels, nlat, nlon) at the
    ``serve/operator`` transport dtype."""
    y = sfno_apply(params, x, cfg, policy)
    return y.astype(policy.at("serve/operator").compute_dtype)
