"""Rules-based precision policies and resolved per-site precision.

``PrecisionPolicy`` is now a *named rule set* over the shared site table
(:mod:`repro.precision.rules`), replacing the flat 4-dtype dataclass.
``policy.at(site)`` resolves one site to a :class:`SitePrecision`
carrying the ``cast / stabilize / quantize / contract`` helpers every
consumer needs — models, kernels, trainer, serving and launch all speak
in sites and never hand-thread dtypes.

The registry policies (``full``, ``amp_*``, ``mixed_fno_*``,
``half_fno_only``) are rebuilt as rule sets that resolve to exactly the
same formats the old dataclass fields encoded, so their numerics are
bit-identical; the simulated fp8 formats (Appendix B.11) join the same
registry as ``sim_fp8_e4m3`` / ``sim_fp8_e5m2`` rule sets.

Canonical site vocabulary (patterns in the rule tables address these):

  ``<model>/dense``                 real-valued AMP set (lift, skips,
                                    projections, attention/FFN matmuls)
  ``<model>/layer<i>/spectral/fft_in``    stabilise + boundary-quantise
  ``<model>/layer<i>/spectral/contract``  spectral contraction storage/accum
  ``<model>/layer<i>/spectral/fft_out``   iFFT output storage
  ``<model>/proj_out``              output heads (f32 by default)
  ``lm/router``                     MoE router (f32 by default)
  ``serve/kv_cache``                KV-cache storage dtype
  ``serve/paged/kv_blocks``         paged KV block storage dtype
  ``serve/paged/pool``              block-pool gauges (telemetry tap)
  ``serve/sampler``                 sampling softmax/filter math (f32)
  ``serve/operator``                operator-inference transport dtype
  ``train/loss_scale``              dynamic-loss-scaling switch
  ``params``                        master weight storage
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from .rules import (
    Entry,
    SiteRule,
    normalize_entries,
    resolve_fields,
)


@dataclasses.dataclass(frozen=True)
class SitePrecision:
    """The fully-resolved precision of one site.

    Carries the four helpers the paper's pipeline needs — ``cast`` (AMP
    boundary), ``stabilize`` (pre-FFT), ``quantize`` (half/fp8 boundary
    rounding), ``contract`` (memory-greedy mixed-precision einsum) — and
    quacks like the old policy for the contraction executor
    (``spectral_dtype`` / ``spectral_is_half`` / ``accum_dtype``).
    """

    site: str = dataclasses.field(compare=False)
    compute: Optional[Any] = None
    accum: Any = jnp.float32
    stabilizer: Optional[str] = None
    quantize_fmt: Optional[str] = None
    loss_scaling: bool = False

    # -- dtype views ---------------------------------------------------------
    @property
    def compute_dtype(self):
        return self.compute if self.compute is not None else jnp.float32

    @property
    def accum_dtype(self):
        return self.accum

    @property
    def spectral_dtype(self):
        """Split-real storage dtype for spectral data; None => complex64."""
        return self.compute if self.quantize_fmt is not None else None

    @property
    def spectral_is_half(self) -> bool:
        return self.quantize_fmt is not None

    @property
    def eps(self) -> float:
        """Relative precision of this site's storage grid (theory checks)."""
        from repro.core.precision import FORMAT_EPS

        if self.quantize_fmt is not None and self.quantize_fmt != "half":
            return FORMAT_EPS[self.quantize_fmt]
        key = (
            jnp.dtype(self.spectral_dtype).name
            if self.spectral_dtype is not None
            else "float32"
        )
        return FORMAT_EPS[key]

    # -- helpers -------------------------------------------------------------
    def cast(self, tree):
        """Cast a pytree of real floating arrays to the compute dtype."""
        dt = self.compute_dtype

        def _c(x):
            if isinstance(x, jnp.ndarray) and jnp.issubdtype(x.dtype, jnp.floating):
                return x.astype(dt)
            return x

        return jax.tree_util.tree_map(_c, tree)

    def stabilize(self, x: jnp.ndarray) -> jnp.ndarray:
        """Apply the site's pre-FFT stabiliser.  Only active when the site
        actually quantises (matching the paper: the stabiliser exists to
        keep the *half* forward transform finite)."""
        if self.quantize_fmt is None or not self.stabilizer:
            return x
        from repro.core.stabilizer import get_stabilizer

        with jax.named_scope(self.site):
            return get_stabilizer(self.stabilizer)(x)

    def quantize(self, c: jnp.ndarray) -> jnp.ndarray:
        """Round a complex tensor onto this site's storage grid: half
        round-trip (Thm 3.2's representation error) or the simulated fp8
        grid (Appendix B.11).  Identity when the site is full precision.

        Feeds the autoprec telemetry tap either way (no-op unless a
        collector is in scope): the pre-quantisation values carry the
        site's true range — including for sites currently at f32, which
        is exactly what the controller needs to decide a demotion — and
        the post-quantisation values give the measured Thm 3.2 error."""
        from repro.autoprec.telemetry import fmt_of, tap

        if self.quantize_fmt is None:
            tap(self.site, c, fmt=fmt_of(self))
            return c
        from repro.core.precision import quantize_complex, simulate_fp8

        # named_scope: eqns traced under this site carry its address in
        # their name stack — repro.analyze attributes findings with it
        with jax.named_scope(self.site):
            if self.quantize_fmt == "half":
                q = quantize_complex(c, self.compute)
            else:
                re = simulate_fp8(jnp.real(c), self.quantize_fmt)
                im = simulate_fp8(jnp.imag(c), self.quantize_fmt)
                q = jax.lax.complex(re, im)
        tap(self.site, c, fmt=fmt_of(self), quantized=q)
        return q

    def contract(self, expr: str, *operands, objective: str = "memory", cache=None):
        """Memory-greedy contraction at this site's storage/accum dtypes."""
        from repro.autoprec.telemetry import fmt_of, tap
        from repro.core.contraction import contract as _contract

        if operands:
            # tap the activation operand against the contract site's
            # storage format (the site auto-precision demotes/promotes)
            tap(self.site, operands[0], fmt=fmt_of(self))
        with jax.named_scope(self.site):
            return _contract(
                expr, *operands, policy=self, objective=objective, cache=cache
            )


def resolve_site(site: str, rules: Tuple[Entry, ...]) -> SitePrecision:
    f = resolve_fields(site, rules)
    return SitePrecision(
        site=site,
        compute=f["compute"],
        accum=f["accum"],
        stabilizer=f["stabilize"],
        quantize_fmt=f["quantize"],
        loss_scaling=bool(f["loss_scaling"]),
    )


# ---------------------------------------------------------------------------
# PrecisionPolicy — a named rule set
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """A named overlay of site rules over the shared DEFAULT_RULES table.

    ``at(site)`` is the one resolution entry point; the legacy dtype
    properties (``compute_dtype`` / ``spectral_dtype`` / ``stabilizer`` /
    ``requires_loss_scaling``) are kept as *views* onto canonical sites
    so policy-level introspection (benchmarks, reports) still reads
    naturally — they resolve through the same tables, including any
    active ``precision_rules`` scope.
    """

    name: str
    rules: Tuple[Entry, ...] = ()

    def at(self, site: str) -> SitePrecision:
        return resolve_site(site, self.rules)

    def with_rules(self, *entries, name: Optional[str] = None) -> "PrecisionPolicy":
        """A new policy with ``entries`` layered on top (highest priority)."""
        return PrecisionPolicy(
            name=name or self.name, rules=normalize_entries(entries) + self.rules
        )

    # -- legacy facade -------------------------------------------------------
    @property
    def param_dtype(self):
        return self.at("params").compute_dtype

    @property
    def compute_dtype(self):
        return self.at("model/dense").compute_dtype

    @property
    def spectral_dtype(self):
        return self.at("model/spectral/contract").spectral_dtype

    @property
    def accum_dtype(self):
        return self.at("model/spectral/contract").accum_dtype

    @property
    def stabilizer(self):
        return self.at("model/spectral/fft_in").stabilizer

    @property
    def requires_loss_scaling(self) -> bool:
        return self.at("train/loss_scale").loss_scaling

    @property
    def spectral_is_half(self) -> bool:
        return self.at("model/spectral/contract").spectral_is_half

    @property
    def eps(self) -> float:
        return self.at("model/spectral/contract").eps

    def cast_compute(self, tree):
        return self.at("model/dense").cast(tree)

    def cast_spectral(self, c: jnp.ndarray):
        site = self.at("model/spectral/contract")
        if site.spectral_dtype is None:
            return c
        from repro.core.precision import ComplexPair

        return ComplexPair.from_complex(c, site.spectral_dtype)


# ---------------------------------------------------------------------------
# Registry: the paper's settings as rule sets over the shared table
# ---------------------------------------------------------------------------


def _amp_rules(half) -> Tuple[Entry, ...]:
    return (
        ("*/dense", SiteRule(compute=half)),
        ("serve/kv_cache", SiteRule(compute=half)),
        # paged KV blocks follow the dense cache's storage format so the
        # paged and dense serving paths stay bit-identical per policy
        ("serve/paged/kv_blocks", SiteRule(compute=half)),
    )


def _spectral_rules(half, quantize: str = "half") -> Tuple[Entry, ...]:
    return (("*/spectral/*", SiteRule(compute=half, quantize=quantize, stabilize="tanh")),)


_SCALE = (("train/loss_scale", SiteRule(loss_scaling=True)),)

FULL = PrecisionPolicy(name="full")
AMP_FP16 = PrecisionPolicy(name="amp_fp16", rules=_amp_rules(jnp.float16) + _SCALE)
AMP_BF16 = PrecisionPolicy(name="amp_bf16", rules=_amp_rules(jnp.bfloat16))
MIXED_FNO_FP16 = PrecisionPolicy(
    name="mixed_fno_fp16",
    rules=_spectral_rules(jnp.float16) + _amp_rules(jnp.float16) + _SCALE,
)
MIXED_FNO_BF16 = PrecisionPolicy(
    name="mixed_fno_bf16",
    rules=_spectral_rules(jnp.bfloat16) + _amp_rules(jnp.bfloat16),
)
# FNO block half, rest full — the "Half-Prec FNO only" bar in Fig. 3.
HALF_FNO_ONLY = PrecisionPolicy(
    name="half_fno_only", rules=_spectral_rules(jnp.float16) + _SCALE
)
# Simulated fp8 spectral pipelines (Appendix B.11): split-real fp16
# storage whose values are rounded onto the fp8 grid at the FFT boundary.
SIM_FP8_E4M3 = PrecisionPolicy(
    name="sim_fp8_e4m3",
    rules=_spectral_rules(jnp.float16, quantize="fp8_e4m3") + _SCALE,
)
SIM_FP8_E5M2 = PrecisionPolicy(
    name="sim_fp8_e5m2",
    rules=_spectral_rules(jnp.float16, quantize="fp8_e5m2") + _SCALE,
)

POLICIES = {
    p.name: p
    for p in [
        FULL,
        AMP_FP16,
        AMP_BF16,
        MIXED_FNO_FP16,
        MIXED_FNO_BF16,
        HALF_FNO_ONLY,
        SIM_FP8_E4M3,
        SIM_FP8_E5M2,
    ]
}


def get_policy(name: str) -> PrecisionPolicy:
    try:
        return POLICIES[name]
    except KeyError:
        raise KeyError(
            f"unknown precision policy {name!r}; have {sorted(POLICIES)}"
        ) from None


#: Sites worth surfacing in reports / dry-run records.
CANONICAL_SITES = (
    "params",
    "model/dense",
    "model/spectral/fft_in",
    "model/spectral/contract",
    "model/spectral/fft_out",
    "model/proj_out",
    "lm/router",
    "serve/kv_cache",
    "serve/paged/kv_blocks",
    "serve/paged/pool",
    "serve/sampler",
    "serve/operator",
    "train/loss_scale",
)


def describe(policy: PrecisionPolicy) -> dict:
    """Human/JSON-friendly site table for a policy — what the dry-runs log
    so a lowered cell records exactly which sites ran at which formats."""
    out = {}
    for site in CANONICAL_SITES:
        s = policy.at(site)
        out[site] = {
            "compute": None if s.compute is None else jnp.dtype(s.compute).name,
            "accum": jnp.dtype(s.accum).name,
            "stabilize": s.stabilizer,
            "quantize": s.quantize_fmt,
            "loss_scaling": s.loss_scaling,
        }
    return out
