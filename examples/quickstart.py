"""Quickstart: the paper's mixed-precision FNO in ~40 lines.

Builds a small FNO, runs it under the full-precision and mixed-precision
policies, shows the memory-greedy contraction and the tanh stabiliser in
action, and verifies Theorem 3.1/3.2 empirically.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    FULL, get_policy, greedy_path, path_intermediate_bytes, theory,
)
from repro.models import FNOConfig, fno_apply, init_fno

# 1. a small FNO
cfg = FNOConfig(in_channels=1, out_channels=1, hidden_channels=32,
                lifting_channels=32, projection_channels=32,
                n_layers=4, modes=(12, 12))
params = init_fno(jax.random.PRNGKey(0), cfg)
x = jnp.asarray(np.random.RandomState(0).randn(4, 1, 64, 64), jnp.float32)

# 2. full vs mixed precision forward
y_full = fno_apply(params, x, cfg, FULL)
y_mixed = fno_apply(params, x, cfg, get_policy("mixed_fno_bf16"))
rel = float(jnp.linalg.norm(y_mixed.astype(jnp.float32) - y_full)
            / jnp.linalg.norm(y_full))
print(f"mixed-vs-full relative error: {rel:.4f}  (paper: <1%)")

# 3. the memory-greedy contraction order (paper §4.2 / Table 10)
expr = "bixy,r,ir,or,xr,yr->boxy"   # TFNO CP contraction
shapes = [(4, 32, 12, 12), (16,), (32, 16), (32, 16), (12, 16), (12, 16)]
p_mem = greedy_path(expr, shapes, "memory")
p_flop = greedy_path(expr, shapes, "flops")
print(f"greedy-memory path {p_mem}: peak intermediate "
      f"{path_intermediate_bytes(expr, shapes, p_mem)} B vs FLOP-optimal "
      f"{path_intermediate_bytes(expr, shapes, p_flop)} B")

# 4. theory: precision error is dominated by discretisation error
v = lambda xs: np.sin(2 * np.pi * xs[..., 0]) + 0.5 * np.prod(xs, axis=-1)
disc = theory.disc_error(v, m=64, d=2, omega=1.0)
prec = theory.prec_error(v, m=64, d=2, omega=1.0, dtype="float16")
print(f"disc error {disc:.2e} vs fp16 precision error {prec:.2e} "
      f"-> half precision is 'free' (Thm 3.1/3.2)")
print(f"3-D crossover mesh size for fp16: "
      f"{theory.crossover_mesh_size(1e-4, 3):.2e} points (paper: ~1e6)")
