"""Observability overhead guard: obs-on trainer steps must stay within
5% of obs-off.

The design promise of ``repro.obs`` is that the trace layer is free when
disabled and near-free when enabled (preallocated ring, one lock per
record, no allocation off the hot path).  This bench holds it to the
number: alternating obs-off / obs-on legs of an identical tiny-FNO
training run, per-step wall from the trainer's own history (the same
``t0..dt`` window in both modes — the spans sit inside it, so the obs-on
median carries their cost), best-of-medians across repeats to shed
scheduler noise.

    PYTHONPATH=src python -m benchmarks.bench_obs [--max-overhead 0.05]

Results land in ``benchmarks/results/obs_overhead.json``; exits nonzero
when the overhead budget is blown.
"""
from __future__ import annotations

import argparse
import os
import statistics

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import FNOConfig, fno_apply, init_fno
from repro.obs import registry, trace
from repro.train import Trainer, TrainerConfig, relative_l2

RESULTS = os.path.join(os.path.dirname(__file__), "results",
                       "obs_overhead.json")


def _problem():
    cfg = FNOConfig(in_channels=1, out_channels=1, hidden_channels=8,
                    lifting_channels=8, projection_channels=8,
                    n_layers=2, modes=(4, 4))
    params = init_fno(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(4, 1, 16, 16), jnp.float32)
    t = jnp.asarray(rng.randn(4, 1, 16, 16) * 0.1, jnp.float32)

    def loss_fn(p, batch, policy):
        return relative_l2(fno_apply(p, batch["x"], cfg, policy),
                           batch["t"])

    return params, loss_fn, {"x": x, "t": t}


def run_leg(obs_on: bool, steps: int, warmup: int) -> float:
    """Median post-warmup step wall (seconds) of one training leg."""
    params, loss_fn, batch = _problem()
    trainer = Trainer(loss_fn, params,
                      TrainerConfig(total_steps=steps, obs=obs_on))
    if not obs_on:
        trace.disable()
    hist = trainer.run(lambda _step: batch)
    trace.disable()
    trace.clear()
    return statistics.median(h["dt"] for h in hist[warmup:])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--warmup", type=int, default=10,
                    help="leading steps dropped from each leg's median "
                         "(compile + cache warm)")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--max-overhead", type=float, default=0.05)
    args = ap.parse_args()

    # counters accumulated during the legs are bench-local noise
    registry().reset()

    med_off, med_on = [], []
    for r in range(args.repeats):
        med_off.append(run_leg(False, args.steps, args.warmup))
        med_on.append(run_leg(True, args.steps, args.warmup))
        print(f"repeat {r}: off={med_off[-1] * 1e3:.3f}ms "
              f"on={med_on[-1] * 1e3:.3f}ms")

    best_off, best_on = min(med_off), min(med_on)
    overhead = best_on / best_off - 1.0
    ok = overhead <= args.max_overhead

    report = {
        "steps": args.steps,
        "warmup": args.warmup,
        "repeats": args.repeats,
        "median_step_wall_s": {"obs_off": med_off, "obs_on": med_on},
        "best_median_s": {"obs_off": best_off, "obs_on": best_on},
        "overhead": round(overhead, 6),
        "max_overhead": args.max_overhead,
        "ok": ok,
    }
    from benchmarks.common import write_result

    write_result(RESULTS, report)
    print(f"obs overhead: {overhead * 100:+.2f}% "
          f"(budget {args.max_overhead * 100:.0f}%) -> "
          f"{'OK' if ok else 'OVER BUDGET'}")
    print(f"results -> {RESULTS}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
