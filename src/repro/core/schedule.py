"""Precision scheduling (paper Section 4.4, Table 1).

The paper's schedule: first 25% of training fully mixed (half FNO block +
AMP), middle 50% AMP only, final 25% full precision.  Intuition: early
gradients are large and tolerate coarse arithmetic; late-training updates
are small and benefit from full precision.  The scheduled run *beats* the
full-precision baseline on zero-shot super-resolution (Table 1).

Because a precision change alters compiled dtypes, each phase owns its own
jitted train step; the trainer swaps steps at phase boundaries (cheap: at
most ``len(phases)-1`` recompiles per run).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

from .precision import PrecisionPolicy, get_policy


@dataclasses.dataclass(frozen=True)
class PrecisionSchedule:
    """Piecewise-constant policy over normalised training progress.

    ``phases`` is a tuple of (end_fraction, policy_name), end-exclusive and
    strictly increasing, final end_fraction == 1.0.
    """

    phases: Tuple[Tuple[float, str], ...]

    def __post_init__(self):
        ends = [e for e, _ in self.phases]
        if sorted(ends) != ends or ends[-1] != 1.0:
            raise ValueError(f"phase ends must increase to 1.0, got {ends}")

    def policy_at(self, step: int, total_steps: int) -> PrecisionPolicy:
        frac = (step + 0.5) / max(total_steps, 1)
        for end, name in self.phases:
            if frac < end:
                return get_policy(name)
        return get_policy(self.phases[-1][1])

    def phase_boundaries(self, total_steps: int):
        """[(start_step, end_step, policy), ...] for trainer step swapping."""
        out = []
        prev = 0.0
        for end, name in self.phases:
            s, e = int(prev * total_steps), int(end * total_steps)
            if e > s:
                out.append((s, e, get_policy(name)))
            prev = end
        return out

    @classmethod
    def paper_default(cls, half: str = "fp16") -> "PrecisionSchedule":
        mixed = f"mixed_fno_{half}"
        amp = f"amp_{half}"
        return cls(phases=((0.25, mixed), (0.75, amp), (1.0, "full")))

    @classmethod
    def constant(cls, name: str) -> "PrecisionSchedule":
        return cls(phases=((1.0, name),))
