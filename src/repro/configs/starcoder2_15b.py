"""starcoder2-15b [dense] — GQA kv=4, RoPE.
[arXiv:2402.19173; hf]"""
from .base import LMArchConfig

CONFIG = LMArchConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4,
    d_ff=24576, vocab=49152, head_dim=128,
)

SMOKE = LMArchConfig(
    name="starcoder2-15b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=192, vocab=256, head_dim=16,
)
