"""Pallas TPU kernel for the mixed-precision spectral tensor contraction.

This is the paper's compute hot-spot (Appendix B.4: complex-valued tensor
contraction = 4 of the top-5 GPU kernels).  The GPU implementation uses
``view_as_real`` + cuBLAS half GEMMs; the TPU-native adaptation tiles the
contraction over *retained Fourier modes* into VMEM and issues, per tile,
a batched complex matmul as four real MXU matmuls with f32 accumulation:

    out[b,o,m] = Σ_i x[b,i,m] · w[i,o,m]          (complex, per mode m)

Layout decisions (HBM→VMEM→MXU):
  * modes are flattened to one axis ``M`` and tiled by ``block_m`` — each
    grid step holds (B·I + I·O + B·O)·block_m·2 half words in VMEM;
  * channels (I, O) are MXU-aligned by the wrapper (pad to multiples of 8;
    128 is the sweet spot for v5e) and contracted with
    ``preferred_element_type=float32`` so accumulation never happens in
    half precision — only *storage* is half, which is precisely the error
    model of Theorem 3.2;
  * the 4-multiply complex product (rr−ii, ri+ir) is used rather than
    Karatsuba 3-mult: on the MXU the extra multiply is free relative to
    the added adds/temporaries of the 3-mult form.

Validated against ``ref.spectral_contract_ref`` in interpret mode on CPU
(see tests/test_kernels.py); on TPU the same code path compiles natively.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xr_ref, xi_ref, wr_ref, wi_ref, or_ref, oi_ref):
    """One mode-tile step: batched (over modes) complex matmul.

    Refs (VMEM tiles):
      xr/xi: (B, I, TM)   wr/wi: (I, O, TM)   or/oi: (B, O, TM)
    """
    xr, xi = xr_ref[...], xi_ref[...]
    wr, wi = wr_ref[...], wi_ref[...]

    def bmm(a, b):
        # contract I; batch over the mode tile axis (last axis of both).
        # dot_general batch dims lead the output: (TM, B, O).
        return jax.lax.dot_general(
            a,
            b,
            dimension_numbers=(((1,), (0,)), ((2,), (2,))),
            preferred_element_type=jnp.float32,
        )

    rr = bmm(xr, wr)
    ii = bmm(xi, wi)
    ri = bmm(xr, wi)
    ir = bmm(xi, wr)
    out_re = jnp.transpose(rr - ii, (1, 2, 0))
    out_im = jnp.transpose(ri + ir, (1, 2, 0))
    or_ref[...] = out_re.astype(or_ref.dtype)
    oi_ref[...] = out_im.astype(oi_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "interpret", "out_dtype")
)
def spectral_contract_pallas(
    xr: jnp.ndarray,
    xi: jnp.ndarray,
    wr: jnp.ndarray,
    wi: jnp.ndarray,
    *,
    block_m: int = 64,
    interpret: bool = True,
    out_dtype=None,
) -> tuple:
    """Split-real complex contraction ``bim,iom->bom``.

    Args:
      xr/xi: (B, I, M) half (or f32) real/imag parts of the spectrum tile.
      wr/wi: (I, O, M) spectral weights.
      block_m: mode-tile size (VMEM working set scales linearly in it).
      interpret: run the kernel body in Python (CPU validation); on TPU
        pass False to compile to Mosaic.

    Returns (out_re, out_im): (B, O, M) at ``out_dtype`` (default: x dtype).
    """
    B, I, M = xr.shape
    I2, O, M2 = wr.shape
    assert I == I2 and M == M2, (xr.shape, wr.shape)
    out_dtype = out_dtype or xr.dtype

    # pad modes to a multiple of block_m
    pad = (-M) % block_m
    if pad:
        xr = jnp.pad(xr, ((0, 0), (0, 0), (0, pad)))
        xi = jnp.pad(xi, ((0, 0), (0, 0), (0, pad)))
        wr = jnp.pad(wr, ((0, 0), (0, 0), (0, pad)))
        wi = jnp.pad(wi, ((0, 0), (0, 0), (0, pad)))
    Mp = M + pad
    grid = (Mp // block_m,)

    x_spec = pl.BlockSpec((B, I, block_m), lambda m: (0, 0, m))
    w_spec = pl.BlockSpec((I, O, block_m), lambda m: (0, 0, m))
    o_spec = pl.BlockSpec((B, O, block_m), lambda m: (0, 0, m))

    out_shape = [
        jax.ShapeDtypeStruct((B, O, Mp), out_dtype),
        jax.ShapeDtypeStruct((B, O, Mp), out_dtype),
    ]
    out_re, out_im = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[x_spec, x_spec, w_spec, w_spec],
        out_specs=[o_spec, o_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(xr, xi, wr, wi)
    if pad:
        out_re = out_re[..., :M]
        out_im = out_im[..., :M]
    return out_re, out_im


def vmem_bytes(B: int, I: int, O: int, block_m: int, itemsize: int = 2) -> int:
    """VMEM working set per grid step — used to pick block_m so the tile
    fits comfortably under the ~16 MiB v5e VMEM budget."""
    halves = (B * I + I * O + B * O) * block_m * 2  # re+im
    accum = B * O * block_m * 4  # f32 accumulators
    return halves * itemsize + accum
