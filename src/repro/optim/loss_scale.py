"""Dynamic loss scaling for the fp16 path.

The paper shows (§B.5, Fig. 10) that loss scaling *alone* cannot rescue a
naïve half-precision FNO — the forward FFT overflows before the loss is
even computed, and AMP's scale collapses to an infinitesimal value.  With
the tanh stabiliser in place, loss scaling resumes its normal job: keeping
small fp16 *gradients* from flushing to zero.  Whether a training run
needs it is decided by the resolved precision rules — the
``train/loss_scale`` site (:func:`loss_scaling_required`) — not by a
policy bool: fp16-family rule sets turn it on, bf16 rule sets don't.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp



def loss_scaling_required(policy) -> bool:
    """Resolve the ``train/loss_scale`` site of a precision rule set —
    this is the single switch the trainer consults (scoped
    ``precision_rules`` overrides apply here too)."""
    return bool(policy.at("train/loss_scale").loss_scaling)


class LossScaleState(NamedTuple):
    scale: jnp.ndarray        # f32 scalar
    good_steps: jnp.ndarray   # int32 scalar


def init_loss_scale(initial: float = 2.0 ** 15) -> LossScaleState:
    return LossScaleState(
        scale=jnp.asarray(initial, jnp.float32),
        good_steps=jnp.zeros((), jnp.int32),
    )


def scale_loss(loss: jnp.ndarray, state: LossScaleState) -> jnp.ndarray:
    return loss * state.scale.astype(loss.dtype)


def unscale_grads(grads, state: LossScaleState):
    inv = 1.0 / state.scale
    return jax.tree_util.tree_map(lambda g: g.astype(jnp.float32) * inv, grads)


def update_loss_scale(
    state: LossScaleState,
    grads_finite: jnp.ndarray,
    growth_interval: int = 200,
    growth_factor: float = 2.0,
    backoff_factor: float = 0.5,
    max_scale: float = 2.0 ** 24,
    min_scale: float = 1.0,
) -> LossScaleState:
    good = jnp.where(grads_finite, state.good_steps + 1, 0)
    grow = good >= growth_interval
    new_scale = jnp.where(
        grads_finite,
        jnp.where(grow, jnp.minimum(state.scale * growth_factor, max_scale), state.scale),
        jnp.maximum(state.scale * backoff_factor, min_scale),
    )
    return LossScaleState(scale=new_scale, good_steps=jnp.where(grow, 0, good))
