"""mamba2-370m [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]"""
from .base import LMArchConfig

CONFIG = LMArchConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=50280,
    mixer="ssd", ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=128,
)

SMOKE = LMArchConfig(
    name="mamba2-370m-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=256,
    mixer="ssd", ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_chunk=16,
)
