"""Empirical certification of the paper's precision bounds (autoprec leg 3).

Runs a mixed-precision FNO forward on GRF/Darcy inputs with telemetry
taps live, then checks — site by site — that the *measured* quantisation
error stays under its Theorem 3.2 budget ``4 ε M`` with ``M`` the
*observed* amax at that site, and that the end-to-end precision error is
a small fraction of the Theorem 3.1 discretisation bound at the input
grid.  The output is a machine-readable report (the CI bench-smoke job
uploads ``benchmarks/results/autoprec_certify.json``).

Also hosts the closed-form-vs-measured helpers that
``benchmarks/bench_theory.py`` (Fig. 7) reuses:
:func:`random_fourier_field` builds Darcy-like smooth random fields with
analytic sup-norm/Lipschitz bounds, and :func:`theory_rows` tabulates
measured discretisation/precision error against the Thm 3.1/3.2 bounds.

CLI (tiny certification pass, used by CI)::

    PYTHONPATH=src python -m repro.autoprec.certify \
        --policies mixed_fno_bf16 mixed_fno_fp16 --auto \
        --resolution 24 --batch 2 --out benchmarks/results/autoprec_certify.json
"""
from __future__ import annotations

import argparse
import os
from typing import List, Optional, Tuple

import jax
import numpy as np

from repro.core import theory
from repro.core.precision import FORMAT_EPS, precision_system_for

from .controller import AutoPrecisionController
from .telemetry import (
    SiteWindow,
    TelemetryAggregator,
    TraceCollector,
    collecting,
    fmt_of,
)

DEFAULT_OUT = os.path.join(
    os.path.dirname(__file__), "..", "..", "..",
    "benchmarks", "results", "autoprec_certify.json")


# ---------------------------------------------------------------------------
# Inputs and instrumented runs
# ---------------------------------------------------------------------------


def tiny_fno(n_layers: int = 2, hidden: int = 16, modes: Tuple[int, ...] = (8, 8)):
    """A small FNO whose spectral sites are representative but cheap."""
    from repro.models import FNOConfig, init_fno

    cfg = FNOConfig(in_channels=1, out_channels=1, hidden_channels=hidden,
                    lifting_channels=hidden, projection_channels=hidden,
                    n_layers=n_layers, modes=modes)
    params = init_fno(jax.random.PRNGKey(7), cfg)
    return cfg, params


def sample_inputs(source: str, resolution: int, batch: int, seed: int = 0):
    """Unit-normalised input fields (B, 1, n, n) from a GRF or Darcy."""
    key = jax.random.PRNGKey(seed)
    if source == "grf":
        from repro.data import grf_2d

        g = np.asarray(grf_2d(key, resolution, alpha=2.5, tau=3.0,
                              batch=batch))
        g = g / (np.abs(g).max() + 1e-12)
        return g[:, None].astype(np.float32)
    if source == "darcy":
        from repro.data import sample_darcy_batch

        a, _ = sample_darcy_batch(key, resolution, batch, maxiter=200)
        return np.asarray(a, np.float32)
    raise ValueError(f"unknown source {source!r}; have grf | darcy")


def instrumented_apply(policy, cfg, params, x):
    """One eager forward with telemetry live.  Returns (y, totals) where
    totals maps tap sites onto host :class:`SiteWindow` aggregates."""
    from repro.models import fno_apply

    col = TraceCollector()
    with collecting(col):
        y = fno_apply(params, jax.numpy.asarray(x), cfg, policy)
    agg = TelemetryAggregator()
    agg.update(col.snapshot())
    return np.asarray(y, np.float32), agg.totals


# ---------------------------------------------------------------------------
# Certification
# ---------------------------------------------------------------------------


def _site_row(site: str, w: SiteWindow, policy) -> dict:
    sp = policy.at(site)
    fmt = fmt_of(sp)
    eps = FORMAT_EPS[fmt]
    budget = theory.prec_upper_bound(eps, M=w.amax)
    row = {
        "fmt": fmt,
        "demoted": fmt != "float32",
        "eps": eps,
        "amax": w.amax,
        "overflow": w.overflow,
        "underflow": w.underflow,
        "qerr_measured": w.qerr,
        "prec_budget": budget,  # Thm 3.2: 4 ε M with M = observed amax
    }
    # Only quantising taps measure a qerr; pass-through taps (contract
    # inputs, fft_out storage) certify on range counters alone.
    row["checked"] = w.qerr > 0.0 or not row["demoted"]
    row["within"] = bool(w.qerr <= budget) and w.overflow == 0
    return row


def certify_policy(policy, cfg=None, params=None, x=None, *,
                   resolution: int = 32, batch: int = 4,
                   source: str = "grf", seed: int = 0,
                   omega: float = 1.0) -> dict:
    """Certify one policy: measured per-site precision error vs Thm 3.2
    budgets, end-to-end precision error vs the Thm 3.1 bound."""
    from repro.models import fno_apply
    from repro.precision import FULL

    if cfg is None or params is None:
        cfg, params = tiny_fno()
    if x is None:
        x = sample_inputs(source, resolution, batch, seed)
    y_ref = np.asarray(fno_apply(params, jax.numpy.asarray(x), cfg, FULL),
                       np.float32)
    y_pol, totals = instrumented_apply(policy, cfg, params, x)

    sites = {s: _site_row(s, w, policy) for s, w in sorted(totals.items())}
    demoted = [s for s, r in sites.items() if r["demoted"]]

    # end-to-end precision error vs the discretisation bound of the grid
    diff = y_pol - y_ref
    ref_norm = float(np.sqrt((y_ref ** 2).sum()) + 1e-12)
    L, M = theory.estimate_lipschitz_and_bound(np.asarray(x[0, 0]))
    n = int(np.prod(x.shape[2:]))
    d = x.ndim - 2
    disc_bound = theory.disc_upper_bound(n, d, omega, L, M)
    end_to_end = {
        "prec_rel_l2": float(np.sqrt((diff ** 2).sum()) / ref_norm),
        "prec_abs_max": float(np.abs(diff).max()),
        "disc_upper_bound": disc_bound,
        "prec_fraction_of_disc": float(np.abs(diff).max() / disc_bound)
        if disc_bound > 0 else None,
        "field_L": L,
        "field_M": M,
        "grid_points": n,
    }
    return {
        "policy": policy.name,
        "source": source,
        "resolution": resolution,
        "batch": int(np.shape(x)[0]),
        "sites": sites,
        "demoted_sites": demoted,
        "all_within": all(r["within"] for r in sites.values()),
        "end_to_end": end_to_end,
    }


def certify_controller(controller: AutoPrecisionController, *,
                       rounds: int = 4, resolution: int = 32,
                       batch: int = 4, source: str = "grf",
                       seed: int = 0) -> dict:
    """Drive a controller with live telemetry for a few rounds, then
    certify the policy it converged to.  The report carries the
    controller's decision trace alongside the per-site checks."""
    cfg, params = tiny_fno()
    x = sample_inputs(source, resolution, batch, seed)
    for r in range(rounds):
        _, totals = instrumented_apply(controller.policy(), cfg, params, x)
        # each instrumented run is one telemetry window for the controller
        controller.update(totals, grid_points=resolution ** 2, step=r)
    report = certify_policy(controller.policy(), cfg, params, x,
                            resolution=resolution, source=source, seed=seed)
    report["controller"] = controller.describe()
    return report


# ---------------------------------------------------------------------------
# Closed-form-vs-measured helpers (shared with benchmarks/bench_theory.py)
# ---------------------------------------------------------------------------


def random_fourier_field(seed: int, d: int = 2, max_wavenumber: float = 3.0,
                         n_modes: int = 24, decay: float = 2.0):
    """A Darcy-like smooth random field as a *callable on arbitrary
    points* (what ``theory.disc_error`` needs), with analytic bounds.

    ``v(x) = Σ_k a_k cos(2π k·x + φ_k)`` over *continuous* random
    wavevectors ``|k|_∞ <= max_wavenumber`` with GRF-style power-law
    amplitudes.  Non-integer frequencies keep the field non-periodic on
    the unit cell, so the lattice Riemann sum genuinely carries the
    Thm 3.1 ``n^{-1/d}`` error (integer modes would be integrated
    exactly).  Returns ``(v, L_bound, M_bound)`` where
    ``M_bound = Σ|a_k|`` bounds the sup norm and
    ``L_bound = Σ|a_k|·2π|k|_2`` the Lipschitz constant — the exact
    quantities Thm 3.1/3.2 consume.
    """
    rng = np.random.RandomState(seed)
    K = rng.uniform(-max_wavenumber, max_wavenumber, size=(n_modes, d))
    amps = rng.randn(n_modes) * (
        1.0 + np.linalg.norm(K, axis=-1)) ** (-decay)
    phases = rng.uniform(0, 2 * np.pi, size=n_modes)

    def v(xi: np.ndarray) -> np.ndarray:
        # xi: (N, d) points in [0,1]^d
        phase = 2.0 * np.pi * xi @ K.T + phases[None, :]
        return (np.cos(phase) * amps[None, :]).sum(axis=-1)

    M_bound = float(np.abs(amps).sum())
    L_bound = float((np.abs(amps) * 2.0 * np.pi *
                     np.linalg.norm(K, axis=-1)).sum())
    return v, L_bound, M_bound


def measured_prec_error(v, m: int, d: int, omega: float, fmt: str) -> float:
    """Eq. (2) measured for a named format: numpy cast where one exists
    (fp16), the paper's (a0, ε, T)-system quantiser otherwise."""
    if fmt == "float16":
        return theory.prec_error(v, m, d, omega, dtype="float16")
    return theory.prec_error(v, m, d, omega, q=precision_system_for(fmt))


def theory_rows(seed: int = 0, d: int = 2,
                m_values: Tuple[int, ...] = (6, 10, 16, 24),
                formats: Tuple[str, ...] = ("float16", "bfloat16",
                                            "fp8_e4m3", "fp8_e5m2"),
                omega: float = 1.0) -> List[dict]:
    """Fig. 7 data: measured disc/prec errors vs the closed-form bounds
    on a Darcy-like random field, per mesh size and per format."""
    v, L, M = random_fourier_field(seed, d=d)
    rows = []
    for m in m_values:
        n = m ** d
        row = {
            "m": m, "n": n, "d": d, "omega": omega,
            "disc_measured": theory.disc_error(v, m, d, omega),
            "disc_upper": theory.disc_upper_bound(n, d, omega, L, M),
            "disc_lower": theory.disc_lower_bound(n, d, M),
            "prec": {},
        }
        for fmt in formats:
            row["prec"][fmt] = {
                "measured": measured_prec_error(v, m, d, omega, fmt),
                "upper": theory.prec_upper_bound(FORMAT_EPS[fmt], M),
            }
        rows.append(row)
    return rows


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def write_report(reports: List[dict], path: str) -> None:
    from repro.obs import write_result

    write_result(path, {"reports": reports})


def main(argv: Optional[List[str]] = None) -> int:
    from repro.precision import get_policy

    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--policies", nargs="*",
                    default=["mixed_fno_bf16", "mixed_fno_fp16"])
    ap.add_argument("--auto", action="store_true",
                    help="also certify an AutoPrecisionController-derived "
                         "policy (base=full, telemetry-driven)")
    ap.add_argument("--resolution", type=int, default=24)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--source", default="grf", choices=["grf", "darcy"])
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)

    reports = []
    for name in args.policies:
        rep = certify_policy(get_policy(name), resolution=args.resolution,
                             batch=args.batch, source=args.source)
        reports.append(rep)
    if args.auto:
        ctl = AutoPrecisionController(
            base="full", grid_points=args.resolution ** 2,
            demote_patience=1, cooldown=0)
        reports.append(certify_controller(
            ctl, resolution=args.resolution, batch=args.batch,
            source=args.source))

    write_report(reports, args.out)
    bad = 0
    for rep in reports:
        n_dem = len(rep["demoted_sites"])
        ok = rep["all_within"]
        bad += not ok
        print(f"{rep['policy']:<24s} demoted={n_dem:2d} "
              f"prec/disc={rep['end_to_end']['prec_fraction_of_disc']} "
              f"{'CERTIFIED' if ok else 'VIOLATION'}")
    print(f"report -> {args.out}")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
