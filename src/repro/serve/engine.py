"""Engine API: one serving protocol over heterogeneous workloads.

Every engine — the LM slot engine here, the FNO/SFNO field engine in
``operator.py`` — speaks the same four verbs:

    submit(req) -> bool     queue a request (capacity-rejected => failed)
    tick()                  one fused device step
    drain(max_ticks)        tick until idle; returns finished requests
    stats()                 tokens/s or fields/s, slot occupancy, queue

``LMEngine`` is the renamed, slimmed ``ServeEngine``: a fixed pool of B
slots over the unified LM decode step (continuous batching), now with

  * a :class:`~repro.serve.scheduler.Scheduler` owning admission (FCFS /
    shortest-prompt-first) and ``max_len`` capacity checks — oversized
    requests fail at submit instead of overrunning the KV cache or
    spinning ``drain`` forever;
  * chunked batched prefill: up to ``prefill_chunk`` pending prompt
    tokens per slot are consumed per tick through one fused
    ``lm_prefill_chunk`` step (prompts cost ceil(len/K) ticks instead of
    len ticks — the headline throughput win, benchmarked in
    ``benchmarks/bench_serve.py``);
  * per-request sampling (greedy / temperature / top-k / top-p) with
    explicit jax PRNG keys through the ``serve/sampler`` precision site.

Pure-decode ticks still run the one-token ``lm_decode_step`` — byte-for-
byte the old engine's step — so chunking only touches the prefill phase.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PrecisionPolicy, FULL
from repro.configs.base import LMArchConfig
from repro.dist import use_mesh
from repro.models.lm import init_cache, lm_decode_step, lm_prefill_chunk
from repro.obs import trace as obs_trace

from .sampler import GREEDY, SamplingParams, request_key, sample_token
from .scheduler import Scheduler


@dataclasses.dataclass(eq=False)
class Request:
    """One LM generation request.  Identity semantics (``eq=False``):
    two requests are never "the same work item" just because their
    fields match."""

    uid: int
    prompt: List[int]
    max_new_tokens: int = 16
    sampling: SamplingParams = GREEDY
    generated: List[int] = dataclasses.field(default_factory=list)
    status: str = "new"          # new | queued | running | done | failed
    error: Optional[str] = None
    submit_tick: int = -1
    start_tick: int = -1
    finish_tick: int = -1

    @property
    def done(self) -> bool:
        return self.status == "done"


@runtime_checkable
class Engine(Protocol):
    """The engine-agnostic serving protocol (LM and operator engines)."""

    def submit(self, req) -> bool: ...
    def tick(self) -> None: ...
    def drain(self, max_ticks: int = 10_000) -> Tuple[List[Any], int]: ...
    def stats(self) -> Dict[str, Any]: ...


class EngineBase:
    """Shared slot bookkeeping + drain loop + stats scaffolding."""

    kind = "engine"

    def __init__(self, scheduler: Scheduler, n_slots: int):
        self.scheduler = scheduler
        self.n_slots = n_slots
        self._ticks = 0
        self._tick0 = 0     # tick count at the last reset_counters()
        self._wall_s = 0.0
        self._occupancy_sum = 0.0
        self._n_done = 0
        self._n_failed = 0

    # subclasses implement one device step over the current slots
    def _tick_impl(self) -> List[Any]:
        raise NotImplementedError

    def _busy(self) -> bool:
        raise NotImplementedError

    def submit(self, req) -> bool:
        ok = self.scheduler.submit(req, self._ticks)
        if not ok:
            self._n_failed += 1
        elif obs_trace.is_enabled():
            # request lifecycle: an async track slice per uid, queued at
            # submit, closed when the request finishes (Perfetto renders
            # one row per in-flight request)
            obs_trace.begin("request", getattr(req, "uid", id(req)),
                            category="request", engine=self.kind)
        return ok

    def tick(self) -> List[Any]:
        """One engine step.  Returns the requests finished this tick."""
        t0 = time.perf_counter()
        with obs_trace.span("serve/tick", engine=self.kind, tick=self._ticks):
            finished = self._tick_impl()
        self._wall_s += time.perf_counter() - t0
        self._ticks += 1
        for r in finished:
            r.finish_tick = self._ticks
            r.status = "done"
            self._n_done += 1
            if obs_trace.is_enabled():
                obs_trace.end("request", getattr(r, "uid", id(r)),
                              category="request",
                              ticks=self._ticks - getattr(r, "submit_tick", 0))
        return finished

    def drain(self, max_ticks: int = 10_000) -> Tuple[List[Any], int]:
        """Tick until every submitted request is finished (or max_ticks).

        Capacity-rejected requests come back *failed* rather than
        burning ticks — the old engine span ``max_ticks`` admitting
        nothing when a request could never fit.
        """
        finished: List[Any] = list(self.scheduler.take_failed())
        ticks = 0
        while (self.scheduler.depth or self._busy()) and ticks < max_ticks:
            finished.extend(self.tick())
            ticks += 1
        finished.extend(self.scheduler.take_failed())
        return finished, ticks

    def stats(self) -> Dict[str, Any]:
        denom = max(self._ticks - self._tick0, 1)
        out = {
            "engine": self.kind,
            "ticks": self._ticks,
            "wall_s": round(self._wall_s, 6),
            "n_slots": self.n_slots,
            "slot_occupancy": round(self._occupancy_sum / denom, 4),
            "completed": self._n_done,
            "failed": self._n_failed,
            "queue": self.scheduler.stats(),
            **self._extra_stats(),
        }
        # the dict stays the caller-facing return; the registry snapshot
        # is the machine-readable export source for the same numbers
        from repro.obs import registry

        registry().publish(f"serve_{self.kind}", out)
        return out

    def _extra_stats(self) -> Dict[str, Any]:
        return {}

    def reset_counters(self) -> None:
        """Zero the engine's throughput/occupancy counters (bench hygiene:
        call between the warmup and measurement legs, with no requests in
        flight).  The absolute tick count is preserved — scheduler wait
        accounting is keyed on it — but occupancy averages over ticks
        since the reset."""
        self._tick0 = self._ticks
        self._wall_s = 0.0
        self._occupancy_sum = 0.0
        self._n_done = 0
        self._n_failed = 0
        self._reset_extra_counters()

    def _reset_extra_counters(self) -> None:
        pass


# ---------------------------------------------------------------------------
# LM engine
# ---------------------------------------------------------------------------


class LMEngine(EngineBase):
    kind = "lm"

    def __init__(
        self,
        params,
        cfg: LMArchConfig,
        n_slots: int = 4,
        max_len: int = 512,
        policy: PrecisionPolicy = FULL,
        mesh=None,
        scheduler: str = "fcfs",
        prefill_chunk: Optional[int] = None,
        seed: int = 0,
        telemetry: bool = False,
        record_logits: bool = False,
    ):
        if prefill_chunk is None:
            # MoE expert-capacity dispatch depends on the dispatch-batch
            # composition (moe_apply drops over-capacity tokens), so a
            # K-token chunk routes differently than token-by-token.  The
            # default contract is exactness: MoE archs prefill one token
            # per tick unless the caller opts into chunking explicitly.
            prefill_chunk = 1 if cfg.moe_experts else 8
        if prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}")
        super().__init__(
            Scheduler(
                scheduler,
                capacity_check=self._capacity_check,
                cost=lambda r: len(r.prompt),
            ),
            n_slots,
        )
        self.params = params
        self.cfg = cfg
        self.policy = policy
        self.max_len = max_len
        self.mesh = mesh
        self.prefill_chunk = prefill_chunk
        self._base_key = jax.random.PRNGKey(seed)
        self._sampler_site = policy.at("serve/sampler")
        # dense cache width per slot: SWA archs keep a ring narrower than
        # max_len; a chunk must never wrap rows still inside an in-chunk
        # query's window, so the per-slot chunk is clamped to the
        # remaining un-wrapped rows.
        if cfg.mixer in ("attn", "hymba"):
            self._kv_len = max_len if cfg.attn_window <= 0 else min(max_len, cfg.attn_window)
            self._ring = self._kv_len if cfg.attn_window > 0 else None
        else:
            self._kv_len = 0
            self._ring = None
        self.cache = self._build_cache()
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.slot_pending: List[List[int]] = [[] for _ in range(n_slots)]
        self.slot_pos: List[int] = [0] * n_slots   # host mirror of cache step
        self._record_logits = record_logits
        self._logits_log: Dict[int, List[np.ndarray]] = {}
        self._n_prompt_tokens = 0
        self._n_generated = 0
        self._prefill_ticks = 0
        self._decode_ticks = 0
        # numerics counters over the decoded logits (the engine's own
        # observable; already materialised on host, so the checks are
        # free): running amax and non-finite count — a non-finite row is
        # a numerics incident under the active precision rule set.
        self._telemetry_on = telemetry
        self._logits_amax = 0.0
        self._logits_nonfinite = 0
        self._rows_observed = 0
        self._build_steps()

    # -- build hooks (overridden by the paged engine) --------------------------
    def _build_cache(self):
        # KV storage dtype comes from the serve/kv_cache site of the rule
        # table (f32 under `full` for an exact decode contract; bf16/fp16
        # under the AMP rule sets for the memory saving).
        return init_cache(self.cfg, self.n_slots, self.max_len,
                          dtype=self.policy.at("serve/kv_cache").compute_dtype)

    def _build_steps(self):
        cfg, policy, mesh = self.cfg, self.policy, self.mesh
        n_slots, prefill_chunk, params = self.n_slots, self.prefill_chunk, self.params
        decode_fn = lambda p, c, t: lm_decode_step(p, c, t, cfg, policy)
        chunk_fn = lambda p, c, t, n: lm_prefill_chunk(p, c, t, n, cfg, policy)
        if mesh is None:
            self._decode = jax.jit(decode_fn)
            self._chunk = jax.jit(chunk_fn)
        else:
            # shard the serving state through the same rule tables the
            # dry-run lowers with: params by lm_param_specs, the slot
            # cache by cache_specs, per-slot tokens data-parallel.
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.dist.sharding import (
                batch_specs,
                cache_specs,
                lm_param_specs,
                to_named,
            )

            p_named = to_named(
                mesh, lm_param_specs(jax.eval_shape(lambda: params), mesh))
            c_named = to_named(
                mesh, cache_specs(jax.eval_shape(lambda: self.cache), mesh, cfg))
            t_named = to_named(
                mesh,
                batch_specs(jax.ShapeDtypeStruct((n_slots,), jnp.int32), mesh))
            t2_named = to_named(
                mesh,
                batch_specs(
                    jax.ShapeDtypeStruct((n_slots, prefill_chunk), jnp.int32),
                    mesh))
            logits_sh = NamedSharding(mesh, P())
            self.params = jax.device_put(params, p_named)
            self.cache = jax.device_put(self.cache, c_named)
            self._decode = jax.jit(
                decode_fn,
                in_shardings=(p_named, c_named, t_named),
                out_shardings=(logits_sh, c_named),
            )
            self._chunk = jax.jit(
                chunk_fn,
                in_shardings=(p_named, c_named, t2_named, t_named),
                out_shardings=(logits_sh, c_named),
            )

    # -- admission -------------------------------------------------------------
    def _capacity_check(self, req: Request) -> Tuple[bool, str]:
        need = len(req.prompt) + req.max_new_tokens
        if need > self.max_len:
            return False, (
                f"request needs {need} cache rows "
                f"(prompt {len(req.prompt)} + max_new_tokens "
                f"{req.max_new_tokens}) but max_len is {self.max_len}"
            )
        return True, ""

    def _admit_slot(self, i: int, req: Request) -> int:
        """Slot-admission hook; returns the request's starting position
        (nonzero when a cached prompt prefix lets prefill be skipped —
        the paged engine's prefix index)."""
        del i, req
        return 0

    def _reset_slots(self, admitted: List[Tuple[int, int]]):
        """Reset the newly admitted slots' clocks and invalidate their
        cache rows in ONE indexed device update per array (continuous
        batching: other slots keep decoding undisturbed).  ``admitted``
        is [(slot, start_pos), ...] — start_pos > 0 for prefix hits."""
        ids = np.asarray([i for i, _ in admitted], np.int32)
        starts = np.asarray([s for _, s in admitted], np.int32)
        c = dict(self.cache)
        c["step"] = c["step"].at[ids].set(starts)
        if "kv_pos" in c:
            c["kv_pos"] = c["kv_pos"].at[:, ids].set(-1)
        if "ssd_state" in c:
            c["ssd_state"] = c["ssd_state"].at[:, ids].set(0.0)
        self.cache = c

    def _release_slot(self, i: int):
        """Slot-release hook (request finished): the paged engine drops
        its block-table references here."""
        del i

    def _on_prefill_complete(self, i: int, req: Request):
        """Called once per request, the tick its last prompt token is
        consumed (the paged engine registers shared prefix blocks)."""
        del i, req

    def _assign_slots(self):
        free = [i for i, s in enumerate(self.slots) if s is None]
        if not free:
            return
        admitted: List[Tuple[int, int]] = []
        for i, req in zip(free, self.scheduler.take(len(free), self._ticks),
                          strict=False):
            self.slots[i] = req
            start = self._admit_slot(i, req)
            self.slot_pos[i] = start
            # empty prompts decode from token 0, like the old engine
            self.slot_pending[i] = list(req.prompt)[start:] or [0]
            admitted.append((i, start))
        if admitted:
            self._reset_slots(admitted)

    def _observe_logits(self, logits: np.ndarray) -> None:
        """Update host-side numerics counters over the active slots' rows."""
        if not self._telemetry_on:
            return
        rows = [i for i, s in enumerate(self.slots) if s is not None]
        if not rows:
            return
        sub = logits[rows]
        finite = np.isfinite(sub)
        if finite.any():
            self._logits_amax = max(
                self._logits_amax, float(np.abs(sub[finite]).max()))
        n_bad = int((~finite).sum())
        if n_bad:
            from repro.obs import numerics_event

            numerics_event("nonfinite_logits", site="serve/logits",
                           count=n_bad, tick=self._ticks)
        self._logits_nonfinite += n_bad
        self._rows_observed += len(rows)

    # -- sampling --------------------------------------------------------------
    def _next_token(self, req: Request, logits_row) -> int:
        if req.sampling.temperature <= 0.0:
            # greedy hot path: the row is already a materialised f32
            # numpy array — argmax needs no device dispatch (and is
            # invariant under the sampler site's monotone cast)
            return int(np.argmax(logits_row))
        key = request_key(self._base_key, req.uid, len(req.generated))
        return sample_token(logits_row, req.sampling, key,
                            site=self._sampler_site)

    def _record(self, req: Request, logits_row: np.ndarray):
        if self._record_logits:
            self._logits_log.setdefault(req.uid, []).append(
                np.array(logits_row, copy=True))

    def logits_for(self, uid: int) -> List[np.ndarray]:
        """Per-step logits rows recorded for ``uid`` (requires
        ``record_logits=True``) — the bit-identity tests' observable."""
        return self._logits_log.get(uid, [])

    def _finish_or_continue(self, i: int, req: Request, finished: List[Request]):
        if len(req.generated) >= req.max_new_tokens:
            finished.append(req)
            self.slots[i] = None  # free the slot (continuous batching)
            self._release_slot(i)

    # -- one engine tick -------------------------------------------------------
    def _busy(self) -> bool:
        return any(s is not None for s in self.slots)

    def _tick_impl(self) -> List[Request]:
        self._assign_slots()
        self._occupancy_sum += (
            sum(s is not None for s in self.slots) / self.n_slots)
        prefilling = any(
            self.slots[i] is not None and len(self.slot_pending[i]) > 0
            for i in range(self.n_slots)
        )
        if prefilling and self.prefill_chunk > 1:
            with obs_trace.span("serve/prefill"):
                return self._tick_chunk()
        with obs_trace.span("serve/decode"):
            return self._tick_decode()

    def _chunk_limit(self, i: int) -> int:
        """Largest safe chunk for slot i (ring-buffer wrap guard)."""
        if self._ring is None:
            return self.prefill_chunk
        return max(1, min(self.prefill_chunk, self._ring - self.slot_pos[i]))

    def _tick_chunk(self) -> List[Request]:
        """Chunked prefill tick: consume up to K pending prompt tokens per
        prefilling slot; decoding slots ride along as 1-valid-token rows.
        The step that consumes a slot's last prompt token also emits its
        first generated token (the logits are not discarded)."""
        K = self.prefill_chunk
        tokens = np.zeros((self.n_slots, K), np.int32)
        n_valid = np.zeros((self.n_slots,), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if self.slot_pending[i]:
                k = min(len(self.slot_pending[i]), self._chunk_limit(i))
                tokens[i, :k] = self.slot_pending[i][:k]
                n_valid[i] = k
            else:
                tokens[i, 0] = req.generated[-1]
                n_valid[i] = 1
        logits = self._run_chunk(tokens, n_valid)
        self._observe_logits(logits)
        self._prefill_ticks += 1
        finished: List[Request] = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            k = int(n_valid[i])
            if self.slot_pending[i]:
                del self.slot_pending[i][:k]
                self.slot_pos[i] += k
                self._n_prompt_tokens += k
                if self.slot_pending[i]:
                    continue  # still prefilling this slot
                self._on_prefill_complete(i, req)
                if obs_trace.is_enabled():
                    obs_trace.event("serve/prefill_complete",
                                    category="request", uid=req.uid,
                                    prompt_tokens=len(req.prompt))
            else:
                self.slot_pos[i] += 1
            self._record(req, logits[i])
            req.generated.append(self._next_token(req, logits[i]))
            self._n_generated += 1
            self._finish_or_continue(i, req, finished)
        return finished

    def _run_chunk(self, tokens: np.ndarray, n_valid: np.ndarray) -> np.ndarray:
        with use_mesh(self.mesh):
            logits, self.cache = self._chunk(
                self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(n_valid))
        return np.asarray(logits)

    def _run_decode(self, tokens: np.ndarray) -> np.ndarray:
        with use_mesh(self.mesh):
            logits, self.cache = self._decode(self.params, self.cache,
                                              jnp.asarray(tokens))
        return np.asarray(logits)

    def _tick_decode(self) -> List[Request]:
        """One fused one-token decode step for the slot pool (also the
        prefill path at ``prefill_chunk=1``: teacher-forced token-by-token,
        exactly the old engine).

        The step that consumes a slot's *last* pending prompt token is
        also the step whose logits define the first generated token —
        discarding them (and re-feeding ``prompt[-1]`` next tick) would
        decode from a skewed cache position, desynchronising the engine
        from a straight-line ``lm_forward`` greedy decode.
        """
        tokens = np.zeros((self.n_slots,), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            if self.slot_pending[i]:
                tokens[i] = self.slot_pending[i][0]
            else:
                tokens[i] = req.generated[-1]
        logits = self._run_decode(tokens)
        self._observe_logits(logits)
        self._decode_ticks += 1
        finished: List[Request] = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self.slot_pos[i] += 1
            if self.slot_pending[i]:
                self.slot_pending[i].pop(0)
                self._n_prompt_tokens += 1
                if self.slot_pending[i]:
                    continue  # still prefilling this slot
                # fall through: the prompt is consumed and this step's
                # logits are the first generation
                self._on_prefill_complete(i, req)
                if obs_trace.is_enabled():
                    obs_trace.event("serve/prefill_complete",
                                    category="request", uid=req.uid,
                                    prompt_tokens=len(req.prompt))
            self._record(req, logits[i])
            req.generated.append(self._next_token(req, logits[i]))
            self._n_generated += 1
            self._finish_or_continue(i, req, finished)
        return finished

    # -- back-compat driver ----------------------------------------------------
    def run_until_done(self, requests: List[Request],
                       max_ticks: int = 10_000) -> Tuple[List[Request], int]:
        """Submit ``requests`` and drain.  Returns (finished, ticks);
        capacity-rejected requests come back with ``status='failed'``
        instead of spinning the loop until ``max_ticks``."""
        for r in requests:
            self.submit(r)
        return self.drain(max_ticks)

    def _extra_stats(self) -> Dict[str, Any]:
        processed = self._n_prompt_tokens + self._n_generated
        out = {
            "prefill_chunk": self.prefill_chunk,
            "prefill_ticks": self._prefill_ticks,
            "decode_ticks": self._decode_ticks,
            "prompt_tokens": self._n_prompt_tokens,
            "tokens_generated": self._n_generated,
            "tokens_per_s": round(processed / self._wall_s, 2)
            if self._wall_s else None,
        }
        if self._telemetry_on:
            out["numerics"] = {
                "logits_amax": self._logits_amax,
                "logits_nonfinite": self._logits_nonfinite,
                "rows_observed": self._rows_observed,
            }
        return out

    def _reset_extra_counters(self) -> None:
        self._n_prompt_tokens = 0
        self._n_generated = 0
        self._prefill_ticks = 0
        self._decode_ticks = 0
        self._logits_amax = 0.0
        self._logits_nonfinite = 0
        self._rows_observed = 0


#: Back-compat alias — PRs 0-2 called the slot engine ``ServeEngine``.
ServeEngine = LMEngine
