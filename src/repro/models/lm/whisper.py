"""Whisper-style encoder-decoder backbone (audio family).

Per the assignment, the conv/mel frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings (B, S, d) directly.  The backbone is
faithful in structure: bidirectional encoder self-attention over frames,
causal decoder self-attention (max 448 tokens), cross-attention into the
encoder memory.  Positions use RoPE uniformly (documented deviation from
Whisper's sinusoidal/learned embeddings — DESIGN.md §7).

Decode (`whisper_decode_step`) caches decoder self-KV (ring over 448) and
the cross-KV projected once from the encoder memory — the 32k-frame
`decode_32k` cell measures exactly that cross-KV-bound regime.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import FULL
from repro.configs.base import LMArchConfig
from .common import apply_rope, apply_rope_one, decode_attention, gqa_attention, init_swiglu, rmsnorm, swiglu
from .model import FULL_WINDOW, _init_attn
from repro.dist.constrain import constrain_bhsd, constrain_bsd


def _init_block(key, cfg, cross: bool):
    keys = jax.random.split(key, 4)
    blk = {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": _init_attn(keys[0], cfg),
        "ffn": init_swiglu(keys[1], cfg.d_model, cfg.d_ff),
    }
    if cross:
        blk["ln_x"] = jnp.ones((cfg.d_model,), jnp.float32)
        blk["xattn"] = _init_attn(keys[2], cfg)
    return blk


def init_whisper(key: jax.Array, cfg: LMArchConfig) -> Dict:
    enc_l = cfg.n_layers
    dec_l = cfg.dec_layers or cfg.n_layers
    keys = jax.random.split(key, enc_l + dec_l + 2)
    enc = [_init_block(keys[i], cfg, cross=False) for i in range(enc_l)]
    dec = [_init_block(keys[enc_l + i], cfg, cross=True) for i in range(dec_l)]
    return {
        "embed": (1.0 / cfg.d_model ** 0.5)
        * jax.random.normal(keys[-1], (cfg.vocab, cfg.d_model), jnp.float32),
        "enc_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "dec_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "enc": jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *enc),
        "dec": jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *dec),
    }


def _mha(ap, hq, hkv, q_pos, k_pos, causal, cfg, dtype):
    B, Sq, d = hq.shape
    Sk = hkv.shape[1]
    H, Hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    def proj(w, x):
        return jnp.einsum("bsd,de->bse", x.astype(dtype), w.astype(dtype),
                          preferred_element_type=jnp.float32).astype(dtype)

    q = constrain_bhsd(proj(ap["wq"], hq).reshape(B, Sq, H, hd).transpose(0, 2, 1, 3))
    k = constrain_bhsd(proj(ap["wk"], hkv).reshape(B, Sk, Hk, hd).transpose(0, 2, 1, 3))
    v = constrain_bhsd(proj(ap["wv"], hkv).reshape(B, Sk, Hk, hd).transpose(0, 2, 1, 3))
    q = apply_rope(q, q_pos, cfg.rope_theta)
    k = apply_rope(k, k_pos, cfg.rope_theta)
    if causal:
        o = gqa_attention(q, k, v, q_pos, k_pos, FULL_WINDOW)
    else:
        # bidirectional: shift the "causal" mask away by using kpos - max
        o = gqa_attention(q, k, v, q_pos + Sk, k_pos, FULL_WINDOW)
    o = o.transpose(0, 2, 1, 3).reshape(B, Sq, H * hd)
    return jnp.einsum("bse,ed->bsd", o, ap["wo"].astype(dtype),
                      preferred_element_type=jnp.float32).astype(dtype)


def whisper_encode(params, frames: jnp.ndarray, cfg, policy=FULL,
                   remat: bool = False) -> jnp.ndarray:
    """frames: (B, S, d) stub embeddings -> encoder memory (B, S, d)."""
    dtype = policy.at("lm/dense").compute_dtype
    h = frames.astype(dtype)
    S = h.shape[1]
    pos = jnp.arange(S)

    def block(h, lp):
        h = constrain_bsd(h)
        hn = rmsnorm(h, lp["ln1"], cfg.norm_eps)
        h = h + _mha(lp["attn"], hn, hn, pos, pos, False, cfg, dtype)
        hn = rmsnorm(h, lp["ln2"], cfg.norm_eps)
        h = h + swiglu(lp["ffn"], hn, dtype)
        return h, None

    if remat:
        block = jax.checkpoint(block, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(block, h, params["enc"])
    return rmsnorm(h, params["enc_norm"], cfg.norm_eps)


def whisper_forward(
    params, frames: jnp.ndarray, dec_tokens: jnp.ndarray, cfg, policy=FULL,
    remat: bool = False,
) -> jnp.ndarray:
    """Training forward: (B,S,d) frames + (B,T) decoder tokens -> logits."""
    dtype = policy.at("lm/dense").compute_dtype
    head_dt = policy.at("lm/proj_out").compute_dtype
    memory = whisper_encode(params, frames, cfg, policy, remat=remat)
    h = params["embed"][dec_tokens].astype(dtype)
    T = h.shape[1]
    dpos = jnp.arange(T)
    epos = jnp.arange(memory.shape[1])

    def block(h, lp):
        h = constrain_bsd(h)
        hn = rmsnorm(h, lp["ln1"], cfg.norm_eps)
        h = h + _mha(lp["attn"], hn, hn, dpos, dpos, True, cfg, dtype)
        hn = rmsnorm(h, lp["ln_x"], cfg.norm_eps)
        h = h + _mha(lp["xattn"], hn, memory, dpos, epos, False, cfg, dtype)
        hn = rmsnorm(h, lp["ln2"], cfg.norm_eps)
        h = h + swiglu(lp["ffn"], hn, dtype)
        return h, None

    if remat:
        block = jax.checkpoint(block, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(block, h, params["dec"])
    h = rmsnorm(h, params["dec_norm"], cfg.norm_eps)
    return jnp.einsum("btd,vd->btv", h.astype(head_dt),
                      params["embed"].astype(head_dt))


def init_whisper_cache(params, memory: jnp.ndarray, cfg, batch: int,
                       policy=FULL, dtype=None) -> Dict:
    """Precompute cross-KV from the encoder memory; zero self-KV ring.

    The KV storage dtype resolves from the ``serve/kv_cache`` site unless
    an explicit ``dtype`` is passed (f32 under ``full`` keeps decode
    exact; AMP rule sets store bf16/fp16 for the memory saving)."""
    cdt = policy.at("lm/dense").compute_dtype
    if dtype is None:
        dtype = policy.at("serve/kv_cache").compute_dtype
    L = cfg.dec_layers or cfg.n_layers
    S = memory.shape[1]
    Hk, hd = cfg.n_kv_heads, cfg.hd
    epos = jnp.arange(S)

    def cross_kv(lp):
        k = jnp.einsum("bsd,de->bse", memory.astype(cdt), lp["xattn"]["wk"].astype(cdt),
                       preferred_element_type=jnp.float32)
        v = jnp.einsum("bsd,de->bse", memory.astype(cdt), lp["xattn"]["wv"].astype(cdt),
                       preferred_element_type=jnp.float32)
        k = k.reshape(batch, S, Hk, hd).transpose(0, 2, 1, 3)
        k = apply_rope(k, epos, cfg.rope_theta)
        v = v.reshape(batch, S, Hk, hd).transpose(0, 2, 1, 3)
        return k.astype(dtype), v.astype(dtype)

    xk, xv = jax.vmap(cross_kv)(params["dec"])  # (L, B, Hk, S, hd)
    W = cfg.max_dec_len
    return {
        "step": jnp.zeros((batch,), jnp.int32),
        "self_k": jnp.zeros((L, batch, Hk, W, hd), dtype),
        "self_v": jnp.zeros((L, batch, Hk, W, hd), dtype),
        "self_pos": jnp.full((L, batch, W), -1, jnp.int32),
        "cross_k": xk,
        "cross_v": xv,
        "cross_pos": jnp.broadcast_to(epos, (L, batch, S)),
    }


def whisper_decode_step(params, cache: Dict, tokens: jnp.ndarray, cfg,
                        policy=FULL) -> Tuple[jnp.ndarray, Dict]:
    """One decoder token against cached self+cross KV."""
    dtype = policy.at("lm/dense").compute_dtype
    head_dt = policy.at("lm/proj_out").compute_dtype
    pos = cache["step"]                          # (B,) per-slot clocks
    h = params["embed"][tokens].astype(dtype)
    B = h.shape[0]
    H, Hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    W = cache["self_pos"].shape[-1]
    slot = jnp.mod(pos, W)                       # (B,)
    b_idx = jnp.arange(B)

    xs = {k: cache[k] for k in
          ("self_k", "self_v", "self_pos", "cross_k", "cross_v", "cross_pos")}

    def proj(w, x):
        return jnp.einsum("bd,de->be", x.astype(dtype), w.astype(dtype),
                          preferred_element_type=jnp.float32).astype(dtype)

    def block(h, layer_in):
        lp, lc = layer_in
        new_lc = dict(lc)
        # self attention
        hn = rmsnorm(h, lp["ln1"], cfg.norm_eps)
        q = apply_rope_one(proj(lp["attn"]["wq"], hn).reshape(B, H, hd), pos, cfg.rope_theta)[:, :, None, :]
        k = apply_rope_one(proj(lp["attn"]["wk"], hn).reshape(B, Hk, hd), pos, cfg.rope_theta)
        v = proj(lp["attn"]["wv"], hn).reshape(B, Hk, hd)
        sk = lc["self_k"].at[b_idx, :, slot].set(k.astype(lc["self_k"].dtype))
        sv = lc["self_v"].at[b_idx, :, slot].set(v.astype(lc["self_v"].dtype))
        sp = lc["self_pos"].at[b_idx, slot].set(pos)
        o = decode_attention(q, sk.astype(dtype), sv.astype(dtype), sp, pos, FULL_WINDOW)
        o = o[:, :, 0].reshape(B, H * hd)
        h = h + jnp.einsum("be,ed->bd", o, lp["attn"]["wo"].astype(dtype),
                           preferred_element_type=jnp.float32).astype(dtype)
        new_lc.update({"self_k": sk, "self_v": sv, "self_pos": sp})
        # cross attention
        hn = rmsnorm(h, lp["ln_x"], cfg.norm_eps)
        qx = apply_rope_one(proj(lp["xattn"]["wq"], hn).reshape(B, H, hd), pos, cfg.rope_theta)[:, :, None, :]
        ox = decode_attention(qx, lc["cross_k"].astype(dtype), lc["cross_v"].astype(dtype),
                              lc["cross_pos"] * 0, pos * 0, FULL_WINDOW)
        ox = ox[:, :, 0].reshape(B, H * hd)
        h = h + jnp.einsum("be,ed->bd", ox, lp["xattn"]["wo"].astype(dtype),
                           preferred_element_type=jnp.float32).astype(dtype)
        # ffn
        hn = rmsnorm(h, lp["ln2"], cfg.norm_eps)
        h = h + swiglu(lp["ffn"], hn, dtype)
        return h, new_lc

    h, new_xs = jax.lax.scan(block, h, (params["dec"], xs))
    h = rmsnorm(h, params["dec_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", h.astype(head_dt),
                        params["embed"].astype(head_dt))
    new_cache = dict(new_xs)
    new_cache["step"] = pos + 1
    return logits, new_cache
