"""SFNO on the spherical shallow-water equations (paper's SWE protocol):
data generated on the fly each epoch by the in-repo spherical solver,
trained under the mixed-precision policy with tanh stabilisation.

    PYTHONPATH=src python examples/spherical_swe.py [--steps 20]
"""
import argparse

import jax

from repro.core import FULL, get_policy
from repro.data import sample_swe_batch
from repro.models import SFNOConfig, init_sfno, sfno_apply
from repro.optim import AdamW
from repro.train.losses import relative_l2


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    args = ap.parse_args()

    cfg = SFNOConfig(in_channels=3, out_channels=3, hidden_channels=16,
                     n_layers=2, nlat=32, nlon=64, lmax=16, mmax=16,
                     lifting_channels=16, projection_channels=16)
    params = init_sfno(jax.random.PRNGKey(0), cfg)
    policy = get_policy("mixed_fno_bf16")
    opt = AdamW(lr=2e-3)
    state = opt.init(params)

    @jax.jit
    def step(p, s, x, y):
        def loss_fn(pp):
            return relative_l2(sfno_apply(pp, x, cfg, policy), y)
        loss, g = jax.value_and_grad(loss_fn)(p)
        p2, s2 = opt.update(g, s, p)
        return p2, s2, loss

    for i in range(args.steps):
        # on-the-fly data generation, as in the paper's SWE setup
        x, y = sample_swe_batch(jax.random.PRNGKey(100 + i), 32, 64, 4, steps=40)
        params, state, loss = step(params, state, x, y)
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:3d}  rel-L2 {float(loss):.4f}")

    x, y = sample_swe_batch(jax.random.PRNGKey(999), 32, 64, 4, steps=40)
    e = float(relative_l2(sfno_apply(params, x, cfg, FULL), y))
    print(f"eval rel-L2 (fresh ICs): {e:.4f}")


if __name__ == "__main__":
    main()
