"""llava-next-mistral-7b [vlm] — mistral-7b backbone; anyres tiling
frontend is a STUB (input_specs provides precomputed patch embeddings,
576 base-resolution patches prepended to the text sequence).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from .base import LMArchConfig

CONFIG = LMArchConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=32000, head_dim=128,
    frontend="vision_stub", n_patches=576,
)

SMOKE = LMArchConfig(
    name="llava-next-mistral-7b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, head_dim=16,
    frontend="vision_stub", n_patches=8,
)
