"""Real spherical harmonic transform (SHT) built from scratch:
longitude FFT + per-order Legendre matmul on a Gauss-Legendre grid.

This is the substrate SFNO (Bonev et al. 2023) needs; torch-harmonics is
not available in JAX, and re-deriving it makes the spherical path
matmul-dominant — exactly the structure the paper's mixed-precision
contraction accelerates (the Legendre transform is a (lat × l) GEMM per
order m, batched over channels).

Conventions: fully-normalised spherical harmonics Y_lm = P̄_lm(cosθ)e^{imφ}
with ∫|Y_lm|²dΩ = 1; Gauss-Legendre latitude nodes make the analysis/
synthesis roundtrip exact for band-limited fields (lmax <= nlat-1).
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax.numpy as jnp
import numpy as np


@functools.lru_cache(maxsize=16)
def legendre_matrices(nlat: int, lmax: int, mmax: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Precompute (P, x, w): P[m, l, lat] = P̄_lm(x_lat) (0 for l < m),
    Gauss-Legendre nodes x and weights w.  float64 numpy for stability."""
    x, w = np.polynomial.legendre.leggauss(nlat)
    P = np.zeros((mmax, lmax, nlat), dtype=np.float64)
    sin2 = 1.0 - x * x
    # p̄_mm via upward recurrence in m
    pmm = np.full(nlat, math.sqrt(1.0 / (4.0 * math.pi)))
    for m in range(mmax):
        if m > 0:
            pmm = -np.sqrt((2.0 * m + 1.0) / (2.0 * m)) * np.sqrt(sin2) * pmm
        if m < lmax:
            P[m, m] = pmm
        if m + 1 < lmax:
            P[m, m + 1] = x * math.sqrt(2.0 * m + 3.0) * pmm
        for l in range(m + 2, lmax):
            a = math.sqrt((4.0 * l * l - 1.0) / (l * l - m * m))
            b = math.sqrt(((l - 1.0) ** 2 - m * m) / (4.0 * (l - 1.0) ** 2 - 1.0))
            P[m, l] = a * (x * P[m, l - 1] - b * P[m, l - 2])
    return P, x, w


def sht_forward(f: jnp.ndarray, lmax: int, mmax: int, precision=None) -> jnp.ndarray:
    """Analysis: f (..., nlat, nlon) real -> coeffs (..., lmax, mmax) complex.

    coeffs[l,m] = Σ_lat w_lat P̄_lm(x_lat) · (2π/nlon)·rfft(f)[lat, m]

    ``precision`` is an optional resolved ``SitePrecision`` (a
    ``*/spectral/fft_in`` site): the transform itself runs in f32 — like
    the planar FFT, there is no half SHT on TPU — and the output spectrum
    is boundary-quantised onto the site's storage grid (Thm 3.2's
    representation error).
    """
    nlat, nlon = f.shape[-2], f.shape[-1]
    P, _, w = legendre_matrices(nlat, lmax, mmax)
    Pw = jnp.asarray((P * w[None, None, :]), jnp.float32)  # (m, l, lat)
    Fm = jnp.fft.rfft(f.astype(jnp.float32), axis=-1) * (2.0 * math.pi / nlon)
    Fm = Fm[..., :mmax]  # (..., lat, m)
    # coeffs[..., l, m] = Σ_lat Pw[m, l, lat] Fm[..., lat, m]
    coeffs = jnp.einsum("mlt,...tm->...lm", Pw.astype(jnp.complex64), Fm)
    if precision is not None:
        coeffs = precision.quantize(coeffs)
    return coeffs


def sht_inverse(coeffs: jnp.ndarray, nlat: int, nlon: int) -> jnp.ndarray:
    """Synthesis: coeffs (..., lmax, mmax) -> f (..., nlat, nlon) real."""
    lmax, mmax = coeffs.shape[-2], coeffs.shape[-1]
    P, _, _ = legendre_matrices(nlat, lmax, mmax)
    Pj = jnp.asarray(P, jnp.float32)  # (m, l, lat)
    G = jnp.einsum("mlt,...lm->...tm", Pj.astype(jnp.complex64), coeffs)
    nfreq = nlon // 2 + 1
    if mmax > nfreq:  # orders beyond the grid's Nyquist cannot be realised
        G = G[..., :nfreq]
    pad = nfreq - G.shape[-1]
    if pad > 0:
        G = jnp.pad(G, [(0, 0)] * (G.ndim - 1) + [(0, pad)])
    # irfft applies the hermitian doubling and 1/nlon; the real-field
    # synthesis needs G_0 + 2ReΣ_{m>0} G_m e^{imφ}, i.e. scale by nlon.
    # (Roundtrip identity: rfft∘irfft = id, quadrature ∫p̄²dx = 1/2π.)
    f = jnp.fft.irfft(G, n=nlon, axis=-1) * float(nlon)
    return f
