"""Mamba-2 SSD layer (state-space duality, arXiv:2405.21060).

Chunked algorithm: within a chunk the recurrence is evaluated as a masked
(attention-like) tensor contraction; across chunks a small recurrent state
(B, H, P, N) is carried by ``lax.scan``.  The chunk contractions go through
the paper's memory-greedy contraction executor (`repro.core.contract`) —
this is where the paper's technique partially applies to the SSM family
(DESIGN.md §5): storage at the policy's compute dtype, f32 accumulation.

Decode is the O(1) recurrent update — the sub-quadratic serve path that
makes the long_500k cell runnable.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import contract, FULL
from repro.dist.constrain import constrain_bsd


def init_ssd(key, d_model, d_inner, n_heads, d_state):
    P = d_inner // n_heads
    keys = jax.random.split(key, 7)
    s_in = (1.0 / d_model) ** 0.5
    return {
        # fused input projection: [x (d_inner), z (d_inner), B (N), C (N), dt (H)]
        "w_in": s_in * jax.random.normal(
            keys[0], (d_model, 2 * d_inner + 2 * d_state + n_heads), jnp.float32
        ),
        "w_out": (1.0 / d_inner) ** 0.5 * jax.random.normal(
            keys[1], (d_inner, d_model), jnp.float32
        ),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads).astype(jnp.float32)),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm_w": jnp.ones((d_inner,), jnp.float32),
    }


def _split_proj(params, u, d_inner, d_state, _n_heads, dtype):
    proj = jnp.einsum("...d,de->...e", u.astype(dtype), params["w_in"].astype(dtype),
                      preferred_element_type=jnp.float32).astype(dtype)
    x, z, Bc, Cc, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + d_state, 2 * d_inner + 2 * d_state],
        axis=-1,
    )
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    return x, z, Bc.astype(jnp.float32), Cc.astype(jnp.float32), dt


def ssd_forward(
    params, u: jnp.ndarray, cfg, policy=FULL
) -> jnp.ndarray:
    """u: (B, S, d_model) -> (B, S, d_model); chunked SSD over S.

    Dense projections resolve the ``lm/dense`` site; the intra-chunk
    score contraction goes through ``lm/ssd/spectral/contract`` so the
    mixed spectral rule sets reach the SSM family's GEMMs too
    (DESIGN.md §5)."""
    dtype = policy.at("lm/dense").compute_dtype
    ctr = policy.at("lm/ssd/spectral/contract")
    B, S, _ = u.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    Q = cfg.ssm_chunk
    d_inner = cfg.d_inner

    u = constrain_bsd(u)
    x, z, Bc, Cc, dt = _split_proj(params, u, d_inner, N, H, dtype)
    x = constrain_bsd(x)
    A = -jnp.exp(params["A_log"])                    # (H,) negative

    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // Q

    xh = x.reshape(B, nc, Q, H, P).astype(jnp.float32)
    dtc = dt.reshape(B, nc, Q, H)
    Bcc = Bc.reshape(B, nc, Q, N)
    Ccc = Cc.reshape(B, nc, Q, N)

    # per-step log decay and within-chunk cumulative decay
    dA = dtc * A[None, None, None, :]                # (B, nc, Q, H) negative
    cum = jnp.cumsum(dA, axis=2)                     # a_t = Σ_{s<=t} dA_s

    def chunk_step(state, inp):
        # state: (B, H, P, N)
        xq, dtq, bq, cq, aq, da = inp                # xq (B,Q,H,P) etc.
        # intra-chunk "attention": L[t,s] = exp(a_t - a_s) for s<=t.
        # Mask the *exponent* (not the result): where() after exp() leaks
        # inf into the gradient of masked entries.
        delta = aq[:, :, None, :] - aq[:, None, :, :]           # (B,Q,Qs,H)
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        delta = jnp.where(tri[None, :, :, None], delta, -jnp.inf)
        L = jnp.exp(delta)
        scores = contract("bqn,bsn->bqs", cq, bq, policy=ctr)  # (B,Q,Qs)
        xdt = xq * dtq[..., None]                    # (B,Q,H,P) dt-weighted
        y_intra = jnp.einsum("bqs,bqsh,bshp->bqhp", scores, L, xdt,
                             preferred_element_type=jnp.float32)
        # inter-chunk: contribution of the carried state
        y_inter = jnp.einsum("bqn,bhpn,bqh->bqhp", cq, state, jnp.exp(aq),
                             preferred_element_type=jnp.float32)
        # state update: S' = exp(a_Q) S + Σ_t exp(a_Q - a_t) B_t (dt_t x_t)
        decay_to_end = jnp.exp(aq[:, -1, None, :] - aq)          # (B,Q,H)
        ds = jnp.einsum("bqn,bqhp,bqh->bhpn", bq, xdt, decay_to_end,
                        preferred_element_type=jnp.float32)
        new_state = state * jnp.exp(aq[:, -1])[:, :, None, None] + ds
        return new_state, y_intra + y_inter

    state0 = jnp.zeros((B, H, P, N), jnp.float32)
    inputs = (
        xh.transpose(1, 0, 2, 3, 4),
        dtc.transpose(1, 0, 2, 3),
        Bcc.transpose(1, 0, 2, 3),
        Ccc.transpose(1, 0, 2, 3),
        cum.transpose(1, 0, 2, 3),
        dA.transpose(1, 0, 2, 3),
    )
    _, ys = jax.lax.scan(chunk_step, state0, inputs)             # (nc,B,Q,H,P)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, Sp, H, P)[:, :S]
    y = y + xh.reshape(B, Sp, H, P)[:, :S] * params["D"][None, None, :, None]

    # gated RMSNorm output (mamba2)
    y = y.reshape(B, S, d_inner)
    z = z[:, :S].astype(jnp.float32)
    y = y * jax.nn.silu(z)
    ms = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(ms + 1e-6) * params["norm_w"]
    return jnp.einsum("bsd,de->bse", y.astype(dtype), params["w_out"].astype(dtype),
                      preferred_element_type=jnp.float32).astype(dtype)


def ssd_decode_step(
    params, u: jnp.ndarray, state: jnp.ndarray, cfg, policy=FULL
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One-token recurrent update.  u: (B, d_model); state (B, H, P, N)."""
    dtype = policy.at("lm/dense").compute_dtype
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    x, z, Bc, Cc, dt = _split_proj(params, u, cfg.d_inner, N, H, dtype)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt * A[None, :])                                # (B, H)
    xh = x.reshape(-1, H, P).astype(jnp.float32)
    xdt = xh * dt[..., None]
    new_state = state * dA[:, :, None, None] + jnp.einsum(
        "bn,bhp->bhpn", Bc, xdt, preferred_element_type=jnp.float32
    )
    y = jnp.einsum("bn,bhpn->bhp", Cc, new_state,
                   preferred_element_type=jnp.float32)
    y = y + xh * params["D"][None, :, None]
    y = y.reshape(-1, cfg.d_inner) * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(y * y, axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(ms + 1e-6) * params["norm_w"]
    out = jnp.einsum("bd,de->be", y.astype(dtype), params["w_out"].astype(dtype),
                     preferred_element_type=jnp.float32).astype(dtype)
    return out, new_state
