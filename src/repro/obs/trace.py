"""Lightweight span/event tracing: the timeline half of ``repro.obs``.

Host-side wall-clock tracing designed to nest *around* jit boundaries:
a span brackets the host call (``step()``, ``tick()``) and the caller
fences with ``jax.block_until_ready`` inside it, so the span's duration
attributes device wall to the host phase that launched it.  Nothing here
touches traced values — tracing a jitted function records the (one-off)
trace, executing it records the dispatch+device wall.

Design constraints (the <5% overhead budget of ``bench_obs``):

* **off is free**: ``span(...)`` / ``event(...)`` check one module-level
  flag and return a shared no-op object — no allocation, no lock;
* **on is a ring buffer**: records land in a preallocated ring of
  fixed-slot lists (drop-oldest, ``dropped()`` counts what fell off);
  writing a record assigns slots in place — the only per-record
  allocation is the caller's ``attrs`` dict when it passes attributes;
* **monotonic time**: ``time.perf_counter_ns`` relative to the enable()
  origin, so exported timelines are comparable across threads.

Record kinds (the wire vocabulary shared with :mod:`repro.obs.export`):

  ``span``   completed span: name, ts, dur, tid, depth, parent, attrs
  ``event``  instant: name, ts, tid, category, attrs
  ``b``/``e``  async begin/end pair correlated by ``id`` (request
             lifecycle phases: queued -> prefill -> decode -> drain)

Thread-local span stacks give nesting (depth + parent name) without any
cross-thread coordination; the ring itself takes one lock per record.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional

#: record slot layout: [kind, name, ts_ns, dur_ns, tid, depth, parent,
#: category, id, attrs]
_KIND, _NAME, _TS, _DUR, _TID, _DEPTH, _PARENT, _CAT, _ID, _ATTRS = range(10)
_N_SLOTS = 10

DEFAULT_CAPACITY = 1 << 16


class _Ring:
    """Preallocated drop-oldest ring of record slots."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.slots: List[list] = [[None] * _N_SLOTS for _ in range(capacity)]
        self.head = 0          # next write index
        self.count = 0         # live records (<= capacity)
        self.dropped = 0
        self.lock = threading.Lock()

    def write(self, kind, name, ts, dur, tid, depth, parent, cat, rid, attrs):
        with self.lock:
            slot = self.slots[self.head]
            slot[_KIND] = kind
            slot[_NAME] = name
            slot[_TS] = ts
            slot[_DUR] = dur
            slot[_TID] = tid
            slot[_DEPTH] = depth
            slot[_PARENT] = parent
            slot[_CAT] = cat
            slot[_ID] = rid
            slot[_ATTRS] = attrs
            self.head = (self.head + 1) % self.capacity
            if self.count < self.capacity:
                self.count += 1
            else:
                self.dropped += 1


_enabled = False
_ring: Optional[_Ring] = None
_origin_ns = 0
_local = threading.local()


def _stack() -> list:
    st = getattr(_local, "spans", None)
    if st is None:
        st = _local.spans = []
    return st


def enable(capacity: int = DEFAULT_CAPACITY) -> None:
    """Turn tracing on with a fresh ring of ``capacity`` records."""
    global _enabled, _ring, _origin_ns
    _ring = _Ring(capacity)
    _origin_ns = time.perf_counter_ns()
    _enabled = True


def disable() -> None:
    """Turn tracing off (the ring is kept until ``enable``/``clear``)."""
    global _enabled
    _enabled = False


def is_enabled() -> bool:
    return _enabled


def clear() -> None:
    """Drop every buffered record (keeps the enabled state)."""
    global _ring, _origin_ns
    if _ring is not None:
        _ring = _Ring(_ring.capacity)
        _origin_ns = time.perf_counter_ns()


def dropped() -> int:
    """Records lost to ring wrap since enable()/clear()."""
    return _ring.dropped if _ring is not None else 0


def _now() -> int:
    return time.perf_counter_ns() - _origin_ns


class _NullSpan:
    """The shared no-op returned while tracing is off."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL = _NullSpan()


class _Span:
    __slots__ = ("name", "attrs", "t0", "depth", "parent")

    def __init__(self, name: str, attrs: Optional[Dict[str, Any]]):
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        st = _stack()
        self.depth = len(st)
        self.parent = st[-1] if st else None
        st.append(self.name)
        self.t0 = _now()
        return self

    def __exit__(self, *exc):
        t1 = _now()
        st = _stack()
        if st and st[-1] == self.name:
            st.pop()
        if _enabled and _ring is not None:
            _ring.write("span", self.name, self.t0, t1 - self.t0,
                        threading.get_ident(), self.depth, self.parent,
                        None, None, self.attrs)
        return False


def span(name: str, **attrs):
    """Context manager timing one host-side phase.

    Spans nest through a thread-local stack (depth + parent recorded);
    wrap device work together with its ``block_until_ready`` fence so
    the duration includes device wall.  Free no-op while disabled.
    """
    if not _enabled:
        return _NULL
    return _Span(name, attrs or None)


def event(name: str, category: str = "event", **attrs) -> None:
    """Record one instant event.  No-op while disabled."""
    if not _enabled or _ring is None:
        return
    _ring.write("event", name, _now(), None, threading.get_ident(),
                len(_stack()), None, category, None, attrs or None)


def begin(name: str, rid, category: str = "async", **attrs) -> None:
    """Open an async interval correlated by ``rid`` (e.g. request uid).
    Renders as an async track slice in Perfetto once ``end`` closes it."""
    if not _enabled or _ring is None:
        return
    _ring.write("b", name, _now(), None, threading.get_ident(),
                0, None, category, rid, attrs or None)


def end(name: str, rid, category: str = "async", **attrs) -> None:
    """Close the async interval opened by ``begin(name, rid)``."""
    if not _enabled or _ring is None:
        return
    _ring.write("e", name, _now(), None, threading.get_ident(),
                0, None, category, rid, attrs or None)


_FIELDS = ("kind", "name", "ts_ns", "dur_ns", "tid", "depth", "parent",
           "category", "id", "attrs")


def snapshot() -> List[Dict[str, Any]]:
    """The buffered records, oldest first, as JSON-friendly dicts."""
    if _ring is None:
        return []
    with _ring.lock:
        n, head, cap = _ring.count, _ring.head, _ring.capacity
        start = (head - n) % cap
        rows = [list(_ring.slots[(start + i) % cap]) for i in range(n)]
    out = []
    for row in rows:
        rec = {k: v for k, v in zip(_FIELDS, row, strict=True)
               if v is not None}
        rec.setdefault("kind", "event")
        out.append(rec)
    return out
