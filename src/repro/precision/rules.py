"""Site-pattern precision rule table: the single place where *precision
sites* map onto numeric formats.

This is the precision-domain twin of ``repro.dist.rules``.  Models and
step builders never hand-pick a dtype; they name a *site* — a
slash-separated address like ``"fno/layer2/spectral/contract"`` or
``"serve/kv_cache"`` — and a rule table maps site patterns onto
``SiteRule`` entries (compute dtype, accumulation dtype, stabiliser,
boundary quantisation, loss scaling).  A policy is nothing but a named
overlay of rules over the shared :data:`DEFAULT_RULES` base table, and
:func:`precision_rules` pushes scoped overrides (thread-local) exactly
like ``dist.axis_rules`` does for sharding.

Resolution is field-wise, first-match-wins: for each field of
``SiteRule`` the first entry (scoped overrides, then the policy's rules,
then ``DEFAULT_RULES``) whose pattern matches the site and whose field
is not :data:`UNSET` supplies the value.  Patterns use fnmatch
semantics, so ``"*/spectral/contract"`` matches any model's contraction
site and ``"fno/layer3/*"`` addresses one specific FNO layer — the
per-site expressiveness the paper's targeted-precision argument calls
for (half exactly where discretisation error dominates, full elsewhere).
"""
from __future__ import annotations

import dataclasses
import fnmatch
import threading
from contextlib import contextmanager
from typing import Any, Iterator, Sequence, Tuple

import jax.numpy as jnp


class _Unset:
    """Sentinel distinguishing "rule does not speak to this field" from an
    explicit ``None`` (which means "full precision" / "off")."""

    __slots__ = ()

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return "UNSET"


UNSET = _Unset()

#: SiteRule fields, in resolution order.
RULE_FIELDS = ("compute", "accum", "stabilize", "quantize", "loss_scaling")


@dataclasses.dataclass(frozen=True)
class SiteRule:
    """One rule-table entry.  Every field defaults to :data:`UNSET` so an
    overlay can override a single aspect of a site (e.g. just the
    stabiliser) without clobbering the rest.

    Fields (set explicitly to ``None`` to force the full-precision /
    disabled behaviour):

      compute:      storage/compute dtype at the site; ``None`` => f32
                    real / complex64 spectral (full precision).
      accum:        contraction accumulation dtype (default f32 — MXU).
      stabilize:    pre-FFT stabiliser name ('tanh' | 'hard_clip' |
                    'sigma_clip' | 'fixed_scale' | None).
      quantize:     boundary quantisation grid: ``None`` (off), '"half"'
                    (split-real storage at ``compute``), or a simulated
                    fp8 format name ('fp8_e4m3' | 'fp8_e5m2').
      loss_scaling: whether training under this rule set needs dynamic
                    loss scaling (fp16-family yes, bf16 no).
    """

    compute: Any = UNSET
    accum: Any = UNSET
    stabilize: Any = UNSET
    quantize: Any = UNSET
    loss_scaling: Any = UNSET


Entry = Tuple[str, SiteRule]

#: Convenience rule forcing a site back to full precision (the override
#: used for e.g. "last FNO layer in f32" experiments).
FULL_PRECISION = SiteRule(compute=None, stabilize=None, quantize=None)

#: The shared base table.  Policies are overlays on top of this; it
#: encodes the format-agnostic invariants: master weights and
#: reduction-sensitive ops (routers, output heads) stay f32, every
#: contraction accumulates in f32, loss scaling is off unless a rule set
#: turns it on.
DEFAULT_RULES: Tuple[Entry, ...] = (
    ("params", SiteRule(compute=jnp.float32)),
    ("*/router", SiteRule(compute=jnp.float32)),
    ("*/proj_out", SiteRule(compute=jnp.float32)),
    # serving: the sampler's softmax/filtering math is a reduction over
    # the vocab (AMP-blocklist treatment), and operator-inference outputs
    # transport at f32 regardless of the compute rule set.
    ("serve/sampler", SiteRule(compute=jnp.float32)),
    ("serve/operator", SiteRule(compute=jnp.float32)),
    ("train/loss_scale", SiteRule(loss_scaling=False)),
    (
        "*",
        SiteRule(
            compute=None,
            accum=jnp.float32,
            stabilize=None,
            quantize=None,
            loss_scaling=False,
        ),
    ),
)


def site_matches(pattern: str, site: str) -> bool:
    """fnmatch-style pattern match (``*`` crosses ``/`` boundaries, so
    ``*/spectral/contract`` matches ``fno/layer3/spectral/contract``)."""
    return pattern == site or fnmatch.fnmatchcase(site, pattern)


def normalize_entries(entries: Sequence) -> Tuple[Entry, ...]:
    """Accept (pattern, SiteRule) or (pattern, dict) pairs."""
    out = []
    for e in entries:
        try:
            pattern, r = e
        except (TypeError, ValueError):
            raise TypeError(
                f"rule entry must be a (pattern, SiteRule) pair, got {e!r}"
            ) from None
        if isinstance(r, dict):
            r = SiteRule(**r)
        if not isinstance(r, SiteRule):
            raise TypeError(f"rule for {pattern!r} must be a SiteRule, got {type(r)}")
        out.append((str(pattern), r))
    return tuple(out)


_local = threading.local()


def current_overrides() -> Tuple[Entry, ...]:
    """The active scoped-override entries (innermost scope first)."""
    return getattr(_local, "overrides", ())


@contextmanager
def precision_rules(*entries) -> Iterator[None]:
    """Scope-local precision overrides, symmetric to ``dist.axis_rules``.

    Entries are ``(site_pattern, SiteRule)`` pairs prepended to rule
    resolution for the dynamic scope, taking precedence over the active
    policy's own rules:

    >>> with precision_rules(("fno/layer3/*", FULL_PRECISION)):
    ...     y = fno_apply(params, x, cfg, get_policy("mixed_fno_bf16"))

    Like the sharding overrides, these are consulted at *trace* time —
    an already-jitted function keeps the rules it was traced under.
    """
    norm = normalize_entries(entries)
    prev = current_overrides()
    _local.overrides = norm + prev
    try:
        yield
    finally:
        _local.overrides = prev


def resolve_fields(site: str, rules: Tuple[Entry, ...]) -> dict:
    """Field-wise first-match resolution of ``site`` through the scoped
    overrides, then ``rules`` (a policy's overlay), then DEFAULT_RULES.
    Returns a dict with every field of :class:`SiteRule` filled in."""
    fields = {f: UNSET for f in RULE_FIELDS}
    missing = len(RULE_FIELDS)
    for pattern, rule in current_overrides() + tuple(rules) + DEFAULT_RULES:
        if not site_matches(pattern, site):
            continue
        for f in RULE_FIELDS:
            if fields[f] is UNSET:
                v = getattr(rule, f)
                if v is not UNSET:
                    fields[f] = v
                    missing -= 1
        if not missing:
            break
    # the catch-all in DEFAULT_RULES guarantees completion, but guard
    # against a caller stripping it:
    for f, default in (
        ("compute", None),
        ("accum", jnp.float32),
        ("stabilize", None),
        ("quantize", None),
        ("loss_scaling", False),
    ):
        if fields[f] is UNSET:
            fields[f] = default
    return fields
