"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``*_ref`` computes the mathematically exact (f32/complex64) result the
kernel must match to within the storage-precision tolerance.  Tests sweep
shapes/dtypes and ``assert_allclose`` kernel-vs-oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def spectral_contract_ref(
    x: jnp.ndarray, w: jnp.ndarray
) -> jnp.ndarray:
    """Oracle for the spectral contraction.

    x: (B, I, M) complex64; w: (I, O, M) complex64 -> (B, O, M) complex64.
    """
    return jnp.einsum("bim,iom->bom", x, w)


def spectral_contract_cp_ref(
    x: jnp.ndarray, lam: jnp.ndarray, ui: jnp.ndarray, uo: jnp.ndarray,
    w_modes: jnp.ndarray,
) -> jnp.ndarray:
    """Oracle for the CP-factorised contraction with the combined mode
    factor already folded (``w_modes[r, m] = λ_r Π_k U_mk[m_k, r]``;
    pass ``lam = ones`` in that case, or the raw λ with the bare product
    of mode factors).

    x: (B, I, M); ui: (I, R); uo: (O, R); w_modes: (R, M) -> (B, O, M).
    """
    return jnp.einsum("bim,r,ir,or,rm->bom", x, lam, ui, uo, w_modes)


def flash_attention_ref(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, causal: bool = True
) -> jnp.ndarray:
    """Oracle softmax attention. q/k/v: (BH, S, D)."""
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", qf, kf) * scale
    if causal:
        S, Sk = q.shape[1], k.shape[1]
        mask = jnp.arange(S)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, vf).astype(q.dtype)


def rmsnorm_ref(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * w.astype(jnp.float32)).astype(x.dtype)
