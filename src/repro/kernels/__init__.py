"""Pallas TPU kernels for the performance-critical compute layers.

  spectral_contract — the paper's hot-spot: complex spectral tensor
                      contraction in split-real half precision, f32 MXU
                      accumulation (Appendix B.4 / Table 8 Option C).
  flash_attention   — blocked online-softmax attention for the 32k-token
                      prefill cells of the LM architecture pool.
  rmsnorm           — bandwidth-bound normalisation, f32 reduction.

Each kernel: ``<name>.py`` (pl.pallas_call + BlockSpec), a jit'd wrapper in
``ops.py``, and a pure-jnp oracle in ``ref.py``.  On this CPU container all
kernels run (and are tested) in interpret mode; on TPU the identical call
sites compile to Mosaic.
"""
from . import ops, ref  # noqa: F401
