"""CLI: ``python -m repro.analyze`` — run all passes, print the findings
table, write ``benchmarks/results/analyze.json``, exit nonzero on any
unsuppressed error-severity finding (the CI merge gate).
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List

from .findings import (
    ERROR,
    Finding,
    dedupe,
    load_suppressions,
    partition,
    summarize,
)

_REPO_ROOT = os.path.normpath(
    os.path.join(os.path.dirname(__file__), "..", "..", ".."))
DEFAULT_OUT = os.path.join(_REPO_ROOT, "benchmarks", "results", "analyze.json")
DEFAULT_SUPPRESSIONS = os.path.join(_REPO_ROOT, "analyze.toml")
DEFAULT_SRC = os.path.join(_REPO_ROOT, "src")

MODELS = ("fno", "tfno", "sfno")


def run_dataflow(policies: List[str], models: List[str],
                 pallas_paths: List[bool], trainer: bool) -> List[Finding]:
    from repro.precision.policy import get_policy

    from .dataflow import model_findings, trainer_findings

    findings: List[Finding] = []
    for name in policies:
        policy = get_policy(name)
        for model in models:
            for use_pallas in pallas_paths:
                findings.extend(model_findings(model, policy, use_pallas))
        if trainer:
            findings.extend(trainer_findings(policy))
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analyze",
        description="static numerics & precision linter (jaxpr dtype flow, "
                    "site rules, Pallas kernels)")
    ap.add_argument("--policies", nargs="*", default=None,
                    help="registry policies to trace (default: all)")
    ap.add_argument("--models", nargs="*", default=list(MODELS),
                    choices=MODELS, help="models to trace")
    ap.add_argument("--pallas", choices=("both", "on", "off"),
                    default="both",
                    help="which spectral kernel paths to trace")
    ap.add_argument("--no-trainer", action="store_true",
                    help="skip the full-Trainer-step traces")
    ap.add_argument("--skip", nargs="*", default=[],
                    choices=("dataflow", "sites", "kernels", "calibration",
                             "obs"),
                    help="passes to skip")
    ap.add_argument("--calibration-state", default=None,
                    help="calibration-state JSON to lint for tile "
                         "coverage (default: $REPRO_CALIBRATION_STATE; "
                         "the check is skipped when neither is set)")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="findings report path (JSON)")
    ap.add_argument("--suppressions", default=DEFAULT_SUPPRESSIONS,
                    help="reviewed-allowlist TOML (missing file = empty)")
    ap.add_argument("--src", default=DEFAULT_SRC,
                    help="source root for the site-literal AST scan")
    ap.add_argument("--max-print", type=int, default=20,
                    help="cap on individually printed findings per severity")
    args = ap.parse_args(argv)

    from repro.precision.policy import POLICIES

    policies = args.policies or sorted(POLICIES)
    pallas_paths = {"both": [False, True], "on": [True],
                    "off": [False]}[args.pallas]

    findings: List[Finding] = []
    if "dataflow" not in args.skip:
        print(f"[analyze] dataflow: {len(policies)} policies x "
              f"{len(args.models)} models x {len(pallas_paths)} paths"
              + ("" if args.no_trainer else " + trainer steps"))
        findings.extend(run_dataflow(policies, args.models, pallas_paths,
                                     trainer=not args.no_trainer))
    if "sites" not in args.skip:
        print(f"[analyze] sites: AST scan of {args.src} + rule tables")
        from .sites import sites_pass

        findings.extend(sites_pass(args.src))
    if "kernels" not in args.skip:
        print("[analyze] kernels: tracing Pallas kernel families")
        from .kernels import kernels_pass

        findings.extend(kernels_pass())
    if "obs" not in args.skip:
        print(f"[analyze] obs: counter-registry coverage scan of {args.src}")
        from .obscov import obs_coverage_pass

        findings.extend(obs_coverage_pass(args.src))
    if "calibration" not in args.skip:
        cal_path = (args.calibration_state
                    or os.environ.get("REPRO_CALIBRATION_STATE"))
        if cal_path:
            print(f"[analyze] calibration: tile coverage of {cal_path}")
            from .kernels import calibration_pass

            findings.extend(calibration_pass(cal_path))

    findings = dedupe(findings)
    suppressions = load_suppressions(args.suppressions)
    active, suppressed = partition(findings, suppressions)
    summary = summarize(active)

    # -- report --------------------------------------------------------------
    print()
    print(f"{'pass':<10} {'check':<22} {'severity':<9} count")
    for row in summary["by_check"]:
        print(f"{row['pass']:<10} {row['check']:<22} {row['severity']:<9} "
              f"{row['count']}")
    if not summary["by_check"]:
        print("(no findings)")
    print(f"\n{summary['errors']} error(s), {summary['warnings']} "
          f"warning(s); {len(suppressed)} suppressed via "
          f"{os.path.relpath(args.suppressions, _REPO_ROOT)}")

    errors = [f for f in active if f.severity == ERROR]
    for sev, rows in (("error", errors),
                      ("warning", [f for f in active
                                   if f.severity != ERROR])):
        for f in rows[:args.max_print]:
            loc = f" site={f.site}" if f.site else ""
            print(f"  [{sev}] {f.check} @ {f.where}{loc}: {f.detail}")
        if len(rows) > args.max_print:
            print(f"  ... {len(rows) - args.max_print} more {sev}(s) — "
                  f"see {os.path.relpath(args.out, _REPO_ROOT)}")

    report = {
        "policies": policies,
        "models": list(args.models),
        "pallas_paths": pallas_paths,
        "summary": summary,
        "findings": [f.to_json() for f in active],
        "suppressed": [f.to_json() for f in suppressed],
    }
    from repro.obs import write_result

    write_result(args.out, report)
    print(f"\nwrote {args.out}")

    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
