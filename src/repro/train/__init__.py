from .losses import cross_entropy, relative_h1, relative_l2  # noqa: F401
from .trainer import Trainer, TrainerConfig  # noqa: F401
from . import checkpoint  # noqa: F401
