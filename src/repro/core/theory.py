"""Empirical estimators and bounds for the paper's theory (Section 3, App. A).

* ``disc_error``  — Eq. (1): |∫_D v φ_ω dx − Σ_j v(ξ_j) φ_ω(ξ_j) |Q_j||,
  the discretisation error of the Fourier transform on the lattice Q_d.
* ``prec_error``  — Eq. (2): the additional error from evaluating the sum
  with quantised values q(v(ξ)) q(φ(ξ)).
* Closed-form worst-case bounds:
    Thm 3.1:  c1 √d M n^{-2/d}  <=  sup Disc  <=  c2 √d (|ω|+L) M n^{-1/d}
    Thm 3.2:  sup Prec <= c ε M            (c = 4 in the paper's proof)
    Thm A.1/A.2: analogous bounds for general (non-Fourier) integrands.

The benchmark ``benchmarks/bench_theory.py`` reproduces Fig. 7 by plotting
these bounds against measured errors on Darcy-flow-like fields.
"""
from __future__ import annotations

import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .precision import PrecisionSystem


# ---------------------------------------------------------------------------
# Lattice construction:  Q_d with n = m^d cells, ξ_j = lower corner of Q_j
# ---------------------------------------------------------------------------


def lattice(m: int, d: int) -> np.ndarray:
    """Return the (m^d, d) array of ξ_j = (i_1/m, ..., i_d/m)."""
    axes = [np.arange(m) / m for _ in range(d)]
    grid = np.meshgrid(*axes, indexing="ij")
    return np.stack([g.reshape(-1) for g in grid], axis=-1)


def fourier_basis(xi: np.ndarray, omega: float) -> np.ndarray:
    """φ_ω(x) = exp(2πi <ω·1, x>) with scalar frequency applied isotropically."""
    phase = 2.0 * math.pi * omega * xi.sum(axis=-1)
    return np.exp(1j * phase)


# ---------------------------------------------------------------------------
# Empirical errors
# ---------------------------------------------------------------------------


def riemann_sum(v: Callable[[np.ndarray], np.ndarray], m: int, d: int, omega: float) -> complex:
    xi = lattice(m, d)
    vals = v(xi) * fourier_basis(xi, omega)
    return complex(vals.sum() / (m ** d))


def disc_error(
    v: Callable[[np.ndarray], np.ndarray],
    m: int,
    d: int,
    omega: float,
    ref_multiplier: int = 8,
) -> float:
    """Eq. (1), with the true integral estimated on an 8x finer lattice."""
    coarse = riemann_sum(v, m, d, omega)
    fine = riemann_sum(v, m * ref_multiplier, d, omega)
    return abs(fine - coarse)


def prec_error(
    v: Callable[[np.ndarray], np.ndarray],
    m: int,
    d: int,
    omega: float,
    q: Optional[PrecisionSystem] = None,
    dtype: str = "float16",
) -> float:
    """Eq. (2): quantise both v(ξ) and φ_ω(ξ) then compare the sums.

    With ``q=None`` the quantiser is the actual numpy cast to ``dtype``
    (the "true difference in precision between float32 and float16" used in
    the paper's Fig. 7)."""
    xi = lattice(m, d)
    vals = v(xi).astype(np.float64)
    phi = fourier_basis(xi, omega)
    exact = (vals * phi).sum() / (m ** d)
    if q is not None:
        qv = np.asarray(jax.device_get(q.quantize(jnp.asarray(vals))))
        qpr = np.asarray(jax.device_get(q.quantize(jnp.asarray(phi.real))))
        qpi = np.asarray(jax.device_get(q.quantize(jnp.asarray(phi.imag))))
    else:
        dt = np.dtype(dtype)
        qv = vals.astype(dt).astype(np.float64)
        qpr = phi.real.astype(dt).astype(np.float64)
        qpi = phi.imag.astype(dt).astype(np.float64)
    approx = (qv * (qpr + 1j * qpi)).sum() / (m ** d)
    return abs(exact - approx)


# ---------------------------------------------------------------------------
# Closed-form bounds
# ---------------------------------------------------------------------------


def disc_upper_bound(n: int, d: int, omega: float, L: float, M: float, c2: float = 2.0) -> float:
    """Thm 3.1 upper: c2 √d (M|ω| + L) n^{-1/d}."""
    return c2 * math.sqrt(d) * (M * abs(omega) + L) * n ** (-1.0 / d)


def disc_lower_bound(n: int, d: int, M: float, c1: float = None) -> float:
    """Thm 3.1 lower (ω=1, v = x_1···x_d): d/(3·2^d·π^{d-2}) · n^{-2/d}·M."""
    if c1 is None:
        c1 = d / (3.0 * 2 ** d * math.pi ** (d - 2))
    return c1 * M * n ** (-2.0 / d)


def prec_upper_bound(eps: float, M: float, c: float = 4.0) -> float:
    """Thm 3.2: c · ε · M  (paper's proof gives c = 4)."""
    return c * eps * M


def prec_lower_bound(eps: float, M: float) -> float:
    """Thm A.2 lower: ε M / 4."""
    return 0.25 * eps * M


def general_disc_upper_bound(n: int, d: int, L: float) -> float:
    """Thm A.1 upper: L √d n^{-1/d}."""
    return L * math.sqrt(d) * n ** (-1.0 / d)


def crossover_mesh_size(eps: float, d: int, M: float = 1.0, L: float = 1.0, omega: float = 1.0) -> float:
    """Mesh size n* where the discretisation upper bound falls to the
    precision bound: below n* half precision is 'free'.  The paper quotes
    n* ~ 1e6 for d=3, fp16 (ε≈1e-4)."""
    # c2 √d (M|ω|+L) n^{-1/d} = 4 ε M   =>  n* = (c2 √d (M|ω|+L) / (4εM))^d
    c2 = 2.0
    return (c2 * math.sqrt(d) * (M * abs(omega) + L) / (4.0 * eps * M)) ** d


# Convenience: Lipschitz/M estimation on sampled fields (for Fig. 7 with
# real Darcy data where L and M must be measured).


def estimate_lipschitz_and_bound(field: np.ndarray) -> tuple:
    """Given a sampled field on a uniform grid (any d), estimate (L, M)."""
    M = float(np.abs(field).max())
    L = 0.0
    for ax in range(field.ndim):
        diff = np.abs(np.diff(field, axis=ax)) * field.shape[ax]
        if diff.size:
            L = max(L, float(diff.max()))
    return L, M
