"""granite-moe-3b-a800m [moe] — MoE 40e top-8 (assignment header; the
inline comment says 32e — we follow the explicit '40e top-8' spec),
per-expert d_ff=512.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from .base import LMArchConfig

CONFIG = LMArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    d_ff=512, vocab=49155, head_dim=64,
    moe_experts=40, moe_top_k=8, moe_shared=0, moe_ff=512,
)

SMOKE = LMArchConfig(
    name="granite-moe-3b-a800m-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=64, vocab=256, head_dim=16,
    moe_experts=4, moe_top_k=2, moe_shared=0, moe_ff=64,
)
