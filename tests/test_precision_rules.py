"""Tests for the site-addressed precision API (repro.precision).

Covers: rule resolution / scoping, bit-identity of the rebuilt registry
rule sets against a reference implementation of the old flat-dataclass
pipeline, per-site overrides the old API could not express, the overlay
schedule, loss-scaling resolution, and the simulated fp8 rule sets.
"""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ComplexPair,
    FULL,
    PrecisionSchedule,
    contract,
    get_policy,
    quantize_complex,
    simulate_fp8,
)
from repro.core.stabilizer import get_stabilizer
from repro.models import FNOConfig, fno_apply, init_fno
from repro.models.fno import _linear, layers_uniform
from repro.models.lm import init_lm, lm_forward
from repro.configs import get_config
from repro.optim import loss_scaling_required
from repro.precision import (
    FULL_PRECISION,
    SiteRule,
    describe,
    precision_rules,
)

jax.config.update("jax_platform_name", "cpu")

POLICY_NAMES = ["full", "amp_bf16", "mixed_fno_bf16", "mixed_fno_fp16"]


# ---------------------------------------------------------------------------
# Rule resolution
# ---------------------------------------------------------------------------


class TestResolution:
    def test_registry_resolves_like_old_dataclass(self):
        """The rebuilt rule sets resolve to exactly the formats the old
        flat policy fields carried."""
        expect = {
            # name: (compute, spectral, stabilizer, loss_scaling)
            "full": (jnp.float32, None, None, False),
            "amp_fp16": (jnp.float16, None, None, True),
            "amp_bf16": (jnp.bfloat16, None, None, False),
            "mixed_fno_fp16": (jnp.float16, jnp.float16, "tanh", True),
            "mixed_fno_bf16": (jnp.bfloat16, jnp.bfloat16, "tanh", False),
            "half_fno_only": (jnp.float32, jnp.float16, "tanh", True),
        }
        for name, (cdt, sdt, stab, ls) in expect.items():
            p = get_policy(name)
            assert p.compute_dtype == cdt, name
            assert p.spectral_dtype == sdt, name
            assert p.stabilizer == stab, name
            assert p.requires_loss_scaling is ls, name
            assert loss_scaling_required(p) is ls, name

    def test_sites_resolve_independently(self):
        p = get_policy("mixed_fno_bf16")
        # routers and output heads stay f32 even under the mixed rule set
        assert p.at("lm/router").compute_dtype == jnp.float32
        assert p.at("fno/proj_out").compute_dtype == jnp.float32
        assert p.at("params").compute_dtype == jnp.float32
        # spectral sites are addressable per layer
        s = p.at("fno/layer3/spectral/contract")
        assert s.spectral_dtype == jnp.bfloat16
        assert s.accum_dtype == jnp.float32
        # kv cache follows the rule set's compute dtype
        assert p.at("serve/kv_cache").compute_dtype == jnp.bfloat16
        assert get_policy("full").at("serve/kv_cache").compute_dtype == jnp.float32

    def test_precision_rules_scoping(self):
        p = get_policy("mixed_fno_bf16")
        assert p.at("fno/layer2/spectral/contract").spectral_is_half
        with precision_rules(("fno/layer2/*", FULL_PRECISION)):
            assert not p.at("fno/layer2/spectral/contract").spectral_is_half
            # other layers untouched
            assert p.at("fno/layer1/spectral/contract").spectral_is_half
            # nesting: innermost wins
            with precision_rules(
                ("fno/layer2/*", SiteRule(compute=jnp.float16, quantize="half"))
            ):
                assert (
                    p.at("fno/layer2/spectral/contract").spectral_dtype == jnp.float16
                )
            assert not p.at("fno/layer2/spectral/contract").spectral_is_half
        assert p.at("fno/layer2/spectral/contract").spectral_is_half

    def test_field_wise_merge(self):
        """An overlay overriding one field leaves the others resolved by
        the policy's own rules."""
        p = get_policy("mixed_fno_fp16").with_rules(
            ("*/spectral/*", SiteRule(stabilize="hard_clip"))
        )
        s = p.at("fno/layer0/spectral/fft_in")
        assert s.stabilizer == "hard_clip"
        assert s.spectral_dtype == jnp.float16  # untouched

    def test_describe_reports_canonical_sites(self):
        d = describe(get_policy("mixed_fno_fp16"))
        assert d["model/spectral/contract"]["compute"] == "float16"
        assert d["model/spectral/contract"]["quantize"] == "half"
        assert d["lm/router"]["compute"] == "float32"
        assert d["train/loss_scale"]["loss_scaling"] is True


# ---------------------------------------------------------------------------
# Bit-identity vs the old flat-dataclass pipeline
# ---------------------------------------------------------------------------


def _old_policy_view(policy):
    """The old flat dataclass, reconstructed from the policy's compat
    properties (these resolve through the rule table)."""
    return types.SimpleNamespace(
        compute_dtype=policy.compute_dtype,
        spectral_dtype=policy.spectral_dtype,
        accum_dtype=policy.accum_dtype,
        stabilizer=policy.stabilizer,
        spectral_is_half=policy.spectral_is_half,
    )


def _old_spectral_conv(params, x, modes, pol):
    """Reference: the seed's spectral_conv_apply, driven by flat fields."""
    from repro.core.spectral import _corner_slices, _corner_weight_ops, _out_channels

    ndim = len(modes)
    spatial = x.shape[2:]
    in_dtype = x.dtype
    if pol.spectral_is_half and pol.stabilizer:
        x = get_stabilizer(pol.stabilizer)(x)
    xf = jnp.fft.rfftn(x.astype(jnp.float32), axes=tuple(range(2, 2 + ndim)))
    if pol.spectral_is_half:
        xf = quantize_complex(xf, pol.spectral_dtype)
    corners = _corner_slices(modes, xf.shape[2:])
    out_f = jnp.zeros((x.shape[0], _out_channels(params), *xf.shape[2:]),
                      jnp.complex64)
    for c, sl in enumerate(corners):
        xc = xf[(slice(None), slice(None), *sl)]
        ops, expr = _corner_weight_ops(params, c, ndim)
        yc = contract(expr, xc, *ops, policy=pol)
        if isinstance(yc, ComplexPair):
            yc = yc.to_complex()
        out_f = out_f.at[(slice(None), slice(None), *sl)].set(
            yc.astype(jnp.complex64))
    y = jnp.fft.irfftn(out_f, s=spatial, axes=tuple(range(2, 2 + ndim)))
    if pol.spectral_is_half:
        y = y.astype(pol.spectral_dtype)
    return y.astype(in_dtype)


def _old_fno_apply(params, x, cfg, pol, spectral_pols=None):
    """Reference: the seed's fno_apply with flat-field casts.

    Mirrors the seed's structure exactly: a ``lax.scan`` block loop when
    every layer shares one flat policy (XLA fuses scan and unrolled
    bodies differently under bf16, so structure matters for bitwise
    comparison), and an unrolled loop when ``spectral_pols`` gives a
    per-layer flat policy (cross-checking per-site overrides, where the
    new API unrolls too).
    """
    B, spatial = x.shape[0], x.shape[2:]
    cdt = pol.compute_dtype
    if cfg.positional_embedding:
        from repro.models.fno import _positional_grid

        pos = jnp.broadcast_to(_positional_grid(spatial, x.dtype)[None],
                               (B, cfg.ndim, *spatial))
        x = jnp.concatenate([x, pos], axis=1)
    h = jnp.moveaxis(x, 1, -1)
    h = _linear(params["lift1"], h, cdt)
    h = jax.nn.gelu(h)
    h = _linear(params["lift2"], h, cdt)
    h = jnp.moveaxis(h, -1, 1).astype(cdt)

    def block(h, spect, skip, lpol):
        ldt = lpol.compute_dtype
        y = _old_spectral_conv(spect, h, cfg.modes, lpol).astype(ldt)
        s = jnp.moveaxis(_linear(skip, jnp.moveaxis(h, 1, -1), ldt), -1, 1)
        return jax.nn.gelu(y + s)

    if spectral_pols is None:
        h, _ = jax.lax.scan(
            lambda c, lp: (block(c, lp[0], lp[1], pol), None),
            h, (params["spectral"], params["skips"]),
        )
    else:
        for l in range(cfg.n_layers):
            spect = {k: v[l] for k, v in params["spectral"].items()}
            skip = {k: v[l] for k, v in params["skips"].items()}
            h = block(h, spect, skip, spectral_pols[l])
    h = jnp.moveaxis(h, 1, -1)
    h = _linear(params["proj1"], h, cdt)
    h = jax.nn.gelu(h)
    h = _linear(params["proj2"], h, jnp.float32)
    return jnp.moveaxis(h, -1, 1)


class TestBitIdentity:
    @pytest.fixture(scope="class")
    def fno_setup(self):
        # pinned to the einsum path: the frozen flat-policy reference
        # below predates the Pallas kernels, and this test is about rule
        # resolution being bit-identical, not about the kernel backend
        # (tests/test_kernels_diff.py owns pallas-vs-einsum)
        cfg = FNOConfig(in_channels=1, out_channels=1, hidden_channels=8,
                        lifting_channels=8, projection_channels=8,
                        n_layers=2, modes=(4, 4), use_pallas=False)
        params = init_fno(jax.random.PRNGKey(0), cfg)
        x = jnp.asarray(np.random.RandomState(0).randn(2, 1, 16, 16),
                        jnp.float32)
        return cfg, params, x

    @pytest.mark.parametrize("name", POLICY_NAMES)
    def test_fno_forward_bit_identical(self, fno_setup, name):
        cfg, params, x = fno_setup
        policy = get_policy(name)
        got = np.asarray(fno_apply(params, x, cfg, policy), np.float32)
        want = np.asarray(
            _old_fno_apply(params, x, cfg, _old_policy_view(policy)), np.float32
        )
        np.testing.assert_array_equal(got, want)

    @pytest.mark.parametrize("name", POLICY_NAMES)
    def test_lm_forward_identical_to_hand_built_rule_set(self, name):
        """A registry policy and the same rule set assembled by hand via
        with_rules produce identical logits — the registry really is just
        rules over the shared table."""
        cfg = get_config("smollm-360m", smoke=True)
        params = init_lm(jax.random.PRNGKey(1), cfg)
        toks = jnp.asarray(np.random.RandomState(1).randint(0, cfg.vocab, (1, 8)))
        policy = get_policy(name)
        rebuilt = FULL.with_rules(*policy.rules, name=f"rebuilt_{name}")
        la, _ = lm_forward(params, toks, cfg, policy)
        lb, _ = lm_forward(params, toks, cfg, rebuilt)
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# Per-site overrides the flat dataclass could not express
# ---------------------------------------------------------------------------


class TestPerSiteOverride:
    def test_last_fno_layer_forced_full(self):
        """Pin the last FNO layer to full precision under the mixed rule
        set — inexpressible with the old whole-model policy — and check
        the result against a per-layer flat-policy reference."""
        cfg = FNOConfig(in_channels=1, out_channels=1, hidden_channels=8,
                        lifting_channels=8, projection_channels=8,
                        n_layers=3, modes=(4, 4),
                        use_pallas=False)  # einsum-path reference below
        params = init_fno(jax.random.PRNGKey(2), cfg)
        x = jnp.asarray(np.random.RandomState(2).randn(2, 1, 16, 16),
                        jnp.float32)
        mixed = get_policy("mixed_fno_bf16")
        last = f"fno/layer{cfg.n_layers - 1}"

        y_mixed = np.asarray(fno_apply(params, x, cfg, mixed), np.float32)
        with precision_rules((f"{last}/*", FULL_PRECISION)):
            assert not layers_uniform(mixed, "fno", cfg.n_layers)
            y_over = np.asarray(fno_apply(params, x, cfg, mixed), np.float32)
        # outside the scope the layers are homogeneous again
        assert layers_uniform(mixed, "fno", cfg.n_layers)

        # reference: seed-style pipeline with a per-layer policy list
        mixed_flat = _old_policy_view(mixed)
        full_flat = _old_policy_view(get_policy("full"))
        # the override pins the layer's *dense* skip too, but lift/proj
        # stay at the mixed compute dtype
        full_flat.compute_dtype = jnp.float32
        pols = [mixed_flat, mixed_flat, full_flat]
        want = np.asarray(
            _old_fno_apply(params, x, cfg, mixed_flat, spectral_pols=pols),
            np.float32,
        )
        np.testing.assert_array_equal(y_over, want)
        assert not np.array_equal(y_over, y_mixed)

    def test_override_flips_loss_scaling(self):
        p = get_policy("mixed_fno_fp16")
        assert loss_scaling_required(p)
        with precision_rules(("train/loss_scale", SiteRule(loss_scaling=False))):
            assert not loss_scaling_required(p)

    def test_trainer_step_cache_keyed_by_override_scope(self):
        """A train step built under a precision_rules scope bakes those
        rules in at trace time; leaving the scope must rebuild the step
        rather than reuse the stale one (cache key includes the scope)."""
        from repro.train import Trainer, TrainerConfig, relative_l2

        cfg = FNOConfig(in_channels=1, out_channels=1, hidden_channels=8,
                        lifting_channels=8, projection_channels=8,
                        n_layers=1, modes=(4, 4))
        params = init_fno(jax.random.PRNGKey(4), cfg)
        rng = np.random.RandomState(4)
        batch = {"x": jnp.asarray(rng.randn(2, 1, 16, 16), jnp.float32),
                 "t": jnp.asarray(rng.randn(2, 1, 16, 16), jnp.float32)}

        def loss_fn(p, b, policy):
            return relative_l2(fno_apply(p, b["x"], cfg, policy), b["t"])

        sched = PrecisionSchedule.constant("mixed_fno_fp16")
        tr = Trainer(loss_fn, params, TrainerConfig(total_steps=4, schedule=sched))
        with precision_rules(("train/loss_scale", SiteRule(loss_scaling=False))):
            tr.run(lambda _s: batch, steps=1)
        assert tr.stats["recompiles"] == 1
        tr.run(lambda _s: batch)  # outside the scope: same name, new rules
        assert tr.stats["recompiles"] == 2


# ---------------------------------------------------------------------------
# Overlay schedule
# ---------------------------------------------------------------------------


class TestOverlaySchedule:
    def test_named_phases_return_registry_policies(self):
        s = PrecisionSchedule.paper_default("bf16")
        assert s.policy_at(0, 100).name == "mixed_fno_bf16"
        assert s.policy_at(99, 100).name == "full"

    def test_rule_overlay_phase(self):
        """A phase may be a raw rule overlay over the base — a partial-
        precision phase no whole-policy swap could express."""
        overlay = (
            ("*/spectral/contract", SiteRule(compute=jnp.bfloat16, quantize="half")),
        )
        s = PrecisionSchedule(phases=((0.5, overlay), (1.0, "full")))
        p0 = s.policy_at(0, 10)
        # only the contraction is half; the FFT boundary stays full
        assert p0.at("fno/layer0/spectral/contract").spectral_is_half
        assert not p0.at("fno/layer0/spectral/fft_in").spectral_is_half
        assert p0.name != "full"  # distinct step-cache key
        assert s.policy_at(9, 10).name == "full"

    def test_malformed_overlay_raises_early(self):
        with pytest.raises(TypeError):
            PrecisionSchedule(phases=((1.0, (("*/dense", "bf16"),)),))


# ---------------------------------------------------------------------------
# Simulated fp8 rule sets
# ---------------------------------------------------------------------------


class TestSimFP8:
    @pytest.mark.parametrize("name", ["sim_fp8_e4m3", "sim_fp8_e5m2"])
    def test_fft_in_quantizes_onto_fp8_grid(self, name):
        p = get_policy(name)
        site = p.at("fno/layer0/spectral/fft_in")
        rng = np.random.RandomState(0)
        c = jnp.asarray(rng.randn(32) + 1j * rng.randn(32), jnp.complex64)
        q = site.quantize(c)
        fmt = site.quantize_fmt
        # idempotent: the values already sit on the fp8 grid
        np.testing.assert_array_equal(
            np.asarray(simulate_fp8(jnp.real(q), fmt)), np.asarray(jnp.real(q))
        )
        # and it is a genuinely coarser grid than fp16
        assert np.abs(np.asarray(q) - np.asarray(c)).max() > 1e-3

    def test_fp8_fno_runs_finite(self):
        cfg = FNOConfig(in_channels=1, out_channels=1, hidden_channels=8,
                        lifting_channels=8, projection_channels=8,
                        n_layers=1, modes=(4, 4))
        params = init_fno(jax.random.PRNGKey(3), cfg)
        x = jnp.asarray(np.random.RandomState(3).randn(2, 1, 16, 16),
                        jnp.float32)
        y = fno_apply(params, x, cfg, get_policy("sim_fp8_e5m2"))
        assert np.isfinite(np.asarray(y, np.float32)).all()
