"""Operator serving: micro-batched FNO/SFNO field inference under the
same :class:`~repro.serve.engine.Engine` protocol as the LM engine.

This is PDE-inference-as-a-service — the deployment story the paper's
precision bounds actually pay for: each request carries one input field
``(C, *spatial)``; the engine groups the waiting queue into
*resolution buckets* (FNO weights are resolution-agnostic, but a fused
step needs one static spatial shape), admits up to ``max_batch``
same-resolution requests per tick through the scheduler policy, and
runs one jitted batched ``fno_infer`` / ``sfno_infer`` per bucket shape.

Because every op in the operator forward is per-sample independent
(batched GEMMs, FFTs, pointwise), micro-batching is *bit-identical* to
serving each field alone under the same precision policy — the property
the acceptance test pins down — so batching is purely a throughput knob.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PrecisionPolicy, FULL
from repro.models import fno_infer, sfno_infer
from repro.obs import trace as obs_trace

from .engine import EngineBase
from .paged.prefix import content_key
from .scheduler import Scheduler


@dataclasses.dataclass(eq=False)
class FieldRequest:
    """One operator-inference request: a single input field.  Identity
    semantics (``eq=False``): value comparison over the ndarray payload
    is both meaningless and ambiguous."""

    uid: int
    x: Any                        # (C, *spatial) array-like
    y: Optional[np.ndarray] = None
    status: str = "new"           # new | queued | running | done | failed
    error: Optional[str] = None
    submit_tick: int = -1
    start_tick: int = -1
    finish_tick: int = -1

    @property
    def done(self) -> bool:
        return self.status == "done"

    @property
    def resolution(self) -> Tuple[int, ...]:
        return tuple(np.shape(self.x)[1:])


class OperatorEngine(EngineBase):
    """Micro-batching engine over ``fno_infer`` / ``sfno_infer``.

    ``n_slots`` (the Engine protocol's slot pool) is the micro-batch
    width: each tick fills up to ``n_slots`` same-resolution requests
    into one fused batched forward.
    """

    kind = "operator"

    def __init__(
        self,
        params,
        cfg,
        model: str = "fno",
        policy: PrecisionPolicy = FULL,
        max_batch: int = 8,
        scheduler: str = "fcfs",
        telemetry: bool = False,
        autoprec=None,
        autoprec_every: int = 4,
        use_pallas: Optional[bool] = None,
        memo_window: int = 0,
        calibration_state: Optional[str] = None,
    ):
        if model not in ("fno", "sfno"):
            raise ValueError(f"model must be 'fno' or 'sfno', got {model!r}")
        # tuned spectral tiles: an explicit state path beats the
        # $REPRO_CALIBRATION_STATE env default; either way kernel tile
        # resolution (repro.kernels.ops) consults the calibration cache
        # and falls back to the static heuristic per miss
        self.calibration_state = calibration_state
        if calibration_state is not None:
            from repro.tune.cache import activate

            activate(calibration_state)
        super().__init__(
            Scheduler(
                scheduler,
                capacity_check=self._capacity_check,
                # spf for fields = smallest-grid-first
                cost=lambda r: float(np.prod(r.resolution, dtype=np.int64)),
            ),
            max_batch,
        )
        self.params = params
        # serving-side Pallas toggle: an explicit engine argument beats
        # the config's tri-state; the resolved flag is baked into every
        # per-resolution compiled step below
        from repro.kernels.ops import resolve_use_pallas

        self.use_pallas = resolve_use_pallas(
            use_pallas if use_pallas is not None else cfg.use_pallas)
        self.cfg = dataclasses.replace(cfg, use_pallas=self.use_pallas)
        self.model = model
        # online auto-precision: the controller owns the policy; its
        # telemetry comes from the same taps the trainer collects
        self.controller = autoprec
        self.policy = autoprec.policy() if autoprec is not None else policy
        self.max_batch = max_batch
        self.autoprec_every = autoprec_every
        self._telemetry_on = bool(telemetry or autoprec is not None)
        self._telem = None
        self._window_max_points = 0
        if self._telemetry_on:
            from repro.autoprec import TelemetryAggregator

            self._telem = TelemetryAggregator()
        self._infer = fno_infer if model == "fno" else sfno_infer
        self._steps: Dict[Tuple[int, ...], Any] = {}   # resolution -> jitted
        # content-hash memo: identical input fields (by value, under the
        # active policy) reuse the computed output instead of re-running
        # the forward.  Sound because inference is a pure function of
        # (params, field, policy) and micro-batching is per-sample exact
        # — a memoised answer is bit-identical to a recompute.  LRU over
        # the last ``memo_window`` distinct fields; 0 disables.
        self.memo_window = memo_window
        self._memo: OrderedDict[str, np.ndarray] = OrderedDict()
        self._memo_hits = 0
        self._memo_misses = 0
        self._memo_evictions = 0
        self._n_fields = 0
        self._n_points = 0
        self._n_batches = 0
        self._bucket_counts: Dict[str, int] = {}

    # -- admission -------------------------------------------------------------
    def _capacity_check(self, req: FieldRequest) -> Tuple[bool, str]:
        shape = tuple(np.shape(req.x))
        if len(shape) < 2:
            return False, f"field must be (channels, *spatial), got shape {shape}"
        if shape[0] != self.cfg.in_channels:
            return False, (
                f"field has {shape[0]} channels but the {self.model} config "
                f"expects {self.cfg.in_channels}"
            )
        if self.model == "sfno":
            want = (self.cfg.nlat, self.cfg.nlon)
            if shape[1:] != want:
                return False, (
                    f"sfno grid is fixed at {want}, got {shape[1:]}"
                )
        elif len(shape) - 1 != self.cfg.ndim:
            return False, (
                f"{self.cfg.ndim}-d FNO got a {len(shape) - 1}-d field"
            )
        return True, ""

    def _step_for(self, resolution: Tuple[int, ...]):
        fn = self._steps.get(resolution)
        if fn is None:
            policy = self.policy
            if self._telemetry_on:
                from repro.autoprec import TraceCollector, collecting

                def run(p, x):
                    col = TraceCollector()
                    with collecting(col):
                        y = self._infer(p, x, self.cfg, policy)
                    return y, col.snapshot()
            else:
                def run(p, x):
                    return self._infer(p, x, self.cfg, policy), {}
            fn = jax.jit(run)
            self._steps[resolution] = fn
        return fn

    # -- one engine tick -------------------------------------------------------
    def _busy(self) -> bool:
        return False  # fields finish within their tick; no carried state

    def _memo_partition(self, batch: List[FieldRequest]
                        ) -> Tuple[Optional[List[str]], List[int]]:
        """Split a bucket batch into memoised fields and the indices that
        still need compute.  In-batch duplicates collapse onto the first
        occurrence; only that one enters the device batch."""
        if self.memo_window <= 0:
            return None, list(range(len(batch)))
        keys = [content_key(np.asarray(r.x, np.float32)) for r in batch]
        compute: List[int] = []
        pending = set()
        for j, k in enumerate(keys):
            if k in self._memo:
                self._memo.move_to_end(k)
                self._memo_hits += 1
            elif k in pending:
                self._memo_hits += 1
            else:
                pending.add(k)
                self._memo_misses += 1
                compute.append(j)
        return keys, compute

    def _tick_impl(self) -> List[FieldRequest]:
        batch = self.scheduler.take(
            self.max_batch, self._ticks, bucket_key=lambda r: r.resolution)
        self._occupancy_sum += len(batch) / self.max_batch
        if not batch:
            return []
        res = batch[0].resolution
        keys, compute = self._memo_partition(batch)
        computed: Dict[str, np.ndarray] = {}
        if compute:
            xb = jnp.stack([jnp.asarray(batch[j].x, jnp.float32)
                            for j in compute])
            if len(compute) < self.max_batch:
                # pad to the fixed micro-batch width: one compiled kernel
                # per resolution (no recompiles as occupancy fluctuates),
                # and the per-sample outputs stay independent of batch
                # fill — a solo request and a full batch produce
                # bit-identical fields.
                pad = self.max_batch - len(compute)
                xb = jnp.concatenate([xb, jnp.zeros((pad, *xb.shape[1:]),
                                                    xb.dtype)])
            with obs_trace.span("serve/operator/batch",
                                resolution="x".join(map(str, res)),
                                fill=len(compute)):
                yb, telem = self._step_for(res)(self.params, xb)
                yb = np.asarray(yb)[:len(compute)]
            self._n_batches += 1
            if self._telem is not None:
                self._telem.update(telem)
                self._window_max_points = max(
                    self._window_max_points, int(np.prod(res, dtype=np.int64)))
            if (self.controller is not None
                    and self._n_batches % self.autoprec_every == 0):
                # budget against the finest grid the window saw: with mixed
                # resolution buckets, the Thm 3.1 bound of the finest field
                # is the binding one (coarser fields only have more headroom)
                changed = self.controller.update(
                    self._telem.take_window(),
                    grid_points=self._window_max_points or None)
                self._window_max_points = 0
                if changed:
                    # new overlay => new formats: drop the compiled buckets
                    # so the next tick traces under the updated policy —
                    # and the memo, whose entries were computed under the
                    # old formats
                    self.policy = self.controller.policy()
                    self._steps.clear()
                    self._memo.clear()
            if keys is None:
                computed = {str(j): yb[pos]
                            for pos, j in enumerate(compute)}
            else:
                computed = {keys[j]: yb[pos]
                            for pos, j in enumerate(compute)}
        key = "x".join(map(str, res))
        self._bucket_counts[key] = self._bucket_counts.get(key, 0) + len(batch)
        self._n_fields += len(batch)
        self._n_points += int(np.prod(res, dtype=np.int64)) * len(batch)
        finished = []
        for j, r in enumerate(batch):
            if keys is None:
                r.y = computed[str(j)]
            else:
                r.y = computed.get(keys[j], self._memo.get(keys[j]))
            finished.append(r)
        if keys is not None:
            # admit this tick's fresh results, then LRU-trim — after the
            # batch is answered, so an admission never evicts a key a
            # later request in the same tick still needs
            self._memo.update(computed)
            while len(self._memo) > self.memo_window:
                self._memo.popitem(last=False)
                self._memo_evictions += 1
        return finished

    def _extra_stats(self) -> Dict[str, Any]:
        out = {
            "model": self.model,
            "max_batch": self.max_batch,
            "policy": self.policy.name,
            "use_pallas": self.use_pallas,
            "fields_served": self._n_fields,
            "batches": self._n_batches,
            "avg_batch_fill": round(
                self._n_fields / (self._n_batches * self.max_batch), 4)
            if self._n_batches else 0.0,
            "buckets": dict(self._bucket_counts),
            "fields_per_s": round(self._n_fields / self._wall_s, 2)
            if self._wall_s else None,
            "points_per_s": round(self._n_points / self._wall_s, 2)
            if self._wall_s else None,
        }
        if self.memo_window > 0:
            seen = self._memo_hits + self._memo_misses
            out["memo"] = {
                "window": self.memo_window,
                "entries": len(self._memo),
                "hits": self._memo_hits,
                "misses": self._memo_misses,
                "hit_rate": round(self._memo_hits / seen, 4) if seen else 0.0,
                "evictions": self._memo_evictions,
            }
        if self._telem is not None:
            out["numerics"] = self._telem.counters()
        if self.controller is not None:
            out["autoprec"] = self.controller.describe()
        from repro.kernels.ops import tile_resolution_stats

        out["tiles"] = tile_resolution_stats()
        return out

    def _reset_extra_counters(self) -> None:
        """Memo + throughput counter hygiene (exposed through the obs
        registry's reset path; bench scripts call this between legs)."""
        self._memo_hits = 0
        self._memo_misses = 0
        self._memo_evictions = 0
        self._n_fields = 0
        self._n_points = 0
        self._n_batches = 0
        self._bucket_counts = {}
