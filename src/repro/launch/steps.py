"""Step builders: per (architecture × shape), construct the jittable step
function, its ShapeDtypeStruct input specs, and the sharding trees.

``train_*`` lowers a full optimizer step (fwd + bwd + AdamW update, grads
remat'd through the layer scan) so the dry-run's memory analysis covers
params + moments + activation working set.  ``prefill`` lowers the forward;
``decode`` lowers one serve step against a seq_len KV cache.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LMArchConfig, ShapeConfig
from repro.core import PrecisionPolicy, AMP_BF16
from repro.models.lm import (
    init_cache,
    init_lm,
    init_whisper,
    init_whisper_cache,
    lm_decode_step,
    lm_forward,
    lm_prefill_chunk,
    whisper_decode_step,
    whisper_encode,
    whisper_forward,
)
from repro.optim import AdamW
from repro.train.losses import cross_entropy


@dataclasses.dataclass
class StepBundle:
    """Everything the dry-run needs to lower one cell."""
    step_fn: Callable
    inputs: Dict[str, Any]           # name -> ShapeDtypeStruct pytree
    params_shape: Any                # ShapeDtypeStruct pytree
    extra_state_shape: Dict[str, Any]  # opt state / cache, ShapeDtypeStructs
    description: str
    #: True when step_fn carries an autoprec telemetry snapshot as its
    #: trailing output (bundle_shardings leaves its sharding unspecified)
    telemetry: bool = False


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _init_fn(cfg: LMArchConfig):
    return init_whisper if cfg.encoder_decoder else init_lm


def params_shape(cfg: LMArchConfig):
    """Parameter ShapeDtypeStructs without allocating (eval_shape)."""
    return jax.eval_shape(lambda k: _init_fn(cfg)(k, cfg), jax.random.PRNGKey(0))


def _remat(fn):
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)


def _loss_fn(cfg: LMArchConfig, policy: PrecisionPolicy):
    if cfg.encoder_decoder:
        def loss(params, batch):
            logits = whisper_forward(params, batch["frames"], batch["dec_tokens"],
                                     cfg, policy, remat=True)
            return cross_entropy(logits, batch["dec_labels"])
        return loss
    if cfg.frontend == "vision_stub":
        def loss(params, batch):
            logits, aux = lm_forward(params, batch["tokens"], cfg, policy,
                                     patch_embeds=batch["patch_embeds"], remat=True)
            logits = logits[:, cfg.n_patches:]
            return cross_entropy(logits, batch["labels"]) + 0.01 * aux
        return loss

    def loss(params, batch):
        logits, aux = lm_forward(params, batch["tokens"], cfg, policy, remat=True)
        return cross_entropy(logits, batch["labels"]) + 0.01 * aux
    return loss


def train_inputs(cfg: LMArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    if cfg.encoder_decoder:
        T = cfg.max_dec_len
        return {
            "frames": _sds((B, S, cfg.d_model), jnp.bfloat16),
            "dec_tokens": _sds((B, T), jnp.int32),
            "dec_labels": _sds((B, T), jnp.int32),
        }
    if cfg.frontend == "vision_stub":
        S_text = S - cfg.n_patches
        return {
            "tokens": _sds((B, S_text), jnp.int32),
            "labels": _sds((B, S_text), jnp.int32),
            "patch_embeds": _sds((B, cfg.n_patches, cfg.d_model), jnp.bfloat16),
        }
    return {
        "tokens": _sds((B, S), jnp.int32),
        "labels": _sds((B, S), jnp.int32),
    }


def build_train_step(cfg: LMArchConfig, shape: ShapeConfig,
                     policy: PrecisionPolicy = AMP_BF16,
                     optimizer: Optional[AdamW] = None,
                     telemetry: bool = False) -> StepBundle:
    opt = optimizer or AdamW(lr=1e-4)
    loss_fn = _loss_fn(cfg, policy)

    if telemetry:
        # autoprec-instrumented twin: numerics taps collected inside the
        # differentiated loss ride out as a trailing step output (the
        # dry-runs lower both variants and record the overhead)
        def train_step(params, opt_state, batch):
            from repro.autoprec import TraceCollector, collecting

            def instrumented(p, b):
                col = TraceCollector()
                with collecting(col):
                    loss = loss_fn(p, b)
                return loss, col.snapshot()

            (loss, telem), grads = jax.value_and_grad(
                instrumented, has_aux=True)(params, batch)
            new_params, new_opt = opt.update(grads, opt_state, params)
            return new_params, new_opt, loss, telem
    else:
        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            new_params, new_opt = opt.update(grads, opt_state, params)
            return new_params, new_opt, loss

    p_shape = params_shape(cfg)
    opt_shape = jax.eval_shape(opt.init, p_shape)
    return StepBundle(
        step_fn=train_step,
        inputs={"batch": train_inputs(cfg, shape)},
        params_shape=p_shape,
        extra_state_shape={"opt_state": opt_shape},
        description=f"train_step {cfg.name} {shape.name}"
                    + (" [telemetry]" if telemetry else ""),
        telemetry=telemetry,
    )


def build_prefill_step(cfg: LMArchConfig, shape: ShapeConfig,
                       policy: PrecisionPolicy = AMP_BF16) -> StepBundle:
    B, S = shape.global_batch, shape.seq_len
    if cfg.encoder_decoder:
        def prefill(params, batch):
            memory = whisper_encode(params, batch["frames"], cfg, policy)
            return memory
        inputs = {"batch": {"frames": _sds((B, S, cfg.d_model), jnp.bfloat16)}}
    elif cfg.frontend == "vision_stub":
        def prefill(params, batch):
            logits, _ = lm_forward(params, batch["tokens"], cfg, policy,
                                   patch_embeds=batch["patch_embeds"])
            return logits[:, -1]
        inputs = {"batch": {
            "tokens": _sds((B, S - cfg.n_patches), jnp.int32),
            "patch_embeds": _sds((B, cfg.n_patches, cfg.d_model), jnp.bfloat16),
        }}
    else:
        def prefill(params, batch):
            logits, _ = lm_forward(params, batch["tokens"], cfg, policy)
            return logits[:, -1]
        inputs = {"batch": {"tokens": _sds((B, S), jnp.int32)}}
    return StepBundle(
        step_fn=prefill,
        inputs=inputs,
        params_shape=params_shape(cfg),
        extra_state_shape={},
        description=f"prefill {cfg.name} {shape.name}",
    )


def build_decode_step(cfg: LMArchConfig, shape: ShapeConfig,
                      policy: PrecisionPolicy = AMP_BF16) -> StepBundle:
    B, S = shape.global_batch, shape.seq_len
    p_shape = params_shape(cfg)
    if cfg.encoder_decoder:
        # decode against a seq_len-frame encoder memory (cross-KV cached)
        cache_shape = jax.eval_shape(
            lambda p: init_whisper_cache(
                p, jnp.zeros((B, S, cfg.d_model), jnp.bfloat16), cfg, B, policy),
            p_shape,
        )

        def serve_step(params, cache, tokens):
            return whisper_decode_step(params, cache, tokens, cfg, policy)
    else:
        cache_shape = jax.eval_shape(
            lambda: init_cache(cfg, B, S,
                               dtype=policy.at("serve/kv_cache").compute_dtype))

        def serve_step(params, cache, tokens):
            return lm_decode_step(params, cache, tokens, cfg, policy)

    return StepBundle(
        step_fn=serve_step,
        inputs={"cache": cache_shape, "tokens": _sds((B,), jnp.int32)},
        params_shape=p_shape,
        extra_state_shape={},
        description=f"serve_step {cfg.name} {shape.name} (KV len {S})",
    )


def build_prefill_chunk_step(cfg: LMArchConfig, shape: ShapeConfig,
                             policy: PrecisionPolicy = AMP_BF16,
                             chunk: int = 16) -> StepBundle:
    """The serve engine's chunked-prefill step against a seq_len KV cache:
    (B, chunk) pending prompt tokens with per-slot valid lengths.  This is
    what a prefill-heavy serving tick lowers to — the dry-run records it
    next to the one-token decode step so the roofline shows the
    arithmetic-intensity win of chunking."""
    if cfg.encoder_decoder:
        raise ValueError("chunked prefill targets the decoder-only cache path")
    B, S = shape.global_batch, shape.seq_len
    p_shape = params_shape(cfg)
    cache_shape = jax.eval_shape(
        lambda: init_cache(cfg, B, S,
                           dtype=policy.at("serve/kv_cache").compute_dtype))

    def chunk_step(params, cache, tokens, n_valid):
        return lm_prefill_chunk(params, cache, tokens, n_valid, cfg, policy)

    return StepBundle(
        step_fn=chunk_step,
        inputs={"cache": cache_shape,
                "tokens": _sds((B, chunk), jnp.int32),
                "n_valid": _sds((B,), jnp.int32)},
        params_shape=p_shape,
        extra_state_shape={},
        description=f"prefill_chunk[{chunk}] {cfg.name} {shape.name} (KV len {S})",
    )


def build_step(cfg: LMArchConfig, shape: ShapeConfig,
               policy: PrecisionPolicy = AMP_BF16) -> StepBundle:
    if shape.kind == "train":
        return build_train_step(cfg, shape, policy)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, policy)
    return build_decode_step(cfg, shape, policy)


# ---------------------------------------------------------------------------
# Sharding derivation — every consumer of a StepBundle (dry-run, launch,
# serving) gets its NamedShardings from the repro.dist rule tables here.
# ---------------------------------------------------------------------------


def opt_specs(opt_shape: Any, param_specs: Any) -> Any:
    """Optimizer-state specs mirror the parameter specs (AdamW moments
    are param-shaped; the step count replicates)."""
    from jax.sharding import PartitionSpec as P
    from repro.optim import AdamWState

    del opt_shape  # structure is implied by AdamWState
    return AdamWState(count=P(), mu=param_specs, nu=param_specs)


def bundle_shardings(bundle: StepBundle, cfg: LMArchConfig, mesh,
                     param_specs: Any = None) -> Tuple[Any, Any]:
    """(in_shardings, out_shardings) for ``bundle.step_fn`` on ``mesh``,
    derived entirely from the ``repro.dist`` rule tables.

    ``param_specs`` lets a caller that already derived the parameter
    specs (e.g. for a replication report) pass them in instead of
    re-walking the tree.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.dist.sharding import (
        batch_specs,
        cache_specs,
        lm_param_specs,
        to_named,
    )

    if param_specs is None:
        param_specs = lm_param_specs(bundle.params_shape, mesh)
    p_named = to_named(mesh, param_specs)
    scalar = NamedSharding(mesh, P())
    if "opt_state" in bundle.extra_state_shape:      # train step
        o_named = to_named(
            mesh, opt_specs(bundle.extra_state_shape["opt_state"], param_specs))
        b_named = to_named(mesh, batch_specs(bundle.inputs["batch"], mesh))
        outs = (p_named, o_named, scalar)
        if bundle.telemetry:                          # trailing snapshot
            outs = outs + (None,)
        return (p_named, o_named, b_named), outs
    if "cache" in bundle.inputs:                     # decode / prefill-chunk
        c_named = to_named(mesh, cache_specs(bundle.inputs["cache"], mesh, cfg))
        t_named = to_named(mesh, batch_specs(bundle.inputs["tokens"], mesh))
        if "n_valid" in bundle.inputs:
            n_named = to_named(mesh, batch_specs(bundle.inputs["n_valid"], mesh))
            return (p_named, c_named, t_named, n_named), (None, c_named)
        return (p_named, c_named, t_named), (None, c_named)
    b_named = to_named(mesh, batch_specs(bundle.inputs["batch"], mesh))
    return (p_named, b_named), None                  # prefill
