"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Usage:
    PYTHONPATH=src python -m benchmarks.run [--only substring]
"""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from . import bench_paper_tables as bp

    print("name,us_per_call,derived")
    failures = 0
    for fn in bp.ALL:
        if args.only and args.only not in fn.__name__:
            continue
        try:
            fn()
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{fn.__name__},0.0,ERROR")
    # roofline summary (reads dry-run artifacts if present)
    try:
        from .roofline_report import rows
        for r in rows():
            print("roofline_" + r[0] + "_" + r[1] + ",0.0," + " ".join(map(str, r[2:])))
    except Exception:
        traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
