"""repro.serve — the Engine serving API.

One protocol (``submit / tick / drain / stats``) over the engines:
:class:`LMEngine` (continuous-batching LM decode with chunked batched
prefill and per-request sampling), :class:`PagedLMEngine` (the same
engine over a paged KV-block cache with copy-on-write prefix sharing),
and :class:`OperatorEngine` (micro-batched FNO/SFNO field inference in
resolution buckets with content-hash memoisation), all fed by a shared
:class:`Scheduler` (FCFS / shortest-prompt-first with capacity
rejection).  :class:`AsyncServeFrontend` puts ``submit_async`` /
``stream`` coroutines with deadline accounting in front of any engine's
tick loop.  ``ServeEngine`` is the pre-v2 alias of ``LMEngine``.
"""
from .engine import Engine, EngineBase, LMEngine, Request, ServeEngine  # noqa: F401
from .operator import FieldRequest, OperatorEngine  # noqa: F401
from .paged import (  # noqa: F401
    AsyncServeFrontend,
    BlockPool,
    PagedLMEngine,
    PrefixIndex,
    content_key,
)
from .sampler import (  # noqa: F401
    GREEDY,
    SamplingParams,
    request_key,
    sample_token,
)
from .scheduler import POLICIES, Scheduler  # noqa: F401
