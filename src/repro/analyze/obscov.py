"""Obs-coverage pass: find hand-rolled counters invisible to repro.obs.

The observability layer's contract is that ``repro.obs.registry()``'s
``snapshot()`` is the single source of runtime statistics: every counter
a subsystem keeps must either live in the registry directly, be adopted
via ``register_external``, or be flattened into gauges through
``publish()`` by the code path that owns it.  A ``self._hits += 1``
in a module that never touches ``repro.obs`` is a stat that silently
falls outside every snapshot, Prometheus scrape and run log.

One check:

  counter-outside-registry (warning)  an ``x += ...`` on a counter-named
      ``self`` attribute (``_n_*``, ``*_hits``, ``*_total``, ...) in a
      module under the instrumented subtrees (serve / train / kernels /
      tune / autoprec) that never imports or references ``repro.obs``.
      Modules that do reference ``repro.obs`` are trusted to route their
      counters (that is the wiring convention this pass enforces);
      intentionally-internal tallies are reviewed into ``analyze.toml``.

Findings are per (file, attribute): the first mutation site of each
attribute is reported, not every increment.
"""
from __future__ import annotations

import ast
import os
import re
from typing import List, Optional, Tuple

from .findings import WARNING, Finding

#: Subtrees (relative to the source root's ``repro`` package) whose
#: modules are expected to route counters through the obs registry.
INSTRUMENTED_SUBTREES = ("serve", "train", "kernels", "tune", "autoprec")

#: Attribute-name shape that marks an integer tally (as opposed to an
#: accumulator like ``_wall_s`` or a cursor like ``_pos``).
_COUNTER_RE = re.compile(
    r"(^|_)(n|num|count|counts|total|totals|hits|hit|misses|miss|stale|"
    r"evictions|drops|dropped|ticks|calls|rejects|rejected|overflows?|"
    r"streaks?)(_|$)")


def _references_obs(tree: ast.AST) -> bool:
    """True if the module imports ``repro.obs`` (any spelling)."""
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            if any(a.name == "repro.obs" or a.name.startswith("repro.obs.")
                   for a in node.names):
                return True
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if mod == "repro.obs" or mod.startswith("repro.obs."):
                return True
            if mod == "repro" and any(a.name == "obs" for a in node.names):
                return True
    return False


def _counter_attr(target: ast.expr) -> Optional[str]:
    """The counter-like ``self`` attribute a ``+=`` target mutates, or
    None.  Covers ``self._hits += 1`` and ``self._counts[k] += 1``."""
    if isinstance(target, ast.Subscript):
        target = target.value
    if (isinstance(target, ast.Attribute)
            and isinstance(target.value, ast.Name)
            and target.value.id == "self"
            and _COUNTER_RE.search(target.attr)):
        return target.attr
    return None


def _module_counter_mutations(tree: ast.AST) -> List[Tuple[int, str]]:
    """(lineno, attr) of the first ``+=`` per counter-like attribute."""
    first: dict = {}
    for node in ast.walk(tree):
        if not (isinstance(node, ast.AugAssign)
                and isinstance(node.op, ast.Add)):
            continue
        attr = _counter_attr(node.target)
        if attr is not None and attr not in first:
            first[attr] = node.lineno
    return sorted((lineno, attr) for attr, lineno in first.items())


def obs_coverage_pass(src_root: str) -> List[Finding]:
    """Scan the instrumented subtrees under ``src_root`` (the directory
    containing the ``repro`` package)."""
    findings: List[Finding] = []
    for subtree in INSTRUMENTED_SUBTREES:
        root = os.path.join(src_root, "repro", subtree)
        if not os.path.isdir(root):
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = sorted(
                d for d in dirnames
                if d not in ("__pycache__", ".git", ".ruff_cache"))
            for fn in sorted(filenames):
                if not fn.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fn)
                with open(path, "r", encoding="utf-8") as fh:
                    tree = ast.parse(fh.read(), filename=path)
                if _references_obs(tree):
                    continue
                rel = os.path.relpath(path, src_root)
                for lineno, attr in _module_counter_mutations(tree):
                    findings.append(Finding(
                        pass_name="obs", check="counter-outside-registry",
                        severity=WARNING, site=None,
                        where=f"{rel}:{lineno}",
                        detail=f"counter {attr!r} is mutated in a module "
                               f"that never references repro.obs — it is "
                               f"invisible to registry().snapshot(); route "
                               f"it via publish()/register_external or "
                               f"review it into analyze.toml",
                    ))
    return findings
